"""Sharding rules on abstract meshes (no devices needed): TP/FSDP/EP
placement, divisibility fallbacks, batch/cache rules."""
import jax
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs.registry import get_config
from repro.launch.mesh import make_abstract_mesh
from repro.launch.sharding import spec_partition, cache_shardings, \
    batch_sharding
from repro.models import api
from repro.models.common import ParamSpec, tree_paths

POD = make_abstract_mesh((16, 16), ("data", "model"))
MULTI = make_abstract_mesh((2, 16, 16), ("pod", "data", "model"))


def test_tp_rules():
    s = ParamSpec((4096, 14336), ("embed", "mlp"))
    assert spec_partition(POD, s) == P("data", "model")
    s = ParamSpec((4096, 32, 128), ("embed", "heads", None))
    assert spec_partition(POD, s) == P("data", "model", None)


def test_divisibility_fallback():
    # qwen1.5-4b: 20 heads on a 16-way model axis -> replicated heads dim
    s = ParamSpec((2560, 20, 128), ("embed", "heads", None))
    assert spec_partition(POD, s) == P("data", None, None)
    # 20*128=2560 fused would divide, but per-spec dims don't — fallback


def test_experts_rule():
    s = ParamSpec((16, 6144, 10752), ("experts", "embed", "mlp"))
    part = spec_partition(POD, s)
    assert part[0] == "model"          # EP over model axis
    assert part[1] == "data"           # expert-internal FSDP
    assert part[2] is None             # model already used by experts


def test_no_axis_reuse_within_param():
    cfg = get_config("qwen1.5-110b")
    specs = api.specs(cfg)
    for path, spec in tree_paths(specs):
        part = spec_partition(POD, spec)
        used = [a for a in jax.tree.leaves(tuple(part)) if a]
        flat = []
        for a in used:
            flat.extend(a if isinstance(a, tuple) else (a,))
        assert len(flat) == len(set(flat)), (path, part)


def test_fsdp_toggle():
    s = ParamSpec((4096, 14336), ("embed", "mlp"))
    assert spec_partition(POD, s, fsdp=False) == P(None, "model")


@pytest.mark.parametrize("arch", ["qwen1.5-110b", "mixtral-8x7b",
                                  "rwkv6-7b", "recurrentgemma-9b"])
def test_every_param_gets_some_sharding_on_pod(arch):
    """At 110B scale every big tensor must shard somewhere; guard the
    bytes-per-chip budget analytically."""
    cfg = get_config(arch)
    specs = api.specs(cfg)
    per_chip = 0
    for path, spec in tree_paths(specs):
        part = spec_partition(POD, spec)
        n = int(np.prod(spec.shape)) * 2      # bf16
        div = 1
        for axes in part:
            if axes is None:
                continue
            for a in (axes if isinstance(axes, tuple) else (axes,)):
                div *= dict(POD.shape)[a]
        per_chip += n // div
    # replicated parameter residue must fit comfortably in HBM
    assert per_chip < 4e9, (arch, per_chip / 1e9)


def test_cache_shardings_decode32k_110b():
    cfg = get_config("qwen1.5-110b")
    cache = api.cache_specs(cfg, 128, 32768)
    sh = cache_shardings(POD, cache)
    spec = sh["k"].spec
    # (L, B, S, KV, Dh): batch over data; seq or kv over model
    assert spec[1] == "data"
    assert "model" in jax.tree.leaves(tuple(spec)), spec
    # bytes per chip bounded
    n = np.prod([80, 128, 32768, 8, 128]) * 2 / (16 * 16)
    assert n < 3e9


def test_batch_sharding_rules():
    toks = jax.ShapeDtypeStruct((256, 4096), jax.numpy.int32)
    sh = batch_sharding(MULTI, {"tokens": toks})
    assert sh["tokens"].spec[0] == ("pod", "data")
    small = jax.ShapeDtypeStruct((3, 4), jax.numpy.int32)
    sh = batch_sharding(MULTI, {"x": small})
    assert sh["x"].spec == P()        # indivisible -> replicated
