"""MoE dispatch: capacity math, identical-experts equivalence, drops,
aux-loss behaviour."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs.base import MoEConfig
from repro.configs.registry import get_config
from repro.models import moe as moe_mod
from repro.models.layers import apply_mlp


def test_capacity_lane_aligned():
    cfg = get_config("mixtral-8x7b-reduced")
    c = moe_mod.capacity(1024, cfg)
    assert c % 128 == 0 and c >= 1024 * cfg.moe.top_k / cfg.moe.num_experts


def test_identical_experts_equal_dense(rng):
    """If all experts share weights, MoE output == that expert's SwiGLU
    regardless of routing (dropless case) — the strongest dispatch test."""
    cfg = get_config("mixtral-8x7b-reduced")
    d, f, e = cfg.d_model, cfg.d_ff, cfg.moe.num_experts
    wi = jax.random.normal(jax.random.fold_in(rng, 1), (d, f)) * 0.05
    wg = jax.random.normal(jax.random.fold_in(rng, 2), (d, f)) * 0.05
    wo = jax.random.normal(jax.random.fold_in(rng, 3), (f, d)) * 0.05
    p = {
        "router": jax.random.normal(jax.random.fold_in(rng, 4), (d, e)),
        "wi": jnp.broadcast_to(wi, (e, d, f)),
        "wg": jnp.broadcast_to(wg, (e, d, f)),
        "wo": jnp.broadcast_to(wo, (e, f, d)),
    }
    x = jax.random.normal(jax.random.fold_in(rng, 5), (2, 8, d)) * 0.5
    out, aux = moe_mod.apply_moe(p, x, cfg)
    dense = apply_mlp({"wi": wi, "wg": wg, "wo": wo}, x, "swiglu")
    np.testing.assert_allclose(np.asarray(out), np.asarray(dense),
                               atol=2e-4, rtol=2e-3)
    assert np.isfinite(float(aux))


def test_router_weights_normalized(rng):
    cfg = get_config("dbrx-132b-reduced")
    d = cfg.d_model
    x = jax.random.normal(rng, (1, 4, d))
    p_zero = {
        "router": jnp.zeros((d, cfg.moe.num_experts)),
        "wi": jnp.zeros((cfg.moe.num_experts, d, cfg.d_ff)),
        "wg": jnp.zeros((cfg.moe.num_experts, d, cfg.d_ff)),
        "wo": jnp.zeros((cfg.moe.num_experts, cfg.d_ff, d)),
    }
    out, _ = moe_mod.apply_moe(p_zero, x, cfg)
    np.testing.assert_array_equal(np.asarray(out), 0.0)


def test_overflow_drops_not_crash(rng):
    """Push all tokens to one expert: over-capacity assignments must drop
    silently (scatter mode=drop), output stays finite."""
    cfg = get_config("mixtral-8x7b-reduced")
    d, e = cfg.d_model, cfg.moe.num_experts
    p = {
        "router": jnp.zeros((d, e)).at[:, 0].set(100.0),  # everyone -> e0
        "wi": jax.random.normal(rng, (e, d, cfg.d_ff)) * 0.05,
        "wg": jax.random.normal(rng, (e, d, cfg.d_ff)) * 0.05,
        "wo": jax.random.normal(rng, (e, cfg.d_ff, d)) * 0.05,
    }
    x = jax.random.normal(rng, (4, 64, d))
    out, aux = moe_mod.apply_moe(p, x, cfg)
    assert np.isfinite(np.asarray(out)).all()
    assert float(aux) > 0.01           # load-balance loss fires
