"""Event-loop serving subsystem units: channel affinity invariants, poll
strategies, round-robin assignment, structured failure records, the
restart seam, elastic reshard properties, percentile helpers, RTT bench
rows."""
import numpy as np
import pytest

from benchmarks.common import (PERCENTILE_QS, percentile_rows, percentiles)
from repro.launch.elastic import reshard_affinity
from repro.serving.event_loop import (EventLoop, EventLoopGroup,
                                      LoopFailure, Poller, PollStats,
                                      channel_affinity)


# ---------------------------------------------------------------------------
# Channel affinity (the ownership invariant)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("n_channels,n_loops", [(4, 1), (4, 2), (4, 4),
                                                (8, 3), (5, 2), (16, 4)])
def test_affinity_disjoint_contiguous_covering(n_channels, n_loops):
    """Every loop owns a non-empty CONTIGUOUS run; runs are disjoint,
    cover the whole pool, and are balanced to within one channel."""
    groups = channel_affinity(n_channels, n_loops)
    assert len(groups) == n_loops
    flat = [c for g in groups for c in g]
    assert sorted(flat) == list(range(n_channels))      # disjoint + cover
    for g in groups:
        assert g, "a loop must own at least one channel"
        assert list(g) == list(range(min(g), max(g) + 1))   # contiguous
    sizes = [len(g) for g in groups]
    assert max(sizes) - min(sizes) <= 1


def _affinity_domain():
    """Fixed-grid enumeration of the valid (n_channels, n_loops, n_pods,
    leaders, leader_loops) domain — the no-hypothesis property-test
    convention (see test_tac_core.py). ~360 cases."""
    cases = []
    for n_channels in (2, 3, 4, 6, 8, 12, 16):
        for n_loops in (1, 2, 3, 4):
            for n_pods in (1, 2, 4):
                for leaders in (0, 1, 2):
                    n_local = n_channels - leaders
                    if leaders == 0:
                        # the flat fabric has no pod structure in its
                        # emission — pod alignment is a property of the
                        # topology-aware (leaders > 0) form only
                        if n_pods == 1 and n_loops <= n_channels:
                            cases.append((n_channels, n_loops, 1, 0, 1))
                        continue
                    if n_local < 1 or n_loops > n_local:
                        continue
                    for leader_loops in (1, 2):
                        if 1 <= leader_loops <= n_loops:
                            cases.append((n_channels, n_loops, n_pods,
                                          leaders, leader_loops))
    return cases


@pytest.mark.parametrize("n_channels,n_loops,n_pods,leaders,leader_loops",
                         _affinity_domain())
def test_affinity_property_grid(n_channels, n_loops, n_pods, leaders,
                                leader_loops):
    """Property test over the whole valid domain: the partition is
    disjoint + covering, each loop's LOCAL run is contiguous, local runs
    are pod-aligned (a run overlapping a partial pod block stays inside
    that block), and leader lanes appear ONLY on the first
    min(leader_loops, leaders) loops."""
    groups = channel_affinity(n_channels, n_loops, n_pods=n_pods,
                              leaders=leaders, leader_loops=leader_loops)
    assert len(groups) == n_loops
    flat = [c for g in groups for c in g]
    assert sorted(flat) == list(range(n_channels))      # disjoint + cover
    n_local = n_channels - leaders
    lead_lanes = set(range(n_local, n_channels))
    # pod blocks = the ready_groups partition of the LOCAL pool — the
    # same (independently tested) primitive pod_aligned_groups blocks on
    from repro.core import selector
    blocks = selector.ready_groups(n_local, max(1, min(n_pods, n_local)))
    for i, g in enumerate(groups):
        local = [c for c in g if c not in lead_lanes]
        assert local, "every loop owns at least one local channel"
        assert list(local) == list(range(min(local), max(local) + 1))
        # pod alignment: a run inside any pod block never leaks past it
        for blk in blocks:
            inside = [c for c in local if c in blk]
            if inside and len(inside) != len(local):
                # straddling is only legal at whole-block granularity:
                # the overlap must BE the whole block
                assert inside == list(blk), (
                    f"loop {i} local run {local} straddles pod block "
                    f"{list(blk)} partially")
        owned_leads = [c for c in g if c in lead_lanes]
        if i >= min(leader_loops, leaders):
            assert not owned_leads, \
                f"non-leader loop {i} owns leader lanes {owned_leads}"
    owned_all_leads = [c for g in groups[:max(1, min(leader_loops, leaders))]
                       for c in g if c in lead_lanes]
    assert sorted(owned_all_leads) == sorted(lead_lanes)


def test_affinity_rejects_more_loops_than_channels():
    with pytest.raises(ValueError, match="own at least one channel"):
        channel_affinity(2, 3)


def test_group_rejects_overlapping_ownership():
    loops = [EventLoop(0, channels=(0, 1)), EventLoop(1, channels=(1, 2))]
    with pytest.raises(AssertionError, match="disjoint"):
        EventLoopGroup(loops)


# ---------------------------------------------------------------------------
# Poll strategies
# ---------------------------------------------------------------------------


class _Handle:
    """A completion handle that becomes ready after N probes."""

    def __init__(self, ready_after: int):
        self._left = ready_after
        self.blocked = False

    def is_ready(self):
        self._left -= 1
        return self._left <= 0

    def block_until_ready(self):
        self.blocked = True
        self._left = 0


def test_busy_poll_spins_never_parks():
    p = Poller("busy")
    h = _Handle(ready_after=5)
    p.wait([h])
    assert p.stats.parks == 0 and p.stats.spins >= 1 and p.stats.waits == 1
    assert not h.blocked


def test_park_blocks_never_spins():
    p = Poller("park")
    h = _Handle(ready_after=100)
    p.wait([h])
    assert p.stats.parks == 1 and p.stats.spins == 0
    assert h.blocked


def test_adaptive_spins_then_parks_on_slow_completion():
    p = Poller("adaptive", spin_s=0.0)          # zero budget: park at once
    h = _Handle(ready_after=10**9)
    p.wait([h])
    assert h.blocked and p.stats.parks == 1
    # a fast completion is absorbed by the spin phase
    p2 = Poller("adaptive", spin_s=10.0)
    h2 = _Handle(ready_after=3)
    p2.wait([h2])
    assert not h2.blocked and p2.stats.parks == 0 and p2.stats.spins >= 1


def test_poller_ignores_non_array_leaves():
    p = Poller("busy")
    p.wait({"a": 1, "b": [2.0, "x"]})            # nothing to wait on
    assert p.stats.waits == 1 and p.stats.spins == 0


def test_poll_stats_merge():
    a, b = PollStats(1, 2, 3, 4, 5), PollStats(10, 20, 30, 40, 50)
    m = a.merge(b)
    assert (m.spins, m.parks, m.waits, m.stalls, m.delays) == \
        (11, 22, 33, 44, 55)


def test_poller_fault_delay_verdict_counts_delays():
    """A fault returning "delay" is counted in ``delays`` (the
    supervisor's slow-channel health signal) but neither stalls nor
    parks — the wait proceeds normally."""
    p = Poller("busy")
    p.fault = lambda poller: "delay"
    p.wait([_Handle(ready_after=1)])
    p.wait([_Handle(ready_after=1)])
    assert p.stats.delays == 2
    assert p.stats.stalls == 0 and p.stats.parks == 0
    assert p.stats.waits == 2


def test_adaptive_zero_spin_budget_goes_straight_to_park():
    """spin_s=0 IS park: exactly one park, ZERO spins — no probe burned
    before the epoll fallback."""
    p = Poller("adaptive", spin_s=0.0)
    h = _Handle(ready_after=10**9)
    p.wait([h])
    assert h.blocked
    assert (p.stats.spins, p.stats.parks, p.stats.waits) == (0, 1, 1)
    # negative budgets behave identically (no busy window to honor)
    p2 = Poller("adaptive", spin_s=-1.0)
    p2.wait([_Handle(ready_after=10**9)])
    assert (p2.stats.spins, p2.stats.parks) == (0, 1)


@pytest.mark.parametrize("poll,ready_after,spins_bound,parks", [
    ("busy", 1, (0, 0), 0),      # ready on first probe: no spin, no park
    ("busy", 4, (3, 3), 0),      # N-1 not-ready probes, never parks
    ("park", 1, (0, 0), 1),      # park never probes
    ("adaptive", 1, (0, 0), 0),  # absorbed by the spin phase
])
def test_poller_counter_boundary_invariants(poll, ready_after, spins_bound,
                                            parks):
    p = Poller(poll, spin_s=10.0)
    h = _Handle(ready_after=ready_after)
    p.wait([h])
    lo, hi = spins_bound
    assert lo <= p.stats.spins <= hi
    assert p.stats.parks == parks
    assert p.stats.waits == 1
    assert p.stats.stalls == 0           # no fault installed, ever


def test_poller_fault_seam_delay_and_stall():
    """The chaos seam: a fault hook may observe every wait (and sleep),
    and returning "stall" forces one counted over-park regardless of the
    strategy — the only path that increments ``stalls``."""
    calls = []

    def fault(poller):
        calls.append(poller.stats.waits)
        return "stall" if len(calls) == 2 else None

    p = Poller("busy")
    p.fault = fault
    p.wait([_Handle(ready_after=1)])      # fault consulted, no stall
    h = _Handle(ready_after=10**9)
    p.wait([h])                           # forced over-park
    assert calls == [1, 2]
    assert p.stats.stalls == 1 and p.stats.parks == 1
    assert h.blocked


# ---------------------------------------------------------------------------
# Run queues + round-robin assignment
# ---------------------------------------------------------------------------


def test_round_robin_submit_and_drain():
    seen = {}

    def runner(loop, items):
        seen.setdefault(loop.index, []).extend(items)
        return [(loop.index, it) for it in items]

    loops = [EventLoop(i, channels=(i,), runner=runner) for i in range(3)]
    grp = EventLoopGroup(loops)
    grp.submit(list(range(7)))
    out = grp.run(threads=False)
    # paper §IV-C: connections land on loops round-robin
    assert seen == {0: [0, 3, 6], 1: [1, 4], 2: [2, 5]}
    assert len(out) == 7


def test_threaded_drain_matches_inline():
    def runner(loop, items):
        return [it * 2 for it in items]

    def make():
        loops = [EventLoop(i, channels=(i,), runner=runner)
                 for i in range(4)]
        g = EventLoopGroup(loops)
        g.submit(list(range(10)))
        return g

    inline = sorted(make().run(threads=False))
    threaded = sorted(make().run(threads=True))
    assert inline == threaded == sorted(i * 2 for i in range(10))


def test_threaded_run_propagates_loop_failure():
    """A loop whose runner raises must fail the whole run AFTER every
    thread joined — a partial result set must never look like success."""
    def runner(loop, items):
        if loop.index == 1:
            raise RuntimeError("engine blew up")
        return items

    loops = [EventLoop(i, channels=(i,), runner=runner) for i in range(3)]
    grp = EventLoopGroup(loops)
    grp.submit(list(range(6)))
    with pytest.raises(RuntimeError, match="engine blew up"):
        grp.run(threads=True)
    assert loops[1].error is not None
    # inline drain propagates too
    grp2 = EventLoopGroup([EventLoop(0, channels=(0,), runner=runner),
                           EventLoop(1, channels=(1,), runner=runner)])
    grp2.submit([0, 1])
    with pytest.raises(RuntimeError, match="engine blew up"):
        grp2.run(threads=False)


def test_threaded_failure_does_not_hang_siblings():
    """Regression: one raising loop must not wedge or starve its
    siblings — every survivor finishes its full drain (results intact),
    the error surfaces on join, ``loop_failures`` counts the casualty,
    and ``poll_stats()`` still merges the survivors' counters."""
    def runner(loop, items):
        loop.poller.wait([_Handle(ready_after=1)])   # survivors do poll
        if loop.index == 2:
            raise RuntimeError("loop 2 died")
        return [(loop.index, it) for it in items]

    loops = [EventLoop(i, channels=(i,), runner=runner) for i in range(4)]
    grp = EventLoopGroup(loops)
    grp.submit(list(range(8)))
    with pytest.raises(RuntimeError, match="loop 2 died"):
        grp.run(threads=True)
    assert grp.loop_failures == 1
    assert loops[2].error is not None
    survivors = [l for l in loops if l.index != 2]
    for l in survivors:
        assert l.error is None
        assert l.results == [(l.index, it) for it in range(l.index, 8, 4)]
    # merged stats cover every loop that actually waited (all 4 reached
    # the poller before the casualty raised)
    st = grp.poll_stats()
    assert st.waits == 4 and st.stalls == 0
    # the group stays usable: resubmit to survivors-only indices works
    ok = EventLoopGroup([EventLoop(0, channels=(0,),
                                   runner=lambda l, it: it)])
    ok.submit([1, 2])
    assert ok.run(threads=True) == [1, 2] and ok.loop_failures == 0


def test_drain_picks_up_items_submitted_mid_drain():
    """The run-queue contract: submissions landing while the loop drains
    are processed in the same drain (continuous admission)."""
    loop = EventLoop(0, channels=(0,))
    fed = {"done": False}

    def runner(l, items):
        if not fed["done"]:
            fed["done"] = True
            l.submit("late")
        return items

    loop.runner = runner
    loop.submit("early")
    assert loop.drain() == ["early", "late"]


# ---------------------------------------------------------------------------
# Structured failures, heartbeats and the restart seam (the supervisor's
# detect/heal surface)
# ---------------------------------------------------------------------------


def _failing_runner(fail_index):
    def runner(loop, items):
        if loop.index == fail_index:
            raise RuntimeError("engine blew up")
        return [(loop.index, it) for it in items]
    return runner


@pytest.mark.parametrize("threads", [False, True])
def test_structured_failure_records(threads):
    """``raise_on_failure=False`` (the supervisor's entry point) returns
    the survivors' results and records a structured LoopFailure — loop
    index, exception repr, pending count — for threaded AND inline
    drains."""
    loops = [EventLoop(i, channels=(i,), runner=_failing_runner(1))
             for i in range(3)]
    grp = EventLoopGroup(loops)
    grp.submit(list(range(6)))
    out = grp.run(threads=threads, raise_on_failure=False)
    # survivors 0 and 2 served their round-robin shares
    assert sorted(out) == sorted([(0, 0), (0, 3), (2, 2), (2, 5)])
    assert grp.loop_failures == 1
    assert len(grp.failures) == 1
    lf = grp.failures[0]
    assert isinstance(lf, LoopFailure)
    assert lf.loop_index == 1
    assert "RuntimeError" in lf.error and "engine blew up" in lf.error
    # the in-flight batch is pending, stashed for re-admission
    assert lf.pending == 2
    assert loops[1].failed_items == [1, 4]
    # default behavior still raises (a partial result set must never
    # silently look like success) AND records the structured failure
    grp2 = EventLoopGroup(
        [EventLoop(i, channels=(i,), runner=_failing_runner(0))
         for i in range(2)])
    grp2.submit([7, 8])
    with pytest.raises(RuntimeError, match="engine blew up"):
        grp2.run(threads=threads)
    assert len(grp2.failures) == 1 and grp2.failures[0].loop_index == 0


def test_inline_drain_continues_past_failed_loop():
    """Inline non-raising drains keep draining the REMAINING loops after
    a casualty — the supervisor sees every loop's round, not just the
    prefix before the first failure."""
    loops = [EventLoop(i, channels=(i,), runner=_failing_runner(0))
             for i in range(3)]
    grp = EventLoopGroup(loops)
    grp.submit(list(range(6)))
    out = grp.run(threads=False, raise_on_failure=False)
    assert sorted(out) == sorted([(1, 1), (1, 4), (2, 2), (2, 5)])


def test_heartbeats_advance_per_drained_batch():
    loop = EventLoop(0, channels=(0,), runner=lambda l, items: items)
    assert loop.heartbeats == 0
    loop.submit(1)
    loop.drain()
    assert loop.heartbeats == 1
    loop.submit(2)
    loop.submit(3)
    loop.drain()
    assert loop.heartbeats == 2
    loop.drain()                       # empty drain: no work, no beat
    assert loop.heartbeats == 2


def test_restart_replaces_poller_and_repoints_engine():
    """The quarantine-and-restart seam: a fresh poller (same strategy /
    spin budget, NO fault, zeroed counters), failure state forgotten, an
    attached engine re-pointed, and the restart counted."""
    import types
    loop = EventLoop(0, channels=(0,), runner=_failing_runner(0))
    loop.poller = Poller("adaptive", spin_s=2.5)
    loop.poller.fault = lambda p: "stall"
    loop.poller.stats.stalls = 7
    eng = types.SimpleNamespace(poller=loop.poller)
    loop.engine = eng
    loop.submit("x")
    with pytest.raises(RuntimeError):
        loop.drain()
    assert loop.error is not None and loop.failed_items == ["x"]
    old = loop.poller
    fresh = loop.restart()
    assert fresh is loop.poller and fresh is not old
    assert fresh.poll == "adaptive" and fresh.spin_s == 2.5
    assert fresh.fault is None and fresh.stats.stalls == 0
    assert loop.error is None and loop.failed_items == []
    assert loop.restarts == 1
    assert eng.poller is fresh         # the engine polls the new one


def test_restart_folds_poll_stats_into_lifetime():
    """A restart must not LOSE the retired poller's counters: they fold
    into ``lifetime_stats`` and keep surfacing through ``poll_stats()``
    (the lifetime view) while the live poller starts from zero."""
    loop = EventLoop(0, channels=(0,), runner=lambda l, items: items)
    loop.poller.stats.waits = 5
    loop.poller.stats.stalls = 2
    loop.poller.stats.spins = 11
    loop.restart()
    assert loop.poller.stats.waits == 0            # fresh poller
    assert loop.lifetime_stats.waits == 5
    st = loop.poll_stats()
    assert (st.waits, st.stalls, st.spins) == (5, 2, 11)
    loop.poller.stats.waits = 3                    # second generation
    loop.restart()
    loop.poller.stats.waits = 1                    # third generation
    assert loop.poll_stats().waits == 9            # 5 + 3 + 1
    # and the group view aggregates lifetime, not just live pollers
    grp = EventLoopGroup([loop])
    assert grp.poll_stats().waits == 9


# ---------------------------------------------------------------------------
# Elastic reshard properties (launch/elastic.reshard_affinity): resize
# sequences preserve the ownership invariants with MINIMAL migration
# ---------------------------------------------------------------------------


def _assert_partition_invariants(groups, n_channels):
    flat = [c for g in groups for c in g]
    assert sorted(flat) == list(range(n_channels))      # disjoint + cover
    for g in groups:
        assert g, "a loop must own at least one channel"
        assert list(g) == list(range(min(g), max(g) + 1))   # contiguous


def _reshard_domain():
    """Fixed-grid enumeration (no-hypothesis convention): every
    grow→shrink→grow / shrink→grow→shrink walk through small fleets."""
    cases = []
    for n_channels in (4, 6, 8, 12):
        for walk in [(1, 3, 2, 4), (2, 4, 1, 3), (4, 2, 3, 1),
                     (3, 1, 4, 2), (2, 1, 2, 4), (1, 4, 2, 1)]:
            if all(k <= n_channels for k in walk):
                cases.append((n_channels, walk))
    return cases


@pytest.mark.parametrize("n_channels,walk", _reshard_domain())
def test_reshard_affinity_walk_minimal_migration(n_channels, walk):
    """Across an arbitrary resize walk the partition stays disjoint,
    covering, contiguous and non-empty at every step, the reported
    ``moved`` set is exact, and migration is MINIMAL on the flat fabric:
    a shrink moves exactly the removed loops' channels; a grow moves
    channels only onto the added loops (unless the minimal step was
    impossible and the documented recompute fallback was taken)."""
    groups = channel_affinity(n_channels, walk[0])
    for prev_k, k in zip(walk, walk[1:]):
        old = groups
        old_owner = {c: i for i, g in enumerate(old) for c in g}
        groups, moved = reshard_affinity(n_channels, old, k)
        _assert_partition_invariants(groups, n_channels)
        assert len(groups) == k
        # moved is exact: every channel whose owner index changed
        expect = tuple(sorted(
            c for i, g in enumerate(groups) for c in g
            if old_owner[c] != i))
        assert moved == expect
        if k < prev_k:       # shrink: only the removed loops' channels
            removed = sorted(c for g in old[k:] for c in g)
            assert list(moved) == removed
            # survivors below the last keep their runs verbatim
            assert groups[:k - 1] == old[:k - 1]
        elif k > prev_k:     # grow: moved lands on ADDED loops only —
            recompute = channel_affinity(n_channels, k)
            if groups != recompute:
                for c in moved:
                    new_owner = next(i for i, g in enumerate(groups)
                                     if c in g)
                    assert new_owner >= prev_k, (c, new_owner)
                assert all(len(g) == 1 for g in groups[prev_k:])
            # else: documented fallback (a donor would have emptied) —
            # the recompute's own invariants hold, asserted above


def test_reshard_affinity_same_count_is_identity():
    old = channel_affinity(8, 3)
    new, moved = reshard_affinity(8, old, 3)
    assert new == old and moved == ()


def test_reshard_affinity_rejects_impossible_fleet():
    with pytest.raises(ValueError, match="own at least one channel"):
        reshard_affinity(2, channel_affinity(2, 2), 3)


@pytest.mark.parametrize("n_channels,leaders,leader_loops,walk", [
    (6, 2, 1, (2, 3, 2)), (8, 2, 2, (2, 4, 3)), (8, 1, 1, (1, 2, 1)),
])
def test_reshard_affinity_topology_form_recomputes(n_channels, leaders,
                                                   leader_loops, walk):
    """The topology form (leader lanes / pods) always recomputes the
    pod-aligned, leader-pinned partition — alignment is a correctness
    constraint worth the extra migrations."""
    kw = dict(n_pods=2, leaders=leaders, leader_loops=leader_loops)
    groups = channel_affinity(n_channels, walk[0], **kw)
    for k in walk[1:]:
        groups, moved = reshard_affinity(n_channels, groups, k, **kw)
        assert groups == channel_affinity(n_channels, k, **kw)
        flat = [c for g in groups for c in g]
        assert sorted(flat) == list(range(n_channels))
        lead = set(range(n_channels - leaders, n_channels))
        for i, g in enumerate(groups):
            if i >= min(leader_loops, leaders):
                assert not (set(g) & lead)


# ---------------------------------------------------------------------------
# Percentile helpers (benchmarks/common.py — shared by latency, gradsync,
# serving_rtt)
# ---------------------------------------------------------------------------


def test_percentiles_ragged_nested_input():
    ps = percentiles([[1.0, 2.0, 3.0], [4.0], [5.0, 6.0]])
    assert ps[50.0] == pytest.approx(3.5)
    assert ps[50.0] <= ps[99.0] <= ps[99.9]


def test_percentiles_single_sample_degrades_gracefully():
    ps = percentiles([7.25])
    assert all(v == 7.25 for v in ps.values())


def test_percentiles_small_sample_monotone():
    ps = percentiles([3.0, 1.0])
    assert ps[50.0] <= ps[99.0] <= ps[99.9] <= 3.0


def test_percentiles_empty_raises():
    with pytest.raises(ValueError, match="empty"):
        percentiles([])
    with pytest.raises(ValueError, match="empty"):
        percentiles([[], []])


def test_percentile_rows_shape_and_monotonicity():
    rows = percentile_rows("serving_rtt", "fig5-8", "uni", 1024, 4,
                           [[1e-6, 2e-6], [50e-6]], suffix="el2")
    assert [r.metric for r in rows] == \
        ["rtt_p50:el2", "rtt_p99:el2", "rtt_p99.9:el2"]
    vals = [r.value for r in rows]
    assert vals == sorted(vals)
    assert all(r.unit == "us" and r.kind == "measured" for r in rows)
    assert len(PERCENTILE_QS) == 3


# ---------------------------------------------------------------------------
# RTT benchmark smoke (tiny sweep, inline loops)
# ---------------------------------------------------------------------------


def test_serving_rtt_rows_smoke():
    from benchmarks import serving_rtt
    rows = serving_rtt.run(msg_sizes=[16], loops=[1, 2],
                           conns_per_loop=[1], directions=("uni",),
                           iters=2, threads=False, evidence=False)
    p50 = [r for r in rows if r.metric.startswith("rtt_p50")]
    assert {r.metric.split(":")[-1] for r in p50} == {"el1", "el2"}
    by_key = {}
    for r in rows:
        if r.metric.startswith("rtt_p"):
            by_key.setdefault(r.metric.split(":")[-1], {})[
                r.metric.split(":")[0]] = r.value
    for v in by_key.values():
        assert v["rtt_p50"] <= v["rtt_p99"] <= v["rtt_p99.9"]
