"""The pluggable CommBackend layer (docs/COMM_BACKENDS.md).

Single-device coverage of the registry contract, the cross-backend
numerical parity of ``sync_grads``, and the emission structure of the
beyond-paper ``hadronio_overlap`` mode (independent collectives emitted
before the loss epilogue). Multi-device numerics are exercised by
tests/distributed/check_tac_modes.py / check_steps.py.
"""
import re

import numpy as np
import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from repro import compat
from repro.configs.base import CommConfig, RunConfig, ShapeConfig
from repro.configs.registry import get_config
from repro.core import aggregation as agg
from repro.core import tac
from repro.core.backends import (CommBackend, available_modes, get_backend,
                                 register, scatter_group_size)
from repro.core.backends.hadronio_overlap import make_buckets
from repro.launch import steps as steps_mod
from repro.launch.mesh import make_mesh

ALL_MODES = ("gspmd", "sockets", "vma", "hadronio", "hadronio_rs",
             "hadronio_overlap", "hadronio_overlap_rs")


# ---------------------------------------------------------------------------
# Registry contract
# ---------------------------------------------------------------------------


def test_registry_roundtrip():
    """Every registered mode resolves, lists, and self-identifies."""
    modes = available_modes()
    for m in ALL_MODES:
        assert m in modes, m
    for m in modes:
        b = get_backend(m)
        assert isinstance(b, CommBackend)
        assert b.name == m
        # singletons: repeated lookup is the same object
        assert get_backend(m) is b


def test_registry_unknown_mode():
    with pytest.raises(KeyError, match="hadronio"):   # lists known modes
        get_backend("carrier_pigeon")


def test_registry_rejects_duplicate_names():
    with pytest.raises(ValueError, match="already registered"):
        @register("hadronio")
        class Dupe(CommBackend):   # pragma: no cover - never instantiated
            def sync(self, grads, ctx):
                raise NotImplementedError


def test_config_validation_derives_from_registry():
    for m in available_modes():
        assert CommConfig(mode=m).mode == m
    with pytest.raises(AssertionError, match="registered"):
        CommConfig(mode="nope")


def test_capability_flags():
    assert not get_backend("gspmd").manual
    for m in ALL_MODES[1:]:
        assert get_backend(m).manual, m
    assert get_backend("hadronio_rs").zero1
    assert get_backend("hadronio_overlap_rs").zero1
    for m in ("sockets", "vma", "hadronio", "hadronio_overlap"):
        assert not get_backend(m).zero1, m


def test_scatter_group_size():
    hier = CommConfig(mode="hadronio_rs", hierarchical=True)
    flat = CommConfig(mode="hadronio_rs", hierarchical=False)
    assert scatter_group_size(8, 2, hier) == 4     # in-pod group
    assert scatter_group_size(8, 2, flat) == 8
    assert scatter_group_size(8, 1, hier) == 8


def test_overlap_supports_compression():
    """Per-bucket EF keying (ISSUE 2): the overlap modes now accept wire
    compression — validate() passes and the backend declares EF state."""
    for mode in ("hadronio_overlap", "hadronio_overlap_rs"):
        for compress in ("bf16", "int8_ef"):
            comm = CommConfig(mode=mode, compress=compress,
                              hierarchical=False)
            get_backend(mode).validate(comm)     # must not raise
            assert get_backend(mode).needs_ef(comm)


def test_comm_config_rejects_bad_values():
    """Clear errors for the enum/range fields (ISSUE 2 satellite)."""
    with pytest.raises(ValueError, match="channels"):
        CommConfig(mode="hadronio", channels=0, hierarchical=False)
    with pytest.raises(ValueError, match="channels"):
        CommConfig(mode="hadronio", channels=-3, hierarchical=False)
    with pytest.raises(ValueError, match="compress"):
        CommConfig(mode="hadronio", compress="fp4", hierarchical=False)
    with pytest.raises(ValueError, match="pack"):
        CommConfig(mode="hadronio", pack="cuda", hierarchical=False)
    with pytest.raises(ValueError, match="aggregate"):
        CommConfig(mode="hadronio", aggregate="tensor", hierarchical=False)


def test_unsupported_compress_rejected_at_validate():
    """Strategies that cannot honor a codec say so instead of silently
    ignoring it."""
    for mode, compress in [("sockets", "bf16"), ("sockets", "int8_ef"),
                           ("vma", "int8_ef"), ("gspmd", "bf16")]:
        comm = CommConfig(mode=mode, compress=compress, hierarchical=False)
        with pytest.raises(ValueError, match="compress"):
            get_backend(mode).validate(comm)


def test_overlap_bucketing():
    # 4-byte items; 3 leaves of 100/200/50 elems, 512B buckets, reverse order
    buckets = make_buckets([100, 200, 50], 512 // 4)
    assert buckets[0][0] == 2                      # last leaf first
    flat = [i for b in buckets for i in b]
    assert sorted(flat) == [0, 1, 2]               # exact partition
    for b in buckets[:-1]:
        assert sum(100 if i == 0 else 200 if i == 1 else 50
                   for i in b) <= 512 // 4 or len(b) == 1
    # one oversized leaf still gets a bucket
    assert make_buckets([10_000], 64) == [[0]]


# ---------------------------------------------------------------------------
# Cross-backend parity (1-device ring: psum == identity, so every mode
# must return the input gradients exactly — pack/slice/bucket roundtrips
# included)
# ---------------------------------------------------------------------------


def _model_grads():
    cfg = get_config("qwen2-0.5b-reduced")
    from repro.models import api
    return api.init(jax.random.PRNGKey(0), cfg)


@pytest.mark.parametrize("mode", ["sockets", "vma", "hadronio",
                                  "hadronio_overlap", "hadronio_rs",
                                  "hadronio_overlap_rs"])
def test_cross_backend_parity_small_model(mode):
    grads = _model_grads()
    comm = CommConfig(mode=mode, slice_bytes=64 * 1024, hierarchical=False)
    mesh = make_mesh((1,), ("data",))

    def body(g):
        r = tac.sync_grads(g, comm, data_axis=("data",))
        # zero1: reconstruct via the backend's own gather epilogue
        return get_backend(mode).gathered_grads(r, g)

    out = jax.jit(compat.shard_map(body, mesh=mesh, in_specs=(P(),),
                                   out_specs=P()))(grads)
    flat_in, _ = jax.tree.flatten(grads)
    flat_out, treedef_out = jax.tree.flatten(out)
    assert jax.tree.structure(grads) == treedef_out
    for a, b in zip(flat_in, flat_out):
        np.testing.assert_allclose(np.asarray(b), np.asarray(a),
                                   rtol=1e-6, atol=1e-7)


# ---------------------------------------------------------------------------
# Emission structure of the beyond-paper overlap mode
# ---------------------------------------------------------------------------

_AR_RE = re.compile(
    r'%(\S+)\s*=\s*"?stablehlo\.all_reduce"?\s*\(([^)]*)\)')


def _lower_tac_step(mode: str, slice_bytes: int = 16 * 1024):
    cfg = get_config("qwen2-0.5b-reduced")
    run = RunConfig(model=cfg, shape=ShapeConfig("t", "train", 16, 4),
                    comm=CommConfig(mode=mode, slice_bytes=slice_bytes,
                                    hierarchical=False))
    mesh = make_mesh((1,), ("data",))
    with compat.set_mesh(mesh):
        step_fn, state_sh, _ = steps_mod.make_train_step(run, mesh)
        state = steps_mod.init_tac_state(jax.random.PRNGKey(0), run, 1)
        batch = {"tokens": jnp.zeros((4, 16), jnp.int32),
                 "labels": jnp.zeros((4, 16), jnp.int32)}
        return jax.jit(step_fn).lower(state, batch).as_text()


def test_overlap_emits_independent_collectives():
    """The overlap backend must emit >= 2 all-reduces that do not feed
    each other (independence is what the latency-hiding scheduler needs),
    and the gradient collectives must precede the scalar loss epilogue."""
    text = _lower_tac_step("hadronio_overlap")
    matches = list(_AR_RE.finditer(text))
    assert len(matches) >= 2, f"expected >=2 all_reduce, got {len(matches)}"
    results = {m.group(1) for m in matches}
    for m in matches:
        operands = {o.strip().lstrip("%") for o in m.group(2).split(",")}
        assert not (operands & results), \
            f"all_reduce feeds another all_reduce: {m.group(0)}"
    # the loss epilogue (scalar f32 all-reduce) comes after at least one
    # gradient-bucket collective in emission order
    scalar = [i for i, m in enumerate(matches)
              if "tensor<f32>" in text[m.start():m.start() + 400]]
    assert scalar and scalar[-1] > 0, \
        "scalar loss all-reduce should follow gradient collectives"


def test_overlap_matches_bucket_count():
    """One all-reduce per bucket (+1 for the loss) — send-call count, the
    paper's messages axis."""
    cfg = get_config("qwen2-0.5b-reduced")
    from repro.models import api
    params = api.abstract(cfg)
    leaves = jax.tree.leaves(params)
    sizes = [int(np.prod(l.shape)) if l.shape else 1 for l in leaves]
    slice_bytes = 16 * 1024
    n_buckets = len(make_buckets(sizes, slice_bytes))
    assert n_buckets >= 2       # the config is small but multi-bucket
    text = _lower_tac_step("hadronio_overlap", slice_bytes)
    n_ar = len(_AR_RE.findall(text))
    assert n_ar == n_buckets + 1, (n_ar, n_buckets)


def test_channel_count_is_a_real_lever():
    """comm.channels bounds in-flight collectives: with fewer channels
    than slices, same-channel collectives are chained through
    optimization_barrier (visible in the emitted HLO); numerics are
    unchanged either way."""
    grads = _model_grads()
    mesh = make_mesh((1,), ("data",))
    outs = {}
    for n_ch in (1, 64):
        comm = CommConfig(mode="hadronio", slice_bytes=16 * 1024,
                          channels=n_ch, hierarchical=False)
        f = jax.jit(compat.shard_map(
            lambda g: tac.sync_grads(g, comm, data_axis=("data",)).grads,
            mesh=mesh, in_specs=(P(),), out_specs=P()))
        outs[n_ch] = f(grads)
        text = f.lower(grads).as_text()
        n_barriers = text.count("stablehlo.optimization_barrier")
        if n_ch == 1:
            assert n_barriers > 0, "serialized channel must chain ops"
        else:
            assert n_barriers == 0, "independent slices need no chaining"
    for a, b in zip(jax.tree.leaves(outs[1]), jax.tree.leaves(outs[64])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_hadronio_op_count_matches_plan():
    """hadronio emits exactly one collective per ring-buffer slice (+1
    loss) — the gathering-write invariant, now routed via channels."""
    cfg = get_config("qwen2-0.5b-reduced")
    from repro.models import api
    comm = CommConfig(mode="hadronio", slice_bytes=16 * 1024,
                      hierarchical=False)
    plan = agg.make_plan(api.abstract(cfg), comm)
    text = _lower_tac_step("hadronio", 16 * 1024)
    n_ar = len(_AR_RE.findall(text))
    assert n_ar == plan.n_slices + 1, (n_ar, plan.n_slices)


def test_overlap_rs_emits_one_reduce_scatter_per_bucket():
    """The bucketed ZeRO-1 mode: one reduce-scatter per bucket in the
    lowered step (the overlap property on the scatter path), ahead of
    the loss epilogue's all-reduce."""
    cfg = get_config("qwen2-0.5b-reduced")
    from repro.models import api
    from repro.core.backends.hadronio_overlap_rs import rs_bucket_plan
    slice_bytes = 16 * 1024
    comm = CommConfig(mode="hadronio_overlap_rs", slice_bytes=slice_bytes,
                      hierarchical=False)
    plan = rs_bucket_plan(api.abstract(cfg), comm, 1)
    text = _lower_tac_step("hadronio_overlap_rs", slice_bytes)
    n_rs = text.count("stablehlo.reduce_scatter")
    assert n_rs == plan.n_buckets, (n_rs, plan.n_buckets)


# ---------------------------------------------------------------------------
# The pack stage (comm.pack): pallas fused kernel vs jnp reference
# ---------------------------------------------------------------------------


def _pack_comm(compress, pack):
    return CommConfig(mode="hadronio", compress=compress, pack=pack,
                      hierarchical=False)


def test_pack_stage_identical_wire_bytes(np_rng):
    """comm.pack='pallas' and 'jnp' must produce bit-identical wire
    bytes (and residuals): the fused kernel is a copy-path optimization,
    never a numerics change."""
    from repro.core.backends import pipeline
    slices = jnp.asarray(np_rng.normal(size=(3, 1536)), jnp.float32)
    ef = jnp.asarray(np_rng.normal(size=(3, 1536)) * 0.01, jnp.float32)
    for compress in ("none", "bf16"):
        outs = {}
        for pack in ("jnp", "pallas"):
            e = ef if compress == "bf16" else None
            wire, new_ef, scale = pipeline.pack_wire(
                slices, e, _pack_comm(compress, pack))
            assert scale is None
            outs[pack] = (wire, new_ef)
        wj, ej = outs["jnp"]
        wp, ep = outs["pallas"]
        assert wj.dtype == wp.dtype
        np.testing.assert_array_equal(
            np.asarray(wj).view(np.uint8), np.asarray(wp).view(np.uint8))
        if compress == "bf16":
            np.testing.assert_array_equal(np.asarray(ej), np.asarray(ep))


def test_pack_stage_int8_always_jnp(np_rng):
    """int8 needs an amax reduction the kernel does not fuse: both pack
    settings take the identical jnp path."""
    from repro.core.backends import pipeline
    slices = jnp.asarray(np_rng.normal(size=(2, 512)), jnp.float32)
    q1, e1, s1 = pipeline.pack_wire(slices, None, _pack_comm("int8_ef",
                                                             "jnp"))
    q2, e2, s2 = pipeline.pack_wire(slices, None, _pack_comm("int8_ef",
                                                             "pallas"))
    np.testing.assert_array_equal(np.asarray(q1), np.asarray(q2))
    np.testing.assert_array_equal(np.asarray(s1), np.asarray(s2))
    np.testing.assert_array_equal(np.asarray(e1), np.asarray(e2))


def test_pack_falls_back_without_pallas(monkeypatch):
    """comm.pack='pallas' in a pallas-less environment silently takes
    the jnp path (the compat fallback), with identical results."""
    from repro.core.backends import pipeline
    monkeypatch.setattr(compat, "pallas_available", lambda: False)
    assert pipeline.pack_impl(_pack_comm("bf16", "pallas")) == "jnp"
    assert pipeline.pack_impl(_pack_comm("bf16", "jnp")) == "jnp"


def test_unpack_stage_identical_outputs(np_rng):
    """The unpack stage (scattering read) mirrors the pack-stage harness
    discipline: pallas and jnp implementations produce bit-identical f32
    outputs from the same wire bytes; a wire already in the target dtype
    is returned untouched (no copy pass)."""
    from repro.core.backends import pipeline
    src = jnp.asarray(np_rng.normal(size=(3, 1536)), jnp.float32)
    wire = src.astype(jnp.bfloat16)
    outs = {p: pipeline.unpack_wire(wire, _pack_comm("bf16", p))
            for p in ("jnp", "pallas")}
    for p, o in outs.items():
        assert o.dtype == jnp.float32 and o.shape == wire.shape, p
    np.testing.assert_array_equal(np.asarray(outs["jnp"]),
                                  np.asarray(outs["pallas"]))
    # bf16 -> f32 widening is exact: the unpack stage loses nothing
    np.testing.assert_array_equal(np.asarray(outs["jnp"]),
                                  np.asarray(wire, np.float32))
    for p in ("jnp", "pallas"):
        assert pipeline.unpack_wire(src, _pack_comm("none", p)) is src


# ---------------------------------------------------------------------------
# Channel-count autotune (benchmarks/latency.py, ROADMAP item)
# ---------------------------------------------------------------------------


def test_channel_autotune_smoke():
    """The sweep runs on the live mesh and returns a channel count from
    the swept set, plus the recommended-default row for the CSV."""
    from benchmarks.latency import autotune_channels
    best, rows = autotune_channels(msg_size=1024, channels=(1, 2), iters=1)
    assert best in (1, 2)
    rec = [r for r in rows if r.metric == "recommended_channels"]
    assert len(rec) == 1 and rec[0].value == best
    assert CommConfig(mode="hadronio", channels=best,
                      hierarchical=False).channels == best


def test_autotune_rows_carry_mode_label():
    """The autotune rows thread the ACTUAL mode name into the CSV (they
    used to hard-code "hadronio"), so sweeps over the overlap modes stay
    distinguishable."""
    from benchmarks.latency import autotune_channels
    _, rows = autotune_channels(msg_size=1024, channels=(1,), iters=1,
                                mode="hadronio_overlap_rs")
    assert rows and all(r.mode == "hadronio_overlap_rs" for r in rows)


def test_slice_bytes_autotune_smoke():
    """The slice-granularity sweep (ROADMAP follow-up) runs the LIVE wire
    pipeline on this mesh, returns a granularity from the swept set, and
    derives the recommended-default row from the already-measured points
    (no re-measurement)."""
    from benchmarks.latency import autotune_slice_bytes
    best, rows = autotune_slice_bytes(payload_bytes=64 * 1024,
                                      slice_sizes=(4096, 16384),
                                      channels=2, iters=1)
    assert best in (4096, 16384)
    measured = [r for r in rows if r.metric == "sweep_slice_goodput"]
    assert len(measured) == 2 and all(r.kind == "measured"
                                      for r in measured)
    rec = [r for r in rows if r.metric == "recommended_slice_bytes"]
    assert len(rec) == 1 and rec[0].value == best and rec[0].kind == "derived"
    assert CommConfig(mode="hadronio", slice_bytes=best,
                      hierarchical=False).slice_bytes == best


def test_slice_bytes_autotune_sweeps_aggregate_axis():
    """The same sweep parameterizes over the new aggregate axis — the
    channel-flush pipeline is measurable per mesh too."""
    from benchmarks.latency import autotune_slice_bytes
    best, rows = autotune_slice_bytes(payload_bytes=64 * 1024,
                                      slice_sizes=(16384,), channels=2,
                                      aggregate="channel", iters=1)
    assert best == 16384 and rows
