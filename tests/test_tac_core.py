"""Property tests for the paper's core: gathering-write aggregation
(pack/unpack roundtrip), ring-buffer slice planning, channels.

Formerly hypothesis-driven; the tier-1 environment has no ``hypothesis``,
so the properties are checked over a fixed grid of representative cases
(scalars, odd shapes, clamped plans) instead of random search.
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs.base import CommConfig
from repro.core import aggregation as agg
from repro.core.channels import make_channels, round_robin
from repro.core.ring_buffer import plan_slices
from repro.launch.steps import _decay_mask_flat

# shape lists spanning: single scalar, mixed ranks, many small leaves,
# leaves larger than one slice
SHAPE_CASES = [
    [[]],
    [[1]],
    [[7], [], [3, 5]],
    [[2, 3, 4], [1, 1, 1], [6]],
    [[5, 5], [4], [], [2, 2, 2], [7, 3]],
    [[3000], [17], [64, 9]],
    [[1] for _ in range(8)],
]


def comm(slice_bytes=4096, cap=1 << 20):
    return CommConfig(mode="hadronio", slice_bytes=slice_bytes,
                      ring_capacity_bytes=cap, hierarchical=False)


@pytest.mark.parametrize("seed", [0, 1])
@pytest.mark.parametrize("shapes", SHAPE_CASES)
def test_pack_unpack_roundtrip(shapes, seed):
    rng = np.random.default_rng(seed)
    tree = {f"p{i}": jnp.asarray(rng.normal(size=s), jnp.float32)
            for i, s in enumerate(shapes)}
    plan = agg.make_plan(tree, comm())
    flat = agg.pack(tree, plan)
    assert flat.shape == (plan.padded_elems,)
    assert plan.padded_elems % plan.slice_elems == 0
    back = agg.unpack(flat, plan, tree)
    for k in tree:
        np.testing.assert_array_equal(np.asarray(back[k]),
                                      np.asarray(tree[k]))


@pytest.mark.parametrize("total,slice_bytes,cap_mult", [
    (1, 64, 1),
    (1, 1 << 20, 64),
    (4096, 64, 2),
    (100_000, 4096, 4),
    (1 << 24, 1 << 16, 8),          # clamped: needs more slices than cap
    (1 << 24, 1 << 20, 64),
    (12345, 777, 3),                # non-power-of-two everything
    (1 << 20, 64, 1),               # heavy clamp
])
def test_slice_plan_invariants(total, slice_bytes, cap_mult):
    c = CommConfig(mode="hadronio", slice_bytes=slice_bytes,
                   ring_capacity_bytes=slice_bytes * cap_mult)
    sp = plan_slices(total, c)
    assert sp.n_slices >= 1
    assert sp.slice_bytes * sp.n_slices >= total      # covers the payload
    assert sp.n_slices <= max(1, c.ring_capacity_bytes // slice_bytes)
    if not sp.clamped:
        assert sp.slice_bytes == slice_bytes


def test_clamped_slice_plan_is_512_aligned():
    """Capacity clamping grows slices by ceil-division, which can land on
    any byte count; the plan rounds the effective slice up to 512-byte
    alignment (so the pallas pack/unpack tiling never degrades to gcd-1
    tiles) and records the rounding."""
    c = CommConfig(mode="hadronio", slice_bytes=777,
                   ring_capacity_bytes=777 * 3)
    sp = plan_slices(12345, c)
    assert sp.clamped and sp.n_slices == 3
    raw = -(-12345 // 3)                      # 4115: what clamping alone gives
    assert sp.slice_bytes % 512 == 0
    assert sp.slice_bytes == -(-raw // 512) * 512
    assert sp.align_pad_bytes == sp.slice_bytes - raw
    assert sp.slice_bytes * sp.n_slices >= 12345
    # unclamped plans honor the request exactly and record no rounding
    sp2 = plan_slices(100, CommConfig(mode="hadronio", slice_bytes=777,
                                      ring_capacity_bytes=1 << 20))
    assert not sp2.clamped and sp2.slice_bytes == 777
    assert sp2.align_pad_bytes == 0


def test_slice_alignment_for_any_ring():
    """slice_elems is 512-aligned so reduce-scatter shards evenly over any
    DP ring up to 512 peers (the multi-pod mesh size)."""
    tree = {"a": jnp.zeros((1000,)), "b": jnp.zeros((3, 5))}
    plan = agg.make_plan(tree, comm(slice_bytes=1024))
    assert plan.slice_elems % 512 == 0
    for n in (2, 4, 8, 16, 256, 512):
        assert plan.slice_elems % n == 0


def test_decay_mask_layout():
    tree = {"w": jnp.zeros((4, 4)), "b": jnp.zeros((16,)),
            "n": {"scale": jnp.zeros((8,)), "m": jnp.zeros((2, 3))}}
    plan = agg.make_plan(tree, comm())
    mask = _decay_mask_flat(plan)
    total = sum(int(np.prod(l.shape)) for l in jax.tree.leaves(tree)
                if l.ndim >= 2)
    assert mask.sum() == total
    # mask positions match the leaf offsets of >=2D leaves
    leaves = jax.tree.leaves(tree)
    for (start, end), leaf in zip(plan.offsets, leaves):
        expect = 1.0 if leaf.ndim >= 2 else 0.0
        assert (mask[start:end] == expect).all()


def test_channels_round_robin():
    assert round_robin(7, 3) == [0, 1, 2, 0, 1, 2, 0]
    chans = make_channels(4, ("data",))
    assert [c.index for c in chans] == [0, 1, 2, 3]


def test_pack_casts_and_pads():
    tree = {"a": jnp.ones((3,), jnp.bfloat16),
            "b": jnp.full((5,), 2.0, jnp.float32)}
    plan = agg.make_plan(tree, comm(slice_bytes=4096))
    flat = agg.pack(tree, plan)
    assert flat.dtype == jnp.float32
    np.testing.assert_array_equal(np.asarray(flat[:3]), np.ones(3))
    assert float(flat[plan.total_elems:].sum()) == 0.0   # zero padding
    back = agg.unpack(flat, plan, tree)
    assert back["a"].dtype == jnp.bfloat16               # dtype restored


@pytest.mark.parametrize("n,seed", [(1, 0), (2, 1), (4, 2), (6, 3)])
def test_slice_view_roundtrip(n, seed):
    """as_slices/from_slices are exact views (the ring-buffer carve)."""
    rng = np.random.default_rng(seed)
    tree = {"x": jnp.asarray(rng.normal(size=(n * 700 + 3,)), jnp.float32)}
    plan = agg.make_plan(tree, comm(slice_bytes=2048))
    flat = agg.pack(tree, plan)
    sl = agg.as_slices(flat, plan)
    assert sl.shape == (plan.n_slices, plan.slice_elems)
    np.testing.assert_array_equal(np.asarray(agg.from_slices(sl, plan)),
                                  np.asarray(flat))
