"""Serving engine: batching exactness, eos, buckets, determinism,
continuous batching, and the event-loop group."""
import numpy as np
import jax
import pytest

from repro.configs.base import CommConfig, ServeConfig
from repro.configs.registry import get_config
from repro.models import api
from repro.serving import DecodeEngine, Request, make_engine_group


@pytest.fixture(scope="module")
def qwen():
    cfg = get_config("qwen2-0.5b-reduced")
    params = api.init(jax.random.PRNGKey(0), cfg)
    return cfg, params


def test_mixed_length_batch_is_exact(qwen):
    cfg, params = qwen
    eng = DecodeEngine(cfg, params, max_batch=4, max_len=64)
    p1 = (np.arange(7) * 3) % cfg.vocab_size
    p2 = (np.arange(12) * 5) % cfg.vocab_size
    solo = eng.generate([Request(0, p1, max_new=5)])[0].tokens
    both = eng.generate([Request(0, p1, max_new=5),
                         Request(1, p2, max_new=5)])
    np.testing.assert_array_equal(solo, both[0].tokens)
    assert len(both[1].tokens) == 5


def test_eos_stops_early(qwen):
    cfg, params = qwen
    eng = DecodeEngine(cfg, params, max_batch=2, max_len=64)
    first = eng.generate([Request(0, np.arange(5), max_new=8)])[0].tokens
    eos = int(first[1])
    eng2 = DecodeEngine(cfg, params, max_batch=2, max_len=64, eos_id=eos)
    out = eng2.generate([Request(0, np.arange(5), max_new=8)])[0].tokens
    assert len(out) <= 2 + 1 and out[-1] == eos


def test_respects_max_batch(qwen):
    cfg, params = qwen
    eng = DecodeEngine(cfg, params, max_batch=2, max_len=64)
    reqs = [Request(i, np.arange(4 + i), max_new=3) for i in range(5)]
    res = eng.generate(reqs)
    assert sorted(r.uid for r in res) == list(range(5))
    assert all(len(r.tokens) == 3 for r in res)


def test_recurrent_arch_buckets_by_length():
    cfg = get_config("rwkv6-7b-reduced")
    params = api.init(jax.random.PRNGKey(1), cfg)
    eng = DecodeEngine(cfg, params, max_batch=4, max_len=64)
    p1 = np.arange(6) % cfg.vocab_size
    p2 = np.arange(11) % cfg.vocab_size
    solo = eng.generate([Request(0, p1, max_new=4)])[0].tokens
    mixed = eng.generate([Request(0, p1, max_new=4),
                          Request(1, p2, max_new=4)])
    np.testing.assert_array_equal(solo, mixed[0].tokens)


def test_greedy_is_deterministic(qwen):
    cfg, params = qwen
    eng = DecodeEngine(cfg, params, max_batch=2, max_len=64)
    a = eng.generate([Request(0, np.arange(6), max_new=6)])[0].tokens
    b = eng.generate([Request(0, np.arange(6), max_new=6)])[0].tokens
    np.testing.assert_array_equal(a, b)


# ---------------------------------------------------------------------------
# Continuous batching: admission at flush boundaries
# ---------------------------------------------------------------------------


def test_admitted_mid_flight_matches_solo(qwen):
    """A request admitted into a freed slot at a flush boundary (the run
    queue overflowing max_batch) generates exactly the tokens of a solo
    run — the per-row exactness that makes continuous batching safe."""
    cfg, params = qwen
    rng = np.random.default_rng(3)
    reqs = [Request(i, rng.integers(0, cfg.vocab_size,
                                    size=int(rng.integers(4, 30))),
                    max_new=3 + i % 3) for i in range(5)]
    eng = DecodeEngine(cfg, params, max_batch=2, max_len=64)
    batched = {r.uid: r.tokens.tolist() for r in eng.generate(reqs)}
    assert sorted(batched) == list(range(5))
    for r in reqs:
        solo = DecodeEngine(cfg, params, max_batch=2,
                            max_len=64).generate([r])[0]
        assert batched[r.uid] == solo.tokens.tolist(), r.uid


def _counting_engine(cfg, params, **kw):
    """Engine whose (stubbed) model deterministically emits
    ``(previous token + 1) % vocab`` — a NON-degenerate stream (the real
    reduced model greedily repeats one constant token, which would hide
    any off-by-one or reordering in the admission path)."""
    import jax.numpy as jnp
    eng = DecodeEngine(cfg, params, **kw)
    V = cfg.vocab_size
    eye = np.eye(V, dtype=np.float32) * 10.0

    def fake_prefill(p, batch):
        toks = np.asarray(batch["tokens"])
        last = np.asarray(batch["last_pos"])
        prev = toks[np.arange(toks.shape[0]), last]
        cache = {"k": jnp.zeros((1, toks.shape[0], 4), jnp.float32)}
        return jnp.asarray(eye[(prev + 1) % V]), cache

    def fake_decode(p, cache, dec):
        prev = np.asarray(dec["token"])
        return jnp.asarray(eye[(prev + 1) % V]), cache

    eng._prefill = fake_prefill
    eng._decode = fake_decode
    return eng


def test_admission_exact_on_nondegenerate_stream(qwen):
    """With a counting token stream, an admitted request must produce
    EXACTLY [last+1, last+2, ...] — this catches the whole class of
    'first prefill-sampled token consumed by decode but never recorded'
    bugs that a constant-token model cannot see."""
    cfg, params = qwen
    eng = _counting_engine(cfg, params, max_batch=1, max_len=64)
    reqs = [Request(0, np.asarray([5, 20]), max_new=4),
            Request(1, np.asarray([7, 40]), max_new=4)]   # admitted
    res = eng.generate(reqs)
    assert [r.tokens.tolist() for r in res] == \
        [[21, 22, 23, 24], [41, 42, 43, 44]]


def test_admission_eos_on_first_token(qwen):
    """A request whose FIRST generated token is eos finishes at
    admission with exactly that one token (and the slot stays usable)."""
    cfg, params = qwen
    eng = _counting_engine(cfg, params, max_batch=1, max_len=64,
                           eos_id=31)
    reqs = [Request(0, np.asarray([3, 10]), max_new=3),
            Request(1, np.asarray([4, 30]), max_new=5),   # t0 == eos
            Request(2, np.asarray([6, 50]), max_new=2)]
    res = eng.generate(reqs)
    assert [r.tokens.tolist() for r in res] == \
        [[11, 12, 13], [31], [51, 52]]


def test_max_new_zero_generates_nothing(qwen):
    """max_new=0 is prefill-only (score a prompt, warm a cache): zero
    tokens, both as a resident and as an admitted request."""
    cfg, params = qwen
    eng = DecodeEngine(cfg, params, max_batch=1, max_len=64)
    res = eng.generate([Request(0, np.arange(4), max_new=0),
                        Request(1, np.arange(6), max_new=2),   # admitted
                        Request(2, np.arange(5), max_new=0)])  # admitted
    assert [len(r.tokens) for r in res] == [0, 2, 0]


def test_admission_pad_never_exceeds_cache_capacity(qwen):
    """An admitted prompt whose ADMIT_PAD rounding would pass max_len
    must still fit the resident cache (the rounding clamps to the
    sequence capacity): max_len=20, queued 17-token prompt."""
    cfg, params = qwen
    eng = DecodeEngine(cfg, params, max_batch=1, max_len=20)
    reqs = [Request(0, np.arange(5), max_new=3),
            Request(1, np.arange(17) % cfg.vocab_size, max_new=3)]
    res = eng.generate(reqs)
    assert [r.uid for r in res] == [0, 1]
    solo = DecodeEngine(cfg, params, max_batch=1, max_len=20).generate(
        [reqs[1]])[0]
    np.testing.assert_array_equal(res[1].tokens, solo.tokens)


def test_admission_respects_eos_freed_slots(qwen):
    """Slots freed by eos (not just max_new) admit the next queued
    request."""
    cfg, params = qwen
    first = DecodeEngine(cfg, params, max_batch=1, max_len=64).generate(
        [Request(0, np.arange(5), max_new=8)])[0].tokens
    eos = int(first[1])
    eng = DecodeEngine(cfg, params, max_batch=1, max_len=64, eos_id=eos)
    res = eng.generate([Request(0, np.arange(5), max_new=8),
                        Request(1, np.arange(7), max_new=3)])
    assert res[0].tokens[-1] == eos and len(res[0].tokens) <= 3
    assert len(res[1].tokens) >= 1      # admitted after slot freed


# ---------------------------------------------------------------------------
# The event-loop group (serving through the comm stack)
# ---------------------------------------------------------------------------


def _group_tokens(cfg, params, serve, reqs, threads):
    grp = make_engine_group(cfg, params, serve)
    grp.submit(reqs)
    res = sorted(grp.run(threads=threads), key=lambda r: r.uid)
    return [tuple(r.tokens.tolist()) for r in res], grp


def test_engine_group_matches_single_engine(qwen):
    """The full subsystem (event loops + channel affinity + comm-backed
    dispatch + continuous batching) returns exactly the legacy engine's
    greedy tokens, threaded or not."""
    cfg, params = qwen
    rng = np.random.default_rng(0)
    reqs = [Request(i, rng.integers(0, cfg.vocab_size,
                                    size=int(rng.integers(4, 20))),
                    max_new=4) for i in range(6)]
    ref = [tuple(r.tokens.tolist())
           for r in DecodeEngine(cfg, params, max_batch=4,
                                 max_len=64).generate(reqs)]
    serve = ServeConfig(event_loops=2, poll="adaptive", max_batch=4,
                        max_len=64,
                        comm=CommConfig(mode="hadronio", slice_bytes=1024,
                                        channels=4, hierarchical=False))
    got, grp = _group_tokens(cfg, params, serve, reqs, threads=True)
    assert got == ref
    # ownership facts: disjoint affinity, every loop served something
    owned = [c for l in grp.loops for c in l.channels]
    assert sorted(owned) == list(range(4))
    assert all(l.results for l in grp.loops)
    st = grp.poll_stats()
    assert st.waits > 0


def test_engine_group_poll_strategies_agree(qwen):
    """busy / park / adaptive change HOW completions are awaited, never
    the tokens."""
    cfg, params = qwen
    reqs = [Request(i, np.arange(5 + i) % cfg.vocab_size, max_new=3)
            for i in range(3)]
    outs = {}
    for poll in ServeConfig.POLLS:
        serve = ServeConfig(event_loops=1, poll=poll, max_batch=4,
                            max_len=64,
                            comm=CommConfig(mode="hadronio",
                                            slice_bytes=2048, channels=2,
                                            hierarchical=False))
        outs[poll], grp = _group_tokens(cfg, params, serve, reqs,
                                        threads=False)
        st = grp.poll_stats()
        if poll == "park":
            assert st.spins == 0 and st.parks > 0
    assert outs["busy"] == outs["park"] == outs["adaptive"]


def test_serve_config_validation():
    with pytest.raises(ValueError, match="poll"):
        ServeConfig(poll="epoll")
    with pytest.raises(ValueError, match="event_loops"):
        ServeConfig(event_loops=0)
    with pytest.raises(ValueError, match="disjoint"):
        ServeConfig(event_loops=8,
                    comm=CommConfig(mode="hadronio", channels=4,
                                    hierarchical=False))


# ---------------------------------------------------------------------------
# Multi-tenant groups (docs/FAMILIES.md §Tenants and fairness)
# ---------------------------------------------------------------------------


def test_tenant_config_validation():
    from repro.configs.base import TenantConfig
    with pytest.raises(ValueError, match="unique"):
        ServeConfig(event_loops=2,
                    comm=CommConfig(mode="hadronio", channels=4,
                                    hierarchical=False),
                    tenants=(TenantConfig("a"), TenantConfig("a")))
    with pytest.raises(ValueError, match="weight"):
        ServeConfig(event_loops=2,
                    comm=CommConfig(mode="hadronio", channels=4,
                                    hierarchical=False),
                    tenants=(TenantConfig("a", weight=0),
                             TenantConfig("b")))
    with pytest.raises(ValueError, match="pin the fleet size"):
        ServeConfig(event_loops=4,
                    comm=CommConfig(mode="hadronio", channels=4,
                                    hierarchical=False),
                    tenants=(TenantConfig("a"), TenantConfig("b")))


@pytest.fixture(scope="module")
def rwkv():
    cfg = get_config("rwkv6-7b-reduced")
    params = api.init(jax.random.PRNGKey(1), cfg)
    return cfg, params


def _two_tenant_serve(wa=2, wb=1):
    from repro.configs.base import TenantConfig
    return ServeConfig(
        event_loops=2, poll="busy", max_batch=4, max_len=64,
        comm=CommConfig(mode="hadronio", channels=4, slice_bytes=1024,
                        hierarchical=False),
        tenants=(TenantConfig("qwen", arch="qwen2-0.5b", weight=wa,
                              event_loops=1),
                 TenantConfig("rwkv", arch="rwkv6-7b", weight=wb,
                              event_loops=1)))


def test_two_families_one_group_tokens_identical(qwen, rwkv):
    """The acceptance row: a dense and an ssm model served side by side
    in ONE EventLoopGroup (per-tenant loop/channel ranges) produce
    greedy tokens bit-identical to each model's single-tenant run."""
    cfg_a, p_a = qwen
    cfg_b, p_b = rwkv
    rng = np.random.default_rng(3)
    reqs = []
    for uid in range(6):
        t = "qwen" if uid % 2 == 0 else "rwkv"
        v = (cfg_a if t == "qwen" else cfg_b).vocab_size
        reqs.append(Request(uid, rng.integers(1, v, size=8), max_new=4,
                            tenant=t))
    grp = make_engine_group({"qwen": cfg_a, "rwkv": cfg_b},
                            {"qwen": p_a, "rwkv": p_b},
                            _two_tenant_serve())
    grp.submit(reqs)
    res = {r.uid: tuple(r.tokens.tolist()) for r in grp.run(threads=False)}
    assert grp.fairness_counters == {"qwen": 3, "rwkv": 3}
    for t, (c, p) in (("qwen", qwen), ("rwkv", rwkv)):
        solo = ServeConfig(event_loops=1, poll="busy", max_batch=4,
                           max_len=64,
                           comm=CommConfig(mode="hadronio", channels=2,
                                           hierarchical=False))
        g1 = make_engine_group(c, p, solo)
        mine = [Request(r.uid, r.prompt, max_new=r.max_new)
                for r in reqs if r.tenant == t]
        g1.submit(mine)
        ref = {r.uid: tuple(r.tokens.tolist())
               for r in g1.run(threads=False)}
        assert {u: res[u] for u in ref} == ref, t


def test_weighted_fair_dispatch_is_deterministic(qwen, rwkv):
    """The stride scheduler: weights 2:1 dispatch in the exact sequence
    A A B A A B…, ties broken in declaration order, and the per-tenant
    counters plus the routing trace are reproducible run to run."""
    cfg_a, p_a = qwen
    cfg_b, p_b = rwkv
    logs = []
    for _ in range(2):
        grp = make_engine_group({"qwen": cfg_a, "rwkv": cfg_b},
                                {"qwen": p_a, "rwkv": p_b},
                                _two_tenant_serve(wa=2, wb=1))
        reqs = [Request(u, np.arange(6) % cfg_a.vocab_size, max_new=0,
                        tenant="qwen") for u in range(6)]
        reqs += [Request(6 + u, np.arange(6) % cfg_b.vocab_size,
                         max_new=0, tenant="rwkv") for u in range(3)]
        grp.submit(reqs)
        logs.append(list(grp.dispatch_log))
        assert grp.dispatch_log == ["qwen", "qwen", "rwkv"] * 3
        assert grp.fairness_counters == {"qwen": 6, "rwkv": 3}
    assert logs[0] == logs[1]


def test_tenant_routing_rules(qwen):
    """Untagged requests ride the FIRST tenant; an unknown tenant name
    is rejected at submit (never silently misrouted)."""
    cfg, params = qwen
    grp = make_engine_group(cfg, params, _two_tenant_serve())
    grp.submit(Request(0, np.arange(4) % cfg.vocab_size, max_new=0))
    assert grp.dispatch_log == ["qwen"]
    with pytest.raises(ValueError, match="unknown tenant"):
        grp.submit(Request(1, np.arange(4) % cfg.vocab_size, max_new=0,
                           tenant="nobody"))


def test_heterogeneous_bindings_validated(qwen):
    """A per-tenant cfg/params dict must key exactly the tenant names;
    per-tenant dicts without tenants are rejected."""
    cfg, params = qwen
    with pytest.raises(ValueError, match="tenant names"):
        make_engine_group({"qwen": cfg, "other": cfg},
                          {"qwen": params, "other": params},
                          _two_tenant_serve())
    with pytest.raises(ValueError, match="serve.tenants is empty"):
        make_engine_group(
            {"qwen": cfg}, {"qwen": params},
            ServeConfig(event_loops=1,
                        comm=CommConfig(mode="hadronio", channels=2,
                                        hierarchical=False)))
