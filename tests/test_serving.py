"""Serving engine: batching exactness, eos, buckets, determinism."""
import numpy as np
import jax
import pytest

from repro.configs.registry import get_config
from repro.models import api
from repro.serving import DecodeEngine, Request


@pytest.fixture(scope="module")
def qwen():
    cfg = get_config("qwen2-0.5b-reduced")
    params = api.init(jax.random.PRNGKey(0), cfg)
    return cfg, params


def test_mixed_length_batch_is_exact(qwen):
    cfg, params = qwen
    eng = DecodeEngine(cfg, params, max_batch=4, max_len=64)
    p1 = (np.arange(7) * 3) % cfg.vocab_size
    p2 = (np.arange(12) * 5) % cfg.vocab_size
    solo = eng.generate([Request(0, p1, max_new=5)])[0].tokens
    both = eng.generate([Request(0, p1, max_new=5),
                         Request(1, p2, max_new=5)])
    np.testing.assert_array_equal(solo, both[0].tokens)
    assert len(both[1].tokens) == 5


def test_eos_stops_early(qwen):
    cfg, params = qwen
    eng = DecodeEngine(cfg, params, max_batch=2, max_len=64)
    first = eng.generate([Request(0, np.arange(5), max_new=8)])[0].tokens
    eos = int(first[1])
    eng2 = DecodeEngine(cfg, params, max_batch=2, max_len=64, eos_id=eos)
    out = eng2.generate([Request(0, np.arange(5), max_new=8)])[0].tokens
    assert len(out) <= 2 + 1 and out[-1] == eos


def test_respects_max_batch(qwen):
    cfg, params = qwen
    eng = DecodeEngine(cfg, params, max_batch=2, max_len=64)
    reqs = [Request(i, np.arange(4 + i), max_new=3) for i in range(5)]
    res = eng.generate(reqs)
    assert sorted(r.uid for r in res) == list(range(5))
    assert all(len(r.tokens) == 3 for r in res)


def test_recurrent_arch_buckets_by_length():
    cfg = get_config("rwkv6-7b-reduced")
    params = api.init(jax.random.PRNGKey(1), cfg)
    eng = DecodeEngine(cfg, params, max_batch=4, max_len=64)
    p1 = np.arange(6) % cfg.vocab_size
    p2 = np.arange(11) % cfg.vocab_size
    solo = eng.generate([Request(0, p1, max_new=4)])[0].tokens
    mixed = eng.generate([Request(0, p1, max_new=4),
                          Request(1, p2, max_new=4)])
    np.testing.assert_array_equal(solo, mixed[0].tokens)


def test_greedy_is_deterministic(qwen):
    cfg, params = qwen
    eng = DecodeEngine(cfg, params, max_batch=2, max_len=64)
    a = eng.generate([Request(0, np.arange(6), max_new=6)])[0].tokens
    b = eng.generate([Request(0, np.arange(6), max_new=6)])[0].tokens
    np.testing.assert_array_equal(a, b)
