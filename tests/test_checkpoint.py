"""Checkpoint store: roundtrip, atomicity, GC, async, mismatch hook,
TAC moment resharding math."""
import os

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.checkpoint import CheckpointStore
from repro.launch.elastic import reshard_tac_opt


def tree():
    return {"w": jnp.arange(12.0).reshape(3, 4),
            "opt": {"mu": jnp.ones((5,)), "count": jnp.asarray(7)}}


def like_of(t):
    return jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), t)


def test_roundtrip(tmp_path):
    st = CheckpointStore(str(tmp_path))
    st.save(3, tree(), extra={"loss": 1.0})
    assert st.latest_step() == 3
    r = st.restore(3, like_of(tree()))
    for a, b in zip(jax.tree.leaves(r), jax.tree.leaves(tree())):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert st.manifest(3)["extra"]["loss"] == 1.0


def test_gc_keeps_last_k(tmp_path):
    st = CheckpointStore(str(tmp_path), keep=2)
    for s in (1, 2, 3, 4):
        st.save(s, tree())
    assert st.available_steps() == [3, 4]
    assert st.latest_step() == 4


def test_async_save(tmp_path):
    st = CheckpointStore(str(tmp_path))
    st.save_async(5, tree())
    st.wait()
    assert st.latest_step() == 5


def test_atomic_overwrite(tmp_path):
    st = CheckpointStore(str(tmp_path))
    st.save(1, tree())
    t2 = jax.tree.map(lambda x: x * 2, tree())
    st.save(1, t2)
    r = st.restore(1, like_of(tree()))
    np.testing.assert_array_equal(np.asarray(r["w"]),
                                  np.asarray(tree()["w"]) * 2)


def test_dtype_cast_on_restore(tmp_path):
    st = CheckpointStore(str(tmp_path))
    st.save(1, {"w": jnp.ones((4,), jnp.float32)})
    like = {"w": jax.ShapeDtypeStruct((4,), jnp.bfloat16)}
    r = st.restore(1, like)
    assert r["w"].dtype == jnp.bfloat16


def test_mismatch_hook(tmp_path):
    st = CheckpointStore(str(tmp_path))
    st.save(1, {"m": jnp.arange(8.0).reshape(2, 4)})
    like = {"m": jax.ShapeDtypeStruct((4, 2), jnp.float32)}
    with pytest.raises(ValueError):
        st.restore(1, like)
    r = st.restore(1, like, on_mismatch=lambda n, a, ref: a.reshape(4, 2))
    assert r["m"].shape == (4, 2)


def test_reshard_tac_opt_roundtrip():
    """Re-slicing flat moment shards preserves the global vector, for any
    old/new ring sizes (the elastic-scaling invariant)."""
    n_slices, slice_elems = 3, 512 * 4
    glob = np.arange(n_slices * slice_elems, dtype=np.float32)
    glob2 = glob.reshape(n_slices, slice_elems)

    def shards_for(n):
        c = slice_elems // n
        return np.stack([
            np.concatenate([glob2[s, i * c:(i + 1) * c]
                            for s in range(n_slices)])
            for i in range(n)])

    for old, new in [(8, 4), (4, 8), (8, 8), (2, 16)]:
        mu_old = shards_for(old)
        mu_new, _ = reshard_tac_opt(mu_old, mu_old, old, new, n_slices)
        np.testing.assert_array_equal(mu_new, shards_for(new))


def _overlap_rs_run(n_shards):
    from repro.configs.base import CommConfig, RunConfig, ShapeConfig
    from repro.configs.registry import get_config
    run = RunConfig(model=get_config("qwen2-0.5b-reduced"),
                    shape=ShapeConfig("t", "train", 16, 4),
                    comm=CommConfig(mode="hadronio_overlap_rs",
                                    slice_bytes=16 * 1024,
                                    hierarchical=False))
    from repro.core.backends import get_backend
    backend = get_backend("hadronio_overlap_rs")
    spec = backend.state_specs(run, n_shards).opt.mu
    return run, backend, tuple(spec.shape)


def test_overlap_rs_reshard_power_of_two_preserves_values():
    """Ring changes that keep the lcm(512, group) bucket alignment (the
    power-of-two case) re-slice the old moments exactly."""
    run, backend, shape_old = _overlap_rs_run(2)
    stacked = np.arange(np.prod(shape_old), dtype=np.float32).reshape(
        shape_old)
    out = backend.reshard_flat_shards(run, stacked, 4)
    _, _, shape_new = _overlap_rs_run(4)
    assert tuple(out.shape) == shape_new
    # the re-slice is a permutation of the same global values
    np.testing.assert_array_equal(np.sort(out.reshape(-1)),
                                  np.sort(stacked.reshape(-1)))
    assert out.reshape(-1).sum() == stacked.reshape(-1).sum()


def test_overlap_rs_reshard_odd_group_replans_and_reinits():
    """ROADMAP follow-up: a non-power-of-two scatter group changes the
    lcm(512, group) bucket padding, so the old flat layout has no
    element-preserving mapping — the backend replans at the new alignment
    and reinitializes the moments to zero instead of asserting."""
    run, backend, shape_old = _overlap_rs_run(2)
    stacked = np.ones(shape_old, np.float32)
    out = backend.reshard_flat_shards(run, stacked, 3)    # lcm 512 -> 1536
    _, _, shape_new = _overlap_rs_run(3)
    assert tuple(out.shape) == shape_new
    assert out.dtype == np.float32 and not out.any()


def test_elastic_mismatch_hook_routes_odd_group_reshard():
    """launch.elastic.make_on_mismatch must reach the backend hook even
    when the total flat length changes (the replan path) — and still
    reset error-feedback residuals by name, not by shape."""
    from repro.launch.elastic import make_on_mismatch
    run, backend, shape_old = _overlap_rs_run(2)
    _, _, shape_new = _overlap_rs_run(3)
    hook = make_on_mismatch(run)
    ref = jax.ShapeDtypeStruct(shape_new, jnp.float32)
    out = hook(".opt_.mu.npy", np.ones(shape_old, np.float32), ref)
    assert tuple(out.shape) == tuple(shape_new) and not out.any()
    # a 2-D per-bucket EF residual resets to zero instead of resharding
    ef_ref = jax.ShapeDtypeStruct((3, 1536), jnp.float32)
    out = hook(".ef_0.npy", np.ones((2, 512), np.float32), ef_ref)
    assert out.shape == (3, 1536) and not out.any()
