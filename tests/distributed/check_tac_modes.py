import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import numpy as np
import jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from repro.compat import shard_map
from repro.configs.base import CommConfig
from repro.core import tac, aggregation as agg
from repro.launch.mesh import make_mesh

mesh = make_mesh((4, 2), ("data", "model"))

def tree(rng):
    ks = jax.random.split(rng, 4)
    return {"a": jax.random.normal(ks[0], (33, 7)),
            "b": {"c": jax.random.normal(ks[1], (129,)),
                  "d": jax.random.normal(ks[2], (2, 3, 5))},
            "e": jax.random.normal(ks[3], (1024,))}

grads = tree(jax.random.PRNGKey(0))
# expected: mean over data shards? No - psum = sum over data axis of per-shard grads.
# We feed identical grads per shard (replicated), so psum = n_data * grads.

results = {}
for mode in ("sockets", "vma", "hadronio", "hadronio_overlap", "hadronio_rs"):
    comm = CommConfig(mode=mode, slice_bytes=1024, ring_capacity_bytes=64 * 1024,
                      hierarchical=False)

    @jax.jit
    def run(g):
        def inner(g):
            r = tac.sync_grads(g, comm, data_axis="data")
            if mode == "hadronio_rs":
                return tac.gather_updated(r.flat_shard, r.plan, g, comm,
                                          gather_axes=r.gather_axes)
            return r.grads
        return shard_map(inner, mesh=mesh, in_specs=(P(),), out_specs=P(),
                         check_vma=False)(g)

    out = run(grads)
    ref = jax.tree.map(lambda g: g * 4.0, grads)
    errs = jax.tree.map(lambda a, b: float(jnp.max(jnp.abs(a - b))), out, ref)
    maxerr = max(jax.tree.leaves(errs))
    results[mode] = maxerr
    print(f"{mode:12s} max err vs 4*g: {maxerr:.2e}")

# hierarchical with pod axis
mesh3 = make_mesh((2, 2, 2), ("pod", "data", "model"))
for mode in ("hadronio", "hadronio_rs"):
    for hier in (False, True):
        comm = CommConfig(mode=mode, slice_bytes=1024, ring_capacity_bytes=64 * 1024,
                          hierarchical=hier)

        @jax.jit
        def run(g):
            def inner(g):
                r = tac.sync_grads(g, comm, data_axis="data", pod_axis="pod")
                if mode == "hadronio_rs":
                    return tac.gather_updated(r.flat_shard, r.plan, g, comm,
                                              gather_axes=r.gather_axes)
                return r.grads
            return shard_map(inner, mesh=mesh3, in_specs=(P(),), out_specs=P(),
                             check_vma=False)(g)
        out = run(grads)
        ref = jax.tree.map(lambda g: g * 4.0, grads)
        errs = jax.tree.map(lambda a, b: float(jnp.max(jnp.abs(a - b))), out, ref)
        maxerr = max(jax.tree.leaves(errs))
        print(f"{mode:12s} hier={hier} (2,2,2): max err: {maxerr:.2e}")

# compression
for compress in ("bf16", "int8_ef"):
    comm = CommConfig(mode="hadronio", slice_bytes=1024, ring_capacity_bytes=64*1024,
                      compress=compress, hierarchical=False)
    @jax.jit
    def run(g):
        def inner(g):
            r = tac.sync_grads(g, comm, data_axis="data")
            return r.grads
        return shard_map(inner, mesh=mesh, in_specs=(P(),), out_specs=P(),
                         check_vma=False)(g)
    out = run(grads)
    ref = jax.tree.map(lambda g: g * 4.0, grads)
    errs = jax.tree.map(lambda a, b: float(jnp.max(jnp.abs(a - b) / (jnp.abs(b) + 1e-3))), out, ref)
    maxerr = max(jax.tree.leaves(errs))
    print(f"compress={compress:8s} max rel err: {maxerr:.2e}")
print("done")
