import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import numpy as np
import jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from repro.compat import shard_map
from repro.configs.base import CommConfig
from repro.core import tac, aggregation as agg
from repro.core.backends import get_backend
from repro.launch.mesh import make_mesh

mesh = make_mesh((4, 2), ("data", "model"))

def tree(rng):
    ks = jax.random.split(rng, 4)
    return {"a": jax.random.normal(ks[0], (33, 7)),
            "b": {"c": jax.random.normal(ks[1], (129,)),
                  "d": jax.random.normal(ks[2], (2, 3, 5))},
            "e": jax.random.normal(ks[3], (1024,))}

grads = tree(jax.random.PRNGKey(0))
# expected: mean over data shards? No - psum = sum over data axis of per-shard grads.
# We feed identical grads per shard (replicated), so psum = n_data * grads.

MODES = ("sockets", "vma", "hadronio", "hadronio_overlap", "hadronio_rs",
         "hadronio_overlap_rs")

results = {}
for mode in MODES:
    comm = CommConfig(mode=mode, slice_bytes=1024, ring_capacity_bytes=64 * 1024,
                      hierarchical=False)

    @jax.jit
    def run(g):
        def inner(g):
            r = tac.sync_grads(g, comm, data_axis="data")
            # zero1 modes reconstruct via the backend's gather epilogue
            return get_backend(mode).gathered_grads(r, g)
        return shard_map(inner, mesh=mesh, in_specs=(P(),), out_specs=P(),
                         check_vma=False)(g)

    out = run(grads)
    ref = jax.tree.map(lambda g: g * 4.0, grads)
    errs = jax.tree.map(lambda a, b: float(jnp.max(jnp.abs(a - b))), out, ref)
    maxerr = max(jax.tree.leaves(errs))
    results[mode] = maxerr
    assert maxerr < 1e-4, (mode, maxerr)
    print(f"{mode:20s} max err vs 4*g: {maxerr:.2e}")

# hierarchical with pod axis
mesh3 = make_mesh((2, 2, 2), ("pod", "data", "model"))
for mode in ("hadronio", "hadronio_rs", "hadronio_overlap",
             "hadronio_overlap_rs"):
    for hier in (False, True):
        comm = CommConfig(mode=mode, slice_bytes=1024, ring_capacity_bytes=64 * 1024,
                          hierarchical=hier)

        @jax.jit
        def run(g):
            def inner(g):
                r = tac.sync_grads(g, comm, data_axis="data", pod_axis="pod")
                return get_backend(mode).gathered_grads(r, g)
            return shard_map(inner, mesh=mesh3, in_specs=(P(),), out_specs=P(),
                             check_vma=False)(g)
        out = run(grads)
        ref = jax.tree.map(lambda g: g * 4.0, grads)
        errs = jax.tree.map(lambda a, b: float(jnp.max(jnp.abs(a - b))), out, ref)
        maxerr = max(jax.tree.leaves(errs))
        assert maxerr < 1e-4, (mode, hier, maxerr)
        print(f"{mode:20s} hier={hier} (2,2,2): max err: {maxerr:.2e}")

# compression: every codec-capable mode, both EF keyings, both pack impls
# on the real 4-peer ring (chunk indexing / scale math is invisible on 1
# device, so this is the coverage that catches shard-order bugs)
for mode in ("hadronio", "hadronio_overlap", "hadronio_rs",
             "hadronio_overlap_rs"):
    for compress, pack in (("bf16", "jnp"), ("bf16", "pallas"),
                           ("int8_ef", "jnp")):
        comm = CommConfig(mode=mode, slice_bytes=1024, ring_capacity_bytes=64*1024,
                          compress=compress, pack=pack, hierarchical=False)
        @jax.jit
        def run(g):
            def inner(g):
                r = tac.sync_grads(g, comm, data_axis="data")
                return get_backend(mode).gathered_grads(r, g)
            return shard_map(inner, mesh=mesh, in_specs=(P(),), out_specs=P(),
                             check_vma=False)(g)
        out = run(grads)
        ref = jax.tree.map(lambda g: g * 4.0, grads)
        if compress == "bf16":
            # bf16 rounding is relative to the element
            errs = jax.tree.map(lambda a, b: float(jnp.max(
                jnp.abs(a - b) / (jnp.abs(b) + 1e-3))), out, ref)
            maxerr = max(jax.tree.leaves(errs))
            assert maxerr < 0.02, (mode, compress, pack, maxerr)
            kind = "rel"
        else:
            # int8 max-abs quantization error is absolute: bounded by
            # n_peers * slice_amax / 254 (~0.05 here)
            errs = jax.tree.map(lambda a, b: float(jnp.max(jnp.abs(a - b))),
                                out, ref)
            maxerr = max(jax.tree.leaves(errs))
            assert maxerr < 0.1, (mode, compress, pack, maxerr)
            kind = "abs"
        print(f"{mode:20s} compress={compress:8s} pack={pack:6s} max {kind} err: {maxerr:.2e}")
print("done")
