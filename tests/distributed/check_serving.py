"""Multi-shard serving path (4 virtual devices): the batch-sharded
prefill gathering write (peer-major carve + batch-axis re-merge), the
tensor-parallel logit reduction, channel affinity, and engine-group
continuous batching — everything the 1-device tier-1 run degenerates to
identity. Invariants checked at n_shards=4:

* dispatch logits are BIT-identical across comm modes (raw vs staged
  wire) and across channel affinities;
* engine-group greedy tokens are identical across event-loop counts and
  modes, with continuous admission in play (max_batch < ring size);
* an admitted request matches its solo run through the SAME serve path.
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import numpy as np
import jax
import jax.numpy as jnp

from repro.configs.base import CommConfig, ServeConfig
from repro.configs.registry import get_config
from repro.launch.mesh import make_mesh
from repro.models import api
from repro.serving import DecodeEngine, Request, make_engine_group
from repro.serving import dispatch

mesh = make_mesh((4,), ("data",))
cfg = get_config("qwen2-0.5b-reduced")
params = api.init(jax.random.PRNGKey(0), cfg)


def comm_for(mode):
    return CommConfig(mode=mode, slice_bytes=512, channels=4,
                      hierarchical=False)


def step_logits(mode, affinity=None):
    step = dispatch.make_serve_step(cfg, comm_for(mode), mesh,
                                    channel_indices=affinity)
    assert step.n_shards == 4
    toks = np.zeros((4, 8), np.int32)          # 4 rows, mixed lengths
    lens = np.array([5, 6, 7, 5], np.int32)
    for r in range(4):
        toks[r, :lens[r]] = (np.arange(lens[r]) * (r + 2)) % cfg.vocab_size
    batch = {"tokens": jnp.asarray(toks), "last_pos": jnp.asarray(lens - 1)}
    logits_p, cache = step.prefill(params, batch)
    cache = api.grow_cache(cfg, cache, 32)
    dec = {"token": jnp.argmax(logits_p, -1).astype(jnp.int32),
           "pos": jnp.asarray(lens, jnp.int32)}
    logits_d, _ = step.decode(params, cache, dec)
    return np.asarray(logits_p), np.asarray(logits_d)


ref_p, ref_d = step_logits("gspmd")
assert ref_p.shape == (4, cfg.vocab_size)
for mode in ("sockets", "hadronio", "hadronio_overlap_rs"):
    got_p, got_d = step_logits(mode)
    np.testing.assert_array_equal(got_p, ref_p)
    np.testing.assert_array_equal(got_d, ref_d)
    print(f"dispatch logits bit-identical: {mode}")
aff_p, aff_d = step_logits("hadronio", affinity=(1, 3))
np.testing.assert_array_equal(aff_p, ref_p)
np.testing.assert_array_equal(aff_d, ref_d)
print("dispatch logits invariant to channel affinity")

rng = np.random.default_rng(5)
reqs = [Request(i, rng.integers(0, cfg.vocab_size,
                                size=int(rng.integers(4, 14))),
                max_new=3) for i in range(5)]


def group_tokens(mode, el):
    serve = ServeConfig(event_loops=el, poll="busy", max_batch=2,
                        max_len=48, comm=comm_for(mode))
    grp = make_engine_group(cfg, params, serve, mesh=mesh)
    grp.submit(reqs)
    res = sorted(grp.run(threads=False), key=lambda r: r.uid)
    assert [r.uid for r in res] == list(range(5))
    return [tuple(r.tokens.tolist()) for r in res]


a = group_tokens("hadronio", 1)      # max_batch=2 < ring 4: padded rows
b = group_tokens("hadronio", 2)      # stay empty, admission in play
c = group_tokens("gspmd", 1)
assert a == b == c, (a, b, c)
print("engine-group tokens identical across modes and event loops:", a[0])

serve = ServeConfig(event_loops=1, poll="busy", max_batch=2, max_len=48,
                    comm=comm_for("hadronio"))
solo_eng = DecodeEngine(cfg, params, max_batch=2, max_len=48, serve=serve,
                        mesh=mesh)
solo = solo_eng.generate([reqs[4]])[0]       # reqs[4] was admitted above
assert tuple(solo.tokens.tolist()) == a[4], (solo.tokens, a[4])
print("admitted request matches its solo run at n_shards=4")

print("ALL OK")
