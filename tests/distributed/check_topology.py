"""Two-level serving fabric at 8 virtual devices (2 pods x 4): the
leader-channel emission, pod-aware dispatch wiring, and topology-aware
affinity — everything the 1-device tier-1 run degenerates to identity.
Invariants checked on the (2, 4) ("pod", "data") serve mesh:

* ``psum_hierarchical`` equals the flat psum numerically (allclose — the
  two summation orders legitimately differ in the last ulps) including
  the non-divisible-S padding edge, and gathers are BIT-identical;
* dispatch logits are BIT-identical across the hadronio-family modes and
  channel affinities WITHIN a fixed emission (the transparency claim,
  pod-aware); across flat vs hierarchical emission the prefill logits
  stay bitwise (gathers move data, they never re-associate) and decode
  logits agree to allclose with equal argmax;
* engine-group greedy TOKENS are identical for flat vs leader-channel
  hierarchical emission across event-loop counts {1, 2, 4};
* the lowered decode step's cross-pod collective count drops to
  ``comm.leader_channels`` under leader emission while flat emission
  keeps all ``comm.channels`` collectives cross-pod.
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import numpy as np
import jax
import jax.numpy as jnp
from functools import partial
from jax.sharding import PartitionSpec as P

from repro.compat import shard_map
from repro.configs.base import CommConfig, ServeConfig
from repro.configs.registry import get_config
from repro.core.hierarchical import (psum_hierarchical,
                                     psum_scatter_hierarchical)
from repro.launch import hlo_analysis as hlo
from repro.launch.mesh import make_serve_mesh
from repro.models import api
from repro.serving import Request, make_engine_group
from repro.serving import dispatch

mesh = make_serve_mesh(2)                   # (2, 4) ("pod", "data")
assert tuple(mesh.axis_names) == ("pod", "data")
cfg = get_config("qwen2-0.5b-reduced")
params = api.init(jax.random.PRNGKey(0), cfg)

# -- core/hierarchical.py in isolation ---------------------------------

for S in (64, 1003):                        # divisible and padded edges
    x = (np.arange(8 * S, dtype=np.float32).reshape(8, S) * 1e-3 + 0.1)
    xd = jax.device_put(jnp.asarray(x),
                        jax.NamedSharding(mesh, P(("pod", "data"))))

    @jax.jit
    @partial(shard_map, mesh=mesh, in_specs=P(("pod", "data")),
             out_specs=P(), check_vma=False)
    def hier(v):
        return psum_hierarchical(v.reshape(-1), "pod", "data")

    @jax.jit
    @partial(shard_map, mesh=mesh, in_specs=P(("pod", "data")),
             out_specs=P(), check_vma=False)
    def flat(v):
        return jax.lax.psum(v.reshape(-1), ("pod", "data"))

    np.testing.assert_allclose(np.asarray(hier(xd)), np.asarray(flat(xd)),
                               rtol=1e-5)
    print(f"psum_hierarchical == flat psum (allclose) at S={S}")

try:
    @jax.jit
    @partial(shard_map, mesh=mesh, in_specs=P(("pod", "data")),
             out_specs=P(("pod", "data")), check_vma=False)
    def bad(v):
        return psum_scatter_hierarchical(v, "pod", "data")

    bad(jax.device_put(jnp.ones((8, 1003), jnp.float32),
                       jax.NamedSharding(mesh, P(("pod", "data")))))
    raise SystemExit("psum_scatter_hierarchical accepted non-divisible S")
except ValueError as e:
    assert "divisible by the in-pod ring size" in str(e)
    print("psum_scatter_hierarchical rejects non-divisible S with a clear "
          "error")

# -- dispatch conformance: flat vs hierarchical emission ---------------


def comm_for(mode, hier, channels=6, leader_channels=2):
    return CommConfig(mode=mode, slice_bytes=512, channels=channels,
                      aggregate="channel", flush="ready",
                      hierarchical=hier, leader_channels=leader_channels)


def step_logits(comm, affinity=None):
    step = dispatch.make_serve_step(cfg, comm, mesh,
                                    channel_indices=affinity)
    assert step.n_shards == 8
    assert step.n_pods == 2 and (step.pod_axis == "pod"
                                 if comm.hierarchical
                                 else step.pod_axis is None)
    toks = np.zeros((8, 8), np.int32)
    lens = np.array([5, 6, 7, 5, 4, 8, 6, 5], np.int32)
    for r in range(8):
        toks[r, :lens[r]] = (np.arange(lens[r]) * (r + 2)) % cfg.vocab_size
    batch = {"tokens": jnp.asarray(toks), "last_pos": jnp.asarray(lens - 1)}
    logits_p, cache = step.prefill(params, batch)
    cache = api.grow_cache(cfg, cache, 32)
    dec = {"token": jnp.argmax(logits_p, -1).astype(jnp.int32),
           "pos": jnp.asarray(lens, jnp.int32)}
    logits_d, _ = step.decode(params, cache, dec)
    return np.asarray(logits_p), np.asarray(logits_d)


hier_p, hier_d = step_logits(comm_for("hadronio", True))
for mode in ("hadronio_overlap", "hadronio_overlap_rs"):
    got_p, got_d = step_logits(comm_for(mode, True))
    np.testing.assert_array_equal(got_p, hier_p)
    np.testing.assert_array_equal(got_d, hier_d)
    print(f"hierarchical dispatch logits bit-identical: {mode}")
aff_p, aff_d = step_logits(comm_for("hadronio", True), affinity=(1, 2, 5))
np.testing.assert_array_equal(aff_p, hier_p)
np.testing.assert_array_equal(aff_d, hier_d)
print("hierarchical dispatch logits invariant to channel affinity")

flat_p, flat_d = step_logits(comm_for("hadronio", False))
ref_p, ref_d = step_logits(comm_for("gspmd", False))
np.testing.assert_array_equal(flat_p, ref_p)
np.testing.assert_array_equal(flat_d, ref_d)
# gathers are data movement: prefill logits stay bitwise across emissions
np.testing.assert_array_equal(hier_p, flat_p)
print("prefill logits BIT-identical across flat vs hierarchical emission")
# the all-reduce re-associates: decode logits agree to allclose, and the
# served (greedy) tokens are identical
np.testing.assert_allclose(hier_d, flat_d, rtol=1e-4, atol=1e-5)
np.testing.assert_array_equal(hier_d.argmax(-1), flat_d.argmax(-1))
print("decode logits allclose + argmax-equal across emissions")

# -- engine group: served tokens across emissions and loop counts ------

rng = np.random.default_rng(5)
reqs = [Request(i, rng.integers(0, cfg.vocab_size,
                                size=int(rng.integers(4, 14))),
                max_new=2) for i in range(4)]


def group_tokens(hier, el):
    serve = ServeConfig(event_loops=el, poll="busy", max_batch=2,
                        max_len=32, pods=2,
                        leader_loops=min(el, 2) if hier else 1,
                        comm=comm_for("hadronio_overlap", hier))
    grp = make_engine_group(cfg, params, serve, mesh=mesh)
    if hier:
        leads = {c for l in grp.loops for c in l.channels if c >= 4}
        owners = [l.index for l in grp.loops
                  if any(c >= 4 for c in l.channels)]
        assert leads == {4, 5}, leads
        assert owners == list(range(len(owners))), owners
    grp.submit(reqs)
    res = sorted(grp.run(threads=False), key=lambda r: r.uid)
    return [tuple(r.tokens.tolist()) for r in res]


base = group_tokens(False, 1)
for el in (1, 2, 4):
    got = group_tokens(True, el)
    assert got == base, (el, got, base)
    print(f"served tokens identical, flat vs hierarchical, "
          f"event_loops={el}")

# -- cross-pod collective evidence -------------------------------------

for leader_channels in (1, 2):
    comm = comm_for("hadronio_overlap", True,
                    leader_channels=leader_channels)
    cp = hlo.cross_pod_collective_count(
        dispatch.lowered_decode_text(cfg, comm, batch=8, mesh=mesh), 4)
    assert cp["cross_pod_total"] == leader_channels, (leader_channels, cp)
    assert cp["in_pod_total"] > 0, cp
flat_cp = hlo.cross_pod_collective_count(
    dispatch.lowered_decode_text(cfg, comm_for("hadronio_overlap", False),
                                 batch=8, mesh=mesh), 4)
assert flat_cp["cross_pod_total"] == 6, flat_cp    # every channel
print("cross-pod collectives: n_leader_channels (hierarchical) vs "
      "n_channels (flat)")

print("ALL OK")
