import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import numpy as np
import jax, jax.numpy as jnp
from repro.configs.base import CommConfig, RunConfig
from repro.configs.registry import get_config, get_shape
from repro.launch import steps
from repro.launch.mesh import make_mesh
from repro import compat
from repro.launch.sharding import batch_sharding
from repro.models import api

print("jax.shard_map:", hasattr(jax, "shard_map"))
print("set_mesh:", hasattr(jax, "set_mesh"))

cfg = get_config("qwen1.5-4b-reduced")
B, S = 8, 32
shape = get_shape("train_4k")
rng = jax.random.PRNGKey(0)
batch = {"tokens": jax.random.randint(rng, (B, S), 0, cfg.vocab_size),
         "labels": jax.random.randint(rng, (B, S), 0, cfg.vocab_size)}

mesh = make_mesh((4, 2), ("data", "model"))

# --- GSPMD path ---
run = RunConfig(model=cfg, shape=shape, comm=CommConfig(mode="gspmd"))
with compat.set_mesh(mesh):
    step_fn, state_sh, batch_sh_fn = steps.make_train_step(run, mesh)
    state = jax.device_put(steps.init_train_state(rng, run), state_sh)
    jitted = jax.jit(step_fn, in_shardings=(state_sh, batch_sh_fn(mesh, batch)),
                     out_shardings=(state_sh, None), donate_argnums=(0,))
    state1, metrics = jitted(state, batch)
    print("gspmd loss:", float(metrics["loss"]), "gnorm:", float(metrics["grad_norm"]))
    state2, m2 = jitted(state1, batch)
    print("gspmd loss2:", float(m2["loss"]))
    assert float(m2["loss"]) < float(metrics["loss"]), "loss should drop"

# --- TAC paths ---
losses = {}
for mode in ("sockets", "vma", "hadronio", "hadronio_overlap", "hadronio_rs"):
    run = RunConfig(model=cfg, shape=shape,
                    comm=CommConfig(mode=mode, slice_bytes=256 * 1024,
                                    ring_capacity_bytes=16 * 1024 * 1024,
                                    hierarchical=False))
    with compat.set_mesh(mesh):
        step_fn, state_sh, batch_sh_fn = steps.make_train_step(run, mesh)
        state = jax.device_put(steps.init_tac_state(rng, run, 8), state_sh)
        jitted = jax.jit(step_fn, in_shardings=(state_sh, batch_sh_fn(mesh, batch)),
                         out_shardings=(state_sh, None), donate_argnums=(0,))
        state1, metrics = jitted(state, batch)
        state2, m2 = jitted(state1, batch)
        losses[mode] = (float(metrics["loss"]), float(m2["loss"]))
        print(f"{mode}: loss {losses[mode][0]:.6f} -> {losses[mode][1]:.6f}")

# transparency: all modes produce the same loss trajectory (within fp tolerance)
vals0 = [v[0] for v in losses.values()]
vals1 = [v[1] for v in losses.values()]
assert max(vals0) - min(vals0) < 1e-4, vals0
assert max(vals1) - min(vals1) < 1e-3, vals1
print("transparency check OK")

# microbatching
run = RunConfig(model=cfg, shape=shape, comm=CommConfig(mode="hadronio", hierarchical=False),
                microbatches=2)
batch16 = {"tokens": jax.random.randint(rng, (16, S), 0, cfg.vocab_size),
           "labels": jax.random.randint(rng, (16, S), 0, cfg.vocab_size)}
with compat.set_mesh(mesh):
    step_fn, state_sh, batch_sh_fn = steps.make_train_step(run, mesh)
    state = jax.device_put(steps.init_tac_state(rng, run, 8), state_sh)
    s1, m = jax.jit(step_fn, in_shardings=(state_sh, batch_sh_fn(mesh, batch16)),
                    out_shardings=(state_sh, None))(state, batch16)
    print("microbatch hadronio loss:", float(m["loss"]))

# compression state threading
run = RunConfig(model=cfg, shape=shape,
                comm=CommConfig(mode="hadronio", compress="bf16", hierarchical=False))
with compat.set_mesh(mesh):
    step_fn, state_sh, batch_sh_fn = steps.make_train_step(run, mesh)
    state = jax.device_put(steps.init_tac_state(rng, run, 8), state_sh)
    s1, m = jax.jit(step_fn, in_shardings=(state_sh, batch_sh_fn(mesh, batch)),
                    out_shardings=(state_sh, None))(state, batch)
    print("bf16-compressed hadronio loss:", float(m["loss"]), "ef shape:", s1.ef.shape)
print("ALL OK")

# --- hierarchical TAC on a (pod, data, model) mesh: trajectories must match
mesh3 = make_mesh((2, 2, 2), ("pod", "data", "model"))
batch3 = {"tokens": jax.random.randint(rng, (8, S), 0, cfg.vocab_size),
          "labels": jax.random.randint(rng, (8, S), 0, cfg.vocab_size)}
tr3 = {}
for mode, hier in (("sockets", False), ("hadronio", True),
                   ("hadronio_rs", True), ("hadronio_rs", False)):
    run = RunConfig(model=cfg, shape=shape,
                    comm=CommConfig(mode=mode, slice_bytes=256 * 1024,
                                    hierarchical=hier))
    with compat.set_mesh(mesh3):
        step_fn, state_sh, batch_sh_fn = steps.make_train_step(run, mesh3)
        state = jax.device_put(steps.init_tac_state(rng, run, 8, 2),
                               state_sh)
        jitted = jax.jit(step_fn, in_shardings=(state_sh,
                                                batch_sh_fn(mesh3, batch3)),
                         out_shardings=(state_sh, None))
        losses = []
        for _ in range(3):
            state, m = jitted(state, batch3)
            losses.append(float(m["loss"]))
        tr3[(mode, hier)] = losses
        print(f"pod-mesh {mode:12s} hier={hier}: {['%.5f' % l for l in losses]}")
ref3 = np.array(tr3[("sockets", False)])
for k, v in tr3.items():
    assert np.max(np.abs(np.array(v) - ref3)) < 2e-3, (k, v)
print("hierarchical pod-mesh trajectory equivalence OK")
print("ALL OK")
