import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import shutil
import numpy as np
import jax
from repro.configs.base import CommConfig, RunConfig, ShapeConfig
from repro.configs.registry import get_config
from repro.launch.mesh import make_mesh
from repro.launch.train import Trainer, train_with_restarts
from repro.launch.elastic import restore_elastic
from repro.checkpoint import CheckpointStore

cfg = get_config("qwen2-0.5b-reduced")
shape = ShapeConfig(name="t", kind="train", seq_len=64, global_batch=8)

def mkrun(mode, ckpt="", steps=6, **kw):
    return RunConfig(model=cfg, shape=shape,
                     comm=CommConfig(mode=mode, slice_bytes=128 * 1024,
                                     hierarchical=False),
                     lr=1e-3, total_steps=steps, warmup_steps=2,
                     checkpoint_dir=ckpt, checkpoint_every=3,
                     async_checkpoint=False, **kw)

mesh = make_mesh((8,), ("data",))

# --- 6-step trajectory equivalence across modes (catches opt-state bugs) ---
trajs = {}
for mode in ("gspmd", "sockets", "hadronio", "hadronio_rs"):
    t = Trainer(mkrun(mode), mesh, log_every=100, log_fn=lambda s: None)
    out = t.run_loop()
    trajs[mode] = out["losses"]
    print(f"{mode:12s}: {['%.4f' % l for l in out['losses']]}")
ref = np.array(trajs["sockets"])
for mode, tr in trajs.items():
    d = np.max(np.abs(np.array(tr) - ref))
    assert d < 2e-3, (mode, d, tr)
print("6-step trajectory equivalence OK")

# --- fault injection + restart + checkpoint resume ---
ck = "/tmp/ck_train_test"
shutil.rmtree(ck, ignore_errors=True)
for f in ("/tmp/repro_fault_fired",):
    if os.path.exists(f): os.remove(f)
os.environ["REPRO_FAULT_AT_STEP"] = "4"
run = mkrun("hadronio", ckpt=ck, steps=8)
out = train_with_restarts(lambda: Trainer(run, mesh, log_every=100,
                                          log_fn=lambda s: None))
del os.environ["REPRO_FAULT_AT_STEP"]
# must match an uninterrupted run exactly (deterministic resume)
shutil.rmtree(ck, ignore_errors=True)
run2 = mkrun("hadronio", ckpt="", steps=8)
out2 = Trainer(run2, mesh, log_every=100, log_fn=lambda s: None).run_loop()
print("faulted final:", out["final_loss"], "clean final:", out2["final_loss"])
assert abs(out["final_loss"] - out2["final_loss"]) < 1e-5
print("fault-tolerant restart OK (bitwise resume)")

# --- elastic: continue on a smaller mesh ---
shutil.rmtree(ck, ignore_errors=True)
run = mkrun("hadronio_rs", ckpt=ck, steps=4)
t = Trainer(run, mesh, log_every=100, log_fn=lambda s: None)
t.run_loop()
mesh4 = make_mesh((4,), ("data",))
store = CheckpointStore(ck)
run_cont = mkrun("hadronio_rs", ckpt=ck, steps=8)
state, s = restore_elastic(store, run_cont, mesh4)
print(f"elastic restore at step {s} onto 4 devices OK")
t4 = Trainer(run_cont, mesh4, log_every=100, log_fn=lambda s: None)
out4 = t4.run_loop()   # restores from ckpt internally? no - restore_or_init needs same shapes
print("elastic continue final:", out4["final_loss"])
shutil.rmtree(ck, ignore_errors=True)
print("ALL OK")
