"""Chaos harness at n_shards=4 (virtual devices): every fault scenario
recovers bit-identically when the serve path actually shards — the
batch-sharded prefill wire, the staged per-channel flushes and the
cross-shard collectives are all live, so drops/dups/stalls/storms/
reshards are absorbed by the REAL multi-shard emission structure, not
the 1-device identity degeneration tier-1 exercises.

Checked here:
* same seed => same injection trace and same runtime evidence at 4
  shards (deterministic replay is not a 1-device artifact);
* all five scenarios x (hadronio, hadronio_overlap) x event_loops in
  {1, 2} recover against one fault-free 4-shard reference.
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import jax
import numpy as np

from repro.configs.base import ModelConfig
from repro.launch.mesh import make_mesh
from repro.models import api
from repro.serving import chaos, slo

mesh = make_mesh((4,), ("data",))
cfg = ModelConfig(name="chaos-tiny", family="dense", num_layers=1,
                  d_model=16, num_heads=2, num_kv_heads=2, d_ff=32,
                  vocab_size=64, head_dim=8, param_dtype="float32",
                  compute_dtype="float32")
params = api.init(jax.random.PRNGKey(0), cfg)
reqs = chaos.make_requests(4, vocab_size=cfg.vocab_size)

base = chaos.run_baseline(cfg, params,
                          chaos.chaos_serve_config("hadronio", 1),
                          reqs, mesh=mesh)
assert base.tokens and all(base.tokens.values())
ref = chaos.Baseline(tokens=base.tokens)
print(f"fault-free reference @4 shards: {len(base.tokens)} requests")

# deterministic replay at 4 shards
serve = chaos.chaos_serve_config("hadronio", 2)
for scenario in chaos.SCENARIOS:
    runs = [chaos.run_scenario(scenario, cfg, params, serve, reqs,
                               seed=11, baseline=ref, mesh=mesh)
            for _ in range(2)]
    a, b = runs
    assert a.plan.trace() == b.plan.trace()
    assert a.fired == b.fired and a.drains == b.drains
    assert a.tokens == b.tokens == base.tokens
    print(f"replay deterministic @4 shards: {scenario} "
          f"({a.report.n_injected} injected)")

# recovery matrix across modes x loop counts
for mode in ("hadronio", "hadronio_overlap"):
    for el in (1, 2):
        sv = chaos.chaos_serve_config(mode, el)
        for scenario in chaos.SCENARIOS:
            res = chaos.run_scenario(scenario, cfg, params, sv, reqs,
                                     seed=5, baseline=ref, mesh=mesh)
            assert res.report.recovered, (scenario, mode, el)
            assert res.tokens == base.tokens, (scenario, mode, el)
            slo.assert_slo(res.report)
        print(f"recovered @4 shards: {mode} el={el} "
              f"({len(chaos.SCENARIOS)} scenarios)")

print("ALL OK")
