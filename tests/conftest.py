# NOTE: deliberately no --xla_force_host_platform_device_count here (the
# brief requires smoke tests to see 1 device). Multi-device behaviour is
# exercised by the subprocess scripts under tests/distributed/.
import os
import sys

import numpy as np
import pytest

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
# repo root: makes the benchmarks/ package importable (autotune smoke test)
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

import jax  # noqa: E402


@pytest.fixture(scope="session")
def rng():
    return jax.random.PRNGKey(0)


@pytest.fixture()
def np_rng():
    return np.random.default_rng(0)
