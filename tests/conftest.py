# NOTE: deliberately no --xla_force_host_platform_device_count here (the
# brief requires smoke tests to see 1 device). Multi-device behaviour is
# exercised by the subprocess scripts under tests/distributed/.
#
# ONE opt-in exception: the CI pod-conformance leg sets
# REPRO_CONFORMANCE_TOPO=pod, which needs real ring peers for the
# flat-vs-hierarchical emission checks in tests/test_topology.py — that
# leg (and only that leg) forces 4 host devices, and only when the
# caller has not pinned XLA_FLAGS itself.
import os
import sys

if os.environ.get("REPRO_CONFORMANCE_TOPO") == "pod" \
        and "XLA_FLAGS" not in os.environ:
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"

import numpy as np
import pytest

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
# repo root: makes the benchmarks/ package importable (autotune smoke test)
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

import jax  # noqa: E402


@pytest.fixture(scope="session")
def rng():
    return jax.random.PRNGKey(0)


@pytest.fixture()
def np_rng():
    return np.random.default_rng(0)
