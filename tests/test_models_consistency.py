"""Prefill/decode consistency: for every family, decoding token-by-token
must reproduce the logits of a longer prefill. This exercises every cache
path (KV, rolling-window, RWKV state, RG-LRU state, whisper cross-attn)."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs.registry import get_config
from repro.models import api

ARCHS = ["qwen1.5-4b", "starcoder2-3b", "mixtral-8x7b", "rwkv6-7b",
         "recurrentgemma-9b", "whisper-tiny", "llava-next-mistral-7b",
         "dbrx-132b"]


def extras(cfg, rng, b):
    out = {}
    if cfg.family == "encdec":
        out["frames"] = jax.random.normal(
            rng, (b, cfg.num_frames, cfg.d_model), jnp.float32)
    if cfg.family == "vlm":
        out["patches"] = jax.random.normal(
            rng, (b, cfg.num_patches, cfg.d_model), jnp.float32)
    return out


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_matches_prefill(arch, rng):
    cfg = get_config(arch + "-reduced")
    params = api.init(rng, cfg)
    b, s0, n_extra = 2, 7, 3
    toks = jax.random.randint(rng, (b, s0 + n_extra), 0, cfg.vocab_size)
    ex = extras(cfg, rng, b)

    # reference: prefill over progressively longer prefixes
    ref_logits = []
    for t in range(s0, s0 + n_extra + 1):
        lg, _ = api.prefill(params, {"tokens": toks[:, :t], **ex}, cfg)
        ref_logits.append(np.asarray(lg, np.float32))

    # decode path: prefill s0 then feed tokens one at a time (prefill
    # caches are prompt-sized; decode slots must be grown first)
    lg, cache = api.prefill(params, {"tokens": toks[:, :s0], **ex}, cfg)
    cache = api.grow_cache(cfg, cache, s0 + n_extra)
    got = [np.asarray(lg, np.float32)]
    for i in range(n_extra):
        step = {"token": toks[:, s0 + i], "pos": jnp.asarray(s0 + i)}
        lg, cache = api.decode_step(params, cache, step, cfg)
        got.append(np.asarray(lg, np.float32))

    for t, (a, b_) in enumerate(zip(got, ref_logits)):
        np.testing.assert_allclose(a, b_, atol=2e-3, rtol=2e-3,
                                   err_msg=f"{arch} step {t}")


def test_windowed_decode_consistency_beyond_window(rng):
    """Mixtral-style SWA: consistency must hold after the rolling cache
    wraps (prefix length > window)."""
    cfg = get_config("mixtral-8x7b-reduced")   # window reduced to 16
    params = api.init(rng, cfg)
    b, s0, n_extra = 1, 20, 3                  # s0 > window
    toks = jax.random.randint(rng, (b, s0 + n_extra), 0, cfg.vocab_size)

    lg, cache = api.prefill(params, {"tokens": toks[:, :s0]}, cfg)
    cache = api.grow_cache(cfg, cache, s0 + n_extra)
    got = [np.asarray(lg, np.float32)]
    for i in range(n_extra):
        lg, cache = api.decode_step(
            params, cache, {"token": toks[:, s0 + i],
                            "pos": jnp.asarray(s0 + i)}, cfg)
        got.append(np.asarray(lg, np.float32))
    for t in range(n_extra + 1):
        ref, _ = api.prefill(params, {"tokens": toks[:, :s0 + t]}, cfg)
        np.testing.assert_allclose(got[t], np.asarray(ref, np.float32),
                                   atol=2e-3, rtol=2e-3, err_msg=f"t={t}")


def test_loss_mask(rng):
    cfg = get_config("qwen2-0.5b-reduced")
    params = api.init(rng, cfg)
    b, s = 2, 8
    batch = {"tokens": jax.random.randint(rng, (b, s), 0, cfg.vocab_size),
             "labels": jax.random.randint(rng, (b, s), 0, cfg.vocab_size)}
    l_full, _ = api.loss(params, batch, cfg)
    half = dict(batch, loss_mask=jnp.concatenate(
        [jnp.ones((b, s // 2)), jnp.zeros((b, s // 2))], axis=1))
    l_half, _ = api.loss(params, half, cfg)
    assert not np.isclose(float(l_full), float(l_half))
    # fully-masked second half == loss over first half only
    first, _ = api.loss(params, {"tokens": batch["tokens"][:, :s // 2 + 1],
                                 "labels": batch["labels"][:, :s // 2 + 1],
                                 "loss_mask": jnp.ones((b, s // 2 + 1)).at[:, -1].set(0)},
                        cfg)


def test_init_is_path_stable(rng):
    """Adding a parameter elsewhere must not change other leaves' init
    (fold_in by path hash, not traversal order)."""
    cfg = get_config("qwen2-0.5b-reduced")
    p1 = api.init(rng, cfg)
    p2 = api.init(rng, cfg)
    flat1 = jax.tree.leaves(p1)
    flat2 = jax.tree.leaves(p2)
    for a, b in zip(flat1, flat2):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
