"""Flush-when-ready scheduling units (core/flush_scheduler.py,
selector.ready_groups, channels.ChannelFill, the pipeline's staged
emission API). The end-to-end properties — bit-identical parity and the
jaxpr-level overlap evidence — live in tests/test_backend_conformance.py;
this file pins the combinatorial pieces directly."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from repro import compat
from repro.configs.base import CommConfig
from repro.core.backends import SyncContext, pipeline
from repro.core.channels import ChannelFill, channel_groups
from repro.core.flush_scheduler import FLUSHES, make_flush_plan
from repro.core.selector import ready_groups
from repro.launch.mesh import make_mesh


# ---------------------------------------------------------------------------
# ready_groups: the contiguous bucket->channel grouping
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("n,c", [(1, 1), (3, 2), (6, 2), (7, 3), (8, 8),
                                 (5, 16), (12, 5)])
def test_ready_groups_partition(n, c):
    """Exact partition of the production order into contiguous runs,
    sizes balanced to within one, smaller runs FIRST (earliest
    readiness)."""
    groups = ready_groups(n, c)
    assert len(groups) == min(n, c)
    flat = [i for g in groups for i in g]
    assert flat == list(range(n))                 # partition, in order
    sizes = [len(g) for g in groups]
    assert max(sizes) - min(sizes) <= 1
    assert sizes == sorted(sizes)                 # smaller groups first
    for g in groups:
        assert g == tuple(range(g[0], g[0] + len(g)))   # contiguous


def test_ready_groups_reverse():
    """reverse=True partitions the reverse emission order instead."""
    groups = ready_groups(4, 2, reverse=True)
    assert [i for g in groups for i in g] == [3, 2, 1, 0]


# ---------------------------------------------------------------------------
# make_flush_plan
# ---------------------------------------------------------------------------


def test_step_plan_matches_round_robin():
    """flush="step" preserves the PR 3 layout exactly: round-robin
    groups, every item's channel is i % C."""
    plan = make_flush_plan(7, 3, "step")
    assert plan.groups == tuple(tuple(g) for g in channel_groups(7, 3))
    assert plan.assign == tuple(i % 3 for i in range(7))
    assert not plan.contiguous


def test_ready_plan_triggers_and_depth():
    """Triggers are each group's last (max) item; readiness depth — the
    number of buckets that must exist before the FIRST flush — is the
    first group's size under "ready" and the whole exchange under
    "step"."""
    plan = make_flush_plan(6, 2, "ready")
    assert plan.groups == ((0, 1, 2), (3, 4, 5))
    assert plan.triggers == (2, 5)
    assert plan.readiness_depth == 3
    assert plan.contiguous
    step = make_flush_plan(6, 2, "step")
    assert step.readiness_depth == 6
    assert plan.readiness_depth < step.readiness_depth


def test_plan_clamps_channels():
    """More channels than items degenerates to singleton groups (fully
    independent flushes) for both schedules."""
    for flush in FLUSHES:
        plan = make_flush_plan(3, 16, flush)
        assert plan.n_channels == 3
        assert plan.groups == ((0,), (1,), (2,))
        assert plan.readiness_depth == (1 if flush == "ready" else 3)


def test_plan_rejects_unknown_flush():
    with pytest.raises(AssertionError):
        make_flush_plan(4, 2, "eventually")


# ---------------------------------------------------------------------------
# ChannelFill: the readiness watermark
# ---------------------------------------------------------------------------


def test_channel_fill_watermark():
    fill = ChannelFill(frozenset({1, 3, 5}))
    assert fill.watermark == 0.0 and not fill.ready
    fill.stage(1)
    assert fill.watermark == pytest.approx(1 / 3) and not fill.ready
    fill.stage(3)
    fill.stage(5)
    assert fill.watermark == 1.0 and fill.ready
    fill.flushed = True
    assert not fill.ready                       # never flush twice


def test_channel_fill_rejects_bad_stage():
    fill = ChannelFill(frozenset({0, 1}))
    with pytest.raises(AssertionError):
        fill.stage(7)                           # not assigned here
    fill.stage(0)
    with pytest.raises(AssertionError):
        fill.stage(0)                           # double stage


# ---------------------------------------------------------------------------
# The staged emission API (pipeline.begin_emission / stage_slices /
# flush_ready / finish_emission)
# ---------------------------------------------------------------------------


def _ctx(**kw):
    kw.setdefault("mode", "hadronio")
    kw.setdefault("hierarchical", False)
    comm = CommConfig(**kw)
    return SyncContext.resolve(comm, ("data",), None)


def _items(n=5, elems=128):
    rng = np.random.default_rng(0)
    return [jnp.asarray(rng.normal(size=(elems,)), jnp.float32)
            for _ in range(n)]


@pytest.mark.parametrize("aggregate", ["slice", "channel"])
@pytest.mark.parametrize("flush", ["step", "ready"])
def test_incremental_staging_matches_oneshot(aggregate, flush):
    """Driving stage_slices item by item produces the same values as the
    emit_through_channels one-shot wrapper, for every schedule."""
    mesh = make_mesh((1,), ("data",))
    items = _items()

    def oneshot(*xs):
        ctx = _ctx(channels=2, aggregate=aggregate, flush=flush)
        return tuple(pipeline.emit_through_channels(list(xs), ctx,
                                                    "all_reduce"))

    def incremental(*xs):
        ctx = _ctx(channels=2, aggregate=aggregate, flush=flush)
        st = pipeline.begin_emission(ctx, len(xs), "all_reduce")
        for i, x in enumerate(xs):
            pipeline.stage_slices(st, i, x)
        return tuple(pipeline.finish_emission(st))

    outs = {}
    for name, fn in [("oneshot", oneshot), ("incremental", incremental)]:
        f = jax.jit(compat.shard_map(
            fn, mesh=mesh, in_specs=(P(),) * len(items),
            out_specs=(P(),) * len(items)))
        outs[name] = f(*items)
    for a, b, x in zip(outs["oneshot"], outs["incremental"], items):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        np.testing.assert_array_equal(np.asarray(a), np.asarray(x))


def test_step_schedule_defers_all_flushes():
    """Under flush="step" + aggregate="channel", stage_slices never
    emits (the barrier loop is finish_emission); under "ready" the flush
    fires the moment a channel's last item is staged."""
    mesh = make_mesh((1,), ("data",))
    items = _items(4)
    seen = {}

    def body(*xs):
        for flush in ("step", "ready"):
            ctx = _ctx(channels=2, aggregate="channel", flush=flush)
            st = pipeline.begin_emission(ctx, len(xs), "all_reduce")
            flushed = [pipeline.stage_slices(st, i, x)
                       for i, x in enumerate(xs)]
            seen[flush] = [list(f) for f in flushed]
            outs = pipeline.finish_emission(st)
        return tuple(outs)

    jax.jit(compat.shard_map(body, mesh=mesh, in_specs=(P(),) * 4,
                             out_specs=(P(),) * 4))(*items)
    assert seen["step"] == [[], [], [], []]
    # ready groups of 4 items on 2 channels: (0,1) and (2,3)
    assert seen["ready"] == [[], [0, 1], [], [2, 3]]


def test_finish_asserts_complete():
    """finish_emission refuses a half-staged ready emission (a bucket
    never produced is a scheduling bug, not a silent drop)."""
    mesh = make_mesh((1,), ("data",))

    def body(x):
        ctx = _ctx(channels=2, aggregate="channel", flush="ready")
        st = pipeline.begin_emission(ctx, 3, "all_reduce")
        pipeline.stage_slices(st, 0, x)
        return pipeline.finish_emission(st)[0]

    with pytest.raises(AssertionError):
        jax.jit(compat.shard_map(body, mesh=mesh, in_specs=(P(),),
                                 out_specs=P()))(jnp.ones((8,)))


def test_gather_flush_groups_keyed_to_schedule():
    """The ZeRO-1 update epilogue mirrors the flush schedule: grouped
    all-gathers only when the sync flushed per channel with contiguous
    (ready) groups; per-bucket everywhere else."""
    from repro.core.backends.hadronio_overlap import make_bucket_plan
    from repro.core.backends.hadronio_overlap_rs import gather_flush_groups
    tree = {"a": jnp.zeros((3000,)), "b": jnp.zeros((200,)),
            "c": jnp.zeros((100,)), "d": jnp.zeros((50,))}
    comm = CommConfig(mode="hadronio_overlap_rs", slice_bytes=1024,
                      channels=2, hierarchical=False)
    plan = make_bucket_plan(tree, comm)
    assert plan.n_buckets >= 3
    singles = tuple((b,) for b in range(plan.n_buckets))
    import dataclasses
    ready = dataclasses.replace(comm, aggregate="channel", flush="ready")
    assert gather_flush_groups(plan, ready) != singles
    assert sorted(i for g in gather_flush_groups(plan, ready)
                  for i in g) == list(range(plan.n_buckets))
    for agg, fl in [("slice", "ready"), ("channel", "step"),
                    ("slice", "step")]:
        c = dataclasses.replace(comm, aggregate=agg, flush=fl)
        assert gather_flush_groups(plan, c) == singles
