"""Observatory telemetry plane (repro/obs + the instrumentation seams).

Four contracts under test (docs/OBSERVABILITY.md):

* REGISTRY — typed metrics with the closed label taxonomy, snapshot
  sections split by determinism class, byte-stable JSON, RingLog
  bounds, the scoped emission-stats seam.
* SPANS — the staged emission + serving plane instrumentation yields a
  WELL-FORMED interval forest (every ``begin_emission`` closed, leader
  flushes nested in their lane's local flush), exports as loadable
  Chrome-trace JSON, and observation changes NOTHING (served tokens
  bit-identical with tracing on vs off).
* DETERMINISM — same seed + same ChaosPlan => byte-identical
  deterministic snapshot, across the hadronio-family modes x
  event_loops {1, 2, 4} and across every chaos scenario.
* GATE — bench_diff tolerance-band units and CLI exit codes.
"""
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro import compat, obs
from repro.configs.base import CommConfig, ModelConfig
from repro.core.backends import SyncContext, pipeline
from repro.launch.mesh import make_mesh
from repro.models import api
from repro.obs import baseline as bl
from repro.serving import chaos
from repro.serving.dispatch import clear_serve_step_cache

HADRONIO_FAMILY = ("hadronio", "hadronio_rs", "hadronio_overlap",
                   "hadronio_overlap_rs")


# ---------------------------------------------------------------------------
# Metrics registry units
# ---------------------------------------------------------------------------


def test_registry_sections_and_label_keys():
    reg = obs.MetricsRegistry()
    reg.counter("served", tenant="a", loop=0).inc()
    reg.counter("served", tenant="a", loop=0).inc(2)     # get-or-create
    reg.gauge("depth", loop=1).set(4)
    reg.gauge("spins", volatile=True, loop=1).set(99)
    reg.histogram("rtt", mode="hadronio").observe(1.5)
    snap = reg.snapshot()
    assert snap["counters"] == {"served{loop=0,tenant=a}": 3}
    assert snap["gauges"] == {"depth{loop=1}": 4}
    assert snap["volatile"] == {"spins{loop=1}": 99}
    h = snap["histograms"]["rtt{mode=hadronio}"]
    assert h["count"] == 1 and h["min"] == h["max"] == 1.5
    # the deterministic half excludes volatile gauges AND histograms
    det = reg.deterministic_snapshot()
    assert set(det) == {"counters", "gauges"}
    assert "spins{loop=1}" not in det["gauges"]


def test_registry_label_order_independent_and_unknown_rejected():
    reg = obs.MetricsRegistry()
    a = reg.counter("x", loop=1, mode="m")
    b = reg.counter("x", mode="m", loop=1)
    assert a is b
    with pytest.raises(ValueError, match="unknown metric label"):
        reg.counter("x", flavor="nope")


def test_registry_type_conflict_rejected():
    reg = obs.MetricsRegistry()
    reg.counter("x")
    with pytest.raises(TypeError, match="already registered"):
        reg.gauge("x")


def test_registry_to_json_byte_stable():
    def build(order):
        reg = obs.MetricsRegistry()
        for name, labels, v in order:
            reg.gauge(name, **labels).set(v)
        return reg.to_json(deterministic=True)

    rows = [("b", {"loop": 1}, 2), ("a", {}, 1), ("b", {"loop": 0}, 3)]
    assert build(rows) == build(list(reversed(rows)))


def test_ringlog_bounds_dropped_slice_eq():
    r = obs.RingLog(3)
    assert not r and len(r) == 0
    r.extend([1, 2, 3])
    assert r.dropped == 0 and r == [1, 2, 3]
    r.append(4)
    r.append(5)
    assert list(r) == [3, 4, 5] and r.dropped == 2
    assert r[0] == 3 and r[-1] == 5 and r[1:] == [4, 5]
    assert r == (3, 4, 5) and r != [3, 4]
    assert tuple(r) == (3, 4, 5)
    with pytest.raises(ValueError):
        obs.RingLog(0)


def test_stats_scope_shields_module_global():
    base = pipeline.EMISSION_STATS.drops
    with pipeline.stats_scope() as st:
        pipeline.current_stats().drops += 3
        with pipeline.stats_scope() as inner:     # nested scopes shadow
            pipeline.current_stats().dups += 1
            assert inner.dups == 1
        assert st.drops == 3 and st.dups == 0
    assert pipeline.EMISSION_STATS.drops == base  # global untouched
    assert pipeline.current_stats() is pipeline.EMISSION_STATS


# ---------------------------------------------------------------------------
# Trace recorder units + export round-trip
# ---------------------------------------------------------------------------


def test_recorder_nesting_and_round_trip():
    with obs.capture() as rec:
        with obs.span("emission", "e", items=2):
            with obs.span("flush", "ch0", channel=0):
                pass
            with obs.span("flush", "ch1", channel=1):
                pass
        obs.complete("heal", "restart", 0.0, 0.25, round=1)
    assert not obs.enabled()
    ok, problems = obs.well_formed(rec)
    assert ok, problems
    assert rec.kinds() == ["emission", "flush", "heal"]
    # export -> json round-trip: loadable, complete events, us stamps
    doc = json.loads(json.dumps(rec.to_chrome()))
    evs = doc["traceEvents"]
    assert len(evs) == 4
    assert all(e["ph"] == "X" and e["dur"] >= 0 for e in evs)
    em = [e for e in evs if e["cat"] == "emission"][0]
    fl = [e for e in evs if e["cat"] == "flush"]
    for f in fl:   # children contained in the parent (us timeline,
        #            0.01us slack for the 3-decimal export rounding)
        assert em["ts"] <= f["ts"] + 0.01
        assert f["ts"] + f["dur"] <= em["ts"] + em["dur"] + 0.01
    heal = [e for e in evs if e["cat"] == "heal"][0]
    assert abs(heal["dur"] - 0.25e6) < 1e3
    assert doc["otherData"]["open_spans"] == 0


def test_recorder_detects_malformed():
    with obs.capture() as rec:
        obs.begin("emission", "left-open")
    assert rec.open_spans() == [("emission", "left-open")]
    ok, problems = obs.well_formed(rec)
    assert not ok and "unclosed" in problems[0]

    with obs.capture() as rec2:
        outer = obs.begin("emission", "outer")
        obs.begin("flush", "inner")
        obs.end(outer)                 # non-LIFO: inner force-closed
    assert rec2.forced_closes == 1
    assert not obs.well_formed(rec2)[0]


def test_recorder_ring_eviction_counts():
    with obs.capture(capacity=4) as rec:
        for i in range(7):
            with obs.span("decode", f"s{i}"):
                pass
    assert len(rec.spans) == 4 and rec.dropped == 3
    assert rec.to_chrome()["otherData"]["dropped"] == 3


def test_disabled_gate_is_inert():
    assert not obs.enabled()
    assert obs.begin("emission") is None
    obs.end(None)                      # must not raise
    with obs.span("decode"):           # shared nullcontext
        pass
    obs.complete("heal", "x", 0.0, 1.0)
    assert obs.recorder() is None


# ---------------------------------------------------------------------------
# The instrumented serving plane
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def tiny():
    cfg = ModelConfig(name="obs-tiny", family="dense", num_layers=1,
                      d_model=16, num_heads=2, num_kv_heads=2, d_ff=32,
                      vocab_size=64, head_dim=8, param_dtype="float32",
                      compute_dtype="float32")
    params = api.init(jax.random.PRNGKey(0), cfg)
    clear_serve_step_cache()
    return cfg, params


@pytest.fixture(scope="module")
def reference(tiny):
    cfg, params = tiny
    # 6 requests > 1 loop x max_batch 2: the run queue is non-empty, so
    # the continuous-batching admission path (and its spans) is live
    reqs = chaos.make_requests(6, vocab_size=cfg.vocab_size)
    base = chaos.run_baseline(cfg, params,
                              chaos.chaos_serve_config("hadronio", 1),
                              reqs)
    assert base.tokens and all(base.tokens.values())
    return base, reqs


def test_traced_serve_well_formed_and_token_identical(tiny, reference):
    """One traced serve covers the whole span taxonomy: emission /
    stage / flush from the staged emission API (trace-time), build from
    the step builder, prefill / decode / admission from the engine,
    drain from the event loop — well-formed, and OBSERVATION ONLY
    (tokens bit-identical to the untraced run)."""
    cfg, params = tiny
    base, reqs = reference
    serve = chaos.chaos_serve_config("hadronio", 1)

    clear_serve_step_cache()
    off = chaos.run_baseline(cfg, params, serve, reqs)
    clear_serve_step_cache()           # fresh trace => emission spans
    with obs.capture() as rec:
        on = chaos.run_baseline(cfg, params, serve, reqs)
    assert on.tokens == off.tokens == base.tokens

    kinds = set(rec.kinds())
    assert {"emission", "stage", "flush", "build", "prefill", "decode",
            "admission", "drain"} <= kinds, kinds
    ok, problems = obs.well_formed(rec)
    assert ok, problems
    assert rec.forced_closes == 0 and rec.open_spans() == []
    # every flush nests inside an emission (the begin/finish bracket)
    for f in rec.spans_of("flush"):
        assert obs.containing(rec, f, "emission") is not None, f


@pytest.mark.parametrize("mode,el", [("hadronio", 1), ("hadronio", 2),
                                     ("hadronio_overlap", 2)])
def test_tracing_preserves_tokens_per_mode(tiny, reference, mode, el):
    cfg, params = tiny
    base, reqs = reference
    serve = chaos.chaos_serve_config(mode, el)
    clear_serve_step_cache()
    with obs.capture() as rec:
        res = chaos.run_baseline(cfg, params, serve, reqs)
    assert res.tokens == base.tokens, (mode, el)
    assert rec.spans_of("emission") and obs.well_formed(rec)[0]


def test_leader_flush_nests_inside_local_flush():
    """Two-level leader emission: the leader lane's cross-pod collective
    fires from INSIDE its triggering local lane's flush under
    flush="ready" — the span tree must show that containment."""
    mesh = make_mesh((1, 1), ("pod", "data"))
    comm = CommConfig(mode="hadronio", channels=4, aggregate="channel",
                      flush="ready", hierarchical=True, leader_channels=1,
                      slice_bytes=64)
    ctx = SyncContext.resolve(comm, ("data",), "pod")
    assert pipeline.leader_emission(ctx, 2)

    def body(x):
        return pipeline.emit_flat(x.reshape(-1), ctx, "all_reduce")

    f = jax.jit(compat.shard_map(body, mesh=mesh,
                                 in_specs=P(("pod", "data")),
                                 out_specs=P(), check_vma=False))
    x = jnp.arange(96, dtype=jnp.float32).reshape(1, 96)
    with obs.capture() as rec:
        np.testing.assert_array_equal(np.asarray(f(x)), np.asarray(x[0]))
    leads = rec.spans_of("leader_flush")
    assert leads, "hierarchical emission must record leader flushes"
    for l in leads:
        host = obs.containing(rec, l, "flush")
        assert host is not None, (l.name, "no containing local flush")
    ok, problems = obs.well_formed(rec)
    assert ok, problems


def test_supervised_heal_spans_complete_taxonomy(tiny, reference):
    """The acceptance trace: a supervised dropped_flush run records >= 4
    span kinds including emission, flush, admission and heal — and the
    healing spans carry the supervisor's detect->heal window."""
    cfg, params = tiny
    base, reqs = reference
    serve = chaos.chaos_serve_config("hadronio", 1)
    with obs.capture() as rec:
        res = chaos.run_supervised("dropped_flush", cfg, params, serve,
                                   reqs, seed=11, baseline=base)
    assert res.report.recovered and res.tokens == base.tokens
    kinds = set(rec.kinds())
    assert {"emission", "flush", "admission", "heal"} <= kinds, kinds
    assert len(kinds) >= 4
    heals = rec.spans_of("heal")
    assert {s.name for s in heals} >= {"quarantine", "restart"}
    assert all(s.dur >= 0 for s in heals)
    ok, problems = obs.well_formed(rec)
    assert ok, problems


# ---------------------------------------------------------------------------
# Telemetry determinism: same seed + same ChaosPlan => byte-identical
# deterministic snapshot
# ---------------------------------------------------------------------------


def _scenario_snapshot(cfg, params, serve, reqs, base, scenario, seed):
    """One seeded chaos run -> the deterministic half of its telemetry,
    as bytes. Emission counters are read through a PRIVATE stats scope
    (the satellite seam: no cross-test module-global races); the
    serve-step cache is cleared so both runs of a pair trace
    identically."""
    clear_serve_step_cache()
    with pipeline.stats_scope() as st:
        res = chaos.run_scenario(scenario, cfg, params, serve, reqs,
                                 seed=seed, baseline=base)
    assert res.report.recovered, (scenario, serve.comm.mode)
    reg = obs.MetricsRegistry()
    obs.publish_emission_stats(reg, st, mode=serve.comm.mode,
                               scenario=scenario)
    obs.publish_chaos(reg, res, mode=serve.comm.mode, scenario=scenario)
    return reg.to_json(deterministic=True)


@pytest.mark.parametrize("mode", HADRONIO_FAMILY)
def test_snapshot_determinism_matrix(tiny, reference, mode):
    """The acceptance matrix: hadronio-family modes x event_loops
    {1, 2, 4}, dropped_flush (the scenario that exercises the emission
    counters) — two same-seed runs per cell, byte-identical snapshots."""
    cfg, params = tiny
    base, reqs = reference
    for el in (1, 2, 4):
        serve = chaos.chaos_serve_config(mode, el)
        a = _scenario_snapshot(cfg, params, serve, reqs,
                               base, "dropped_flush", seed=5)
        b = _scenario_snapshot(cfg, params, serve, reqs,
                               base, "dropped_flush", seed=5)
        assert a == b, (mode, el)
        snap = json.loads(a)
        assert any(v > 0 for v in snap["gauges"].values()), (mode, el)


@pytest.mark.parametrize("scenario", chaos.SCENARIOS)
def test_snapshot_determinism_every_scenario(tiny, reference, scenario):
    cfg, params = tiny
    base, reqs = reference
    serve = chaos.chaos_serve_config("hadronio", 1)
    a = _scenario_snapshot(cfg, params, serve, reqs, base, scenario, 9)
    b = _scenario_snapshot(cfg, params, serve, reqs, base, scenario, 9)
    assert a == b, scenario


# ---------------------------------------------------------------------------
# Adapters: live group / supervisor -> registry
# ---------------------------------------------------------------------------


def test_collect_publishes_group_and_supervisor(tiny, reference):
    cfg, params = tiny
    base, reqs = reference
    serve = chaos.chaos_serve_config("hadronio", 2)
    from repro.serving.supervisor import Supervisor
    sup = Supervisor(cfg, params, serve, seed=3)
    sup.submit(list(reqs))
    sup.run(threads=False)
    reg = obs.collect(supervisor=sup, mode="hadronio")
    snap = reg.snapshot()
    g = snap["gauges"]
    assert g["group.loops{mode=hadronio}"] == 2
    assert g["supervisor.rounds{mode=hadronio}"] >= 1
    assert g["loop.heartbeats{loop=0,mode=hadronio}"] >= 1
    assert "poll.waits{loop=0,mode=hadronio}" in g
    # wall-clock-coupled poll counters live in the volatile section only
    assert "poll.spins{loop=0,mode=hadronio}" in snap["volatile"]
    assert "poll.spins{loop=0,mode=hadronio}" not in g
    det = json.loads(reg.to_json(deterministic=True))
    assert "volatile" not in det


def test_group_poll_stats_survive_restart(tiny, reference):
    """The restart-fold satellite, observed end to end: group poll stats
    are monotone across a heal (lifetime merge, not a silent reset)."""
    cfg, params = tiny
    base, reqs = reference
    from repro.serving.engine import make_engine_group
    grp = make_engine_group(cfg, params,
                            chaos.chaos_serve_config("hadronio", 2))
    grp.submit(list(reqs))
    grp.run(threads=False)
    before = grp.poll_stats()
    assert before.waits > 0
    grp.loops[0].restart()             # the heal: fresh poller
    after = grp.poll_stats()
    assert after.waits == before.waits, "restart must not reset stats"
    assert grp.loops[0].poller.stats.waits == 0   # poller IS fresh


def test_dispatch_log_ring_is_bounded():
    from repro.serving.event_loop import EventLoop, EventLoopGroup
    loops = [EventLoop(0, channels=(0,), runner=lambda l, items: []),
             EventLoop(1, channels=(1,), runner=lambda l, items: [])]
    grp = EventLoopGroup(loops, tenants=(("a", 1, (0,)), ("b", 1, (1,))),
                         dispatch_log_capacity=4)

    class _Item:
        def __init__(self, tenant):
            self.tenant = tenant

    grp.submit([_Item("a"), _Item("b")] * 5)
    assert len(grp.dispatch_log) == 4
    assert grp.dispatch_log.dropped == 6
    reg = obs.MetricsRegistry()
    obs.publish_group(reg, grp)
    g = reg.snapshot()["gauges"]
    assert g["group.dispatch_log_dropped"] == 6
    assert g["group.dispatch_log_len"] == 4


def test_chaos_evidence_rings_bounded(tiny, reference):
    cfg, params = tiny
    plan = chaos.make_plan("dropped_flush", 3)
    inj = chaos._Injector(plan, cfg.vocab_size, evidence_capacity=2)
    for i in range(5):
        inj.fired.append((i, 0, "drop"))
    assert len(inj.fired) == 2 and inj.fired.dropped == 3


# ---------------------------------------------------------------------------
# bench_diff: tolerance-band units + CLI
# ---------------------------------------------------------------------------


def _row(metric="rtt_p50", value=10.0, unit="us", kind="measured",
         **over):
    r = {"benchmark": "b", "figure": "f", "mode": "m", "msg_bytes": 1024,
         "channels": 2, "metric": metric, "value": value, "unit": unit,
         "kind": kind, "seed": 0}
    r.update(over)
    return r


def test_tolerance_directions():
    lower = bl.Tolerance(rel=0.1, direction="lower_is_better")
    assert lower.judge(10.0, 10.9) == "ok"       # inside the band
    assert lower.judge(10.0, 11.2) == "regression"
    assert lower.judge(10.0, 8.0) == "improved"
    higher = bl.Tolerance(rel=0.1, direction="higher_is_better")
    assert higher.judge(10.0, 9.5) == "ok"
    assert higher.judge(10.0, 8.0) == "regression"
    assert higher.judge(10.0, 12.0) == "improved"
    exact = bl.Tolerance(abs=1e-9, direction="exact")
    assert exact.judge(3.0, 3.0) == "ok"
    assert exact.judge(3.0, 3.0000001) == "regression"
    assert bl.Tolerance(direction="ignore").judge(1.0, 1e9) == "ok"


def test_default_policy_by_unit_and_kind():
    assert bl.default_tolerance(_row()).direction == "lower_is_better"
    assert bl.default_tolerance(_row()).rel == 1.0
    d = bl.default_tolerance(_row(kind="derived"))
    assert d.rel == 0.05 and d.direction == "lower_is_better"
    assert bl.default_tolerance(
        _row(unit="ops", kind="derived")).direction == "exact"
    assert bl.default_tolerance(
        _row(unit="count", kind="derived")).direction == "ignore"
    assert bl.default_tolerance(
        _row(unit="GB/s")).direction == "ignore"   # measured non-time


def test_diff_statuses_and_seed_excluded_from_identity():
    base = [_row(), _row(metric="ops", unit="ops", kind="derived",
                         value=7.0), _row(metric="gone")]
    cand = [_row(value=25.0, seed=99),            # 2.5x: regression
            _row(metric="ops", unit="ops", kind="derived", value=7.0),
            _row(metric="new")]
    rep = bl.diff(base, cand)
    assert {d.status for d in rep.deltas} == \
        {"regression", "ok", "missing", "added"}
    assert not rep.ok
    [reg] = rep.regressions
    assert reg.key[5] == "rtt_p50" and reg.change == pytest.approx(1.5)


def test_diff_overrides_and_ignore():
    base, cand = [_row()], [_row(value=25.0)]
    rep = bl.diff(base, cand,
                  overrides=[("rtt_*", bl.Tolerance(rel=2.0))])
    assert rep.ok                      # widened band swallows the 2.5x
    rep2 = bl.diff(base, cand, ignore=["b:rtt_*"])
    assert rep2.ok and rep2.of("ignored")
    rep3 = bl.diff(base, cand, tol_measured=0.1)
    assert not rep3.ok


def test_derived_exact_units_trip_on_any_drift():
    base = [_row(metric="emitted_collective_ops", unit="ops",
                 kind="derived", value=8.0)]
    cand = [_row(metric="emitted_collective_ops", unit="ops",
                 kind="derived", value=9.0)]
    assert not bl.diff(base, cand).ok
    # count rows (volatile poll counters) never gate
    base2 = [_row(metric="poll_spins:el2", unit="count", kind="derived",
                  value=100.0)]
    cand2 = [_row(metric="poll_spins:el2", unit="count", kind="derived",
                  value=900000.0)]
    assert bl.diff(base2, cand2).ok


def test_bench_diff_cli_exit_codes(tmp_path):
    from benchmarks import bench_diff
    base_p = tmp_path / "base.json"
    good_p = tmp_path / "good.json"
    bad_p = tmp_path / "bad.json"
    rows = [_row(), _row(metric="ops", unit="ops", kind="derived",
                         value=4.0)]
    base_p.write_text(json.dumps(rows))
    good_p.write_text(json.dumps(rows))
    bad = [dict(rows[0], value=rows[0]["value"] * 10), rows[1]]
    bad_p.write_text(json.dumps(bad))
    assert bench_diff.main([str(base_p), str(good_p)]) == 0
    assert bench_diff.main([str(base_p), str(bad_p)]) == 1
    assert bench_diff.main([str(base_p), str(bad_p),
                            "--ignore", "rtt_*"]) == 0
    missing_p = tmp_path / "missing.json"
    missing_p.write_text(json.dumps(rows[:1]))
    assert bench_diff.main([str(base_p), str(missing_p)]) == 0
    assert bench_diff.main([str(base_p), str(missing_p),
                            "--strict-missing"]) == 1


def test_metrics_rows_flatten_deterministic_half():
    from benchmarks.common import metrics_rows
    reg = obs.MetricsRegistry()
    reg.counter("served", tenant="a").inc(5)
    reg.gauge("depth").set(2)
    reg.gauge("spins", volatile=True).set(123)
    rows = metrics_rows("serving_rtt", reg.snapshot())
    metrics = {r.metric: r.value for r in rows}
    assert metrics == {"obs:served{tenant=a}": 5.0, "obs:depth": 2.0}
    assert all(r.unit == "count" and r.kind == "derived" for r in rows)
