"""Per-kernel shape/dtype sweeps: Pallas (interpret mode on CPU) vs the
pure-jnp oracles in kernels/ref.py (brief deliverable (c))."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.kernels import ops, ref


def rel_close(a, b, atol, rtol):
    np.testing.assert_allclose(np.asarray(a, np.float32),
                               np.asarray(b, np.float32),
                               atol=atol, rtol=rtol)


# -- ring pack ---------------------------------------------------------------


@pytest.mark.parametrize("n,s", [(1, 512), (3, 1024), (5, 8192)])
@pytest.mark.parametrize("wire", ["bfloat16", "float32"])
def test_pack_slices(n, s, wire, np_rng):
    flat = jnp.asarray(np_rng.normal(size=(n * s,)), jnp.float32)
    ef = jnp.asarray(np_rng.normal(size=(n, s)) * 0.01, jnp.float32)
    w1, e1 = ops.pack_slices(flat, ef, n_slices=n, slice_elems=s,
                             wire_dtype=wire)
    w2, e2 = ref.pack_slices(flat, ef, n_slices=n, slice_elems=s,
                             wire_dtype=wire)
    rel_close(w1, w2, 0, 0)
    rel_close(e1, e2, 0, 0)
    rel_close(ops.unpack_slices(w1), ref.unpack_slices(w2), 0, 0)


@pytest.mark.parametrize("n,s", [(3, 4608), (5, 1536), (7, 2560),
                                 (1, 512 * 11)])
@pytest.mark.parametrize("wire", ["bfloat16", "float32"])
def test_pack_slices_odd_alignment(n, s, wire, np_rng):
    """Odd slice counts and 512-aligned-but-not-LANE_BLOCK-divisible
    slice lengths (the gcd tiling path): pallas (interpret on CPU) must
    match the jnp oracle bit-for-bit."""
    assert s % (8 * 128 * 4) != 0        # really exercises the gcd path
    flat = jnp.asarray(np_rng.normal(size=(n * s,)), jnp.float32)
    ef = jnp.asarray(np_rng.normal(size=(n, s)) * 0.01, jnp.float32)
    w1, e1 = ops.pack_slices(flat, ef, n_slices=n, slice_elems=s,
                             wire_dtype=wire)
    w2, e2 = ref.pack_slices(flat, ef, n_slices=n, slice_elems=s,
                             wire_dtype=wire)
    rel_close(w1, w2, 0, 0)
    rel_close(e1, e2, 0, 0)
    rel_close(ops.unpack_slices(w1), ref.unpack_slices(w2), 0, 0)


def test_pack_slices_no_ef(np_rng):
    flat = jnp.asarray(np_rng.normal(size=(2 * 512,)), jnp.float32)
    w1, e1 = ops.pack_slices(flat, None, n_slices=2, slice_elems=512,
                             with_ef=False)
    w2, _ = ref.pack_slices(flat, None, n_slices=2, slice_elems=512,
                            with_ef=False)
    assert e1 is None
    rel_close(w1, w2, 0, 0)


def test_pack_ef_telescopes(np_rng):
    """Error feedback property: sum of wire values + final residual equals
    the sum of inputs exactly (per element, over steps)."""
    n, s = 2, 512
    ef = None
    total_wire = np.zeros((n, s), np.float32)
    total_in = np.zeros((n, s), np.float32)
    for step in range(4):
        flat = jnp.asarray(np_rng.normal(size=(n * s,)), jnp.float32)
        total_in += np.asarray(flat).reshape(n, s)
        wire, ef = ops.pack_slices(flat, ef, n_slices=n, slice_elems=s)
        total_wire += np.asarray(wire, np.float32)
    np.testing.assert_allclose(total_wire + np.asarray(ef), total_in,
                               atol=1e-5)


# -- flash attention ---------------------------------------------------------


@pytest.mark.parametrize("b,s,h,dh", [(2, 128, 2, 64), (1, 257, 3, 32),
                                      (1, 64, 1, 128), (2, 96, 4, 16)])
@pytest.mark.parametrize("causal,window", [(True, 0), (True, 48),
                                           (False, 0)])
def test_flash_attention(b, s, h, dh, causal, window, rng):
    q = jax.random.normal(jax.random.fold_in(rng, 1), (b, s, h, dh))
    k = jax.random.normal(jax.random.fold_in(rng, 2), (b, s, h, dh))
    v = jax.random.normal(jax.random.fold_in(rng, 3), (b, s, h, dh))
    o1 = ops.flash_attention(q, k, v, causal=causal, window=window,
                             bq=64, bk=64)
    o2 = ref.flash_attention(q, k, v, causal=causal, window=window)
    rel_close(o1, o2, 2e-4, 2e-3)


def test_flash_attention_bf16(rng):
    b, s, h, dh = 1, 128, 2, 64
    mk = lambda i: jax.random.normal(jax.random.fold_in(rng, i),
                                     (b, s, h, dh)).astype(jnp.bfloat16)
    q, k, v = mk(1), mk(2), mk(3)
    o1 = ops.flash_attention(q, k, v, bq=64, bk=64)
    o2 = ref.flash_attention(q, k, v)
    assert o1.dtype == jnp.bfloat16
    rel_close(o1, o2, 3e-2, 5e-2)


def test_flash_matches_model_attention(rng):
    """The kernel agrees with the model's chunked online-softmax path."""
    from repro.models.attention import attend_chunked
    b, s, h, dh = 1, 160, 2, 32
    q = jax.random.normal(jax.random.fold_in(rng, 1), (b, s, h, dh))
    k = jax.random.normal(jax.random.fold_in(rng, 2), (b, s, h, dh))
    v = jax.random.normal(jax.random.fold_in(rng, 3), (b, s, h, dh))
    o1 = ops.flash_attention(q, k, v, bq=64, bk=64)
    o2 = attend_chunked(q, k, v, causal=True, q_chunk=64, kv_chunk=64)
    rel_close(o1, o2, 2e-4, 2e-3)


# -- WKV6 --------------------------------------------------------------------


@pytest.mark.parametrize("b,t,h,hs", [(2, 64, 2, 16), (1, 37, 3, 32),
                                      (1, 128, 1, 64)])
@pytest.mark.parametrize("chunk", [16, 32])
def test_wkv6(b, t, h, hs, chunk, rng):
    f = lambda i, sh: jax.random.normal(jax.random.fold_in(rng, i), sh)
    r, k, v = f(1, (b, t, h, hs)), f(2, (b, t, h, hs)), f(3, (b, t, h, hs))
    w = jax.nn.sigmoid(f(4, (b, t, h, hs))) * 0.85 + 0.1
    u = f(5, (h, hs)) * 0.1
    s0 = f(6, (b, h, hs, hs)) * 0.1
    y1, sf1 = ops.wkv6(r, k, v, w, u, s0, chunk=chunk)
    y2, sf2 = ref.wkv6(r, k, v, w, u, s0)
    rel_close(y1, y2, 2e-3, 2e-3)
    rel_close(sf1, sf2, 2e-3, 2e-3)


def test_wkv6_extreme_decay(rng):
    """Numerical safety: near-zero and near-one decays (the log-space
    formulation must not overflow)."""
    b, t, h, hs = 1, 32, 1, 16
    f = lambda i, sh: jax.random.normal(jax.random.fold_in(rng, i), sh)
    r, k, v = f(1, (b, t, h, hs)), f(2, (b, t, h, hs)), f(3, (b, t, h, hs))
    w = jnp.concatenate([jnp.full((b, t // 2, h, hs), 1e-6),
                         jnp.full((b, t - t // 2, h, hs), 1.0 - 1e-6)], 1)
    u = f(5, (h, hs)) * 0.1
    s0 = jnp.zeros((b, h, hs, hs))
    y1, sf1 = ops.wkv6(r, k, v, w, u, s0, chunk=16)
    y2, sf2 = ref.wkv6(r, k, v, w, u, s0)
    assert np.isfinite(np.asarray(y1)).all()
    rel_close(y1, y2, 5e-3, 5e-3)


# -- RG-LRU ------------------------------------------------------------------


@pytest.mark.parametrize("b,t,w", [(2, 64, 128), (1, 100, 65), (3, 16, 512)])
def test_rglru(b, t, w, rng):
    a = jax.nn.sigmoid(jax.random.normal(jax.random.fold_in(rng, 1),
                                         (b, t, w))) * 0.95
    bb = jax.random.normal(jax.random.fold_in(rng, 2), (b, t, w))
    h0 = jax.random.normal(jax.random.fold_in(rng, 3), (b, w))
    y1, hf1 = ops.rglru(a, bb, h0, chunk=32, wblock=64)
    y2, hf2 = ref.rglru(a, bb, h0)
    rel_close(y1, y2, 2e-4, 2e-4)
    rel_close(hf1, hf2, 2e-4, 2e-4)


def test_rglru_matches_model(rng):
    """Kernel output matches the model's associative-scan RG-LRU core."""
    from repro.models.hybrid import _rglru
    b, t, lw, nb = 1, 48, 64, 4
    p = {
        "wa": jax.random.normal(jax.random.fold_in(rng, 1),
                                (nb, lw // nb, lw // nb)) * 0.1,
        "ba": jnp.zeros((lw,)),
        "wx": jax.random.normal(jax.random.fold_in(rng, 2),
                                (nb, lw // nb, lw // nb)) * 0.1,
        "bx": jnp.zeros((lw,)),
        "lam": jnp.ones((lw,)),
    }
    y = jax.random.normal(jax.random.fold_in(rng, 3), (b, t, lw))
    h0 = jax.random.normal(jax.random.fold_in(rng, 4), (b, lw)) * 0.1
    hs_model, hlast_model = _rglru(y, p, h0, nb, lw // nb)

    # rebuild (a, gated) exactly as the model does, then run the kernel
    from repro.models.hybrid import RGLRU_C
    yb = y.reshape(b, t, nb, lw // nb)
    r = jax.nn.sigmoid(
        jnp.einsum("btni,nij->btnj", yb, p["wa"]).reshape(b, t, lw)
        + p["ba"])
    i = jax.nn.sigmoid(
        jnp.einsum("btni,nij->btnj", yb, p["wx"]).reshape(b, t, lw)
        + p["bx"])
    a = jnp.exp(-RGLRU_C * jax.nn.softplus(p["lam"]) * r)
    gated = jnp.sqrt(jnp.maximum(1 - a**2, 1e-12)) * (i * y)
    hs_kern, hlast_kern = ops.rglru(a, gated, h0, chunk=16, wblock=64)
    rel_close(hs_kern, hs_model, 2e-4, 2e-4)
    rel_close(hlast_kern, hlast_model, 2e-4, 2e-4)
