"""Property-style tests of error-feedback compression.

The EF contract: truncation error is never dropped, only deferred — over
K steps the accumulated wire output plus the final residual equals the
accumulated input exactly (the telescoping sum), so the compressed
update is unbiased over time. Verified here for

* both codecs (``bf16`` and ``int8_ef``),
* both EF keyings — global ring plan (``hadronio``) and per-bucket
  (``hadronio_overlap`` / ``hadronio_overlap_rs``),
* both pack-stage implementations (jnp and the fused pallas kernel),
* a tree whose biggest leaf exceeds a bucket (the oversized-singleton
  edge case of the greedy bucketing).
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from repro import compat
from repro.configs.base import CommConfig, RunConfig, ShapeConfig
from repro.configs.registry import get_config
from repro.core import aggregation as agg
from repro.core import tac
from repro.core.backends import get_backend
from repro.core.backends import hadronio_overlap as ho
from repro.core.backends import hadronio_overlap_rs as hors
from repro.launch.mesh import make_mesh

K_STEPS = 4
SLICE_BYTES = 4096
BUCKET_MODES = ("hadronio_overlap", "hadronio_overlap_rs")


def _tree(step: int):
    """Per-step random gradients; the 3000-elem leaf carries 12 KB of
    payload > slice_bytes, so bucketing gives it its own bucket."""
    ks = jax.random.split(jax.random.PRNGKey(100 + step), 3)
    return {"a": jax.random.normal(ks[0], (17, 9)),
            "b": jax.random.normal(ks[1], (200,)),
            "big": jax.random.normal(ks[2], (3000,))}


def _comm(mode, compress, pack="jnp"):
    return CommConfig(mode=mode, compress=compress, pack=pack,
                      slice_bytes=SLICE_BYTES, hierarchical=False)


def _bucket_plan(like, comm):
    return ho.make_bucket_plan(like, comm) \
        if comm.mode == "hadronio_overlap" \
        else hors.rs_bucket_plan(like, comm, 1)


def _zero_ef(like, comm):
    """The zero residual in the backend's own EF keying."""
    if comm.mode in BUCKET_MODES:
        plan = _bucket_plan(like, comm)
        return tuple(jnp.zeros((p,), jnp.float32) for p in plan.padded)
    plan = agg.make_plan(like, comm)
    return jnp.zeros((plan.n_slices, plan.slice_elems), jnp.float32)


def _decode_ef(ef, like, comm):
    """Carve a residual (ring- or bucket-keyed) back into tree space."""
    f32 = jax.tree.map(lambda l: jnp.zeros(l.shape, jnp.float32), like)
    if comm.mode in BUCKET_MODES:
        plan = _bucket_plan(like, comm)
        leaves = jax.tree.leaves(f32)
        out = [None] * len(leaves)
        for b in range(plan.n_buckets):
            ho.unpack_bucket(ef[b], plan, b, leaves, out)
        return jax.tree.unflatten(jax.tree.structure(like), out)
    plan = agg.make_plan(like, comm)
    return agg.unpack(agg.from_slices(ef, plan), plan, f32)


CASES = [(m, c, p)
         for m in ("hadronio",) + BUCKET_MODES
         for c, p in (("bf16", "jnp"), ("bf16", "pallas"),
                      ("int8_ef", "jnp"))]


@pytest.mark.parametrize("mode,compress,pack", CASES)
def test_ef_unbiased_over_k_steps(mode, compress, pack):
    """sum_k(wire_k) + final_residual == sum_k(input_k): the accumulated
    wire+EF drift goes to zero, for global-ring AND per-bucket keying."""
    comm = _comm(mode, compress, pack)
    backend = get_backend(mode)
    mesh = make_mesh((1,), ("data",))
    like = _tree(0)

    def body(g, ef):
        r = tac.sync_grads(g, comm, data_axis=("data",), ef=ef)
        return backend.gathered_grads(r, g), r.ef

    ef = _zero_ef(like, comm)
    f = jax.jit(compat.shard_map(body, mesh=mesh, in_specs=(P(), P()),
                                 out_specs=(P(), P())))

    total_in = jax.tree.map(jnp.zeros_like, like)
    total_out = jax.tree.map(jnp.zeros_like, like)
    for k in range(K_STEPS):
        x = _tree(k)
        out, ef = f(x, ef)
        total_in = jax.tree.map(jnp.add, total_in, x)
        total_out = jax.tree.map(jnp.add, total_out, out)

    resid = _decode_ef(ef, like, comm)
    drift = jax.tree.map(lambda o, r, i: jnp.max(jnp.abs(o + r - i)),
                         total_out, resid, total_in)
    assert max(float(d) for d in jax.tree.leaves(drift)) < 1e-4

    # the lossy wire really was lossy (EF had something to carry)
    resid_max = max(float(jnp.max(jnp.abs(r)))
                    for r in jax.tree.leaves(resid))
    assert resid_max > 1e-6


@pytest.mark.parametrize("mode", BUCKET_MODES)
def test_oversized_leaf_gets_own_bucket_and_ef(mode):
    """One leaf bigger than a bucket: the greedy bucketing gives it a
    singleton bucket whose EF leaf covers the whole (padded) payload."""
    comm = _comm(mode, "bf16")
    like = _tree(0)
    plan = _bucket_plan(like, comm)
    sizes = dict(zip(range(len(plan.sizes)), plan.sizes))
    big = max(sizes, key=sizes.get)
    assert plan.sizes[big] * 4 > comm.slice_bytes
    assert (big,) in plan.buckets       # its own bucket
    b = plan.buckets.index((big,))
    assert plan.padded[b] >= plan.sizes[big]
    ef = _zero_ef(like, comm)
    assert len(ef) == plan.n_buckets
    assert ef[b].shape == (plan.padded[b],)


@pytest.mark.parametrize("mode", BUCKET_MODES)
def test_state_ef_keyed_by_bucket_id(mode):
    """state_specs' EF pytree is keyed by bucket id — one (ring, padded)
    leaf per bucket, independent of any global ring plan."""
    cfg = get_config("qwen2-0.5b-reduced")
    run = RunConfig(model=cfg, shape=ShapeConfig("t", "train", 16, 4),
                    comm=_comm(mode, "bf16"))
    from repro.models import api
    eff = 4
    plan = ho.make_bucket_plan(api.abstract(cfg), run.comm) \
        if mode == "hadronio_overlap" \
        else hors.rs_bucket_plan(api.abstract(cfg), run.comm, eff)
    specs = get_backend(mode).state_specs(run, eff)
    assert isinstance(specs.ef, tuple)
    assert len(specs.ef) == plan.n_buckets
    for b, e in enumerate(specs.ef):
        assert tuple(e.shape) == (eff, plan.padded[b])
        assert e.dtype == jnp.float32
