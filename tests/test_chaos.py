"""Deterministic chaos harness (serving/chaos.py + serving/slo.py):
seeded plans replay exactly, every fault scenario recovers bit-identical
to the fault-free run, and the SLO layer's invariants hold.

The heavyweight acceptance check lives in test_recovery_matrix: all five
scenarios across the hadronio-family modes x event_loops in {1, 2, 4},
all recovering against ONE shared fault-free token reference (the
conformance contract makes served tokens invariant to mode, affinity and
loop count — which is exactly why one reference suffices)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro import compat
from repro.configs.base import CommConfig, ModelConfig
from repro.core.backends import SyncContext, pipeline
from repro.launch.mesh import make_mesh
from repro.models import api
from repro.serving import chaos, slo
from repro.serving.chaos import (SCENARIOS, STORM_UID_BASE, ChaosPlan,
                                 make_plan)
from repro.serving.dispatch import clear_serve_step_cache

HADRONIO_FAMILY = ("hadronio", "hadronio_rs", "hadronio_overlap",
                   "hadronio_overlap_rs")


# ---------------------------------------------------------------------------
# Seeded plans: same seed <=> same injection trace (no jax involved)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("scenario", SCENARIOS)
def test_plan_replay_identical(scenario):
    a = make_plan(scenario, 7, n_channels=4, n_loops=2)
    b = make_plan(scenario, 7, n_channels=4, n_loops=2)
    assert a.trace() == b.trace() and a.trace()
    assert a == b                       # frozen dataclasses compare whole


@pytest.mark.parametrize("scenario", SCENARIOS)
def test_plan_seed_varies_trace(scenario):
    traces = {make_plan(scenario, s, n_channels=4, n_loops=2).trace()
              for s in range(8)}
    assert len(traces) > 1, "seed must actually drive the trace"


@pytest.mark.parametrize("scenario", SCENARIOS)
def test_plan_shapes(scenario):
    plan = make_plan(scenario, 3, n_channels=4, n_loops=2, n_requests=4,
                     horizon=16)
    kinds = {e.kind for e in plan.events}
    steps = [e.step for e in plan.events]
    assert steps == sorted(steps)
    if scenario == "slow_channel":
        assert kinds == {"delay"} and steps[0] == 0
        assert len({e.target for e in plan.events}) == 1   # one channel
        assert all(0 < e.magnitude < 0.1 for e in plan.events)
    elif scenario == "stalled_loop":
        assert kinds == {"stall"} and steps[0] == 0
        assert all(0 <= e.target < 2 for e in plan.events)
    elif scenario == "dropped_flush":
        assert kinds <= {"drop", "dup"} and steps[0] == 0
    elif scenario == "admission_storm":
        assert kinds == {"burst"} and steps[0] == 1
        assert all(1 <= e.target <= 2 for e in plan.events)
    elif scenario == "reshard_mid_request":
        assert kinds == {"resize"} and len(plan.events) == 1
        assert plan.events[0].target in (1, 2, 4)
        assert plan.events[0].target != 2                  # != current
        assert 1 <= plan.events[0].step < 4
    else:   # mem_pressure
        assert kinds == {"pressure"} and steps[0] == 0
        assert all(0.5e-3 <= e.magnitude <= 2e-3 for e in plan.events)
        assert all(e.target == -1 for e in plan.events)
    assert all(e.step < 16 for e in plan.events)


# ---------------------------------------------------------------------------
# SLO layer units
# ---------------------------------------------------------------------------


def test_rtt_percentiles_monotone_and_degenerate():
    ps = slo.rtt_percentiles([3e-6, 1e-6, 2e-6, 50e-6])
    assert ps["p50"] <= ps["p99"] <= ps["p99.9"]
    one = slo.rtt_percentiles([7.0])
    assert one == {"p50": 7.0, "p99": 7.0, "p99.9": 7.0}
    with pytest.raises(ValueError, match="empty"):
        slo.rtt_percentiles([])


def test_token_recovery_ignores_storm_extras():
    ref = {0: (1, 2), 1: (3,)}
    ok, bad = slo.token_recovery(ref, {0: (1, 2), 1: (3,),
                                       STORM_UID_BASE: (9,)})
    assert ok and bad == ()
    ok, bad = slo.token_recovery(ref, {0: (1, 2)})          # 1 missing
    assert not ok and bad == (1,)
    ok, bad = slo.token_recovery(ref, {0: (1, 9), 1: (3,)})  # 0 differs
    assert not ok and bad == (0,)


def test_p999_inflation_and_assert_slo():
    rep = slo.make_report(scenario="s", seed=1, mode="hadronio",
                          event_loops=1, reference={0: (1,)},
                          served={0: (1,)}, fault_rtts=[2e-3],
                          baseline_rtts=[1e-3])
    assert rep.recovered and rep.p999_inflation == pytest.approx(2.0)
    slo.assert_slo(rep, max_p999_inflation=2.5)
    with pytest.raises(AssertionError, match="inflated"):
        slo.assert_slo(rep, max_p999_inflation=1.5)
    # token-only reference: no baseline, inflation unavailable, bound moot
    tokonly = slo.make_report(scenario="s", seed=1, mode="hadronio",
                              event_loops=1, reference={0: (1,)},
                              served={0: (1,)}, fault_rtts=[2e-3])
    assert tokonly.p999_inflation is None
    slo.assert_slo(tokonly, max_p999_inflation=0.1)     # does not bind
    # a zero baseline has nothing to inflate
    zero = slo.make_report(scenario="s", seed=1, mode="hadronio",
                           event_loops=1, reference={}, served={},
                           fault_rtts=[1e-3], baseline_rtts=[0.0])
    assert zero.p999_inflation == 1.0
    broken = slo.make_report(scenario="s", seed=2, mode="hadronio",
                             event_loops=1, reference={0: (1,)},
                             served={0: (2,)}, fault_rtts=[1e-3])
    with pytest.raises(AssertionError, match="diverged.*uids \\(0,\\)"):
        slo.assert_slo(broken)


# ---------------------------------------------------------------------------
# The flush-fault seam at the pipeline level: drops re-flush at the
# barrier, duplicates are idempotent — values NEVER change
# ---------------------------------------------------------------------------


def _emit(fault):
    mesh = make_mesh((1,), ("data",))
    rng = np.random.default_rng(0)
    items = [jnp.asarray(rng.normal(size=(64,)), jnp.float32)
             for _ in range(4)]

    def body(*xs):
        comm = CommConfig(mode="hadronio", channels=2, slice_bytes=128,
                          aggregate="channel", flush="ready",
                          hierarchical=False)
        ctx = SyncContext.resolve(comm, ("data",), None)
        st = pipeline.begin_emission(ctx, len(xs), "all_reduce")
        for i, x in enumerate(xs):
            pipeline.stage_slices(st, i, x)
        return tuple(pipeline.finish_emission(st))

    if fault is not None:
        pipeline.set_flush_fault(fault)
    try:
        assert pipeline.flush_fault_active() == (fault is not None)
        f = jax.jit(compat.shard_map(body, mesh=mesh,
                                     in_specs=(P(),) * 4,
                                     out_specs=(P(),) * 4))
        return items, [np.asarray(o) for o in f(*items)]
    finally:
        pipeline.clear_flush_fault()
        assert not pipeline.flush_fault_active()


@pytest.mark.parametrize("name,fault", [
    ("none", None),
    ("drop_all", lambda c: "drop"),
    ("dup_all", lambda c: "dup"),
    ("drop_even", lambda c: "drop" if c % 2 == 0 else None),
])
def test_flush_fault_bit_identical(name, fault):
    """Any drop/dup pattern on the ready-flush schedule yields values
    bit-identical to the fault-free emission (one-device all_reduce is
    identity, so the inputs ARE the reference)."""
    items, out = _emit(fault)
    for x, o in zip(items, out):
        np.testing.assert_array_equal(np.asarray(x), o)


def test_flush_fault_consults_ready_channels():
    consulted = []

    def fault(c):
        consulted.append(c)
        return "drop"

    _emit(fault)
    assert consulted, "flush_ready never consulted the installed fault"
    assert set(consulted) <= {0, 1}


# ---------------------------------------------------------------------------
# End-to-end scenarios over a tiny dense model
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def tiny():
    cfg = ModelConfig(name="chaos-tiny", family="dense", num_layers=1,
                      d_model=16, num_heads=2, num_kv_heads=2, d_ff=32,
                      vocab_size=64, head_dim=8, param_dtype="float32",
                      compute_dtype="float32")
    params = api.init(jax.random.PRNGKey(0), cfg)
    clear_serve_step_cache()
    return cfg, params


@pytest.fixture(scope="module")
def reference(tiny):
    """ONE fault-free run (hadronio, 1 loop) shared by the whole matrix:
    the conformance contract makes greedy tokens invariant to mode,
    affinity and loop count, so this token set is THE reference for
    every (mode, event_loops, scenario) cell. Token-only — tier-1 leans
    on the deterministic half of the SLO, not wall-clock."""
    cfg, params = tiny
    reqs = chaos.make_requests(4, vocab_size=cfg.vocab_size)
    base = chaos.run_baseline(cfg, params,
                              chaos.chaos_serve_config("hadronio", 1),
                              reqs)
    assert base.tokens and all(base.tokens.values())
    return chaos.Baseline(tokens=base.tokens), reqs


@pytest.mark.parametrize("scenario", SCENARIOS)
def test_replay_deterministic(tiny, reference, scenario):
    """The acceptance property, per scenario: same seed => same injection
    trace AND same runtime evidence (fired faults, drain trace, served
    tokens) — and the served tokens are bit-identical to the fault-free
    run."""
    cfg, params = tiny
    base, reqs = reference
    serve = chaos.chaos_serve_config("hadronio", 2)
    runs = [chaos.run_scenario(scenario, cfg, params, serve, reqs,
                               seed=11, baseline=base)
            for _ in range(2)]
    a, b = runs
    assert a.plan == b.plan and a.plan.trace() == b.plan.trace()
    assert a.fired == b.fired
    assert a.drains == b.drains
    assert a.tokens == b.tokens == base.tokens
    assert a.report.recovered and b.report.recovered
    assert a.report.n_injected == b.report.n_injected > 0
    slo.assert_slo(a.report)


def test_recovery_matrix(tiny, reference):
    """The acceptance matrix: every scenario recovers bit-identically
    across the hadronio-family modes x event_loops in {1, 2, 4}."""
    cfg, params = tiny
    base, reqs = reference
    for mode in HADRONIO_FAMILY:
        for el in (1, 2, 4):
            serve = chaos.chaos_serve_config(mode, el)
            for scenario in SCENARIOS:
                res = chaos.run_scenario(scenario, cfg, params, serve,
                                         reqs, seed=5, baseline=base)
                assert res.report.recovered, (scenario, mode, el)
                assert res.tokens == base.tokens, (scenario, mode, el)
                slo.assert_slo(res.report)


def test_stalled_loop_counts_stalls(tiny, reference):
    cfg, params = tiny
    base, reqs = reference
    res = chaos.run_scenario("stalled_loop", cfg, params,
                             chaos.chaos_serve_config("hadronio", 2),
                             reqs, seed=11, baseline=base)
    assert res.poll_stats.stalls > 0          # forced over-parks counted
    assert res.poll_stats.stalls == len(
        [f for f in res.fired if f[2] == "stall"])
    assert {f[2] for f in res.fired} == {"stall"}


def test_slow_channel_targets_owner_loop(tiny, reference):
    cfg, params = tiny
    base, reqs = reference
    serve = chaos.chaos_serve_config("hadronio", 2)
    res = chaos.run_scenario("slow_channel", cfg, params, serve, reqs,
                             seed=11, baseline=base)
    assert res.fired and {f[2] for f in res.fired} == {"delay"}
    # every fired delay was charged to the single owner loop
    assert len({f[1] for f in res.fired}) == 1
    assert res.poll_stats.stalls == 0         # delays are not stalls


def test_admission_storm_filters_injected_uids(tiny, reference):
    cfg, params = tiny
    base, reqs = reference
    res = chaos.run_scenario("admission_storm", cfg, params,
                             chaos.chaos_serve_config("hadronio", 2),
                             reqs, seed=11, baseline=base)
    assert {f[2] for f in res.fired} == {"burst"}
    assert res.report.n_injected > 0
    # storm uids never leak into the recovery comparison
    assert all(uid < STORM_UID_BASE for uid in res.tokens)
    assert res.tokens == base.tokens


def test_reshard_migrates_channels(tiny, reference):
    cfg, params = tiny
    base, reqs = reference
    res = chaos.run_scenario("reshard_mid_request", cfg, params,
                             chaos.chaos_serve_config("hadronio", 2),
                             reqs, seed=11, baseline=base)
    e = res.plan.events[0]
    assert e.kind == "resize" and e.target != 2
    assert res.moved_channels, "a loop-count change must migrate channels"
    assert res.fired == ((max(1, min(3, e.step)), e.target, "resize"),)
    assert res.tokens == base.tokens


def test_dropped_flush_traces_fresh_and_recovers(tiny, reference):
    cfg, params = tiny
    base, reqs = reference
    res = chaos.run_scenario("dropped_flush", cfg, params,
                             chaos.chaos_serve_config("hadronio", 2),
                             reqs, seed=11, baseline=base)
    assert {f[2] for f in res.fired} <= {"drop", "dup"} and res.fired
    # the armed window bypasses the serve-step cache, so this run traced
    # fresh programs — the collective-hook trace must be non-empty and
    # confined to the configured channel pool
    assert res.emissions
    assert {c for c, _ in res.emissions} <= set(range(4))
    assert res.tokens == base.tokens


def test_mem_pressure_consults_alloc_seam(tiny, reference):
    """The allocator seam: every planned pressure event that fires is a
    consult of the buffer-pool hook (recorded with its alloc index and
    channel), the run traces fresh programs (cache bypassed while the
    hook is armed), and recovery is bit-identical — allocation pressure
    slows a trace, never changes a value."""
    cfg, params = tiny
    base, reqs = reference
    res = chaos.run_scenario("mem_pressure", cfg, params,
                             chaos.chaos_serve_config("hadronio", 2),
                             reqs, seed=11, baseline=base)
    assert res.fired and {f[2] for f in res.fired} == {"pressure"}
    assert res.emissions                       # fresh traces happened
    assert res.tokens == base.tokens
    # the seam counts every coalesced-buffer build it consulted
    assert pipeline.EMISSION_STATS.allocs > 0
    assert not pipeline.alloc_hook_active()    # cleared after the run


def test_serve_step_cache_reuse_and_bypass(tiny):
    """Fault-free group builds share jitted serve steps (the cache that
    makes the matrix affordable); an armed flush fault bypasses both
    lookup and store so a faulted trace can never leak into fault-free
    callers."""
    from repro.serving import dispatch
    cfg, params = tiny
    serve = chaos.chaos_serve_config("hadronio", 2)
    clear_serve_step_cache()
    from repro.serving.engine import make_engine_group
    make_engine_group(cfg, params, serve)
    n = len(dispatch._STEP_CACHE)
    assert n > 0
    make_engine_group(cfg, params, serve)          # pure cache hits
    assert len(dispatch._STEP_CACHE) == n
    pipeline.set_flush_fault(lambda c: None)
    try:
        make_engine_group(cfg, params, serve)      # bypassed: no growth
    finally:
        pipeline.clear_flush_fault()
    assert len(dispatch._STEP_CACHE) == n
    # the allocation seam is a fault window too
    pipeline.set_alloc_hook(lambda c, nbytes: None)
    try:
        assert pipeline.fault_active()
        make_engine_group(cfg, params, serve)      # bypassed: no growth
    finally:
        pipeline.clear_alloc_hook()
    assert not pipeline.fault_active()
    assert len(dispatch._STEP_CACHE) == n
