"""Per-architecture smoke tests (the brief's (f) deliverable): reduced
config of the same family, one forward/train step on CPU, asserting
output shapes and no NaNs."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs.base import CommConfig, RunConfig, ShapeConfig
from repro.configs.registry import ARCH_IDS, get_config
from repro.models import api
from repro.optim import adamw

B, S = 2, 16


def make_batch(cfg, rng, with_labels=True):
    batch = {"tokens": jax.random.randint(rng, (B, S), 0, cfg.vocab_size)}
    if with_labels:
        batch["labels"] = jax.random.randint(rng, (B, S), 0, cfg.vocab_size)
    if cfg.family == "encdec":
        batch["frames"] = jax.random.normal(
            rng, (B, cfg.num_frames, cfg.d_model), jnp.float32)
    if cfg.family == "vlm":
        batch["patches"] = jax.random.normal(
            rng, (B, cfg.num_patches, cfg.d_model), jnp.float32)
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_train_step(arch, rng):
    cfg = get_config(arch + "-reduced")
    params = api.init(rng, cfg)
    batch = make_batch(cfg, rng)
    run = RunConfig(model=cfg,
                    shape=ShapeConfig("t", "train", S, B), lr=1e-3)

    @jax.jit
    def step(params, opt, batch):
        (l, aux), g = jax.value_and_grad(
            lambda p: api.loss(p, batch, cfg), has_aux=True)(params)
        new_p, new_opt, m = adamw.update(g, opt, params, run)
        return new_p, new_opt, l

    new_p, _, l1 = step(params, adamw.init(params), batch)
    assert np.isfinite(float(l1))
    # params actually changed
    changed = jax.tree.map(lambda a, b: float(jnp.max(jnp.abs(a - b))),
                           params, new_p)
    assert max(jax.tree.leaves(changed)) > 0


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_prefill_decode_shapes(arch, rng):
    cfg = get_config(arch + "-reduced")
    params = api.init(rng, cfg)
    batch = make_batch(cfg, rng, with_labels=False)
    logits, cache = jax.jit(lambda p, b: api.prefill(p, b, cfg))(params,
                                                                 batch)
    assert logits.shape == (B, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits, np.float32)).all()
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    out2, cache2 = jax.jit(lambda p, c, b: api.decode_step(p, c, b, cfg))(
        params, cache, {"token": tok, "pos": jnp.asarray(S, jnp.int32)})
    assert out2.shape == (B, cfg.vocab_size)
    assert np.isfinite(np.asarray(out2, np.float32)).all()


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_input_and_cache_specs(arch):
    """Dry-run shape builders: every assigned cell has well-defined specs
    and the decode cache is bounded for sub-quadratic archs."""
    from repro.configs.base import SHAPES, cell_skip_reason
    cfg = get_config(arch)
    for shape in SHAPES.values():
        if cell_skip_reason(cfg, shape):
            continue
        specs = api.input_specs(cfg, shape)
        assert "tokens" in specs or shape.kind == "decode"
        if shape.kind == "decode":
            cache = api.cache_specs(cfg, shape.global_batch, shape.seq_len)
            leaves = jax.tree.leaves(cache)
            assert leaves, arch
            if shape.name == "long_500k":
                # sub-quadratic claim: decode state must NOT scale with the
                # 524288-token context (window/recurrent state only)
                big = max(int(np.prod(l.shape)) for l in leaves)
                assert big < 1e9, (arch, shape.name, big)
