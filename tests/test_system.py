"""End-to-end system behaviour via multi-device subprocesses (the brief
forbids forcing the host device count globally, so these spawn fresh
interpreters with XLA_FLAGS set — see tests/distributed/*.py):

* check_tac_modes — all TAC sync modes numerically equal plain psum,
  hierarchical + compressed variants included (8 virtual devices).
* check_steps — GSPMD and TAC train steps produce identical loss
  trajectories (the paper's transparency claim, end to end).
* check_train_ft — fault injection -> supervised restart -> bitwise
  resume; elastic restore onto a smaller mesh.
* check_serving — the multi-shard serving path (4 devices): prefill
  gathering-write carve/re-merge, TP logit reduction, channel affinity,
  engine-group continuous batching — bit-identical across modes,
  affinities and event-loop counts.
* check_topology — the two-level serving fabric (8 devices, 2 pods):
  pod-aware psum parity, leader-channel emission conformance (flat vs
  hierarchical), topology-aware affinity, cross-pod collective counts.
* check_chaos — the chaos harness at 4 shards: seeded fault injection
  replays deterministically and every scenario recovers bit-identically
  over the real multi-shard emission structure.
"""
import os
import subprocess
import sys

import pytest

HERE = os.path.dirname(__file__)
SRC = os.path.join(HERE, "..", "src")


def run_script(name, timeout=560):
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    p = subprocess.run(
        [sys.executable, os.path.join(HERE, "distributed", name)],
        capture_output=True, text=True, timeout=timeout, env=env)
    assert p.returncode == 0, \
        f"{name} failed:\nstdout:\n{p.stdout[-4000:]}\nstderr:\n{p.stderr[-4000:]}"
    return p.stdout


def test_tac_modes_multidevice():
    out = run_script("check_tac_modes.py")
    assert "done" in out


def test_step_transparency_multidevice():
    out = run_script("check_steps.py")
    assert "ALL OK" in out


def test_fault_tolerance_and_elastic():
    out = run_script("check_train_ft.py")
    assert "ALL OK" in out


def test_serving_multidevice():
    out = run_script("check_serving.py")
    assert "ALL OK" in out


def test_topology_multidevice():
    out = run_script("check_topology.py")
    assert "ALL OK" in out


def test_chaos_multidevice():
    out = run_script("check_chaos.py")
    assert "ALL OK" in out
    from repro.serving.chaos import SCENARIOS
    assert out.count("replay deterministic @4 shards") == len(SCENARIOS)
    assert out.count("recovered @4 shards") == 4
