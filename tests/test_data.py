"""Data pipeline: determinism, exact resume, host sharding, binary shards."""
import os
import tempfile

import numpy as np
import pytest

from repro.data import (BinarySource, DataConfig, SyntheticSource, batch_at,
                        make_batches)


def test_synthetic_deterministic():
    s = SyntheticSource(256, seed=1)
    a = batch_at(s, DataConfig(16, 4), 3)
    b = batch_at(s, DataConfig(16, 4), 3)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    assert (batch_at(s, DataConfig(16, 4), 4)["tokens"]
            != a["tokens"]).any()
    assert a["tokens"].max() < 256 and a["tokens"].min() >= 0


def test_labels_are_shifted():
    s = SyntheticSource(100, seed=0)
    b = batch_at(s, DataConfig(12, 2), 0)
    np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])


def test_host_sharding_partitions_global_batch():
    s = SyntheticSource(64, seed=0)
    full = batch_at(s, DataConfig(8, 6), 2)
    parts = [batch_at(s, DataConfig(8, 6, host_index=i, num_hosts=3), 2)
             for i in range(3)]
    got = np.concatenate([p["tokens"] for p in parts])
    np.testing.assert_array_equal(got, full["tokens"])


def test_resume_equals_continuous():
    s = SyntheticSource(64, seed=0)
    dc = DataConfig(8, 2)
    it = make_batches(s, dc, start_step=0)
    run = [next(it) for _ in range(5)]
    resumed = [next(make_batches(s, dc, start_step=k)) for k in range(5)]
    for a, b in zip(run, resumed):
        np.testing.assert_array_equal(a["tokens"], b["tokens"])


def test_binary_source(tmp_path):
    toks = (np.arange(10_000) * 7919) % 5000
    f = tmp_path / "shard0.bin"
    toks.astype(np.uint16).tofile(f)
    src = BinarySource(str(tmp_path), seed=0)
    b = batch_at(src, DataConfig(32, 4), 0)
    assert b["tokens"].shape == (4, 32)
    assert b["tokens"].max() < 5000
    # the sampled sequence is a verbatim slice of the stream
    seq = src.sequence(0, 0, 32)
    pos = int(np.where(toks == seq[0])[0][0]) if seq[0] in toks else None
    b2 = batch_at(src, DataConfig(32, 4), 0)
    np.testing.assert_array_equal(b["tokens"], b2["tokens"])


def test_binary_source_uint32_meta(tmp_path):
    toks = np.arange(1000, dtype=np.uint32) + 70000
    (tmp_path / "s.bin").write_bytes(toks.tobytes())
    (tmp_path / "s.meta").write_text("uint32")
    src = BinarySource(str(tmp_path))
    seq = src.sequence(0, 0, 16)
    assert seq.min() >= 70000


@pytest.mark.parametrize("hosts", [1, 2, 3, 4, 6, 12])
@pytest.mark.parametrize("step", [0, 1, 17, 50])
def test_any_host_count_partitions(hosts, step):
    s = SyntheticSource(97, seed=5)
    full = batch_at(s, DataConfig(8, 12), step)
    parts = [batch_at(s, DataConfig(8, 12, host_index=i,
                                    num_hosts=hosts), step)
             for i in range(hosts)]
    got = np.concatenate([p["tokens"] for p in parts])
    np.testing.assert_array_equal(got, full["tokens"])
