"""HLO collective parser + roofline math (the dry-run's analysis layer)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import get_config, get_shape
from repro.launch import hlo_analysis as hlo

SAMPLE = """
HloModule m
ENTRY e {
  %p = bf16[16,512]{1,0} parameter(0)
  %ag = bf16[16,8192]{1,0} all-gather(%p), dimensions={1}
  %ar.1 = f32[1024]{0} all-reduce(%x), to_apply=%add
  ROOT %rs = f32[64]{0} reduce-scatter(%y), dimensions={0}
  %cp = u32[2,2]{1,0} collective-permute(%z), source_target_pairs={{0,1}}
  %a2a = s8[128]{0} all-to-all(%w), dimensions={0}
  %agd = bf16[4]{0} all-gather-done(%t)
  %dot = f32[4,4]{1,0} dot(%a, %b)
}
"""


def test_shape_bytes():
    assert hlo.shape_bytes("bf16[16,512]") == 16 * 512 * 2
    assert hlo.shape_bytes("f32[]") == 4
    assert hlo.shape_bytes("(f32[8], bf16[4])") == 32 + 8
    assert hlo.shape_bytes("s8[128]") == 128


def test_collective_stats_parser():
    st = hlo.collective_stats(SAMPLE)
    assert st.counts == {"all-gather": 1, "all-reduce": 1,
                         "reduce-scatter": 1, "collective-permute": 1,
                         "all-to-all": 1}
    assert st.bytes_["all-gather"] == 16 * 8192 * 2
    assert st.bytes_["all-reduce"] == 1024 * 4
    assert st.bytes_["reduce-scatter"] == 64 * 4
    assert st.bytes_["all-to-all"] == 128
    assert st.total_ops == 5


def test_parser_on_real_compiled_module():
    """Parse an actually-compiled psum program and find its all-reduce."""
    if jax.device_count() < 2:
        mesh = None
    f = jax.jit(lambda x: x * 2 + 1)
    txt = f.lower(jnp.ones((4,))).compile().as_text()
    st = hlo.collective_stats(txt)
    assert st.total_ops == 0            # no collectives in elementwise fn


MLIR_WITH_COLLECTIVE = """
module {
  func.func @main(%arg0: tensor<4xf32>) -> tensor<4xf32> {
    %0 = stablehlo.add %arg0, %arg0 : tensor<4xf32>
    %1 = "stablehlo.all_reduce"(%0) ({
    ^bb0(%a: tensor<f32>, %b: tensor<f32>):
      %s = stablehlo.add %a, %b : tensor<f32>
      stablehlo.return %s : tensor<f32>
    }) : (tensor<4xf32>) -> tensor<4xf32>
    return %1 : tensor<4xf32>
  }
}
"""


def test_first_collective_position_tuple():
    pos = hlo.first_collective_position(MLIR_WITH_COLLECTIVE)
    assert pos is not None
    first, total = pos
    assert 0 < first < total


def test_first_collective_position_none_without_collectives():
    """A program with no collectives has NO emission position — the
    contract serving jaxprs on 1 device rely on (a local decode step
    must not report a fabricated position)."""
    f = jax.jit(lambda x: jnp.tanh(x) * 2)
    text = f.lower(jnp.ones((4,))).as_text()
    assert hlo.first_collective_position(text) is None
    assert hlo.first_collective_position("") is None


def test_first_collective_position_none_on_local_serve_decode():
    """The motivating case: the 1-device local-reference serve decode
    (gspmd mode) emits no collectives and must yield None, while the
    hadronio serve decode on the same device yields a real position."""
    from repro.configs.base import CommConfig
    from repro.configs.registry import get_config
    from repro.serving import dispatch

    cfg = get_config("qwen2-0.5b-reduced")
    local = dispatch.lowered_decode_text(
        cfg, CommConfig(mode="gspmd", hierarchical=False), batch=2,
        max_len=32)
    assert hlo.first_collective_position(local) is None
    wired = dispatch.lowered_decode_text(
        cfg, CommConfig(mode="hadronio", slice_bytes=512, channels=2,
                        hierarchical=False), batch=2, max_len=32)
    pos = hlo.first_collective_position(wired)
    assert pos is not None and 0 < pos[0] < pos[1]


def test_roofline_terms_bottleneck():
    t = hlo.roofline_terms(flops=1e17, hbm_bytes=1e9, collective_bytes=1e9,
                           n_chips=256)
    assert t["bottleneck"] == "compute"
    t = hlo.roofline_terms(flops=1e9, hbm_bytes=1e14, collective_bytes=1e9,
                           n_chips=1, flops_are_global=False)
    assert t["bottleneck"] == "memory"
    t = hlo.roofline_terms(flops=1e9, hbm_bytes=1e9, collective_bytes=1e13,
                           n_chips=1, flops_are_global=False)
    assert t["bottleneck"] == "collective"


def test_model_flops_moe_uses_active():
    mx = get_config("mixtral-8x7b")
    shape = get_shape("train_4k")
    f = hlo.model_flops(mx, shape)
    # active ~13B of 47B total: 6*N_active*D bounds
    n_tok = shape.global_batch * shape.seq_len
    assert f < 6.2 * 20e9 * n_tok
    assert f > 6.0 * 10e9 * n_tok


def test_model_flops_decode_vs_train():
    cfg = get_config("qwen2-0.5b")
    tr = hlo.model_flops(cfg, get_shape("train_4k"))
    de = hlo.model_flops(cfg, get_shape("decode_32k"))
    assert tr > de * 1000     # decode is one token per sequence
