"""HLO collective parser + roofline math (the dry-run's analysis layer)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import get_config, get_shape
from repro.launch import hlo_analysis as hlo

SAMPLE = """
HloModule m
ENTRY e {
  %p = bf16[16,512]{1,0} parameter(0)
  %ag = bf16[16,8192]{1,0} all-gather(%p), dimensions={1}
  %ar.1 = f32[1024]{0} all-reduce(%x), to_apply=%add
  ROOT %rs = f32[64]{0} reduce-scatter(%y), dimensions={0}
  %cp = u32[2,2]{1,0} collective-permute(%z), source_target_pairs={{0,1}}
  %a2a = s8[128]{0} all-to-all(%w), dimensions={0}
  %agd = bf16[4]{0} all-gather-done(%t)
  %dot = f32[4,4]{1,0} dot(%a, %b)
}
"""


def test_shape_bytes():
    assert hlo.shape_bytes("bf16[16,512]") == 16 * 512 * 2
    assert hlo.shape_bytes("f32[]") == 4
    assert hlo.shape_bytes("(f32[8], bf16[4])") == 32 + 8
    assert hlo.shape_bytes("s8[128]") == 128


def test_collective_stats_parser():
    st = hlo.collective_stats(SAMPLE)
    assert st.counts == {"all-gather": 1, "all-reduce": 1,
                         "reduce-scatter": 1, "collective-permute": 1,
                         "all-to-all": 1}
    assert st.bytes_["all-gather"] == 16 * 8192 * 2
    assert st.bytes_["all-reduce"] == 1024 * 4
    assert st.bytes_["reduce-scatter"] == 64 * 4
    assert st.bytes_["all-to-all"] == 128
    assert st.total_ops == 5


def test_parser_on_real_compiled_module():
    """Parse an actually-compiled psum program and find its all-reduce."""
    if jax.device_count() < 2:
        mesh = None
    f = jax.jit(lambda x: x * 2 + 1)
    txt = f.lower(jnp.ones((4,))).compile().as_text()
    st = hlo.collective_stats(txt)
    assert st.total_ops == 0            # no collectives in elementwise fn


def test_roofline_terms_bottleneck():
    t = hlo.roofline_terms(flops=1e17, hbm_bytes=1e9, collective_bytes=1e9,
                           n_chips=256)
    assert t["bottleneck"] == "compute"
    t = hlo.roofline_terms(flops=1e9, hbm_bytes=1e14, collective_bytes=1e9,
                           n_chips=1, flops_are_global=False)
    assert t["bottleneck"] == "memory"
    t = hlo.roofline_terms(flops=1e9, hbm_bytes=1e9, collective_bytes=1e13,
                           n_chips=1, flops_are_global=False)
    assert t["bottleneck"] == "collective"


def test_model_flops_moe_uses_active():
    mx = get_config("mixtral-8x7b")
    shape = get_shape("train_4k")
    f = hlo.model_flops(mx, shape)
    # active ~13B of 47B total: 6*N_active*D bounds
    n_tok = shape.global_batch * shape.seq_len
    assert f < 6.2 * 20e9 * n_tok
    assert f > 6.0 * 10e9 * n_tok


def test_model_flops_decode_vs_train():
    cfg = get_config("qwen2-0.5b")
    tr = hlo.model_flops(cfg, get_shape("train_4k"))
    de = hlo.model_flops(cfg, get_shape("decode_32k"))
    assert tr > de * 1000     # decode is one token per sequence
