"""Single-device unit tests for launch/steps.py internals and the
unroll switch (multi-device behaviour is covered by tests/distributed)."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs.base import CommConfig, RunConfig, ShapeConfig
from repro.configs.registry import get_config
from repro.core import aggregation as agg
from repro.launch import steps as steps_mod
from repro.models import api
from repro.models.unroll import scan_or_unroll, unrolled_layers, \
    unroll_enabled


def run_of(cfg, mode="hadronio", **kw):
    return RunConfig(model=cfg, shape=ShapeConfig("t", "train", 16, 4),
                     comm=CommConfig(mode=mode, hierarchical=False), **kw)


def test_microbatches_split():
    b = {"tokens": jnp.arange(24).reshape(6, 4)}
    m = steps_mod._microbatches(b, 3)
    assert m["tokens"].shape == (3, 2, 4)
    np.testing.assert_array_equal(np.asarray(m["tokens"][0]),
                                  np.arange(8).reshape(2, 4))


def test_decay_mask_traced_matches_numpy():
    cfg = get_config("qwen2-0.5b-reduced")
    plan = agg.make_plan(api.abstract(cfg), CommConfig(mode="hadronio_rs"))
    a = steps_mod._decay_mask_flat(plan)
    b = np.asarray(jax.jit(lambda: steps_mod._decay_mask_traced(plan))())
    np.testing.assert_array_equal(a, b)


def test_abstract_tac_state_shapes():
    cfg = get_config("qwen2-0.5b-reduced")
    st = steps_mod.abstract_tac_state(run_of(cfg, "hadronio_rs"), 8)
    assert st.opt.mu.shape[0] == 8                       # ring dim
    plan = agg.make_plan(api.abstract(cfg), CommConfig(mode="hadronio_rs"))
    assert st.opt.mu.shape[1] == plan.padded_elems // 8
    st2 = steps_mod.abstract_tac_state(run_of(cfg, "hadronio"), 8)
    assert isinstance(st2.opt.mu, dict)                  # tree moments
    st3 = steps_mod.abstract_train_state(run_of(cfg, "gspmd"))
    assert jax.tree.structure(st3.params) == \
        jax.tree.structure(api.abstract(cfg))


def test_flat_adamw_matches_tree_adamw():
    """The ZeRO flat update equals the tree update on the same values."""
    from repro.optim import adamw
    rng = np.random.default_rng(0)
    p = jnp.asarray(rng.normal(size=(8, 4)), jnp.float32)
    g = jnp.asarray(rng.normal(size=(8, 4)), jnp.float32)
    run = run_of(get_config("qwen2-0.5b-reduced"))
    # tree path (no clipping effect: scale grads to tiny norm)
    g = g * 1e-3
    new_p, st, _ = adamw.update({"w": g}, adamw.init({"w": p}), {"w": p},
                                run)
    # flat path
    count = jnp.asarray(1, jnp.int32)
    mask = jnp.ones((32,), jnp.float32)
    fp, fmu, fnu = steps_mod._flat_adamw_update(
        p.reshape(-1), g.reshape(-1), jnp.zeros(32), jnp.zeros(32),
        count, mask, run)
    np.testing.assert_allclose(np.asarray(new_p["w"]).reshape(-1),
                               np.asarray(fp), rtol=1e-6, atol=1e-7)


def test_scan_or_unroll_equivalence():
    xs = jnp.arange(12.0).reshape(4, 3)

    def body(c, x):
        return c + jnp.sum(x), c

    c1, y1 = scan_or_unroll(body, jnp.zeros(()), xs, 4)
    with unrolled_layers():
        assert unroll_enabled()
        c2, y2 = scan_or_unroll(body, jnp.zeros(()), xs, 4)
    assert not unroll_enabled()
    np.testing.assert_allclose(float(c1), float(c2))
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2))


def test_unrolled_model_matches_scanned(rng):
    """The dry-run's unrolled lowering computes the same function."""
    cfg = get_config("qwen1.5-4b-reduced")
    params = api.init(rng, cfg)
    batch = {"tokens": jax.random.randint(rng, (2, 8), 0, cfg.vocab_size),
             "labels": jax.random.randint(rng, (2, 8), 0, cfg.vocab_size)}
    l1, _ = api.loss(params, batch, cfg)
    with unrolled_layers():
        l2, _ = api.loss(params, batch, cfg)
    np.testing.assert_allclose(float(l1), float(l2), rtol=1e-5)


def test_grow_cache_roundtrip(rng):
    cfg = get_config("qwen2-0.5b-reduced")
    params = api.init(rng, cfg)
    _, cache = api.prefill(params, {"tokens": jnp.ones((1, 5),
                                                       jnp.int32)}, cfg)
    grown = api.grow_cache(cfg, cache, 32)
    assert grown["k"].shape[2] == 32
    np.testing.assert_array_equal(np.asarray(grown["k"][:, :, :5]),
                                  np.asarray(cache["k"]))
    # recurrent states pass through untouched
    cfg2 = get_config("rwkv6-7b-reduced")
    p2 = api.init(rng, cfg2)
    _, c2 = api.prefill(p2, {"tokens": jnp.ones((1, 5), jnp.int32)}, cfg2)
    assert api.grow_cache(cfg2, c2, 64) is c2
