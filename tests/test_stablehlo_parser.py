"""StableHLO (emitted-schedule) parser unit tests — crafted MLIR text,
covering region-form ops whose type signature sits on the closing line."""
from repro.launch.hlo_analysis import stablehlo_collective_stats

SAMPLE = '''
module @jit_step {
  func.func public @main(%arg0: tensor<1024xf32>) -> tensor<1024xf32> {
    %0 = "stablehlo.all_reduce"(%arg0) <{replica_groups = dense<0>}> ({
    ^bb0(%a: tensor<f32>, %b: tensor<f32>):
      %s = stablehlo.add %a, %b : tensor<f32>
      stablehlo.return %s : tensor<f32>
    }) : (tensor<1024xf32>) -> tensor<1024xf32>
    %1 = "stablehlo.all_gather"(%0) {all_gather_dim = 0 : i64} : (tensor<1024xf32>) -> tensor<8192xf32>
    %2 = "stablehlo.reduce_scatter"(%1) <{scatter_dimension = 0 : i64}> ({
    ^bb0(%a: tensor<f32>, %b: tensor<f32>):
      %s = stablehlo.add %a, %b : tensor<f32>
      stablehlo.return %s : tensor<f32>
    }) : (tensor<8192xf32>) -> tensor<1024xf32>
    %3 = "stablehlo.collective_permute"(%2) {source_target_pairs = dense<0>} : (tensor<1024xf32>) -> tensor<1024xf32>
    %4 = "stablehlo.all_to_all"(%3) {split_dimension = 0 : i64} : (tensor<1024xf32>) -> tensor<1024xf32>
    return %4 : tensor<1024xf32>
  }
}
'''


def test_counts_and_bytes():
    st = stablehlo_collective_stats(SAMPLE)
    assert st.counts == {"all-reduce": 1, "all-gather": 1,
                         "reduce-scatter": 1, "collective-permute": 1,
                         "all-to-all": 1}
    assert st.bytes_["all-reduce"] == 1024 * 4      # region-form
    assert st.bytes_["all-gather"] == 8192 * 4      # inline form
    assert st.bytes_["reduce-scatter"] == 1024 * 4  # region-form
    assert st.total_ops == 5


def test_bf16_and_int_dtypes():
    txt = ('%1 = "stablehlo.all_gather"(%0) : (tensor<2x8xbf16>) -> '
           'tensor<16x8xbf16>\n'
           '%2 = "stablehlo.all_to_all"(%1) : (tensor<4xi32>) -> '
           'tensor<4xi32>')
    st = stablehlo_collective_stats(txt)
    assert st.bytes_["all-gather"] == 16 * 8 * 2
    assert st.bytes_["all-to-all"] == 16


def test_non_collective_lines_ignored():
    txt = "%5 = stablehlo.dot_general %a, %b : tensor<4x4xf32>"
    st = stablehlo_collective_stats(txt)
    assert st.total_ops == 0
