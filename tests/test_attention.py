"""Attention-path unit tests: chunked vs direct, decode vs full, rolling
windows, GQA expansion, RoPE."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.models import attention as att
from repro.models.layers import rope


def mk(rng, i, shape):
    return jax.random.normal(jax.random.fold_in(rng, i), shape)


@pytest.mark.parametrize("s", [8, 64, 130, 257])
@pytest.mark.parametrize("window", [0, 32])
def test_chunked_equals_direct(s, window, rng):
    b, h, dh = 2, 2, 16
    q, k, v = (mk(rng, i, (b, s, h, dh)) for i in range(3))
    pos = jnp.arange(s)
    o1 = att.attend_chunked(q, k, v, causal=True, window=window,
                            q_chunk=64, kv_chunk=32)
    o2 = att.attend_direct(q, k, v, pos, pos, causal=True, window=window)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2),
                               atol=2e-5, rtol=2e-4)


def test_gqa_expand():
    b, s, kv, g, dh = 1, 3, 2, 3, 4
    k = mk(jax.random.PRNGKey(0), 0, (b, s, kv, dh))
    kx = att.expand_kv(k, kv * g)
    assert kx.shape == (b, s, kv * g, dh)
    for i in range(kv * g):
        np.testing.assert_array_equal(np.asarray(kx[:, :, i]),
                                      np.asarray(k[:, :, i // g]))


def test_decode_matches_direct_full(rng):
    """Decoding token t against a cache equals direct attention over the
    full prefix."""
    b, s, h, dh = 2, 9, 2, 8
    q, k, v = (mk(rng, i, (b, s, h, dh)) for i in range(3))
    pos_all = jnp.arange(s)
    full = att.attend_direct(q, k, v, pos_all, pos_all, causal=True)
    cache_k = jnp.zeros((b, 16, h, dh))
    cache_v = jnp.zeros((b, 16, h, dh))
    for t in range(s):
        out, cache_k, cache_v = att.decode_attend(
            q[:, t:t + 1], cache_k, cache_v, k[:, t:t + 1], v[:, t:t + 1],
            jnp.asarray(t), num_heads=h)
        np.testing.assert_allclose(np.asarray(out[:, 0]),
                                   np.asarray(full[:, t]),
                                   atol=2e-5, rtol=2e-4)


def test_decode_vector_pos_matches_scalar(rng):
    b, h, dh, smax = 3, 2, 8, 16
    ck, cv = mk(rng, 1, (b, smax, h, dh)), mk(rng, 2, (b, smax, h, dh))
    q = mk(rng, 3, (b, 1, h, dh))
    nk, nv = mk(rng, 4, (b, 1, h, dh)), mk(rng, 5, (b, 1, h, dh))
    o_s, k_s, v_s = att.decode_attend(q, ck, cv, nk, nv,
                                      jnp.asarray(5), num_heads=h)
    o_v, k_v, v_v = att.decode_attend(q, ck, cv, nk, nv,
                                      jnp.full((b,), 5), num_heads=h)
    np.testing.assert_allclose(np.asarray(o_s), np.asarray(o_v), atol=1e-6)
    np.testing.assert_allclose(np.asarray(k_s), np.asarray(k_v), atol=0)


@pytest.mark.parametrize("s,w", [(5, 8), (8, 8), (13, 8)])
def test_to_rolling_layout(s, w, rng):
    k = mk(rng, 0, (1, s, 1, 4))
    r = att.to_rolling(k, w)
    assert r.shape == (1, w, 1, 4)
    # position p (for p in the live window) sits at slot p % w
    for p in range(max(0, s - w), s):
        np.testing.assert_array_equal(np.asarray(r[0, p % w]),
                                      np.asarray(k[0, p]))


def test_windowed_decode_matches_full_band(rng):
    """Rolling-cache decode == direct banded attention, beyond one wrap."""
    b, h, dh, w = 1, 1, 8, 4
    s = 11
    q, k, v = (mk(rng, i, (b, s, h, dh)) for i in range(3))
    pos_all = jnp.arange(s)
    full = att.attend_direct(q, k, v, pos_all, pos_all, causal=True,
                             window=w)
    ck = jnp.zeros((b, w, h, dh))
    cv = jnp.zeros((b, w, h, dh))
    for t in range(s):
        out, ck, cv = att.decode_attend(
            q[:, t:t + 1], ck, cv, k[:, t:t + 1], v[:, t:t + 1],
            jnp.asarray(t), num_heads=h, window=w)
        np.testing.assert_allclose(np.asarray(out[:, 0]),
                                   np.asarray(full[:, t]),
                                   atol=2e-5, rtol=2e-4, err_msg=f"t={t}")


def test_rope_rotation_property(rng):
    """RoPE inner products depend only on relative position."""
    h, dh = 1, 16
    q = mk(rng, 0, (1, 1, h, dh))
    k = mk(rng, 1, (1, 1, h, dh))

    def score(pq, pk):
        qr = rope(q, jnp.asarray([pq])[None], 10000.0)
        kr = rope(k, jnp.asarray([pk])[None], 10000.0)
        return float(jnp.sum(qr * kr))

    assert abs(score(3, 1) - score(7, 5)) < 1e-4
    assert abs(score(3, 1) - score(4, 1)) > 1e-6   # actually rotates
