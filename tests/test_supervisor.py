"""Self-healing supervisor (serving/supervisor.py): the detect → decide
→ heal loop over the EventLoopGroup + DecodeEngine fleet.

The acceptance property tested here: every chaos scenario (including the
new ``mem_pressure`` allocator-seam class) recovers bit-identically
UNDER the supervisor — the supervisor's own seed-deterministic,
non-empty healing trace is the evidence that it, not the harness, did
the healing — plus unit coverage for each healing mechanism: retry
budgets, admission shedding, heartbeat quarantine, autoscale and
external resize (both mid-stream, with token identity)."""
import jax
import numpy as np
import pytest

from repro.configs.base import ModelConfig
from repro.models import api
from repro.serving import chaos, slo
from repro.serving.chaos import SCENARIOS
from repro.serving.dispatch import clear_serve_step_cache
from repro.serving.engine import DecodeEngine, Request
from repro.serving.supervisor import (Outcome, RetryBudget, Supervisor,
                                      SupervisorConfig)


@pytest.fixture(scope="module")
def tiny():
    cfg = ModelConfig(name="sup-tiny", family="dense", num_layers=1,
                      d_model=16, num_heads=2, num_kv_heads=2, d_ff=32,
                      vocab_size=64, head_dim=8, param_dtype="float32",
                      compute_dtype="float32")
    params = api.init(jax.random.PRNGKey(0), cfg)
    clear_serve_step_cache()
    return cfg, params


@pytest.fixture(scope="module")
def reference(tiny):
    """One fault-free token reference for the whole module (conformance
    makes tokens invariant to mode/affinity/loop count)."""
    cfg, params = tiny
    reqs = chaos.make_requests(4, vocab_size=cfg.vocab_size)
    base = chaos.run_baseline(cfg, params,
                              chaos.chaos_serve_config("hadronio", 1),
                              reqs)
    assert base.tokens and all(base.tokens.values())
    return chaos.Baseline(tokens=base.tokens), reqs


def _tokens(results) -> dict:
    return {r.uid: tuple(np.asarray(r.tokens).tolist()) for r in results}


# ---------------------------------------------------------------------------
# RetryBudget: seeded, capped, bounded backoff
# ---------------------------------------------------------------------------


def test_retry_budget_backoff_deterministic_and_bounded():
    b = RetryBudget(limit=4, base_s=1e-3, cap_s=4e-3, jitter=0.25)
    seq = [b.backoff_s(a, np.random.default_rng(7)) for a in range(6)]
    seq2 = [b.backoff_s(a, np.random.default_rng(7)) for a in range(6)]
    assert seq == seq2                      # same seed ⇒ same jitter
    for a, s in enumerate(seq):
        raw = min(b.cap_s, b.base_s * 2 ** a)
        assert raw * (1 - b.jitter) - 1e-12 <= s \
            <= raw * (1 + b.jitter) + 1e-12
    # the cap binds: attempts beyond log2(cap/base) stop growing
    assert seq[4] <= b.cap_s * (1 + b.jitter)
    # jitter=0 is exactly the capped exponential
    b0 = RetryBudget(jitter=0.0, base_s=1e-3, cap_s=4e-3)
    rng = np.random.default_rng(0)
    assert [b0.backoff_s(a, rng) for a in range(4)] == \
        [1e-3, 2e-3, 4e-3, 4e-3]


# ---------------------------------------------------------------------------
# The acceptance matrix: every scenario recovers UNDER the supervisor,
# with the supervisor's own (non-empty, seed-deterministic) trace
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("scenario", SCENARIOS)
def test_supervised_recovery_and_trace_determinism(tiny, reference,
                                                   scenario):
    cfg, params = tiny
    base, reqs = reference
    serve = chaos.chaos_serve_config("hadronio", 2)
    runs = [chaos.run_supervised(scenario, cfg, params, serve, reqs,
                                 seed=11, baseline=base)
            for _ in range(2)]
    a, b = runs
    assert a.plan == b.plan
    assert a.fired == b.fired
    # recovery = 1.0: bit-identical tokens vs the fault-free reference
    assert a.tokens == b.tokens == base.tokens
    assert a.report.recovered and b.report.recovered
    assert a.report.n_injected > 0
    # the supervisor did the healing: its canonical trace is non-empty
    # and seed-deterministic (wall-clock stamps are excluded from it)
    assert a.trace, scenario
    assert a.trace == b.trace, scenario
    assert a.report.healing_actions == len(a.trace) > 0
    # every client request reached a terminal 'served' outcome
    assert {u: o.status for u, o in a.outcomes.items()
            if u < chaos.STORM_UID_BASE} == \
        {r.uid: "served" for r in reqs}
    slo.assert_slo(a.report)


def test_supervised_scenarios_map_to_expected_healing(tiny, reference):
    """Each fault class exercises ITS healing mechanism — the trace
    kinds are the evidence the right detector fired."""
    cfg, params = tiny
    base, reqs = reference
    serve = chaos.chaos_serve_config("hadronio", 2)
    expect = {
        "slow_channel": {"quarantine"},       # delay EWMA
        "stalled_loop": {"quarantine"},       # stall EWMA
        "dropped_flush": {"retry"},           # drain crash → retry/backoff
        "admission_storm": {"backpressure"},  # in-wave gate
        "reshard_mid_request": {"resize"},    # external elasticity
        "mem_pressure": {"retry"},            # alloc abort → retry
    }
    for scenario, kinds in expect.items():
        res = chaos.run_supervised(scenario, cfg, params, serve, reqs,
                                   seed=11, baseline=base)
        got = {k for _, k, _, _ in res.trace}
        assert kinds <= got, (scenario, res.trace)


# ---------------------------------------------------------------------------
# Retry exhaustion: structured surfacing, never a hang
# ---------------------------------------------------------------------------


def test_retry_exhaustion_surfaces_structured_outcome(tiny, reference):
    cfg, params = tiny
    base, reqs = reference
    budget = RetryBudget(limit=2, base_s=1e-6, cap_s=1e-6, jitter=0.0,
                         deadline_s=5.0)
    sup = Supervisor(cfg, params, chaos.chaos_serve_config("hadronio", 2),
                     seed=3, config=SupervisorConfig(retry=budget))

    def wedge(grp):
        def crash(loop, items):
            raise RuntimeError("wedged NIC")
        grp.loops[0].drain_hook = crash   # survives restart (loop attr)

    sup.fleet_hook = wedge
    sup.submit(reqs)
    results = sup.run()                   # returns — never hangs
    # round-robin put uids 0,2 on loop 0: budget ran dry for them
    dead = {u for u, o in sup.outcomes.items()
            if o.status == "retry_exhausted"}
    assert dead == {0, 2}
    for u in dead:
        o = sup.outcomes[u]
        assert "wedged NIC" in o.reason
        assert o.attempts == budget.limit + 1
    # loop 1's requests were served normally, bit-identical
    assert _tokens(results) == {u: t for u, t in base.tokens.items()
                                if u in (1, 3)}
    kinds = [k for _, k, _, _ in sup.healing_trace()]
    assert "retry_exhausted" in kinds
    assert kinds.count("quarantine") >= 1
    ex = next(a for a in sup.trace if a.kind == "retry_exhausted")
    assert ex.detail[0] == budget.limit and ex.detail[1] == (0, 2)


# ---------------------------------------------------------------------------
# Bounded admission queue: lowest-priority shedding
# ---------------------------------------------------------------------------


def test_admission_queue_sheds_lowest_priority(tiny):
    cfg, params = tiny
    sup = Supervisor(cfg, params, chaos.chaos_serve_config("hadronio", 1),
                     config=SupervisorConfig(admission_capacity=2))
    mk = lambda uid, pri: Request(uid, np.asarray([3, 4]), max_new=2,
                                  priority=pri)
    sup.submit([mk(0, 0), mk(1, 1)])      # fills the queue
    assert len(sup.queue) == 2 and not sup.outcomes
    sup.submit(mk(2, 0))                  # no higher than the floor: shed
    assert sup.outcomes[2] == Outcome(2, "rejected",
                                      "admission_queue_full", 0)
    assert [r.uid for r in sup.queue] == [0, 1]
    sup.submit(mk(3, 2))                  # evicts the lowest (uid 0)
    assert sup.outcomes[0].status == "rejected"
    assert sorted(r.uid for r in sup.queue) == [1, 3]
    assert len(sup.queue) == 2            # still bounded
    sheds = [a for a in sup.trace if a.kind == "shed"]
    assert [(a.target, a.detail) for a in sheds] == [(2, (0,)), (0, (0,))]


# ---------------------------------------------------------------------------
# Heartbeat quarantine: a silently-wedged loop is detected by rounds
# (not wall-clock) and its queue migrates to survivors
# ---------------------------------------------------------------------------


def test_heartbeat_quarantine_migrates_queue(tiny, reference):
    cfg, params = tiny
    base, reqs = reference
    sup = Supervisor(cfg, params, chaos.chaos_serve_config("hadronio", 2),
                     seed=0)

    state = {"armed": True}

    def wedge(grp):
        l0 = grp.loops[0]
        real = l0.drain

        def drain():
            if state["armed"]:
                return []          # no beat, queue untouched: wedged
            return real()
        l0.drain = drain

    sup.fleet_hook = wedge
    sup.submit(reqs)
    results = sup.run()
    q = [a for a in sup.trace if a.kind == "quarantine"]
    assert q and q[0].target == 0
    assert q[0].detail[0] == "heartbeat"
    assert q[0].detail[3] == 2            # uids 0,2 migrated off loop 0
    # after migration the SURVIVOR served everything bit-identically
    # (the wedged drain stub stays armed — loop 0 never runs again)
    assert _tokens(results) == base.tokens
    assert all(sup.outcomes[r.uid].status == "served" for r in reqs)


# ---------------------------------------------------------------------------
# Elasticity mid-stream: autoscale (queue depth + hysteresis) and
# external resize, both with token identity across the resize
# ---------------------------------------------------------------------------


def test_autoscale_grows_mid_stream_with_token_identity(tiny, reference):
    cfg, params = tiny
    base, reqs = reference
    sup = Supervisor(
        cfg, params, chaos.chaos_serve_config("hadronio", 1), seed=0,
        config=SupervisorConfig(dispatch_quantum=1, scale_up_depth=1.0,
                                hysteresis=2, cooldown_rounds=0))
    sup.submit(reqs)
    results = sup.run()
    resizes = [a for a in sup.trace if a.kind == "resize"]
    assert resizes, sup.healing_trace()
    first = resizes[0]
    assert first.detail[2] == "queue_depth"
    assert first.target == 2 and first.detail[0] == 1     # grew 1 → 2
    # exercised MID-stream: requests were still queued when it fired,
    # and serving continued for more rounds afterwards
    assert 1 <= first.round < sup.rounds
    assert sup.group.n_loops >= 2
    # minimal migration on the flat fabric: moved ⊆ the added loop's run
    moved = first.detail[1]
    assert set(moved) <= set(sup.group.loops[-1].channels) or \
        len(resizes) > 1
    # token identity across the in-flight resize (the conformance
    # invariant: affinity changes emission structure, never logits)
    assert _tokens(results) == base.tokens


def test_external_resize_applies_at_round_boundary(tiny, reference):
    cfg, params = tiny
    base, reqs = reference
    sup = Supervisor(cfg, params, chaos.chaos_serve_config("hadronio", 1),
                     seed=0, config=SupervisorConfig(dispatch_quantum=2))
    sup.request_resize(3)
    sup.submit(reqs)
    results = sup.run()
    resizes = [a for a in sup.trace if a.kind == "resize"]
    assert len(resizes) == 1
    assert resizes[0].target == 3
    assert resizes[0].detail[0] == 1 and resizes[0].detail[2] == "requested"
    assert sup.group.n_loops == 3
    assert tuple(l.channels for l in sup.group.loops) == \
        tuple(sup._affinity)
    assert _tokens(results) == base.tokens


def test_resize_is_clamped_to_channel_pool(tiny):
    cfg, params = tiny
    serve = chaos.chaos_serve_config("hadronio", 2)   # 4-channel pool
    sup = Supervisor(cfg, params, serve, config=SupervisorConfig())
    sup.request_resize(99)
    sup.submit(Request(0, np.asarray([3, 4]), max_new=2))
    sup.run()
    assert sup.group.n_loops == serve.comm.channels   # clamped to 4


# ---------------------------------------------------------------------------
# Batched admission: one prefill per flush boundary, not per request
# ---------------------------------------------------------------------------


def _counting_engine(cfg, params, **kw):
    """Engine emitting ``(previous token + 1) % vocab`` (the
    non-degenerate stream from tests/test_serving.py) with stubbed
    prefill/decode — isolates the admission path."""
    import jax.numpy as jnp
    eng = DecodeEngine(cfg, params, **kw)
    V = cfg.vocab_size
    eye = np.eye(V, dtype=np.float32) * 10.0

    def fake_prefill(p, batch):
        toks = np.asarray(batch["tokens"])
        last = np.asarray(batch["last_pos"])
        prev = toks[np.arange(toks.shape[0]), last]
        cache = {"k": jnp.zeros((1, toks.shape[0], 4), jnp.float32)}
        return jnp.asarray(eye[(prev + 1) % V]), cache

    def fake_decode(p, cache, dec):
        prev = np.asarray(dec["token"])
        return jnp.asarray(eye[(prev + 1) % V]), cache

    eng._prefill = fake_prefill
    eng._decode = fake_decode
    return eng


def test_batched_admission_one_prefill_per_boundary(tiny):
    """Three residents finish at the same flush boundary; both queued
    requests are admitted by ONE batched prefill (admit_prefills == 1),
    and every stream is exact — batched admission is bit-identical to
    solo."""
    cfg, params = tiny
    eng = _counting_engine(cfg, params, max_batch=3, max_len=32)
    reqs = [Request(u, np.asarray([1, 10 * u + 5]), max_new=2)
            for u in range(5)]
    res = eng.generate(reqs)
    assert eng.admit_prefills == 1
    assert _tokens(res) == {
        u: (10 * u + 6, 10 * u + 7) for u in range(5)}
