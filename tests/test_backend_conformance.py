"""Cross-backend conformance suite.

The JIB-benchmark lesson (Nothaas et al., arXiv:1910.02245): transport
variants are only trustworthy when ONE harness exercises every
implementation identically. Every registered comm backend runs through
the same fixture matrix here:

* **sync parity** — on a 1-peer ring psum == identity, so the
  reconstructed synced gradients must equal the inputs within the wire
  codec's dtype tolerance, for every supported ``(compress, pack)``
  combination; unsupported combinations must be REJECTED by
  ``validate()`` with a clear error (never silently ignored).
* **state round-trip** — ``state_specs`` / ``init`` / ``apply_update``
  agree: a jitted train step returns a state matching the abstract specs
  leaf-for-leaf (structure, shape, dtype), for compress off AND on.
* **gspmd parity** — every manual backend's two-step loss equals the
  gspmd reference within tolerance (the paper's transparency claim).
* **bucket independence** — in BOTH overlap modes, each bucket's
  collective depends only on its own leaves (+ its own per-bucket error
  feedback): a jaxpr-level dependency check, for every codec.

The matrix is generated from ``available_modes()`` and indexed into
``SUPPORTED_COMPRESS`` at collection time — registering a backend
without declaring its conformance expectations fails collection.

* **aggregate parity** — ``comm.aggregate="channel"`` (one coalesced
  wire flush per connection) must be BIT-identical to the per-slice
  schedule for every hadronio-family mode and codec, including the
  ZeRO-1 flat-shard ordering, and the per-exchange collective count must
  drop from n_slices to n_channels (checked on the emitted StableHLO via
  ``launch/hlo_analysis``).

* **flush parity** — ``comm.flush="ready"`` (the flush-when-ready
  channel schedule from ``core/flush_scheduler``) must be BIT-identical
  to the ``"step"`` schedule for every hadronio-family mode × codec ×
  pack at BOTH aggregate granularities, and the jaxpr-level evidence
  tests prove the overlap recovery: under ``aggregate="channel"`` with
  channels < n_buckets the first channel's collective is emitted before
  the last bucket's pack (and depends only on its own contiguous run of
  first-produced buckets), which ``"step"`` structurally cannot do.

Set ``REPRO_CONFORMANCE_PACK=jnp|pallas`` to pin the pack-stage
implementation (CI runs the jnp fallback explicitly),
``REPRO_CONFORMANCE_AGG=slice|channel`` to pin the wire-flush
granularity, and ``REPRO_CONFORMANCE_FLUSH=step|ready`` to pin the
channel schedule the whole matrix runs under — CI runs one conformance
leg per pin (a workflow matrix with fail-fast off).
"""
import functools
import os

import numpy as np
import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from repro import compat
from repro.configs.base import CommConfig, RunConfig, ShapeConfig
from repro.configs.registry import get_config
from repro.core import tac
from repro.core.backends import (SyncContext, available_modes, get_backend)
from repro.core.backends import hadronio_overlap as ho
from repro.core.backends import hadronio_overlap_rs as hors
from repro.launch import steps as steps_mod
from repro.launch.mesh import make_mesh

COMPRESS = ("none", "bf16", "int8_ef")
_PACK_ENV = os.environ.get("REPRO_CONFORMANCE_PACK")
PACKS = (_PACK_ENV,) if _PACK_ENV else ("jnp", "pallas")
assert all(p in ("jnp", "pallas") for p in PACKS), _PACK_ENV
# wire-flush granularity the whole matrix runs under (the aggregate-parity
# tests below always exercise BOTH, so the default leg stays "slice");
# empty values (unset legs of the CI matrix) fall back to the default
AGG = os.environ.get("REPRO_CONFORMANCE_AGG") or "slice"
assert AGG in ("slice", "channel"), AGG
# channel schedule the whole matrix runs under (the flush-parity tests
# below always exercise BOTH, so the default leg stays "step")
FLUSH = os.environ.get("REPRO_CONFORMANCE_FLUSH") or "step"
assert FLUSH in ("step", "ready"), FLUSH

# Which codecs each registered mode must honor; everything not listed
# must be rejected by validate(). EVERY registered mode needs an entry —
# the matrix below indexes this dict with each name in available_modes()
# at collection time, so a backend registered without conformance
# coverage fails before a single test runs.
SUPPORTED_COMPRESS = {
    "gspmd": ("none",),
    "sockets": ("none",),
    "vma": ("none", "bf16"),
    "hadronio": ("none", "bf16", "int8_ef"),
    "hadronio_rs": ("none", "bf16", "int8_ef"),
    "hadronio_overlap": ("none", "bf16", "int8_ef"),
    "hadronio_overlap_rs": ("none", "bf16", "int8_ef"),
}

SYNC_CASES = [(m, c, p)
              for m in available_modes()
              for c in SUPPORTED_COMPRESS[m]      # KeyError => no coverage
              for p in PACKS]
REJECT_CASES = [(m, c)
                for m in available_modes()
                for c in COMPRESS if c not in SUPPORTED_COMPRESS[m]]
STEP_CASES = [(m, c) for m in available_modes()
              for c in SUPPORTED_COMPRESS[m]]
BUCKET_MODES = ("hadronio_overlap", "hadronio_overlap_rs")

# int8 quantizes per slice/bucket against the group amax; tolerance is
# absolute against the tree's amax (~4 for unit normals)
TOL = {"none": dict(rtol=1e-6, atol=1e-6),
       "bf16": dict(rtol=1e-2, atol=1e-3),
       "int8_ef": dict(rtol=0.0, atol=0.05)}


def test_matrix_covers_registry_exactly():
    """No registered mode without coverage, no stale matrix entries."""
    assert set(SUPPORTED_COMPRESS) == set(available_modes())


def _grad_tree():
    """Mixed-shape synthetic gradients: a scalar-ish 1-D leaf, odd dims,
    and one 3000-element leaf that is BIGGER than a 4 KiB bucket (12 KB
    payload -> its own bucket)."""
    ks = jax.random.split(jax.random.PRNGKey(7), 4)
    return {"a": jax.random.normal(ks[0], (33, 7)),
            "b": {"c": jax.random.normal(ks[1], (129,)),
                  "d": jax.random.normal(ks[2], (2, 3, 5))},
            "e": jax.random.normal(ks[3], (3000,))}


def _comm(mode, compress="none", pack="jnp", **kw):
    kw.setdefault("slice_bytes", 4096)
    kw.setdefault("hierarchical", False)
    kw.setdefault("aggregate", AGG)
    kw.setdefault("flush", FLUSH)
    return CommConfig(mode=mode, compress=compress, pack=pack, **kw)


@pytest.mark.parametrize("mode,compress,pack", SYNC_CASES)
def test_sync_parity(mode, compress, pack):
    """Identity on a 1-peer ring, reconstructed through the backend's own
    gathered_grads (exercises the zero1 gather epilogues too)."""
    backend = get_backend(mode)
    if not backend.manual:
        pytest.skip("no manual sync; covered by the step round-trip")
    comm = _comm(mode, compress, pack)
    backend.validate(comm)
    grads = _grad_tree()
    mesh = make_mesh((1,), ("data",))

    def body(g):
        r = tac.sync_grads(g, comm, data_axis=("data",))
        return backend.gathered_grads(r, g)

    out = jax.jit(compat.shard_map(body, mesh=mesh, in_specs=(P(),),
                                   out_specs=P()))(grads)
    assert jax.tree.structure(out) == jax.tree.structure(grads)
    for a, b in zip(jax.tree.leaves(out), jax.tree.leaves(grads)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   **TOL[compress])


@pytest.mark.parametrize("mode,compress", REJECT_CASES)
def test_unsupported_codec_rejected(mode, compress):
    """A codec the strategy cannot honor must raise at validate() —
    silently ignoring compression is a conformance failure."""
    comm = _comm(mode, compress)
    with pytest.raises(ValueError, match="compress"):
        get_backend(mode).validate(comm)


# ---------------------------------------------------------------------------
# Step-level round-trip + gspmd parity (one cached 2-step run per case)
# ---------------------------------------------------------------------------


@functools.lru_cache(maxsize=None)
def _two_step(mode, compress):
    """(final_state, abstract_specs, [loss1, loss2]) for a jitted 2-step
    run of the given mode on a 1-device mesh."""
    cfg = get_config("qwen2-0.5b-reduced")
    run = RunConfig(model=cfg, shape=ShapeConfig("t", "train", 16, 4),
                    comm=_comm(mode, compress, slice_bytes=16 * 1024))
    mesh = make_mesh((1,), ("data",))
    with compat.set_mesh(mesh):
        step_fn, state_sh, _ = steps_mod.make_train_step(run, mesh)
        if get_backend(mode).manual:
            sds = steps_mod.abstract_tac_state(run, 1)
            state = steps_mod.init_tac_state(jax.random.PRNGKey(0), run, 1)
        else:
            sds = steps_mod.abstract_train_state(run)
            state = steps_mod.init_train_state(jax.random.PRNGKey(0), run)
        batch = {"tokens": jnp.ones((4, 16), jnp.int32),
                 "labels": jnp.ones((4, 16), jnp.int32)}
        jf = jax.jit(step_fn)
        losses = []
        for _ in range(2):
            state, m = jf(state, batch)
            losses.append(float(m["loss"]))
    return state, sds, losses


@pytest.mark.parametrize("mode,compress", STEP_CASES)
def test_state_roundtrip(mode, compress):
    """The state a step RETURNS matches the state_specs layout the
    backend DECLARED — structure, shape, and dtype, leaf for leaf (error
    feedback included when the codec carries one)."""
    state, sds, losses = _two_step(mode, compress)
    assert jax.tree.structure(state) == jax.tree.structure(sds)
    paths_out = jax.tree_util.tree_flatten_with_path(state)[0]
    paths_sds = jax.tree_util.tree_flatten_with_path(sds)[0]
    for (pa, a), (pb, b) in zip(paths_out, paths_sds):
        assert pa == pb
        assert tuple(a.shape) == tuple(b.shape), (pa, a.shape, b.shape)
        assert a.dtype == b.dtype, (pa, a.dtype, b.dtype)
    assert all(np.isfinite(l) for l in losses), losses
    if get_backend(mode).needs_ef(CommConfig(mode=mode, compress=compress,
                                             hierarchical=False)):
        assert state.ef is not None
    else:
        assert state.ef is None


@pytest.mark.parametrize("mode", [m for m in available_modes()
                                  if get_backend(m).manual])
def test_gspmd_parity(mode):
    """Two-step loss trajectory equals the gspmd reference (transparency:
    the synchronization strategy must not change the math)."""
    _, _, ref = _two_step("gspmd", "none")
    _, _, got = _two_step(mode, "none")
    np.testing.assert_allclose(got, ref, rtol=0, atol=2e-3)


# ---------------------------------------------------------------------------
# Bucket independence (jaxpr-level): each bucket's collective depends
# only on its own leaves (+ its own per-bucket EF residual)
# ---------------------------------------------------------------------------


def _collective_deps(mode, compress, pack):
    """Trace the backend's sync inside the shard_map and return
    (plan, [(primitive_name, dep_label_set)]) for every collective eqn.
    Labels: ('leaf', i) for gradient leaf i, ('ef', b) for bucket b's
    residual."""
    comm = _comm(mode, compress, pack, channels=64, slice_bytes=1024,
                 ring_capacity_bytes=1 << 20)
    grads = _grad_tree()
    leaves, treedef = jax.tree.flatten(grads)
    backend = get_backend(mode)
    plan = ho.make_bucket_plan(grads, comm) if mode == "hadronio_overlap" \
        else hors.rs_bucket_plan(grads, comm, 1)
    n_ef = plan.n_buckets if compress != "none" else 0
    mesh = make_mesh((1,), ("data",))

    def body(*args):
        g = jax.tree.unflatten(treedef, list(args[:len(leaves)]))
        efs = tuple(args[len(leaves):]) or None
        ctx = SyncContext.resolve(comm, ("data",), None, efs)
        r = backend.sync(g, ctx)
        outs = jax.tree.leaves(r.grads) if r.grads is not None \
            else [r.flat_shard]
        return tuple(outs)

    args = leaves + [jnp.zeros((p,), jnp.float32) for p in plan.padded[:n_ef]]
    n_out = len(leaves) if mode == "hadronio_overlap" else 1
    f = compat.shard_map(body, mesh=mesh, in_specs=(P(),) * len(args),
                         out_specs=(P(),) * n_out)
    jaxpr = jax.make_jaxpr(f)(*args)

    inner = None
    for eqn in jaxpr.jaxpr.eqns:
        if eqn.primitive.name == "shard_map":
            inner = eqn.params["jaxpr"]
            break
    assert inner is not None, "no shard_map eqn found"

    Literal = jax.core.Literal
    deps = {}
    for i, v in enumerate(inner.invars):
        deps[v] = frozenset([("leaf", i) if i < len(leaves)
                             else ("ef", i - len(leaves))])
    for v in inner.constvars:
        deps[v] = frozenset()

    def var_deps(a):
        return frozenset() if isinstance(a, Literal) \
            else deps.get(a, frozenset())

    collectives = []
    for eqn in inner.eqns:
        d = frozenset().union(*[var_deps(a) for a in eqn.invars]) \
            if eqn.invars else frozenset()
        name = eqn.primitive.name
        if any(k in name for k in ("psum", "all_gather", "all_to_all",
                                   "ppermute", "reduce_scatter")):
            collectives.append((name, d))
        for ov in eqn.outvars:
            deps[ov] = d
    return plan, collectives


# ---------------------------------------------------------------------------
# Channel-level gathering-write aggregation (comm.aggregate="channel"):
# bit-identical numerics, fewer wire flushes
# ---------------------------------------------------------------------------

HADRONIO_FAMILY = tuple(m for m in available_modes()
                        if m.startswith("hadronio"))
AGG_CASES = [(m, c, p)
             for m in HADRONIO_FAMILY
             for c in SUPPORTED_COMPRESS[m]
             for p in PACKS]


def _sync_outputs(mode, comm, grads):
    """(leaves-or-flat-shard tuple, ef leaves tuple) of one jitted sync,
    plus the emitted StableHLO collective stats."""
    from repro.launch import hlo_analysis as hlo
    backend = get_backend(mode)

    def body(g):
        r = tac.sync_grads(g, comm, data_axis=("data",))
        outs = tuple(jax.tree.leaves(r.grads)) if r.grads is not None \
            else (r.flat_shard,)
        efs = tuple(jax.tree.leaves(r.ef)) if r.ef is not None else ()
        return outs + efs

    mesh = make_mesh((1,), ("data",))
    f = jax.jit(compat.shard_map(body, mesh=mesh, in_specs=(P(),),
                                 out_specs=P()))
    stats = hlo.stablehlo_collective_stats(f.lower(grads).as_text())
    return f(grads), stats


@pytest.mark.parametrize("mode,compress,pack", AGG_CASES)
def test_aggregate_channel_parity(mode, compress, pack):
    """aggregate="channel" (one coalesced wire flush per connection) is
    BIT-identical to the per-slice schedule — synced grads for the tree
    modes, the flat-shard ordering for the ZeRO-1 modes, and the
    error-feedback residuals — with fewer channels than slices/buckets so
    coalescing genuinely merges buffers."""
    grads = _grad_tree()
    outs = {}
    for aggregate in ("slice", "channel"):
        comm = _comm(mode, compress, pack, channels=2, slice_bytes=1024,
                     ring_capacity_bytes=1 << 20, aggregate=aggregate)
        outs[aggregate], _ = _sync_outputs(mode, comm, grads)
    assert len(outs["slice"]) == len(outs["channel"])
    for a, b in zip(outs["slice"], outs["channel"]):
        assert a.dtype == b.dtype
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@pytest.mark.parametrize("mode", HADRONIO_FAMILY)
def test_aggregate_collective_count_drops_to_channel_count(mode):
    """The gathering-write payoff, read off the emitted StableHLO
    (launch/hlo_analysis): per exchange, the per-slice schedule emits one
    collective per slice/bucket; aggregate="channel" emits exactly
    min(channels, n_items) — one coalesced flush per connection."""
    grads = _grad_tree()
    n_channels = 2       # < n_buckets (3) and < n_slices (7) at 1 KiB
    counts = {}
    for aggregate in ("slice", "channel"):
        comm = _comm(mode, "none", "jnp", channels=n_channels,
                     slice_bytes=1024, ring_capacity_bytes=1 << 20,
                     aggregate=aggregate)
        _, stats = _sync_outputs(mode, comm, grads)
        counts[aggregate] = stats.total_ops
    if mode in BUCKET_MODES:
        plan = ho.make_bucket_plan(grads, _comm(mode, slice_bytes=1024)) \
            if mode == "hadronio_overlap" \
            else hors.rs_bucket_plan(grads, _comm(mode, slice_bytes=1024), 1)
        n_items = plan.n_buckets
    else:
        from repro.core import aggregation as agg
        n_items = agg.make_plan(_grad_tree(),
                                _comm(mode, slice_bytes=1024)).n_slices
    assert n_items > n_channels, (n_items, n_channels)
    assert counts["slice"] == n_items, counts
    assert counts["channel"] == n_channels, counts


def test_channel_flush_preserves_scatter_layout(np_rng):
    """The reduce-scatter flush interleave: peer p's contiguous 1/group
    chunk of the coalesced buffer equals the concatenation of p's
    per-slice chunks — the property that keeps the ZeRO-1 flat-shard
    ordering identical across aggregate granularities."""
    from repro.core.backends import pipeline
    group = 4
    sizes = [512, 1024, 512]
    flats = [jnp.asarray(np_rng.normal(size=(s,)), jnp.float32)
             for s in sizes]
    buf = np.asarray(pipeline.interleave_for_scatter(flats, group))
    assert buf.shape == (sum(sizes),)
    c = buf.shape[0] // group
    for p in range(group):
        expect = np.concatenate(
            [np.asarray(f)[p * (len(f) // group):(p + 1) * (len(f) // group)]
             for f in flats])
        np.testing.assert_array_equal(buf[p * c:(p + 1) * c], expect)
    # single-buffer flush needs no interleave (identity)
    np.testing.assert_array_equal(
        np.asarray(pipeline.interleave_for_scatter(flats[:1], group)),
        np.asarray(flats[0]))


# ---------------------------------------------------------------------------
# Flush-when-ready channel schedule (comm.flush="ready",
# core/flush_scheduler): bit-identical numerics, overlap recovered under
# aggregate="channel" with fewer channels than buckets
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("mode,compress,pack", AGG_CASES)
def test_flush_ready_parity(mode, compress, pack):
    """flush="ready" (contiguous production-order groups, each flushed
    the moment its last bucket is staged) is BIT-identical to the
    flush="step" barrier loop at BOTH aggregate granularities, for every
    hadronio-family mode, codec and pack impl — synced grads, ZeRO-1
    flat-shard ordering and EF residuals. The schedule moves the same
    bytes; only the emission structure may differ."""
    grads = _grad_tree()
    for aggregate in ("slice", "channel"):
        outs = {}
        for flush in ("step", "ready"):
            comm = _comm(mode, compress, pack, channels=2,
                         slice_bytes=1024, ring_capacity_bytes=1 << 20,
                         aggregate=aggregate, flush=flush)
            outs[flush], _ = _sync_outputs(mode, comm, grads)
        assert len(outs["step"]) == len(outs["ready"])
        for a, b in zip(outs["step"], outs["ready"]):
            assert a.dtype == b.dtype
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def _sync_trace(mode, flush):
    """Inner-jaxpr eqn list of one backend.sync under
    aggregate="channel" with channels < n_buckets, plus the bucket plan
    and the per-eqn transitive gradient-leaf dependency sets."""
    comm = _comm(mode, "none", PACKS[0], channels=2, slice_bytes=1024,
                 ring_capacity_bytes=1 << 20, aggregate="channel",
                 flush=flush)
    grads = _grad_tree()
    leaves, treedef = jax.tree.flatten(grads)
    backend = get_backend(mode)
    plan = ho.make_bucket_plan(grads, comm) if mode == "hadronio_overlap" \
        else hors.rs_bucket_plan(grads, comm, 1)
    mesh = make_mesh((1,), ("data",))

    def body(*args):
        g = jax.tree.unflatten(treedef, list(args))
        ctx = SyncContext.resolve(comm, ("data",), None)
        r = backend.sync(g, ctx)
        outs = jax.tree.leaves(r.grads) if r.grads is not None \
            else [r.flat_shard]
        return tuple(outs)

    n_out = len(leaves) if not backend.zero1 else 1
    f = compat.shard_map(body, mesh=mesh, in_specs=(P(),) * len(leaves),
                         out_specs=(P(),) * n_out)
    jaxpr = jax.make_jaxpr(f)(*leaves)
    inner = next(e for e in jaxpr.jaxpr.eqns
                 if e.primitive.name == "shard_map").params["jaxpr"]

    Literal = jax.core.Literal
    deps = {v: frozenset([i]) for i, v in enumerate(inner.invars)}
    for v in inner.constvars:
        deps[v] = frozenset()
    eqn_deps = []
    for eqn in inner.eqns:
        d = frozenset().union(
            *[deps.get(a, frozenset()) for a in eqn.invars
              if not isinstance(a, Literal)]) if eqn.invars else frozenset()
        eqn_deps.append((eqn.primitive.name, d))
        for ov in eqn.outvars:
            deps[ov] = d
    return plan, eqn_deps


def _is_collective(name: str) -> bool:
    return any(k in name for k in ("psum", "all_gather", "all_to_all",
                                   "ppermute", "reduce_scatter"))


@pytest.mark.parametrize("mode", BUCKET_MODES)
def test_flush_ready_recovers_channel_overlap(mode):
    """The tentpole acceptance, on the real sync dataflow: under
    aggregate="channel" with channels < n_buckets, flush="ready" makes
    the FIRST-emitted channel collective (a) appear in the jaxpr BEFORE
    any op that reads the last bucket's leaves — the flush goes out
    mid-exchange, before the later buckets are even packed — and (b)
    depend ONLY on the first contiguous run of production-order buckets,
    so the latency-hiding scheduler may start it while the remaining
    backward compute runs. flush="step" structurally forfeits both: every
    flush follows every pack, and round-robin puts a late bucket on the
    channel that carries bucket 0."""
    for flush in ("step", "ready"):
        plan, eqn_deps = _sync_trace(mode, flush)
        assert plan.n_buckets >= 3
        last_leaves = set(plan.buckets[-1])
        colls = [(i, d) for i, (n, d) in enumerate(eqn_deps)
                 if _is_collective(n)]
        assert colls, "sync emitted no collectives"
        first_coll_idx, first_coll_deps = colls[0]
        reads_last = [i for i, (n, d) in enumerate(eqn_deps)
                      if set(d) & last_leaves and not _is_collective(n)]
        if flush == "ready":
            # (a) emitted before the FIRST op that touches the last
            # bucket's leaves (its pack hasn't even been traced yet)
            assert first_coll_idx < min(reads_last), \
                (first_coll_idx, min(reads_last))
            # (b) depends exactly on the first-produced bucket(s), never
            # on the last bucket
            assert set(first_coll_deps) == set(plan.buckets[0])
            assert not set(first_coll_deps) & last_leaves
        else:
            # the barrier loop: the first flush comes after the last
            # bucket's pack started
            assert first_coll_idx > min(reads_last), \
                (first_coll_idx, min(reads_last))
            # round-robin: bucket 0's channel also waits on the last
            # bucket (n_buckets=3, channels=2 -> channel 0 = {0, 2})
            with_b0 = [d for _, d in colls
                       if set(plan.buckets[0]) <= set(d)]
            assert with_b0 and any(set(d) & last_leaves for d in with_b0)


def test_flush_ready_first_flush_precedes_final_bucket_grad():
    """The mid-backward emission property, stated positionally: drive
    the staged emission API (pipeline.begin_emission / stage_slices /
    finish_emission) with bucket "gradients" produced by a sequential
    chain (g_b = tanh(g_{b-1}) — the backward-pass analogue: bucket b's
    grads exist only after bucket b-1's), staging each one the moment it
    is produced. Under flush="ready" the traced program emits the first
    channel's collective BEFORE the eqn computing the LAST bucket's
    gradient; under flush="step" every collective comes after it."""
    from repro.core.backends import pipeline
    n_buckets, n_channels, elems = 6, 2, 512
    mesh = make_mesh((1,), ("data",))

    def positions(flush):
        comm = _comm("hadronio_overlap", channels=n_channels,
                     aggregate="channel", flush=flush)

        def body(x):
            ctx = SyncContext.resolve(comm, ("data",), None)
            st = pipeline.begin_emission(ctx, n_buckets, "all_reduce",
                                         unpack=True)
            g = x
            for b in range(n_buckets):
                g = jnp.tanh(g)            # bucket b's gradient
                pipeline.stage_slices(st, b, g[None])
            outs = pipeline.finish_emission(st)
            return jnp.stack([o.reshape(-1) for o in outs])

        f = compat.shard_map(body, mesh=mesh, in_specs=(P(),),
                             out_specs=P())
        jaxpr = jax.make_jaxpr(f)(jnp.ones((elems,), jnp.float32))
        inner = next(e for e in jaxpr.jaxpr.eqns
                     if e.primitive.name == "shard_map").params["jaxpr"]
        names = [e.primitive.name for e in inner.eqns]
        first_coll = min(i for i, n in enumerate(names) if "psum" in n)
        last_grad = max(i for i, n in enumerate(names) if n == "tanh")
        return first_coll, last_grad

    first_ready, last_grad_ready = positions("ready")
    assert first_ready < last_grad_ready, \
        (first_ready, last_grad_ready)
    first_step, last_grad_step = positions("step")
    assert first_step > last_grad_step, (first_step, last_grad_step)


# ---------------------------------------------------------------------------
# Serving conformance (the event-loop serving subsystem): identical
# logits per comm mode × channel affinity × event-loop count, plus jaxpr
# evidence that serving collectives flow through the staged emission API.
# Parametrized straight from available_modes(), so a newly registered
# backend is serving-conformance-tested without edits here.
# ---------------------------------------------------------------------------


def _serve_model():
    return _serve_model_cached()


@functools.lru_cache(maxsize=None)
def _serve_model_cached():
    cfg = get_config("qwen2-0.5b-reduced")
    from repro.models import api
    params = api.init(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _serve_comm(mode, **kw):
    kw.setdefault("channels", 4)
    kw.setdefault("slice_bytes", 512)     # logit payload -> several slices
    return _comm(mode, "none", PACKS[0], **kw)


@functools.lru_cache(maxsize=None)
def _serve_logits(mode, affinity):
    """(prefill logits, one-step decode logits) of the dispatch-built
    serve step for (mode, channel affinity), on fixed inputs."""
    from repro.models import api
    from repro.serving import dispatch as serve_dispatch
    cfg, params = _serve_model()
    step = serve_dispatch.make_serve_step(cfg, _serve_comm(mode),
                                          channel_indices=affinity)
    toks = np.zeros((2, 8), np.int32)
    toks[0, :6] = (np.arange(6) * 3) % cfg.vocab_size
    toks[1, :8] = (np.arange(8) * 5) % cfg.vocab_size
    batch = {"tokens": jnp.asarray(toks),
             "last_pos": jnp.asarray([5, 7])}
    logits_p, cache = step.prefill(params, batch)
    cache = api.grow_cache(cfg, cache, 32)
    dec = {"token": jnp.argmax(logits_p, -1).astype(jnp.int32),
           "pos": jnp.asarray([6, 8], jnp.int32)}
    logits_d, _ = step.decode(params, cache, dec)
    return np.asarray(logits_p), np.asarray(logits_d)


@pytest.mark.parametrize("mode", available_modes())
def test_serving_logits_identical_across_modes(mode):
    """The serving transparency claim: every registered strategy's wire
    path (raw whole-payload collectives for gspmd/sockets/vma, the staged
    slice pipeline for the hadronio family) yields BIT-identical prefill
    and decode logits — summing per element and gathering peer-major
    commute with slicing."""
    ref_p, ref_d = _serve_logits("gspmd", None)
    got_p, got_d = _serve_logits(mode, None)
    np.testing.assert_array_equal(got_p, ref_p)
    np.testing.assert_array_equal(got_d, ref_d)


@pytest.mark.parametrize("affinity", [(0, 1), (2, 3), (1,)])
def test_serving_logits_invariant_to_channel_affinity(affinity):
    """Channel affinity (which disjoint run of the pool an event loop
    emits on) changes the emission structure, never the logits — the
    dispatch-level statement of event-loop-count invariance."""
    ref_p, ref_d = _serve_logits("hadronio", None)
    got_p, got_d = _serve_logits("hadronio", affinity)
    np.testing.assert_array_equal(got_p, ref_p)
    np.testing.assert_array_equal(got_d, ref_d)


def test_serving_tokens_identical_across_event_loops():
    """The subsystem-level acceptance row: greedy tokens are identical
    for event_loops ∈ {1, 2, 4} (with continuous admission in play:
    more requests than slots per loop at el=1)."""
    from repro.configs.base import ServeConfig
    from repro.serving import Request, make_engine_group
    cfg, params = _serve_model()
    rng = np.random.default_rng(11)
    reqs = [Request(i, rng.integers(0, cfg.vocab_size,
                                    size=int(rng.integers(4, 16))),
                    max_new=3) for i in range(6)]
    outs = {}
    for el in (1, 2, 4):
        serve = ServeConfig(event_loops=el, poll="busy", max_batch=2,
                            max_len=48, comm=_serve_comm("hadronio"))
        grp = make_engine_group(cfg, params, serve)
        grp.submit(reqs)
        res = sorted(grp.run(threads=False), key=lambda r: r.uid)
        outs[el] = [tuple(r.tokens.tolist()) for r in res]
    assert outs[1] == outs[2] == outs[4]


@pytest.mark.parametrize("mode", HADRONIO_FAMILY)
def test_serving_collectives_flow_through_staged_emission(mode):
    """Jaxpr-level evidence: the serve decode's logit reduction is the
    staged emission API's schedule — one collective per ring slice under
    aggregate="slice", exactly min(channels, n_slices) coalesced flushes
    under "channel" — while sockets emits ONE unsliced op and gspmd
    none (1-device local reference)."""
    from repro.launch import hlo_analysis as hlo
    from repro.serving import dispatch as serve_dispatch
    cfg, _ = _serve_model()
    n_channels = 2
    counts = {}
    for aggregate in ("slice", "channel"):
        comm = _serve_comm(mode, channels=n_channels, aggregate=aggregate)
        text = serve_dispatch.lowered_decode_text(cfg, comm, batch=2,
                                                  max_len=32)
        counts[aggregate] = hlo.stablehlo_collective_stats(text).total_ops
    n_slices = serve_dispatch.logit_payload_slices(
        cfg, 2, _serve_comm(mode, channels=n_channels))
    assert n_slices > n_channels, (n_slices, n_channels)
    assert counts["slice"] == n_slices, counts
    assert counts["channel"] == n_channels, counts
    # baselines: per-buffer (1 op) and XLA-owned (0 ops on 1 device)
    sockets = serve_dispatch.lowered_decode_text(
        cfg, _serve_comm("sockets"), batch=2, max_len=32)
    assert hlo.stablehlo_collective_stats(sockets).total_ops == 1
    local = serve_dispatch.lowered_decode_text(
        cfg, _serve_comm("gspmd"), batch=2, max_len=32)
    assert hlo.stablehlo_collective_stats(local).total_ops == 0


@pytest.mark.parametrize("mode", available_modes())
def test_serving_rejects_wire_compression(mode):
    """Serving payloads are activations — a lossy codec has no EF state
    to stay unbiased against, so the dispatch layer must reject it for
    EVERY mode (never silently ignore it)."""
    from repro.serving import dispatch as serve_dispatch
    with pytest.raises(ValueError, match="compress"):
        serve_dispatch.validate_serve_comm(
            CommConfig(mode=mode, compress="bf16", hierarchical=False))


@pytest.mark.parametrize("mode", BUCKET_MODES)
@pytest.mark.parametrize("compress", COMPRESS)
@pytest.mark.parametrize("pack", PACKS)
def test_bucket_collectives_depend_only_on_own_leaves(mode, compress, pack):
    """The overlap property, stated on the dataflow graph itself: with
    enough channels, every collective's transitive input set is exactly
    one bucket's leaves (plus that bucket's own EF residual) — so the
    latency-hiding scheduler may start it as soon as those leaves exist,
    in BOTH the all-reduce and the reduce-scatter (ZeRO-1) modes, with
    and without wire compression, for both pack implementations."""
    plan, collectives = _collective_deps(mode, compress, pack)
    assert plan.n_buckets >= 3          # the fixture really is multi-bucket
    assert any(len(b) == 1 for b in plan.buckets)   # oversized-leaf bucket
    assert collectives, "sync emitted no collectives"
    buckets_hit = set()
    for name, d in collectives:
        leaf_deps = {i for kind, i in d if kind == "leaf"}
        ef_deps = {b for kind, b in d if kind == "ef"}
        owners = [b for b in range(plan.n_buckets)
                  if leaf_deps == set(plan.buckets[b])]
        assert len(owners) == 1, \
            (f"{name}: leaf deps {sorted(leaf_deps)} are not exactly one "
             f"bucket of {plan.buckets}")
        assert ef_deps <= {owners[0]}, \
            (f"{name}: bucket {owners[0]} collective reads EF of "
             f"buckets {sorted(ef_deps)}")
        buckets_hit.add(owners[0])
    assert buckets_hit == set(range(plan.n_buckets))


# ---------------------------------------------------------------------------
# Model-family serving conformance (docs/FAMILIES.md §The support matrix).
# FAMILY_ARCH is indexed with EVERY family in the arch registry at
# collection time (KeyError => a family shipped without a serving
# conformance row) — the SUPPORTED_COMPRESS pattern applied to model
# families. Each matrix row below is the named test a FAMILIES.md row
# points at.
# ---------------------------------------------------------------------------

from repro.configs.registry import ARCH_IDS  # noqa: E402

FAMILY_ARCH = {
    "dense": "qwen2-0.5b-reduced",
    "moe": "mixtral-8x7b-reduced",
    "ssm": "rwkv6-7b-reduced",
    "hybrid": "recurrentgemma-9b-reduced",
    "encdec": "whisper-tiny-reduced",
    "vlm": "llava-next-mistral-7b-reduced",
}
REGISTERED_FAMILIES = sorted({get_config(a).family for a in ARCH_IDS})
FAMILY_CASES = [(f, FAMILY_ARCH[f])            # KeyError => no coverage
                for f in REGISTERED_FAMILIES]


def test_family_matrix_covers_registry_exactly():
    """No registered family without a serving row, no stale rows."""
    assert set(FAMILY_ARCH) == set(REGISTERED_FAMILIES)


def test_every_family_declares_a_cache_layout():
    """The gathering write is family-agnostic BECAUSE every family
    declares its decode-state batch layout (the cache-layout contract,
    docs/FAMILIES.md); an undeclared family must fail at build time
    with an error naming the missing declaration."""
    from repro.serving import cache_layout
    for fam in REGISTERED_FAMILIES:
        assert cache_layout.layout_for(fam) is not None
    with pytest.raises(ValueError, match="declares no cache layout"):
        cache_layout.layout_for("made-up-family")


@functools.lru_cache(maxsize=None)
def _family_model(family):
    from repro.models import api
    cfg = get_config(FAMILY_ARCH[family])
    params = api.init(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _family_batch(cfg, b=2, s=8):
    rng = np.random.default_rng(0)
    batch = {"tokens": jnp.asarray(
        rng.integers(0, cfg.vocab_size, size=(b, s)), jnp.int32)}
    if cfg.family not in ("ssm", "hybrid"):
        batch["last_pos"] = jnp.asarray([s - 3, s - 1], jnp.int32)
    if cfg.family == "vlm":
        batch["patches"] = jnp.zeros((b, cfg.num_patches, cfg.d_model),
                                     jnp.dtype(cfg.compute_dtype))
    if cfg.family == "encdec":
        batch["frames"] = jnp.zeros((b, cfg.num_frames, cfg.d_model),
                                    jnp.dtype(cfg.compute_dtype))
    return batch


@functools.lru_cache(maxsize=None)
def _family_outputs(family, mode):
    """(prefill logits, grown-cache leaves, one-step decode logits) of
    the dispatch-built serve step for (family, mode) on fixed inputs."""
    from repro.models import api
    from repro.serving import dispatch as serve_dispatch
    cfg, params = _family_model(family)
    step = serve_dispatch.make_serve_step(cfg, _serve_comm(mode))
    batch = _family_batch(cfg)
    lg, cache = step.prefill(params, batch)
    cache = api.grow_cache(cfg, cache, 16)
    tok = jnp.argmax(lg, -1).astype(jnp.int32)
    pos = (jnp.asarray([6, 8], jnp.int32) if "last_pos" in batch
           else jnp.asarray(8, jnp.int32))
    dl, _ = step.decode(params, cache, {"token": tok, "pos": pos})
    return (np.asarray(lg),
            tuple(np.asarray(l) for l in jax.tree.leaves(cache)),
            np.asarray(dl))


@pytest.mark.parametrize("family", [f for f, _ in FAMILY_CASES])
@pytest.mark.parametrize("mode", HADRONIO_FAMILY)
def test_family_serving_bitwise_vs_solo(family, mode):
    """docs/FAMILIES.md matrix row: EVERY registered family's sharded
    prefill (per-family cache layout through the one gathering write),
    decode-state and one-step decode logits are BIT-identical between
    the pure-local gspmd reference and the mode's wire path — the
    transparency claim, per family, per hadronio-family mode."""
    ref = _family_outputs(family, "gspmd")
    got = _family_outputs(family, mode)
    np.testing.assert_array_equal(got[0], ref[0])
    assert len(got[1]) == len(ref[1])
    for a, b in zip(got[1], ref[1]):
        np.testing.assert_array_equal(a, b)
    np.testing.assert_array_equal(got[2], ref[2])


def test_moe_expert_exchange_flows_through_staged_alltoall():
    """docs/FAMILIES.md MoE row evidence: expert-parallel
    dispatch/combine is the staged emission API's all_to_all kind — the
    serve step's channels NOTE all_to_all at trace time (the chaos
    hook), and the traced decode jaxpr carries the all_to_all
    primitive. At >1 device the lowered module keeps stablehlo
    all-to-all ops (a size-1 exchange folds away locally, which is the
    point: same program, the wire appears with the ring)."""
    from repro.core import channels
    from repro.models import api
    from repro.serving import dispatch as serve_dispatch
    cfg, params = _family_model("moe")
    comm = _serve_comm("hadronio", slice_bytes=768)   # un-memoized step
    kinds = []
    channels.set_collective_hook(lambda idx, kind: kinds.append(kind))
    try:
        step = serve_dispatch.make_serve_step(cfg, comm)
        batch = _family_batch(cfg)
        lg, cache = step.prefill(params, batch)
    finally:
        channels.clear_collective_hook()
    assert "all_to_all" in kinds, kinds
    cache = api.grow_cache(cfg, cache, 16)
    dec = {"token": jnp.argmax(lg, -1).astype(jnp.int32),
           "pos": jnp.asarray([6, 8], jnp.int32)}
    txt = str(jax.make_jaxpr(step.decode)(params, cache, dec))
    assert "all_to_all" in txt
    if jax.device_count() > 1:
        from repro.launch import hlo_analysis as hlo
        low = serve_dispatch.lowered_decode_text(cfg, comm)
        st = hlo.stablehlo_collective_stats(low)
        assert st.counts.get("all-to-all", 0) > 0, st.counts
