"""The two-level serving fabric, tier-1 side: everything about the
topology machinery that is decidable WITHOUT a multi-device mesh —
config validation, the mesh factory, leader-lane carving, topology-aware
affinity, the leader flush plan, pod-aligned grouping, and the
replica-group evidence parser — plus a structural (1, 1) pod-mesh
lowering proving the leader emission path traces on one device.

The numeric flat-vs-hierarchical conformance needs real ring peers:
``tests/distributed/check_topology.py`` runs it at 8 devices under
``tests/test_system.py``, and the ``REPRO_CONFORMANCE_TOPO=pod`` CI leg
re-runs a 4-device slice in-process here (``tests/conftest.py`` forces
the host device count for that leg only).
"""
import os
import types

import numpy as np
import pytest

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro import compat
from repro.configs.base import CommConfig, ServeConfig
from repro.core.backends import pipeline
from repro.core.backends.base import SyncContext
from repro.core.flush_scheduler import make_leader_plan
from repro.core.selector import pod_aligned_groups, ready_groups
from repro.launch import hlo_analysis as hlo
from repro.launch.mesh import make_mesh, make_serve_mesh
from repro.serving.event_loop import channel_affinity

POD_LEG = (os.environ.get("REPRO_CONFORMANCE_TOPO") == "pod"
           and jax.device_count() >= 4)
pod_leg = pytest.mark.skipif(
    not POD_LEG,
    reason="pod conformance leg: set REPRO_CONFORMANCE_TOPO=pod "
           "(tests/conftest.py then forces 4 host devices)")


# -- config validation -------------------------------------------------


def test_serve_config_rejects_bad_pod_topology():
    with pytest.raises(ValueError, match="pods must be >= 1"):
        ServeConfig(pods=0)
    with pytest.raises(ValueError, match="pod_axis must be a non-empty"):
        ServeConfig(pod_axis="")
    with pytest.raises(ValueError, match="leader_loops"):
        ServeConfig(event_loops=2, leader_loops=3,
                    comm=CommConfig(channels=4))
    # carving every lane for cross-pod traffic leaves no local lane
    with pytest.raises(ValueError, match="no local lane"):
        ServeConfig(pods=2, comm=CommConfig(channels=2, leader_channels=2,
                                            hierarchical=True))
    # every loop must own at least one LOCAL channel
    with pytest.raises(ValueError, match="LOCAL channels"):
        ServeConfig(pods=2, event_loops=3,
                    comm=CommConfig(channels=4, leader_channels=2,
                                    hierarchical=True))
    # the same shape is fine when the emission stays flat
    ServeConfig(pods=2, event_loops=3,
                comm=CommConfig(channels=4, leader_channels=2,
                                hierarchical=False))


def test_comm_config_rejects_nonpositive_leader_channels():
    with pytest.raises(ValueError, match="leader_channels must be >= 1"):
        CommConfig(leader_channels=0)


def test_make_serve_mesh_shapes_and_validation():
    n = jax.device_count()
    flat = make_serve_mesh(1)
    assert tuple(flat.axis_names) == ("data",)
    assert flat.shape["data"] == n
    with pytest.raises(ValueError, match="pods must be >= 1"):
        make_serve_mesh(0)
    with pytest.raises(ValueError, match="divisors"):
        make_serve_mesh(n + 1)
    if n % 2 == 0:
        two = make_serve_mesh(2)
        assert tuple(two.axis_names) == ("pod", "data")
        assert two.shape["pod"] == 2 and two.shape["data"] == n // 2


# -- leader-lane carving (pipeline) ------------------------------------


def _ctx(channels, leader_channels, aggregate="channel", pod="pod"):
    return types.SimpleNamespace(
        pod_axis=pod,
        comm=CommConfig(channels=channels, leader_channels=leader_channels,
                        aggregate=aggregate))


def test_leader_emission_predicate():
    assert pipeline.leader_emission(_ctx(4, 1), 4)
    assert not pipeline.leader_emission(_ctx(4, 1, pod=None), 4)
    assert not pipeline.leader_emission(_ctx(4, 1, aggregate="slice"), 4)
    assert not pipeline.leader_emission(_ctx(4, 1), 1)  # nothing to carve


def test_leader_split_carves_the_pool_tail():
    assert pipeline._leader_split(_ctx(4, 1), (0, 1, 2, 3)) \
        == ((0, 1, 2), (3,))
    assert pipeline._leader_split(_ctx(4, 2), (0, 1, 2, 3)) \
        == ((0, 1), (2, 3))
    # leader_channels >= channels clamps to channels - 1
    assert pipeline._leader_split(_ctx(4, 9), (0, 1, 2, 3)) \
        == ((0,), (1, 2, 3))


def test_leader_split_never_leaves_a_side_empty():
    # an affinity slice owning no tail lane promotes its last local
    assert pipeline._leader_split(_ctx(4, 1), (0, 1)) == ((0,), (1,))
    # an affinity slice owning ONLY tail lanes keeps one as local
    assert pipeline._leader_split(_ctx(4, 2), (2, 3)) == ((2,), (3,))


# -- topology-aware affinity and the leader flush plan -----------------


def _assert_partition(groups, ids):
    flat = [c for g in groups for c in g]
    assert sorted(flat) == sorted(ids)
    assert len(flat) == len(set(flat))
    assert all(g for g in groups)


@pytest.mark.parametrize("n_loops,leader_loops", [(1, 1), (2, 1), (2, 2),
                                                  (4, 2)])
def test_channel_affinity_topology(n_loops, leader_loops):
    n_channels, leaders, n_pods = 6, 2, 2
    groups = channel_affinity(n_channels, n_loops, n_pods=n_pods,
                              leaders=leaders, leader_loops=leader_loops)
    _assert_partition(groups, range(n_channels))
    n_local = n_channels - leaders
    lead_ids = set(range(n_local, n_channels))
    owners = [i for i, g in enumerate(groups) if lead_ids & set(g)]
    assert owners == list(range(min(leader_loops, leaders)))
    # every loop owns at least one LOCAL lane; with loops >= pods its
    # locals never straddle a pod block, with fewer loops each owns
    # whole consecutive blocks (still pod-aligned, never a partial mix)
    blocks = ready_groups(n_local, n_pods)
    block_of = {c: b for b, g in enumerate(blocks) for c in g}
    for g in groups:
        locals_ = [c for c in g if c < n_local]
        assert locals_
        owned = {block_of[c] for c in locals_}
        if n_loops >= n_pods:
            assert len(owned) == 1
        else:
            assert all(c in locals_ for b in owned for c in blocks[b])


def test_channel_affinity_topology_errors():
    with pytest.raises(ValueError, match="LOCAL channel"):
        channel_affinity(4, 4, n_pods=2, leaders=1)
    with pytest.raises(ValueError, match="leader_loops"):
        channel_affinity(6, 2, n_pods=2, leaders=2, leader_loops=3)
    # leaders=0 keeps the original contiguous form
    assert channel_affinity(4, 2) == ((0, 1), (2, 3))


def test_make_leader_plan_contiguous_and_triggered():
    for n_local, n_leaders in [(4, 1), (4, 2), (5, 2), (3, 7)]:
        plan = make_leader_plan(n_local, n_leaders, "ready")
        _assert_partition(plan.groups, range(n_local))
        for l, g in enumerate(plan.groups):
            assert list(g) == list(range(min(g), max(g) + 1))
            assert plan.triggers[l] == max(g)
            assert all(plan.assign[c] == l for c in g)
        assert len(plan.groups) == min(n_leaders, n_local)


@pytest.mark.parametrize("n_slices,n_groups,n_blocks",
                         [(8, 4, 2), (8, 2, 4), (6, 3, 2), (5, 5, 2),
                          (7, 2, 3)])
def test_pod_aligned_groups_partition(n_slices, n_groups, n_blocks):
    groups = pod_aligned_groups(n_slices, n_groups, n_blocks)
    _assert_partition(groups, range(n_slices))
    blocks = ready_groups(n_slices, min(n_blocks, n_slices))
    block_of = {c: b for b, g in enumerate(blocks) for c in g}
    for g in groups:
        assert list(g) == list(range(min(g), max(g) + 1))  # contiguous
        if len(groups) >= len(blocks):
            assert len({block_of[c] for c in g}) == 1      # no straddle


# -- replica-group evidence parser -------------------------------------

_SYNTH = """\
module @decode {
  %0 = "stablehlo.all_reduce"(%a) {replica_groups = dense<[[0, 1], [2, 3]]> : tensor<2x2xi64>} : (tensor<8xf32>) -> tensor<8xf32>
  %1 = "stablehlo.all_gather"(%b) {replica_groups = dense<[[0, 2]]> : tensor<1x2xi64>} : (tensor<4xf32>) -> tensor<8xf32>
  %2 = "stablehlo.reduce_scatter"(%c) {replica_groups = dense<0> : tensor<1x1xi64>} : (tensor<8xf32>) -> tensor<8xf32>
  %3 = "stablehlo.all_reduce"(%d) {replica_groups = dense<[[0, 1, 2, 3]]> : tensor<1x4xi64>} : (tensor<8xf32>) -> tensor<8xf32>
  %4 = stablehlo.add %0, %1 : tensor<8xf32>
}
"""


def test_parse_replica_groups_forms():
    assert hlo.parse_replica_groups("stablehlo.add %0, %1") is None
    assert hlo.parse_replica_groups(
        "replica_groups = dense<0> : tensor<1x1xi64>") == [[0]]
    assert hlo.parse_replica_groups(
        "replica_groups = dense<[[0, 1], [2, 3]]> : tensor<2x2xi64>") \
        == [[0, 1], [2, 3]]


def test_cross_pod_collective_count_classification():
    cp = hlo.cross_pod_collective_count(_SYNTH, in_pod_size=2)
    # [[0,1],[2,3]] and the splat group stay in-pod at in_pod_size=2;
    # [[0,2]] and [[0,1,2,3]] straddle the pod boundary
    assert cp["in_pod"] == {"all-reduce": 1, "reduce-scatter": 1}
    assert cp["cross_pod"] == {"all-gather": 1, "all-reduce": 1}
    assert cp["in_pod_total"] == 2 and cp["cross_pod_total"] == 2
    # at in_pod_size=1 every multi-member group is cross-pod
    assert hlo.cross_pod_collective_count(
        _SYNTH, in_pod_size=1)["cross_pod_total"] == 3
    # at in_pod_size=4 everything collapses into one pod
    assert hlo.cross_pod_collective_count(
        _SYNTH, in_pod_size=4)["cross_pod_total"] == 0


# -- structural: the leader path traces on a (1, 1) pod mesh -----------


def test_leader_emission_traces_on_degenerate_pod_mesh():
    mesh = make_mesh((1, 1), ("pod", "data"))
    comm = CommConfig(mode="hadronio", channels=2, aggregate="channel",
                      flush="ready", hierarchical=True, leader_channels=1)
    ctx = SyncContext.resolve(comm, ("data",), "pod")
    assert ctx.pod_axis == "pod"
    assert pipeline.leader_emission(ctx, 2)

    def body(x):
        return pipeline.emit_flat(x.reshape(-1), ctx, "all_reduce")

    f = jax.jit(compat.shard_map(body, mesh=mesh,
                                 in_specs=P(("pod", "data")),
                                 out_specs=P(), check_vma=False))
    x = jnp.arange(1 * 37, dtype=jnp.float32).reshape(1, 37) * 0.5
    np.testing.assert_array_equal(np.asarray(f(x)), np.asarray(x[0]))


def test_serve_step_reports_pod_topology_facts():
    from repro.configs.registry import get_config
    from repro.serving import dispatch

    mesh = make_mesh((1, 1), ("pod", "data"))
    cfg = get_config("qwen2-0.5b-reduced")
    comm = CommConfig(mode="hadronio", channels=2, aggregate="channel",
                      hierarchical=True)
    step = dispatch.make_serve_step(cfg, comm, mesh)
    assert step.pod_axis == "pod" and step.n_pods == 1
    # flat emission on the same mesh keeps the pod axis out of the wire
    flat = dispatch.make_serve_step(
        cfg, CommConfig(mode="hadronio", channels=2, hierarchical=False),
        mesh)
    assert flat.pod_axis is None and flat.n_pods == 1
    with pytest.raises(ValueError, match="not a mesh axis"):
        dispatch.make_serve_step(cfg, comm, mesh, pod_axis="rack")
    with pytest.raises(ValueError, match="in-pod data axis"):
        dispatch.make_serve_step(cfg, comm, make_mesh((1,), ("pod",)))


# -- pod conformance leg (REPRO_CONFORMANCE_TOPO=pod, 4 devices) -------


@pod_leg
def test_psum_hierarchical_parity_pod():
    from functools import partial
    from repro.core.hierarchical import psum_hierarchical

    mesh = make_serve_mesh(2)
    axes = tuple(mesh.axis_names)
    for s in (16, 1003):                  # divisible and padded edges
        x = jnp.asarray(np.linspace(0.0, 1.0, 4 * s, dtype=np.float32)
                        .reshape(4, s))

        @jax.jit
        @partial(compat.shard_map, mesh=mesh, in_specs=P(axes),
                 out_specs=P(), check_vma=False)
        def hier(v):
            return psum_hierarchical(v.reshape(-1), "pod", "data")

        @jax.jit
        @partial(compat.shard_map, mesh=mesh, in_specs=P(axes),
                 out_specs=P(), check_vma=False)
        def flat(v):
            return jax.lax.psum(v.reshape(-1), axes)

        np.testing.assert_allclose(np.asarray(hier(x)),
                                   np.asarray(flat(x)), rtol=1e-5)


@pod_leg
@pytest.mark.parametrize("mode", ["hadronio", "hadronio_overlap",
                                  "hadronio_overlap_rs"])
def test_serve_dispatch_conformance_pod(mode):
    """Flat vs hierarchical emission on the (2, 2) fabric: prefill
    logits bitwise (gathers only move data), decode logits allclose with
    equal argmax (the two-level all-reduce re-associates)."""
    from repro.configs.registry import get_config
    from repro.models import api
    from repro.serving import dispatch

    mesh = make_serve_mesh(2)
    cfg = get_config("qwen2-0.5b-reduced")
    params = api.init(jax.random.PRNGKey(0), cfg)
    toks = np.zeros((4, 6), np.int32)
    lens = np.array([4, 5, 6, 3], np.int32)
    for r in range(4):
        toks[r, :lens[r]] = (np.arange(lens[r]) * (r + 3)) % cfg.vocab_size
    batch = {"tokens": jnp.asarray(toks), "last_pos": jnp.asarray(lens - 1)}

    def logits(hier):
        comm = CommConfig(mode=mode, slice_bytes=512, channels=4,
                          aggregate="channel", flush="ready",
                          hierarchical=hier, leader_channels=1)
        step = dispatch.make_serve_step(cfg, comm, mesh)
        lp, cache = step.prefill(params, batch)
        cache = api.grow_cache(cfg, cache, 24)
        dec = {"token": jnp.argmax(lp, -1).astype(jnp.int32),
               "pos": jnp.asarray(lens, jnp.int32)}
        ld, _ = step.decode(params, cache, dec)
        return np.asarray(lp), np.asarray(ld)

    hier_p, hier_d = logits(True)
    flat_p, flat_d = logits(False)
    np.testing.assert_array_equal(hier_p, flat_p)
    np.testing.assert_allclose(hier_d, flat_d, rtol=1e-4, atol=1e-5)
    np.testing.assert_array_equal(hier_d.argmax(-1), flat_d.argmax(-1))


@pod_leg
@pytest.mark.parametrize("el", [1, 2])
def test_served_tokens_conformance_pod(el):
    from repro.configs.registry import get_config
    from repro.models import api
    from repro.serving import Request, make_engine_group

    cfg = get_config("qwen2-0.5b-reduced")
    params = api.init(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(11)
    reqs = [Request(i, rng.integers(0, cfg.vocab_size,
                                    size=int(rng.integers(4, 12))),
                    max_new=2) for i in range(3)]

    def tokens(hier):
        serve = ServeConfig(
            event_loops=el, poll="busy", max_batch=2, max_len=24, pods=2,
            comm=CommConfig(mode="hadronio_overlap", slice_bytes=512,
                            channels=4, aggregate="channel", flush="ready",
                            hierarchical=hier, leader_channels=1))
        grp = make_engine_group(cfg, params, serve)
        grp.submit(reqs)
        return [tuple(r.tokens.tolist())
                for r in sorted(grp.run(threads=False),
                                key=lambda r: r.uid)]

    assert tokens(True) == tokens(False)


@pod_leg
def test_cross_pod_collective_evidence_pod():
    from repro.configs.registry import get_config
    from repro.serving import dispatch

    mesh = make_serve_mesh(2)
    cfg = get_config("qwen2-0.5b-reduced")
    for leader_channels, hier, want in [(1, True, 1), (2, True, 2),
                                        (1, False, 4)]:
        comm = CommConfig(mode="hadronio_overlap", slice_bytes=512,
                          channels=4, aggregate="channel", flush="ready",
                          hierarchical=hier,
                          leader_channels=leader_channels)
        cp = hlo.cross_pod_collective_count(
            dispatch.lowered_decode_text(cfg, comm, batch=4, mesh=mesh), 2)
        assert cp["cross_pod_total"] == want, (leader_channels, hier, cp)
