"""The assigned architecture table, verified field by field (the brief's
numbers are normative — a typo here silently invalidates every cell)."""
import pytest

from repro.configs.registry import ARCH_IDS, get_config
from repro.configs.base import SHAPES, cell_skip_reason, cells_for, reduced

# (arch, layers, d_model, heads, kv, d_ff, vocab, family)
ASSIGNED = {
    "qwen1.5-4b": (40, 2560, 20, 20, 6912, 151936, "dense"),
    "starcoder2-3b": (30, 3072, 24, 2, 12288, 49152, "dense"),
    "qwen2-0.5b": (24, 896, 14, 2, 4864, 151936, "dense"),
    "qwen1.5-110b": (80, 8192, 64, 8, 49152, 152064, "dense"),
    "whisper-tiny": (4, 384, 6, 6, 1536, 51865, "encdec"),
    "dbrx-132b": (40, 6144, 48, 8, 10752, 100352, "moe"),
    "mixtral-8x7b": (32, 4096, 32, 8, 14336, 32000, "moe"),
    "llava-next-mistral-7b": (32, 4096, 32, 8, 14336, 32000, "vlm"),
    "rwkv6-7b": (32, 4096, 0, 0, 14336, 65536, "ssm"),
    "recurrentgemma-9b": (38, 4096, 16, 1, 12288, 256000, "hybrid"),
}


def test_all_archs_present():
    assert set(ARCH_IDS) == set(ASSIGNED)


@pytest.mark.parametrize("arch", sorted(ASSIGNED))
def test_assigned_numbers(arch):
    L, d, h, kv, ff, v, fam = ASSIGNED[arch]
    cfg = get_config(arch)
    assert cfg.num_layers == L
    assert cfg.d_model == d
    assert cfg.num_heads == h
    assert cfg.num_kv_heads == kv
    assert cfg.d_ff == ff
    assert cfg.vocab_size == v
    assert cfg.family == fam


def test_arch_features():
    assert get_config("qwen1.5-4b").qkv_bias
    assert get_config("qwen2-0.5b").qkv_bias
    assert get_config("qwen1.5-110b").qkv_bias
    assert not get_config("mixtral-8x7b").qkv_bias
    mx = get_config("mixtral-8x7b")
    assert mx.moe.num_experts == 8 and mx.moe.top_k == 2
    assert mx.sliding_window == 4096
    db = get_config("dbrx-132b")
    assert db.moe.num_experts == 16 and db.moe.top_k == 4
    rg = get_config("recurrentgemma-9b")
    assert rg.block_pattern == ("rglru", "rglru", "local_attn")
    wt = get_config("whisper-tiny")
    assert wt.encoder_layers == 4


def test_param_counts_plausible():
    # analytic N within 25% of the nameplate for the honestly-named archs
    expect = {"qwen1.5-110b": 110e9, "dbrx-132b": 132e9,
              "mixtral-8x7b": 46.7e9, "rwkv6-7b": 7e9,
              "recurrentgemma-9b": 9e9, "starcoder2-3b": 3e9}
    for arch, n in expect.items():
        got = get_config(arch).param_count()
        assert abs(got - n) / n < 0.3, (arch, got, n)
    mx = get_config("mixtral-8x7b")
    assert mx.active_param_count() < 0.35 * mx.param_count() + 4e9


def test_cells_and_skips():
    total = 0
    skipped = []
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        for s in SHAPES.values():
            total += 1
            if cell_skip_reason(cfg, s):
                skipped.append((arch, s.name))
    assert total == 40
    # long_500k skips exactly the pure-full-attention archs
    assert set(skipped) == {
        (a, "long_500k") for a in
        ("qwen1.5-4b", "starcoder2-3b", "qwen2-0.5b", "qwen1.5-110b",
         "whisper-tiny", "dbrx-132b", "llava-next-mistral-7b")}


@pytest.mark.parametrize("arch", sorted(ASSIGNED))
def test_reduced_is_small(arch):
    r = get_config(arch + "-reduced")
    assert r.param_count() < 5e6
    assert r.family == get_config(arch).family
