"""Chaos + SLO benchmark — seeded fault scenarios against the serving
plane, reported as recovery rows and RTT-inflation percentiles.

Ibdxnet's failure catalogue (arXiv:1812.01963) meets the JIB benchmark
methodology (arXiv:1910.02245): for every (scenario x comm mode x
event-loop count) cell the harness runs ONE fault-free baseline and one
seeded fault run (``serving/chaos.py``), then reports

* ``recovered:<scenario>:el<N>`` — 1.0 iff the served greedy tokens are
  BIT-identical to the fault-free run (the hard SLO),
* ``injected:<scenario>:el<N>`` — how many planned faults actually
  fired (replay evidence: same --seed, same counts),
* ``p999_inflation:<scenario>:el<N>`` — fault p99.9 RTT over baseline
  p99.9 (the soft SLO; wall-clock, so CI asserts a generous bound),
* per-scenario RTT percentile rows (p50/p99/p99.9, the JIB shape).

``--supervised`` adds, per cell, a run under the self-healing
:class:`~repro.serving.supervisor.Supervisor` (``run_supervised`` —
two scenarios escalate so there is a REAL failure to heal):

* ``recovered_sup:<scenario>:el<N>`` / ``injected_sup:...`` — the same
  hard SLO, but the supervisor's own detect/heal loop does the
  recovering,
* ``healing:<scenario>:el<N>`` — healing actions in the supervisor's
  seed-deterministic trace (>= 1 everywhere: the evidence the
  supervisor healed, not the harness),
* ``mttr:<scenario>:el<N>`` — mean detect→heal span in us (wall-clock;
  generous bound in CI),
* ``p999_inflation_sup:...`` — supervised-vs-unsupervised comparison:
  both cells divide by the SAME fault-free baseline.

The model is a deliberately tiny dense config: chaos cost is dominated
by serve-step (re)compiles, and the recovery invariant is model-size
independent — faults act on emission structure, host waits and the
admission path, never on a logit.

  PYTHONPATH=src python -m benchmarks.serving_chaos --smoke --seed 5 \
      --json BENCH_chaos.json
"""
from __future__ import annotations

import jax

from benchmarks.common import Row, percentile_rows
from repro.configs.base import ModelConfig
from repro.serving import chaos

MODES = ("hadronio", "hadronio_rs", "hadronio_overlap",
         "hadronio_overlap_rs")
SMOKE_MODES = ("hadronio", "hadronio_overlap")
LOOPS = (1, 2, 4)
SMOKE_LOOPS = (1, 2)
CHANNELS = 4


def _tiny_model():
    cfg = ModelConfig(name="chaos-tiny", family="dense", num_layers=1,
                      d_model=16, num_heads=2, num_kv_heads=2, d_ff=32,
                      vocab_size=64, head_dim=8, param_dtype="float32",
                      compute_dtype="float32")
    from repro.models import api
    return cfg, api.init(jax.random.PRNGKey(0), cfg)


def run(*, modes=MODES, loops=LOOPS, scenarios=chaos.SCENARIOS,
        seed: int = 0, smoke: bool = False,
        supervised: bool = False) -> list:
    if smoke:
        modes = SMOKE_MODES
        loops = SMOKE_LOOPS
    from repro.launch.mesh import make_mesh
    n = len(jax.devices())
    mesh = make_mesh((n,), ("data",)) if n > 1 else None
    cfg, params = _tiny_model()
    reqs = chaos.make_requests(4, vocab_size=cfg.vocab_size,
                               seed=1234 + seed)
    rows = []
    for mode in modes:
        for el in loops:
            serve = chaos.chaos_serve_config(mode, el, channels=CHANNELS)
            chaos.run_baseline(cfg, params, serve, reqs, mesh=mesh)
            # second, warm run: baseline RTTs must not be dominated by
            # the serve-step compile the fault runs then get for free
            base = chaos.run_baseline(cfg, params, serve, reqs, mesh=mesh)
            for scenario in scenarios:
                res = chaos.run_scenario(scenario, cfg, params, serve,
                                         reqs, seed=seed, baseline=base,
                                         mesh=mesh)
                sfx = f"{scenario}:el{el}"
                rep = res.report
                rows.append(Row("serving_chaos", "chaos-slo", mode, 0,
                                CHANNELS, f"recovered:{sfx}",
                                1.0 if rep.recovered else 0.0, "bool",
                                "measured"))
                rows.append(Row("serving_chaos", "chaos-slo", mode, 0,
                                CHANNELS, f"injected:{sfx}",
                                rep.n_injected, "count", "derived"))
                infl = rep.p999_inflation
                if infl is not None:
                    rows.append(Row("serving_chaos", "chaos-slo", mode, 0,
                                    CHANNELS, f"p999_inflation:{sfx}",
                                    infl, "ratio", "measured"))
                rows.extend(percentile_rows(
                    "serving_chaos", "chaos-slo", mode, 0, CHANNELS,
                    res.rtts, suffix=sfx))
                if not supervised:
                    continue
                sup = chaos.run_supervised(scenario, cfg, params, serve,
                                           reqs, seed=seed,
                                           baseline=base, mesh=mesh)
                srep = sup.report
                rows.append(Row("serving_chaos", "chaos-slo", mode, 0,
                                CHANNELS, f"recovered_sup:{sfx}",
                                1.0 if srep.recovered else 0.0, "bool",
                                "measured"))
                rows.append(Row("serving_chaos", "chaos-slo", mode, 0,
                                CHANNELS, f"injected_sup:{sfx}",
                                srep.n_injected, "count", "derived"))
                rows.append(Row("serving_chaos", "chaos-slo", mode, 0,
                                CHANNELS, f"healing:{sfx}",
                                srep.healing_actions, "count",
                                "measured"))
                if srep.mttr_s is not None:
                    rows.append(Row("serving_chaos", "chaos-slo", mode,
                                    0, CHANNELS, f"mttr:{sfx}",
                                    srep.mttr_s * 1e6, "us", "measured"))
                sinfl = srep.p999_inflation
                if sinfl is not None:
                    rows.append(Row("serving_chaos", "chaos-slo", mode,
                                    0, CHANNELS,
                                    f"p999_inflation_sup:{sfx}",
                                    sinfl, "ratio", "measured"))
    return rows


def main() -> int:
    import argparse

    from benchmarks import common
    from benchmarks.common import write_json, write_rows

    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--smoke", action="store_true",
                   help="CI sweep: 2 modes x {1,2} loops, all scenarios")
    p.add_argument("--seed", type=int, default=0,
                   help="drives every injection plan AND is recorded in "
                        "each row's seed column — same seed, same trace")
    p.add_argument("--supervised", action="store_true",
                   help="per cell, also run under the self-healing "
                        "Supervisor: recovered_sup/healing/mttr rows")
    p.add_argument("--csv", default="")
    p.add_argument("--json", default="")
    args = p.parse_args()
    common.set_run_seed(args.seed)
    rows = run(seed=args.seed, smoke=args.smoke,
               supervised=args.supervised)
    text = write_rows(rows, args.csv or None)
    if args.json:
        write_json(rows, args.json)
    print(text)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
