"""Figures 3/5/7 — round-trip latency vs connection count.

Paper setup: ping-pong between two nodes, one thread per connection,
message sizes 16 B / 1 KiB / 64 KiB, connections 1..16.

TPU reading: one "connection" = one independent ppermute channel on the
ring (a message to the neighbour and back = one RTT). ``channels``
independent ping-pongs are issued in a single XLA program; the measured
time per round trip shows how channel count degrades latency per channel
(the paper's Fig. 3 scaling axis). Derived numbers report the per-op
collective schedule from the compiled HLO.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from benchmarks.common import (Row, block, derived_collective_time,
                               percentile_rows, slice_view, timeit,
                               timeit_samples)
from repro import compat
from repro.configs.base import CommConfig
from repro.core.backends import pipeline
from repro.core.backends.base import SyncContext
from repro.launch import hlo_analysis as hlo
from repro.launch.mesh import make_mesh

MSG_SIZES = [16, 1024, 64 * 1024]
CHANNELS = [1, 2, 4, 8, 16]
SLICE_SIZES = [16 * 1024, 64 * 1024, 256 * 1024, 1024 * 1024]


def _pingpong_fn(mesh, n_channels: int, msg_elems: int, n_dev: int):
    perm_fwd = [(i, (i + 1) % n_dev) for i in range(n_dev)]
    perm_bwd = [(i, (i - 1) % n_dev) for i in range(n_dev)]

    def body(*xs):
        outs = []
        for x in xs:        # independent channels — no data deps
            y = jax.lax.ppermute(x, "data", perm_fwd)
            z = jax.lax.ppermute(y, "data", perm_bwd)
            outs.append(z)
        return tuple(outs)

    f = compat.shard_map(body, mesh=mesh,
                      in_specs=tuple([P("data", None)] * n_channels),
                      out_specs=tuple([P("data", None)] * n_channels),
                      check_vma=False)
    return jax.jit(f)


FLUSHES = ("step", "ready")
AGGREGATES = ("slice", "channel")


def recommend_channels(rtt_by_channels: dict[int, float], msg_size: int,
                       mode: str = "hadronio") -> tuple[int, list[Row]]:
    """Pick the channel count maximizing aggregate round-trip throughput
    from measured (channels -> RTT seconds) points — the paper's Fig. 3
    trade-off: more connections overlap more, but degrade per-channel
    latency. Returns (best, rows) with one ``recommended_channels`` CSV
    row plus the derived per-point throughputs. ``mode`` labels the rows
    (sweeps over the overlap modes stay distinguishable in the CSV)."""
    rows, best, best_tput = [], None, -1.0
    for ch, t in sorted(rtt_by_channels.items()):
        tput = ch * msg_size / max(t, 1e-12)
        rows.append(Row("latency", "autotune", mode, msg_size, ch,
                        "sweep_throughput", tput / 1e6, "MB/s", "derived"))
        if tput > best_tput:
            best_tput, best = tput, ch
    rows.append(Row("latency", "autotune", mode, msg_size, best,
                    "recommended_channels", best, "channels", "derived"))
    return best, rows


def autotune_channels(mesh=None, *, msg_size: int = 64 * 1024,
                      channels=CHANNELS, iters: int = 10,
                      mode: str = "hadronio", joint: bool = False):
    """Channel-count autotune (ROADMAP item): sweep ``comm.channels``
    over the ping-pong microbenchmark ON THIS MESH and pick a per-mesh
    default. Returns ``(best_channels, rows)``; feed ``best_channels``
    into ``CommConfig(channels=...)``. ``run()`` derives the same
    recommendation from its own sweep without re-measuring. ``mode`` is
    the row label only (the ping-pong primitive is mode-agnostic).

    ``joint=True`` recommends over the JOINT ``flush`` × ``aggregate`` ×
    ``channels`` space instead, driving the LIVE wire pipeline
    (:func:`autotune_flush_schedule`): the aggregation-vs-latency
    trade-off the benchmark paper shows must be tunable is three-axis
    once the flush schedule exists, so the channel count is only
    meaningful per (flush, aggregate) point. Returns
    ``((flush, aggregate, channels), rows)``."""
    if joint:
        return autotune_flush_schedule(mesh, payload_bytes=8 * msg_size,
                                       channels=channels, iters=iters,
                                       mode=mode)
    if mesh is None:
        n = len(jax.devices())
        mesh = make_mesh((n,), ("data",))
    n_dev = mesh.shape["data"]
    elems = max(1, msg_size // 4)
    rows, rtts = [], {}
    for ch in channels:
        xs = tuple(jnp.zeros((n_dev, elems), jnp.float32) + i
                   for i in range(ch))
        fn = _pingpong_fn(mesh, ch, elems, n_dev)
        t = timeit(lambda: block(fn(*xs)), warmup=1, iters=iters)
        rtts[ch] = t
        rows.append(Row("latency", "autotune", mode, msg_size, ch,
                        "sweep_rtt", t * 1e6, "us", "measured"))
    best, rec_rows = recommend_channels(rtts, msg_size, mode)
    return best, rows + rec_rows


# ---------------------------------------------------------------------------
# Slice-size autotune (the ROADMAP's open bucket-granularity sweep)
# ---------------------------------------------------------------------------


def _slice_exchange_fn(mesh, comm: CommConfig, payload_elems: int):
    """One jitted gradient exchange of ``payload_elems`` f32 through the
    LIVE wire pipeline (pack stage -> channel schedule at the configured
    aggregate granularity -> unpack stage)."""

    def body(x):
        ctx = SyncContext.resolve(comm, ("data",), None)
        sl, _ = slice_view(x, comm)
        red, _ = pipeline.reduce_slices(sl, ctx)
        return red.reshape(-1)[:payload_elems]

    f = compat.shard_map(body, mesh=mesh, in_specs=(P(),), out_specs=P(),
                         check_vma=False)
    return jax.jit(f)


def recommend_slice_bytes(goodput_by_size: dict[int, float],
                          mode: str = "hadronio",
                          channels: int = 4) -> tuple[int, list[Row]]:
    """Pick the slice granularity maximizing goodput from already-measured
    (slice_bytes -> bytes/s) points — no re-measurement. Returns (best,
    rows) with the per-mesh ``recommended_slice_bytes`` default row, the
    granularity analogue of ``recommend_channels``."""
    best = max(sorted(goodput_by_size), key=goodput_by_size.get)
    row = Row("latency", "autotune", mode, best, channels,
              "recommended_slice_bytes", best, "bytes", "derived")
    return best, [row]


def autotune_slice_bytes(mesh=None, *, payload_bytes: int = 4 * 1024 * 1024,
                         slice_sizes=SLICE_SIZES, channels: int = 4,
                         aggregate: str = "slice", mode: str = "hadronio",
                         iters: int = 10):
    """Slice/bucket-granularity autotune (ROADMAP follow-up: the channel
    sweep existed, the ``comm.slice_bytes`` sweep did not): exchange a
    fixed payload through the live wire pipeline once per candidate
    granularity ON THIS MESH, and pick the slice size maximizing goodput
    — the paper's §V-B trade-off (small slices pay per-send overhead,
    huge slices forfeit overlap). Returns ``(best_slice_bytes, rows)``;
    feed the result into ``CommConfig(slice_bytes=...)``. The
    ``recommended_slice_bytes`` row is derived from the sweep without
    re-measuring; ``aggregate`` selects the flush granularity under test
    and ``mode`` labels the rows."""
    if mesh is None:
        n = len(jax.devices())
        mesh = make_mesh((n,), ("data",))
    payload_elems = max(1, payload_bytes // 4)
    rows, goodput = [], {}
    for sb in slice_sizes:
        comm = CommConfig(mode=mode, slice_bytes=sb, channels=channels,
                          aggregate=aggregate, hierarchical=False,
                          ring_capacity_bytes=max(64 * sb,
                                                  2 * payload_bytes))
        fn = _slice_exchange_fn(mesh, comm, payload_elems)
        x = jnp.ones((payload_elems,), jnp.float32)
        t = timeit(lambda: block(fn(x)), warmup=1, iters=iters)
        goodput[sb] = payload_bytes / max(t, 1e-12)
        rows.append(Row("latency", "autotune", mode, sb, channels,
                        "sweep_slice_goodput", goodput[sb] / 1e6, "MB/s",
                        "measured"))
    best, rec_rows = recommend_slice_bytes(goodput, mode, channels)
    return best, rows + rec_rows


# ---------------------------------------------------------------------------
# Joint flush-schedule autotune (flush x aggregate x channels — the
# three-axis coalescing trade-off once the flush-when-ready schedule
# exists)
# ---------------------------------------------------------------------------


def recommend_flush_schedule(goodput_by_combo: dict,
                             payload_bytes: int,
                             mode: str = "hadronio") -> tuple:
    """Pick the (flush, aggregate, channels) combo maximizing goodput
    from already-measured points. The recommended-default row encodes
    the combo in its metric name (CSV stays one-value-per-row):
    ``recommended_flush_schedule:<flush>:<aggregate>`` with the channel
    count as the value."""
    best = max(sorted(goodput_by_combo), key=goodput_by_combo.get)
    flush, aggregate, ch = best
    row = Row("latency", "autotune", mode, payload_bytes, ch,
              f"recommended_flush_schedule:{flush}:{aggregate}", ch,
              "channels", "derived")
    return best, [row]


def autotune_flush_schedule(mesh=None, *,
                            payload_bytes: int = 512 * 1024,
                            slice_bytes: int = 32 * 1024,
                            channels=(1, 2, 4), flushes=FLUSHES,
                            aggregates=AGGREGATES, iters: int = 10,
                            mode: str = "hadronio"):
    """The joint sweep the flush axis makes necessary: exchange a fixed
    payload through the LIVE wire pipeline once per (flush, aggregate,
    channels) combo ON THIS MESH — the paper's aggregation-vs-latency
    trade-off (§V-B) plus the readiness schedule from
    ``core/flush_scheduler`` — and recommend the best combo. Returns
    ``((flush, aggregate, channels), rows)``; each measured row's metric
    is ``sweep_flush_goodput:<flush>:<aggregate>``."""
    if mesh is None:
        n = len(jax.devices())
        mesh = make_mesh((n,), ("data",))
    payload_elems = max(1, payload_bytes // 4)
    rows, goodput = [], {}
    for flush in flushes:
        for aggregate in aggregates:
            for ch in channels:
                comm = CommConfig(
                    mode=mode, slice_bytes=slice_bytes, channels=ch,
                    aggregate=aggregate, flush=flush, hierarchical=False,
                    ring_capacity_bytes=max(64 * slice_bytes,
                                            2 * payload_bytes))
                fn = _slice_exchange_fn(mesh, comm, payload_elems)
                x = jnp.ones((payload_elems,), jnp.float32)
                t = timeit(lambda: block(fn(x)), warmup=1, iters=iters)
                goodput[(flush, aggregate, ch)] = \
                    payload_bytes / max(t, 1e-12)
                rows.append(Row(
                    "latency", "autotune", mode, payload_bytes, ch,
                    f"sweep_flush_goodput:{flush}:{aggregate}",
                    goodput[(flush, aggregate, ch)] / 1e6, "MB/s",
                    "measured"))
    best, rec_rows = recommend_flush_schedule(goodput, payload_bytes, mode)
    return best, rows + rec_rows


def run(mesh=None, *, msg_sizes=MSG_SIZES, channels=CHANNELS,
        iters: int = 10, quick: bool = False):
    if mesh is None:
        n = len(jax.devices())
        mesh = make_mesh((n,), ("data",))
    n_dev = mesh.shape["data"]
    rows = []
    rtts_at_max = {}
    for msg in msg_sizes:
        elems = max(1, msg // 4)
        for ch in channels:
            xs = tuple(jnp.zeros((n_dev, elems), jnp.float32) + i
                       for i in range(ch))
            fn = _pingpong_fn(mesh, ch, elems, n_dev)
            lowered = fn.lower(*([jax.ShapeDtypeStruct((n_dev, elems),
                                                       jnp.float32)] * ch))
            stats = hlo.stablehlo_collective_stats(lowered.as_text())
            samples = timeit_samples(lambda: block(fn(*xs)), iters=iters)
            t = float(np.median(samples))
            if msg == max(msg_sizes):
                rtts_at_max[ch] = t
            rtt_us = t * 1e6
            rows.append(Row("latency", "fig3/5/7", "hadronio", msg, ch,
                            "rtt", rtt_us, "us", "measured"))
            # the hhu-benchmark percentile view of the same sample stream
            rows.extend(percentile_rows("latency", "fig3/5/7", "hadronio",
                                        msg, ch, samples))
            rows.append(Row("latency", "fig3/5/7", "hadronio", msg, ch,
                            "emitted_collective_ops", stats.total_ops,
                            "ops", "derived"))
            rows.append(Row("latency", "fig3/5/7", "hadronio", msg, ch,
                            "rtt_v5e_model",
                            derived_collective_time(stats) * 1e6 / ch,
                            "us", "derived"))
    # per-mesh recommended comm.channels default (ROADMAP autotune item)
    # derived from the sweep just measured — no re-measurement
    _, rec_rows = recommend_channels(rtts_at_max, max(msg_sizes))
    rows.extend(rec_rows)
    # per-mesh recommended comm.slice_bytes default (the granularity sweep)
    sb_kw = dict(payload_bytes=256 * 1024,
                 slice_sizes=(16 * 1024, 64 * 1024)) if quick else {}
    _, sb_rows = autotune_slice_bytes(mesh, iters=max(1, iters // 2),
                                      **sb_kw)
    rows.extend(sb_rows)
    # joint flush x aggregate x channels sweep + recommended combo (the
    # flush-when-ready schedule makes coalescing a three-axis trade-off)
    fl_kw = dict(payload_bytes=128 * 1024, channels=(1, 2)) if quick \
        else {}
    _, fl_rows = autotune_flush_schedule(mesh, iters=max(1, iters // 2),
                                         **fl_kw)
    rows.extend(fl_rows)
    return rows
