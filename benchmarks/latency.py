"""Figures 3/5/7 — round-trip latency vs connection count.

Paper setup: ping-pong between two nodes, one thread per connection,
message sizes 16 B / 1 KiB / 64 KiB, connections 1..16.

TPU reading: one "connection" = one independent ppermute channel on the
ring (a message to the neighbour and back = one RTT). ``channels``
independent ping-pongs are issued in a single XLA program; the measured
time per round trip shows how channel count degrades latency per channel
(the paper's Fig. 3 scaling axis). Derived numbers report the per-op
collective schedule from the compiled HLO.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from benchmarks.common import Row, block, derived_collective_time, timeit
from repro import compat
from repro.launch import hlo_analysis as hlo
from repro.launch.mesh import make_mesh

MSG_SIZES = [16, 1024, 64 * 1024]
CHANNELS = [1, 2, 4, 8, 16]


def _pingpong_fn(mesh, n_channels: int, msg_elems: int, n_dev: int):
    perm_fwd = [(i, (i + 1) % n_dev) for i in range(n_dev)]
    perm_bwd = [(i, (i - 1) % n_dev) for i in range(n_dev)]

    def body(*xs):
        outs = []
        for x in xs:        # independent channels — no data deps
            y = jax.lax.ppermute(x, "data", perm_fwd)
            z = jax.lax.ppermute(y, "data", perm_bwd)
            outs.append(z)
        return tuple(outs)

    f = compat.shard_map(body, mesh=mesh,
                      in_specs=tuple([P("data", None)] * n_channels),
                      out_specs=tuple([P("data", None)] * n_channels),
                      check_vma=False)
    return jax.jit(f)


def recommend_channels(rtt_by_channels: dict[int, float],
                       msg_size: int) -> tuple[int, list[Row]]:
    """Pick the channel count maximizing aggregate round-trip throughput
    from measured (channels -> RTT seconds) points — the paper's Fig. 3
    trade-off: more connections overlap more, but degrade per-channel
    latency. Returns (best, rows) with one ``recommended_channels`` CSV
    row plus the derived per-point throughputs."""
    rows, best, best_tput = [], None, -1.0
    for ch, t in sorted(rtt_by_channels.items()):
        tput = ch * msg_size / max(t, 1e-12)
        rows.append(Row("latency", "autotune", "hadronio", msg_size, ch,
                        "sweep_throughput", tput / 1e6, "MB/s", "derived"))
        if tput > best_tput:
            best_tput, best = tput, ch
    rows.append(Row("latency", "autotune", "hadronio", msg_size, best,
                    "recommended_channels", best, "channels", "derived"))
    return best, rows


def autotune_channels(mesh=None, *, msg_size: int = 64 * 1024,
                      channels=CHANNELS, iters: int = 10):
    """Channel-count autotune (ROADMAP item): sweep ``comm.channels``
    over the ping-pong microbenchmark ON THIS MESH and pick a per-mesh
    default. Returns ``(best_channels, rows)``; feed ``best_channels``
    into ``CommConfig(channels=...)``. ``run()`` derives the same
    recommendation from its own sweep without re-measuring."""
    if mesh is None:
        n = len(jax.devices())
        mesh = make_mesh((n,), ("data",))
    n_dev = mesh.shape["data"]
    elems = max(1, msg_size // 4)
    rows, rtts = [], {}
    for ch in channels:
        xs = tuple(jnp.zeros((n_dev, elems), jnp.float32) + i
                   for i in range(ch))
        fn = _pingpong_fn(mesh, ch, elems, n_dev)
        t = timeit(lambda: block(fn(*xs)), warmup=1, iters=iters)
        rtts[ch] = t
        rows.append(Row("latency", "autotune", "hadronio", msg_size, ch,
                        "sweep_rtt", t * 1e6, "us", "measured"))
    best, rec_rows = recommend_channels(rtts, msg_size)
    return best, rows + rec_rows


def run(mesh=None, *, msg_sizes=MSG_SIZES, channels=CHANNELS,
        iters: int = 10):
    if mesh is None:
        n = len(jax.devices())
        mesh = make_mesh((n,), ("data",))
    n_dev = mesh.shape["data"]
    rows = []
    rtts_at_max = {}
    for msg in msg_sizes:
        elems = max(1, msg // 4)
        for ch in channels:
            xs = tuple(jnp.zeros((n_dev, elems), jnp.float32) + i
                       for i in range(ch))
            fn = _pingpong_fn(mesh, ch, elems, n_dev)
            lowered = fn.lower(*([jax.ShapeDtypeStruct((n_dev, elems),
                                                       jnp.float32)] * ch))
            stats = hlo.stablehlo_collective_stats(lowered.as_text())
            t = timeit(lambda: block(fn(*xs)), iters=iters)
            if msg == max(msg_sizes):
                rtts_at_max[ch] = t
            rtt_us = t * 1e6
            rows.append(Row("latency", "fig3/5/7", "hadronio", msg, ch,
                            "rtt", rtt_us, "us", "measured"))
            rows.append(Row("latency", "fig3/5/7", "hadronio", msg, ch,
                            "emitted_collective_ops", stats.total_ops,
                            "ops", "derived"))
            rows.append(Row("latency", "fig3/5/7", "hadronio", msg, ch,
                            "rtt_v5e_model",
                            derived_collective_time(stats) * 1e6 / ch,
                            "us", "derived"))
    # per-mesh recommended comm.channels default (ROADMAP autotune item)
    # derived from the sweep just measured — no re-measurement
    _, rec_rows = recommend_channels(rtts_at_max, max(msg_sizes))
    rows.extend(rec_rows)
    return rows
