"""Perf-regression gate CLI: diff two BENCH_*.json artifacts.

    python benchmarks/bench_diff.py BENCH_baseline.json BENCH_candidate.json

Exits 0 when every shared row is within its tolerance band, 1 on any
regression (and, with --strict-missing, on rows the candidate dropped).
Policy and band semantics live in repro.obs.baseline; per-row overrides:

    --tol 'rtt_*=0.25'            custom rel band (glob on metric or
                                  benchmark:metric; first match wins)
    --tol 'serving_rtt:p99*=0.5'
    --ignore 'obs:*'              force-ignore matching rows
    --tol-measured 1.0            default band for measured time rows
    --tol-derived-time 0.05       default band for derived time rows
"""
from __future__ import annotations

import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.obs import baseline as bl


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="Compare two BENCH_*.json artifacts with tolerance "
                    "bands; exit non-zero on regression.")
    ap.add_argument("baseline", help="baseline BENCH_*.json")
    ap.add_argument("candidate", help="candidate BENCH_*.json")
    ap.add_argument("--tol-measured", type=float, default=1.0,
                    help="rel band for measured time rows (default 1.0 "
                         "= 2x; CI wall-clock is noisy)")
    ap.add_argument("--tol-derived-time", type=float, default=0.05,
                    help="rel band for derived/model time rows")
    ap.add_argument("--tol", action="append", default=[],
                    metavar="GLOB=REL",
                    help="override: metric glob -> rel band "
                         "(lower-is-better); repeatable, first match "
                         "wins")
    ap.add_argument("--ignore", action="append", default=[],
                    metavar="GLOB", help="force-ignore matching rows")
    ap.add_argument("--strict-missing", action="store_true",
                    help="fail when the candidate drops baseline rows")
    ap.add_argument("--verbose", action="store_true",
                    help="print every delta, not just the notable ones")
    args = ap.parse_args(argv)

    overrides = []
    for spec in args.tol:
        if "=" not in spec:
            ap.error(f"--tol expects GLOB=REL, got {spec!r}")
        pat, rel = spec.rsplit("=", 1)
        overrides.append((pat, bl.Tolerance(rel=float(rel),
                                            direction="lower_is_better")))

    rep = bl.diff_files(args.baseline, args.candidate,
                        tol_measured=args.tol_measured,
                        tol_derived_time=args.tol_derived_time,
                        overrides=overrides, ignore=args.ignore)

    notable = ("regression", "improved", "missing", "added")
    for d in rep.deltas:
        if args.verbose or d.status in notable:
            print(d.describe())
    print(f"bench_diff: {rep.summary() or 'no comparable rows'}  "
          f"({args.baseline} -> {args.candidate})")

    if rep.regressions:
        print(f"bench_diff: FAIL — {len(rep.regressions)} regression(s) "
              "outside tolerance", file=sys.stderr)
        return 1
    if args.strict_missing and rep.of("missing"):
        print(f"bench_diff: FAIL — {len(rep.of('missing'))} baseline "
              "row(s) missing from candidate", file=sys.stderr)
        return 1
    print("bench_diff: OK")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
