"""Figs. 5-8 analog — uni-/bi-directional RTT percentiles through the
EventLoopGroup (the paper's multi-threaded netty microbenchmark).

Paper setup: an EventLoopGroup of worker threads, each owning a set of
connections; uni-directional streams one side's messages, bi-directional
keeps both directions in flight; results are reported as latency
percentiles over the message stream (the hhu JIB-benchmark methodology,
arXiv:1910.02245 — p50/p99/p99.9, never means).

TPU reading: one "connection" = one independent ppermute ping-pong on
the ring, OWNED by one event loop (disjoint channel affinity —
``serving/event_loop.py``); a loop drains its run queue by dispatching
its connections' round trips in a single jitted program and polling
completion per the configured strategy (busy / park / adaptive). The
sweep axes are event-loop count x connections-per-loop x message size,
uni (fwd-then-bwd chained) and bi (both directions concurrently in
flight per connection). Samples from every loop merge into ONE ragged
distribution per point (benchmarks/common.percentiles).

Also emits serving-dispatch evidence rows: the decode-step program of
``serving/dispatch.py`` lowered per comm mode, with emitted collective
counts and the first-collective position (None-safe on programs with no
collectives — the 1-device local reference).

  PYTHONPATH=src python -m benchmarks.serving_rtt --smoke \
      --json BENCH_serving.json
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from benchmarks.common import (Row, block, percentile_rows, timeit_samples)
from repro import compat
from repro.configs.base import CommConfig
from repro.core.backends import pipeline
from repro.launch import hlo_analysis as hlo
from repro.launch.mesh import make_mesh
from repro.serving.event_loop import EventLoop, EventLoopGroup

MSG_SIZES = [16, 1024, 64 * 1024]
LOOPS = [1, 2, 4]
CONNS_PER_LOOP = [1, 2]
DIRECTIONS = ("uni", "bi")

EVIDENCE_MODES = ("sockets", "hadronio")


def _rtt_fn(mesh, n_conns: int, n_dev: int, direction: str):
    """One event loop's jitted program: every owned connection completes
    one round trip. ``uni`` chains fwd-then-bwd per connection; ``bi``
    keeps a second, reverse-starting payload in flight per connection
    (both directions on the wire at once)."""
    perm_fwd = [(i, (i + 1) % n_dev) for i in range(n_dev)]
    perm_bwd = [(i, (i - 1) % n_dev) for i in range(n_dev)]

    def trip(x, first, second):
        y = jax.lax.ppermute(x, "data", first)
        return jax.lax.ppermute(y, "data", second)

    def body(*xs):
        outs = []
        for x in xs:                     # independent connections
            outs.append(trip(x, perm_fwd, perm_bwd))
            if direction == "bi":
                outs.append(trip(x, perm_bwd, perm_fwd))
        return tuple(outs)

    f = compat.shard_map(body, mesh=mesh,
                         in_specs=tuple([P("data", None)] * n_conns),
                         out_specs=tuple([P("data", None)] * n_conns
                                         * (2 if direction == "bi" else 1)),
                         check_vma=False)
    return jax.jit(f)


def _loop_runner(fns: dict, mesh, elems: int, n_dev: int, direction: str,
                 iters: int):
    """Runner bound to each event loop: dispatch the loop's connections
    through the SHARED jitted program for that connection count (one
    compile per (n_conns, shape) across all loops — a per-loop jit would
    recompile the identical program once per loop), poll completions per
    the loop's strategy, return the RTT sample stream."""
    def runner(loop: EventLoop, items: list) -> list:
        n = len(items)
        if n == 0:
            return []
        if n not in fns:
            fns[n] = _rtt_fn(mesh, n, n_dev, direction)
        fn = fns[n]
        xs = tuple(jnp.zeros((n_dev, elems), jnp.float32) + u
                   for u in items)

        def once():
            out = fn(*xs)
            loop.poller.wait(out)        # busy / park / adaptive
            block(out)

        return [timeit_samples(once, warmup=1, iters=iters)]
    return runner


def _dispatch_evidence_rows(channels: int = 2) -> list:
    """Serving-dispatch evidence: emitted collective counts + first
    collective position of one lowered decode step per comm mode —
    proof the serve path flows through the staged emission API (and the
    None-safe position contract for collective-free programs)."""
    from repro.configs.registry import get_config
    from repro.serving import dispatch

    cfg = get_config("qwen2-0.5b-reduced")
    rows = []
    for mode in EVIDENCE_MODES:
        comm = CommConfig(mode=mode, slice_bytes=512, channels=channels,
                          aggregate="channel", flush="ready",
                          hierarchical=False)
        text = dispatch.lowered_decode_text(cfg, comm, batch=2, max_len=32)
        st = hlo.stablehlo_collective_stats(text)
        rows.append(Row("serving_rtt", "dispatch-evidence", mode, 0,
                        channels, "emitted_collective_ops", st.total_ops,
                        "ops", "derived"))
        pos = hlo.first_collective_position(text)
        if pos is not None:
            first, total = pos
            rows.append(Row("serving_rtt", "dispatch-evidence", mode, 0,
                            channels, "first_collective_pos",
                            first / max(total, 1), "frac", "derived"))
    return rows


TOPO_MSG_SIZES = [1024, 64 * 1024]
TOPO_MODE = "hadronio_overlap"


def _topo_emit_fn(mesh, ctx, elems: int):
    """One jitted serving logit-reduction through the staged emission
    wire: every ring peer contributes a partial payload, the sum comes
    back replicated (the decode TP-head exchange, isolated from model
    compute so the rows measure emission structure only)."""
    axes = tuple(mesh.axis_names)

    def body(x):
        return pipeline.emit_flat(x.reshape(-1), ctx, "all_reduce")

    f = compat.shard_map(body, mesh=mesh, in_specs=P(axes),
                         out_specs=P(), check_vma=False)
    return jax.jit(f)


def _topo_ctx(comm: CommConfig, mesh):
    """Resolve the emission context for a serve mesh: pod-aware when the
    mesh carries a pod axis (gated on ``comm.hierarchical``, exactly
    like ``serving/dispatch.make_serve_step``)."""
    from repro.core.backends.base import SyncContext
    axes = tuple(mesh.axis_names)
    if "pod" in axes:
        data = tuple(a for a in axes if a != "pod")
        return SyncContext.resolve(comm, data, "pod")
    return SyncContext.resolve(comm, axes, None)


def run_topo(*, msg_sizes=TOPO_MSG_SIZES, pod_counts=None,
             channels: int = 4, leader_channels: int = 1,
             iters: int = 20, smoke: bool = False) -> list:
    """The mesh-growth sweep (the tentpole's headline table): RTT
    percentiles of the serving logit reduction x pod count x emission
    {flat, hierarchical leader-channel}, plus the cross-pod-collective
    evidence rows — under leader emission the cross-pod count stays at
    ``leader_channels`` as pods grow while flat emission keeps every
    one of its ``channels`` collectives on the cross-pod link."""
    from repro.configs.registry import get_config
    from repro.launch.mesh import make_serve_mesh
    from repro.serving import dispatch

    n = len(jax.devices())
    if pod_counts is None:
        pod_counts = [p for p in (1, 2, 4) if p <= n and n % p == 0]
    if smoke:
        iters = min(iters, 5)
        pod_counts = pod_counts[:2]
    rows = []
    cfg = get_config("qwen2-0.5b-reduced")
    for pods in pod_counts:
        mesh = make_serve_mesh(pods)
        emissions = ("flat",) if pods == 1 else ("flat", "hierarchical")
        for emission in emissions:
            comm = CommConfig(
                mode=TOPO_MODE, channels=channels,
                aggregate="channel", flush="ready",
                hierarchical=emission == "hierarchical",
                leader_channels=leader_channels,
                slice_bytes=max(64, min(msg_sizes) // channels))
            ctx = _topo_ctx(comm, mesh)
            for msg in msg_sizes:
                elems = max(1, msg // 4)
                fn = _topo_emit_fn(mesh, ctx, elems)
                x = jnp.ones((n, elems), jnp.float32)

                def once():
                    block(fn(x))

                samples = timeit_samples(once, warmup=2, iters=iters)
                rows.extend(percentile_rows(
                    "serving_rtt", "topo-sweep", emission, msg, channels,
                    [samples], suffix=f"pods{pods}"))
            if pods > 1:
                # jaxpr evidence: in-pod vs cross-pod collective counts
                # of one lowered decode step over this very mesh
                text = dispatch.lowered_decode_text(cfg, comm, batch=n,
                                                    mesh=mesh)
                cp = hlo.cross_pod_collective_count(text, n // pods)
                rows.append(Row(
                    "serving_rtt", "topo-evidence", emission, 0, channels,
                    f"cross_pod_collectives:pods{pods}",
                    cp["cross_pod_total"], "ops", "derived"))
                rows.append(Row(
                    "serving_rtt", "topo-evidence", emission, 0, channels,
                    f"in_pod_collectives:pods{pods}",
                    cp["in_pod_total"], "ops", "derived"))
    return rows


def run(mesh=None, *, msg_sizes=MSG_SIZES, loops=LOOPS,
        conns_per_loop=CONNS_PER_LOOP, directions=DIRECTIONS,
        iters: int = 20, poll: str = "busy", smoke: bool = False,
        threads: bool = True, evidence: bool = True):
    if smoke:
        loops = [1, 2]
        conns_per_loop = [2]
        iters = min(iters, 5)
    if mesh is None:
        n = len(jax.devices())
        mesh = make_mesh((n,), ("data",))
    n_dev = mesh.shape["data"]
    rows = []
    for direction in directions:
        # ONE jitted wrapper per connection count for the whole direction
        # sweep (jit re-specializes per message shape on its own) —
        # shared across loops, loop counts and message sizes
        fns = {n: _rtt_fn(mesh, n, n_dev, direction)
               for n in set(conns_per_loop)}
        for msg in msg_sizes:
            elems = max(1, msg // 4)
            for el in loops:
                for cpl in conns_per_loop:
                    total = el * cpl
                    runner = _loop_runner(fns, mesh, elems, n_dev,
                                          direction, iters)
                    evloops = [EventLoop(i, channels=(i,), poll=poll,
                                         runner=runner)
                               for i in range(el)]
                    grp = EventLoopGroup(evloops)
                    grp.submit(list(range(total)))   # round-robin conns
                    samples = grp.run(threads=threads)   # ragged per loop
                    rows.extend(percentile_rows(
                        "serving_rtt", "fig5-8", direction, msg, total,
                        samples, suffix=f"el{el}"))
                    st = grp.poll_stats()
                    rows.append(Row("serving_rtt", "fig5-8", direction,
                                    msg, total, f"poll_parks:el{el}",
                                    st.parks, "count", "derived"))
                    rows.append(Row("serving_rtt", "fig5-8", direction,
                                    msg, total, f"poll_spins:el{el}",
                                    st.spins, "count", "derived"))
    if evidence:
        rows.extend(_dispatch_evidence_rows())
    return rows


def main() -> int:
    from benchmarks import common
    common.ensure_devices()
    import argparse

    from benchmarks.common import write_json, write_rows

    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--smoke", action="store_true",
                   help="CI sweep: 3 msg sizes x {1,2} loops x 2 conns")
    p.add_argument("--poll", default="busy",
                   choices=("busy", "park", "adaptive"))
    p.add_argument("--iters", type=int, default=20)
    p.add_argument("--csv", default="")
    p.add_argument("--json", default="")
    p.add_argument("--seed", type=int, default=0,
                   help="recorded in every row's seed column (JIB "
                        "methodology: rows carry their reproduction "
                        "conditions)")
    p.add_argument("--topo", action="store_true",
                   help="run the pod-topology sweep instead (RTT "
                        "percentiles x pod count x emission "
                        "{flat, hierarchical} + cross-pod collective "
                        "evidence rows)")
    p.add_argument("--trace-out", default="",
                   help="write a Chrome-trace JSON of the bench's spans "
                        "(drains, staged emissions from the dispatch-"
                        "evidence lowering) here")
    p.add_argument("--metrics-out", default="",
                   help="write the obs registry snapshot (poll/emission "
                        "counters of the bench run) here")
    args = p.parse_args()
    common.set_run_seed(args.seed)
    if args.trace_out:
        from repro import obs
        obs.enable()
        # the dispatch-evidence lowering must trace FRESH programs or a
        # warm serve-step cache yields an emission-span-free trace
        from repro.serving import dispatch
        dispatch.clear_serve_step_cache()
    if args.topo:
        rows = run_topo(iters=args.iters, smoke=args.smoke)
    else:
        rows = run(iters=args.iters, poll=args.poll, smoke=args.smoke)
    if args.metrics_out:
        from repro import obs
        reg = obs.collect(mode="bench")
        with open(args.metrics_out, "w") as f:
            f.write(reg.to_json())
        # the deterministic half also rides the row artifact (unit
        # "count": inspectable in BENCH_*.json, ignored by bench_diff)
        rows.extend(common.metrics_rows("serving_rtt", reg.snapshot(),
                                        mode="bench"))
        print(f"[serving_rtt] metrics snapshot -> {args.metrics_out}")
    text = write_rows(rows, args.csv or None)
    if args.json:
        write_json(rows, args.json)
    if args.trace_out:
        from repro import obs
        rec = obs.disable()
        doc = rec.write(args.trace_out)
        print(f"[serving_rtt] span trace -> {args.trace_out} "
              f"({len(doc['traceEvents'])} spans, kinds={rec.kinds()})")
    print(text)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
