"""Family-matrix smoke — every registered model family served through a
real EventLoopGroup, plus a two-tenant mixed-family group.

This is the executable form of docs/FAMILIES.md: one reduced config per
family (the same FAMILY_ARCH map the conformance tests index) runs
prefill + decode through the comm-backed serve step inside an
EventLoopGroup, and its greedy tokens are asserted bit-identical to the
solo DecodeEngine reference before any row is emitted — a failed
identity raises instead of reporting. The tenant leg serves a dense and
an ssm model side by side in ONE group (per-tenant loop/channel ranges,
weighted-fair admission) and reports the fairness counters.

Deliberately 1 host device (no ``ensure_devices``): the identity
assert's solo reference is the single-shard engine, and the wire path —
staged slicing, channel flushes, the coalesced gathering write — is
fully exercised at ring size 1 (the multi-device bit-identity rows live
in tests/test_backend_conformance.py and tests/distributed/).

Row schema is benchmarks/common.Row; ``mode`` carries the family (or
tenant) name, ``figure`` is family-matrix / tenant-fairness.

  PYTHONPATH=src python -m benchmarks.serving_families --smoke \
      --json BENCH_families.json
"""
from __future__ import annotations

import time

import jax
import numpy as np

FAMILY_ARCH = {
    "dense": "qwen2-0.5b-reduced",
    "moe": "mixtral-8x7b-reduced",
    "ssm": "rwkv6-7b-reduced",
    "hybrid": "recurrentgemma-9b-reduced",
    "encdec": "whisper-tiny-reduced",
    "vlm": "llava-next-mistral-7b-reduced",
}


def _comm(channels=2):
    from repro.configs.base import CommConfig
    return CommConfig(mode="hadronio", channels=channels,
                      slice_bytes=1024, hierarchical=False)


def _requests(cfg, n, max_new, seed=0):
    from repro.serving import Request
    rng = np.random.default_rng(seed)
    return [Request(i, rng.integers(1, cfg.vocab_size, size=8),
                    max_new=max_new) for i in range(n)]


def _family_rows(n_reqs: int, max_new: int) -> list:
    from benchmarks.common import Row
    from repro.configs.base import ServeConfig
    from repro.configs.registry import get_config
    from repro.launch import hlo_analysis as hlo
    from repro.models import api
    from repro.serving import (DecodeEngine, Request, dispatch,
                               make_engine_group)
    rows = []
    for family, arch in sorted(FAMILY_ARCH.items()):
        cfg = get_config(arch)
        params = api.init(jax.random.PRNGKey(0), cfg)
        reqs = _requests(cfg, n_reqs, max_new)
        solo = DecodeEngine(cfg, params, max_batch=4, max_len=64)
        ref = {r.uid: tuple(r.tokens.tolist())
               for r in solo.generate([Request(r.uid, r.prompt,
                                               max_new=r.max_new)
                                       for r in reqs])}
        serve = ServeConfig(event_loops=1, poll="busy", max_batch=4,
                            max_len=64, comm=_comm())
        grp = make_engine_group(cfg, params, serve)
        grp.submit(reqs)
        t0 = time.perf_counter()
        res = grp.run(threads=False)
        wall = time.perf_counter() - t0
        got = {r.uid: tuple(r.tokens.tolist()) for r in res}
        assert got == ref, \
            (f"{family}: group tokens diverged from the solo engine "
             f"(got {got}, want {ref})")
        n_toks = sum(len(r.tokens) for r in res)
        stats = hlo.stablehlo_collective_stats(
            dispatch.lowered_decode_text(cfg, _comm()))
        rows += [
            Row("serving_families", "family-matrix", family, 0, 2,
                "bitwise_vs_solo", 1.0, "bool", "derived"),
            Row("serving_families", "family-matrix", family, 0, 2,
                "tokens_served", n_toks, "count", "measured"),
            Row("serving_families", "family-matrix", family, 0, 2,
                "serve_wall", wall, "s", "measured"),
            Row("serving_families", "family-matrix", family, 0, 2,
                "decode_collective_ops", stats.total_ops, "count",
                "derived"),
        ]
        print(f"  {family:8s} {arch:28s} tokens={n_toks:3d} "
              f"collectives={stats.total_ops}")
    return rows


def _tenant_rows(n_reqs: int, max_new: int) -> list:
    from benchmarks.common import Row
    from repro.configs.base import ServeConfig, TenantConfig
    from repro.configs.registry import get_config
    from repro.models import api
    from repro.serving import Request, make_engine_group
    cfg_a = get_config(FAMILY_ARCH["dense"])
    cfg_b = get_config(FAMILY_ARCH["ssm"])
    p_a = api.init(jax.random.PRNGKey(0), cfg_a)
    p_b = api.init(jax.random.PRNGKey(1), cfg_b)
    serve = ServeConfig(
        event_loops=2, poll="busy", max_batch=4, max_len=64,
        comm=_comm(channels=4),
        tenants=(TenantConfig("dense", arch=cfg_a.name, weight=2,
                              event_loops=1),
                 TenantConfig("ssm", arch=cfg_b.name, weight=1,
                              event_loops=1)))
    grp = make_engine_group({"dense": cfg_a, "ssm": cfg_b},
                            {"dense": p_a, "ssm": p_b}, serve)
    reqs = []
    rng = np.random.default_rng(2)
    for uid in range(2 * n_reqs):
        t = "dense" if uid % 2 == 0 else "ssm"
        v = (cfg_a if t == "dense" else cfg_b).vocab_size
        reqs.append(Request(uid, rng.integers(1, v, size=8),
                            max_new=max_new, tenant=t))
    grp.submit(reqs)
    res = grp.run(threads=False)
    got = {r.uid: tuple(r.tokens.tolist()) for r in res}
    # identity vs each model's single-tenant run
    for t, c, p in (("dense", cfg_a, p_a), ("ssm", cfg_b, p_b)):
        s1 = ServeConfig(event_loops=1, poll="busy", max_batch=4,
                         max_len=64, comm=_comm())
        g1 = make_engine_group(c, p, s1)
        g1.submit([Request(r.uid, r.prompt, max_new=r.max_new)
                   for r in reqs if r.tenant == t])
        ref = {r.uid: tuple(r.tokens.tolist())
               for r in g1.run(threads=False)}
        assert {u: got[u] for u in ref} == ref, \
            f"tenant {t}: tokens diverged from the single-tenant run"
    rows = []
    for t, n in grp.fairness_counters.items():
        rows.append(Row("serving_families", "tenant-fairness", t, 0, 4,
                        "dispatched", n, "count", "measured"))
    rows.append(Row("serving_families", "tenant-fairness", "group", 0, 4,
                    "bitwise_vs_single_tenant", 1.0, "bool", "derived"))
    # the stride pattern is deterministic: weights 2:1 over a balanced
    # mixed stream dispatches dense twice per ssm until dense drains
    head = grp.dispatch_log[:3]
    rows.append(Row("serving_families", "tenant-fairness", "group", 0, 4,
                    "stride_head_ok",
                    float(head == ["dense", "dense", "ssm"]), "bool",
                    "derived"))
    print(f"  tenants  fairness={grp.fairness_counters} "
          f"head={head}")
    return rows


def run(smoke: bool = False) -> list:
    n_reqs, max_new = (3, 3) if smoke else (6, 8)
    print("family matrix:")
    rows = _family_rows(n_reqs, max_new)
    print("tenant leg:")
    rows += _tenant_rows(n_reqs, max_new)
    return rows


def main() -> int:
    import argparse
    from benchmarks import common
    from benchmarks.common import write_json, write_rows

    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--smoke", action="store_true",
                   help="CI leg: 3 requests x 3 tokens per family")
    p.add_argument("--csv", default="")
    p.add_argument("--json", default="")
    p.add_argument("--seed", type=int, default=0)
    args = p.parse_args()
    common.set_run_seed(args.seed)
    rows = run(smoke=args.smoke)
    text = write_rows(rows, args.csv or None)
    if args.json:
        write_json(rows, args.json)
    print(text)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
