"""Regenerate the generated tables inside EXPERIMENTS.md from artifacts.

  PYTHONPATH=src:. python benchmarks/build_experiments_tables.py

Replaces the <!-- ROOFLINE-TABLE --> and <!-- DRYRUN-MULTIPOD-TABLE -->
markers (idempotent: the generated block is fenced by marker comments).
"""
import os
import re
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from benchmarks.roofline import dryrun_summary, table  # noqa: E402

EXP = os.path.join(os.path.dirname(__file__), "..", "EXPERIMENTS.md")


def replace_block(text: str, marker: str, content: str) -> str:
    begin = f"<!-- {marker} -->"
    end = f"<!-- /{marker} -->"
    block = f"{begin}\n{content}\n{end}"
    if end in text:
        return re.sub(re.escape(begin) + r".*?" + re.escape(end), block,
                      text, flags=re.S)
    return text.replace(begin, block)


def main():
    with open(EXP) as f:
        text = f.read()
    text = replace_block(text, "ROOFLINE-TABLE", table("pod", "gspmd"))
    text = replace_block(text, "DRYRUN-MULTIPOD-TABLE",
                         dryrun_summary("multipod"))
    with open(EXP, "w") as f:
        f.write(text)
    print("EXPERIMENTS.md tables regenerated")


if __name__ == "__main__":
    main()
