"""Figures 4/6/8 — sustained throughput vs connection count.

Paper setup: each connection streams messages; netty aggregates
(flush-interval) so many small sends become few large writes.

TPU reading: per channel, a stream of ``flush_interval`` messages is
either sent one collective per message (mode=sockets — the pre-fix
hadroNIO loop of §III-C) or aggregated into ring-buffer slices with one
collective per slice (mode=hadronio — the gathering write). mode=vma
fuses the whole stream into a single monolithic collective.
mode=hadronio_agg sweeps the NEW ``comm.aggregate="channel"`` axis: the
stream's slices are coalesced into ONE wire flush per connection (the
paper's full gathering write — §V-B's one large buffer handed to UCX per
connection), routed through the live pipeline (pack stage -> coalesced
flush -> unpack stage). The measured axis is bytes moved per wall-clock
second across channels; derived numbers give the HLO op count — the
paper's "number of send calls".
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from benchmarks.common import (Row, block, derived_collective_time,
                               slice_view, timeit)
from repro import compat
from repro.configs.base import CommConfig
from repro.core.backends import pipeline
from repro.core.backends.base import SyncContext
from repro.launch import hlo_analysis as hlo
from repro.launch.mesh import make_mesh

MSG_SIZES = [16, 1024, 64 * 1024]
CHANNELS = [1, 2, 4, 8, 16]
FLUSH_INTERVAL = {16: 64, 1024: 16, 64 * 1024: 4}      # paper §V-B


def _stream_fn(mesh, mode: str, n_channels: int, n_msgs: int,
               msg_elems: int, slice_bytes: int):
    """One step: per channel, reduce n_msgs messages across the ring."""

    def body(*xs):
        outs = []
        for x in xs:                       # x: (n_msgs, msg_elems)
            if mode == "sockets":
                parts = [jax.lax.psum(x[i], "data")
                         for i in range(x.shape[0])]
                outs.append(jnp.stack(parts))
            elif mode == "vma":
                outs.append(jax.lax.psum(x.reshape(-1),
                                         "data").reshape(x.shape))
            elif mode == "hadronio":
                # pack into slices, one collective per slice
                total = x.size * 4
                sl, sp = slice_view(x.reshape(-1), CommConfig(
                    mode="hadronio", slice_bytes=slice_bytes,
                    ring_capacity_bytes=max(slice_bytes * 64, total)))
                red = [jax.lax.psum(sl[i], "data")
                       for i in range(sp.n_slices)]
                out = jnp.stack(red).reshape(-1)
                outs.append(out[: x.size].reshape(x.shape))
            else:  # hadronio_agg: ONE coalesced wire flush per stream,
                #    through the live pipeline (aggregate="channel")
                total = x.size * 4
                comm = CommConfig(
                    mode="hadronio", slice_bytes=slice_bytes,
                    channels=1, aggregate="channel", hierarchical=False,
                    ring_capacity_bytes=max(slice_bytes * 64, total))
                sl, _ = slice_view(x.reshape(-1), comm)
                ctx = SyncContext.resolve(comm, ("data",), None)
                red, _ = pipeline.reduce_slices(sl, ctx)
                outs.append(red.reshape(-1)[: x.size].reshape(x.shape))
        return tuple(outs)

    f = compat.shard_map(body, mesh=mesh,
                      in_specs=tuple([P()] * n_channels),
                      out_specs=tuple([P()] * n_channels),
                      check_vma=False)
    return jax.jit(f)


def run(mesh=None, *, msg_sizes=MSG_SIZES, channels=CHANNELS,
        modes=("sockets", "vma", "hadronio", "hadronio_agg"),
        slice_bytes: int = 64 * 1024, iters: int = 5):
    if mesh is None:
        n = len(jax.devices())
        mesh = make_mesh((n,), ("data",))
    rows = []
    for msg in msg_sizes:
        elems = max(1, msg // 4)
        n_msgs = FLUSH_INTERVAL[msg]
        for ch in channels:
            xs = tuple(jnp.ones((n_msgs, elems), jnp.float32) * (i + 1)
                       for i in range(ch))
            sds = [jax.ShapeDtypeStruct((n_msgs, elems), jnp.float32)] * ch
            for mode in modes:
                fn = _stream_fn(mesh, mode, ch, n_msgs, elems, slice_bytes)
                lowered = fn.lower(*sds)
                emitted = hlo.stablehlo_collective_stats(lowered.as_text())
                t = timeit(lambda: block(fn(*xs)), iters=iters)
                payload = ch * n_msgs * msg
                rows.append(Row("throughput", "fig4/6/8", mode, msg, ch,
                                "goodput", payload / t / 1e6, "MB/s",
                                "measured"))
                rows.append(Row("throughput", "fig4/6/8", mode, msg, ch,
                                "emitted_collective_ops",
                                emitted.total_ops, "ops", "derived"))
                rows.append(Row("throughput", "fig4/6/8", mode, msg, ch,
                                "goodput_v5e_model",
                                payload / derived_collective_time(emitted)
                                / 1e6, "MB/s", "derived"))
    return rows
