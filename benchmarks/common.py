"""Shared benchmark plumbing.

The paper's figures sweep {message size} x {connection count} over three
stacks (sockets / libvma / hadroNIO). Our stacks are the TAC modes; the
"connections" axis is the channel count (independent in-flight slice
collectives); message sizes are kept literally (16 B / 1 KiB / 64 KiB)
plus TPU-scale points (1 MiB / 4 MiB).

Because this container is CPU-only, every benchmark reports TWO result
kinds per point:

* measured — wall-clock on the 8-virtual-device host mesh (relative
  numbers: scaling shape, not absolute TPU performance), and
* derived — per-op collective statistics parsed from the compiled HLO
  (op count, bytes) + the v5e analytic time model from hlo_analysis
  (these are hardware-grounded and feed EXPERIMENTS.md).

CSV schema (benchmarks/run.py): benchmark,figure,mode,msg_bytes,channels,
metric,value,unit,kind.
"""
from __future__ import annotations

import csv
import dataclasses
import io
import os
import time
from typing import Callable, Iterable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.ring_buffer import plan_slices
from repro.launch import hlo_analysis as hlo

N_DEVICES = 8     # virtual host devices for measured numbers

# The run-level seed recorded on every Row (JIB methodology: results must
# carry the conditions that reproduce them). -1 = unseeded/legacy run.
_RUN_SEED = -1


def set_run_seed(seed: int) -> None:
    """Record the benchmark invocation's ``--seed`` so every Row built
    afterwards carries it (rows capture the seed at construction)."""
    global _RUN_SEED
    _RUN_SEED = int(seed)


def ensure_devices() -> int:
    """Must be called before jax initializes (benchmarks/run.py does)."""
    flags = os.environ.get("XLA_FLAGS", "")
    if "host_platform_device_count" not in flags:
        os.environ["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={N_DEVICES} " + flags)
    return N_DEVICES


@dataclasses.dataclass
class Row:
    benchmark: str
    figure: str
    mode: str
    msg_bytes: int
    channels: int
    metric: str
    value: float
    unit: str
    kind: str          # measured | derived
    seed: int = dataclasses.field(
        default_factory=lambda: _RUN_SEED)   # reproducibility metadata

    def as_list(self):
        return [self.benchmark, self.figure, self.mode, self.msg_bytes,
                self.channels, self.metric,
                f"{self.value:.6g}", self.unit, self.kind, self.seed]


HEADER = ["benchmark", "figure", "mode", "msg_bytes", "channels", "metric",
          "value", "unit", "kind", "seed"]


def write_rows(rows: Iterable[Row], path: str | None):
    out = io.StringIO()
    w = csv.writer(out)
    w.writerow(HEADER)
    for r in rows:
        w.writerow(r.as_list())
    text = out.getvalue()
    if path:
        with open(path, "w") as f:
            f.write(text)
    return text


def write_json(rows: Iterable[Row], path: str):
    """Machine-readable row dump (the CI benchmark-smoke artifact —
    BENCH_*.json files accumulate the cross-commit trajectory)."""
    import json
    data = [dataclasses.asdict(r) for r in rows]
    with open(path, "w") as f:
        json.dump(data, f, indent=1, sort_keys=True)
    return data


def slice_view(flat, comm):
    """Shared prologue of the slice benchmarks: zero-pad a flat f32
    payload to the ring-buffer plan and view it as (n_slices,
    slice_elems). Returns (slices, plan)."""
    sp = plan_slices(flat.shape[0] * 4, comm)
    elems = sp.slice_bytes // 4
    pad = sp.n_slices * elems - flat.shape[0]
    if pad:
        flat = jnp.pad(flat, (0, pad))
    return flat.reshape(sp.n_slices, elems), sp


def timeit_samples(fn: Callable[[], object], *, warmup: int = 2,
                   iters: int = 10) -> list:
    """Raw per-iteration wall-clock seconds of fn() (which must block) —
    the sample stream the percentile reporting is built from."""
    for _ in range(warmup):
        fn()
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        fn()
        ts.append(time.perf_counter() - t0)
    return ts


def timeit(fn: Callable[[], object], *, warmup: int = 2, iters: int = 10
           ) -> float:
    """Median wall-clock seconds of fn() (which must block)."""
    return float(np.median(timeit_samples(fn, warmup=warmup, iters=iters)))


# ---------------------------------------------------------------------------
# Percentile reporting (the hhu benchmark methodology, arXiv:1910.02245:
# latency distributions are characterized by p50/p99/p99.9, not means) —
# shared by latency.py, gradsync.py and serving_rtt.py.
# ---------------------------------------------------------------------------

PERCENTILE_QS = (50.0, 99.0, 99.9)
PERCENTILE_LABELS = {50.0: "p50", 99.0: "p99", 99.9: "p99.9"}


def percentiles(samples, qs=PERCENTILE_QS) -> dict:
    """``{q: value}`` over a possibly RAGGED sample collection (a flat
    sequence, or nested per-loop/per-connection sequences of different
    lengths — the multi-threaded benchmark's natural shape). Small
    samples degrade gracefully to order statistics (linear
    interpolation; one sample makes every percentile that sample).
    Values are monotone in q by construction. Raises on empty input —
    an empty distribution has no percentiles and silently reporting one
    would fabricate a latency."""
    def _flatten(s):
        if isinstance(s, (list, tuple)) or (isinstance(s, np.ndarray)
                                            and s.ndim > 0):
            out = []
            for item in s:
                out.extend(_flatten(item))
            return out
        return [float(s)]

    flat = np.asarray(_flatten(samples), np.float64)
    if flat.size == 0:
        raise ValueError("percentiles() of an empty sample set")
    vals = np.percentile(flat, list(qs))
    return dict(zip(qs, (float(v) for v in vals)))


def percentile_rows(benchmark: str, figure: str, mode: str, msg_bytes: int,
                    channels: int, samples, *, metric: str = "rtt",
                    unit: str = "us", scale: float = 1e6,
                    suffix: str = "", kind: str = "measured") -> list:
    """One Row per percentile of ``samples`` (seconds; ``scale`` converts
    to ``unit``), metric-named ``<metric>_p50[:<suffix>]`` etc. — the
    shared shape of every RTT/step-time distribution table."""
    ps = percentiles(samples)
    sfx = f":{suffix}" if suffix else ""
    return [Row(benchmark, figure, mode, msg_bytes, channels,
                f"{metric}_{PERCENTILE_LABELS[q]}{sfx}", ps[q] * scale,
                unit, kind)
            for q in PERCENTILE_QS]


def block(tree):
    for leaf in jax.tree.leaves(tree):
        leaf.block_until_ready()


def derived_collective_time(stats: hlo.CollectiveStats, n_ops_latency_us:
                            float = 3.0) -> float:
    """v5e analytic time: per-op fixed cost + bytes over ICI bandwidth."""
    return (stats.total_ops * n_ops_latency_us * 1e-6
            + stats.total_bytes / hlo.ICI_BW)


def metrics_rows(benchmark: str, snapshot: dict, *,
                 mode: str = "obs") -> list:
    """Flatten an obs registry snapshot's DETERMINISTIC half (counters +
    gauges — repro/obs/metrics.py) into derived Rows, metric-named
    ``obs:<key>``. Unit is ``count``, which the bench_diff default
    policy ignores — these rows ride the artifact for inspection and
    are gated by the telemetry determinism tests, not tolerance bands."""
    rows = []
    for section in ("counters", "gauges"):
        for key, value in sorted(snapshot.get(section, {}).items()):
            rows.append(Row(benchmark, "obs-snapshot", mode, 0, 0,
                            f"obs:{key}", float(value), "count",
                            "derived"))
    return rows
