"""Benchmark orchestrator — one function per paper table/figure.

  python -m benchmarks.run                # everything, CSV to stdout
  python -m benchmarks.run --only latency --csv bench.csv

Benchmarks (see DESIGN.md §6):
  latency     Fig. 3/5/7 — ping-pong RTT vs channels x msg size
  throughput  Fig. 4/6/8 — aggregated-stream goodput vs channels x msg size
  gradsync    (new) per-mode collective ops/bytes on real model grads
  serving_rtt Figs. 5-8 (multi-threaded) — uni/bi RTT percentiles through
              the EventLoopGroup (event loops x connections x msg size)
  serving_chaos §Chaos+SLO — seeded fault scenarios x mode x event loops:
              recovery + injection counts + p99.9 inflation
              (--supervised adds the self-healing Supervisor sweep:
              recovered_sup / healing / mttr rows per cell)
  roofline    §Roofline — three-term table from the dry-run artifacts
"""
from benchmarks import common

common.ensure_devices()        # before jax initializes

import argparse                # noqa: E402
import sys                     # noqa: E402
import time                    # noqa: E402

from benchmarks.common import write_json, write_rows   # noqa: E402

BENCHES = ("latency", "throughput", "gradsync", "serving_rtt",
           "serving_chaos", "roofline")


def main() -> int:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--only", choices=BENCHES, nargs="*", default=None)
    p.add_argument("--csv", default="", help="also write CSV here")
    p.add_argument("--json", default="",
                   help="also write the rows as JSON here (the CI "
                        "benchmark-smoke artifact)")
    p.add_argument("--quick", action="store_true",
                   help="fewer sweep points (CI mode)")
    p.add_argument("--seed", type=int, default=0,
                   help="recorded in every row; drives the chaos plans")
    p.add_argument("--supervised", action="store_true",
                   help="serving_chaos: also sweep every cell under the "
                        "self-healing Supervisor")
    args = p.parse_args()
    common.set_run_seed(args.seed)

    which = args.only or BENCHES
    rows = []
    for name in which:
        t0 = time.time()
        mod = __import__(f"benchmarks.{name}", fromlist=["run"])
        kw = {}
        if args.quick and name == "latency":
            kw = {"msg_sizes": [16, 1024], "channels": [1, 4], "iters": 3,
                  "quick": True}
        if args.quick and name == "throughput":
            kw = {"msg_sizes": [16, 1024], "channels": [1, 4], "iters": 3}
        if args.quick and name == "gradsync":
            kw = {"iters": 2}
        if args.quick and name == "serving_rtt":
            kw = {"smoke": True, "iters": 3}
        if name == "serving_chaos":
            kw = {"seed": args.seed, "supervised": args.supervised,
                  **({"smoke": True} if args.quick else {})}
        rows.extend(mod.run(**kw))
        print(f"# {name}: {time.time() - t0:.1f}s", file=sys.stderr)
    text = write_rows(rows, args.csv or None)
    if args.json:
        write_json(rows, args.json)
    print(text)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
