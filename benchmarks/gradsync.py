"""Gradient-sync table (new — the paper's technique applied to its real
target): per-mode HLO collective op count + bytes for REAL model
gradients, plus measured step time on the host mesh.

This is the end-to-end restatement of Figs. 4/6/8: the "messages" are a
model's gradient tensors (hundreds of small buffers), the "flush" is the
TAC pack, and the op-count column is exactly the paper's send-call count.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import (Row, block, derived_collective_time,
                               percentile_rows, timeit_samples)
from repro import compat
from repro.core.backends import available_modes, get_backend
from repro.configs.base import CommConfig, RunConfig, ShapeConfig
from repro.configs.registry import get_config
from repro.data import DataConfig, SyntheticSource, batch_at
from repro.launch import hlo_analysis as hlo
from repro.launch import steps as steps_mod
from repro.launch.mesh import make_mesh

# the paper's four modes in presentation order, then every other
# registered manual mode (e.g. hadronio_overlap) — registry-derived so a
# newly registered backend lands in the table without edits here
PAPER_MODES = ("sockets", "vma", "hadronio", "hadronio_rs")
MODES = PAPER_MODES + tuple(m for m in available_modes()
                            if get_backend(m).manual and m not in PAPER_MODES)


def run(mesh=None, *, arch: str = "qwen1.5-4b-reduced",
        seq_len: int = 64, modes=MODES, slice_bytes: int = 256 * 1024,
        iters: int = 5, flush_evidence: bool = True):
    if mesh is None:
        n = len(jax.devices())
        mesh = make_mesh((n,), ("data",))
    n_dev = int(np.prod(list(mesh.shape.values())))
    cfg = get_config(arch)
    shape = ShapeConfig("bench", "train", seq_len, n_dev)
    src = SyntheticSource(cfg.vocab_size, 0)
    batch_np = batch_at(src, DataConfig(seq_len, n_dev), 0)
    n_grads = len(jax.tree.leaves(
        __import__("repro.models.api", fromlist=["specs"]).specs(cfg)))

    rows = []
    with compat.set_mesh(mesh):
        for mode in modes:
            run_cfg = RunConfig(
                model=cfg, shape=shape,
                comm=CommConfig(mode=mode, slice_bytes=slice_bytes,
                                hierarchical=False))
            step_fn, state_sh, batch_sh_fn = steps_mod.make_train_step(
                run_cfg, mesh)
            state = jax.device_put(
                steps_mod.init_tac_state(jax.random.PRNGKey(0), run_cfg,
                                         n_dev)
                if get_backend(mode).manual else
                steps_mod.init_train_state(jax.random.PRNGKey(0), run_cfg),
                state_sh)
            batch = jax.device_put(batch_np, batch_sh_fn(mesh, batch_np))
            jitted = jax.jit(step_fn)
            lowered = jitted.lower(state, batch)
            emitted = hlo.stablehlo_collective_stats(lowered.as_text())
            compiled = lowered.compile()
            stats = hlo.collective_stats(compiled.as_text())

            def one():
                nonlocal state
                state, m = jitted(state, batch)
                jax.block_until_ready(m["loss"])

            samples = timeit_samples(one, warmup=1, iters=iters)
            t = float(np.median(samples))
            rows.append(Row("gradsync", "table-gradsync", mode, 0, n_dev,
                            "emitted_collective_ops", emitted.total_ops,
                            "ops", "derived"))
            rows.append(Row("gradsync", "table-gradsync", mode, 0, n_dev,
                            "emitted_collective_bytes",
                            emitted.total_bytes, "B", "derived"))
            rows.append(Row("gradsync", "table-gradsync", mode, 0, n_dev,
                            "collective_ops", stats.total_ops, "ops",
                            "derived"))
            rows.append(Row("gradsync", "table-gradsync", mode, 0, n_dev,
                            "collective_bytes", stats.total_bytes, "B",
                            "derived"))
            rows.append(Row("gradsync", "table-gradsync", mode, 0, n_dev,
                            "step_time", t * 1e3, "ms", "measured"))
            rows.extend(percentile_rows("gradsync", "table-gradsync", mode,
                                        0, n_dev, samples,
                                        metric="step_time", unit="ms",
                                        scale=1e3))
            rows.append(Row("gradsync", "table-gradsync", mode, 0, n_dev,
                            "sync_v5e_model",
                            derived_collective_time(stats) * 1e3, "ms",
                            "derived"))
            rows.append(Row("gradsync", "table-gradsync", mode, 0, n_dev,
                            "n_grad_tensors", n_grads, "tensors",
                            "derived"))

        if flush_evidence:
            rows.extend(_flush_evidence_rows(mesh, cfg, shape, n_dev,
                                             slice_bytes))
    return rows


def _flush_evidence_rows(mesh, cfg, shape, n_dev: int,
                         slice_bytes: int) -> list:
    """The flush-axis evidence table: for the overlap modes under
    ``aggregate="channel"`` with fewer channels than buckets, compare
    ``flush="step"`` vs ``"ready"`` on the EMITTED program — collective
    op count (same sync flushes either way; for ``hadronio_overlap_rs``
    the count DROPS under ``ready`` because the ZeRO-1 update epilogue
    legitimately merges its all-gathers per channel flush,
    ``gather_flush_groups``) and the position of the first collective
    among all emitted ops
    (``hlo_analysis.first_collective_position``): the readiness-driven
    schedule emits the first gathering write before the later buckets'
    pack ops, which is the overlap the ROADMAP follow-up asked for."""
    rows = []
    overlap_modes = [m for m in MODES if m.startswith("hadronio_overlap")]
    for mode in overlap_modes:
        for flush in ("step", "ready"):
            run_cfg = RunConfig(
                model=cfg, shape=shape,
                comm=CommConfig(mode=mode, slice_bytes=slice_bytes,
                                channels=2, aggregate="channel",
                                flush=flush, hierarchical=False))
            step_fn, state_sh, batch_sh_fn = steps_mod.make_train_step(
                run_cfg, mesh)
            state_sds = steps_mod.abstract_tac_state(run_cfg, n_dev)
            batch_sds = {
                "tokens": jax.ShapeDtypeStruct(
                    (n_dev, shape.seq_len), jnp.int32),
                "labels": jax.ShapeDtypeStruct(
                    (n_dev, shape.seq_len), jnp.int32)}
            text = jax.jit(step_fn).lower(state_sds, batch_sds).as_text()
            emitted = hlo.stablehlo_collective_stats(text)
            pos = hlo.first_collective_position(text)
            rows.append(Row("gradsync", "flush-evidence", mode, 0, 2,
                            f"emitted_collective_ops:{flush}",
                            emitted.total_ops, "ops", "derived"))
            if pos is not None:          # None = no collectives emitted
                first, total = pos
                rows.append(Row("gradsync", "flush-evidence", mode, 0, 2,
                                f"first_collective_pos:{flush}",
                                first / max(total, 1), "frac", "derived"))
    return rows
