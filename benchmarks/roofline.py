"""§Roofline table generator: reads dry-run artifacts (launch/dryrun.py)
and emits the three-term roofline per (arch x shape x mesh), dominant
bottleneck, MODEL_FLOPS ratio, and the per-cell note (EXPERIMENTS.md)."""
from __future__ import annotations

import glob
import json
import os

from benchmarks.common import Row

ARTIFACTS = os.path.join(os.path.dirname(__file__), "..", "artifacts")


def load_artifacts(pattern: str = "dryrun_*.json") -> list[dict]:
    out = []
    for f in sorted(glob.glob(os.path.join(ARTIFACTS, pattern))):
        with open(f) as fh:
            out.append(json.load(fh))
    return out


def run(mesh=None, **_):
    rows = []
    for art in load_artifacts():
        if art.get("status") != "ok":
            continue
        key = f"{art['arch']}x{art['shape']}x{art['mesh']}x{art['mode']}"
        r = art["roofline"]
        for term in ("compute_s", "memory_s", "collective_s"):
            rows.append(Row("roofline", key, art["mode"], 0,
                            art["n_chips"], term, r[term], "s", "derived"))
        ratio = art.get("useful_flops_ratio") or 0.0
        rows.append(Row("roofline", key, art["mode"], 0, art["n_chips"],
                        "useful_flops_ratio", ratio, "x", "derived"))
    return rows


def _fmt(x, digits=4):
    if x is None:
        return "-"
    if x == 0:
        return "0"
    if x < 10 ** -digits:
        return f"{x:.1e}"
    return f"{x:.{digits}f}"


def table(mesh_filter: str = "pod", mode: str = "gspmd",
          include_skips: bool = True) -> str:
    """Markdown §Roofline table (EXPERIMENTS.md embeds this output).

    Terms per §Methodology: compute from analytic MODEL_FLOPS; memory
    from the analytic HBM-traffic model; collective from scan-corrected
    compiled-HLO parsing. 'useful' = MODEL_FLOPS / corrected HLO FLOPs.
    'frac' = compute_s / max(term)s — the roofline fraction."""
    lines = ["| arch | shape | compute s | memory s | collective s | "
             "bottleneck | frac | useful | note |",
             "|---|---|---|---|---|---|---|---|---|"]
    for art in load_artifacts():
        if art["mesh"] != mesh_filter or art["mode"] != mode:
            continue
        if art.get("status") == "skip":
            if include_skips:
                lines.append(f"| {art['arch']} | {art['shape']} | - | - | "
                             f"- | skipped | - | - | "
                             f"{art['reason'][:48]}... |")
            continue
        if art.get("status") != "ok":
            lines.append(f"| {art['arch']} | {art['shape']} | - | - | - | "
                         f"FAIL | - | - | {art.get('error', '')[:48]} |")
            continue
        r = art["roofline"]
        ratio = art.get("useful_flops_ratio")
        dom = max(r["compute_s"], r["memory_s"], r["collective_s"])
        frac = r["compute_s"] / dom if dom else 0.0
        note = ""
        if r["bottleneck"] == "collective":
            note = "comm-bound: cut resharding/gather traffic"
        elif r["bottleneck"] == "memory":
            note = "HBM-bound: fuse/cache-resident working set"
        else:
            note = "compute-bound: at roofline when overlapped"
        lines.append(
            f"| {art['arch']} | {art['shape']} | {_fmt(r['compute_s'])} | "
            f"{_fmt(r['memory_s'])} | {_fmt(r['collective_s'])} | "
            f"{r['bottleneck']} | {frac:.2f} | "
            f"{_fmt(ratio, 2)} | {note} |")
    return "\n".join(lines)


def dryrun_summary(mesh_filter: str = "multipod") -> str:
    """§Dry-run table: pass/fail + memory + collective schedule."""
    lines = ["| arch | shape | status | compile s | coll ops | coll GB | "
             "temp GB/chip |", "|---|---|---|---|---|---|---|"]
    for art in load_artifacts():
        if art["mesh"] != mesh_filter or art["mode"] != "gspmd":
            continue
        if art.get("status") == "skip":
            lines.append(f"| {art['arch']} | {art['shape']} | skip | - | - "
                         f"| - | - |")
            continue
        if art.get("status") != "ok":
            lines.append(f"| {art['arch']} | {art['shape']} | **FAIL** | - "
                         f"| - | - | - |")
            continue
        c = art["collectives"]
        m = art["memory_analysis"]
        lines.append(
            f"| {art['arch']} | {art['shape']} | ok | "
            f"{art['compile_seconds']:.0f} | {c['total_ops']} | "
            f"{c['total_bytes']/1e9:.2f} | "
            f"{m.get('temp_size_in_bytes', 0)/1e9:.2f} |")
    return "\n".join(lines)


if __name__ == "__main__":
    import sys
    if len(sys.argv) > 1 and sys.argv[1] == "dryrun":
        print(dryrun_summary(sys.argv[2] if len(sys.argv) > 2
                             else "multipod"))
    else:
        print(table())
