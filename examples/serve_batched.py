"""Batched serving example — the inference-side netty analogue: many
concurrent "connections" (requests) multiplexed onto an EventLoopGroup,
round-robin admission, mixed prompt lengths, continuous batching at
flush boundaries, and the serving collectives (KV gathering writes,
tensor-parallel logit reductions) flowing through the configured
CommBackend wire.

  PYTHONPATH=src python examples/serve_batched.py \
      [--arch qwen2-0.5b-reduced] [--event-loops 2] [--poll adaptive] \
      [--comm-mode hadronio]

  # two-level fabric (pods must divide the device count): pod-aware
  # leader-channel emission with the leader lane pinned to loop 0
  XLA_FLAGS=--xla_force_host_platform_device_count=8 \
  PYTHONPATH=src python examples/serve_batched.py --pods 2 \
      --comm-mode hadronio_overlap --aggregate channel --flush ready \
      --emission hierarchical

  # multi-tenant: a dense and an ssm model in ONE EventLoopGroup, 2:1
  # weighted-fair admission, per-tenant loop/channel ownership
  PYTHONPATH=src python examples/serve_batched.py \
      --tenant chat=qwen2-0.5b-reduced:2 --tenant rnn=rwkv6-7b-reduced:1
"""
import argparse
import time

import jax
import numpy as np

from repro.configs.registry import get_config
from repro.configs.base import CommConfig, ServeConfig
from repro.core.backends import available_modes
from repro.launch.serve import parse_tenant_specs
from repro.models import api
from repro.serving import Request, make_engine_group


def main():
    p = argparse.ArgumentParser()
    p.add_argument("--arch", default="qwen2-0.5b-reduced")
    p.add_argument("--tenant", action="append", default=[],
                   metavar="NAME=ARCH[:WEIGHT[:LOOPS]]",
                   help="repeatable: multi-tenant group (overrides "
                        "--arch; see docs/FAMILIES.md)")
    p.add_argument("--requests", type=int, default=12)
    p.add_argument("--max-batch", type=int, default=4)
    p.add_argument("--max-new", type=int, default=16)
    p.add_argument("--event-loops", type=int, default=2)
    p.add_argument("--poll", default="adaptive", choices=ServeConfig.POLLS)
    p.add_argument("--comm-mode", default="hadronio",
                   choices=available_modes())
    p.add_argument("--channels", type=int, default=4)
    p.add_argument("--aggregate", default="slice",
                   choices=CommConfig.AGGREGATES)
    p.add_argument("--flush", default="step", choices=CommConfig.FLUSHES)
    p.add_argument("--pods", type=int, default=1,
                   help="two-level fabric pod count (must divide devices)")
    p.add_argument("--pod-axis", default="pod")
    p.add_argument("--leader-loops", type=int, default=1)
    p.add_argument("--leader-channels", type=int, default=1)
    p.add_argument("--emission", default="flat",
                   choices=("flat", "hierarchical"))
    args = p.parse_args()

    tenants = parse_tenant_specs(args.tenant)
    if tenants:
        cfg = {t.name: get_config(t.arch) for t in tenants}
        params = {t.name: api.init(jax.random.PRNGKey(i), cfg[t.name])
                  for i, t in enumerate(tenants)}
        args.event_loops = sum(t.event_loops for t in tenants)
    else:
        cfg = get_config(args.arch)
        params = api.init(jax.random.PRNGKey(0), cfg)
    serve = ServeConfig(
        event_loops=args.event_loops, poll=args.poll,
        max_batch=args.max_batch, max_len=256,
        pods=args.pods, pod_axis=args.pod_axis,
        leader_loops=args.leader_loops, tenants=tenants,
        comm=CommConfig(mode=args.comm_mode, channels=args.channels,
                        aggregate=args.aggregate, flush=args.flush,
                        hierarchical=args.emission == "hierarchical",
                        leader_channels=args.leader_channels))
    group = make_engine_group(cfg, params, serve)
    if args.pods > 1:
        print(f"two-level fabric: pods={args.pods}, "
              f"emission={args.emission}, "
              f"leader lanes={args.leader_channels}")

    rng = np.random.default_rng(0)
    reqs = []
    for i in range(args.requests):
        name = tenants[i % len(tenants)].name if tenants else ""
        c = cfg[name] if tenants else cfg
        reqs.append(Request(uid=i,
                            prompt=rng.integers(0, c.vocab_size,
                                                size=int(rng.integers(4, 40))),
                            max_new=args.max_new,
                            temperature=0.0 if i % 2 else 0.8,
                            tenant=name))

    t0 = time.time()
    group.submit(reqs)
    results = sorted(group.run(threads=args.event_loops > 1),
                     key=lambda r: r.uid)
    dt = time.time() - t0
    n_tok = sum(len(r.tokens) for r in results)
    st = group.poll_stats()
    print(f"{len(results)} requests -> {n_tok} tokens in {dt:.2f}s "
          f"({n_tok/dt:.1f} tok/s on {jax.default_backend()}) | "
          f"{args.event_loops} loops, poll={args.poll} "
          f"(spins={st.spins} parks={st.parks}), comm={args.comm_mode}")
    if tenants:
        print(f"  tenants: fairness={group.fairness_counters} "
              f"dispatch={group.dispatch_log[:12]}")
    for loop in group.loops:
        print(f"  loop {loop.index}: owns channels {loop.channels}, "
              f"served {len(loop.results)}")
    for r in results[:5]:
        print(f"  uid={r.uid:2d} len={r.prompt_len:2d} "
              f"-> {r.tokens[:10].tolist()}")


if __name__ == "__main__":
    main()
