"""Batched serving example — the inference-side netty analogue: many
concurrent "connections" (requests) multiplexed onto one engine, with
round-robin admission and mixed prompt lengths.

  PYTHONPATH=src python examples/serve_batched.py [--arch rwkv6-7b-reduced]
"""
import argparse
import time

import jax
import numpy as np

from repro.configs.registry import get_config
from repro.models import api
from repro.serving import DecodeEngine, Request


def main():
    p = argparse.ArgumentParser()
    p.add_argument("--arch", default="qwen2-0.5b-reduced")
    p.add_argument("--requests", type=int, default=12)
    p.add_argument("--max-batch", type=int, default=4)
    p.add_argument("--max-new", type=int, default=16)
    args = p.parse_args()

    cfg = get_config(args.arch)
    params = api.init(jax.random.PRNGKey(0), cfg)
    engine = DecodeEngine(cfg, params, max_batch=args.max_batch,
                          max_len=256)

    rng = np.random.default_rng(0)
    reqs = [Request(uid=i,
                    prompt=rng.integers(0, cfg.vocab_size,
                                        size=int(rng.integers(4, 40))),
                    max_new=args.max_new,
                    temperature=0.0 if i % 2 else 0.8)
            for i in range(args.requests)]

    t0 = time.time()
    results = engine.generate(reqs)
    dt = time.time() - t0
    n_tok = sum(len(r.tokens) for r in results)
    print(f"{len(results)} requests -> {n_tok} tokens in {dt:.2f}s "
          f"({n_tok/dt:.1f} tok/s on {jax.default_backend()})")
    for r in results[:5]:
        print(f"  uid={r.uid:2d} len={r.prompt_len:2d} "
              f"-> {r.tokens[:10].tolist()}")


if __name__ == "__main__":
    main()
