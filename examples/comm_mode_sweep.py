"""The paper's experiment, end to end: train the SAME model under each
communication stack and show (a) identical loss trajectories — the
transparency claim — and (b) the collective-op schedule each mode emits —
the performance claim (hadroNIO's aggregation = fewer, larger sends).

  PYTHONPATH=src python examples/comm_mode_sweep.py
"""
import jax
import numpy as np

from repro import compat
from repro.configs.base import CommConfig, RunConfig, ShapeConfig
from repro.core.backends import available_modes, get_backend
from repro.configs.registry import get_config
from repro.data import DataConfig, SyntheticSource, batch_at
from repro.launch import hlo_analysis as hlo
from repro.launch import steps as steps_mod
from repro.launch.mesh import make_mesh
from repro.launch.train import Trainer

# every registered manual mode, paper order first (registry-derived:
# a new backend shows up here and in the parity assertion automatically)
PAPER = ("sockets", "vma", "hadronio", "hadronio_rs")
MODES = PAPER + tuple(m for m in available_modes()
                      if get_backend(m).manual and m not in PAPER)


def main():
    cfg = get_config("qwen1.5-4b-reduced")
    shape = ShapeConfig("sweep", "train", seq_len=64, global_batch=4)
    mesh = make_mesh((len(jax.devices()),), ("data",))
    n_dev = len(jax.devices())

    print(f"{'mode':12s} {'final loss':>10s} {'coll ops':>9s} "
          f"{'coll bytes':>12s}  trajectory")
    trajs = {}
    for mode in MODES:
        run = RunConfig(model=cfg, shape=shape,
                        comm=CommConfig(mode=mode, slice_bytes=128 * 1024,
                                        hierarchical=False),
                        lr=1e-3, total_steps=8, warmup_steps=2)
        # collective schedule from the compiled step
        with compat.set_mesh(mesh):
            step_fn, state_sh, batch_sh_fn = steps_mod.make_train_step(
                run, mesh)
            state = jax.device_put(
                steps_mod.init_tac_state(jax.random.PRNGKey(0), run, n_dev),
                state_sh)
            batch = batch_at(SyntheticSource(cfg.vocab_size, 0),
                             DataConfig(64, 4), 0)
            batch = jax.device_put(batch, batch_sh_fn(mesh, batch))
            stats = hlo.stablehlo_collective_stats(
                jax.jit(step_fn).lower(state, batch).as_text())

        out = Trainer(run, mesh, log_every=100,
                      log_fn=lambda s: None).run_loop()
        trajs[mode] = out["losses"]
        print(f"{mode:12s} {out['final_loss']:10.4f} "
              f"{stats.total_ops:9d} {stats.total_bytes:12d}  "
              f"{['%.3f' % l for l in out['losses'][:4]]}")

    ref = np.array(trajs["sockets"])
    for mode, t in trajs.items():
        assert np.max(np.abs(np.array(t) - ref)) < 2e-3, mode
    print("\nall modes: identical trajectories (transparency), "
          "different collective schedules (the paper's point).")


if __name__ == "__main__":
    main()
