"""End-to-end training driver: a ~100M-parameter LM trained for a few
hundred steps with the paper's aggregated gradient sync, fault-tolerant
checkpointing, and deterministic data.

Full run (a few hundred steps — sized for a real accelerator; on the CPU
container pass --steps 5 --seq-len 64 for a smoke run):

  PYTHONPATH=src python examples/train_100m.py --steps 300
  PYTHONPATH=src python examples/train_100m.py --steps 5 --seq-len 64 \
      --global-batch 4     # CPU smoke
"""
import argparse
import dataclasses

import jax

from repro.configs.base import CommConfig, ModelConfig, RunConfig, \
    ShapeConfig
from repro.launch.mesh import make_mesh
from repro.launch.train import Trainer, train_with_restarts

# ~100M-parameter decoder LM (a qwen2-family shape scaled to 100M):
# 12L d=640 10H kv=2 ff=2560 vocab=32000 -> ~104M params.
MODEL_100M = ModelConfig(
    name="lm-100m", family="dense", num_layers=12, d_model=640,
    num_heads=10, num_kv_heads=2, d_ff=2560, vocab_size=32000,
    qkv_bias=True, mlp_kind="swiglu", norm_kind="rmsnorm",
    rope_theta=10_000.0, param_dtype="float32", compute_dtype="float32",
    source="examples/train_100m.py")


def main():
    p = argparse.ArgumentParser()
    p.add_argument("--steps", type=int, default=300)
    p.add_argument("--seq-len", type=int, default=512)
    p.add_argument("--global-batch", type=int, default=8)
    p.add_argument("--mode", default="hadronio")
    p.add_argument("--ckpt", default="/tmp/train_100m_ckpt")
    p.add_argument("--microbatches", type=int, default=1)
    args = p.parse_args()

    print(f"model: {MODEL_100M.param_count()/1e6:.0f}M params")
    run = RunConfig(
        model=MODEL_100M,
        shape=ShapeConfig("e2e", "train", args.seq_len, args.global_batch),
        comm=CommConfig(mode=args.mode, hierarchical=False),
        lr=6e-4, total_steps=args.steps,
        warmup_steps=max(args.steps // 20, 1),
        microbatches=args.microbatches,
        checkpoint_dir=args.ckpt, checkpoint_every=50)
    mesh = make_mesh((len(jax.devices()),), ("data",))

    out = train_with_restarts(lambda: Trainer(run, mesh, log_every=10))
    print(f"done: loss {out['losses'][0]:.3f} -> {out['final_loss']:.3f} "
          f"over {len(out['losses'])} steps")


if __name__ == "__main__":
    main()
