"""Quickstart: train a tiny LM with the paper's aggregated gradient sync,
checkpoint it, and generate from it — the whole public API in ~60 lines.

  PYTHONPATH=src python examples/quickstart.py
"""
import os
import tempfile

import jax
import numpy as np

from repro.configs.base import CommConfig, RunConfig, ShapeConfig
from repro.configs.registry import get_config
from repro.launch.mesh import make_mesh
from repro.launch.train import Trainer
from repro.serving import DecodeEngine, Request


def main():
    # 1. pick an assigned architecture (reduced = CPU-sized, same family)
    cfg = get_config("qwen2-0.5b-reduced")
    print(f"model: {cfg.name} ({cfg.param_count()/1e6:.1f}M params)")

    # 2. a run config: the comm mode is the paper's technique — swap
    #    "hadronio" for "sockets"/"vma"/"gspmd" and NOTHING else changes.
    ckpt = tempfile.mkdtemp(prefix="quickstart_ckpt_")
    run = RunConfig(
        model=cfg,
        shape=ShapeConfig("quickstart", "train", seq_len=64, global_batch=4),
        comm=CommConfig(mode="hadronio", slice_bytes=256 * 1024,
                        hierarchical=False),
        lr=1e-3, total_steps=30, warmup_steps=3,
        checkpoint_dir=ckpt, checkpoint_every=10)

    # 3. train (single host; the same Trainer drives the 256-chip mesh)
    mesh = make_mesh((len(jax.devices()),), ("data",))
    out = Trainer(run, mesh, log_every=10).run_loop()
    print(f"loss: {out['losses'][0]:.3f} -> {out['final_loss']:.3f}")
    assert out["final_loss"] < out["losses"][0], "loss should decrease"

    # 4. serve the trained params with batched mixed-length requests
    params = out["state"].params
    engine = DecodeEngine(cfg, params, max_batch=4, max_len=128)
    results = engine.generate([
        Request(uid=0, prompt=np.arange(5) % cfg.vocab_size, max_new=8),
        Request(uid=1, prompt=np.arange(11) % cfg.vocab_size, max_new=8),
    ])
    for r in results:
        print(f"request {r.uid}: prompt_len={r.prompt_len} -> "
              f"{r.tokens.tolist()}")
    print("quickstart OK")


if __name__ == "__main__":
    main()
