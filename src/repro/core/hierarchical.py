"""Pod-aware two-level collectives (DESIGN.md §4, §8).

Cross-pod links (DCN / optical) are scarcer than in-pod ICI, exactly like
the paper's multi-rail transport selection in UCX. All-reduce over
(pod, data) is decomposed as: reduce-scatter in-pod -> all-reduce
cross-pod on 1/n_data of the bytes -> all-gather in-pod. Cross-pod traffic
drops by the in-pod width.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def psum_hierarchical(x: jax.Array, pod_axis: str | None,
                      data_axis: str) -> jax.Array:
    """All-reduce over (pod_axis, data_axis), pod-aware. x: (..., S) with S
    divisible by the data-axis size (TAC slices are padded to this)."""
    if pod_axis is None:
        return jax.lax.psum(x, data_axis)
    shard = jax.lax.psum_scatter(x, data_axis, scatter_dimension=x.ndim - 1,
                                 tiled=True)
    shard = jax.lax.psum(shard, pod_axis)
    return jax.lax.all_gather(shard, data_axis, axis=x.ndim - 1, tiled=True)


def psum_scatter_hierarchical(x: jax.Array, pod_axis: str | None,
                              data_axis: str) -> jax.Array:
    """Reduce-scatter over data (+ cross-pod all-reduce of the shard)."""
    shard = jax.lax.psum_scatter(x, data_axis, scatter_dimension=x.ndim - 1,
                                 tiled=True)
    if pod_axis is not None:
        shard = jax.lax.psum(shard, pod_axis)
    return shard


def all_gather_data(x: jax.Array, axes) -> jax.Array:
    """All-gather over one axis name or a tuple of axis names."""
    return jax.lax.all_gather(x, axes, axis=x.ndim - 1, tiled=True)
