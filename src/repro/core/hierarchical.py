"""Pod-aware two-level collectives (DESIGN.md §4, §8).

Cross-pod links (DCN / optical) are scarcer than in-pod ICI, exactly like
the paper's multi-rail transport selection in UCX. All-reduce over
(pod, data) is decomposed as: reduce-scatter in-pod -> all-reduce
cross-pod on 1/n_data of the bytes -> all-gather in-pod. Cross-pod traffic
drops by the in-pod width.

``psum_hierarchical`` pads internally when the trailing dim is not
divisible by the in-pod ring size (serving payloads are arbitrary-length
activation buffers, unlike TAC slices which are alignment-padded): the
zero tail scatters onto the last shard, survives the cross-pod sum as
zeros, and is trimmed after the gather — so flat and padded inputs see
identical per-element summation trees. ``psum_scatter_hierarchical``
keeps the divisibility requirement (a scatter RESULT is a 1/n shard;
transparent padding would change its meaning) and raises a clear error.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def in_group_size(axes) -> int:
    """Static ring size of one axis name or a tuple of names (the
    psum-of-1 idiom: constant-folds at trace time)."""
    return jax.lax.psum(1, axes)


def psum_hierarchical(x: jax.Array, pod_axis: str | None,
                      data_axis: str) -> jax.Array:
    """All-reduce over (pod_axis, data_axis), pod-aware. x: (..., S);
    a trailing dim not divisible by the in-pod ring size is zero-padded
    for the scatter and trimmed after the gather."""
    if pod_axis is None:
        return jax.lax.psum(x, data_axis)
    group = in_group_size(data_axis)
    s = x.shape[-1]
    pad = (-s) % group
    if pad:
        x = jnp.pad(x, [(0, 0)] * (x.ndim - 1) + [(0, pad)])
    shard = jax.lax.psum_scatter(x, data_axis, scatter_dimension=x.ndim - 1,
                                 tiled=True)
    shard = jax.lax.psum(shard, pod_axis)
    full = jax.lax.all_gather(shard, data_axis, axis=x.ndim - 1, tiled=True)
    return jax.lax.slice_in_dim(full, 0, s, axis=x.ndim - 1) if pad else full


def psum_scatter_hierarchical(x: jax.Array, pod_axis: str | None,
                              data_axis: str) -> jax.Array:
    """Reduce-scatter over data (+ cross-pod all-reduce of the shard).
    The trailing dim MUST divide by the in-pod ring size — the result is
    a 1/n shard, so padding cannot be hidden from the caller (TAC slices
    are alignment-padded to guarantee this)."""
    group = in_group_size(data_axis)
    if x.shape[-1] % group != 0:
        raise ValueError(
            f"psum_scatter_hierarchical: trailing dim {x.shape[-1]} is not "
            f"divisible by the in-pod ring size {group}; scatter shards "
            "cannot be transparently padded — pad the payload to the "
            "alignment first (aggregation.make_plan does)")
    shard = jax.lax.psum_scatter(x, data_axis, scatter_dimension=x.ndim - 1,
                                 tiled=True)
    if pod_axis is not None:
        shard = jax.lax.psum(shard, pod_axis)
    return shard


def all_gather_data(x: jax.Array, axes) -> jax.Array:
    """All-gather over one axis name or a tuple of axis names."""
    return jax.lax.all_gather(x, axes, axis=x.ndim - 1, tiled=True)
