"""``hadronio_overlap_rs`` — beyond-paper: bucketed ZeRO-1.

The composition the ROADMAP called out: ``hadronio_overlap``'s
reverse-layer bucketing (per-bucket collectives that depend only on
their own leaves, emitted before the loss epilogue) with
``hadronio_rs``'s reduce-scatter + data-sharded flat AdamW update
(:mod:`repro.optim.flat`). Each bucket reduce-scatters its OWN shard as
soon as its leaves exist, so the ZeRO-1 exchange overlaps the remaining
backward compute — Ibdxnet's point that the buffer scheme and the send
schedule must be co-designed (arXiv:1812.01963), applied to the ZeRO
path.

Layout: the peer's flat shard is the concatenation, in bucket order, of
its contiguous chunk of every bucket (chunk = padded_b / group). Buckets
are padded to lcm(512, scatter-group) so every bucket shards evenly;
with pod-aware collectives the scatter group is in-pod and shards
replicate across pods (hierarchical ZeRO). Error feedback is keyed by
bucket id, exactly as in ``hadronio_overlap``.
"""
from __future__ import annotations

import math
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import CommConfig, RunConfig
from repro.core import compress as comp
from repro.core.backends import pipeline
from repro.core.backends.base import (CommBackend, StateSpecs, SyncContext,
                                      SyncResult, UpdateContext, register,
                                      scatter_group_size)
from repro.core.backends.hadronio_overlap import (
    _ALIGN, BucketPlan, bucket_ef_result, bucket_ef_specs, make_bucket_plan,
    pack_bucket, pack_buckets_wire, stage_buckets, unpack_bucket)
from repro.core.flush_scheduler import make_flush_plan
from repro.core.hierarchical import all_gather_data
from repro.optim import adamw
from repro.optim.flat import flat_adamw_update, reshard_ring_segments

PyTree = Any


def rs_align(group: int) -> int:
    """Bucket padding alignment: every bucket must shard evenly over the
    scatter group AND keep the 512-lane alignment -> lcm."""
    return _ALIGN * group // math.gcd(_ALIGN, group)


def rs_bucket_plan(tree: PyTree, comm: CommConfig, group: int) -> BucketPlan:
    return make_bucket_plan(tree, comm, align=rs_align(group))


def bucket_decay_mask(plan: BucketPlan) -> jax.Array:
    """Per-element weight-decay mask in bucketed-flat layout (decay only
    >= 2-D leaves, matching adamw.update). Built from contiguous-run
    fills inside the trace, like optim.flat.decay_mask_traced."""
    mask = jnp.zeros((plan.total_padded,), jnp.float32)
    runs = []
    base = 0
    for b, idx in enumerate(plan.buckets):
        off, run_start = base, None
        for i in idx:
            if len(plan.shapes[i]) >= 2:
                if run_start is None:
                    run_start = off
                run_end = off + plan.sizes[i]
            elif run_start is not None:
                runs.append((run_start, run_end))
                run_start = None
            off += plan.sizes[i]
        if run_start is not None:
            runs.append((run_start, run_end))
        base += plan.padded[b]
    for s, e in runs:
        mask = jax.lax.dynamic_update_slice_in_dim(
            mask, jnp.ones((e - s,), jnp.float32), s, axis=0)
    return mask


def gather_flush_groups(plan: BucketPlan, comm: CommConfig) -> tuple:
    """Bucket ids per all-gather flush of the ZeRO-1 update epilogue.
    Under the flush-when-ready channel schedule the epilogue mirrors the
    sync's flush structure — keyed to CHANNEL FLUSHES rather than
    buckets: the ready groups are contiguous bucket runs, so each
    flush's chunk is contiguous in the flat-shard layout and one
    all-gather per flush returns the identical bytes as one per bucket
    (n_channels epilogue collectives instead of n_buckets). Every other
    schedule keeps the per-bucket epilogue."""
    if comm.aggregate == "channel" and comm.flush == "ready":
        fp = make_flush_plan(plan.n_buckets, comm.channels, "ready")
        if fp.contiguous:
            return fp.groups
    return tuple((b,) for b in range(plan.n_buckets))


def shard_of_buckets(vectors_by_bucket, plan: BucketPlan, group: int, my):
    """Concatenate this peer's contiguous chunk of every bucket vector —
    the flat-shard layout (bucket-major, ring-ordered chunks)."""
    parts = []
    for b, vec in enumerate(vectors_by_bucket):
        c = plan.padded[b] // group
        parts.append(jax.lax.dynamic_slice_in_dim(vec, my * c, c, axis=0))
    return jnp.concatenate(parts)


@register("hadronio_overlap_rs")
class HadronioOverlapRsBackend(CommBackend):

    zero1 = True

    def sync(self, grads, ctx: SyncContext) -> SyncResult:
        leaves, _ = jax.tree.flatten(grads)
        gather_axes, group = pipeline.scatter_group(ctx)
        plan = rs_bucket_plan(grads, ctx.comm, group)

        if ctx.comm.compress == "int8_ef":
            # per-bucket dequant-sum everywhere, keep this peer's chunk
            wires, new_efs, scales = pack_buckets_wire(leaves, plan, ctx)
            my = jax.lax.axis_index(gather_axes)
            shards = [
                jax.lax.dynamic_slice_in_dim(
                    comp.int8_allreduce(q, s, ctx.flat_axes).reshape(-1),
                    my * (plan.padded[b] // group),
                    plan.padded[b] // group, axis=0)
                for b, (q, s) in enumerate(zip(wires, scales))]
        else:
            # staged per-bucket reduce-scatter through the channel
            # schedule: buckets are packed and staged in production
            # order, so under flush="ready" each channel's coalesced
            # flush (peer-major interleaved — each bucket's shard and
            # the flat-shard bucket ordering are unchanged) goes out the
            # moment its last bucket exists; the fused unpack stage runs
            # per flush inside the emitter.
            reduced, new_efs = stage_buckets(leaves, plan, ctx,
                                             "reduce_scatter", group=group)
            shards = [r.reshape(-1) for r in reduced]
        flat_shard = jnp.concatenate(shards)
        return SyncResult(None, flat_shard, plan, bucket_ef_result(new_efs),
                          gather_axes)

    def serve_emit(self, flat, ctx, kind):
        """Serving payloads flush when ready (same rationale as the
        tree-overlap mode: the latency-critical path never waits for the
        step barrier). Emission structure only — bit-identical values."""
        import dataclasses

        from repro.core.backends import pipeline as pl
        ready = dataclasses.replace(ctx.comm, flush="ready")
        rctx = dataclasses.replace(ctx, comm=ready)
        group = jax.lax.psum(1, ctx.flat_axes) \
            if kind in ("all_gather", "all_to_all") else 1
        return pl.emit_flat(flat, rctx, kind, group=group)

    def state_specs(self, run: RunConfig, n_shards: int,
                    pod_size: int = 1) -> StateSpecs:
        """Flat ZeRO-1 moment shards in bucketed layout (leading ring dim
        makes each peer's shard explicit), per-bucket error feedback."""
        from repro.models import api
        params = api.abstract(run.model)
        eff = scatter_group_size(n_shards, pod_size, run.comm)
        plan = rs_bucket_plan(params, run.comm, eff)
        ef = bucket_ef_specs(plan, n_shards) if self.needs_ef(run.comm) \
            else None
        shard = jax.ShapeDtypeStruct(
            (n_shards, plan.total_padded // eff), jnp.float32)
        opt = adamw.AdamState(mu=shard, nu=shard,
                              count=jax.ShapeDtypeStruct((), jnp.int32))
        return StateSpecs(opt=opt, ef=ef)

    def apply_update(self, params: PyTree, opt: adamw.AdamState,
                     res: SyncResult, run: RunConfig,
                     uctx: UpdateContext):
        """Bucketed ZeRO-1: update this peer's flat param/moment shard,
        then all-gather the updated parameters PER BUCKET (independent,
        overlappable). With hierarchical collectives the shard index is
        in-pod."""
        plan: BucketPlan = res.plan
        eff = uctx.eff_shards
        leaves_p, treedef = jax.tree.flatten(params)
        my = jax.lax.axis_index(res.gather_axes)
        psl = shard_of_buckets(
            [pack_bucket(leaves_p, plan, b) for b in range(plan.n_buckets)],
            plan, eff, my)
        gsh = res.flat_shard
        # grad clip on the global flat grad norm (shards replicate across
        # pods in hierarchical mode: normalize the psum)
        gn2 = jax.lax.psum(jnp.sum(jnp.square(gsh)), uctx.axes)
        gn2 = gn2 / (uctx.n_shards // eff)
        gnorm = jnp.sqrt(gn2)
        scale = jnp.minimum(1.0, run.grad_clip / jnp.maximum(gnorm, 1e-12))
        gsh = gsh * scale
        mask = bucket_decay_mask(plan)
        dm = shard_of_buckets(
            [jax.lax.slice_in_dim(mask, sum(plan.padded[:b]),
                                  sum(plan.padded[:b]) + plan.padded[b])
             for b in range(plan.n_buckets)], plan, eff, my)
        count = opt.count + 1
        new_psl, new_mu, new_nu = flat_adamw_update(
            psl, gsh, opt.mu[0], opt.nu[0], count, dm, run)
        out: list = [None] * len(leaves_p)
        off = 0
        new_psl = new_psl.astype(jnp.float32)
        # epilogue all-gathers keyed to the flush schedule: one gather
        # per channel flush under flush="ready" (contiguous bucket runs
        # in the flat layout), one per bucket otherwise — identical
        # bytes either way
        for grp in gather_flush_groups(plan, run.comm):
            glen = sum(plan.padded[b] // eff for b in grp)
            shard_g = jax.lax.slice_in_dim(new_psl, off, off + glen,
                                           axis=0)
            full_g = all_gather_data(shard_g, res.gather_axes)
            mat = full_g.reshape(eff, glen)
            coff = 0
            for b in grp:
                c = plan.padded[b] // eff
                full_b = jax.lax.slice_in_dim(
                    mat, coff, coff + c, axis=1).reshape(-1)
                unpack_bucket(full_b, plan, b, leaves_p, out)
                coff += c
            off += glen
        new_params = jax.tree.unflatten(treedef, out)
        new_opt = adamw.AdamState(new_mu[None], new_nu[None], count)
        metrics = {"grad_norm": gnorm, "lr": adamw.schedule(run, count)}
        return new_params, new_opt, metrics

    def gathered_grads(self, res: SyncResult, like: PyTree) -> PyTree:
        """Reconstruct the synced gradient tree: per-bucket all-gather of
        the shard chunks, then the inverse carve."""
        plan: BucketPlan = res.plan
        like_leaves, treedef = jax.tree.flatten(like)
        out: list = [None] * len(like_leaves)
        group = plan.total_padded // res.flat_shard.shape[0]
        off = 0
        for b in range(plan.n_buckets):
            c = plan.padded[b] // group
            shard_b = jax.lax.slice_in_dim(res.flat_shard, off, off + c,
                                           axis=0)
            full_b = all_gather_data(shard_b, res.gather_axes)
            unpack_bucket(full_b, plan, b, like_leaves, out)
            off += c
        return jax.tree.unflatten(treedef, out)

    def reshard_flat_shards(self, run: RunConfig, stacked, new_shards: int):
        """Elastic re-slice of the bucketed flat moments. When the bucket
        plan is ring-size-invariant (the scatter group divides the 512
        alignment for both ring sizes — the common power-of-two case) the
        old values are re-sliced exactly. A non-power-of-two group changes
        the lcm(512, group) bucket padding itself, so the old flat layout
        has no element-preserving mapping: take the replan-and-reinit path
        — rebuild the plan at the new alignment and reinitialize the flat
        moments to zero (AdamW warms them back up over ~1/(1-beta) steps;
        the parameters are replicated and untouched)."""
        import numpy as np
        from repro.models import api
        old_shards = stacked.shape[0]
        eff_old = scatter_group_size(old_shards, 1, run.comm)
        eff_new = scatter_group_size(new_shards, 1, run.comm)
        if rs_align(eff_old) != rs_align(eff_new):
            plan = rs_bucket_plan(api.abstract(run.model), run.comm,
                                  eff_new)
            return np.zeros((new_shards, plan.total_padded // eff_new),
                            np.float32)
        plan = rs_bucket_plan(api.abstract(run.model), run.comm, eff_old)
        return reshard_ring_segments(stacked, old_shards, new_shards,
                                     plan.padded)
