"""``sockets`` — the plain-sockets baseline (paper's JSOR comparison
point): one ``psum`` per gradient tensor. Per-buffer sends, fixed cost
paid per tensor; no aggregation, no plan, no packing."""
from __future__ import annotations

import jax

from repro.configs.base import CommConfig
from repro.core.backends.base import (CommBackend, SyncContext, SyncResult,
                                      register)


@register("sockets")
class SocketsBackend(CommBackend):

    def needs_ef(self, comm: CommConfig) -> bool:
        return False

    def validate(self, comm: CommConfig) -> None:
        if comm.compress != "none":
            raise ValueError(
                "sockets cannot honor wire compression "
                f"(compress={comm.compress!r}): each tensor is psum'd "
                "unpacked — there is no wire stage to compress; use a "
                "hadronio-family mode")

    def sync(self, grads, ctx: SyncContext) -> SyncResult:
        self.validate(ctx.comm)
        synced = jax.tree.map(lambda g: jax.lax.psum(g, ctx.flat_axes),
                              grads)
        return SyncResult(synced, None, None, None)

    def serve_emit(self, flat, ctx, kind):
        """Per-buffer serving sends: one unsliced collective per payload
        tensor — the plain-sockets baseline, no aggregation."""
        from repro.core.backends import pipeline
        return pipeline.raw_emit(flat, ctx, kind)
