"""``sockets`` — the plain-sockets baseline (paper's JSOR comparison
point): one ``psum`` per gradient tensor. Per-buffer sends, fixed cost
paid per tensor; no aggregation, no plan, no packing."""
from __future__ import annotations

import jax

from repro.core.backends.base import (CommBackend, SyncContext, SyncResult,
                                      register)


@register("sockets")
class SocketsBackend(CommBackend):

    def sync(self, grads, ctx: SyncContext) -> SyncResult:
        synced = jax.tree.map(lambda g: jax.lax.psum(g, ctx.flat_axes),
                              grads)
        return SyncResult(synced, None, None, ctx.ef)
