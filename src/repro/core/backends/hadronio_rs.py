"""``hadronio_rs`` — beyond-paper: per-slice reduce-scatter with a
data-sharded (ZeRO-1) optimizer. Each peer reduces + keeps 1/ring of
every slice, updates its flat parameter/moment shard, and all-gathers the
updated parameter slices back (per slice, independent — overlappable).
With hierarchical collectives the scatter group is in-pod and shards
replicate across pods (hierarchical ZeRO). ``comm.aggregate="channel"``
coalesces each channel's slices into one peer-major-interleaved
reduce-scatter flush; the ZeRO-1 flat-shard layout is unchanged
(pipeline.interleave_for_scatter)."""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import CommConfig, RunConfig
from repro.core import aggregation as agg
from repro.core.backends import pipeline
from repro.core.backends.base import (CommBackend, StateSpecs, SyncContext,
                                      SyncResult, UpdateContext, register,
                                      scatter_group_size)
from repro.core.hierarchical import all_gather_data
from repro.optim import adamw
from repro.optim.flat import (decay_mask_traced, flat_adamw_update,
                              reshard_ring_segments)

PyTree = Any


def gather_updated(flat_shard: jax.Array, plan: agg.PackPlan,
                   like: PyTree, comm: CommConfig, *,
                   gather_axes=("data",)) -> PyTree:
    """ZeRO-1 epilogue: all-gather updated parameter slices (per slice,
    independent — overlappable) and unpack into the parameter tree.
    ``gather_axes``: the axes the shard was reduce-scattered over (from
    SyncResult.gather_axes)."""
    n = plan.n_slices
    shard = flat_shard.reshape(n, -1)
    outs = [all_gather_data(shard[i], gather_axes) for i in range(n)]
    return agg.unpack(agg.from_slices(jnp.stack(outs), plan), plan, like)


@register("hadronio_rs")
class HadronioRsBackend(CommBackend):

    zero1 = True

    def sync(self, grads, ctx: SyncContext) -> SyncResult:
        plan = agg.make_plan(grads, ctx.comm, dtype=jnp.float32)
        flat = agg.pack(grads, plan)
        slices = agg.as_slices(flat, plan)
        flat_shard, new_ef, gather_axes = pipeline.scatter_slices(slices, ctx)
        return SyncResult(None, flat_shard, plan, new_ef, gather_axes)

    def state_specs(self, run: RunConfig, n_shards: int,
                    pod_size: int = 1) -> StateSpecs:
        """Flat ZeRO-1 moment shards; the leading ring dim makes each
        peer's shard explicit (global (n_shards, len), local (1, len))."""
        from repro.models import api
        params = api.abstract(run.model)
        plan = agg.make_plan(params, run.comm)
        ef = None
        if self.needs_ef(run.comm):
            ef = jax.ShapeDtypeStruct(
                (n_shards, plan.n_slices, plan.slice_elems), jnp.float32)
        eff = scatter_group_size(n_shards, pod_size, run.comm)
        assert plan.padded_elems % eff == 0, (plan.padded_elems, eff)
        shard = jax.ShapeDtypeStruct(
            (n_shards, plan.padded_elems // eff), jnp.float32)
        opt = adamw.AdamState(mu=shard, nu=shard,
                              count=jax.ShapeDtypeStruct((), jnp.int32))
        return StateSpecs(opt=opt, ef=ef)

    def apply_update(self, params: PyTree, opt: adamw.AdamState,
                     res: SyncResult, run: RunConfig,
                     uctx: UpdateContext):
        """ZeRO-1: update this peer's flat param/moment shard, then
        all-gather the updated parameter slices (per slice). With
        hierarchical collectives the shard index is in-pod."""
        eff_shards = uctx.eff_shards
        flat_p = agg.pack(params, res.plan)
        nsl = res.plan.n_slices
        my = jax.lax.axis_index(res.gather_axes)
        psl = flat_p.reshape(nsl, eff_shards, -1)[:, my].reshape(-1)
        gsh = res.flat_shard
        # grad clip on the global flat grad norm (shards replicate
        # across pods in hierarchical mode: normalize the psum)
        gn2 = jax.lax.psum(jnp.sum(jnp.square(gsh)), uctx.axes)
        gn2 = gn2 / (uctx.n_shards // eff_shards)
        gnorm = jnp.sqrt(gn2)
        scale = jnp.minimum(1.0, run.grad_clip / jnp.maximum(gnorm, 1e-12))
        gsh = gsh * scale
        dm = decay_mask_traced(res.plan).reshape(nsl, eff_shards, -1)[:, my]
        count = opt.count + 1
        new_psl, new_mu, new_nu = flat_adamw_update(
            psl, gsh, opt.mu[0], opt.nu[0], count, dm.reshape(-1), run)
        new_params = gather_updated(
            new_psl.astype(jnp.float32), res.plan, params, run.comm,
            gather_axes=res.gather_axes)
        new_opt = adamw.AdamState(new_mu[None], new_nu[None], count)
        metrics = {"grad_norm": gnorm, "lr": adamw.schedule(run, count)}
        return new_params, new_opt, metrics

    def gathered_grads(self, res: SyncResult, like: PyTree) -> PyTree:
        """Reconstruct the synced gradient tree from the ZeRO-1 shard
        (per-slice all-gather + unpack)."""
        return gather_updated(res.flat_shard, res.plan, like, None,
                              gather_axes=res.gather_axes)

    def reshard_flat_shards(self, run: RunConfig, stacked, new_shards: int):
        """Elastic re-slice: the global flat layout is slice-major with
        ring-ordered chunks — n_slices equal segments."""
        from repro.models import api
        plan = agg.make_plan(api.abstract(run.model), run.comm)
        return reshard_ring_segments(stacked, stacked.shape[0], new_shards,
                                     [plan.slice_elems] * plan.n_slices)
