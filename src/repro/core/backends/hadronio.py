"""``hadronio`` — the paper-faithful gathering write (§III-C): pack the
gradient pytree into ring-buffer slices, then one INDEPENDENT collective
per slice, each issued through a round-robin-assigned CommChannel (the
worker-per-connection analogue). The XLA latency-hiding scheduler
overlaps the independent collectives with compute and each other.
``comm.aggregate="channel"`` raises the flush granularity to one
coalesced wire buffer per channel (n_channels collectives per exchange
instead of n_slices) with bit-identical results — see
pipeline.emit_through_channels."""
from __future__ import annotations

import jax.numpy as jnp

from repro.core import aggregation as agg
from repro.core.backends import pipeline
from repro.core.backends.base import (CommBackend, SyncContext, SyncResult,
                                      register)


@register("hadronio")
class HadronioBackend(CommBackend):

    def sync(self, grads, ctx: SyncContext) -> SyncResult:
        plan = agg.make_plan(grads, ctx.comm, dtype=jnp.float32)
        flat = agg.pack(grads, plan)
        slices = agg.as_slices(flat, plan)
        red, new_ef = pipeline.reduce_slices(slices, ctx)
        synced = agg.unpack(agg.from_slices(red, plan), plan, grads)
        return SyncResult(synced, None, plan, new_ef)
