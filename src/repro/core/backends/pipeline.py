"""The composable slice pipeline shared by the hadronio-family backends.

One gradient exchange is a fixed sequence of stages, written once here
instead of per-branch in every mode:

    pack -> ring-buffer plan -> pack stage (cast/EF) -> per-channel
    collective -> unpack stage -> unpack

``pack``/``plan`` live in :mod:`repro.core.aggregation` (the gathering
write); this module owns the wire stages:

* :func:`channels_for` — build the connection pool for a resolved axis
  topology (pod-aware when the context says so).
* :func:`pack_wire` — the pack stage: the fused add-error-feedback /
  cast-to-wire-dtype copy pass (the paper's §III-C gathering-write hot
  spot). ``comm.pack`` selects the implementation: ``"pallas"`` runs the
  fused one-HBM-pass kernel (kernels/ring_pack.py, interpret mode
  off-TPU), ``"jnp"`` the reference elementwise path; both produce
  bit-identical wire bytes. Selection falls back through
  :func:`repro.compat.pallas_available` so pallas-less environments run
  every backend unchanged. int8 needs a per-slice amax reduction the
  kernel does not fuse, so it always takes the jnp path.
* :func:`begin_emission` / :func:`stage_slices` / :func:`flush_ready` /
  :func:`finish_emission` — the worker-per-connection schedule as a
  STAGED emission: wire buffers are staged one at a time in production
  order and flushed per the bucket->channel schedule from
  :mod:`repro.core.flush_scheduler` (``comm.flush``: round-robin with
  one end-of-exchange flush loop under ``"step"``; contiguous
  production-order groups flushed the moment they fill under
  ``"ready"`` — hadroNIO's flush-on-writable, §III-B). The flush
  granularity is ``comm.aggregate``. Under ``"slice"`` each channel
  issues its collectives IN ORDER (an ``optimization_barrier`` chains
  consecutive ops on the same channel — the selector's ordering lever
  from :mod:`repro.core.selector`), while different channels stay
  data-independent. Under ``"channel"`` every channel coalesces its
  slices into ONE contiguous wire buffer and flushes a single collective
  — hadroNIO's ring-buffer gathering write (§III-C, §V-B), where many
  small application writes become one large UCX request per connection.
  :func:`emit_through_channels` is the one-shot wrapper over the four.
* :func:`unpack_wire` — the unpack stage (the scattering-read
  counterpart of the pack stage): one fused cast-from-wire-dtype +
  re-slice HBM pass over the stacked collective results, replacing the
  old per-slice ``.astype(f32)`` epilogue. Implementation selection is
  the same ``comm.pack`` switch (kernels/ring_pack.unpack_slices_kernel
  vs jnp), with identical outputs.
* :func:`reduce_slices` / :func:`scatter_slices` — pack stage + per-slice
  all-reduce / reduce-scatter + unpack stage composed over the channel
  schedule.

Under a pod-aware context with ``comm.aggregate="channel"`` the staged
emission runs the TWO-LEVEL **leader-channel** schedule (the UCX
multi-rail analogue: cross-pod links are the scarce resource and get
dedicated connections): the pool is carved into LOCAL lanes and
``comm.leader_channels`` LEADER lanes (:func:`channels_for`). A local
lane's coalesced flush becomes the IN-POD stage only (reduce-scatter /
gather over the data axis) and parks its 1/n_data intermediate; each
leader lane coalesces the intermediates of its assigned local lanes
(``flush_scheduler.make_leader_plan``) into ONE cross-pod collective,
carves them back, and the in-pod return stage completes per lane. Under
``comm.flush="ready"`` the leader flush fires the moment its last local
lane stages (each pod's local flush triggers the leader flush —
hadroNIO's flush-on-writable applied across the hierarchy), not at a
global barrier. Cross-pod collective count drops from n_channels to
n_leader_channels; numerics are bit-identical to the per-channel
hierarchical path (identical per-element summation trees — concatenation
before an elementwise psum changes nothing; gathers are data movement).
The ``all_to_all`` kind (the MoE expert exchange, serving path) is the
one exception: it carries source-target traffic over the full flattened
ring and bypasses the leader split entirely (see
:func:`begin_emission`).

Backends compose these; none of them re-implements a stage.
"""
from __future__ import annotations

import contextlib
import contextvars
from dataclasses import dataclass, field
from typing import Any

import jax
import jax.numpy as jnp

from repro import compat
from repro.configs.base import CommConfig
from repro.core import compress as comp
from repro.core.channels import ChannelFill, CommChannel, make_channels
from repro.core.flush_scheduler import (FlushPlan, make_flush_plan,
                                        make_leader_plan)
from repro.core.hierarchical import in_group_size
from repro.core.selector import barrier
from repro.obs import trace as obs_trace

from repro.core.backends.base import SyncContext

_KINDS = ("all_reduce", "reduce_scatter", "all_gather", "all_to_all")

# ---------------------------------------------------------------------------
# Chaos seam: an injectable flush fault (serving/chaos.py). The callable is
# consulted by flush_ready() once per READY channel with the channel's pool
# position and returns "drop" (defer the flush — the finish_emission step
# barrier recovers it), "dup" (flush twice; re-emitting the identical
# collective is idempotent, XLA dedups/DCEs the shadow), or None. Faults act
# at TRACE time, so a seeded plan yields a deterministic injection trace, and
# the staged-emission completeness contract guarantees recovery: every drop
# is re-flushed at the barrier, every dup overwrites outs with equal values.
# ---------------------------------------------------------------------------

_FLUSH_FAULT = None


def set_flush_fault(fault) -> None:
    """Install ``fault(channel) -> "drop" | "dup" | None`` on the staged
    emission's flush path. Callers MUST pair with
    :func:`clear_flush_fault` (try/finally); the serve-step cache
    (``serving/dispatch.py``) is bypassed while a fault is armed so a
    faulted trace never poisons fault-free callers."""
    global _FLUSH_FAULT
    _FLUSH_FAULT = fault


def clear_flush_fault() -> None:
    global _FLUSH_FAULT
    _FLUSH_FAULT = None


def flush_fault_active() -> bool:
    return _FLUSH_FAULT is not None


# ---------------------------------------------------------------------------
# Allocator seam: a buffer-pool hook on the staged emission path. The staged
# emission materialises ONE coalesced wire buffer per channel flush (and one
# wire buffer per item under aggregate="slice") — the ring-buffer allocation
# of the paper's §III-C connection-granularity design. The hook is consulted
# with (global channel index, wire bytes) right before that buffer is built;
# it may sleep (host memory pressure / gc thrash — the chaos class ROADMAP
# asked for) or raise (pool exhaustion), and like the flush fault it acts at
# TRACE time, so seeded plans replay deterministically and the serve-step
# cache is bypassed while armed (dispatch checks fault_active()).
# ---------------------------------------------------------------------------

_ALLOC_HOOK = None


@dataclass
class EmissionStats:
    """Trace-time emission counters (cumulative module state — consumers
    snapshot and diff): ``drops``/``dups`` = flush-fault verdicts applied,
    ``allocs`` = wire-buffer allocations consulted. Deterministic for a
    given program trace, which is what makes them usable as supervisor
    health signals (``serving/supervisor.py`` diffs drops around each
    drain to detect dropped flushes without any wall clock)."""
    drops: int = 0
    dups: int = 0
    allocs: int = 0


EMISSION_STATS = EmissionStats()

# Scoped emission stats: mutation sites write to the ACTIVE scope — the
# module global unless a stats_scope() is armed on this context. Scopes
# are contextvars, so parallel tests and the supervisor's worker threads
# stop racing on global resets; code that never arms a scope (and the
# default scope itself) sees the historical module-global behavior
# unchanged.
_STATS_SCOPE: contextvars.ContextVar = contextvars.ContextVar(
    "emission_stats", default=None)


def current_stats() -> EmissionStats:
    """The EmissionStats all mutation sites write to: the innermost
    armed :func:`stats_scope`, else the module-global ``EMISSION_STATS``."""
    st = _STATS_SCOPE.get()
    return EMISSION_STATS if st is None else st


@contextlib.contextmanager
def stats_scope(stats: EmissionStats = None):
    """Arm a private EmissionStats for the duration of the block (and
    any jit TRACING it triggers — the counters are trace-time). Yields
    the scoped stats; nested scopes shadow, the module global is the
    default scope when none is armed."""
    st = EmissionStats() if stats is None else stats
    tok = _STATS_SCOPE.set(st)
    try:
        yield st
    finally:
        _STATS_SCOPE.reset(tok)


def set_alloc_hook(hook) -> None:
    """Install ``hook(channel_index, nbytes)`` on every staged wire-buffer
    allocation. Pair with :func:`clear_alloc_hook` (try/finally)."""
    global _ALLOC_HOOK
    _ALLOC_HOOK = hook


def clear_alloc_hook() -> None:
    global _ALLOC_HOOK
    _ALLOC_HOOK = None


def alloc_hook_active() -> bool:
    return _ALLOC_HOOK is not None


def fault_active() -> bool:
    """Any trace-affecting fault armed (flush fault OR alloc hook) — the
    serve-step cache gate (``serving/dispatch.py``)."""
    return _FLUSH_FAULT is not None or _ALLOC_HOOK is not None


def _consult_alloc(channel_index: int, flats: list) -> None:
    current_stats().allocs += 1
    if _ALLOC_HOOK is not None:
        nbytes = sum(int(f.size) * f.dtype.itemsize for f in flats)
        _ALLOC_HOOK(channel_index, nbytes)


def leader_emission(ctx: SyncContext, pool_size: int) -> bool:
    """True when the two-level leader-channel schedule applies: pod-aware
    context, channel-granularity flushes, and a pool big enough to carve
    (a 1-channel pool keeps the per-channel hierarchical path)."""
    return (ctx.pod_axis is not None and ctx.comm.aggregate == "channel"
            and pool_size >= 2)


def _leader_split(ctx: SyncContext, idx: tuple) -> tuple:
    """Carve the emitting pool into (local, leader) channel ids. The
    GLOBAL leader lanes are the last ``comm.leader_channels`` ids of the
    ``comm.channels`` pool (the topology-aware affinity pins exactly
    those to the designated leader loops); an emitting pool that owns
    none — a non-leader event loop — promotes its last owned lane, so
    every loop can complete its cross-pod stage independently (numerics
    are invariant to which lane carries it). A pool is never left
    without a local lane."""
    n_lead = min(ctx.comm.leader_channels, ctx.comm.channels - 1)
    tail = range(ctx.comm.channels - n_lead, ctx.comm.channels)
    leads = tuple(i for i in idx if i in tail)
    locs = tuple(i for i in idx if i not in tail)
    if not leads:
        locs, leads = idx[:-1], (idx[-1],)
    if not locs:
        locs, leads = (leads[0],), leads[1:]
    return locs, leads


def channels_for(ctx: SyncContext, n_slices: int) -> list[CommChannel]:
    """The connection pool: at most ``comm.channels`` workers, pod-aware
    when the context resolved a pod axis. A context carrying
    ``channel_indices`` (the event-loop channel-affinity API) gets
    exactly that disjoint run of the global pool instead — the emitting
    event loop OWNS those channels (serving/event_loop.py). Under the
    two-level schedule (:func:`leader_emission`) the pool's leader lanes
    come back flagged ``leader=True``, locals first."""
    if ctx.channel_indices:
        idx = tuple(ctx.channel_indices)[:max(1, n_slices)]
    else:
        idx = tuple(range(max(1, min(ctx.comm.channels, n_slices))))
    leaders = frozenset()
    if leader_emission(ctx, len(idx)):
        locs, leads = _leader_split(ctx, idx)
        idx = locs + leads
        leaders = frozenset(leads)
    return make_channels(len(idx), ctx.flat_axes, pod_axis=ctx.pod_axis,
                         data_axis=ctx.data_axis, indices=idx,
                         leaders=leaders)


def pack_impl(comm: CommConfig) -> str:
    """Resolve the pack/unpack-stage implementation: honor ``comm.pack``
    when the pallas toolchain is importable, else fall back to jnp."""
    if comm.pack == "pallas" and compat.pallas_available():
        return "pallas"
    return "jnp"


def pack_wire(slices: jax.Array, ef, comm: CommConfig):
    """The pack stage over a ``(n, S)`` slice view: one fused pass doing
    add-EF, cast-to-wire-dtype, and residual capture.

    Returns ``(wire, new_ef, int8_scale)``. ``new_ef`` is None when the
    codec carries no residual; a non-None ``int8_scale`` signals that the
    caller must use :func:`comp.int8_allreduce`-style summation."""
    if comm.compress == "int8_ef":
        # amax reduction + quant: jnp path regardless of comm.pack
        q, scale, new_ef = comp.int8_quantize(slices, ef)
        return q, new_ef, scale
    with_ef = comm.compress == "bf16"
    wire_dtype = "bfloat16" if with_ef else jnp.dtype(slices.dtype).name
    if pack_impl(comm) == "pallas":
        from repro.kernels import ops
        n, s = slices.shape
        wire, new_ef = ops.pack_slices(slices.reshape(-1), ef, n_slices=n,
                                       slice_elems=s, wire_dtype=wire_dtype,
                                       with_ef=with_ef)
        return wire, new_ef, None
    if with_ef:
        wire, new_ef = comp.bf16_compress(slices, ef)
        return wire, new_ef, None
    return slices, None, None


def unpack_wire(wire: jax.Array, comm: CommConfig,
                out_dtype=jnp.float32) -> jax.Array:
    """The unpack stage — the paper's scattering read (§III-C): one fused
    cast-from-wire-dtype + re-slice HBM pass over the stacked ``(n, S)``
    collective results, instead of one ``.astype`` round trip per slice.
    ``comm.pack`` selects the implementation exactly like the pack stage
    (pallas kernel vs jnp reference; bit-identical outputs). A wire
    already in ``out_dtype`` needs no pass at all."""
    if wire.dtype == jnp.dtype(out_dtype):
        return wire
    if pack_impl(comm) == "pallas":
        from repro.kernels import ops
        return ops.unpack_slices(
            wire, out_dtype=jnp.dtype(out_dtype).name).reshape(wire.shape)
    return wire.astype(out_dtype)


def interleave_for_scatter(flats: list, group: int) -> jax.Array:
    """Peer-major coalescing of 1-D wire buffers for ONE reduce-scatter
    flush: peer ``p``'s contiguous ``1/group`` chunk of the result is the
    concatenation of ``p``'s chunk of every buffer, in buffer order — so
    a coalesced reduce-scatter hands every peer exactly the same
    per-slice shards (and therefore the same ZeRO-1 flat-shard ordering)
    as one collective per slice."""
    if len(flats) == 1:
        return flats[0]
    return jnp.concatenate([f.reshape(group, -1) for f in flats],
                           axis=1).reshape(-1)


def _scattered_shape(shape: tuple, group: int) -> tuple:
    return shape[:-1] + (shape[-1] // group,)


@dataclass
class EmitState:
    """In-flight state of one staged emission (built by
    :func:`begin_emission`, driven by :func:`stage_slices` /
    :func:`flush_ready`, closed by :func:`finish_emission`)."""
    ctx: SyncContext
    kind: str
    group: int
    unpack: bool                  # run the unpack stage per flush
    plan: FlushPlan
    chans: list                   # CommChannel pool
    fills: list                   # per-channel ChannelFill watermark
    staged: dict                  # item id -> wire array
    outs: list                    # per-item results
    last: dict                    # channel idx -> previous collective
    #                               output (aggregate="slice" chaining)
    # -- two-level leader emission (empty leads = flat schedule) --------
    leads: list = field(default_factory=list)   # leader CommChannels
    lplan: FlushPlan = None       # local lane -> leader lane schedule
    lfills: list = field(default_factory=list)  # per-leader ChannelFill
    pending: dict = field(default_factory=dict)  # local lane id -> parked
    #                               in-pod intermediate (awaiting leader)
    lpad: dict = field(default_factory=dict)     # local lane id -> zero
    #                               pad added for in-pod divisibility
    span: Any = None              # open obs emission-span token (or None)


def _unpack_flush(buf: jax.Array, comm: CommConfig) -> jax.Array:
    """Unpack stage over ONE flushed buffer (any shape): the fused
    cast-from-wire-dtype pass keyed to the flush, not the bucket."""
    if buf.dtype == jnp.float32:
        return buf
    return unpack_wire(buf.reshape(1, -1), comm).reshape(buf.shape)


def _carve_reduce(st: EmitState, c: int, red: jax.Array) -> None:
    """Carve one lane's fully reduced buffer back per item (all_reduce) —
    the scattering read."""
    red = _unpack_flush(red, st.ctx.comm) if st.unpack else red
    off = 0
    for i in st.plan.groups[c]:
        n = st.staged[i].size
        st.outs[i] = jax.lax.slice_in_dim(red, off, off + n).reshape(
            st.staged[i].shape)
        off += n


def _carve_gather(st: EmitState, c: int, g: jax.Array) -> None:
    """Carve one lane's gathered buffer back per item: the tiled result
    is peer-major over the whole coalesced buffer, so item i's gathered
    bytes are the same column range of every peer block."""
    g = (_unpack_flush(g, st.ctx.comm) if st.unpack
         else g).reshape(st.group, -1)
    off = 0
    for i in st.plan.groups[c]:
        n = st.staged[i].size
        st.outs[i] = jax.lax.slice(g, (0, off),
                                   (st.group, off + n)).reshape(-1)
        off += n


def _carve_alltoall(st: EmitState, c: int, ex: jax.Array) -> None:
    """Carve one lane's exchanged buffer back per item (all_to_all): the
    coalesced wire is peer-major (:func:`interleave_for_scatter`), so the
    exchanged result's row ``p`` holds peer ``p``'s chunk of every item
    in buffer order — item i's exchange is the same column range of
    every row, exactly the gather carve with a per-item width of
    ``size // group``."""
    ex = (_unpack_flush(ex, st.ctx.comm) if st.unpack
          else ex).reshape(st.group, -1)
    off = 0
    for i in st.plan.groups[c]:
        n = st.staged[i].size // st.group
        st.outs[i] = jax.lax.slice(ex, (0, off),
                                   (st.group, off + n)).reshape(-1)
        off += n


def _carve_scatter(st: EmitState, c: int, sh: jax.Array) -> None:
    """Carve one lane's scattered shard back per item (reduce_scatter:
    each item contributes 1/group of its elements)."""
    sh = _unpack_flush(sh, st.ctx.comm) if st.unpack else sh
    off = 0
    for i in st.plan.groups[c]:
        n = st.staged[i].size // st.group
        st.outs[i] = jax.lax.slice_in_dim(sh, off, off + n).reshape(
            _scattered_shape(st.staged[i].shape, st.group))
        off += n


def _stage_local(st: EmitState, c: int, flats: list) -> None:
    """The IN-POD stage of one local lane's coalesced flush (leader
    emission): issue only the data-axis collective and park the 1/n_data
    intermediate for the lane's leader. The all-reduce pad rule matches
    ``psum_hierarchical`` exactly (zero tail scatters onto the last
    shard), so the summation trees stay bit-identical."""
    ch = st.chans[c]
    if st.kind == "all_reduce":
        buf = flats[0] if len(flats) == 1 else jnp.concatenate(flats)
        pad = (-buf.shape[0]) % in_group_size(ch.data_axis)
        if pad:
            buf = jnp.pad(buf, (0, pad))
        st.lpad[c] = pad
        st.pending[c] = ch.in_pod_reduce_scatter(buf)
    elif st.kind == "all_gather":
        buf = flats[0] if len(flats) == 1 else jnp.concatenate(flats)
        st.pending[c] = ch.in_pod_all_gather(buf)
    else:
        buf = interleave_for_scatter(flats, st.group)
        st.pending[c] = ch.in_pod_reduce_scatter(buf)


def _flush_leader(st: EmitState, l: int) -> None:
    if not obs_trace.enabled():
        return _flush_leader_impl(st, l)
    with obs_trace.span("leader_flush", f"lead{st.leads[l].index}",
                        channel=st.leads[l].index,
                        lanes=len(st.lplan.groups[l])):
        return _flush_leader_impl(st, l)


def _flush_leader_impl(st: EmitState, l: int) -> None:
    """The CROSS-POD stage: ONE coalesced leader-lane collective carrying
    every parked in-pod intermediate of the local lanes assigned to
    leader ``l``, carved back per lane, then the in-pod return stage
    (all-reduce only) completes each lane's items. This is where the
    cross-pod collective count drops from n_channels to
    n_leader_channels."""
    lanes = st.lplan.groups[l]
    parts = [st.pending.pop(c) for c in lanes]
    lens = [p.shape[0] for p in parts]
    buf = parts[0] if len(parts) == 1 else jnp.concatenate(parts)
    lead = st.leads[l]
    if st.kind == "all_gather":
        g = lead.cross_pod_all_gather(buf)
        n_pods = g.shape[0] // buf.shape[0]
        g = g.reshape(n_pods, -1)
        off = 0
        for c, n in zip(lanes, lens):
            lane = jax.lax.slice(g, (0, off), (n_pods, off + n))
            off += n
            # (pods, data*len) -> (pods*data, len): pod-major peer order,
            # matching the flat tiled gather over (pod,)+data axes
            _carve_gather(st, c, lane.reshape(st.group, -1))
    else:
        red = lead.cross_pod_all_reduce(buf)
        off = 0
        for c, n in zip(lanes, lens):
            shard = jax.lax.slice_in_dim(red, off, off + n)
            off += n
            if st.kind == "all_reduce":
                full = st.chans[c].in_pod_all_gather(shard)
                if st.lpad.get(c):
                    full = jax.lax.slice_in_dim(
                        full, 0, full.shape[0] - st.lpad[c])
                _carve_reduce(st, c, full)
            else:
                _carve_scatter(st, c, shard)
    st.lfills[l].flushed = True


def _flush_channel(st: EmitState, c: int) -> None:
    if not obs_trace.enabled():
        return _flush_channel_impl(st, c)
    with obs_trace.span("flush", f"ch{st.chans[c].index}",
                        channel=st.chans[c].index,
                        items=len(st.plan.groups[c])):
        return _flush_channel_impl(st, c)


def _flush_channel_impl(st: EmitState, c: int) -> None:
    """One coalesced wire flush: concatenate the channel's staged items
    into a single contiguous buffer, issue ONE collective, optionally run
    the unpack stage on the flushed buffer, carve the results back out
    (the scattering read). Under leader emission the flush is only the
    in-pod stage; the items complete when the lane's leader flushes
    (:func:`_flush_leader`)."""
    idx = st.plan.groups[c]
    flats = [st.staged[i].reshape(-1) for i in idx]
    _consult_alloc(st.chans[c].index, flats)   # coalesced wire buffer
    if st.leads:
        _stage_local(st, c, flats)
        st.fills[c].flushed = True
        l = st.lplan.assign[c]
        st.lfills[l].stage(c)
        if st.ctx.comm.flush == "ready" and st.lfills[l].ready:
            _flush_leader(st, l)
        return
    if st.kind == "all_reduce":
        buf = flats[0] if len(flats) == 1 else jnp.concatenate(flats)
        _carve_reduce(st, c, st.chans[c].all_reduce(buf))
    elif st.kind == "all_gather":
        # the serving gathering write: ONE coalesced gather per channel
        buf = flats[0] if len(flats) == 1 else jnp.concatenate(flats)
        _carve_gather(st, c, st.chans[c].all_gather(buf))
    elif st.kind == "all_to_all":
        # the expert exchange: peer-major coalescing keeps every item's
        # per-peer chunks contiguous per row, ONE exchange per channel
        buf = interleave_for_scatter(flats, st.group)
        _carve_alltoall(st, c, st.chans[c].all_to_all(
            buf.reshape(st.group, -1)))
    else:
        buf = interleave_for_scatter(flats, st.group)
        _carve_scatter(st, c, st.chans[c].reduce_scatter(buf))
    st.fills[c].flushed = True


def begin_emission(ctx: SyncContext, n_items: int, kind: str, *,
                   group: int = 1, unpack: bool = False) -> EmitState:
    """Open one staged emission of ``n_items`` wire buffers through the
    connection pool. The bucket->channel schedule is ``comm.flush``
    (``core/flush_scheduler``): round-robin + end-of-exchange flush loop
    under ``"step"``, contiguous production-order groups flushed the
    moment they fill under ``"ready"``. ``unpack=True`` additionally runs
    the unpack stage per flush (channel-local instead of bucket-local —
    the scattering read keyed to the flush that produced the bytes).

    Under leader emission (:func:`leader_emission`) the pool splits into
    local lanes (they get the bucket->channel plan) and leader lanes
    (they get the second-level local-lane->leader plan,
    ``make_leader_plan``); ``st.chans`` holds only the local lanes so
    plan group ids stay aligned."""
    assert kind in _KINDS, kind
    pool = channels_for(ctx, n_items)
    if kind == "all_to_all":
        # the expert exchange BYPASSES leader emission: all-to-all
        # carries source-target pairs over the full flattened ring (the
        # ring IS the expert axis), not replica groups, so there is no
        # in-pod/cross-pod decomposition to carve leader lanes for —
        # leader-flagged lanes flush flat like locals
        local, leads = list(pool), []
    else:
        local = [c for c in pool if not c.leader]
        leads = [c for c in pool if c.leader]
    plan = make_flush_plan(n_items, len(local), ctx.comm.flush)
    fills = [ChannelFill(frozenset(g)) for g in plan.groups]
    st = EmitState(ctx=ctx, kind=kind, group=group, unpack=unpack,
                   plan=plan, chans=local, fills=fills, staged={},
                   outs=[None] * n_items, last={})
    if leads:
        st.leads = leads
        st.lplan = make_leader_plan(plan.n_channels, len(leads),
                                    ctx.comm.flush)
        st.lfills = [ChannelFill(frozenset(g)) for g in st.lplan.groups]
    if obs_trace.enabled():
        st.span = obs_trace.begin(
            "emission", kind, items=n_items, channels=len(local),
            leaders=len(leads), aggregate=ctx.comm.aggregate,
            flush=ctx.comm.flush)
    return st


def stage_slices(st: EmitState, i: int, wire: jax.Array) -> list:
    if not obs_trace.enabled():
        return _stage_slices_impl(st, i, wire)
    with obs_trace.span("stage", f"item{i}", item=i):
        return _stage_slices_impl(st, i, wire)


def _stage_slices_impl(st: EmitState, i: int, wire: jax.Array) -> list:
    """Stage item ``i``'s wire bytes (items MUST be staged in production
    order, 0..n-1) and emit whatever that makes ready:

    * ``aggregate="slice"`` — the item's own collective goes out
      immediately, barrier-chained on the channel's previous op (one
      in-flight collective per channel; the selector's ordering lever).
    * ``aggregate="channel"``, ``flush="ready"`` — if ``i`` completes its
      channel's assigned set, the channel's coalesced flush is emitted
      NOW (mid-backward when driven from a bucketed backend).
    * ``aggregate="channel"``, ``flush="step"`` — staging only; every
      flush waits for :func:`finish_emission` (the step barrier).

    Returns the item ids flushed by this call."""
    st.staged[i] = wire
    c = st.plan.assign[i]
    st.fills[c].stage(i)
    if st.ctx.comm.aggregate == "slice":
        ch = st.chans[c]
        x = wire
        _consult_alloc(ch.index, [x.reshape(-1)])  # per-item wire buffer
        if ch.index in st.last:
            x, _ = barrier(x, st.last[ch.index])
        if st.kind == "all_reduce":
            y = ch.all_reduce(x)
        elif st.kind == "all_gather":
            y = ch.all_gather(x.reshape(-1))
        elif st.kind == "all_to_all":
            y = ch.all_to_all(x.reshape(st.group, -1)).reshape(-1)
        else:
            y = ch.reduce_scatter(x)
        st.last[ch.index] = y
        st.outs[i] = _unpack_flush(y, st.ctx.comm) if st.unpack else y
        if st.fills[c].ready:
            st.fills[c].flushed = True
        return [i]
    if st.ctx.comm.flush == "ready":
        return flush_ready(st)
    return []


def flush_ready(st: EmitState) -> list:
    """Flush every channel whose fill watermark reached its assigned set
    (the selector reporting writable channels). Returns the item ids
    flushed."""
    flushed: list = []
    for c, fill in enumerate(st.fills):
        if fill.ready:
            if _FLUSH_FAULT is not None:
                act = _FLUSH_FAULT(c)
                if act == "drop":
                    # deferred, not lost: the fill stays ready, so a later
                    # flush_ready retries it and finish_emission's step
                    # barrier flushes it unconditionally — the recovery
                    # invariant the chaos harness asserts
                    current_stats().drops += 1
                    continue
                if act == "dup" and not st.leads:
                    current_stats().dups += 1
                    _flush_channel(st, c)   # shadow flush: idempotent —
                    #                         outs re-carved from an equal
                    #                         collective result below
            _flush_channel(st, c)
            flushed.extend(st.plan.groups[c])
    return flushed


def finish_emission(st: EmitState) -> list:
    """Close the emission: under ``flush="step"`` this is the
    end-of-exchange flush loop (every channel flushed at one barrier, in
    channel order — PR 3's schedule); under ``"ready"`` everything
    already went out and this only asserts completeness. Returns the
    per-item results."""
    if st.ctx.comm.aggregate == "channel":
        for c, fill in enumerate(st.fills):
            if not fill.flushed:
                assert fill.ready or st.ctx.comm.flush == "step", \
                    (c, fill.watermark)
                _flush_channel(st, c)
        # leader emission, flush="step": the second-level flush loop —
        # every leader's coalesced cross-pod collective at the barrier
        for l, fill in enumerate(st.lfills):
            if not fill.flushed:
                assert fill.ready or st.ctx.comm.flush == "step", \
                    (l, fill.watermark)
                _flush_leader(st, l)
    assert all(o is not None for o in st.outs), "emission incomplete"
    if st.span is not None:
        obs_trace.end(st.span)
        st.span = None
    return st.outs


def emit_through_channels(items: list, ctx: SyncContext, kind: str,
                          *, group: int = 1, unpack: bool = False) -> list:
    """Issue the collective ``kind`` ("all_reduce" | "reduce_scatter")
    for every item through the connection pool, at the flush granularity
    ``ctx.comm.aggregate``:

    * ``"slice"`` — one collective per item. Items on the SAME channel
      are chained (each op's input is barrier-pinned on the channel's
      previous output, so the compiler must run them in order — one
      in-flight collective per channel); different channels stay
      data-independent and may overlap freely.
    * ``"channel"`` — one coalesced wire flush per channel: all items
      assigned to a channel become ONE contiguous buffer and ONE
      collective (n_channels collectives per exchange instead of
      n_slices). Reduce-scatter flushes are peer-major interleaved
      (:func:`interleave_for_scatter`) so each item's shard is unchanged.

    ``comm.flush`` picks the schedule (``core/flush_scheduler``):
    ``"step"`` is the round-robin assignment with one end-of-exchange
    flush loop; ``"ready"`` groups items contiguously in production
    order and flushes each channel the moment its last item is staged.
    This one-shot wrapper stages everything before finishing, so the
    dataflow (not the Python order) is what ``"ready"`` improves here;
    bucketed backends drive :func:`stage_slices` incrementally instead.

    Returns per-item results: reduced arrays in the item's own shape
    (all_reduce), or the item's scatter shard with the trailing dim
    divided by ``group`` (reduce_scatter). All four granularity/schedule
    combinations return bit-identical values."""
    st = begin_emission(ctx, len(items), kind, group=group, unpack=unpack)
    for i, x in enumerate(items):
        stage_slices(st, i, x)
    return finish_emission(st)


def emit_flat(flat: jax.Array, ctx: SyncContext, kind: str, *,
              group: int = 1) -> jax.Array:
    """The serving wire path: carve ONE flat f32 payload (a partial logit
    sum, a coalesced KV-cache write) into ring-buffer slices and emit
    them through the staged channel schedule — the same gathering write
    the gradient path uses, applied to inference traffic. ``kind`` is
    ``"all_reduce"`` (returns the summed payload, ``flat``'s own shape)
    or ``"all_gather"`` (``group`` = ring size; returns the peer-major
    concatenation, shape ``(group * len,)``) or ``"all_to_all"``
    (``group`` = ring size; ``flat`` is the peer-major ``(group, len //
    group)`` exchange payload flattened, and the result is the received
    payload in the same layout — the MoE expert dispatch/combine).
    Zero-padding added by the slice plan is trimmed from the result (per
    peer block for gathers and exchanges), so callers see exactly their
    payload."""
    assert flat.ndim == 1, flat.shape
    assert kind in ("all_reduce", "all_gather", "all_to_all"), \
        ("serving payloads are replicated, gathered or exchanged, "
         f"never scattered: {kind}")
    from repro.core.ring_buffer import plan_slices
    n_elems = flat.shape[0]
    itemsize = jnp.dtype(flat.dtype).itemsize
    if kind == "all_to_all":
        # the exchange payload is a (group, row) peer-major block; the
        # ring-buffer plan carves the per-peer ROW, so every staged
        # slice (a column block, flattened group-major) is itself a
        # complete peer-major exchange payload and the carved results
        # re-concatenate per row — slicing commutes with the exchange
        # exactly like it does with gathers
        assert n_elems % group == 0, (n_elems, group)
        row = n_elems // group
        sp = plan_slices(row * itemsize, ctx.comm)
        elems = max(1, sp.slice_bytes // itemsize)
        n = sp.n_slices
        pad = n * elems - row
        assert pad >= 0, (sp, row)
        view = flat.reshape(group, row)
        if pad:
            view = jnp.pad(view, ((0, 0), (0, pad)))
        st = begin_emission(ctx, n, kind, group=group)
        for i in range(n):
            stage_slices(st, i, jax.lax.slice(
                view, (0, i * elems),
                (group, (i + 1) * elems)).reshape(-1))
        outs = finish_emission(st)
        ex = outs[0].reshape(group, -1) if len(outs) == 1 else \
            jnp.concatenate([o.reshape(group, -1) for o in outs], axis=1)
        return ex[:, :row].reshape(-1)
    sp = plan_slices(n_elems * itemsize, ctx.comm)
    elems = max(1, sp.slice_bytes // itemsize)
    # the plan's slice count IS the emitted-collective prediction
    # (dispatch.logit_payload_slices, evidence rows) — never recompute it
    n = sp.n_slices
    pad = n * elems - n_elems
    assert pad >= 0, (sp, n_elems)
    if pad:
        flat = jnp.pad(flat, (0, pad))
    slices = flat.reshape(n, elems)
    st = begin_emission(ctx, n, kind, group=group)
    for i in range(n):
        stage_slices(st, i, slices[i])
    outs = finish_emission(st)
    if kind == "all_gather":
        g = outs[0].reshape(group, -1) if len(outs) == 1 else \
            jnp.concatenate([o.reshape(group, -1) for o in outs], axis=1)
        return g[:, :n_elems].reshape(-1)
    out = outs[0].reshape(-1) if len(outs) == 1 else \
        jnp.concatenate([o.reshape(-1) for o in outs])
    return out[:n_elems]


def raw_emit(flat: jax.Array, ctx: SyncContext, kind: str) -> jax.Array:
    """The unsliced serving emission (gspmd / sockets / vma overrides of
    ``CommBackend.serve_emit``): one collective for the whole payload —
    per-buffer sends with no ring-buffer aggregation. Bit-identical
    values to :func:`emit_flat` (summing per element and concatenating
    peer-major commute with slicing); only the emission structure
    differs."""
    if kind == "all_reduce":
        return jax.lax.psum(flat, ctx.flat_axes)
    if kind == "all_to_all":
        group = jax.lax.psum(1, ctx.flat_axes)
        return jax.lax.all_to_all(
            flat.reshape(group, -1), ctx.flat_axes, split_axis=0,
            concat_axis=0, tiled=True).reshape(-1)
    assert kind == "all_gather", kind
    return jax.lax.all_gather(flat, ctx.flat_axes, axis=0, tiled=True)


def scatter_group(ctx: SyncContext):
    """(gather_axes, group_size) for the ZeRO-1 reduce-scatter: in-pod
    when pod-aware (shards replicate across pods), the whole flattened
    ring otherwise. ``group_size`` is a static int (psum-of-1 idiom)."""
    gather_axes = ctx.data_axes_tuple if ctx.pod_axis is not None \
        else ctx.flat_axes
    return gather_axes, jax.lax.psum(1, gather_axes)


def reduce_slices(slices: jax.Array, ctx: SyncContext):
    """Per-slice all-reduce with the pack/unpack stages, scheduled over
    the channel pool at the configured flush granularity. slices: (n, S)
    f32. Returns (reduced (n, S) f32, new_ef)."""
    wire, new_ef, scale = pack_wire(slices, ctx.ef, ctx.comm)
    if scale is not None:
        # int8: all-gather + local dequant-sum (one fused exchange)
        return comp.int8_allreduce(wire, scale, ctx.flat_axes), new_ef

    outs = emit_through_channels(
        [wire[i] for i in range(wire.shape[0])], ctx, "all_reduce")
    return unpack_wire(jnp.stack(outs), ctx.comm), new_ef


def scatter_slices(slices: jax.Array, ctx: SyncContext):
    """Per-slice reduce-scatter (the ZeRO-1 exchange) over the channel
    schedule, with the pack/unpack stages. slices: (n, S) f32
    (wire-compressible). Returns (flat_shard, new_ef, gather_axes) where
    flat_shard is the peer's (n * S/group,) ZeRO-1 slice and
    ``gather_axes`` are the axes the shard must be all-gathered over."""
    gather_axes, group = scatter_group(ctx)
    wire, new_ef, scale = pack_wire(slices, ctx.ef, ctx.comm)
    if scale is not None:
        # int8: full dequant-sum everywhere, then keep this peer's chunk
        # of every slice (pods replicate shards, matching gather_axes)
        red = comp.int8_allreduce(wire, scale, ctx.flat_axes)
        n, s = red.shape
        assert s % group == 0, (s, group)
        my = jax.lax.axis_index(gather_axes)
        shard = jax.lax.dynamic_slice_in_dim(red, my * (s // group),
                                             s // group, axis=1)
        return shard.reshape(-1), new_ef, gather_axes

    shards = emit_through_channels(
        [wire[i] for i in range(wire.shape[0])], ctx, "reduce_scatter",
        group=group)
    # (n_slices, S/group) -> flat local shard, ZeRO-1 layout
    flat_shard = unpack_wire(jnp.stack(shards), ctx.comm).reshape(-1)
    return flat_shard, new_ef, gather_axes
