"""The composable slice pipeline shared by the hadronio-family backends.

One gradient exchange is a fixed sequence of stages, written once here
instead of per-branch in every mode:

    pack -> ring-buffer plan -> compress -> per-channel collective -> unpack

``pack``/``plan`` live in :mod:`repro.core.aggregation` (the gathering
write); this module owns the wire stages:

* :func:`channels_for` — build the connection pool for a resolved axis
  topology (pod-aware when the context says so).
* :func:`compress_slices` — the optional wire codec (bf16 + error
  feedback, int8 with local dequant-sum).
* :func:`emit_through_channels` — the worker-per-connection schedule:
  slices are assigned to channels round-robin (paper §IV-C) and each
  channel issues its collectives IN ORDER (an ``optimization_barrier``
  chains consecutive ops on the same channel — the selector's ordering
  lever from :mod:`repro.core.selector`), while different channels stay
  data-independent. ``comm.channels`` therefore really is the paper's
  connection-count axis: it bounds how many collectives can be in
  flight, from fully serialized (1) to fully independent (>= n_slices).
* :func:`reduce_slices` / :func:`scatter_slices` — compress + per-slice
  all-reduce / reduce-scatter composed over the channel schedule.

Backends compose these; none of them re-implements a stage.
"""
from __future__ import annotations

from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.core import compress as comp
from repro.core.channels import CommChannel, make_channels, round_robin
from repro.core.selector import barrier, emission_order

from repro.core.backends.base import SyncContext


def channels_for(ctx: SyncContext, n_slices: int) -> list[CommChannel]:
    """The connection pool: at most ``comm.channels`` workers, pod-aware
    when the context resolved a pod axis."""
    n = max(1, min(ctx.comm.channels, n_slices))
    return make_channels(n, ctx.flat_axes, pod_axis=ctx.pod_axis,
                         data_axis=ctx.data_axis)


def compress_slices(slices: jax.Array, ctx: SyncContext):
    """Wire codec stage. Returns (wire, new_ef, int8_scale). For int8 the
    caller must use :func:`comp.int8_allreduce`-style summation (signalled
    by a non-None scale)."""
    comm = ctx.comm
    if comm.compress == "bf16":
        wire, new_ef = comp.bf16_compress(slices, ctx.ef)
        return wire, new_ef, None
    if comm.compress == "int8_ef":
        q, scale, new_ef = comp.int8_quantize(slices, ctx.ef)
        return q, new_ef, scale
    return slices, None, None


def emit_through_channels(items: list, ctx: SyncContext,
                          op: Callable[[CommChannel, jax.Array],
                                       jax.Array]) -> list:
    """Issue ``op(channel, item)`` for every item through the connection
    pool. Items on the SAME channel are chained (each op's input is
    barrier-pinned on the channel's previous output, so the compiler must
    run them in order — one in-flight collective per channel); different
    channels carry no data dependencies and may overlap freely."""
    chans = channels_for(ctx, len(items))
    assign = round_robin(len(items), len(chans))
    last: dict[int, jax.Array] = {}
    outs: list[Optional[jax.Array]] = [None] * len(items)
    for i in emission_order(len(items), reverse=False):
        ch = chans[assign[i]]
        x = items[i]
        if ch.index in last:
            x, _ = barrier(x, last[ch.index])
        y = op(ch, x)
        outs[i] = y
        last[ch.index] = y
    return outs


def reduce_slices(slices: jax.Array, ctx: SyncContext):
    """Per-slice all-reduce with optional compression, scheduled over the
    channel pool. slices: (n, S) f32. Returns (reduced (n, S) f32,
    new_ef)."""
    wire, new_ef, scale = compress_slices(slices, ctx)
    if scale is not None:
        # int8: all-gather + local dequant-sum (one fused exchange)
        return comp.int8_allreduce(wire, scale, ctx.flat_axes), new_ef

    outs = emit_through_channels(
        [wire[i] for i in range(wire.shape[0])], ctx,
        lambda ch, x: ch.all_reduce(x).astype(jnp.float32))
    return jnp.stack(outs), new_ef


def scatter_slices(slices: jax.Array, ctx: SyncContext):
    """Per-slice reduce-scatter (the ZeRO-1 exchange) over the channel
    pool. slices: (n, S) f32 (bf16-compressible). Returns (flat_shard,
    new_ef, gather_axes) where flat_shard is the peer's (n * S/group,)
    ZeRO-1 slice and ``gather_axes`` are the axes the shard must be
    all-gathered over."""
    comm = ctx.comm
    new_ef = None
    if comm.compress == "bf16":
        slices, new_ef = comp.bf16_compress(slices, ctx.ef)
    hier = ctx.pod_axis is not None
    gather_axes = ctx.data_axes_tuple if hier else ctx.flat_axes

    shards = emit_through_channels(
        [slices[i] for i in range(slices.shape[0])], ctx,
        lambda ch, x: ch.reduce_scatter(x).astype(jnp.float32))
    # (n_slices, S/group) -> flat local shard, ZeRO-1 layout
    flat_shard = jnp.stack(shards).reshape(-1)
    return flat_shard, new_ef, gather_axes
