"""The composable slice pipeline shared by the hadronio-family backends.

One gradient exchange is a fixed sequence of stages, written once here
instead of per-branch in every mode:

    pack -> ring-buffer plan -> pack stage (cast/EF) -> per-channel
    collective -> unpack stage -> unpack

``pack``/``plan`` live in :mod:`repro.core.aggregation` (the gathering
write); this module owns the wire stages:

* :func:`channels_for` — build the connection pool for a resolved axis
  topology (pod-aware when the context says so).
* :func:`pack_wire` — the pack stage: the fused add-error-feedback /
  cast-to-wire-dtype copy pass (the paper's §III-C gathering-write hot
  spot). ``comm.pack`` selects the implementation: ``"pallas"`` runs the
  fused one-HBM-pass kernel (kernels/ring_pack.py, interpret mode
  off-TPU), ``"jnp"`` the reference elementwise path; both produce
  bit-identical wire bytes. Selection falls back through
  :func:`repro.compat.pallas_available` so pallas-less environments run
  every backend unchanged. int8 needs a per-slice amax reduction the
  kernel does not fuse, so it always takes the jnp path.
* :func:`emit_through_channels` — the worker-per-connection schedule:
  slices are assigned to channels round-robin (paper §IV-C) and the
  flush granularity is ``comm.aggregate``. Under ``"slice"`` each
  channel issues its collectives IN ORDER (an ``optimization_barrier``
  chains consecutive ops on the same channel — the selector's ordering
  lever from :mod:`repro.core.selector`), while different channels stay
  data-independent. Under ``"channel"`` every channel coalesces its
  slices into ONE contiguous wire buffer and flushes a single collective
  — hadroNIO's ring-buffer gathering write (§III-C, §V-B), where many
  small application writes become one large UCX request per connection.
* :func:`unpack_wire` — the unpack stage (the scattering-read
  counterpart of the pack stage): one fused cast-from-wire-dtype +
  re-slice HBM pass over the stacked collective results, replacing the
  old per-slice ``.astype(f32)`` epilogue. Implementation selection is
  the same ``comm.pack`` switch (kernels/ring_pack.unpack_slices_kernel
  vs jnp), with identical outputs.
* :func:`reduce_slices` / :func:`scatter_slices` — pack stage + per-slice
  all-reduce / reduce-scatter + unpack stage composed over the channel
  schedule.

Backends compose these; none of them re-implements a stage.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro import compat
from repro.configs.base import CommConfig
from repro.core import compress as comp
from repro.core.channels import (CommChannel, channel_groups, make_channels,
                                 round_robin)
from repro.core.selector import barrier, emission_order

from repro.core.backends.base import SyncContext

_KINDS = ("all_reduce", "reduce_scatter")


def channels_for(ctx: SyncContext, n_slices: int) -> list[CommChannel]:
    """The connection pool: at most ``comm.channels`` workers, pod-aware
    when the context resolved a pod axis."""
    n = max(1, min(ctx.comm.channels, n_slices))
    return make_channels(n, ctx.flat_axes, pod_axis=ctx.pod_axis,
                         data_axis=ctx.data_axis)


def pack_impl(comm: CommConfig) -> str:
    """Resolve the pack/unpack-stage implementation: honor ``comm.pack``
    when the pallas toolchain is importable, else fall back to jnp."""
    if comm.pack == "pallas" and compat.pallas_available():
        return "pallas"
    return "jnp"


def pack_wire(slices: jax.Array, ef, comm: CommConfig):
    """The pack stage over a ``(n, S)`` slice view: one fused pass doing
    add-EF, cast-to-wire-dtype, and residual capture.

    Returns ``(wire, new_ef, int8_scale)``. ``new_ef`` is None when the
    codec carries no residual; a non-None ``int8_scale`` signals that the
    caller must use :func:`comp.int8_allreduce`-style summation."""
    if comm.compress == "int8_ef":
        # amax reduction + quant: jnp path regardless of comm.pack
        q, scale, new_ef = comp.int8_quantize(slices, ef)
        return q, new_ef, scale
    with_ef = comm.compress == "bf16"
    wire_dtype = "bfloat16" if with_ef else jnp.dtype(slices.dtype).name
    if pack_impl(comm) == "pallas":
        from repro.kernels import ops
        n, s = slices.shape
        wire, new_ef = ops.pack_slices(slices.reshape(-1), ef, n_slices=n,
                                       slice_elems=s, wire_dtype=wire_dtype,
                                       with_ef=with_ef)
        return wire, new_ef, None
    if with_ef:
        wire, new_ef = comp.bf16_compress(slices, ef)
        return wire, new_ef, None
    return slices, None, None


def unpack_wire(wire: jax.Array, comm: CommConfig,
                out_dtype=jnp.float32) -> jax.Array:
    """The unpack stage — the paper's scattering read (§III-C): one fused
    cast-from-wire-dtype + re-slice HBM pass over the stacked ``(n, S)``
    collective results, instead of one ``.astype`` round trip per slice.
    ``comm.pack`` selects the implementation exactly like the pack stage
    (pallas kernel vs jnp reference; bit-identical outputs). A wire
    already in ``out_dtype`` needs no pass at all."""
    if wire.dtype == jnp.dtype(out_dtype):
        return wire
    if pack_impl(comm) == "pallas":
        from repro.kernels import ops
        return ops.unpack_slices(
            wire, out_dtype=jnp.dtype(out_dtype).name).reshape(wire.shape)
    return wire.astype(out_dtype)


def interleave_for_scatter(flats: list, group: int) -> jax.Array:
    """Peer-major coalescing of 1-D wire buffers for ONE reduce-scatter
    flush: peer ``p``'s contiguous ``1/group`` chunk of the result is the
    concatenation of ``p``'s chunk of every buffer, in buffer order — so
    a coalesced reduce-scatter hands every peer exactly the same
    per-slice shards (and therefore the same ZeRO-1 flat-shard ordering)
    as one collective per slice."""
    if len(flats) == 1:
        return flats[0]
    return jnp.concatenate([f.reshape(group, -1) for f in flats],
                           axis=1).reshape(-1)


def _scattered_shape(shape: tuple, group: int) -> tuple:
    return shape[:-1] + (shape[-1] // group,)


def _flush_channel(ch: CommChannel, items: list, idx: list, kind: str,
                   group: int, outs: list) -> None:
    """One coalesced wire flush: concatenate the channel's items into a
    single contiguous buffer, issue ONE collective, carve the results
    back out (the scattering read)."""
    flats = [items[i].reshape(-1) for i in idx]
    if kind == "all_reduce":
        buf = flats[0] if len(flats) == 1 else jnp.concatenate(flats)
        red = ch.all_reduce(buf)
        off = 0
        for i, f in zip(idx, flats):
            outs[i] = jax.lax.slice_in_dim(
                red, off, off + f.shape[0]).reshape(items[i].shape)
            off += f.shape[0]
        return
    buf = interleave_for_scatter(flats, group)
    sh = ch.reduce_scatter(buf)
    off = 0
    for i, f in zip(idx, flats):
        c = f.shape[0] // group
        outs[i] = jax.lax.slice_in_dim(sh, off, off + c).reshape(
            _scattered_shape(items[i].shape, group))
        off += c


def emit_through_channels(items: list, ctx: SyncContext, kind: str,
                          *, group: int = 1) -> list:
    """Issue the collective ``kind`` ("all_reduce" | "reduce_scatter")
    for every item through the connection pool, at the flush granularity
    ``ctx.comm.aggregate``:

    * ``"slice"`` — one collective per item. Items on the SAME channel
      are chained (each op's input is barrier-pinned on the channel's
      previous output, so the compiler must run them in order — one
      in-flight collective per channel); different channels carry no
      data dependencies and may overlap freely.
    * ``"channel"`` — one coalesced wire flush per channel: all items
      round-robin-assigned to a channel become ONE contiguous buffer and
      ONE collective (n_channels collectives per exchange instead of
      n_slices). Reduce-scatter flushes are peer-major interleaved
      (:func:`interleave_for_scatter`) so each item's shard is unchanged.

    Returns per-item results: reduced arrays in the item's own shape
    (all_reduce), or the item's scatter shard with the trailing dim
    divided by ``group`` (reduce_scatter). Both granularities return
    bit-identical values."""
    assert kind in _KINDS, kind
    chans = channels_for(ctx, len(items))
    outs: list = [None] * len(items)
    if ctx.comm.aggregate == "channel":
        for ch, idx in zip(chans, channel_groups(len(items), len(chans))):
            if idx:
                _flush_channel(ch, items, idx, kind, group, outs)
        return outs
    assign = round_robin(len(items), len(chans))
    last: dict[int, jax.Array] = {}
    for i in emission_order(len(items), reverse=False):
        ch = chans[assign[i]]
        x = items[i]
        if ch.index in last:
            x, _ = barrier(x, last[ch.index])
        y = ch.all_reduce(x) if kind == "all_reduce" \
            else ch.reduce_scatter(x)
        outs[i] = y
        last[ch.index] = y
    return outs


def scatter_group(ctx: SyncContext):
    """(gather_axes, group_size) for the ZeRO-1 reduce-scatter: in-pod
    when pod-aware (shards replicate across pods), the whole flattened
    ring otherwise. ``group_size`` is a static int (psum-of-1 idiom)."""
    gather_axes = ctx.data_axes_tuple if ctx.pod_axis is not None \
        else ctx.flat_axes
    return gather_axes, jax.lax.psum(1, gather_axes)


def reduce_slices(slices: jax.Array, ctx: SyncContext):
    """Per-slice all-reduce with the pack/unpack stages, scheduled over
    the channel pool at the configured flush granularity. slices: (n, S)
    f32. Returns (reduced (n, S) f32, new_ef)."""
    wire, new_ef, scale = pack_wire(slices, ctx.ef, ctx.comm)
    if scale is not None:
        # int8: all-gather + local dequant-sum (one fused exchange)
        return comp.int8_allreduce(wire, scale, ctx.flat_axes), new_ef

    outs = emit_through_channels(
        [wire[i] for i in range(wire.shape[0])], ctx, "all_reduce")
    return unpack_wire(jnp.stack(outs), ctx.comm), new_ef


def scatter_slices(slices: jax.Array, ctx: SyncContext):
    """Per-slice reduce-scatter (the ZeRO-1 exchange) over the channel
    schedule, with the pack/unpack stages. slices: (n, S) f32
    (wire-compressible). Returns (flat_shard, new_ef, gather_axes) where
    flat_shard is the peer's (n * S/group,) ZeRO-1 slice and
    ``gather_axes`` are the axes the shard must be all-gathered over."""
    gather_axes, group = scatter_group(ctx)
    wire, new_ef, scale = pack_wire(slices, ctx.ef, ctx.comm)
    if scale is not None:
        # int8: full dequant-sum everywhere, then keep this peer's chunk
        # of every slice (pods replicate shards, matching gather_axes)
        red = comp.int8_allreduce(wire, scale, ctx.flat_axes)
        n, s = red.shape
        assert s % group == 0, (s, group)
        my = jax.lax.axis_index(gather_axes)
        shard = jax.lax.dynamic_slice_in_dim(red, my * (s // group),
                                             s // group, axis=1)
        return shard.reshape(-1), new_ef, gather_axes

    shards = emit_through_channels(
        [wire[i] for i in range(wire.shape[0])], ctx, "reduce_scatter",
        group=group)
    # (n_slices, S/group) -> flat local shard, ZeRO-1 layout
    flat_shard = unpack_wire(jnp.stack(shards), ctx.comm).reshape(-1)
    return flat_shard, new_ef, gather_axes
