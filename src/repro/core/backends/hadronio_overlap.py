"""``hadronio_overlap`` — beyond-paper: DDP-style gradient bucketing.

The monolithic gathering write (``hadronio``) concatenates EVERY gradient
leaf before the first collective, so in the step's dataflow graph each
slice collective depends on the entire backward pass. This backend
instead packs per-bucket subsets of leaves, in reverse-layer order (the
selector's ``emission_order``: backward produces last-layer gradients
first). Each bucket's collective depends only on its own leaves, so the
XLA latency-hiding scheduler can start the early buckets' collectives
while the remaining backward compute for earlier layers is still running
— and the step builder emits them before the loss epilogue.

Buckets fill greedily to ``comm.slice_bytes`` (one leaf larger than a
slice gets its own bucket) and are padded to the 512-element alignment so
pod-aware two-level collectives shard evenly. Wire compression IS
supported here: error-feedback state is a pytree keyed by bucket id (one
residual per bucket, independent of the global ring plan), so each
bucket's pack stage — the fused add-EF/cast pass from
:func:`repro.core.backends.pipeline.pack_wire` — stays self-contained
and the bucket's collective still depends only on its own leaves.
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import CommConfig, RunConfig
from repro.core import compress as comp
from repro.core.backends import pipeline
from repro.core.backends.base import (CommBackend, StateSpecs, SyncContext,
                                      SyncResult, register)
from repro.core.selector import emission_order
from repro.optim import adamw

PyTree = Any

_ALIGN = 512   # matches aggregation.make_plan's reduce-scatter alignment


def make_buckets(sizes: list[int], slice_bytes: int,
                 itemsize: int = 4) -> list[list[int]]:
    """Greedy reverse-layer bucketing: leaf indices grouped so each bucket
    holds at most ``slice_bytes`` of wire payload (a single oversized leaf
    gets its own bucket). Bucket 0 holds the LAST leaves — the gradients
    backward produces first."""
    buckets: list[list[int]] = []
    cur: list[int] = []
    cur_bytes = 0
    for i in emission_order(len(sizes), reverse=True):
        b = sizes[i] * itemsize
        if cur and cur_bytes + b > slice_bytes:
            buckets.append(cur)
            cur, cur_bytes = [], 0
        cur.append(i)
        cur_bytes += b
    if cur:
        buckets.append(cur)
    return buckets


class BucketPlan(NamedTuple):
    """Static layout of one bucketed exchange (the bucketed counterpart
    of :class:`repro.core.aggregation.PackPlan` — shape-only, computed at
    trace time from the pytree structure)."""
    buckets: tuple            # per bucket: tuple of leaf indices
    sizes: tuple              # per-leaf element counts (flatten order)
    shapes: tuple             # per-leaf shapes (flatten order)
    padded: tuple             # per-bucket padded element count
    align: int

    @property
    def n_buckets(self) -> int:
        return len(self.buckets)

    @property
    def total_padded(self) -> int:
        return sum(self.padded)


def make_bucket_plan(tree: PyTree, comm: CommConfig,
                     align: int = _ALIGN) -> BucketPlan:
    leaves = jax.tree.leaves(tree)
    shapes = tuple(tuple(l.shape) for l in leaves)
    sizes = tuple(int(np.prod(s)) if s else 1 for s in shapes)
    buckets = tuple(tuple(b) for b in make_buckets(list(sizes),
                                                   comm.slice_bytes))
    padded = tuple(-(-sum(sizes[i] for i in b) // align) * align
                   for b in buckets)
    return BucketPlan(buckets, sizes, shapes, padded, align)


def pack_bucket(leaves: list, plan: BucketPlan, b: int) -> jax.Array:
    """The per-bucket gathering write: concatenate the bucket's leaves
    into one padded f32 vector."""
    flat = jnp.concatenate(
        [leaves[i].astype(jnp.float32).reshape(-1) for i in plan.buckets[b]])
    pad = plan.padded[b] - flat.shape[0]
    return jnp.pad(flat, (0, pad)) if pad else flat


def unpack_bucket(vec: jax.Array, plan: BucketPlan, b: int,
                  like_leaves: list, out: list) -> None:
    """Inverse carve of one bucket into ``out`` (a per-leaf slot list),
    casting each leaf to its ``like`` dtype."""
    off = 0
    for i in plan.buckets[b]:
        piece = jax.lax.slice_in_dim(vec, off, off + plan.sizes[i], axis=0)
        out[i] = piece.reshape(plan.shapes[i]).astype(like_leaves[i].dtype)
        off += plan.sizes[i]


def bucket_ef_specs(plan: BucketPlan, n_shards: int) -> tuple:
    """Per-bucket error-feedback layout: the EF pytree is keyed by bucket
    id (leaf b <-> bucket b); the leading ring dim makes each peer's
    residual row explicit, exactly like the global-ring EF spec."""
    return tuple(jax.ShapeDtypeStruct((n_shards, p), jnp.float32)
                 for p in plan.padded)


def pack_buckets_wire(leaves: list, plan: BucketPlan, ctx: SyncContext):
    """Run the pack stage per bucket. Returns (wires, new_efs, scales) —
    lists indexed by bucket id; ``new_efs`` entries are (padded_b,) f32
    or None, wires are (1, padded_b) of the wire dtype."""
    efs = list(ctx.ef) if ctx.ef is not None else [None] * plan.n_buckets
    assert len(efs) == plan.n_buckets, (len(efs), plan.n_buckets)
    wires, new_efs, scales = [], [], []
    for b in range(plan.n_buckets):
        flat = pack_bucket(leaves, plan, b)
        ef_b = None if efs[b] is None else efs[b][None]
        wire, nef, scale = pipeline.pack_wire(flat[None], ef_b, ctx.comm)
        wires.append(wire)
        new_efs.append(None if nef is None else nef[0])
        scales.append(scale)
    return wires, new_efs, scales


def stage_buckets(leaves: list, plan: BucketPlan, ctx: SyncContext,
                  kind: str, *, group: int = 1):
    """The readiness-driven gathering write: pack each bucket and stage
    it with the channel emitter IN PRODUCTION ORDER (bucket 0 holds the
    gradients backward produces first), so under ``comm.flush="ready"``
    each channel's coalesced collective is emitted the moment the last
    bucket assigned to it is staged — mid-exchange, before later buckets
    are packed. Returns ``(per-bucket f32 results, new_efs)``; the unpack
    stage runs inside the emitter, per flush."""
    efs = list(ctx.ef) if ctx.ef is not None else [None] * plan.n_buckets
    assert len(efs) == plan.n_buckets, (len(efs), plan.n_buckets)
    st = pipeline.begin_emission(ctx, plan.n_buckets, kind, group=group,
                                 unpack=True)
    new_efs = []
    for b in range(plan.n_buckets):
        flat = pack_bucket(leaves, plan, b)
        ef_b = None if efs[b] is None else efs[b][None]
        wire, nef, scale = pipeline.pack_wire(flat[None], ef_b, ctx.comm)
        assert scale is None      # int8 never reaches the emitter
        new_efs.append(None if nef is None else nef[0])
        pipeline.stage_slices(st, b, wire)
    return pipeline.finish_emission(st), new_efs


def bucket_ef_result(new_efs: list):
    return tuple(new_efs) if any(e is not None for e in new_efs) else None


@register("hadronio_overlap")
class HadronioOverlapBackend(CommBackend):

    def state_specs(self, run: RunConfig, n_shards: int,
                    pod_size: int = 1) -> StateSpecs:
        """Tree moments (DDP-style), plus per-bucket error feedback when
        compression is on — keyed by bucket id, NOT by the global ring
        plan (this mode never builds one)."""
        from repro.models import api
        params = api.abstract(run.model)
        f32 = lambda p: jax.ShapeDtypeStruct(p.shape, jnp.float32)
        ef = None
        if self.needs_ef(run.comm):
            plan = make_bucket_plan(params, run.comm)
            ef = bucket_ef_specs(plan, n_shards)
        opt = adamw.AdamState(mu=jax.tree.map(f32, params),
                              nu=jax.tree.map(f32, params),
                              count=jax.ShapeDtypeStruct((), jnp.int32))
        return StateSpecs(opt=opt, ef=ef)

    def sync(self, grads, ctx: SyncContext) -> SyncResult:
        leaves, treedef = jax.tree.flatten(grads)
        plan = make_bucket_plan(grads, ctx.comm)

        if ctx.comm.compress == "int8_ef":
            # per-bucket all-gather + local dequant-sum; every bucket's
            # exchange still depends only on its own leaves
            wires, new_efs, scales = pack_buckets_wire(leaves, plan, ctx)
            reduced = [comp.int8_allreduce(q, s, ctx.flat_axes)
                       for q, s in zip(wires, scales)]
        else:
            # staged emission through the channel schedule: each bucket
            # is packed AND staged in production order, so under
            # comm.flush="ready" a channel's coalesced flush is emitted
            # the moment its last bucket's wire bytes exist — before the
            # later buckets are even packed. The fused unpack stage runs
            # per FLUSH (channel-local keeps the cast inside the flush's
            # own dataflow; a merged unpack would join every bucket).
            reduced, new_efs = stage_buckets(leaves, plan, ctx,
                                             "all_reduce")

        out: list = [None] * len(leaves)
        for b, red in enumerate(reduced):
            unpack_bucket(red.reshape(-1), plan, b, leaves, out)
        synced = jax.tree.unflatten(treedef, out)
        return SyncResult(synced, None, plan, bucket_ef_result(new_efs))

    def serve_emit(self, flat, ctx, kind):
        """The overlap strategy's serving wire path always flushes when
        ready: a serving payload's slices are staged in production order
        and each channel's (or, pod-aware, each leader's) coalesced
        collective goes out the moment its run completes — hadroNIO's
        flush-on-writable applied to the latency-critical path. Pure
        emission structure; values are bit-identical to the step
        schedule (conformance-tested)."""
        import dataclasses

        from repro.core.backends import pipeline
        ready = dataclasses.replace(ctx.comm, flush="ready")
        rctx = dataclasses.replace(ctx, comm=ready)
        group = jax.lax.psum(1, ctx.flat_axes) \
            if kind in ("all_gather", "all_to_all") else 1
        return pipeline.emit_flat(flat, rctx, kind, group=group)
