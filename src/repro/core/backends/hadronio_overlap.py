"""``hadronio_overlap`` — beyond-paper: DDP-style gradient bucketing.

The monolithic gathering write (``hadronio``) concatenates EVERY gradient
leaf before the first collective, so in the step's dataflow graph each
slice collective depends on the entire backward pass. This backend
instead packs per-bucket subsets of leaves, in reverse-layer order (the
selector's ``emission_order``: backward produces last-layer gradients
first). Each bucket's collective depends only on its own leaves, so the
XLA latency-hiding scheduler can start the early buckets' collectives
while the remaining backward compute for earlier layers is still running
— and the step builder emits them before the loss epilogue.

Buckets fill greedily to ``comm.slice_bytes`` (one leaf larger than a
slice gets its own bucket) and are padded to the 512-element alignment so
pod-aware two-level collectives shard evenly. Wire compression is not
supported here: error-feedback state is shaped by the global ring-buffer
plan, which this mode deliberately does not build.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import CommConfig
from repro.core.backends import pipeline
from repro.core.backends.base import (CommBackend, SyncContext, SyncResult,
                                      register)
from repro.core.selector import emission_order

_ALIGN = 512   # matches aggregation.make_plan's reduce-scatter alignment


def make_buckets(sizes: list[int], slice_bytes: int,
                 itemsize: int = 4) -> list[list[int]]:
    """Greedy reverse-layer bucketing: leaf indices grouped so each bucket
    holds at most ``slice_bytes`` of wire payload (a single oversized leaf
    gets its own bucket). Bucket 0 holds the LAST leaves — the gradients
    backward produces first."""
    buckets: list[list[int]] = []
    cur: list[int] = []
    cur_bytes = 0
    for i in emission_order(len(sizes), reverse=True):
        b = sizes[i] * itemsize
        if cur and cur_bytes + b > slice_bytes:
            buckets.append(cur)
            cur, cur_bytes = [], 0
        cur.append(i)
        cur_bytes += b
    if cur:
        buckets.append(cur)
    return buckets


@register("hadronio_overlap")
class HadronioOverlapBackend(CommBackend):

    def validate(self, comm: CommConfig) -> None:
        if comm.compress != "none":
            raise ValueError(
                "hadronio_overlap does not support wire compression "
                f"(compress={comm.compress!r}): error-feedback state is "
                "keyed to the global ring-buffer plan, which bucketing "
                "does not build — use mode='hadronio' for compressed "
                "transfers")

    def needs_ef(self, comm: CommConfig) -> bool:
        return False

    def sync(self, grads, ctx: SyncContext) -> SyncResult:
        self.validate(ctx.comm)
        leaves, treedef = jax.tree.flatten(grads)
        sizes = [int(np.prod(l.shape)) if l.shape else 1 for l in leaves]
        buckets = make_buckets(sizes, ctx.comm.slice_bytes)

        def packed(bucket):
            flat = jnp.concatenate(
                [leaves[i].astype(jnp.float32).reshape(-1) for i in bucket])
            pad = -flat.shape[0] % _ALIGN
            return jnp.pad(flat, (0, pad)) if pad else flat

        reduced = pipeline.emit_through_channels(
            [packed(b) for b in buckets], ctx,
            lambda ch, x: ch.all_reduce(x))

        out: list = [None] * len(leaves)
        for red, bucket in zip(reduced, buckets):
            off = 0
            for i in bucket:
                piece = jax.lax.slice_in_dim(red, off, off + sizes[i],
                                             axis=0)
                out[i] = piece.reshape(leaves[i].shape).astype(
                    leaves[i].dtype)
                off += sizes[i]
        synced = jax.tree.unflatten(treedef, out)
        return SyncResult(synced, None, None, ctx.ef)
