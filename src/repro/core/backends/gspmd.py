"""``gspmd`` — the non-TAC reference: pure GSPMD auto sharding, XLA owns
every collective ("the kernel network stack"). No manual shard_map, no
explicit gradient exchange — ``sync`` is never traced; registering it
here keeps step/state dispatch registry-driven for ALL modes."""
from __future__ import annotations

from repro.core.backends.base import (CommBackend, SyncContext, SyncResult,
                                      register)


@register("gspmd")
class GspmdBackend(CommBackend):

    manual = False

    def sync(self, grads, ctx: SyncContext) -> SyncResult:
        raise RuntimeError(
            "gspmd mode has no explicit gradient exchange: XLA owns the "
            "collectives; sync_grads must not be called")

    def needs_ef(self, comm) -> bool:
        # no manual wire -> no compression, so the inherited state_specs
        # default yields tree moments with ef=None
        return False

    def validate(self, comm) -> None:
        if comm.compress != "none":
            raise ValueError(
                "gspmd cannot honor wire compression "
                f"(compress={comm.compress!r}): XLA owns the collectives "
                "— there is no manual wire stage; use a TAC mode")

    def serve_emit(self, flat, ctx, kind):
        """Serving reference path: one whole-payload collective, XLA owns
        the schedule (no ring-buffer slicing, no channel pool)."""
        from repro.core.backends import pipeline
        return pipeline.raw_emit(flat, ctx, kind)
