"""CommBackend — the pluggable transport layer behind ``sync_grads``.

hadroNIO's transparency claim is that the application keeps the NIO API
while the transport underneath is swapped (sockets / libvma / UCX). This
module enforces the same boundary structurally: every synchronization
strategy is a :class:`CommBackend` registered by mode name, and the only
way callers reach one is through the registry — ``core/tac.py`` and
``launch/steps.py`` carry no per-mode branches. Ibdxnet does the same
with its msgrc transport engine under an unchanged application interface
(arXiv:1812.01963); here the "engine" is a backend class.

A backend owns three things:

* ``sync(grads, ctx) -> SyncResult`` — the collective schedule for one
  gradient exchange, traced inside the fully-manual TAC ``shard_map``.
* ``state_specs(run, n_shards, pod_size)`` — the optimizer/error-feedback
  state layout this strategy needs (tree moments vs ZeRO-1 flat shards).
* ``apply_update(...)`` — how synced gradients become a parameter update
  (tree AdamW by default; ZeRO-1 shard update + all-gather for
  reduce-scatter strategies).

Capability flags replace mode-name dispatch everywhere else:
``manual`` (runs under the TAC shard_map vs GSPMD) and ``zero1``
(optimizer moments are flat ring-sharded slices).
"""
from __future__ import annotations

import abc
from dataclasses import dataclass
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import CommConfig, RunConfig
from repro.core import aggregation as agg
from repro.optim import adamw

PyTree = Any


class SyncResult(NamedTuple):
    """What one gradient exchange produced (fixed across all backends —
    the other half of the transparency boundary)."""
    grads: PyTree             # synced grads (tree), or None in zero1 modes
    flat_shard: Optional[jax.Array]   # data-sharded flat grads (zero1)
    plan: Any = None          # backend-owned pack plan (ring or bucketed)
    ef: Optional[PyTree] = None   # new error-feedback state (compression):
    #                           one array keyed to the global ring plan, or
    #                           a pytree keyed by bucket id (overlap modes)
    gather_axes: tuple = ()   # axes the zero1 shard was scattered over


@dataclass(frozen=True)
class SyncContext:
    """Resolved axis topology + carried state for one ``sync`` call."""
    comm: CommConfig
    pod_axis: Optional[str]   # pod axis when pod-aware collectives apply
    data_axis: Any            # in-pod DP axis name, or tuple of names
    flat_axes: tuple          # every DP axis as one flattened logical ring
    ef: Optional[PyTree] = None   # error-feedback residual (local): an
    #                           array (global ring keying) or a pytree
    #                           keyed by bucket id (per-bucket keying)
    channel_indices: Optional[tuple] = None   # channel-affinity override:
    #                           the disjoint run of the global channel
    #                           pool this emission may use (set by the
    #                           event-loop serving subsystem; None = the
    #                           full comm.channels pool)

    @classmethod
    def resolve(cls, comm: CommConfig, data_axis, pod_axis: Optional[str],
                ef: Optional[PyTree] = None,
                channel_indices: Optional[tuple] = None) -> "SyncContext":
        """``data_axis`` may be one axis name or a tuple of names (a
        flattened DP ring). Pod-awareness applies only when the config
        asks for hierarchical collectives AND a pod axis exists; in flat
        mode (pod, data) is treated as one logical ring."""
        data = (data_axis,) if isinstance(data_axis, str) else tuple(data_axis)
        data = data[0] if len(data) == 1 else data
        if pod_axis is None:
            flat = data if isinstance(data, tuple) else (data,)
            return cls(comm, None, data, flat, ef, channel_indices)
        flat = (pod_axis,) + (data if isinstance(data, tuple) else (data,))
        if comm.hierarchical:
            return cls(comm, pod_axis, data, flat, ef, channel_indices)
        return cls(comm, None, data, flat, ef, channel_indices)

    @property
    def data_axes_tuple(self) -> tuple:
        return self.data_axis if isinstance(self.data_axis, tuple) \
            else (self.data_axis,)


class StateSpecs(NamedTuple):
    """Backend-owned slice of the train state (ShapeDtypeStructs)."""
    opt: adamw.AdamState      # moment layout (tree or flat ring shards)
    ef: Optional[PyTree]      # error-feedback layout: one struct (global
    #                           ring keying) or a tuple keyed by bucket id


@dataclass(frozen=True)
class UpdateContext:
    """Mesh facts ``apply_update`` needs beyond the sync result."""
    axes: tuple               # every mesh axis name (loss/grad-norm psum)
    n_shards: int             # total ring size
    eff_shards: int           # scatter-group size (in-pod when hierarchical)


def scatter_group_size(n_shards: int, pod_size: int,
                       comm: CommConfig) -> int:
    """ZeRO-1 scatter-group size: with hierarchical (pod-aware)
    collectives the reduce-scatter runs IN-POD, so shards are 1/in-pod
    sized and replicated across pods (hierarchical ZeRO)."""
    if comm.hierarchical and pod_size > 1:
        assert n_shards % pod_size == 0
        return n_shards // pod_size
    return n_shards


class CommBackend(abc.ABC):
    """One synchronization strategy. Subclass + ``@register("name")``."""

    name: str = ""            # set by @register
    manual: bool = True       # True: runs inside the TAC manual shard_map
    zero1: bool = False       # True: flat ring-sharded optimizer moments

    # -- the transparent API --------------------------------------------

    @abc.abstractmethod
    def sync(self, grads: PyTree, ctx: SyncContext) -> SyncResult:
        """Exchange gradients across the DP axes (traced in shard_map)."""

    # -- state layout ----------------------------------------------------

    def needs_ef(self, comm: CommConfig) -> bool:
        return comm.compress in ("bf16", "int8_ef")

    def state_specs(self, run: RunConfig, n_shards: int,
                    pod_size: int = 1) -> StateSpecs:
        """Default layout: full-tree fp32 moments; per-peer error-feedback
        residual when compression is on (global shape carries the ring
        dim; each peer holds one row)."""
        from repro.models import api
        params = api.abstract(run.model)
        f32 = lambda p: jax.ShapeDtypeStruct(p.shape, jnp.float32)
        ef = None
        if self.needs_ef(run.comm):
            plan = agg.make_plan(params, run.comm)
            ef = jax.ShapeDtypeStruct(
                (n_shards, plan.n_slices, plan.slice_elems), jnp.float32)
        opt = adamw.AdamState(mu=jax.tree.map(f32, params),
                              nu=jax.tree.map(f32, params),
                              count=jax.ShapeDtypeStruct((), jnp.int32))
        return StateSpecs(opt=opt, ef=ef)

    # -- optimizer application ------------------------------------------

    def apply_update(self, params: PyTree, opt: adamw.AdamState,
                     res: SyncResult, run: RunConfig,
                     uctx: UpdateContext):
        """Turn a SyncResult into (new_params, new_opt, metrics). Default:
        tree AdamW on the synced gradient tree.

        ``metrics`` is a flat dict of replicated scalars; the step builder
        adds ``loss`` and maps the whole dict to a replicated out-spec, so
        backends may add/drop keys freely (every value must be identical
        across ring peers). Include ``grad_norm`` and ``lr`` to keep the
        Trainer's log line informative."""
        return adamw.update(res.grads, opt, params, run)

    def validate(self, comm: CommConfig) -> None:
        """Reject config combinations this strategy cannot honor (called
        at step-build time, before any tracing)."""

    # -- serving wire path ----------------------------------------------

    def serve_emit(self, flat: jax.Array, ctx: SyncContext,
                   kind: str) -> jax.Array:
        """Emit ONE flat f32 serving payload (a tensor-parallel partial
        logit sum, or a coalesced KV-cache gathering write) through this
        strategy's wire path — the inference-side transparency boundary:
        ``serving/dispatch.py`` never branches on mode names, it calls
        this. Default: the staged slice-pipeline emission the
        hadronio-family backends share (``pipeline.emit_flat`` — ring
        slices through the channel schedule at the configured
        aggregate/flush granularity, honoring ``ctx.channel_indices``
        affinity). ``kind`` is ``"all_reduce"`` (sum over the ring; the
        result is replicated), ``"all_gather"`` (peer-major
        concatenation: the result's leading factor is the ring size) or
        ``"all_to_all"`` (the MoE expert exchange: the payload is a
        peer-major ``(ring, len // ring)`` block and each peer receives
        its column of every peer's block). All strategies return
        bit-identical values — only the emission structure differs
        (conformance-tested)."""
        from repro.core.backends import pipeline
        group = jax.lax.psum(1, ctx.flat_axes) \
            if kind in ("all_gather", "all_to_all") else 1
        return pipeline.emit_flat(flat, ctx, kind, group=group)

    # -- reconstruction / resharding hooks ------------------------------

    def gathered_grads(self, res: SyncResult, like: PyTree) -> PyTree:
        """Reconstruct the full synced-gradient tree from a SyncResult
        (traced inside the shard_map). Default: the tree is already
        there. ZeRO-1 backends override: all-gather their flat shard over
        ``res.gather_axes`` and unpack. Used by the conformance suite and
        debugging tools; the train step never needs it."""
        assert res.grads is not None, \
            f"{self.name}: zero1 backend must override gathered_grads"
        return res.grads

    def reshard_flat_shards(self, run: RunConfig, stacked, new_shards: int):
        """Re-slice checkpointed ring-sharded flat optimizer state
        (global (old_shards, len) numpy array) for a ``new_shards`` ring
        (elastic restore). Only meaningful for zero1 backends — the state
        layout is backend-owned, so its resharding rule is too."""
        raise ValueError(
            f"comm backend {self.name!r} has no ring-sharded flat state "
            "to reshard")


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

_REGISTRY: dict[str, CommBackend] = {}


def register(name: str):
    """Class decorator: ``@register("hadronio")``. Instantiates the
    backend as a stateless singleton under ``name``."""
    def deco(cls):
        cls.name = name
        if name in _REGISTRY:
            raise ValueError(f"comm backend {name!r} already registered "
                             f"({type(_REGISTRY[name]).__name__})")
        _REGISTRY[name] = cls()
        return cls
    return deco


def get_backend(name: str) -> CommBackend:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise KeyError(
            f"unknown comm mode {name!r}; registered: "
            f"{', '.join(available_modes())}") from None


def available_modes() -> tuple:
    """Every registered mode name, sorted (the single source of truth for
    config validation and CLI choices)."""
    return tuple(sorted(_REGISTRY))
