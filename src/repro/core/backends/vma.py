"""``vma`` — the libvma analogue: one monolithic ``psum`` of the whole
packed gradient. Minimal op count, but no independence to overlap and a
full-size staging spike (the pack stage materializes every gradient
before the single send)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import aggregation as agg
from repro.core import compress as comp
from repro.core.backends.base import (CommBackend, SyncContext, SyncResult,
                                      register)


@register("vma")
class VmaBackend(CommBackend):

    def sync(self, grads, ctx: SyncContext) -> SyncResult:
        plan = agg.make_plan(grads, ctx.comm, dtype=jnp.float32)
        flat = agg.pack(grads, plan)
        if ctx.comm.compress == "bf16":
            wire, new_ef = comp.bf16_compress(flat[None], ctx.ef)
            red = jax.lax.psum(wire[0],
                               ctx.flat_axes).astype(jnp.float32)[None]
            synced = agg.unpack(agg.from_slices(red, plan), plan, grads)
            return SyncResult(synced, None, plan, new_ef)
        red = jax.lax.psum(flat, ctx.flat_axes)
        return SyncResult(agg.unpack(red, plan, grads), None, plan, ctx.ef)
