"""``vma`` — the libvma analogue: one monolithic ``psum`` of the whole
packed gradient. Minimal op count, but no independence to overlap and a
full-size staging spike (the pack stage materializes every gradient
before the single send)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import CommConfig
from repro.core import aggregation as agg
from repro.core.backends import pipeline
from repro.core.backends.base import (CommBackend, SyncContext, SyncResult,
                                      register)


@register("vma")
class VmaBackend(CommBackend):

    def validate(self, comm: CommConfig) -> None:
        if comm.compress == "int8_ef":
            raise ValueError(
                "vma cannot honor compress='int8_ef': the libvma analogue "
                "is one monolithic psum, and int8 summation needs the "
                "gather + local-dequant exchange of the hadronio family")

    def needs_ef(self, comm: CommConfig) -> bool:
        return comm.compress == "bf16"

    def sync(self, grads, ctx: SyncContext) -> SyncResult:
        self.validate(ctx.comm)
        plan = agg.make_plan(grads, ctx.comm, dtype=jnp.float32)
        flat = agg.pack(grads, plan)
        if ctx.comm.compress == "bf16":
            # pack stage over the ring-slice view (EF layout matches the
            # global-plan state spec); the wire is still ONE psum, and
            # the fused unpack stage does the cast back to f32
            wire, new_ef, _ = pipeline.pack_wire(
                agg.as_slices(flat, plan), ctx.ef, ctx.comm)
            red = pipeline.unpack_wire(jax.lax.psum(wire, ctx.flat_axes),
                                       ctx.comm)
            synced = agg.unpack(agg.from_slices(red, plan), plan, grads)
            return SyncResult(synced, None, plan, new_ef)
        red = jax.lax.psum(flat, ctx.flat_axes)
        return SyncResult(agg.unpack(red, plan, grads), None, plan, None)

    def serve_emit(self, flat, ctx, kind):
        """Monolithic serving send: the payload arrives pre-flattened, so
        the libvma one-big-psum schedule IS the raw whole-payload
        collective (coincides with sockets for a single buffer)."""
        return pipeline.raw_emit(flat, ctx, kind)
