"""Pluggable communication backends (see docs/COMM_BACKENDS.md).

Importing this package registers every built-in mode; external code asks
the registry (``get_backend`` / ``available_modes``) and never branches
on mode names — the hadroNIO transparency claim, enforced structurally.
"""
from repro.core.backends.base import (CommBackend, StateSpecs, SyncContext,
                                      SyncResult, UpdateContext,
                                      available_modes, get_backend,
                                      register, scatter_group_size)

# importing the mode modules runs their @register decorators
from repro.core.backends import gspmd        # noqa: F401
from repro.core.backends import sockets      # noqa: F401
from repro.core.backends import vma          # noqa: F401
from repro.core.backends import hadronio     # noqa: F401
from repro.core.backends import hadronio_rs  # noqa: F401
from repro.core.backends import hadronio_overlap  # noqa: F401
from repro.core.backends import hadronio_overlap_rs  # noqa: F401

__all__ = [
    "CommBackend", "StateSpecs", "SyncContext", "SyncResult",
    "UpdateContext", "available_modes", "get_backend", "register",
    "scatter_group_size",
]
