"""Flush-when-ready channel scheduling (paper §III-B/III-C).

hadroNIO flushes a connection's ring buffer the moment its data is ready:
the selector reports the channel writable and the gathering write goes
out immediately, which is how 5 µs round trips survive aggregation.
Ibdxnet makes the same point with dedicated send threads draining
per-connection ORBs as soon as they fill (arXiv:1812.01963). The XLA
analogue, and the ROADMAP follow-up this module closes: under
``comm.aggregate="channel"`` with fewer channels than buckets, PR 3's
one-barrier flush loop made every channel's coalesced collective depend
on a LATE bucket (round-robin puts some last-produced bucket on each
channel), forfeiting the overlap that the ``hadronio_overlap*`` modes
exist for.

``comm.flush`` selects the schedule:

* ``"step"`` — PR 3 behavior: buckets land on channels round-robin and
  every channel flushes in one end-of-exchange loop (the Netty analogue:
  a single ``flush()`` at the step barrier).
* ``"ready"`` — buckets are grouped onto channels CONTIGUOUSLY in
  gradient-production order (:func:`repro.core.selector.ready_groups`),
  and a channel's coalesced collective is emitted the moment the LAST
  bucket assigned to it is staged — mid-backward, before the loss
  epilogue. The first channel's flush then depends only on the
  first-produced buckets, so the latency-hiding scheduler can issue it
  while the remaining backward compute is still running.

Both schedules move identical bytes per item and produce bit-identical
results (a psum is elementwise; grouping never changes any element's
sum) — the trade-off is purely emission structure, which is why it is a
config axis and not a cliff (PAPERS.md: "A Benchmark to Evaluate
InfiniBand Solutions for Java Applications").
"""
from __future__ import annotations

from typing import NamedTuple

from repro.core.channels import channel_groups
from repro.core.selector import ready_groups

FLUSHES = ("step", "ready")


class FlushPlan(NamedTuple):
    """Static bucket->channel schedule of one exchange (shape-only,
    computed at trace time — the scheduling counterpart of
    ``BucketPlan``/``PackPlan``)."""
    n_items: int
    flush: str                # "step" | "ready"
    groups: tuple             # per channel: item ids, in staging order
    triggers: tuple           # per channel: item id whose staging makes
    #                           the channel ready (max of the group —
    #                           items are staged in production order)
    assign: tuple             # item id -> channel index

    @property
    def n_channels(self) -> int:
        return len(self.groups)

    @property
    def readiness_depth(self) -> int:
        """Items that must be produced before the FIRST flush can go out
        (the overlap metric: lower = earlier emission). ``step`` flushes
        nothing before the end of the exchange."""
        if self.flush != "ready":
            return self.n_items
        return min(self.triggers) + 1

    @property
    def contiguous(self) -> bool:
        """True when every channel's items are one contiguous run of ids
        (the ``ready`` layout) — the property the ZeRO-1 epilogue needs
        to all-gather per flush instead of per bucket."""
        return all(g == tuple(range(g[0], g[0] + len(g)))
                   for g in self.groups if g)


def make_flush_plan(n_items: int, n_channels: int,
                    flush: str = "step") -> FlushPlan:
    """Map ``n_items`` buckets/slices onto at most ``n_channels``
    channels under the given flush schedule. Items are always staged in
    production order (0..n-1: bucket 0 holds the gradients backward
    produces first), so a channel's readiness trigger is the max id it
    carries."""
    assert flush in FLUSHES, flush
    assert n_items >= 1, n_items
    n_channels = max(1, min(n_channels, n_items))
    if flush == "ready":
        groups = ready_groups(n_items, n_channels)
    else:
        groups = tuple(tuple(g)
                       for g in channel_groups(n_items, n_channels))
    assign = [0] * n_items
    triggers = []
    for c, g in enumerate(groups):
        for i in g:
            assign[i] = c
        triggers.append(max(g))
    return FlushPlan(n_items, flush, groups, tuple(triggers),
                     tuple(assign))


def make_leader_plan(n_local: int, n_leaders: int,
                     flush: str = "step") -> FlushPlan:
    """The SECOND level of the hierarchical emission: map the local-lane
    flushes (the in-pod stages, ids ``0..n_local-1``) onto the leader
    lanes that carry their coalesced cross-pod collective. The grouping
    is ALWAYS contiguous (``ready_groups``): local lanes flush in lane
    order under both schedules, so contiguous runs give each leader the
    earliest possible readiness. ``flush`` only decides the trigger —
    under ``"ready"`` a leader's cross-pod flush is emitted the moment
    the LAST local lane assigned to it has staged its in-pod shard
    (each pod's local flush triggers the leader flush, not a global
    barrier); under ``"step"`` leaders flush in the end-of-exchange
    loop, after every local lane."""
    assert flush in FLUSHES, flush
    assert n_local >= 1, n_local
    assert n_leaders >= 1, n_leaders
    n_leaders = min(n_leaders, n_local)
    groups = ready_groups(n_local, n_leaders)
    assign = [0] * n_local
    triggers = []
    for l, g in enumerate(groups):
        for c in g:
            assign[c] = l
        triggers.append(max(g))
    return FlushPlan(n_local, flush, groups, tuple(triggers),
                     tuple(assign))
