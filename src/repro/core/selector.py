"""Slice scheduling — the selector analogue (paper §III-B).

hadroNIO's selector polls one worker per connection; completion order is
whatever the NIC delivers. The XLA analogue: collectives become *ready* in
gradient-production order, and the only scheduling lever we own is the
emission structure — which ops are independent, and in which order they
are emitted. This module decides both:

* ``emission_order``: reverse-layer order (grads for the last layer are
  produced first in backward), so early slices' collectives can overlap
  the remaining backward compute — DDP-style bucketing, expressed to XLA
  by emitting those psums before the loss epilogue.
* ``ready_groups``: the bucket->channel grouping for the flush-when-ready
  schedule (``comm.flush="ready"``): readiness order IS the scheduling
  input — buckets are partitioned onto channels as contiguous runs of
  the production order, so each channel becomes flushable as soon as its
  own run of the backward pass has completed (hadroNIO's
  flush-on-writable, §III-B; consumed by ``core/flush_scheduler``).
* ``barrier``: ``optimization_barrier`` pinning, used by the benchmarks to
  force (or forbid) overlap when measuring — the paper's warmup barrier.
"""
from __future__ import annotations

from typing import Any

import jax

PyTree = Any


def emission_order(n_slices: int, reverse: bool = True) -> list[int]:
    order = list(range(n_slices))
    return order[::-1] if reverse else order


def ready_groups(n_slices: int, n_channels: int,
                 reverse: bool = False) -> tuple:
    """Partition ``emission_order(n_slices, reverse)`` into at most
    ``n_channels`` CONTIGUOUS runs — the bucket->channel grouping of the
    flush-when-ready schedule. Sizes are balanced to within one item,
    with the smaller runs FIRST so the first channel reaches readiness
    (all of its items produced) after the fewest buckets possible."""
    order = emission_order(n_slices, reverse)
    n_channels = max(1, min(n_channels, n_slices))
    base, rem = divmod(n_slices, n_channels)
    groups, off = [], 0
    for c in range(n_channels):
        size = base + (1 if c >= n_channels - rem else 0)
        groups.append(tuple(order[off:off + size]))
        off += size
    return tuple(groups)


def pod_aligned_groups(n_slices: int, n_groups: int,
                       n_blocks: int) -> tuple:
    """:func:`ready_groups` respecting pod boundaries: partition
    ``0..n_slices-1`` into ``n_groups`` contiguous runs that NEVER
    straddle one of ``n_blocks`` contiguous pod blocks (the blocks are
    themselves the ``ready_groups`` partition). Used by the topology-
    aware channel affinity: an event loop's owned channels all talk to
    peers of the same pod, so its flushes complete on in-pod links
    without waiting on a cross-pod straggler.

    With ``n_groups >= n_blocks`` each block is split among the groups
    assigned to it (balanced within the block); with fewer groups each
    group owns whole consecutive blocks. Either way the result is a
    disjoint, covering partition of contiguous runs."""
    n_blocks = max(1, min(n_blocks, n_slices))
    blocks = ready_groups(n_slices, n_blocks)
    n_groups = max(1, min(n_groups, n_slices))
    if n_groups < n_blocks:
        # each group owns whole consecutive blocks (concatenation of
        # contiguous blocks is contiguous)
        owner_runs = ready_groups(n_blocks, n_groups)
        return tuple(tuple(i for b in run for i in blocks[b])
                     for run in owner_runs)
    # distribute the groups over the blocks (ready_groups balances the
    # per-block group counts), then split each block among its groups
    per_block = [len(g) for g in ready_groups(n_groups, n_blocks)]
    out = []
    for b, block in enumerate(blocks):
        out.extend(ready_groups(len(block), per_block[b]))
        base = block[0]
        out[-per_block[b]:] = [tuple(base + i for i in g)
                               for g in out[-per_block[b]:]]
    return tuple(g for g in out if g)


def barrier(*trees: PyTree):
    """Pin ordering between pytrees (measurement fences in benchmarks)."""
    flat = [jax.tree.leaves(t) for t in trees]
    out = jax.lax.optimization_barrier(tuple(x for xs in flat for x in xs))
    res = []
    i = 0
    for t in trees:
        leaves, treedef = jax.tree.flatten(t)
        res.append(jax.tree.unflatten(treedef, list(out[i:i + len(leaves)])))
        i += len(leaves)
    return res if len(res) > 1 else res[0]
