"""Slice scheduling — the selector analogue (paper §III-B).

hadroNIO's selector polls one worker per connection; completion order is
whatever the NIC delivers. The XLA analogue: collectives become *ready* in
gradient-production order, and the only scheduling lever we own is the
emission structure — which ops are independent, and in which order they
are emitted. This module decides both:

* ``emission_order``: reverse-layer order (grads for the last layer are
  produced first in backward), so early slices' collectives can overlap
  the remaining backward compute — DDP-style bucketing, expressed to XLA
  by emitting those psums before the loss epilogue.
* ``barrier``: ``optimization_barrier`` pinning, used by the benchmarks to
  force (or forbid) overlap when measuring — the paper's warmup barrier.
"""
from __future__ import annotations

from typing import Any

import jax

PyTree = Any


def emission_order(n_slices: int, reverse: bool = True) -> list[int]:
    order = list(range(n_slices))
    return order[::-1] if reverse else order


def barrier(*trees: PyTree):
    """Pin ordering between pytrees (measurement fences in benchmarks)."""
    flat = [jax.tree.leaves(t) for t in trees]
    out = jax.lax.optimization_barrier(tuple(x for xs in flat for x in xs))
    res = []
    i = 0
    for t in trees:
        leaves, treedef = jax.tree.flatten(t)
        res.append(jax.tree.unflatten(treedef, list(out[i:i + len(leaves)])))
        i += len(leaves)
    return res if len(res) > 1 else res[0]
