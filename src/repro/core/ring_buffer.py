"""Ring-buffer slice planning — the TPU reading of hadroNIO's 8 MiB ring
buffer with 64 KiB slices (paper §V-B).

The flattened gradient stream is treated as a virtual ring buffer:
``slice_bytes`` is the aggregation granularity (one collective per slice),
``capacity_bytes`` bounds the number of slices in flight (unrolled,
independent collectives the XLA latency-hiding scheduler can overlap —
the "worker per connection" analogue). If the payload needs more slices
than the capacity admits, the slice size is grown (recorded in the plan)
— the paper's ring would instead block the writer, which has no analogue
in a statically scheduled HLO program.
"""
from __future__ import annotations

from dataclasses import dataclass

from repro.configs.base import CommConfig


ALIGN_BYTES = 512   # keeps pallas pack/unpack tiles >= one (8, 128) f32
#                     lane block (note: element-level plans in
#                     aggregation.make_plan align to 512 ELEMENTS; this
#                     byte-level rounding only guards direct consumers)


@dataclass(frozen=True)
class SlicePlan:
    total_bytes: int          # payload bytes (one sync dtype)
    slice_bytes: int          # effective slice size after capacity clamp
    n_slices: int
    requested_slice_bytes: int
    clamped: bool             # True if capacity forced slice growth
    align_pad_bytes: int = 0  # bytes the 512-B rounding added to a
    #                           capacity-grown slice (0 when unclamped)


def plan_slices(total_bytes: int, comm: CommConfig) -> SlicePlan:
    req = comm.slice_bytes
    max_inflight = max(1, comm.ring_capacity_bytes // req)
    n = max(1, -(-total_bytes // req))
    clamped = n > max_inflight
    align_pad = 0
    if clamped:
        n = max_inflight
        eff = -(-total_bytes // n)
        # capacity growth can land on any byte count; round up to the
        # 512-byte alignment so the pallas pack/unpack tiling keeps
        # lane-sized tiles instead of degrading to gcd-1
        aligned = -(-eff // ALIGN_BYTES) * ALIGN_BYTES
        align_pad = aligned - eff
        eff = aligned
    else:
        eff = req
    return SlicePlan(total_bytes=total_bytes, slice_bytes=eff, n_slices=n,
                     requested_slice_bytes=req, clamped=clamped,
                     align_pad_bytes=align_pad)
