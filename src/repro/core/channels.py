"""Comm channels — the worker-per-connection analogue (paper §III-B).

A :class:`CommChannel` is an independent logical stream of slice
collectives. hadroNIO gave each connection its own UCX worker so selectors
could poll many workers; here each channel's collectives are emitted as
independent HLO ops (no data dependencies between channels), which is the
property the XLA latency-hiding scheduler needs to progress them
concurrently. The microbenchmarks (benchmarks/latency.py, throughput.py)
sweep channel count 1..16, reproducing the paper's connection-count axis.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class CommChannel:
    index: int
    axes: tuple               # DP axis names this channel reduces over

    def all_reduce(self, x: jax.Array) -> jax.Array:
        return jax.lax.psum(x, self.axes)

    def ping(self, x: jax.Array, axis: str, n_shards: int) -> jax.Array:
        """One ring hop (the ping-pong primitive for the latency bench)."""
        perm = [(i, (i + 1) % n_shards) for i in range(n_shards)]
        return jax.lax.ppermute(x, axis, perm)


def make_channels(n: int, axes: tuple) -> list[CommChannel]:
    return [CommChannel(i, axes) for i in range(n)]


def round_robin(n_items: int, n_channels: int) -> list[int]:
    """Connection assignment used by the benchmarks (paper §IV-C assigns
    connections to selectors round-robin)."""
    return [i % n_channels for i in range(n_items)]
