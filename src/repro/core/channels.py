"""Comm channels — the worker-per-connection analogue (paper §III-B).

A :class:`CommChannel` is an independent logical stream of slice
collectives. hadroNIO gave each connection its own UCX worker so selectors
could poll many workers; here each channel's collectives are emitted as
independent HLO ops (no data dependencies between channels), which is the
property the XLA latency-hiding scheduler needs to progress them
concurrently.

Channels are LIVE infrastructure: the hadronio-family backends
(:mod:`repro.core.backends.pipeline`) assign ring-buffer slices to
channels round-robin (paper §IV-C assigns connections to selectors
round-robin) and every slice collective is issued through its channel.
Within one channel the collectives are CHAINED in order (an
``optimization_barrier`` pins each op on the channel's previous output),
so ``comm.channels`` genuinely bounds the number of in-flight
collectives — 1 serializes the whole exchange, >= n_slices is fully
independent. Under ``comm.aggregate="channel"`` the chain collapses
entirely: each channel's slices are coalesced (:func:`channel_groups`,
or contiguously in production order under ``comm.flush="ready"`` —
``core/flush_scheduler``) into one contiguous buffer and flushed with a
SINGLE collective — the paper's gathering write at connection
granularity. :class:`ChannelFill` is the per-channel fill watermark the
flush-when-ready schedule polls (the selector's writable signal,
§III-B). A channel built with a ``pod_axis`` issues pod-aware
two-level collectives (the multi-rail analogue); otherwise it reduces
over the flattened DP ring. The microbenchmarks (benchmarks/latency.py,
throughput.py) sweep channel count 1..16, reproducing the paper's
connection-count axis.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Optional

import jax

from repro.core.hierarchical import (psum_hierarchical,
                                     psum_scatter_hierarchical)

# ---------------------------------------------------------------------------
# Chaos seam: an observer called at TRACE time for every collective a channel
# emits, as ``hook(channel_index, kind)``. Tracing is deterministic, so the
# recorded emission trace is the replay evidence the chaos harness
# (serving/chaos.py) compares across same-seed runs — and the anchor the
# slow-channel scenario keys its completion-wait delays to. None = no-op.
# ---------------------------------------------------------------------------

_COLLECTIVE_HOOK = None


def set_collective_hook(hook) -> None:
    """Install ``hook(channel_index, kind)`` on every CommChannel
    collective (pair with :func:`clear_collective_hook`, try/finally)."""
    global _COLLECTIVE_HOOK
    _COLLECTIVE_HOOK = hook


def clear_collective_hook() -> None:
    global _COLLECTIVE_HOOK
    _COLLECTIVE_HOOK = None


def get_collective_hook():
    """The currently installed hook (None when clear) — lets a wrapper
    (the supervisor's per-channel emission counter) COMPOSE with an
    already-armed hook instead of clobbering it, and restore it after."""
    return _COLLECTIVE_HOOK


def _note(ch: "CommChannel", kind: str) -> None:
    if _COLLECTIVE_HOOK is not None:
        _COLLECTIVE_HOOK(ch.index, kind)


@dataclass(frozen=True)
class CommChannel:
    index: int
    axes: tuple               # DP axis names this channel reduces over
    pod_axis: Optional[str] = None   # set -> pod-aware 2-level collectives
    data_axis: Any = None     # in-pod DP axis (name or tuple) when pod-aware
    leader: bool = False      # carved for cross-pod traffic (leader lane):
    #                           under hierarchical channel-granularity
    #                           emission, local lanes carry the in-pod
    #                           stages and leader lanes the coalesced
    #                           cross-pod collective (the UCX multi-rail
    #                           analogue: the scarce link gets dedicated
    #                           connections)

    def all_reduce(self, x: jax.Array) -> jax.Array:
        _note(self, "all_reduce")
        if self.pod_axis is not None:
            return psum_hierarchical(x, self.pod_axis, self.data_axis)
        return jax.lax.psum(x, self.axes)

    def reduce_scatter(self, x: jax.Array) -> jax.Array:
        """Reduce + scatter over the channel's ring (in-pod when
        pod-aware, with a cross-pod all-reduce of the shard)."""
        _note(self, "reduce_scatter")
        if self.pod_axis is not None:
            return psum_scatter_hierarchical(x, self.pod_axis,
                                             self.data_axis)
        return jax.lax.psum_scatter(x, self.axes,
                                    scatter_dimension=x.ndim - 1, tiled=True)

    def all_gather(self, x: jax.Array) -> jax.Array:
        _note(self, "all_gather")
        return jax.lax.all_gather(x, self.axes, axis=x.ndim - 1, tiled=True)

    def all_to_all(self, x: jax.Array) -> jax.Array:
        """Peer-major exchange over the channel's ring: ``x`` is
        ``(group, m)`` (row p = this peer's payload FOR peer p) and the
        result's row p is peer p's payload for this peer — the MoE
        expert-parallel dispatch/combine primitive. Always the full
        flattened ring, even pod-aware: all-to-all carries source-target
        traffic, not replica groups, so there is no in-pod/cross-pod
        decomposition to ride leader lanes (``hlo_analysis._POD_KINDS``
        draws the same line)."""
        _note(self, "all_to_all")
        return jax.lax.all_to_all(x, self.axes, split_axis=0,
                                  concat_axis=0, tiled=True)

    def ping(self, x: jax.Array, axis: str, n_shards: int) -> jax.Array:
        """One ring hop (the ping-pong primitive for the latency bench)."""
        _note(self, "ping")
        perm = [(i, (i + 1) % n_shards) for i in range(n_shards)]
        return jax.lax.ppermute(x, axis, perm)

    # -- split-level collectives (the two-level leader emission) --------
    # A pod-aware exchange decomposes into an IN-POD stage on a local
    # lane and a CROSS-POD stage on a leader lane. These are the same
    # primitive ops psum_hierarchical composes — issuing them on separate
    # channels never changes any element's summation tree, so leader
    # emission is bit-identical to the per-channel hierarchical path.

    def _pod_aware(self) -> None:
        assert self.pod_axis is not None, \
            f"channel {self.index}: split-level collectives need a pod axis"

    def in_pod_reduce_scatter(self, x: jax.Array) -> jax.Array:
        """In-pod stage of a hierarchical reduce: each in-pod peer keeps
        its 1/n_data shard (trailing dim must divide the in-pod size)."""
        self._pod_aware()
        _note(self, "in_pod_reduce_scatter")
        return jax.lax.psum_scatter(x, self.data_axis,
                                    scatter_dimension=x.ndim - 1, tiled=True)

    def in_pod_all_gather(self, x: jax.Array) -> jax.Array:
        """In-pod gather (the return stage of a hierarchical all-reduce,
        or the local stage of a hierarchical gather)."""
        self._pod_aware()
        _note(self, "in_pod_all_gather")
        return jax.lax.all_gather(x, self.data_axis, axis=x.ndim - 1,
                                  tiled=True)

    def cross_pod_all_reduce(self, x: jax.Array) -> jax.Array:
        """Cross-pod sum of an in-pod-reduced shard — the leader lane's
        collective (1/n_data of the flat bytes ride the scarce link)."""
        self._pod_aware()
        _note(self, "cross_pod_all_reduce")
        return jax.lax.psum(x, self.pod_axis)

    def cross_pod_all_gather(self, x: jax.Array) -> jax.Array:
        """Cross-pod gather of in-pod-gathered buffers: the result is
        pod-major, matching the flattened (pod, data) peer order of a
        flat tiled all_gather."""
        self._pod_aware()
        _note(self, "cross_pod_all_gather")
        return jax.lax.all_gather(x, self.pod_axis, axis=x.ndim - 1,
                                  tiled=True)


@dataclass
class ChannelFill:
    """Fill watermark of one channel's gathering write — the selector's
    readiness signal (paper §III-B: a channel is reported writable when
    its ring-buffer data is ready to go out). The emitter stages each
    bucket/slice as its wire bytes exist; ``ready`` flips the moment the
    LAST assigned item lands, which is the flush trigger under
    ``comm.flush="ready"`` (``core/flush_scheduler``)."""
    assigned: frozenset           # item ids this channel carries
    staged: set = field(default_factory=set)
    flushed: bool = False

    def stage(self, i: int) -> None:
        assert i in self.assigned and i not in self.staged, \
            (i, sorted(self.assigned), sorted(self.staged))
        self.staged.add(i)

    @property
    def ready(self) -> bool:
        return not self.flushed and self.staged == set(self.assigned)

    @property
    def watermark(self) -> float:
        """Fill fraction in [0, 1] — 1.0 means flushable."""
        return len(self.staged) / max(1, len(self.assigned))


def make_channels(n: int, axes: tuple, *, pod_axis: Optional[str] = None,
                  data_axis: Any = None,
                  indices: Optional[tuple] = None,
                  leaders: frozenset = frozenset()) -> list[CommChannel]:
    """Build the channel pool. ``indices`` is the channel-affinity API
    (the event-loop serving subsystem, serving/event_loop.py): an event
    loop that OWNS a disjoint contiguous run of the global pool passes
    its run here and gets exactly those channels — ``n`` is ignored, the
    pool is the affinity set (Ibdxnet's per-thread connection ownership,
    arXiv:1812.01963 — no two loops ever emit on the same channel).
    ``leaders`` marks channel ids carved as cross-pod leader lanes (the
    two-level hierarchical emission; ``pipeline.channels_for`` resolves
    the set relative to the emitting pool)."""
    if indices is not None:
        return [CommChannel(int(i), axes, pod_axis, data_axis,
                            leader=int(i) in leaders)
                for i in indices]
    return [CommChannel(i, axes, pod_axis, data_axis, leader=i in leaders)
            for i in range(n)]


def round_robin(n_items: int, n_channels: int) -> list[int]:
    """Connection assignment (paper §IV-C assigns connections to
    selectors round-robin)."""
    return [i % n_channels for i in range(n_items)]


def channel_groups(n_items: int, n_channels: int) -> list[list[int]]:
    """The inverse view of :func:`round_robin`: for each channel, the item
    indices it carries, in emission order. Under
    ``comm.aggregate="channel"`` each group is ONE gathering-write flush —
    the channel's slices are coalesced into a single contiguous wire
    buffer and sent as one collective (paper §III-C: the ring buffer
    merges many small writes into one large request per connection)."""
    groups: list[list[int]] = [[] for _ in range(n_channels)]
    for i, c in enumerate(round_robin(n_items, n_channels)):
        groups[c].append(i)
    return groups
