"""TAC — the paper's primary contribution (see DESIGN.md §2-3)."""
from repro.core import aggregation, channels, compress, hierarchical, \
    ring_buffer, selector, tac
from repro.core.aggregation import PackPlan, as_slices, from_slices, \
    make_plan, pack, unpack
from repro.core.ring_buffer import SlicePlan, plan_slices
from repro.core.tac import SyncResult, gather_updated, sync_grads

__all__ = [
    "PackPlan", "SlicePlan", "SyncResult", "aggregation", "as_slices",
    "channels", "compress", "from_slices", "gather_updated", "hierarchical",
    "make_plan", "pack", "plan_slices", "ring_buffer", "selector",
    "sync_grads", "tac", "unpack",
]
