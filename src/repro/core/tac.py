"""TAC — Transparent Aggregated Communication (the paper's technique).

``sync_grads`` is the transparent boundary: every mode has the same
signature, so the model / training loop never changes when the comm stack
is swapped — the hadroNIO transparency claim, enforced by test AND by
structure: this module is a thin façade over the backend registry
(:mod:`repro.core.backends`); it contains no per-mode branches.

Modes (docs/COMM_BACKENDS.md):
  sockets          one ``psum`` per gradient tensor (plain-sockets
                   baseline: per-buffer sends, fixed cost per tensor).
  vma              one monolithic ``psum`` of the packed gradient (libvma
                   analogue: minimal op count, no independence, full-size
                   staging spike).
  hadronio         paper-faithful gathering-write: pack -> ring-buffer
                   slices -> one independent collective per slice, each
                   issued through its round-robin CommChannel ("worker
                   per connection").
  hadronio_rs      beyond-paper: per-slice reduce-scatter; the caller
                   updates a data-sharded (ZeRO-1) optimizer shard and
                   all-gathers the updated parameter slices back.
  hadronio_overlap beyond-paper: DDP-style reverse-layer bucketing; each
                   bucket's collective depends only on its own leaves so
                   it can overlap the remaining backward compute.
  hadronio_overlap_rs beyond-paper: bucketed ZeRO-1 — the same bucketing
                   composed with per-bucket reduce-scatter and the
                   flat-shard AdamW update (optim/flat.py).

All manual modes run inside a partial-manual ``shard_map`` (manual over
the DP axes, auto/GSPMD over the model axis) — see launch/steps.py.
"""
from __future__ import annotations

from typing import Any, Optional

import jax

from repro.configs.base import CommConfig
from repro.core import aggregation as agg
from repro.core.backends import SyncContext, SyncResult, get_backend
from repro.core.backends.hadronio_rs import gather_updated  # noqa: F401

PyTree = Any

__all__ = ["SyncResult", "sync_grads", "gather_updated", "shard_slice_len"]


def sync_grads(grads: PyTree, comm: CommConfig, *, data_axis: str = "data",
               pod_axis: Optional[str] = None,
               ef: Optional[jax.Array] = None) -> SyncResult:
    """Synchronize per-DP-shard gradients across the DP axes. The mode
    string selects a registered :class:`CommBackend`; the signature —
    and therefore every call site — is identical for all of them."""
    backend = get_backend(comm.mode)
    ctx = SyncContext.resolve(comm, data_axis, pod_axis, ef)
    return backend.sync(grads, ctx)


def shard_slice_len(plan: agg.PackPlan, n_data: int) -> int:
    assert plan.slice_elems % n_data == 0, (plan.slice_elems, n_data)
    return plan.slice_elems // n_data
