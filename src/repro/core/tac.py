"""TAC — Transparent Aggregated Communication (the paper's technique).

``sync_grads`` is the transparent boundary: every mode has the same
signature, so the model / training loop never changes when the comm stack
is swapped — the hadroNIO transparency claim, enforced by test.

Modes (DESIGN.md §2):
  sockets     one ``psum`` per gradient tensor (plain-sockets baseline:
              per-buffer sends, fixed cost paid per tensor).
  vma         one monolithic ``psum`` of the packed gradient (libvma
              analogue: minimal op count, no independence to overlap,
              full-size staging spike).
  hadronio    paper-faithful gathering-write: pack -> ring-buffer slices ->
              one independent collective per slice (unrolled; the XLA
              scheduler overlaps them with compute and each other —
              "worker per connection").
  hadronio_rs beyond-paper: per-slice reduce-scatter; the caller updates a
              data-sharded (ZeRO-1) optimizer shard and all-gathers the
              updated parameter slices back.

All modes run inside a partial-manual ``shard_map`` (manual over the DP
axes, auto/GSPMD over the model axis) — see launch/steps.py.
"""
from __future__ import annotations

from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import CommConfig
from repro.core import aggregation as agg
from repro.core import compress as comp
from repro.core.hierarchical import (all_gather_data, psum_hierarchical,
                                     psum_scatter_hierarchical)

PyTree = Any


class SyncResult(NamedTuple):
    grads: PyTree             # synced grads (tree), or None in _rs mode
    flat_shard: Optional[jax.Array]   # data-sharded flat grads (_rs mode)
    plan: Optional[agg.PackPlan]
    ef: Optional[jax.Array]   # new error-feedback state (compression)
    gather_axes: tuple = ()   # axes the _rs shard was scattered over


def _axes(comm: CommConfig, data_axis, pod_axis: Optional[str]):
    """``data_axis`` may be one axis name or a tuple of names (a flattened
    DP ring). Returns (pod, data, flat_axes)."""
    data = (data_axis,) if isinstance(data_axis, str) else tuple(data_axis)
    data = data[0] if len(data) == 1 else data
    if pod_axis is None:
        return None, data, data if isinstance(data, tuple) else (data,)
    flat = (pod_axis,) + (data if isinstance(data, tuple) else (data,))
    if comm.hierarchical:
        return pod_axis, data, flat
    # flat mode: treat (pod, data) as one logical ring
    return None, data, flat


def _reduce_slices(slices: jax.Array, comm: CommConfig, pod_axis,
                   data_axis, flat_axes, ef):
    """Per-slice independent all-reduce with optional compression.
    slices: (n, S) f32. Returns (reduced (n, S) f32, new_ef)."""
    new_ef = None
    if comm.compress == "bf16":
        wire, new_ef = comp.bf16_compress(slices, ef)
    elif comm.compress == "int8_ef":
        q, scale, new_ef = comp.int8_quantize(slices, ef)
        out = comp.int8_allreduce(q, scale, flat_axes)
        return out, new_ef
    else:
        wire = slices

    # one INDEPENDENT collective per ring-buffer slice (unrolled on n)
    outs = []
    for i in range(wire.shape[0]):
        s = wire[i]
        if comm.hierarchical and pod_axis is not None:
            r = psum_hierarchical(s, pod_axis, data_axis)
        else:
            r = jax.lax.psum(s, flat_axes)
        outs.append(r.astype(jnp.float32))
    return jnp.stack(outs), new_ef


def sync_grads(grads: PyTree, comm: CommConfig, *, data_axis: str = "data",
               pod_axis: Optional[str] = None,
               ef: Optional[jax.Array] = None) -> SyncResult:
    """Synchronize per-DP-shard gradients across the DP axes."""
    pod, data, flat_axes = _axes(comm, data_axis, pod_axis)

    if comm.mode == "sockets":
        synced = jax.tree.map(lambda g: jax.lax.psum(g, flat_axes), grads)
        return SyncResult(synced, None, None, ef)

    plan = agg.make_plan(grads, comm, dtype=jnp.float32)
    flat = agg.pack(grads, plan)

    if comm.mode == "vma":
        if comm.compress == "bf16":
            wire, new_ef = comp.bf16_compress(flat[None], ef)
            red = jax.lax.psum(wire[0], flat_axes).astype(jnp.float32)[None]
            synced = agg.unpack(agg.from_slices(red, plan), plan, grads)
            return SyncResult(synced, None, plan, new_ef)
        red = jax.lax.psum(flat, flat_axes)
        return SyncResult(agg.unpack(red, plan, grads), None, plan, ef)

    slices = agg.as_slices(flat, plan)

    if comm.mode == "hadronio":
        red, new_ef = _reduce_slices(slices, comm, pod, data, flat_axes, ef)
        synced = agg.unpack(agg.from_slices(red, plan), plan, grads)
        return SyncResult(synced, None, plan, new_ef)

    if comm.mode == "hadronio_rs":
        new_ef = None
        if comm.compress == "bf16":
            slices, new_ef = comp.bf16_compress(slices, ef)
        hier = comm.hierarchical and pod is not None
        data_t = data if isinstance(data, tuple) else (data,)
        gather_axes = data_t if hier else flat_axes
        shards = []
        for i in range(slices.shape[0]):
            s = psum_scatter_hierarchical(slices[i], pod, data) if hier else \
                jax.lax.psum_scatter(slices[i], flat_axes,
                                     scatter_dimension=0, tiled=True)
            shards.append(s.astype(jnp.float32))
        # (n_slices, S/n_shards) -> flat local shard, ZeRO-1 layout
        flat_shard = jnp.stack(shards).reshape(-1)
        return SyncResult(None, flat_shard, plan, new_ef, gather_axes)

    raise ValueError(f"unknown TAC mode {comm.mode!r}")


def gather_updated(flat_shard: jax.Array, plan: agg.PackPlan,
                   like: PyTree, comm: CommConfig, *,
                   gather_axes=("data",)) -> PyTree:
    """hadronio_rs epilogue: all-gather updated parameter slices (per slice,
    independent — overlappable) and unpack into the parameter tree.
    ``gather_axes``: the axes the shard was reduce-scattered over (from
    SyncResult.gather_axes)."""
    n = plan.n_slices
    shard = flat_shard.reshape(n, -1)
    outs = [all_gather_data(shard[i], gather_axes) for i in range(n)]
    return agg.unpack(agg.from_slices(jnp.stack(outs), plan), plan, like)


def shard_slice_len(plan: agg.PackPlan, n_data: int) -> int:
    assert plan.slice_elems % n_data == 0, (plan.slice_elems, n_data)
    return plan.slice_elems // n_data
