"""Gathering-write aggregation (paper §III-C) on gradient pytrees.

netty hands hadroNIO an array of buffers; hadroNIO merges as many as
possible into one contiguous ring-buffer region so one UCX request sends
what used to be N. Here: the gradient pytree is flattened into one
contiguous vector ("packed" — the merge), carved into ring-buffer slices,
and each slice becomes ONE collective. ``pack``/``unpack`` are the pure-JAX
copy path; kernels/ring_pack.py is the Pallas DMA version of the same copy.

Everything is shape-static: the plan is computed from the pytree structure
at trace time (property-tested for roundtrip exactness).
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import CommConfig
from repro.core.ring_buffer import SlicePlan, plan_slices

PyTree = Any


class PackPlan(NamedTuple):
    offsets: tuple            # per-leaf (start, end) in flat element space
    shapes: tuple             # per-leaf shapes
    total_elems: int
    padded_elems: int         # n_slices * slice_elems
    slice_elems: int
    n_slices: int
    slice_plan: SlicePlan
    dtype: Any


def make_plan(tree: PyTree, comm: CommConfig, dtype=jnp.float32) -> PackPlan:
    leaves = jax.tree.leaves(tree)
    shapes = tuple(tuple(l.shape) for l in leaves)
    sizes = [int(np.prod(s)) if s else 1 for s in shapes]
    starts = np.cumsum([0] + sizes)
    total = int(starts[-1])
    itemsize = jnp.dtype(dtype).itemsize
    sp = plan_slices(total * itemsize, comm)
    # align slices so reduce-scatter shards evenly over any DP axis <= 512
    slice_elems = max(512, sp.slice_bytes // itemsize)
    slice_elems = -(-slice_elems // 512) * 512
    n_slices = max(1, -(-total // slice_elems))
    return PackPlan(
        offsets=tuple((int(starts[i]), int(starts[i + 1]))
                      for i in range(len(sizes))),
        shapes=shapes,
        total_elems=total,
        padded_elems=n_slices * slice_elems,
        slice_elems=slice_elems,
        n_slices=n_slices,
        slice_plan=sp,
        dtype=jnp.dtype(dtype),
    )


def pack(tree: PyTree, plan: PackPlan) -> jax.Array:
    """Merge all leaves into one contiguous padded vector (the gathering
    write). Returns (padded_elems,) of plan.dtype."""
    leaves = jax.tree.leaves(tree)
    flat = jnp.concatenate([l.astype(plan.dtype).reshape(-1) for l in leaves])
    pad = plan.padded_elems - plan.total_elems
    if pad:
        flat = jnp.pad(flat, (0, pad))
    return flat


def unpack(flat: jax.Array, plan: PackPlan, like: PyTree) -> PyTree:
    """Inverse of ``pack``: carve the vector back into the pytree, casting
    each leaf to the dtype of ``like``."""
    leaves_like, treedef = jax.tree.flatten(like)
    out = []
    for (start, end), shape, ref in zip(plan.offsets, plan.shapes, leaves_like):
        piece = jax.lax.slice_in_dim(flat, start, end, axis=0)
        out.append(piece.reshape(shape).astype(ref.dtype))
    return jax.tree.unflatten(treedef, out)


def as_slices(flat: jax.Array, plan: PackPlan) -> jax.Array:
    """(padded_elems,) -> (n_slices, slice_elems) ring-buffer view."""
    return flat.reshape(plan.n_slices, plan.slice_elems)


def from_slices(slices: jax.Array, plan: PackPlan) -> jax.Array:
    return slices.reshape(plan.padded_elems)
