"""Gradient compression for TAC slices (beyond-paper, DESIGN.md §8).

bf16:    cast slices to bf16 on the wire, fp32 error feedback (the
         truncation residual is re-injected next step, so the update is
         unbiased over time).
int8_ef: per-slice max-abs int8 quantization, summed via all-gather +
         local reduction (wire bytes per device = shards x S/4 vs ring
         all-reduce's ~2S for bf16 — wins only for small, latency-bound
         slices; the benchmark sweeps this).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def bf16_compress(slices: jax.Array, ef: jax.Array | None):
    """slices: (n, S) f32. Returns (wire bf16, new error-feedback f32)."""
    if ef is not None:
        slices = slices + ef
    wire = slices.astype(jnp.bfloat16)
    new_ef = slices - wire.astype(jnp.float32)
    return wire, new_ef


def int8_quantize(slices: jax.Array, ef: jax.Array | None):
    """Returns (q int8, scale f32 (n,1), new_ef)."""
    if ef is not None:
        slices = slices + ef
    amax = jnp.max(jnp.abs(slices), axis=-1, keepdims=True)
    scale = jnp.maximum(amax, 1e-12) / 127.0
    q = jnp.clip(jnp.round(slices / scale), -127, 127).astype(jnp.int8)
    new_ef = slices - q.astype(jnp.float32) * scale
    return q, scale, new_ef


def int8_allreduce(q: jax.Array, scale: jax.Array, axes) -> jax.Array:
    """Sum int8 shards across ``axes`` via all-gather + local dequant-sum.
    q: (n, S) int8; scale: (n, 1) f32. Returns f32 (n, S) sum."""
    qg = q
    sg = scale
    for ax in (axes if isinstance(axes, (tuple, list)) else (axes,)):
        qg = jax.lax.all_gather(qg, ax, axis=0)       # (shards, ..., n, S)
        sg = jax.lax.all_gather(sg, ax, axis=0)
    qg = qg.reshape(-1, *q.shape)                      # (total_shards, n, S)
    sg = sg.reshape(-1, *scale.shape)
    return jnp.sum(qg.astype(jnp.float32) * sg, axis=0)
