"""Sharded, atomic, async checkpointing with elastic restore.

Layout (one directory per step)::

    <dir>/step_000123/
        manifest.json        # step, leaf paths/shapes/dtypes, run fingerprint
        <leaf-path>.npy      # one file per pytree leaf (host arrays)
    <dir>/LATEST             # atomically updated pointer file

Design notes:

* **Atomicity**: writes land in ``step_X.tmp-<pid>`` and are renamed into
  place; ``LATEST`` is written via rename too. A crash mid-save never
  corrupts the previous checkpoint — the restart loop (launch/train.py)
  always restores from ``LATEST``.
* **Async**: ``save_async`` snapshots arrays to host memory synchronously
  (cheap — device->host copy) and writes files on a background thread so
  the train loop is not blocked by disk. ``wait()`` joins before exit or
  the next save.
* **Elastic restore**: leaves are saved as *full* (unsharded) host arrays
  and restored with ``jax.device_put(x, sharding)`` against the *target*
  mesh's shardings — restoring a 256-chip checkpoint onto a 512-chip or
  8-device mesh is the same code path (tested in
  tests/test_checkpoint.py). At real 1000-node scale the writer would
  stream per-shard files (OCDBT); the manifest format already carries
  per-leaf metadata to allow that change without touching callers.
"""
from __future__ import annotations

import json
import os
import re
import shutil
import threading
import time
from typing import Any, Optional

import jax
import numpy as np

PyTree = Any

_SAFE = re.compile(r"[^A-Za-z0-9_.-]")


def _leaf_files(tree: PyTree) -> list[tuple[str, Any]]:
    """(relative-file-name, leaf) pairs via jax key paths."""
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        name = "_".join(_SAFE.sub("-", str(getattr(k, "key", getattr(k, "idx", k))))
                        for k in path) or "leaf"
        out.append((name + ".npy", leaf))
    return out


class CheckpointStore:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        self._thread: Optional[threading.Thread] = None
        os.makedirs(directory, exist_ok=True)

    # -- paths ---------------------------------------------------------

    def step_dir(self, step: int) -> str:
        return os.path.join(self.dir, f"step_{step:08d}")

    def latest_step(self) -> Optional[int]:
        p = os.path.join(self.dir, "LATEST")
        if not os.path.exists(p):
            return None
        with open(p) as f:
            s = f.read().strip()
        return int(s) if s else None

    def available_steps(self) -> list[int]:
        out = []
        for d in os.listdir(self.dir):
            m = re.fullmatch(r"step_(\d+)", d)
            if m and os.path.exists(os.path.join(self.dir, d,
                                                 "manifest.json")):
                out.append(int(m.group(1)))
        return sorted(out)

    # -- save ----------------------------------------------------------

    def save(self, step: int, tree: PyTree, extra: dict | None = None):
        """Blocking save. Snapshots to host then writes atomically."""
        host = [(name, np.asarray(leaf)) for name, leaf in _leaf_files(tree)]
        self._write(step, host, extra or {})

    def save_async(self, step: int, tree: PyTree,
                   extra: dict | None = None):
        """Non-blocking: host snapshot now, file IO on a thread."""
        self.wait()
        host = [(name, np.asarray(leaf)) for name, leaf in _leaf_files(tree)]
        t = threading.Thread(target=self._write, args=(step, host,
                                                       extra or {}),
                             daemon=True)
        t.start()
        self._thread = t

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _write(self, step: int, host: list, extra: dict):
        final = self.step_dir(step)
        tmp = f"{final}.tmp-{os.getpid()}"
        os.makedirs(tmp, exist_ok=True)
        manifest = {"step": step, "time": time.time(), "extra": extra,
                    "leaves": []}
        for name, arr in host:
            np.save(os.path.join(tmp, name), arr, allow_pickle=False)
            manifest["leaves"].append(
                {"file": name, "shape": list(arr.shape),
                 "dtype": str(arr.dtype)})
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
        # atomic LATEST update
        lp = os.path.join(self.dir, "LATEST")
        with open(lp + ".tmp", "w") as f:
            f.write(str(step))
        os.rename(lp + ".tmp", lp)
        self._gc()

    def _gc(self):
        steps = self.available_steps()
        for s in steps[: max(0, len(steps) - self.keep)]:
            shutil.rmtree(self.step_dir(s), ignore_errors=True)

    # -- restore -------------------------------------------------------

    def restore(self, step: int, like: PyTree,
                shardings: Optional[PyTree] = None,
                on_mismatch=None) -> PyTree:
        """Restore into the structure of ``like`` (ShapeDtypeStructs or
        arrays), placing leaves on ``shardings`` if given (elastic restore:
        the target mesh may differ from the one that saved).

        ``on_mismatch(name, arr, ref) -> arr`` resolves shape mismatches
        (used by launch/elastic.py to reslice ring-sized TAC state)."""
        d = self.step_dir(step)
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)
        files = {l["file"]: l for l in manifest["leaves"]}
        names = _leaf_files(like)
        sh_leaves = (jax.tree.leaves(shardings)
                     if shardings is not None else [None] * len(names))
        assert len(sh_leaves) == len(names), "sharding tree mismatch"
        out = []
        for (name, ref), sh in zip(names, sh_leaves):
            if name not in files:
                raise KeyError(f"checkpoint missing leaf {name}")
            arr = np.load(os.path.join(d, name), allow_pickle=False)
            want_shape = tuple(ref.shape)
            if tuple(arr.shape) != want_shape:
                if on_mismatch is None:
                    raise ValueError(
                        f"{name}: checkpoint shape {arr.shape} != "
                        f"{want_shape}")
                arr = on_mismatch(name, arr, ref)
                assert tuple(arr.shape) == want_shape, (arr.shape, want_shape)
            dtype = ref.dtype
            x = arr.astype(dtype) if arr.dtype != dtype else arr
            out.append(jax.device_put(x, sh) if sh is not None
                       else jax.numpy.asarray(x))
        _, treedef = jax.tree.flatten(like)
        return jax.tree.unflatten(treedef, out)

    def manifest(self, step: int) -> dict:
        with open(os.path.join(self.step_dir(step), "manifest.json")) as f:
            return json.load(f)
