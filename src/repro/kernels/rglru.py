"""RG-LRU linear-recurrence kernel (recurrentgemma) for TPU.

The gate/decay computation (sigmoids, per-block matmuls) is dense
elementwise work XLA already fuses well; the *sequential* part —

    h_t = a_t * h_{t-1} + b_t

— is what needs a kernel: lax.associative_scan materializes O(log T)
full-size intermediates in HBM, while this kernel streams (C, Wb) tiles
through VMEM with the running state in scratch, one HBM read + write per
element.

Grid = (B, n_w_blocks, n_chunks) with the chunk dim innermost and
sequential; scratch holds h (Wb,) per (batch, width-block) and is
re-initialized from ``h0`` at chunk 0.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro import compat

DEFAULT_CHUNK = 128
DEFAULT_WBLOCK = 512


def _rglru_kernel(a_ref, b_ref, h0_ref, y_ref, h_out_ref, h_sc, *,
                  chunk: int, n_chunks: int):
    jc = pl.program_id(2)

    @pl.when(jc == 0)
    def _init():
        h_sc[...] = h0_ref[0]

    a = a_ref[0]                       # (C, Wb) f32
    b = b_ref[0]
    h = h_sc[...]                      # (Wb,)

    def step(t, carry):
        h, y = carry
        at = jax.lax.dynamic_slice_in_dim(a, t, 1, axis=0)   # (1, Wb)
        bt = jax.lax.dynamic_slice_in_dim(b, t, 1, axis=0)
        h = at[0] * h + bt[0]
        y = jax.lax.dynamic_update_slice_in_dim(y, h[None], t, axis=0)
        return h, y

    h, y = jax.lax.fori_loop(
        0, chunk, step, (h, jnp.zeros((chunk, a.shape[1]), jnp.float32)))
    y_ref[0] = y
    h_sc[...] = h

    @pl.when(jc == n_chunks - 1)
    def _final():
        h_out_ref[0] = h_sc[...]


def rglru_kernel(a: jax.Array, b: jax.Array, h0: jax.Array, *,
                 chunk: int = DEFAULT_CHUNK, wblock: int = DEFAULT_WBLOCK,
                 interpret: bool = False):
    """a/b: (B, T, W) f32 (decay and gated input); h0: (B, W) f32.
    T % chunk == 0 and W % wblock == 0 (ops.py pads: a=1, b=0).
    Returns (h_seq (B, T, W), h_final (B, W))."""
    bsz, t, w = a.shape
    wblock = min(wblock, w)
    chunk = min(chunk, t)
    assert t % chunk == 0 and w % wblock == 0, (t, chunk, w, wblock)
    n_chunks = t // chunk

    kernel = functools.partial(_rglru_kernel, chunk=chunk,
                               n_chunks=n_chunks)
    seq_spec = pl.BlockSpec((1, chunk, wblock), lambda i, k, j: (i, j, k))
    vec_spec = pl.BlockSpec((1, wblock), lambda i, k, j: (i, k))
    y, h_final = pl.pallas_call(
        kernel,
        grid=(bsz, w // wblock, n_chunks),
        in_specs=[seq_spec, seq_spec, vec_spec],
        out_specs=(seq_spec, vec_spec),
        out_shape=(
            jax.ShapeDtypeStruct((bsz, t, w), jnp.float32),
            jax.ShapeDtypeStruct((bsz, w), jnp.float32),
        ),
        scratch_shapes=[pltpu.VMEM((wblock,), jnp.float32)],
        compiler_params=compat.tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(a, b, h0)
    return y, h_final
