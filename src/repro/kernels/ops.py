"""Jit'd public wrappers around the Pallas kernels.

Each wrapper: pads inputs to kernel block multiples, dispatches
``interpret=True`` automatically off-TPU (the CPU container validates the
kernel bodies in interpret mode; on TPU the same code compiles to Mosaic),
and slices padding off the outputs. Signatures mirror the jnp oracles in
kernels/ref.py one-to-one — tests sweep shapes/dtypes across both.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import flash_attention as _fa
from repro.kernels import rglru as _rg
from repro.kernels import ring_pack as _rp
from repro.kernels import rwkv6_scan as _wk


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def _pad_axis(x, axis, mult, value=0.0):
    n = x.shape[axis]
    target = -(-n // mult) * mult
    if target == n:
        return x
    pad = [(0, 0)] * x.ndim
    pad[axis] = (0, target - n)
    return jnp.pad(x, pad, constant_values=value)


# ---------------------------------------------------------------------------
# ring pack
# ---------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnames=("n_slices", "slice_elems",
                                             "wire_dtype", "with_ef"))
def pack_slices(flat: jax.Array, ef, *, n_slices: int, slice_elems: int,
                wire_dtype="bfloat16", with_ef: bool = True):
    """Fused (add-EF, cast, slice) — see ring_pack.py. flat must already be
    padded to n_slices*slice_elems (aggregation.pack guarantees it)."""
    return _rp.pack_slices_kernel(
        flat, ef, n_slices, slice_elems, jnp.dtype(wire_dtype),
        interpret=_interpret(), with_ef=with_ef)


@functools.partial(jax.jit, static_argnames=("out_dtype",))
def unpack_slices(wire: jax.Array, out_dtype="float32"):
    """Fused cast-from-wire-dtype + re-slice (the unpack stage /
    scattering read) — see ring_pack.py. wire: (n, S). Returns (n*S,)."""
    return _rp.unpack_slices_kernel(wire, jnp.dtype(out_dtype),
                                    interpret=_interpret())


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnames=("causal", "window", "bq", "bk"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window: int = 0,
                    bq: int = _fa.DEFAULT_BQ, bk: int = _fa.DEFAULT_BK):
    """q/k/v: (B, S, H, Dh) with k/v already GQA-expanded to H heads.
    Returns (B, S, H, Dh). Self-attention positions 0..S-1."""
    b, s, h, dh = q.shape
    bq_eff = min(bq, s) if s % min(bq, s) == 0 else min(bq, s)

    def to_bh(x):
        return x.transpose(0, 2, 1, 3).reshape(b * h, s, dh)

    qb, kb, vb = to_bh(q), to_bh(k), to_bh(v)
    blk = min(max(bq, bk), max(s, 1))
    qb = _pad_axis(qb, 1, blk)
    kb = _pad_axis(kb, 1, blk)
    vb = _pad_axis(vb, 1, blk)
    out = _fa.flash_attention_kernel(
        qb, kb, vb, causal=causal, window=window, s_valid=s,
        bq=min(bq, qb.shape[1]), bk=min(bk, kb.shape[1]),
        interpret=_interpret())
    out = out[:, :s]
    return out.reshape(b, h, s, dh).transpose(0, 2, 1, 3)


# ---------------------------------------------------------------------------
# WKV6
# ---------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnames=("chunk",))
def wkv6(r: jax.Array, k: jax.Array, v: jax.Array, w: jax.Array,
         u: jax.Array, s0: jax.Array, *, chunk: int = _wk.DEFAULT_CHUNK):
    """r/k/v/w: (B, T, H, hs); u: (H, hs); s0: (B, H, hs, hs). All f32
    math. Returns (y (B,T,H,hs), s_final (B,H,hs,hs)) — matches
    models.rwkv6._wkv_scan."""
    b, t, h, hs = r.shape
    c = min(chunk, max(t, 1))

    def to_bh(x):
        return x.transpose(0, 2, 1, 3).reshape(b * h, t, hs).astype(
            jnp.float32)

    rb, kb, vb = to_bh(r), to_bh(k), to_bh(v)
    wb = to_bh(w)
    # pad: w=1 (log 0, state frozen), k=v=r=0 (no output contribution)
    rb = _pad_axis(rb, 1, c)
    kb = _pad_axis(kb, 1, c)
    vb = _pad_axis(vb, 1, c)
    wb = _pad_axis(wb, 1, c, value=1.0)
    s0b = s0.reshape(b * h, hs, hs).astype(jnp.float32)
    y, s_f = _wk.wkv6_kernel(rb, kb, vb, wb, u.astype(jnp.float32), s0b,
                             chunk=c, interpret=_interpret())
    y = y[:, :t].reshape(b, h, t, hs).transpose(0, 2, 1, 3)
    return y, s_f.reshape(b, h, hs, hs)


# ---------------------------------------------------------------------------
# RG-LRU
# ---------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnames=("chunk", "wblock"))
def rglru(a: jax.Array, b: jax.Array, h0: jax.Array, *,
          chunk: int = _rg.DEFAULT_CHUNK, wblock: int = _rg.DEFAULT_WBLOCK):
    """Linear recurrence h_t = a_t h_{t-1} + b_t. a/b: (B, T, W) f32;
    h0: (B, W). Returns (h_seq, h_final) — matches models.hybrid._rglru's
    scan core."""
    bsz, t, w = a.shape
    c = min(chunk, max(t, 1))
    wb = min(wblock, w)
    a2 = _pad_axis(a.astype(jnp.float32), 1, c, value=1.0)
    b2 = _pad_axis(b.astype(jnp.float32), 1, c, value=0.0)
    a2 = _pad_axis(a2, 2, wb)
    b2 = _pad_axis(b2, 2, wb)
    h02 = _pad_axis(h0.astype(jnp.float32), 1, wb)
    y, hf = _rg.rglru_kernel(a2, b2, h02, chunk=c, wblock=wb,
                             interpret=_interpret())
    return y[:, :t, :w], hf[:, :w]
