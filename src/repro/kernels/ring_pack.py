"""Ring-buffer pack kernel — the paper's gathering-write copy path on TPU.

hadroNIO's hot spot is the memcpy of many small buffers into one
contiguous ring-buffer region (paper §III-C). The TPU reading: the packed
flat gradient must be (a) carved into ring slices, (b) cast to the wire
dtype and (c) error-feedback-corrected — three elementwise passes that
naive jnp code issues as separate HBM round trips. This kernel fuses them
into ONE HBM read + one write per element, tiled through VMEM.

    wire[i]   = cast(flat[i] + ef[i], wire_dtype)
    new_ef[i] = (flat[i] + ef[i]) - f32(wire[i])

Block layout: the flat buffer is viewed as (n_slices, slice_elems); grid =
(n_slices, slice_elems // LANE_BLOCK); each program moves one (1, 8·128·k)
tile HBM->VMEM->HBM. slice_elems is 512-aligned by the plan (aggregation
.make_plan; ring_buffer.plan_slices additionally rounds capacity-grown
slices to 512 BYTES — at least 128 f32 lanes — for direct byte-level
consumers), so tiles are always lane-aligned.

``unpack_slices_kernel`` is the scattering-read counterpart — the live
unpack stage of the wire pipeline (backends/pipeline.unpack_wire): one
fused cast-from-wire-dtype + re-slice pass over the stacked collective
results, replacing a per-slice ``.astype`` epilogue.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANE_BLOCK = 8 * 128 * 4          # 4096 f32 = 16 KiB per tile per buffer


def _block_for(slice_elems: int, block: int) -> int:
    """Largest tile <= ``block`` that divides ``slice_elems`` exactly.
    Slices are 512-aligned by the plan, so the gcd never drops below the
    lane granularity for any 512-aligned slice length."""
    blk = min(block, slice_elems)
    if slice_elems % blk:
        blk = math.gcd(slice_elems, blk)
    return blk


def _pack_kernel(flat_ref, ef_ref, wire_ref, new_ef_ref):
    x = flat_ref[...].astype(jnp.float32)
    if ef_ref is not None:
        x = x + ef_ref[...]
    w = x.astype(wire_ref.dtype)
    wire_ref[...] = w
    if new_ef_ref is not None:
        new_ef_ref[...] = x - w.astype(jnp.float32)


def _unpack_kernel(wire_ref, out_ref):
    out_ref[...] = wire_ref[...].astype(out_ref.dtype)


def pack_slices_kernel(flat: jax.Array, ef, n_slices: int,
                       slice_elems: int, wire_dtype,
                       *, block: int = LANE_BLOCK, interpret: bool = False,
                       with_ef: bool = True):
    """flat: (n_slices * slice_elems,) f32. Returns (wire (n, S) of
    wire_dtype, new_ef (n, S) f32 or None)."""
    assert flat.shape == (n_slices * slice_elems,), flat.shape
    blk = _block_for(slice_elems, block)
    grid = (n_slices, slice_elems // blk)
    x2 = flat.reshape(n_slices, slice_elems)
    spec = pl.BlockSpec((1, blk), lambda i, j: (i, j))

    if with_ef:
        if ef is None:
            ef = jnp.zeros((n_slices, slice_elems), jnp.float32)
        kernel = _pack_kernel
        in_specs = [spec, spec]
        args = (x2, ef)
        out_shape = (jax.ShapeDtypeStruct((n_slices, slice_elems),
                                          jnp.dtype(wire_dtype)),
                     jax.ShapeDtypeStruct((n_slices, slice_elems),
                                          jnp.float32))
        out_specs = (spec, spec)
        wire, new_ef = pl.pallas_call(
            kernel, grid=grid, in_specs=in_specs, out_specs=out_specs,
            out_shape=out_shape, interpret=interpret)(*args)
        return wire, new_ef

    def kernel_no_ef(flat_ref, wire_ref):
        _pack_kernel(flat_ref, None, wire_ref, None)

    wire = pl.pallas_call(
        kernel_no_ef, grid=grid, in_specs=[spec], out_specs=spec,
        out_shape=jax.ShapeDtypeStruct((n_slices, slice_elems),
                                       jnp.dtype(wire_dtype)),
        interpret=interpret)(x2)
    return wire, None


def unpack_slices_kernel(wire: jax.Array, out_dtype=jnp.float32,
                         *, block: int = LANE_BLOCK,
                         interpret: bool = False) -> jax.Array:
    """(n, S) wire -> (n * S,) of out_dtype (one fused cast+copy pass)."""
    n, s = wire.shape
    blk = _block_for(s, block)
    spec = pl.BlockSpec((1, blk), lambda i, j: (i, j))
    out = pl.pallas_call(
        _unpack_kernel, grid=(n, s // blk), in_specs=[spec], out_specs=spec,
        out_shape=jax.ShapeDtypeStruct((n, s), jnp.dtype(out_dtype)),
        interpret=interpret)(wire)
    return out.reshape(n * s)
