"""Pure-jnp oracles for every Pallas kernel (signature-identical to
kernels/ops.py). Tests sweep shapes/dtypes across both and
assert_allclose; the model code uses these same formulations, so a kernel
validated here is validated against the training path."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import attention as att


# -- ring pack ---------------------------------------------------------------


def pack_slices(flat: jax.Array, ef, *, n_slices: int, slice_elems: int,
                wire_dtype="bfloat16", with_ef: bool = True):
    x = flat.reshape(n_slices, slice_elems).astype(jnp.float32)
    if not with_ef:
        return x.astype(jnp.dtype(wire_dtype)), None
    if ef is None:
        ef = jnp.zeros_like(x)
    y = x + ef
    wire = y.astype(jnp.dtype(wire_dtype))
    return wire, y - wire.astype(jnp.float32)


def unpack_slices(wire: jax.Array, out_dtype="float32"):
    return wire.astype(jnp.dtype(out_dtype)).reshape(-1)


# -- flash attention ---------------------------------------------------------


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window: int = 0, **_):
    s = q.shape[1]
    pos = jnp.arange(s)
    return att.attend_direct(q, k, v, pos, pos, causal=causal,
                             window=window)


# -- WKV6 --------------------------------------------------------------------


def wkv6(r, k, v, w, u, s0, **_):
    from repro.models.rwkv6 import _wkv_scan
    f32 = lambda x: x.astype(jnp.float32)
    return _wkv_scan(f32(r), f32(k), f32(v), f32(w), f32(u), f32(s0))


# -- RG-LRU ------------------------------------------------------------------


def rglru(a, b, h0, **_):
    a = a.astype(jnp.float32)
    b = b.astype(jnp.float32)
    b = b.at[:, 0].add(a[:, 0] * h0.astype(jnp.float32))

    def combine(c1, c2):
        a1, b1 = c1
        a2, b2 = c2
        return a1 * a2, a2 * b1 + b2

    _, hs = jax.lax.associative_scan(combine, (a, b), axis=1)
    return hs, hs[:, -1]
