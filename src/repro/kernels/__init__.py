"""Pallas TPU kernels for the perf-critical compute layers, each validated
in interpret mode against the pure-jnp oracle in kernels/ref.py:

* ring_pack        — fused EF-add + cast + slice (the gathering-write copy)
* flash_attention  — blockwise online-softmax attention (32k prefill)
* rwkv6_scan       — chunked WKV6 recurrence (history matmul + local loop)
* rglru            — RG-LRU linear recurrence (VMEM-streamed scan)
"""
from repro.kernels import ops, ref

__all__ = ["ops", "ref"]
