"""Chunked WKV6 recurrence kernel (RWKV-6 time mix) for TPU.

The recurrence (models/rwkv6.py)::

    S_t = diag(w_t) S_{t-1} + k_t v_t^T
    y_t = r_t (S_{t-1} + diag(u) k_t v_t^T)

is split per chunk of C steps: the *history* contribution is one MXU
matmul, the *intra-chunk* part is a C-step VPU loop entirely in VMEM:

    la_t   = cumsum(log w)_t              (la_0 = log w_1 ... within chunk)
    y_t    = (r_t . exp(la_{t-1})) @ S_in        # history, (C,hs)@(hs,hs)
           + r_t (L_{t-1} + diag(u) k_t v_t^T)   # local loop, L_0 = 0
    S_out  = exp(la_C) * S_in + L_C

All decay factors used are exp of non-positive numbers — numerically safe
for any w in (0,1) (no 1/A blowup; see DESIGN.md hardware-adaptation).

Grid = (B*H, n_chunks), chunk dim sequential; the running state lives in
a (hs, hs) f32 VMEM scratch. Inputs are (BH, T, hs) f32 (ops.py reshapes
from the model's (B,T,H,hs)); u is (H, hs) indexed by bh % H via BlockSpec
index_map (a free modular broadcast, no gather).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro import compat

DEFAULT_CHUNK = 32


def _wkv6_kernel(r_ref, k_ref, v_ref, w_ref, u_ref, s0_ref,
                 y_ref, s_out_ref, state_sc, *, chunk: int, n_chunks: int,
                 hs: int):
    jc = pl.program_id(1)

    @pl.when(jc == 0)
    def _init():
        state_sc[...] = s0_ref[0]

    r = r_ref[0]                      # (C, hs) f32
    k = k_ref[0]
    v = v_ref[0]
    w = w_ref[0]
    u = u_ref[0]                      # (hs,)
    s_in = state_sc[...]              # (hs, hs)

    logw = jnp.log(w)
    la = jnp.cumsum(logw, axis=0)                       # (C, hs), <= 0
    la_prev = la - logw                                  # cum through t-1

    # history: y_hist[t] = (r_t * exp(la_prev_t)) @ S_in
    r_tilde = r * jnp.exp(la_prev)
    y_hist = jax.lax.dot_general(r_tilde, s_in, (((1,), (0,)), ((), ())),
                                 preferred_element_type=jnp.float32)

    # intra-chunk: sequential rank-1 updates on the local state
    def step(t, carry):
        s_loc, y_acc = carry
        rt = jax.lax.dynamic_slice_in_dim(r, t, 1, axis=0)   # (1, hs)
        kt = jax.lax.dynamic_slice_in_dim(k, t, 1, axis=0)
        vt = jax.lax.dynamic_slice_in_dim(v, t, 1, axis=0)
        wt = jax.lax.dynamic_slice_in_dim(w, t, 1, axis=0)
        kv = kt.T * vt                                       # (hs, hs)
        y_t = (rt @ s_loc) + (rt * u[None, :]) @ kv          # (1, hs)
        y_acc = jax.lax.dynamic_update_slice_in_dim(y_acc, y_t, t, axis=0)
        s_loc = wt.T * s_loc + kv
        return s_loc, y_acc

    s_loc, y_local = jax.lax.fori_loop(
        0, chunk, step,
        (jnp.zeros((hs, hs), jnp.float32), jnp.zeros((chunk, hs),
                                                     jnp.float32)))

    y_ref[0] = y_hist + y_local
    state_sc[...] = jnp.exp(la[-1])[:, None] * s_in + s_loc

    @pl.when(jc == n_chunks - 1)
    def _final():
        s_out_ref[0] = state_sc[...]


def wkv6_kernel(r: jax.Array, k: jax.Array, v: jax.Array, w: jax.Array,
                u: jax.Array, s0: jax.Array, *,
                chunk: int = DEFAULT_CHUNK,
                interpret: bool = False):
    """r/k/v/w: (BH, T, hs) f32; u: (H, hs); s0: (BH, hs, hs) f32.
    T must be a multiple of ``chunk`` (ops.py pads with w=1, k=v=0).
    Returns (y (BH, T, hs), s_final (BH, hs, hs))."""
    bh, t, hs = r.shape
    h = u.shape[0]
    assert t % chunk == 0, (t, chunk)
    n_chunks = t // chunk

    kernel = functools.partial(_wkv6_kernel, chunk=chunk,
                               n_chunks=n_chunks, hs=hs)
    seq_spec = pl.BlockSpec((1, chunk, hs), lambda b, j: (b, j, 0))
    y, s_final = pl.pallas_call(
        kernel,
        grid=(bh, n_chunks),
        in_specs=[
            seq_spec, seq_spec, seq_spec, seq_spec,
            pl.BlockSpec((1, hs), lambda b, j: (b % h, 0)),
            pl.BlockSpec((1, hs, hs), lambda b, j: (b, 0, 0)),
        ],
        out_specs=(
            seq_spec,
            pl.BlockSpec((1, hs, hs), lambda b, j: (b, 0, 0)),
        ),
        out_shape=(
            jax.ShapeDtypeStruct((bh, t, hs), jnp.float32),
            jax.ShapeDtypeStruct((bh, hs, hs), jnp.float32),
        ),
        scratch_shapes=[pltpu.VMEM((hs, hs), jnp.float32)],
        compiler_params=compat.tpu_compiler_params(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(r, k, v, w, u, s0)
    return y, s_final
