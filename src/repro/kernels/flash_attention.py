"""Blockwise (flash-style) causal attention kernel for TPU.

The 32k-prefill cells are compute-dominated by attention; the jnp oracle
(models/attention.attend_chunked) materializes (bq, skv) score tiles in
HBM between ops. This kernel keeps the online-softmax state (m, l, acc)
in VMEM scratch across KV blocks, so each (q-block, kv-block) tile does
two MXU matmuls with no HBM round trip for intermediates.

Layout: q/k/v arrive as (BH, S, Dh) (heads pre-expanded/fused with batch
by ops.py). Grid = (BH, n_q_blocks, n_kv_blocks) with the KV dim
innermost and sequential ('arbitrary'): scratch carries (m, l, acc) per
q-block; the normalized output is written on the last KV block.

Masking: causal (kv_pos <= q_pos), optional sliding window
(q_pos - kv_pos < window), and a validity bound ``s_valid`` so ops.py can
pad S to block multiples. Fully-masked tiles short-circuit via pl.when.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro import compat

NEG_INF = -1e30

DEFAULT_BQ = 512
DEFAULT_BK = 512


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_sc, l_sc, acc_sc, *,
                  bq: int, bk: int, n_kv: int, causal: bool, window: int,
                  s_valid: int, scale: float):
    iq = pl.program_id(1)
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        m_sc[...] = jnp.full_like(m_sc, NEG_INF)
        l_sc[...] = jnp.zeros_like(l_sc)
        acc_sc[...] = jnp.zeros_like(acc_sc)

    qpos = iq * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    kpos = ik * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    mask = kpos < s_valid
    if causal:
        mask &= kpos <= qpos
    if window > 0:
        mask &= (qpos - kpos) < window

    # skip tiles that the causal/window structure fully masks
    q_lo, q_hi = iq * bq, (iq + 1) * bq - 1
    k_lo, k_hi = ik * bk, (ik + 1) * bk - 1
    live = k_lo < s_valid
    if causal:
        live &= k_lo <= q_hi
    if window > 0:
        live &= (q_lo - k_hi) < window

    @pl.when(live)
    def _compute():
        q = q_ref[0].astype(jnp.float32)          # (bq, dh)
        k = k_ref[0].astype(jnp.float32)          # (bk, dh)
        v = v_ref[0]
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        s = s * scale
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_sc[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        corr = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new[:, None])
        l_sc[...] = l_sc[...] * corr + jnp.sum(p, axis=-1)
        pv = jax.lax.dot_general(p.astype(v.dtype), v,
                                 (((1,), (0,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        acc_sc[...] = acc_sc[...] * corr[:, None] + pv
        m_sc[...] = m_new

    @pl.when(ik == n_kv - 1)
    def _finalize():
        denom = jnp.maximum(l_sc[...], 1e-30)[:, None]
        o_ref[0] = (acc_sc[...] / denom).astype(o_ref.dtype)


def flash_attention_kernel(q: jax.Array, k: jax.Array, v: jax.Array, *,
                           causal: bool = True, window: int = 0,
                           s_valid: int | None = None,
                           bq: int = DEFAULT_BQ, bk: int = DEFAULT_BK,
                           interpret: bool = False) -> jax.Array:
    """q/k/v: (BH, S, Dh), S a multiple of max(bq, bk). Returns (BH, S, Dh).
    ``s_valid``: number of real (unpadded) positions."""
    bh, s, dh = q.shape
    assert k.shape == (bh, s, dh) and v.shape == (bh, s, dh)
    bq = min(bq, s)
    bk = min(bk, s)
    assert s % bq == 0 and s % bk == 0, (s, bq, bk)
    n_q, n_kv = s // bq, s // bk
    if s_valid is None:
        s_valid = s
    scale = 1.0 / math.sqrt(dh)

    kernel = functools.partial(
        _flash_kernel, bq=bq, bk=bk, n_kv=n_kv, causal=causal,
        window=window, s_valid=s_valid, scale=scale)

    grid = (bh, n_q, n_kv)
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, dh), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bk, dh), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, bk, dh), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, dh), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, s, dh), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq, dh), jnp.float32),
        ],
        compiler_params=compat.tpu_compiler_params(
            dimension_semantics=("parallel", "arbitrary", "arbitrary")),
        interpret=interpret,
    )(q, k, v)
    return out
