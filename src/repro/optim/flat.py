"""AdamW on packed-flat vectors — the ZeRO-1 shard path.

The reduce-scatter backends keep optimizer moments as flat, ring-sharded
slices of the packed gradient vector; this module is the flat-vector
mirror of :mod:`repro.optim.adamw` (same schedule, same decoupled decay,
decay masked per element instead of per leaf).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import RunConfig
from repro.core import aggregation as agg
from repro.optim import adamw


def decay_mask_flat(plan: agg.PackPlan) -> np.ndarray:
    """Per-element weight-decay mask in packed-flat layout (decay only
    params with ndim >= 2, matching adamw.update)."""
    mask = np.zeros((plan.padded_elems,), np.float32)
    for (start, end), shape in zip(plan.offsets, plan.shapes):
        if len(shape) >= 2:
            mask[start:end] = 1.0
    return mask


def decay_mask_traced(plan: agg.PackPlan) -> jax.Array:
    """Same mask built from fills inside the trace — avoids embedding a
    params-sized host constant in the jaxpr (a 110B model's mask is
    ~2 GB; ranges of 2D leaves are contiguous, so a handful of
    dynamic-update-slices suffice)."""
    mask = jnp.zeros((plan.padded_elems,), jnp.float32)
    run_start = None
    runs = []
    for (start, end), shape in zip(plan.offsets, plan.shapes):
        if len(shape) >= 2:
            if run_start is None:
                run_start = start
            run_end = end
        else:
            if run_start is not None:
                runs.append((run_start, run_end))
                run_start = None
    if run_start is not None:
        runs.append((run_start, run_end))
    for s, e in runs:
        mask = jax.lax.dynamic_update_slice_in_dim(
            mask, jnp.ones((e - s,), jnp.float32), s, axis=0)
    return mask


def reshard_ring_segments(stacked: np.ndarray, old_shards: int,
                          new_shards: int, seg_lens) -> np.ndarray:
    """Re-slice ring-sharded flat state for a new ring size (elastic
    restore). The global layout is segment-major: each segment (a ring
    slice or an overlap bucket) of global length ``L`` is carved into
    ``shards`` contiguous chunks in ring order, and each peer's row is
    the concatenation of its chunk of every segment. ``stacked``:
    (old_shards, sum(L)/old_shards). Returns (new_shards, ...)."""
    seg_lens = [int(L) for L in seg_lens]
    assert stacked.shape == (old_shards, sum(seg_lens) // old_shards), \
        (stacked.shape, old_shards, sum(seg_lens))
    for L in seg_lens:
        assert L % old_shards == 0 and L % new_shards == 0, \
            (L, old_shards, new_shards)
    # rebuild each segment's global vector from the old chunks
    globs, off = [], 0
    for L in seg_lens:
        c = L // old_shards
        globs.append(np.concatenate([stacked[i, off:off + c]
                                     for i in range(old_shards)]))
        off += c
    return np.stack([
        np.concatenate([g[j * (len(g) // new_shards):
                          (j + 1) * (len(g) // new_shards)] for g in globs])
        for j in range(new_shards)])


def flat_adamw_update(flat_p, flat_g, mu, nu, count, decay_mask,
                      run: RunConfig):
    """AdamW on flat vectors. All f32. Returns (new_p, new_mu, new_nu)."""
    b1, b2 = run.beta1, run.beta2
    lr = adamw.schedule(run, count)
    c1 = 1.0 - b1 ** count.astype(jnp.float32)
    c2 = 1.0 - b2 ** count.astype(jnp.float32)
    mu = b1 * mu + (1 - b1) * flat_g
    nu = b2 * nu + (1 - b2) * jnp.square(flat_g)
    step = (mu / c1) / (jnp.sqrt(nu / c2) + run.eps)
    step = step + run.weight_decay * decay_mask * flat_p
    return flat_p - lr * step, mu, nu
