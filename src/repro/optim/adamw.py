"""AdamW with decoupled weight decay, global-norm clipping and a linear
warmup + cosine decay schedule. Pure pytree functions (no optax dependency)
so the same code drives both the pytree path and TAC's packed-flat ZeRO
path (arrays are arrays).

Moments are fp32 regardless of parameter dtype; the update is computed in
fp32 and cast back.
"""
from __future__ import annotations

import math
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import RunConfig

PyTree = Any


class AdamState(NamedTuple):
    mu: PyTree     # first moment (fp32)
    nu: PyTree     # second moment (fp32)
    count: jax.Array


def init(params: PyTree) -> AdamState:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return AdamState(mu=jax.tree.map(zeros, params),
                     nu=jax.tree.map(zeros, params),
                     count=jnp.zeros((), jnp.int32))


def schedule(cfg: RunConfig, step: jax.Array) -> jax.Array:
    warm = jnp.minimum(step.astype(jnp.float32) / max(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip((step - cfg.warmup_steps) /
                    max(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = 0.5 * (1.0 + jnp.cos(math.pi * prog))
    return cfg.lr * warm * (0.1 + 0.9 * cos)


def global_norm(tree: PyTree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def clip_by_global_norm(grads: PyTree, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-12))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale), grads), norm


def update(grads: PyTree, state: AdamState, params: PyTree,
           cfg: RunConfig) -> tuple[PyTree, AdamState, dict]:
    """Returns (new_params, new_state, metrics). ``grads`` may be any dtype;
    math is fp32. Weight decay is decoupled and skipped for 1-D params
    (norm scales / biases), matching standard LLM practice."""
    grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip)
    count = state.count + 1
    lr = schedule(cfg, count)
    b1, b2 = cfg.beta1, cfg.beta2
    c1 = 1.0 - b1 ** count.astype(jnp.float32)
    c2 = 1.0 - b2 ** count.astype(jnp.float32)

    def upd(g, m, v, p):
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * jnp.square(g)
        mh = m / c1
        vh = v / c2
        step = mh / (jnp.sqrt(vh) + cfg.eps)
        pf = p.astype(jnp.float32)
        if p.ndim >= 2:
            step = step + cfg.weight_decay * pf
        return (pf - lr * step).astype(p.dtype), m, v

    flat_g, treedef = jax.tree.flatten(grads)
    flat_m = jax.tree.leaves(state.mu)
    flat_v = jax.tree.leaves(state.nu)
    flat_p = jax.tree.leaves(params)
    new_p, new_m, new_v = [], [], []
    for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p):
        a, b, c = upd(g, m, v, p)
        new_p.append(a)
        new_m.append(b)
        new_v.append(c)
    mk = lambda xs: jax.tree.unflatten(treedef, xs)
    return mk(new_p), AdamState(mk(new_m), mk(new_v), count), \
        {"grad_norm": gnorm, "lr": lr}
