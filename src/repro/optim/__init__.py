from repro.optim import adamw
from repro.optim.adamw import AdamState

__all__ = ["adamw", "AdamState"]
