from repro.serving.engine import (DecodeEngine, Request, Result,
                                  make_engine_group)
from repro.serving.event_loop import (EventLoop, EventLoopGroup,
                                      LoopFailure, Poller, PollStats,
                                      channel_affinity)
from repro.serving.supervisor import (HealAction, Outcome, RetryBudget,
                                      Supervisor, SupervisorConfig)
from repro.serving import chaos, slo

__all__ = ["DecodeEngine", "Request", "Result", "make_engine_group",
           "EventLoop", "EventLoopGroup", "LoopFailure", "Poller",
           "PollStats", "channel_affinity", "HealAction", "Outcome",
           "RetryBudget", "Supervisor", "SupervisorConfig", "chaos",
           "slo"]
