from repro.configs.base import TenantConfig
from repro.serving.engine import (DecodeEngine, Request, Result,
                                  make_engine_group)
from repro.serving.event_loop import (EventLoop, EventLoopGroup,
                                      LoopFailure, Poller, PollStats,
                                      channel_affinity)
from repro.serving.supervisor import (HealAction, Outcome, RetryBudget,
                                      Supervisor, SupervisorConfig)
from repro.serving import cache_layout, chaos, slo

__all__ = ["DecodeEngine", "Request", "Result", "make_engine_group",
           "EventLoop", "EventLoopGroup", "LoopFailure", "Poller",
           "PollStats", "channel_affinity", "HealAction", "Outcome",
           "RetryBudget", "Supervisor", "SupervisorConfig", "TenantConfig",
           "cache_layout", "chaos", "slo"]
