from repro.serving.engine import (DecodeEngine, Request, Result,
                                  make_engine_group)
from repro.serving.event_loop import (EventLoop, EventLoopGroup, Poller,
                                      PollStats, channel_affinity)
from repro.serving import chaos, slo

__all__ = ["DecodeEngine", "Request", "Result", "make_engine_group",
           "EventLoop", "EventLoopGroup", "Poller", "PollStats",
           "channel_affinity", "chaos", "slo"]
