"""Deterministic chaos harness for the serving plane.

Ibdxnet (arXiv:1812.01963) catalogues how highly concurrent event-loop
transports fail: stalled completion polling, starved send threads,
backpressured buffers. The JIB benchmark paper (arXiv:1910.02245) adds
the methodological requirement — acceleration layers must be evaluated
under identical, reproducible conditions. This module applies both to
the serving stack: every fault is drawn from a seeded
``numpy.random.Generator`` into a static :class:`ChaosPlan`, so the same
seed always yields the same injection trace, and every scenario asserts
RECOVERY (served tokens bit-identical to the fault-free run —
``serving/slo.py``) instead of wall-clock flakiness.

Scenarios and the seams they hook (all seams are product code, not test
shims — the table lives in docs/SERVING.md §Chaos + SLO):

* ``slow_channel`` — seeded delays on the completion waits of the loop
  owning the target channel (``Poller.fault``): a connection whose
  completions arrive late.
* ``stalled_loop`` — a poller forced to over-park (``Poller.fault``
  returning ``"stall"``; counted in ``PollStats.stalls``): hadroNIO's
  park/epoll fallback taken spuriously.
* ``dropped_flush`` — a faulty ``flush_ready`` in the staged emission
  API (``pipeline.set_flush_fault``): ready channels dropped (recovered
  by the ``finish_emission`` step barrier) or duplicated (idempotent).
* ``admission_storm`` — seeded bursts of extra requests injected at the
  engine's flush boundary (``DecodeEngine.admission_hook``), contending
  for freed slots with the real clients.
* ``reshard_mid_request`` — the fleet resized at a flush boundary via
  ``launch/elastic.reshard_event_loops`` / ``reshard_affinity``: queued
  requests migrate to a group with a different loop count and affinity.
* ``mem_pressure`` — seeded host-memory pressure on the staged
  emission's wire-buffer allocations (``pipeline.set_alloc_hook``): gc
  thrash slows the coalesced-buffer build. Under the SUPERVISED runner
  the first event escalates to a pool-exhaustion raise the supervisor
  must heal with its retry budget.

Because faults either act at trace time (flush structure, allocations),
on host-side waits (delays/stalls), or through the ordinary admission
path (storms, reshard), NONE of them can change a served logit — that
is the point. The harness proves the stack absorbs them: drops re-flush
at the barrier, duplicates are idempotent, storms ride per-row
exactness, resizes ride the affinity-invariance of the conformance
contract.

:func:`run_supervised` runs the same plans under the
:class:`~repro.serving.supervisor.Supervisor` — the acceptance bar is
recovery WITHOUT the harness doing any healing itself, evidenced by the
supervisor's own seed-deterministic healing trace.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Dict, Optional, Sequence

import numpy as np

from repro.configs.base import CommConfig, ModelConfig, ServeConfig
from repro.core import channels as channels_mod
from repro.core.backends import pipeline
from repro.launch.elastic import reshard_affinity, reshard_event_loops
from repro.serving import slo
from repro.obs.metrics import RingLog
from repro.serving.engine import Request, make_engine_group
from repro.serving.event_loop import EventLoopGroup

SCENARIOS = ("slow_channel", "stalled_loop", "dropped_flush",
             "admission_storm", "reshard_mid_request", "mem_pressure")

STORM_UID_BASE = 1_000_000   # injected storm traffic lives above this uid


class ChaosMemPressure(MemoryError):
    """Escalated mem_pressure event: the wire-buffer pool is exhausted.
    Raised from the allocation seam at TRACE time so the drain fails —
    the supervisor's retry budget must re-trace past the consumed
    event."""


class ChaosFlushError(RuntimeError):
    """Injected one-shot drain failure (supervised dropped_flush): the
    transient send-thread crash the retry/backoff budget heals."""


# ---------------------------------------------------------------------------
# The seeded plan
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class Injection:
    """One planned fault. ``step`` is scenario-local: a completion-wait
    index (slow_channel / stalled_loop), a flush_ready consult index
    (dropped_flush), a flush-boundary step (admission_storm), the
    request split point (reshard_mid_request), or a wire-buffer
    allocation consult index (mem_pressure)."""
    step: int
    target: int        # channel id / loop id / burst size / new loop count
    kind: str          # delay | stall | drop | dup | burst | resize | pressure
    magnitude: float   # seconds (delay/stall/pressure), req count (burst)


@dataclass(frozen=True)
class ChaosPlan:
    scenario: str
    seed: int
    events: tuple

    def trace(self) -> tuple:
        """The canonical injection trace — what deterministic replay
        compares: same seed ⇒ equal traces, element for element."""
        return tuple((e.step, e.target, e.kind, round(e.magnitude, 9))
                     for e in self.events)


def make_plan(scenario: str, seed: int, *, n_channels: int = 4,
              n_loops: int = 1, n_requests: int = 4, horizon: int = 16,
              n_events: int = 4, delay_s: tuple = (0.5e-3, 2e-3),
              stall_s: float = 1e-3, max_burst: int = 2,
              loop_choices: tuple = (1, 2, 4)) -> ChaosPlan:
    """Derive the full injection trace from ONE ``numpy`` Generator —
    every sample below is a deterministic function of ``seed``, so the
    plan (and therefore the runtime trace it drives) replays exactly.
    Each scenario pins one guaranteed-early event so at least one fault
    always lands inside the run's horizon."""
    assert scenario in SCENARIOS, scenario
    rng = np.random.default_rng(seed)

    def steps(first: int) -> list:
        pool = np.arange(first + 1, max(first + 2, horizon))
        k = min(max(0, n_events - 1), pool.size)
        picked = rng.choice(pool, size=k, replace=False)
        return [first] + sorted(int(s) for s in picked)

    events: list = []
    if scenario == "slow_channel":
        target = int(rng.integers(n_channels))
        for s in steps(0):
            events.append(Injection(s, target, "delay",
                                    float(rng.uniform(*delay_s))))
    elif scenario == "stalled_loop":
        target = int(rng.integers(n_loops))
        for s in steps(0):
            events.append(Injection(s, target, "stall",
                                    float(stall_s * rng.uniform(0.5, 1.5))))
    elif scenario == "dropped_flush":
        kinds = ("drop", "dup")
        for s in steps(0):
            events.append(Injection(s, -1, kinds[int(rng.integers(2))],
                                    0.0))
    elif scenario == "admission_storm":
        # boundary steps start at 1 (the first polled flush boundary)
        for s in steps(1):
            events.append(Injection(s, int(rng.integers(1, max_burst + 1)),
                                    "burst", 0.0))
    elif scenario == "reshard_mid_request":
        valid = [l for l in loop_choices if 1 <= l <= n_channels]
        other = [l for l in valid if l != n_loops] or valid
        new_loops = int(other[int(rng.integers(len(other)))])
        split = int(rng.integers(1, max(2, n_requests)))
        events.append(Injection(split, new_loops, "resize", 0.0))
    else:   # mem_pressure: gc-thrash pauses on wire-buffer allocations
        for s in steps(0):
            events.append(Injection(s, -1, "pressure",
                                    float(rng.uniform(*delay_s))))
    return ChaosPlan(scenario=scenario, seed=seed, events=tuple(events))


# ---------------------------------------------------------------------------
# Runtime injection
# ---------------------------------------------------------------------------


class _Injector:
    """Arms one plan against a live engine group and records what
    actually fired — the runtime half of the replay evidence (inline
    drains make the fire order deterministic)."""

    def __init__(self, plan: ChaosPlan, vocab_size: int, max_new: int = 1,
                 evidence_capacity: int = 65536):
        self.plan = plan
        self.vocab_size = vocab_size
        self.max_new = max_new
        self.by_step = {e.step: e for e in plan.events}
        # bounded evidence rings (long supervised soaks must not grow
        # memory; evictions count in .dropped) — drains stays a plain
        # list: its length is the round-count invariant the harness
        # asserts exactly
        self.fired = RingLog(evidence_capacity)
        self.drains: list = []
        self.emissions = RingLog(evidence_capacity)
        self._wait_counts: dict = {}
        self._flush_calls = 0
        self._storm_uids = 0
        self._alloc_calls = 0
        self._crashed = False

    # -- Poller.fault (slow_channel / stalled_loop) ---------------------

    def poller_fault(self, loop_index: int):
        def fault(poller):
            c = self._wait_counts.get(id(poller), 0)
            self._wait_counts[id(poller)] = c + 1
            e = self.by_step.get(c)
            if e is None:
                return None
            time.sleep(e.magnitude)
            self.fired.append((c, loop_index, e.kind))
            # the verdict feeds PollStats (stalls / delays) — the health
            # counters the supervisor's EWMA detection reads
            return e.kind
        return fault

    # -- pipeline flush fault (dropped_flush) ----------------------------

    def flush_fault(self, channel: int) -> Optional[str]:
        c = self._flush_calls
        self._flush_calls += 1
        e = self.by_step.get(c)
        if e is None:
            return None
        self.fired.append((c, channel, e.kind))
        return e.kind

    # -- pipeline alloc hook (mem_pressure) ------------------------------

    def alloc_fault(self, *, escalate: bool = False):
        """Buffer-pool hook (``pipeline.set_alloc_hook``): consults the
        plan by allocation index. ``pressure`` events sleep — gc thrash
        slowing the coalesced wire-buffer build. With ``escalate=True``
        (supervised runs only) the FIRST planned event raises
        :class:`ChaosMemPressure` instead: pool exhaustion the
        supervisor heals by retrying the drain — the retry's fresh trace
        consults PAST the consumed event and completes."""
        state = {"oom": escalate}

        def hook(channel: int, nbytes: int) -> None:
            c = self._alloc_calls
            self._alloc_calls += 1
            e = self.by_step.get(c)
            if e is None:
                return
            if state["oom"]:
                state["oom"] = False
                self.fired.append((c, channel, "oom"))
                raise ChaosMemPressure(
                    f"wire-buffer pool exhausted at alloc {c} "
                    f"(channel {channel}, {nbytes} B)")
            time.sleep(e.magnitude)
            self.fired.append((c, channel, e.kind))
        return hook

    # -- one-shot drain crash (supervised dropped_flush) -----------------

    def drain_crash_hook(self):
        """Drain hook that raises ONCE on its armed loop's first drain
        (after recording the drain, like the plain observer). One-shot
        across group rebuilds — injector state, not loop state — so the
        supervisor's retry succeeds instead of looping forever."""
        def hook(loop, items) -> None:
            self.drains.append((loop.index, len(items)))
            if not self._crashed:
                self._crashed = True
                self.fired.append((0, loop.index, "flush_crash"))
                raise ChaosFlushError(
                    f"injected send-thread failure on loop {loop.index}")
        return hook

    # -- engine admission hook (admission_storm) -------------------------

    def admission_storm(self, engine, step: int) -> list:
        e = self.by_step.get(step)
        if e is None or e.kind != "burst":
            return []
        burst = []
        for k in range(int(e.target)):
            rng = np.random.default_rng(
                self.plan.seed * 100_003 + step * 31 + k)
            plen = int(rng.integers(2, 6))
            prompt = rng.integers(0, self.vocab_size, size=plen)
            uid = STORM_UID_BASE + self._storm_uids
            self._storm_uids += 1
            burst.append(Request(uid=uid, prompt=prompt.astype(np.int32),
                                 max_new=self.max_new))
        self.fired.append((step, len(burst), "burst"))
        return burst

    # -- observers --------------------------------------------------------

    def drain_hook(self, loop, items) -> None:
        self.drains.append((loop.index, len(items)))

    def collective_hook(self, channel: int, kind: str) -> None:
        self.emissions.append((channel, kind))


# ---------------------------------------------------------------------------
# Scenario runners
# ---------------------------------------------------------------------------


def chaos_serve_config(mode: str, event_loops: int, *, channels: int = 4,
                       poll: str = "busy", max_batch: int = 2,
                       max_len: int = 48,
                       slice_bytes: int = 128) -> ServeConfig:
    """The harness's canonical serve shape: channel-granularity flushes
    on the ready schedule (so ``flush_ready`` is live — the seam the
    dropped-flush scenario needs) over a ``channels``-lane pool."""
    return ServeConfig(
        event_loops=event_loops, poll=poll, max_batch=max_batch,
        max_len=max_len,
        comm=CommConfig(mode=mode, channels=channels,
                        slice_bytes=slice_bytes, aggregate="channel",
                        flush="ready", hierarchical=False))


def make_requests(n: int, *, vocab_size: int, seed: int = 1234,
                  max_new: tuple = (3, 5),
                  prompt_len: tuple = (3, 8)) -> list:
    """Deterministic greedy client traffic (temperature 0 — bit-identity
    is the recovery invariant, and sampling would tie tokens to the loop
    assignment's PRNG streams)."""
    rng = np.random.default_rng(seed)
    reqs = []
    for uid in range(n):
        plen = int(rng.integers(prompt_len[0], prompt_len[1]))
        reqs.append(Request(
            uid=uid,
            prompt=rng.integers(0, vocab_size, size=plen).astype(np.int32),
            max_new=int(rng.integers(max_new[0], max_new[1] + 1))))
    return reqs


@dataclass
class Baseline:
    """The fault-free reference: served tokens per uid (the recovery
    target) and, optionally, the per-drain RTT samples the inflation
    bound divides by. ``rtts=[]`` means token-only (tier-1 shares one
    token reference across the whole matrix — the conformance contract
    makes tokens invariant to mode/affinity/loop count)."""
    tokens: Dict[int, tuple]
    rtts: list = field(default_factory=list)


@dataclass
class ChaosResult:
    plan: ChaosPlan
    fired: tuple          # runtime injection trace (replay evidence)
    drains: tuple         # (loop, batch) drain trace (drain_hook seam)
    report: slo.SLOReport
    tokens: Dict[int, tuple]
    rtts: list
    moved_channels: tuple = ()   # reshard only: migrated channel ids
    poll_stats: object = None    # merged PollStats (stalls evidence)
    emissions: tuple = ()        # (channel, kind) trace-time collective
    #                              trace — non-empty only when this run
    #                              traced fresh programs (dropped_flush
    #                              always does; cached runs skip tracing)


def _wrap_timing(grp: EventLoopGroup, rtts: list) -> None:
    """Per-request RTT recording: each request is charged its drain
    batch's wall-clock (the engine serves a drain as one continuous
    batch — the batch IS the request's residency window)."""
    for loop in grp.loops:
        orig = loop.runner

        def timed(l, items, _orig=orig):
            t0 = time.perf_counter()
            out = _orig(l, items)
            dt = time.perf_counter() - t0
            rtts.extend(dt for r in out
                        if getattr(r, "uid", 0) < STORM_UID_BASE)
            return out
        loop.runner = timed


def _tokens_of(results: list) -> Dict[int, tuple]:
    return {r.uid: tuple(int(t) for t in r.tokens) for r in results
            if r.uid < STORM_UID_BASE}


def run_baseline(cfg: ModelConfig, params, serve: ServeConfig,
                 reqs: Sequence[Request], *, mesh=None,
                 threads: bool = False) -> Baseline:
    """The fault-free run: the token reference and the RTT baseline."""
    grp = make_engine_group(cfg, params, serve, mesh=mesh)
    rtts: list = []
    _wrap_timing(grp, rtts)
    grp.submit(list(reqs))
    res = grp.run(threads=threads)
    return Baseline(tokens=_tokens_of(res), rtts=rtts)


def run_scenario(scenario: str, cfg: ModelConfig, params,
                 serve: ServeConfig, reqs: Sequence[Request], *,
                 seed: int, baseline: Baseline, mesh=None,
                 threads: bool = False, horizon: int = 16) -> ChaosResult:
    """Run ONE seeded fault scenario against a fresh engine group and
    report recovery. The plan is fully derived before anything runs;
    inline drains (``threads=False``, the default) keep the runtime
    trace deterministic so same-seed runs replay exactly."""
    plan = make_plan(scenario, seed, n_channels=serve.comm.channels,
                     n_loops=serve.event_loops, n_requests=len(reqs),
                     horizon=horizon)
    inj = _Injector(plan, cfg.vocab_size)
    rtts: list = []
    channels_mod.set_collective_hook(inj.collective_hook)
    try:
        if scenario == "dropped_flush":
            # armed BEFORE the group builds: the faults act at trace
            # time, and the armed window bypasses the serve-step cache
            pipeline.set_flush_fault(inj.flush_fault)
        elif scenario == "mem_pressure":
            # same trace-time window, on the allocation seam
            pipeline.set_alloc_hook(inj.alloc_fault())
        try:
            if scenario == "reshard_mid_request":
                res, moved, poll = _run_reshard(plan, cfg, params, serve,
                                                reqs, inj, rtts, mesh,
                                                threads)
            else:
                grp = make_engine_group(cfg, params, serve, mesh=mesh)
                _wrap_timing(grp, rtts)
                for loop in grp.loops:
                    loop.drain_hook = inj.drain_hook
                moved = ()
                _arm(scenario, grp, serve, inj)
                grp.submit(list(reqs))
                res = grp.run(threads=threads)
                poll = grp.poll_stats()
        finally:
            if scenario == "dropped_flush":
                pipeline.clear_flush_fault()
            elif scenario == "mem_pressure":
                pipeline.clear_alloc_hook()
    finally:
        channels_mod.clear_collective_hook()

    tokens = _tokens_of(res)
    report = slo.make_report(
        scenario=scenario, seed=seed, mode=serve.comm.mode,
        event_loops=serve.event_loops, reference=baseline.tokens,
        served=tokens, fault_rtts=rtts, baseline_rtts=baseline.rtts,
        n_injected=len(inj.fired))
    return ChaosResult(plan=plan, fired=tuple(inj.fired),
                       drains=tuple(inj.drains), report=report,
                       tokens=tokens, rtts=rtts, moved_channels=moved,
                       poll_stats=poll, emissions=tuple(inj.emissions))


def _arm(scenario: str, grp: EventLoopGroup, serve: ServeConfig,
         inj: _Injector) -> None:
    plan = inj.plan
    if scenario == "slow_channel":
        target = plan.events[0].target
        owner = next(l for l in grp.loops if target in l.channels)
        owner.poller.fault = inj.poller_fault(owner.index)
    elif scenario == "stalled_loop":
        target = plan.events[0].target % grp.n_loops
        grp.loops[target].poller.fault = inj.poller_fault(target)
    elif scenario == "admission_storm":
        for loop in grp.loops:
            loop.engine.admission_hook = inj.admission_storm
    # dropped_flush / mem_pressure are armed globally before the group
    # builds (trace-time seams); reshard is driven by the runner itself


def _run_reshard(plan: ChaosPlan, cfg, params, serve, reqs, inj, rtts,
                 mesh, threads):
    """Serve the head of the queue on the original fleet, resize at the
    wave boundary (in-flight requests drain, queued ones migrate), serve
    the tail on the rebuilt group. The union of results must equal the
    fault-free reference bit-for-bit — affinity and loop count move
    emission structure, never tokens."""
    e = plan.events[0]
    split = max(1, min(len(reqs) - 1, e.step)) if len(reqs) > 1 else 0
    new_loops = int(e.target)

    grp = make_engine_group(cfg, params, serve, mesh=mesh)
    _wrap_timing(grp, rtts)
    for loop in grp.loops:
        loop.drain_hook = inj.drain_hook
    grp.submit(list(reqs[:split]))
    head = grp.run(threads=threads) if split else []

    serve2 = reshard_event_loops(serve, new_loops)
    old_aff = tuple(l.channels for l in grp.loops)
    new_aff, moved = reshard_affinity(serve.comm.channels, old_aff,
                                      new_loops)
    inj.fired.append((split, new_loops, "resize"))

    # the minimal-migration partition is NOT the from-scratch recompute,
    # so the rebuilt group must be pinned to the resharded affinity
    grp2 = make_engine_group(cfg, params, serve2, mesh=mesh,
                             affinity=new_aff)
    assert tuple(l.channels for l in grp2.loops) == new_aff
    _wrap_timing(grp2, rtts)
    for loop in grp2.loops:
        loop.drain_hook = inj.drain_hook
    grp2.submit(list(reqs[split:]))
    tail = grp2.run(threads=threads)
    poll = grp.poll_stats().merge(grp2.poll_stats())
    return list(head) + list(tail), moved, poll


# ---------------------------------------------------------------------------
# Supervised runs — the same plans, healed by the Supervisor itself
# ---------------------------------------------------------------------------


@dataclass
class SupervisedResult:
    """One scenario run under the self-healing supervisor. ``trace`` is
    the supervisor's CANONICAL healing trace (round, kind, target,
    detail — wall-clock stamps excluded), the seed-deterministic
    evidence that the supervisor, not the harness, did the healing."""
    plan: ChaosPlan
    fired: tuple
    drains: tuple
    trace: tuple
    outcomes: Dict[int, object]
    report: slo.SLOReport
    tokens: Dict[int, tuple]
    rtts: list
    poll_stats: object = None
    emissions: tuple = ()


def run_supervised(scenario: str, cfg: ModelConfig, params,
                   serve: ServeConfig, reqs: Sequence[Request], *,
                   seed: int, baseline: Baseline, mesh=None,
                   threads: bool = False, horizon: int = 16,
                   config=None) -> SupervisedResult:
    """Run one seeded scenario with the :class:`Supervisor` closing the
    detect → decide → heal loop itself. The harness only ARMS faults
    (through the supervisor's ``fleet_hook``, so rebuilds re-arm
    observation seams) and then submits the client requests — every
    quarantine, restart, retry, reflush, backpressure and resize in the
    result's ``trace`` was the supervisor's own decision. Two scenarios
    escalate beyond their unsupervised form so there is a real failure
    to heal: ``dropped_flush`` adds a one-shot drain crash (retry
    budget), ``mem_pressure`` escalates its first event to a pool-
    exhaustion raise (retry re-traces past it)."""
    from repro.serving.supervisor import Supervisor, SupervisorConfig
    plan = make_plan(scenario, seed, n_channels=serve.comm.channels,
                     n_loops=serve.event_loops, n_requests=len(reqs),
                     horizon=horizon)
    inj = _Injector(plan, cfg.vocab_size)
    rtts: list = []
    if config is None:
        # >= 2 dispatch rounds so detection/healing happens MID-stream
        config = SupervisorConfig(
            dispatch_quantum=max(1, (len(reqs) + 1) // 2))
    sup = Supervisor(cfg, params, serve, mesh=mesh, config=config,
                     seed=seed)

    def fleet_hook(grp):
        _wrap_timing(grp, rtts)
        for loop in grp.loops:
            loop.drain_hook = inj.drain_hook
        _arm(scenario, grp, serve, inj)
        if scenario == "dropped_flush":
            grp.loops[0].drain_hook = inj.drain_crash_hook()

    sup.fleet_hook = fleet_hook
    channels_mod.set_collective_hook(inj.collective_hook)
    try:
        if scenario == "dropped_flush":
            pipeline.set_flush_fault(inj.flush_fault)
        elif scenario == "mem_pressure":
            pipeline.set_alloc_hook(inj.alloc_fault(escalate=True))
        try:
            if scenario == "reshard_mid_request":
                e = plan.events[0]
                sup.request_resize(int(e.target))
                inj.fired.append((1, int(e.target), "resize"))
            sup.submit(list(reqs))
            res = sup.run(threads=threads)
        finally:
            if scenario == "dropped_flush":
                pipeline.clear_flush_fault()
            elif scenario == "mem_pressure":
                pipeline.clear_alloc_hook()
    finally:
        channels_mod.clear_collective_hook()

    tokens = _tokens_of(res)
    report = slo.make_report(
        scenario=scenario, seed=seed, mode=serve.comm.mode,
        event_loops=serve.event_loops, reference=baseline.tokens,
        served=tokens, fault_rtts=rtts, baseline_rtts=baseline.rtts,
        n_injected=len(inj.fired), healing_actions=len(sup.trace),
        mttr_s=sup.mttr_s())
    return SupervisedResult(plan=plan, fired=tuple(inj.fired),
                            drains=tuple(inj.drains),
                            trace=sup.healing_trace(),
                            outcomes=dict(sup.outcomes), report=report,
                            tokens=tokens, rtts=rtts,
                            poll_stats=sup.poll_stats(),
                            emissions=tuple(inj.emissions))
