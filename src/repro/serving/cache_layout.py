"""Per-family decode-state layouts — the declarative seam that makes
every registered config family a first-class citizen of the serving
gathering write.

The paper's transparency claim is that the transport never special-cases
the application: hadroNIO slots under the NIO contract and every netty
app rides InfiniBand unchanged (§II). The serving analogue is the
prefill gathering write in ``serving/dispatch.py``: each ring peer
prefills its contiguous run of the request batch, every decode-state
leaf plus the last-token logits coalesce into ONE flat wire payload, and
the gathered result is carved back per leaf with the batch dimension
re-merged peer-major. The ONLY family-specific fact in that pipeline is
*where each cache leaf carries its batch axis* — so that fact lives
here, declaratively, instead of as special cases in the dispatch layer
(arXiv:2001.04206 makes the same argument for keeping model layout
decisions declarative so the comm layer stays generic).

A family's layout is a resolver ``(path, leaf) -> batch_axis`` mapped
over the cache pytree (``path`` is the tuple of dict keys from the root,
``leaf`` the array or ShapeDtypeStruct). Registered layouts:

============  ==============================================  =========
family        cache leaves                                    batch axis
============  ==============================================  =========
dense         KV pages ``{"k","v"}: (L, B, S, KV, Dh)``       1
moe           same KV pages as dense (expert state is         1
              per-token, nothing persists across steps)
ssm           rwkv6 recurrent state ``wkv (L, B, h, hs, hs)``  1
              / ``tm_x`` / ``cm_x (L, B, 1, d)``
hybrid        MIXED — ``groups`` subtree (stacked rglru /     1
              local-attn entries, ``(n_groups, B, ...)``)
              vs the unstacked ``tail*`` entries whose        0
              leaves lead with the batch dim ``(B, ...)``
encdec        whisper ``self`` KV ``(L, B, S, KV, Dh)`` plus  1
              ``cross_k`` / ``cross_v (L, B, frames, ...)``
vlm           llava KV pages with the vision prefix folded    1
              into S — same page shape as dense
============  ==============================================  =========

The last-token logits ``(B, V)`` always merge at axis 0; that is the
dispatch layer's own output contract, not a family fact, so it is not
part of the map. ``docs/FAMILIES.md`` documents the contract a new
family must implement; ``tests/test_backend_conformance.py`` fails
collection when a registered family has no layout (the same
missing-coverage pattern as unregistered comm modes).
"""
from __future__ import annotations

from typing import Any, Callable, Tuple

import jax

# resolver: (path_keys, leaf) -> batch axis of that cache leaf
LayoutFn = Callable[[Tuple[str, ...], Any], int]


def _stacked_axis1(path: Tuple[str, ...], leaf: Any) -> int:
    """Layer-stacked state: every leaf leads with the layer (or frame)
    dim and carries batch at axis 1 — KV pages (L, B, S, KV, Dh),
    rwkv6 recurrent state (L, B, ...), whisper cross caches."""
    return 1


def _hybrid_mixed(path: Tuple[str, ...], leaf: Any) -> int:
    """recurrentgemma's cache is the mixed case: the ``groups`` subtree
    stacks each block-pattern entry over the repeated groups
    ((n_groups, B, ...) — batch at axis 1) while the unstacked ``tail*``
    entries keep their per-layer shapes ((B, lw) rglru hidden,
    (B, conv1d_width-1, lw) conv state, (B, window, KV, Dh) local-attn
    pages — batch at axis 0)."""
    return 1 if "groups" in path else 0


CACHE_LAYOUTS: dict[str, LayoutFn] = {
    "dense": _stacked_axis1,
    "moe": _stacked_axis1,
    "ssm": _stacked_axis1,
    "hybrid": _hybrid_mixed,
    "encdec": _stacked_axis1,
    "vlm": _stacked_axis1,
}


def _path_keys(path) -> Tuple[str, ...]:
    keys = []
    for k in path:
        if hasattr(k, "key"):
            keys.append(str(k.key))
        elif hasattr(k, "idx"):
            keys.append(str(k.idx))
        else:
            keys.append(str(k))
    return tuple(keys)


def layout_for(family: str) -> LayoutFn:
    """The family's layout resolver — the error names the missing layout
    and where to declare it, so a NEW family fails loudly at step-build
    time instead of silently mis-merging its cache."""
    try:
        return CACHE_LAYOUTS[family]
    except KeyError:
        raise ValueError(
            f"family {family!r} declares no cache layout: sharded prefill "
            "re-merges every decode-state leaf after the gathering write "
            "and needs each leaf's batch axis — register a resolver in "
            "repro.serving.cache_layout.CACHE_LAYOUTS (see "
            "docs/FAMILIES.md, §The cache-layout contract)") from None


def batch_axes(family: str, cache: Any) -> list:
    """Per-leaf batch axes of ``cache``, in ``jax.tree.flatten`` leaf
    order (what the dispatch merge loop consumes). Works on arrays and
    on ShapeDtypeStruct spec trees alike."""
    fn = layout_for(family)
    flat = jax.tree_util.tree_flatten_with_path(cache)[0]
    axes = []
    for path, leaf in flat:
        ba = fn(_path_keys(path), leaf)
        assert 0 <= ba < max(1, leaf.ndim), \
            (family, _path_keys(path), ba, getattr(leaf, "shape", None))
        axes.append(ba)
    return axes
