"""Serve-step dispatch: inference collectives through the CommBackend wire.

The training path's transparency boundary (callers never branch on mode
names; the registered backend owns the wire) applied to serving. A
:class:`ServeStep` is a pair of jitted functions with the engine's exact
call signatures — ``prefill(params, batch)`` / ``decode(params, cache,
dec)`` — that run inside a fully-manual ``shard_map`` over the mesh and
emit their collectives via ``CommBackend.serve_emit``:

* **prefill** — batch-sharded for EVERY registered family: each ring
  peer prefills its contiguous run of the request batch locally, then
  every decode-state leaf plus the last-token logits are coalesced into
  ONE flat wire payload and all-gathered — the serving gathering write
  (paper §III-C applied to inference: many small cache buffers become
  one large request), carved back per leaf with the batch dimension
  re-merged peer-major. WHERE each leaf carries its batch axis is the
  family's declared cache layout (``serving/cache_layout.py``) — the
  one family-specific fact, kept declarative so this layer stays
  generic (docs/FAMILIES.md).
* **decode** — tensor-parallel LM head: every peer runs the (replicated)
  trunk, computes partial logits from its contiguous ``d_model`` shard,
  and the partial-logit sum is all-reduced — the serving logit
  reduction. The reduction flows through the SAME staged emission API
  the gradient path uses (``pipeline.begin_emission`` / ``stage_slices``
  / ``flush_ready`` via ``pipeline.emit_flat``), so ``comm.mode`` /
  ``channels`` / ``slice_bytes`` / ``aggregate`` / ``flush`` all shape
  serving traffic, and an event loop's channel affinity
  (``ctx.channel_indices``) bounds which connections it may emit on.
* **MoE expert parallelism** — when the ring divides the expert count,
  the expert-compute stage runs expert-parallel: the dispatched
  ``(B, E, C, D)`` buffer rides an ``all_to_all`` exchange through the
  same staged emission API (each peer receives every batch row's slice
  of the expert axis, runs its local expert slice, and the reverse
  exchange brings the outputs home). Pure data movement + identical
  per-expert einsums, so tokens stay bit-identical to the local expert
  stage; a non-dividing expert count falls back to local compute.

All registered modes return bit-identical logits (per-element sums and
peer-major gathers commute with slicing — conformance-tested in
``tests/test_backend_conformance.py``); only the emitted program
structure differs. Serving payloads are activations: wire compression is
an error-feedback (training-state) feature and is rejected here.
"""
from __future__ import annotations

from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro import compat
from repro.configs.base import CommConfig, ModelConfig
from repro.core.backends import get_backend
from repro.core.backends.base import SyncContext
from repro.launch.mesh import make_mesh
from repro.models import api
from repro.obs import trace as obs_trace
from repro.models import moe as moe_mod
from repro.models.layers import no_shard
from repro.serving import cache_layout

PyTree = Any


class ServeStep(NamedTuple):
    """Jitted serve entry points (engine-compatible signatures) plus the
    resolved topology facts the engine needs for batch padding."""
    prefill: Callable             # (params, batch) -> (logits, cache)
    decode: Callable              # (params, cache, dec) -> (logits, cache)
    n_shards: int                 # ring size: batch rows padded to a multiple
    mesh: Any
    comm: CommConfig
    channel_indices: Optional[tuple]
    pod_axis: Optional[str] = None   # resolved pod axis (None = flat ring:
    #                               no pod dim in the mesh, or hierarchical
    #                               collectives disabled in the config)
    n_pods: int = 1


def validate_serve_comm(comm: CommConfig):
    """Serving-path config validation; returns the backend."""
    backend = get_backend(comm.mode)
    if comm.compress != "none":
        raise ValueError(
            f"serving cannot honor compress={comm.compress!r}: the wire "
            "carries activations (logit partial sums, KV gathers), not "
            "gradients — there is no error-feedback state to make a lossy "
            "codec unbiased; use compress='none'")
    return backend


_STEP_CACHE: dict = {}


def clear_serve_step_cache() -> None:
    """Drop every memoized ServeStep (tests that need fresh traces)."""
    _STEP_CACHE.clear()


def make_serve_step(cfg: ModelConfig, comm: CommConfig, mesh=None, *,
                    channel_indices: Optional[tuple] = None,
                    pod_axis: Optional[str] = None) -> ServeStep:
    if not obs_trace.enabled():
        return _make_serve_step(cfg, comm, mesh,
                                channel_indices=channel_indices,
                                pod_axis=pod_axis)
    with obs_trace.span("build", f"serve_step:{cfg.name}",
                        mode=comm.mode, channels=comm.channels):
        return _make_serve_step(cfg, comm, mesh,
                                channel_indices=channel_indices,
                                pod_axis=pod_axis)


def _make_serve_step(cfg: ModelConfig, comm: CommConfig, mesh=None, *,
                     channel_indices: Optional[tuple] = None,
                     pod_axis: Optional[str] = None) -> ServeStep:
    """Build the TAC serve step for one (model, comm, mesh, affinity)
    combination. ``channel_indices`` is the emitting event loop's owned
    run of the global channel pool (None = the full pool).

    Steps are MEMOIZED per (cfg, comm, mesh, affinity, pod_axis): the
    jitted functions close over nothing but the static topology (params
    and cache are call arguments), so every engine/group built for the
    same combination shares one compiled program instead of re-tracing
    it — the chaos matrix and repeated conformance builds pay one
    compile per affinity. The cache is bypassed (no lookup, no store)
    while any trace-affecting fault is armed — a flush fault
    (``pipeline.set_flush_fault``) or an allocator hook
    (``pipeline.set_alloc_hook``) — so a faulted emission trace can
    never leak into fault-free callers.

    ``pod_axis`` names the mesh's pod dimension for the two-level fabric
    (``launch/mesh.make_serve_mesh``); None auto-detects an axis named
    ``"pod"``. A detected pod axis flows into ``SyncContext.resolve``,
    so the decode all-reduce and the prefill gathering write decompose
    into in-pod stages plus the leader lanes' cross-pod collectives —
    gated, like the training path, on ``comm.hierarchical`` (a False
    config keeps the flat ring over the very same mesh)."""
    from repro.core.backends import pipeline
    backend = validate_serve_comm(comm)
    if mesh is None:
        mesh = make_mesh((jax.device_count(),), ("data",))
    cacheable = not pipeline.fault_active()
    key = (cfg, comm, mesh,
           tuple(channel_indices) if channel_indices is not None else None,
           pod_axis)
    if cacheable and key in _STEP_CACHE:
        return _STEP_CACHE[key]
    axes = tuple(mesh.axis_names)
    n_shards = int(np.prod([mesh.shape[a] for a in axes]))
    # every family is batch-shardable — its declared cache layout tells
    # the gathering write where each decode-state leaf carries batch; a
    # family with NO layout fails here, at build time, with an error
    # naming what to declare (serving/cache_layout.py)
    cache_layout.layout_for(cfg.family)
    chans = tuple(channel_indices) if channel_indices is not None else None
    pod = pod_axis if pod_axis is not None else \
        ("pod" if "pod" in axes else None)
    if pod is not None and pod not in axes:
        raise ValueError(f"pod_axis={pod!r} is not a mesh axis of {axes}")
    data = tuple(a for a in axes if a != pod) if pod is not None else axes
    if pod is not None and not data:
        raise ValueError(
            f"mesh {axes} has only the pod axis; the two-level fabric "
            "needs an in-pod data axis (make_serve_mesh builds one)")
    ctx = SyncContext.resolve(comm, data, pod, channel_indices=chans)
    # the pure-local reference path: nothing to wire (same gate for the
    # TP head, the gathering write and the expert exchange)
    pure_local = n_shards == 1 and not chans and comm.mode == "gspmd"

    # -- MoE expert-parallel dispatch/combine (the expert exchange) -----

    ep = cfg.moe.num_experts // n_shards if cfg.family == "moe" else 0
    use_ep = (cfg.family == "moe" and not pure_local
              and cfg.moe.num_experts % n_shards == 0)

    def ep_experts(mp, buf, _cfg, _shard_fn):
        """Expert-parallel expert stage: all_to_all the dispatched
        buffer peer-major (each peer gets EVERY batch row's slice of the
        expert axis), run the local ``ep``-expert slice, reverse the
        exchange. Data movement + identical per-expert einsums — tokens
        are bit-identical to the local expert stage."""
        b, e, cap, d = buf.shape
        dt = buf.dtype
        p_idx = jax.lax.axis_index(ctx.flat_axes)
        snd = buf.astype(jnp.float32).reshape(b, n_shards, ep, cap, d)
        snd = jnp.moveaxis(snd, 1, 0)                # (n, b, ep, c, d)
        got = backend.serve_emit(snd.reshape(-1), ctx, "all_to_all")
        got = got.reshape(n_shards * b, ep, cap, d)  # all rows, my slice
        wslice = {w: jax.lax.dynamic_slice_in_dim(
                      mp[w].astype(jnp.float32), p_idx * ep, ep, axis=0)
                  for w in ("wi", "wg", "wo")}
        out = moe_mod.apply_experts(wslice, got, cfg)
        back = backend.serve_emit(out.reshape(-1), ctx, "all_to_all")
        back = back.reshape(n_shards, b, ep, cap, d)
        back = jnp.moveaxis(back, 0, 1).reshape(b, e, cap, d)
        return back.astype(dt)

    expert_fn = ep_experts if use_ep else None

    # -- tensor-parallel LM head (the serving logit reduction) ----------

    def tp_head(embed: dict, x: jax.Array, shard_fn=no_shard) -> jax.Array:
        w = embed.get("out")
        if w is None:
            w = embed["tok"].T                       # tied: (d, V)
        d = x.shape[-1]
        ds = -(-d // n_shards)                       # ceil: zero-pad shards
        pad = ds * n_shards - d
        xp = jnp.pad(x, [(0, 0)] * (x.ndim - 1) + [(0, pad)]) if pad else x
        wp = jnp.pad(w, ((0, pad), (0, 0))) if pad else w
        p = jax.lax.axis_index(ctx.flat_axes)
        xs = jax.lax.dynamic_slice_in_dim(xp, p * ds, ds, axis=x.ndim - 1)
        ws = jax.lax.dynamic_slice_in_dim(wp, p * ds, ds, axis=0)
        partial = jnp.einsum("...d,dv->...v", xs, ws.astype(x.dtype))
        red = backend.serve_emit(
            partial.astype(jnp.float32).reshape(-1), ctx, "all_reduce")
        return red.reshape(partial.shape).astype(x.dtype)

    # -- batch-sharded prefill + coalesced KV gathering write -----------

    def prefill_body(params: PyTree, batch: dict):
        b = batch["tokens"].shape[0]
        assert b % n_shards == 0, \
            f"serve batch {b} not padded to the ring size {n_shards}"
        bs = b // n_shards
        p = jax.lax.axis_index(ctx.flat_axes)
        local = jax.tree.map(
            lambda t: jax.lax.dynamic_slice_in_dim(t, p * bs, bs, axis=0),
            batch)
        logits, cache = api.prefill(params, local, cfg, no_shard,
                                    expert_fn=expert_fn)
        if pure_local:
            return logits, cache       # pure local reference, nothing to wire

        # ONE gathering write for the whole prefill result: every cache
        # leaf + the last-token logits coalesced into a single flat f32
        # payload, gathered peer-major, carved back per leaf with the
        # batch axis re-merged (slot k of the full batch = peer k//bs,
        # local row k%bs — matching the engine's row padding).
        leaves, treedef = jax.tree.flatten((cache, logits))
        flats = [l.astype(jnp.float32).reshape(-1) for l in leaves]
        sizes = [f.shape[0] for f in flats]
        wire = flats[0] if len(flats) == 1 else jnp.concatenate(flats)
        g = backend.serve_emit(wire, ctx, "all_gather").reshape(n_shards, -1)

        outs, off = [], 0
        # flatten order: cache leaves then logits. Each cache leaf's
        # batch axis is the family's DECLARED layout (cache_layout.py);
        # the logits row always merges at axis 0 (this layer's own
        # output contract, not a family fact).
        bas = cache_layout.batch_axes(cfg.family, cache) + [0]
        assert len(bas) == len(leaves), (len(bas), len(leaves))
        for leaf, n, ba in zip(leaves, sizes, bas):
            seg = g[:, off:off + n].reshape((n_shards,) + leaf.shape)
            off += n
            m = jnp.moveaxis(seg, 0, ba)
            shape = leaf.shape
            merged = m.reshape(shape[:ba] + (n_shards * shape[ba],)
                               + shape[ba + 1:])
            outs.append(merged.astype(leaf.dtype))
        full_cache, full_logits = jax.tree.unflatten(treedef, outs)
        return full_logits, full_cache

    # -- replicated decode + TP logit reduction -------------------------

    def decode_body(params: PyTree, cache: PyTree, dec: dict):
        head = None if pure_local else tp_head
        return api.decode_step(params, cache, dec, cfg, no_shard,
                               logits_fn=head, expert_fn=expert_fn)

    prefill = jax.jit(compat.shard_map(
        prefill_body, mesh=mesh, in_specs=(P(), P()),
        out_specs=(P(), P()), check_vma=False))
    decode = jax.jit(compat.shard_map(
        decode_body, mesh=mesh, in_specs=(P(), P(), P()),
        out_specs=(P(), P()), check_vma=False))
    step = ServeStep(prefill=prefill, decode=decode, n_shards=n_shards,
                     mesh=mesh, comm=comm, channel_indices=chans,
                     pod_axis=ctx.pod_axis,
                     n_pods=mesh.shape[pod] if pod is not None else 1)
    if cacheable:
        _STEP_CACHE[key] = step
    return step


def lowered_decode_text(cfg: ModelConfig, comm: CommConfig, *,
                        batch: int = 2, max_len: int = 32, mesh=None,
                        channel_indices: Optional[tuple] = None,
                        pod_axis: Optional[str] = None) -> str:
    """Emitted StableHLO of one serve decode step (shape-only lowering) —
    the evidence surface for 'serving collectives flow through the staged
    emission API' (conformance tests + benchmark evidence rows count its
    collectives with ``launch/hlo_analysis``; the topology rows classify
    them as in-pod vs cross-pod with ``cross_pod_collective_count``)."""
    step = make_serve_step(cfg, comm, mesh, channel_indices=channel_indices,
                           pod_axis=pod_axis)
    params = api.abstract(cfg)
    cache = api.cache_specs(cfg, batch, max_len)
    dec = {"token": jax.ShapeDtypeStruct((batch,), jnp.int32),
           "pos": jax.ShapeDtypeStruct((batch,), jnp.int32)}
    return step.decode.lower(params, cache, dec).as_text()


def logit_payload_slices(cfg: ModelConfig, batch: int,
                         comm: CommConfig) -> int:
    """How many ring-buffer slices one decode logit reduction carves into
    (the expected per-step collective count under ``aggregate="slice"``)."""
    from repro.core.ring_buffer import plan_slices
    return plan_slices(batch * cfg.vocab_size * 4, comm).n_slices
