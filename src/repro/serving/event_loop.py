"""EventLoopGroup — the netty worker-group analogue (paper §IV).

The paper's microbenchmarks run an ``EventLoopGroup`` of worker threads:
each event loop OWNS a set of connections, polls their completions
(hadroNIO busy-polls the UCX worker instead of parking in epoll — the
single biggest latency lever, §IV-B), and drains a run queue of
in-flight requests. Ibdxnet (arXiv:1812.01963) shows the same design
scales concurrent Java/IB: dedicated per-thread connection ownership,
no shared mutable transport state between threads.

This module is that subsystem, transport-agnostic:

* :class:`Poller` — the completion-polling strategy (``busy`` spins on
  ``Array.is_ready``, ``park`` blocks — the epoll/selector fallback,
  ``adaptive`` spins for a bounded budget then parks), with counters so
  benchmarks can report how often each path was taken.
* :class:`EventLoop` — one loop: an index, the contiguous run of the
  global CommChannel pool it OWNS (the channel-affinity invariant: no
  two loops ever emit on the same channel), its own poller, and a run
  queue drained by a pluggable ``runner``.
* :class:`EventLoopGroup` — N loops; requests/connections are assigned
  round-robin (paper §IV-C assigns connections to selectors
  round-robin); ``run()`` drains every loop, one OS thread per loop
  when ``threads=True``.
* :func:`channel_affinity` — the bucket→channel grouping rule reused at
  the loop layer: ``selector.ready_groups``-style CONTIGUOUS runs of
  the channel pool, disjoint and covering, balanced to within one.

The engine glue (per-loop :class:`~repro.serving.engine.DecodeEngine`
with the loop's channel affinity baked into its serve step) lives in
``serving/engine.py`` (``make_engine_group``); the RTT microbenchmark
(``benchmarks/serving_rtt.py``) drives the same loops with raw
ping-pong connections.
"""
from __future__ import annotations

import threading
import time
from collections import deque
from dataclasses import dataclass, field
from typing import Any, Callable, Optional, Sequence

import jax

from repro.core import selector
from repro.obs import trace as obs_trace
from repro.obs.metrics import RingLog

POLLS = ("busy", "park", "adaptive")


def channel_affinity(n_channels: int, n_loops: int, *, n_pods: int = 1,
                     leaders: int = 0, leader_loops: int = 1) -> tuple:
    """Partition the global channel pool ``0..n_channels-1`` into
    ``n_loops`` DISJOINT contiguous runs — each event loop's owned
    connections (``selector.ready_groups`` is exactly this grouping rule,
    applied to channels instead of buckets). Raises when a loop would own
    nothing: ownership is the invariant the subsystem is built on.

    The TOPOLOGY-AWARE form (``leaders > 0``) backs the two-level
    serving fabric: the pool's LAST ``leaders`` channels are the
    cross-pod leader lanes (``pipeline._leader_split`` carves the same
    tail) and are pinned to the first ``leader_loops`` loops — the
    designated leader loops, appended to their local runs. The remaining
    LOCAL lanes are partitioned with ``selector.pod_aligned_groups`` so
    a loop's owned locals never straddle a pod boundary: every loop's
    flushes complete on in-pod links without waiting on a cross-pod
    straggler, and only leader loops ever touch the scarce link.
    Ownership stays disjoint and covering in both forms."""
    if leaders <= 0:
        if n_loops > n_channels:
            raise ValueError(
                f"{n_loops} event loops over {n_channels} channels: every "
                "loop must own at least one channel (disjoint ownership); "
                "raise comm.channels or lower event_loops")
        return selector.ready_groups(n_channels, n_loops)
    n_local = n_channels - leaders
    if n_loops > n_local:
        raise ValueError(
            f"{n_loops} event loops over {n_local} local channels "
            f"({n_channels} minus {leaders} leader lanes): every loop "
            "must own at least one LOCAL channel (the in-pod stages are "
            "what loops emit); raise comm.channels or lower event_loops")
    if not 1 <= leader_loops <= n_loops:
        raise ValueError(
            f"leader_loops={leader_loops} must be in 1..{n_loops} "
            "(a leader lane needs an owning loop, and only existing "
            "loops can own one)")
    groups = [list(g) for g in selector.pod_aligned_groups(
        n_local, n_loops, min(n_pods, n_local))]
    for l, run in enumerate(selector.ready_groups(leaders,
                                                  min(leader_loops, leaders))):
        groups[l].extend(n_local + i for i in run)
    return tuple(tuple(g) for g in groups)


@dataclass
class PollStats:
    """How the loop waited: ``spins`` = readiness probes that came back
    not-ready, ``parks`` = blocking waits entered, ``waits`` = completed
    wait calls, ``stalls`` = parks FORCED by the fault seam (the chaos
    harness's over-parking loop — ``serving/chaos.py``), ``delays`` =
    waits the fault seam slowed down without forcing a park (a slow
    channel's completion arriving late). ``busy`` keeps parks at 0;
    ``park`` keeps spins at 0; a fault-free run keeps stalls and delays
    at 0 — both are pure health signals for the supervisor
    (``serving/supervisor.py``)."""
    spins: int = 0
    parks: int = 0
    waits: int = 0
    stalls: int = 0
    delays: int = 0

    def merge(self, other: "PollStats") -> "PollStats":
        return PollStats(self.spins + other.spins,
                         self.parks + other.parks,
                         self.waits + other.waits,
                         self.stalls + other.stalls,
                         self.delays + other.delays)


class Poller:
    """Completion polling for one event loop (hadroNIO §IV-B: busy-poll
    the worker vs. park in epoll; ``adaptive`` is the bounded spin).

    ``fault`` is the chaos seam (``serving/chaos.py``): when set, it is
    called once at the top of every :meth:`wait` with the poller itself
    and may sleep (a slow channel's completion arriving late) or return
    a verdict — ``"stall"`` forces an immediate park (the over-parking
    loop from Ibdxnet's failure catalogue, counted in ``stats.stalls``);
    ``"delay"`` reports that the hook slowed this wait down and proceeds
    normally, counted in ``stats.delays`` so the supervisor's health
    model can see slow channels without any wall-clock measurement.
    ``None`` (the default) is a zero-overhead no-op."""

    def __init__(self, poll: str = "busy", spin_s: float = 50e-6):
        assert poll in POLLS, poll
        self.poll = poll
        self.spin_s = spin_s
        self.stats = PollStats()
        self.fault: Optional[Callable[["Poller"], Optional[str]]] = None

    @staticmethod
    def _handles(tree: Any) -> list:
        return [l for l in jax.tree.leaves(tree) if hasattr(l, "is_ready")]

    @staticmethod
    def _ready(handles: list) -> bool:
        return all(h.is_ready() for h in handles)

    def _park(self, handles: list) -> None:
        self.stats.parks += 1
        for h in handles:
            h.block_until_ready()

    def wait(self, tree: Any) -> Any:
        """Wait for every jax array in ``tree`` per the strategy; returns
        ``tree`` so call sites can chain."""
        handles = self._handles(tree)
        self.stats.waits += 1
        if self.fault is not None:
            verdict = self.fault(self)
            if verdict == "stall":
                self.stats.stalls += 1  # forced over-park (chaos seam)
                self._park(handles)
                return tree
            if verdict == "delay":
                self.stats.delays += 1  # slowed wait; proceed normally
        if self.poll == "park" or (self.poll == "adaptive"
                                   and self.spin_s <= 0):
            # a zero spin budget IS park: straight to the epoll fallback,
            # exactly one park and zero probe burns
            self._park(handles)
            return tree
        deadline = (time.perf_counter() + self.spin_s
                    if self.poll == "adaptive" else None)
        while not self._ready(handles):
            self.stats.spins += 1
            if deadline is not None and time.perf_counter() >= deadline:
                self._park(handles)     # adaptive: bounded spin, then epoll
                break
        return tree


@dataclass(frozen=True)
class LoopFailure:
    """Structured record of one failed drain: WHICH loop died, WHAT
    killed it, and HOW MANY items were in flight (the in-flight batch
    plus anything still queued) — everything the supervisor needs to
    quarantine the loop and re-admit its requests. ``error`` is the
    ``repr`` of the exception (records must stay picklable/comparable);
    the live exception object stays on ``loop.error``."""
    loop_index: int
    error: str
    pending: int


class EventLoop:
    """One event loop: owned channels, a poller, and a run queue drained
    by ``runner(loop, items) -> list`` (the engine batches its items
    through the decode engine; the RTT bench ping-pongs them)."""

    def __init__(self, index: int, *, channels: Sequence[int] = (),
                 poll: str = "busy", spin_s: float = 50e-6,
                 runner: Optional[Callable] = None):
        self.index = index
        self.channels = tuple(channels)   # owned run of the global pool
        self.poller = Poller(poll, spin_s)
        self.runner = runner
        self.queue: deque = deque()       # run queue of in-flight items
        self.results: list = []
        self.error: Optional[BaseException] = None
        self.failed_items: list = []      # in-flight batch of a failed drain
        self.heartbeats = 0               # drained batches, ever — liveness
        self.restarts = 0
        self.lifetime_stats = PollStats()  # folded stats of RETIRED pollers
        #                                    (restart() accumulates here)
        # chaos seam: called with (loop, items) per drained batch, BEFORE
        # the runner — the injection point for queue-level faults and the
        # deterministic drain trace (serving/chaos.py)
        self.drain_hook: Optional[Callable] = None

    def submit(self, item: Any) -> None:
        self.queue.append(item)

    def drain(self) -> list:
        """Run everything queued through the runner (new submissions made
        while draining land in the queue and are picked up too). A
        runner failure is recorded in ``error`` (and re-raised) so a
        threaded group can propagate it instead of silently dropping the
        loop's requests; the in-flight batch is stashed in
        ``failed_items`` so a supervisor can re-admit it after a
        restart."""
        out: list = []
        self.error = None
        self.failed_items = []
        items: list = []
        try:
            while self.queue:
                items = list(self.queue)
                self.queue.clear()
                assert self.runner is not None, "event loop has no runner"
                if self.drain_hook is not None:
                    self.drain_hook(self, items)
                if obs_trace.enabled():
                    with obs_trace.span("drain", f"loop{self.index}",
                                        loop=self.index,
                                        items=len(items)):
                        out.extend(self.runner(self, items))
                else:
                    out.extend(self.runner(self, items))
                self.heartbeats += 1    # one beat per drained batch
        except BaseException as e:
            self.error = e
            self.failed_items = items
            raise
        finally:
            self.results = out
        return out

    def restart(self) -> Poller:
        """Quarantine-and-restart: replace the poller with a FRESH one
        (same strategy/spin budget — but no fault seam and zeroed
        counters, so a wedged or chaos-armed poller is genuinely
        cleared), forget the failure state, and re-point an attached
        engine at the new poller. The caller owns re-admitting
        ``failed_items``/queue contents; ``restarts`` counts how often
        this loop needed healing. The retiring poller's counters fold
        into ``lifetime_stats`` FIRST — a restart heals the loop, it
        must not erase its history (supervisor EWMAs and the group's
        merged ``poll_stats`` stay monotone across heals)."""
        self.lifetime_stats = self.lifetime_stats.merge(self.poller.stats)
        self.poller = Poller(self.poller.poll, self.poller.spin_s)
        self.error = None
        self.failed_items = []
        self.restarts += 1
        eng = getattr(self, "engine", None)
        if eng is not None:
            eng.poller = self.poller
        return self.poller

    def poll_stats(self) -> PollStats:
        """Lifetime poll counters: every retired poller's stats (folded
        at each :meth:`restart`) merged with the live poller's."""
        return self.lifetime_stats.merge(self.poller.stats)


class EventLoopGroup:
    """N event loops over one disjoint channel partition. ``submit``
    assigns items round-robin (paper §IV-C); ``run`` drains every loop —
    one OS thread per loop under ``threads=True`` (the multi-threaded
    benchmark topology), in-line otherwise (deterministic debugging).

    MULTI-TENANT form: ``tenants`` is a sequence of ``(name, weight,
    loop_indices)`` bindings that partition the loops (contiguous ranges
    built by ``engine.make_engine_group`` from ``ServeConfig.tenants``).
    ``submit`` then routes each item to ITS tenant's loops (round-robin
    within the tenant) and orders a mixed batch with a deterministic
    weighted-fair stride scheduler: the next dispatched item belongs to
    the tenant minimizing ``(dispatched[t] + 1) / weight[t]``, ties
    broken in declaration order — weights 2:1 yield the exact sequence
    A A B A A B. The cumulative per-tenant counters persist on
    ``fairness_counters`` and the per-item routing trace on
    ``dispatch_log`` (both are what the fairness tests and the
    family-matrix smoke assert on). Untagged items (``tenant`` empty or
    absent) ride the first tenant; an unknown tenant name raises."""

    def __init__(self, loops: Sequence[EventLoop],
                 tenants: Optional[Sequence] = None, *,
                 dispatch_log_capacity: int = 65536):
        assert loops, "an EventLoopGroup needs at least one loop"
        owned = [c for l in loops for c in l.channels]
        assert len(owned) == len(set(owned)), \
            f"channel ownership must be disjoint: {[l.channels for l in loops]}"
        self.loops = list(loops)
        self._rr = 0
        self.tenants = tuple(tenants) if tenants else ()
        self._torder = [t[0] for t in self.tenants]
        self._tweight = {n: w for n, w, _ in self.tenants}
        self._tloops = {n: tuple(ix) for n, _, ix in self.tenants}
        self._trr = {n: 0 for n in self._torder}
        self.fairness_counters = {n: 0 for n in self._torder}
        # tenant name per routed item — a bounded ring (long-running
        # serves must not grow memory; evictions count in .dropped and
        # surface through the obs registry as group.dispatch_log_dropped)
        self.dispatch_log = RingLog(dispatch_log_capacity)
        if self.tenants:
            allix = sorted(i for _, _, ix in self.tenants for i in ix)
            assert allix == list(range(self.n_loops)), \
                (f"tenant loop ranges must partition the group's "
                 f"{self.n_loops} loops: {self._tloops}")
        self.loop_failures = 0    # loops whose drain raised, across runs —
        #                           the failure-propagation counter the
        #                           chaos harness and the threaded-run
        #                           regression tests assert on
        self.failures: list = []  # structured LoopFailure records, in the
        #                           order failures were observed (appended
        #                           by BOTH threaded and inline drains) —
        #                           the supervisor's detect feed

    @property
    def n_loops(self) -> int:
        return len(self.loops)

    def submit(self, items: Any) -> None:
        """Round-robin connection→loop assignment; accepts one item or a
        sequence. With tenants, routes per tenant in weighted-fair
        stride order (see the class docstring)."""
        if not isinstance(items, (list, tuple)):
            items = [items]
        if not self.tenants:
            for it in items:
                self.loops[self._rr % self.n_loops].submit(it)
                self._rr += 1
            return
        pending = {n: deque() for n in self._torder}
        for it in items:
            name = getattr(it, "tenant", "") or self._torder[0]
            if name not in pending:
                raise ValueError(
                    f"unknown tenant {name!r}: this group serves "
                    f"{self._torder} (Request.tenant must name one, or be "
                    "empty to ride the first tenant)")
            pending[name].append(it)
        remaining = sum(len(q) for q in pending.values())
        while remaining:
            name = min((n for n in self._torder if pending[n]),
                       key=lambda n: ((self.fairness_counters[n] + 1)
                                      / self._tweight[n]))
            it = pending[name].popleft()
            ix = self._tloops[name]
            self.loops[ix[self._trr[name] % len(ix)]].submit(it)
            self._trr[name] += 1
            self.fairness_counters[name] += 1
            self.dispatch_log.append(name)
            remaining -= 1

    def _record_failure(self, loop: EventLoop) -> None:
        self.loop_failures += 1
        self.failures.append(LoopFailure(
            loop.index, repr(loop.error),
            len(loop.failed_items) + len(loop.queue)))

    def run(self, *, threads: bool = True,
            raise_on_failure: bool = True) -> list:
        """Drain every loop; returns the concatenated results (loop
        order — callers sort by uid where ordering matters). A failure
        in ANY loop is recorded as a structured :class:`LoopFailure` in
        ``failures`` and — by default — propagates (after every thread
        has joined): a partial result set must never SILENTLY look like
        success. ``raise_on_failure=False`` is the supervisor's entry
        point: survivors' results are returned and the failure records
        plus each failed loop's ``failed_items`` carry everything needed
        to heal."""
        if threads and self.n_loops > 1:
            def guarded(loop):
                try:
                    loop.drain()
                except BaseException:
                    pass              # recorded in loop.error; raised below
            ts = [threading.Thread(target=guarded, args=(l,),
                                   name=f"event-loop-{l.index}")
                  for l in self.loops]
            for t in ts:
                t.start()
            for t in ts:
                t.join()
            failed = [l for l in self.loops if l.error is not None]
            for l in failed:
                self._record_failure(l)
            if failed and raise_on_failure:
                raise failed[0].error
        else:
            for l in self.loops:
                try:
                    l.drain()
                except BaseException:
                    self._record_failure(l)
                    if raise_on_failure:
                        raise
        return [r for l in self.loops for r in l.results]

    def poll_stats(self) -> PollStats:
        st = PollStats()
        for l in self.loops:
            st = st.merge(l.poll_stats())   # lifetime: survives restarts
        return st
