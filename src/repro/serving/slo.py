"""SLO accounting for the serving plane: per-request RTT percentiles and
the recovery invariants the chaos harness asserts.

The JIB benchmark paper (arXiv:1910.02245) characterizes latency by
p50/p99/p99.9 — never means — and argues that transparent-acceleration
layers must be evaluated under identical, reproducible conditions. For
fault scenarios that translates into TWO checks per injected run:

* **recovery** (hard, deterministic) — after the faults are absorbed,
  every request's served tokens are BIT-identical to the fault-free run
  (:func:`token_recovery`). This is the invariant the whole stack is
  designed around: drops are re-flushed at the step barrier, duplicates
  are idempotent, affinity/loop-count changes move emission structure
  but never values.
* **bounded inflation** (soft, wall-clock) — the faulted run's p99.9
  RTT may not exceed the fault-free baseline by more than a configured
  factor (:func:`assert_slo`). Wall-clock is environment-noisy, so the
  benchmarks assert it with generous bounds while the tier-1 tests lean
  on the deterministic half.

This module is dependency-light on purpose (numpy only — no jax, no
benchmarks/): the engine layer records samples, the benchmark layer
turns reports into rows.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, Optional, Sequence, Tuple

import numpy as np

PERCENTILE_QS = (50.0, 99.0, 99.9)
PERCENTILE_LABELS = {50.0: "p50", 99.0: "p99", 99.9: "p99.9"}


def rtt_percentiles(samples: Sequence[float]) -> Dict[str, float]:
    """``{"p50": s, "p99": s, "p99.9": s}`` (seconds) over a flat sample
    stream. Small samples degrade to order statistics (one sample makes
    every percentile that sample); monotone in q by construction. Raises
    on empty input — an empty distribution has no percentiles and
    silently reporting one would fabricate a latency."""
    flat = np.asarray([float(s) for s in samples], np.float64)
    if flat.size == 0:
        raise ValueError("rtt_percentiles() of an empty sample set")
    vals = np.percentile(flat, list(PERCENTILE_QS))
    return {PERCENTILE_LABELS[q]: float(v)
            for q, v in zip(PERCENTILE_QS, vals)}


def token_recovery(reference: Dict[int, tuple],
                   served: Dict[int, tuple]) -> Tuple[bool, tuple]:
    """The hard recovery invariant: every reference request was served
    and its tokens match BIT-identically. Returns ``(recovered,
    mismatched_uids)`` — a uid is mismatched when missing from
    ``served`` or when its token sequence differs. Extra uids in
    ``served`` (absorbed storm traffic) are ignored: the invariant is
    about the original clients, not the injected load."""
    bad = tuple(sorted(
        uid for uid, toks in reference.items()
        if tuple(served.get(uid, ())) != tuple(toks)))
    return not bad, bad


def mttr(spans: Sequence[float]) -> Optional[float]:
    """Mean time-to-recovery (seconds) over per-incident detect→heal
    spans — the supervised-run analogue of the percentile summary: each
    healing action carries wall-clock (t_detect, t_heal) stamps, the
    span is their difference, and MTTR is the mean. Returns None for an
    empty span set (no incident was healed — distinct from healing
    instantly). Wall-clock, so like p99.9 inflation it is asserted with
    generous bounds only; the deterministic half of a supervised run is
    the healing TRACE (``Supervisor.healing_trace``), which excludes
    these stamps."""
    vals = [float(s) for s in spans]
    if not vals:
        return None
    return float(np.mean(vals))


@dataclass(frozen=True)
class SLOReport:
    """One scenario run's verdict: identity of the run, the recovery
    outcome, and the RTT distributions (seconds). ``baseline`` is None
    when the caller shared a token-only reference (tier-1 determinism
    tests) — inflation is then unavailable and only recovery binds.
    ``healing_actions``/``mttr_s`` describe the supervisor's detect→heal
    loop for SUPERVISED runs (0/None when unsupervised — nothing
    healed)."""
    scenario: str
    seed: int
    mode: str
    event_loops: int
    recovered: bool
    mismatched_uids: tuple
    n_injected: int
    fault: Dict[str, float]
    baseline: Optional[Dict[str, float]] = None
    healing_actions: int = 0
    mttr_s: Optional[float] = None

    @property
    def p999_inflation(self) -> Optional[float]:
        """fault p99.9 / baseline p99.9 (None without a baseline; a
        degenerate zero baseline reports 1.0 — nothing to inflate)."""
        if self.baseline is None:
            return None
        base = self.baseline["p99.9"]
        if base <= 0.0:
            return 1.0
        return self.fault["p99.9"] / base


def make_report(*, scenario: str, seed: int, mode: str, event_loops: int,
                reference: Dict[int, tuple], served: Dict[int, tuple],
                fault_rtts: Sequence[float],
                baseline_rtts: Optional[Sequence[float]] = None,
                n_injected: int = 0, healing_actions: int = 0,
                mttr_s: Optional[float] = None) -> SLOReport:
    recovered, bad = token_recovery(reference, served)
    return SLOReport(
        scenario=scenario, seed=seed, mode=mode, event_loops=event_loops,
        recovered=recovered, mismatched_uids=bad, n_injected=n_injected,
        fault=rtt_percentiles(fault_rtts),
        baseline=(rtt_percentiles(baseline_rtts)
                  if baseline_rtts else None),
        healing_actions=healing_actions, mttr_s=mttr_s)


def assert_slo(report: SLOReport, *,
               max_p999_inflation: Optional[float] = None,
               max_mttr_s: Optional[float] = None) -> None:
    """Raise AssertionError when the report violates its SLO: recovery
    always binds; the p99.9 bound binds only when a baseline exists AND
    a bound was given; the MTTR bound binds only when the report carries
    an MTTR and a bound was given (wall-clock checks are opt-in — CI
    noise must not fail the deterministic harness)."""
    assert report.recovered, (
        f"{report.scenario} seed={report.seed} mode={report.mode} "
        f"el={report.event_loops}: served tokens diverged from the "
        f"fault-free run for uids {report.mismatched_uids}")
    infl = report.p999_inflation
    if max_p999_inflation is not None and infl is not None:
        assert infl <= max_p999_inflation, (
            f"{report.scenario} seed={report.seed}: p99.9 inflated "
            f"{infl:.2f}x > bound {max_p999_inflation:.2f}x "
            f"(fault {report.fault['p99.9'] * 1e6:.1f}us vs baseline "
            f"{report.baseline['p99.9'] * 1e6:.1f}us)")
    if max_mttr_s is not None and report.mttr_s is not None:
        assert report.mttr_s <= max_mttr_s, (
            f"{report.scenario} seed={report.seed}: MTTR "
            f"{report.mttr_s:.3f}s > bound {max_mttr_s:.3f}s over "
            f"{report.healing_actions} healing actions")
