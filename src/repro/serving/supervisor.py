"""Self-healing serving supervisor: detect → decide → heal.

The paper's netty/hadroNIO design keeps throughput stable by binding
connections to event loops and letting the worker pool absorb load
(§IV); Ibdxnet (arXiv:1812.01963) shows the same architecture needs
demand-driven worker management and failure isolation to survive real
concurrency. PR 7's chaos harness *injects* those failures; this module
is the layer that *reacts*. A :class:`Supervisor` wraps the
`EventLoopGroup` + `DecodeEngine` fleet (``make_engine_group``) and runs
the serving plane in ROUNDS — dispatch a quantum from the bounded
admission queue, drain the fleet, then close the loop:

**Detect** — a health model fed exclusively from DETERMINISTIC seams:

* ``PollStats`` counters per loop (``stalls`` = forced over-parks,
  ``delays`` = fault-slowed waits) diffed per round and folded into an
  EWMA per signal;
* structured ``EventLoopGroup.failures`` records (loop index, exception
  repr, pending count) from non-raising drains;
* ``pipeline.current_stats().drops`` deltas — dropped flushes counted at
  trace time (the active :func:`pipeline.stats_scope`, module-global by
  default);
* a heartbeat deadline per loop (``EventLoop.heartbeats`` must advance
  whenever the loop had work) measured in ROUNDS, not seconds;
* run-queue depth (admission backlog per loop) for autoscaling;
* per-channel emission counts via ``channels.set_collective_hook``
  (composed with any already-installed hook), exposed as
  ``emission_counts`` for observability.

**Heal** — every decision appends a structured :class:`HealAction`:

* *quarantine-and-restart*: a stalled/failed loop gets a FRESH poller
  (``EventLoop.restart`` — genuinely clears a wedged fault seam), its
  queued requests migrate to survivors, and a persistently unhealthy
  loop shrinks the fleet via the elastic reshard;
* *retry with backoff*: a failed drain's in-flight batch
  (``failed_items``) is re-admitted under a capped-exponential
  :class:`RetryBudget` with seeded jitter and a per-request deadline;
  exhaustion surfaces a structured ``retry_exhausted``
  :class:`Outcome` instead of a hang;
* *elastic resize*: grow/shrink ``event_loops`` between flush
  boundaries — from queue depth with hysteresis + cooldown
  (autoscale), or on external demand (:meth:`Supervisor.request_resize`)
  — through ``launch/elastic.reshard_event_loops`` +
  minimal-migration ``reshard_affinity``, rebuilding the fleet with the
  EXPLICIT resharded affinity;
* *admission control*: a bounded admission queue with backpressure —
  over capacity, the LOWEST-priority request is shed with an explicit
  ``rejected`` outcome — and in-wave bursts (the chaos storm seam) are
  gated per engine (``DecodeEngine.admission_gate``). Admission itself
  is batched: the engine prefills every freed slot in one call.

**Determinism contract**: every healing decision keys off counters
(stalls, delays, drops, failures, queue depths, rounds), never wall
clock; backoff jitter draws from one ``numpy`` Generator seeded at
construction. Same seed + same ChaosPlan ⇒ same
:meth:`Supervisor.healing_trace` (which EXCLUDES the wall-clock
``t_detect``/``t_heal`` stamps — those only feed MTTR, the wall-clock
half reported through ``slo.mttr``).
"""
from __future__ import annotations

import time
from collections import deque
from dataclasses import dataclass
from typing import Any, Dict, List, Optional

import numpy as np

from repro.configs.base import ModelConfig, ServeConfig
from repro.core import channels as channels_mod
from repro.core.backends import pipeline
from repro.launch.elastic import reshard_affinity, reshard_event_loops
from repro.obs import trace as obs_trace
from repro.serving import slo
from repro.serving.engine import Request, make_engine_group
from repro.serving.event_loop import EventLoop, PollStats

PyTree = Any


@dataclass(frozen=True)
class RetryBudget:
    """Capped exponential backoff for drain retries: attempt ``a`` waits
    ``min(cap_s, base_s * 2**a)`` scaled by ``1 ± jitter`` (drawn from
    the supervisor's SEEDED rng — deterministic backoff trace), at most
    ``limit`` retry attempts, bounded by a per-incident wall-clock
    ``deadline_s``. Exhaustion is surfaced as a structured
    ``retry_exhausted`` :class:`Outcome`, never a hang."""
    limit: int = 3
    base_s: float = 1e-3
    cap_s: float = 20e-3
    jitter: float = 0.25
    deadline_s: float = 30.0

    def backoff_s(self, attempt: int, rng: np.random.Generator) -> float:
        raw = min(self.cap_s, self.base_s * (2.0 ** attempt))
        if self.jitter > 0:
            raw *= 1.0 + self.jitter * float(rng.uniform(-1.0, 1.0))
        return max(0.0, raw)


@dataclass(frozen=True)
class Outcome:
    """Terminal disposition of one request uid: ``served`` (tokens
    delivered), ``rejected`` (shed by admission control), or
    ``retry_exhausted`` (the retry budget ran dry re-draining it).
    ``attempts`` counts drain attempts (1 = served first try)."""
    uid: int
    status: str
    reason: str = ""
    attempts: int = 1


@dataclass(frozen=True)
class HealAction:
    """One supervisor decision. ``kind`` ∈ {quarantine, restart, retry,
    retry_exhausted, reflush, resize, shed, backpressure};
    ``target``/``detail`` are kind-specific but always deterministic;
    ``t_detect``/``t_heal`` are wall-clock stamps for MTTR only and are
    EXCLUDED from the canonical trace."""
    round: int
    kind: str
    target: int
    detail: tuple = ()
    t_detect: float = 0.0
    t_heal: float = 0.0

    @property
    def span_s(self) -> float:
        return max(0.0, self.t_heal - self.t_detect)


@dataclass(frozen=True)
class SupervisorConfig:
    """Knobs of the detect/decide/heal loop. Health: per-loop EWMAs of
    the per-round stall/delay deltas (``ewma_alpha``) against
    ``stall_limit``/``delay_limit``; a loop whose heartbeats don't
    advance for ``heartbeat_rounds`` rounds-with-work is declared dead;
    more than ``max_restarts`` quarantines shrinks the fleet. Autoscale:
    admission backlog per loop ≥ ``scale_up_depth`` votes to grow, ≤
    ``scale_down_depth`` votes to shrink (negative disables shrink —
    the default, so finite runs don't thrash on their natural
    drain-down); ``hysteresis`` consecutive votes act, then
    ``cooldown_rounds`` rounds of quiet. Admission: ``admission_capacity``
    bounds BOTH the client queue and the per-run in-wave burst budget;
    ``dispatch_quantum`` requests leave the queue per round (0 = all).
    ``max_rounds`` is the structured runaway bound — exceeding it raises
    instead of spinning forever."""
    ewma_alpha: float = 0.5
    stall_limit: float = 0.5
    delay_limit: float = 0.5
    heartbeat_rounds: int = 2
    max_restarts: int = 2
    scale_up_depth: float = 8.0
    scale_down_depth: float = -1.0
    hysteresis: int = 2
    cooldown_rounds: int = 1
    min_loops: int = 1
    max_loops: int = 0            # 0 = the channel pool size
    admission_capacity: int = 64
    dispatch_quantum: int = 0     # 0 = drain the whole queue per round
    max_rounds: int = 64
    retry: RetryBudget = RetryBudget()


class Supervisor:
    """The self-healing serving fleet. Construction is LAZY: the group
    is built on first :meth:`run` so callers can arm ``fleet_hook``
    first — it is invoked with every (re)built ``EventLoopGroup``, which
    is how the chaos harness re-arms its injections across supervisor
    rebuilds (a loop-level ``restart`` deliberately does NOT re-invoke
    it: a fresh poller genuinely clears a poller fault — that's the
    healing)."""

    def __init__(self, cfg: ModelConfig, params: PyTree, serve: ServeConfig,
                 *, mesh=None, config: Optional[SupervisorConfig] = None,
                 seed: int = 0, eos_id: Optional[int] = None):
        self.cfg = cfg
        self.params = params
        self.serve = serve
        self.mesh = mesh
        self.config = config or SupervisorConfig()
        self.seed = seed
        self.eos_id = eos_id
        self._rng = np.random.default_rng(seed)   # backoff jitter only
        self.queue: deque = deque()               # bounded admission queue
        self.trace: List[HealAction] = []
        self.outcomes: Dict[int, Outcome] = {}
        self.emission_counts: Dict[int, int] = {}
        self.fleet_hook = None
        self.rounds = 0
        self._group = None
        self._affinity = None                     # explicit after a resize
        self._resize_request: Optional[int] = None
        self._served: set = set()
        self._attempts: Dict[int, int] = {}
        self._ewma: Dict[int, Dict[str, float]] = {}
        self._missed: Dict[int, int] = {}
        self._restarts: Dict[int, int] = {}
        self._restarted_this_round: set = set()
        self._votes = 0
        self._cooldown = 0
        self._wave_admissions = 0
        self._poll_accum = PollStats()

    # -- admission (bounded queue + backpressure) ----------------------

    def submit(self, reqs) -> None:
        """Enqueue client requests through the bounded admission queue.
        Over capacity, the LOWEST-priority request in (queue + newcomer)
        is shed with a ``rejected`` outcome — graceful degradation, not
        unbounded queuing."""
        if isinstance(reqs, Request):
            reqs = [reqs]
        for r in reqs:
            self._enqueue(r)

    def _enqueue(self, req: Request) -> None:
        c = self.config
        if len(self.queue) < c.admission_capacity:
            self.queue.append(req)
            return
        t0 = time.perf_counter()
        victim = min(self.queue,
                     key=lambda r: (getattr(r, "priority", 0), -r.uid))
        if getattr(req, "priority", 0) > getattr(victim, "priority", 0):
            self.queue.remove(victim)
            self.queue.append(req)
            out = victim
        else:
            out = req
        self.outcomes[out.uid] = Outcome(out.uid, "rejected",
                                         "admission_queue_full", 0)
        self._action("shed", out.uid, (getattr(out, "priority", 0),), t0)

    def _admission_gate(self, engine, step: int, extra: list) -> list:
        """In-wave admission control (``DecodeEngine.admission_gate``):
        a hook-injected burst — the chaos storm seam — passes through
        the same bounded budget, highest priority first; the overflow is
        shed with ``rejected`` outcomes."""
        if not extra:
            return extra
        c = self.config
        t0 = time.perf_counter()
        ranked = sorted(extra,
                        key=lambda r: (-getattr(r, "priority", 0), r.uid))
        admitted, shed = [], []
        for r in ranked:
            if self._wave_admissions < c.admission_capacity:
                self._wave_admissions += 1
                admitted.append(r)
            else:
                shed.append(r)
        self._action("backpressure", step,
                     (len(extra), len(admitted), len(shed)), t0)
        for r in shed:
            self.outcomes[r.uid] = Outcome(r.uid, "rejected",
                                           "admission_capacity", 0)
            self._action("shed", r.uid, (getattr(r, "priority", 0),), t0)
        return admitted

    # -- fleet construction --------------------------------------------

    def _build_group(self):
        self._group = make_engine_group(
            self.cfg, self.params, self.serve, mesh=self.mesh,
            eos_id=self.eos_id, seed=self.seed, affinity=self._affinity)
        if self.fleet_hook is not None:
            self.fleet_hook(self._group)
        for l in self._group.loops:
            l.engine.admission_gate = self._admission_gate
        return self._group

    @property
    def group(self):
        if self._group is None:
            self._build_group()
        return self._group

    def request_resize(self, new_loops: int) -> None:
        """External elasticity demand (cluster manager / chaos reshard
        scenario): applied at the next round boundary through the same
        resize path the autoscaler uses."""
        self._resize_request = int(new_loops)

    # -- the supervised serving loop -----------------------------------

    def run(self, *, threads: bool = False) -> list:
        """Serve everything admitted so far, healing as needed; returns
        Results sorted by uid. Inline drains (``threads=False``) give a
        fully deterministic healing trace; threaded drains keep the
        healing semantics but interleave wall-clock."""
        g = self.group
        results: list = []
        self._wave_admissions = 0
        prev_hook = channels_mod.get_collective_hook()

        def emission_hook(c, kind):
            self.emission_counts[c] = self.emission_counts.get(c, 0) + 1
            if prev_hook is not None:
                prev_hook(c, kind)

        channels_mod.set_collective_hook(emission_hook)
        try:
            while self.queue or any(l.queue or l.failed_items
                                    for l in self._group.loops):
                self.rounds += 1
                if self.rounds > self.config.max_rounds:
                    raise RuntimeError(
                        f"supervisor exceeded max_rounds="
                        f"{self.config.max_rounds} with "
                        f"{len(self.queue)} requests still queued — "
                        "healing is not converging")
                self._restarted_this_round = set()
                self._dispatch()
                snap = self._snapshot()
                out = self._group.run(threads=threads,
                                      raise_on_failure=False)
                self._collect(out, results)
                # heal phase: runs after EVERY round (including the last)
                self._heal_failures(snap, results)
                self._detect_reflush(snap)
                self._health_check(snap)
                self._apply_external_resize()
                self._autoscale()
        finally:
            channels_mod.set_collective_hook(prev_hook)
        for r in results:
            self.outcomes[r.uid] = Outcome(
                r.uid, "served", attempts=self._attempts.get(r.uid, 1))
        results.sort(key=lambda r: r.uid)
        return results

    def _dispatch(self) -> None:
        q = self.config.dispatch_quantum or len(self.queue)
        batch = [self.queue.popleft()
                 for _ in range(min(q, len(self.queue)))]
        if batch:
            self._group.submit(batch)

    def _snapshot(self) -> dict:
        g = self._group
        return {
            "stalls": {l.index: l.poller.stats.stalls for l in g.loops},
            "delays": {l.index: l.poller.stats.delays for l in g.loops},
            "beats": {l.index: l.heartbeats for l in g.loops},
            "dispatched": {l.index for l in g.loops if l.queue},
            "drops": pipeline.current_stats().drops,
            "failures": len(g.failures),
        }

    def _collect(self, out: list, results: list) -> None:
        for r in out:
            if r.uid in self._served:
                continue
            self._served.add(r.uid)
            results.append(r)

    # -- detect → heal -------------------------------------------------

    def _action(self, kind: str, target: int, detail: tuple,
                t_detect: float) -> HealAction:
        a = HealAction(self.rounds, kind, int(target), tuple(detail),
                       t_detect, time.perf_counter())
        self.trace.append(a)
        obs_trace.complete("heal", kind, a.t_detect, a.t_heal,
                           round=self.rounds, target=int(target))
        return a

    def _heal_failures(self, snap: dict, results: list) -> None:
        """Retry/backoff healing for loops whose drain raised: restart
        the loop, re-admit its in-flight batch under the RetryBudget."""
        fresh = self._group.failures[snap["failures"]:]
        for lf in fresh:
            loop = self._group.loops[lf.loop_index]
            t0 = time.perf_counter()
            items = list(loop.failed_items) + list(loop.queue)
            loop.queue.clear()
            self._restarts[loop.index] = \
                self._restarts.get(loop.index, 0) + 1
            self._action("quarantine", loop.index,
                         ("drain_failure", lf.error, len(items)), t0)
            loop.restart()
            self._restarted_this_round.add(loop.index)
            self._action("restart", loop.index, (), t0)
            self._reset_health(loop.index)
            if items:
                self._retry_items(loop, items, t0, results)

    def _retry_items(self, loop: EventLoop, items: list, t0: float,
                     results: list) -> None:
        budget = self.config.retry
        deadline = t0 + budget.deadline_s
        last: Optional[BaseException] = None
        for attempt in range(budget.limit):
            back = budget.backoff_s(attempt, self._rng)
            if back > 0:
                time.sleep(back)
            for it in items:
                loop.submit(it)
            try:
                out = loop.drain()
            except BaseException as e:
                last = e
                items = list(loop.failed_items) + list(loop.queue)
                loop.queue.clear()
                loop.restart()
                if time.perf_counter() >= deadline:
                    break
                continue
            for r in out:
                self._attempts[r.uid] = attempt + 2
            self._collect(out, results)
            self._action("retry", loop.index,
                         (attempt + 1, round(back, 9), len(items)), t0)
            return
        # budget exhausted: structured surfacing, never a hang
        uids = tuple(sorted(getattr(it, "uid", -1) for it in items))
        for it in items:
            uid = getattr(it, "uid", None)
            if uid is not None:
                self.outcomes[uid] = Outcome(
                    uid, "retry_exhausted", repr(last), budget.limit + 1)
        self._action("retry_exhausted", loop.index,
                     (budget.limit, uids, repr(last)), t0)

    def _detect_reflush(self, snap: dict) -> None:
        drops = pipeline.current_stats().drops - snap["drops"]
        if drops > 0:
            t0 = time.perf_counter()
            # the staged-emission completeness contract already
            # re-flushed every dropped channel at the finish_emission
            # barrier; the supervisor's job is to DETECT it happened and
            # verify the round's outputs were complete (they were — the
            # drain returned), recorded as a healing observation
            self._action("reflush", drops, ("finish_emission_barrier",),
                         t0)

    def _reset_health(self, index: int) -> None:
        self._ewma.pop(index, None)
        self._missed.pop(index, None)

    def _health_check(self, snap: dict) -> None:
        c = self.config
        for l in list(self._group.loops):
            i = l.index
            if i in self._restarted_this_round:
                continue
            d_stall = max(0, l.poller.stats.stalls
                          - snap["stalls"].get(i, 0))
            d_delay = max(0, l.poller.stats.delays
                          - snap["delays"].get(i, 0))
            ew = self._ewma.setdefault(i, {"stalls": 0.0, "delays": 0.0})
            ew["stalls"] = c.ewma_alpha * d_stall \
                + (1 - c.ewma_alpha) * ew["stalls"]
            ew["delays"] = c.ewma_alpha * d_delay \
                + (1 - c.ewma_alpha) * ew["delays"]
            if i in snap["dispatched"] \
                    and l.heartbeats == snap["beats"].get(i, 0) \
                    and l.error is None:
                self._missed[i] = self._missed.get(i, 0) + 1
            else:
                self._missed[i] = 0
            reason = None
            # >= so a single fault event per round (EWMA alpha*1 ==
            # the default limit) is already detectable
            if ew["stalls"] >= c.stall_limit:
                reason = "stall_ewma"
            elif ew["delays"] >= c.delay_limit:
                reason = "delay_ewma"
            elif self._missed.get(i, 0) >= c.heartbeat_rounds:
                reason = "heartbeat"
            if reason is not None:
                self._quarantine(l, reason,
                                 round(ew["stalls"], 9),
                                 round(ew["delays"], 9))

    def _quarantine(self, loop: EventLoop, reason: str,
                    ew_stalls: float, ew_delays: float) -> None:
        """Health-driven quarantine-and-restart: migrate the loop's
        queued requests to survivors, give it a fresh poller; a loop
        needing this more than ``max_restarts`` times shrinks the fleet
        (the elastic eviction — channels migrate via the minimal
        reshard)."""
        t0 = time.perf_counter()
        items = list(loop.queue)
        loop.queue.clear()
        self._restarts[loop.index] = self._restarts.get(loop.index, 0) + 1
        self._action("quarantine", loop.index,
                     (reason, ew_stalls, ew_delays, len(items)), t0)
        loop.restart()
        self._restarted_this_round.add(loop.index)
        self._action("restart", loop.index, (), t0)
        self._reset_health(loop.index)
        survivors = [x for x in self._group.loops if x is not loop]
        for j, it in enumerate(items):
            (survivors[j % len(survivors)] if survivors else loop).submit(it)
        if self._restarts[loop.index] > self.config.max_restarts \
                and self._group.n_loops > max(1, self.config.min_loops):
            self._apply_resize(self._group.n_loops - 1, "unhealthy_loop")

    # -- elasticity ----------------------------------------------------

    def _max_loops(self) -> int:
        cap = self.config.max_loops or self.serve.comm.channels
        return min(cap, self.serve.comm.channels)

    def _apply_external_resize(self) -> None:
        if self._resize_request is None:
            return
        n, self._resize_request = self._resize_request, None
        self._apply_resize(n, "requested")

    def _autoscale(self) -> None:
        c = self.config
        if self._cooldown > 0:
            self._cooldown -= 1
            return
        n = self._group.n_loops
        depth = len(self.queue) / n
        if self.queue and depth >= c.scale_up_depth \
                and n < self._max_loops():
            self._votes = self._votes + 1 if self._votes > 0 else 1
        elif c.scale_down_depth >= 0 and depth <= c.scale_down_depth \
                and n > c.min_loops:
            self._votes = self._votes - 1 if self._votes < 0 else -1
        else:
            self._votes = 0
            return
        if self._votes >= c.hysteresis:
            self._votes = 0
            self._cooldown = c.cooldown_rounds
            self._apply_resize(n + 1, "queue_depth")
        elif self._votes <= -c.hysteresis:
            self._votes = 0
            self._cooldown = c.cooldown_rounds
            self._apply_resize(n - 1, "drain_idle")

    def _apply_resize(self, new_loops: int, reason: str) -> None:
        """Grow/shrink the fleet at a round (flush) boundary: re-derive
        the ServeConfig, reshard channel affinity with MINIMAL migration,
        rebuild the group with the explicit resharded partition, carry
        undrained items over. Served tokens are invariant to the resize
        (affinity changes emission structure, never logits)."""
        c = self.config
        new_loops = max(c.min_loops, min(int(new_loops), self._max_loops()))
        g = self._group
        if g is None or new_loops == g.n_loops:
            return
        t0 = time.perf_counter()
        old_n = g.n_loops
        old_aff = tuple(l.channels for l in g.loops)
        carry = [it for l in g.loops for it in list(l.queue)]
        for l in g.loops:
            l.queue.clear()
        self._poll_accum = self._poll_accum.merge(g.poll_stats())
        self.serve = reshard_event_loops(self.serve, new_loops)
        kwargs = {}
        if self.serve.pods > 1 and self.serve.comm.hierarchical:
            kwargs = dict(
                n_pods=self.serve.pods,
                leaders=min(self.serve.comm.leader_channels,
                            self.serve.comm.channels - 1),
                leader_loops=self.serve.leader_loops)
        new_aff, moved = reshard_affinity(
            self.serve.comm.channels, old_aff, new_loops, **kwargs)
        self._affinity = new_aff
        self._build_group()
        if carry:
            self._group.submit(carry)
        self._ewma.clear()
        self._missed.clear()
        self._action("resize", new_loops, (old_n, moved, reason), t0)

    # -- reporting -----------------------------------------------------

    def healing_trace(self) -> tuple:
        """The canonical, seed-deterministic trace: every action minus
        its wall-clock stamps. Same seed + same ChaosPlan ⇒ equal
        traces across runs — the replayability contract tests assert."""
        return tuple((a.round, a.kind, a.target, a.detail)
                     for a in self.trace)

    def mttr_spans(self) -> tuple:
        return tuple(a.span_s for a in self.trace)

    def mttr_s(self) -> Optional[float]:
        return slo.mttr(self.mttr_spans())

    def poll_stats(self) -> PollStats:
        st = self._poll_accum
        if self._group is not None:
            st = st.merge(self._group.poll_stats())
        return st
