"""Batched decode engine: KV-cache manager + request batcher + sampler.

The netty analogy carries over (DESIGN.md §2): requests are connections,
the engine's fixed-size decode batch is the worker pool, and admission is
round-robin like the paper's benchmark assigns connections to selectors.

Mechanics:

* Attention-family archs: prompts are **right-padded** to the bucket
  length and tracked with per-request ``pos`` vectors — pad slots are
  never attended (validity mask ``j <= pos``) and the first generated
  token overwrites the first pad slot, so mixed-length batches are exact.
* Recurrent archs (ssm / hybrid): the recurrence would absorb pad tokens,
  so the batcher groups requests into *equal-length* buckets (exact, no
  pads) — noted limitation vs. paged attention, acceptable at this scope.
* Sampling: greedy or temperature; stop on ``eos_id`` or ``max_new``.
"""
from __future__ import annotations

import dataclasses
from collections import defaultdict
from typing import Any, Callable, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, RunConfig
from repro.models import api
from repro.models.layers import no_shard

PyTree = Any


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray            # (len,) int32
    max_new: int = 32
    temperature: float = 0.0      # 0 -> greedy

    def __post_init__(self):
        self.prompt = np.asarray(self.prompt, np.int32)


@dataclasses.dataclass
class Result:
    uid: int
    tokens: np.ndarray            # generated tokens (<= max_new)
    prompt_len: int
    steps: int


class DecodeEngine:
    """Synchronous batched engine around prefill/decode_step.

    ``max_batch`` bounds the decode batch; ``max_len`` bounds prompt+gen
    length (the KV-cache allocation).
    """

    def __init__(self, cfg: ModelConfig, params: PyTree, *,
                 max_batch: int = 8, max_len: int = 256,
                 eos_id: Optional[int] = None, shard_fn=no_shard,
                 rng: Optional[jax.Array] = None):
        self.cfg = cfg
        self.params = params
        self.max_batch = max_batch
        self.max_len = max_len
        self.eos_id = eos_id
        self.shard_fn = shard_fn
        self.rng = rng if rng is not None else jax.random.PRNGKey(0)
        self._recurrent = cfg.family in ("ssm", "hybrid")

        self._prefill = jax.jit(
            lambda p, b: api.prefill(p, b, cfg, shard_fn))
        self._decode = jax.jit(
            lambda p, c, b: api.decode_step(p, c, b, cfg, shard_fn))

    # -- batching ------------------------------------------------------

    def _buckets(self, reqs: Sequence[Request]) -> list[list[Request]]:
        """Split requests into decode batches (round-robin admission).
        Recurrent archs additionally bucket by exact prompt length."""
        groups = defaultdict(list)
        for r in reqs:
            key = len(r.prompt) if self._recurrent else 0
            groups[key].append(r)
        out = []
        for _, rs in sorted(groups.items()):
            for i in range(0, len(rs), self.max_batch):
                out.append(rs[i:i + self.max_batch])
        return out

    # -- sampling ------------------------------------------------------

    def _sample(self, logits: jax.Array, temps: np.ndarray) -> jax.Array:
        self.rng, k = jax.random.split(self.rng)
        greedy = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        t = jnp.asarray(np.maximum(temps, 1e-6), jnp.float32)
        sampled = jax.random.categorical(k, logits / t[:, None], axis=-1)
        return jnp.where(jnp.asarray(temps) > 0.0,
                         sampled.astype(jnp.int32), greedy)

    # -- main entry ----------------------------------------------------

    def generate(self, reqs: Sequence[Request]) -> list[Result]:
        results: list[Result] = []
        for bucket in self._buckets(reqs):
            results.extend(self._run_bucket(bucket))
        results.sort(key=lambda r: r.uid)
        return results

    def _run_bucket(self, bucket: list[Request]) -> list[Result]:
        b = len(bucket)
        lens = np.array([len(r.prompt) for r in bucket], np.int32)
        pad_to = int(lens.max())
        assert pad_to + max(r.max_new for r in bucket) <= self.max_len, \
            "prompt + max_new exceeds engine max_len"
        toks = np.zeros((b, pad_to), np.int32)
        for i, r in enumerate(bucket):
            toks[i, : lens[i]] = r.prompt

        batch = {"tokens": jnp.asarray(toks)}
        if not self._recurrent:
            batch["last_pos"] = jnp.asarray(lens - 1)
        if self.cfg.family == "vlm":
            batch["patches"] = jnp.zeros(
                (b, self.cfg.num_patches, self.cfg.d_model),
                jnp.dtype(self.cfg.compute_dtype))
        if self.cfg.family == "encdec":
            batch["frames"] = jnp.zeros(
                (b, self.cfg.num_frames, self.cfg.d_model),
                jnp.dtype(self.cfg.compute_dtype))

        logits, cache = self._prefill(self.params, batch)
        cache = self._grow_cache(cache, b)

        temps = np.array([r.temperature for r in bucket], np.float32)
        max_new = max(r.max_new for r in bucket)
        pos = jnp.asarray(lens)           # next write slot per request
        out = np.full((b, max_new), -1, np.int64)
        done = np.zeros((b,), bool)
        tok = self._sample(logits, temps)
        steps = 0
        for t in range(max_new):
            tok_np = np.asarray(tok)
            for i, r in enumerate(bucket):
                if not done[i] and t < r.max_new:
                    out[i, t] = tok_np[i]
                    if self.eos_id is not None and tok_np[i] == self.eos_id:
                        done[i] = True
                elif t >= r.max_new:
                    done[i] = True
            steps += 1
            if done.all() or t == max_new - 1:
                break
            dec = {"token": tok, "pos": pos}
            logits, cache = self._decode(self.params, cache, dec)
            tok = self._sample(logits, temps)
            pos = pos + 1

        results = []
        for i, r in enumerate(bucket):
            gen = out[i][out[i] >= 0][: r.max_new]
            results.append(Result(uid=r.uid, tokens=gen.astype(np.int64),
                                  prompt_len=int(lens[i]), steps=steps))
        return results

    # -- cache management ----------------------------------------------

    def _grow_cache(self, cache: PyTree, b: int) -> PyTree:
        """Prefill caches are prompt-sized; decode needs max_len slots."""
        return api.grow_cache(self.cfg, cache, self.max_len)
