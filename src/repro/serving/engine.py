"""Batched decode engine: KV-cache manager + request batcher + sampler.

The netty analogy carries over (DESIGN.md §2): requests are connections,
the engine's fixed-size decode batch is the worker pool, and admission is
round-robin like the paper's benchmark assigns connections to selectors.

Mechanics:

* Attention-family archs: prompts are **right-padded** to the bucket
  length and tracked with per-request ``pos`` vectors — pad slots are
  never attended (validity mask ``j <= pos``) and the first generated
  token overwrites the first pad slot, so mixed-length batches are exact.
* Recurrent archs (ssm / hybrid): the recurrence would absorb pad tokens,
  so the batcher groups requests into *equal-length* buckets (exact, no
  pads) — noted limitation vs. paged attention, acceptable at this scope.
* Sampling: greedy or temperature; stop on ``eos_id`` or ``max_new``.
* **Continuous batching**: the engine runs ``max_batch`` decode SLOTS.
  When a request finishes, its slot is freed and the next request from
  the run queue is admitted AT THE FLUSH BOUNDARY (the decode-step
  boundary where the staged emission's channel flushes have completed
  and been polled): it is prefilled solo (exactness is per-row, so solo
  and batched prefill agree bit-for-bit), its cache rows are written
  into the freed slot, and it decodes alongside the residents.
* **Serving through the comm stack**: constructed with a
  :class:`~repro.configs.base.ServeConfig`, the engine's prefill/decode
  steps come from ``serving/dispatch.py`` — KV gathering writes and
  tensor-parallel logit reductions flow through the registered
  CommBackend wire (staged emission API), honoring the owning event
  loop's channel affinity. Completion waits go through the loop's
  :class:`~repro.serving.event_loop.Poller` (busy/park/adaptive).
"""
from __future__ import annotations

import dataclasses
from collections import defaultdict, deque
from typing import Any, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, ServeConfig
from repro.models import api
from repro.obs import trace as obs_trace
from repro.models.layers import no_shard
from repro.serving import dispatch
from repro.serving.event_loop import (EventLoop, EventLoopGroup, Poller,
                                      channel_affinity)

PyTree = Any

ADMIT_PAD = 16      # solo-prefill prompts pad to this granularity, so
#                     continuous admission compiles O(max_len/16) shapes


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray            # (len,) int32
    max_new: int = 32
    temperature: float = 0.0      # 0 -> greedy
    priority: int = 0             # admission-control rank: LOWER sheds
    #                               first under backpressure (supervisor)
    tenant: str = ""              # multi-tenant routing key: names a
    #                               ServeConfig.tenants entry ("" rides
    #                               the first tenant; single-tenant
    #                               groups ignore it)

    def __post_init__(self):
        self.prompt = np.asarray(self.prompt, np.int32)


@dataclasses.dataclass
class Result:
    uid: int
    tokens: np.ndarray            # generated tokens (<= max_new)
    prompt_len: int
    steps: int


@dataclasses.dataclass
class _Slot:
    """One in-flight request occupying a decode slot."""
    req: Request
    admitted_step: int
    toks: list
    done: bool = False


class DecodeEngine:
    """Synchronous batched engine around prefill/decode_step.

    ``max_batch`` bounds the decode slots; ``max_len`` bounds prompt+gen
    length (the KV-cache allocation). With ``serve`` set, the steps are
    built by :func:`repro.serving.dispatch.make_serve_step` and every
    serving collective flows through ``serve.comm``'s backend wire;
    ``channel_indices`` is the owning event loop's channel affinity.
    """

    def __init__(self, cfg: ModelConfig, params: PyTree, *,
                 max_batch: int = 8, max_len: int = 256,
                 eos_id: Optional[int] = None, shard_fn=no_shard,
                 rng: Optional[jax.Array] = None,
                 serve: Optional[ServeConfig] = None, mesh=None,
                 channel_indices: Optional[tuple] = None,
                 poller: Optional[Poller] = None):
        self.cfg = cfg
        self.params = params
        self.max_batch = max_batch
        self.max_len = max_len
        self.eos_id = eos_id
        self.shard_fn = shard_fn
        self.rng = rng if rng is not None else jax.random.PRNGKey(0)
        self.serve = serve
        self._recurrent = cfg.family in ("ssm", "hybrid")
        self.poller = poller or Poller(
            serve.poll if serve else "park",
            serve.spin_us * 1e-6 if serve else 50e-6)
        # chaos seam: the flush-boundary fault window. Called as
        # hook(engine, step) at every decode-step boundary (after the
        # poll, before queue admission); returned Requests join the run
        # queue and contend for freed slots — the admission-storm
        # injection point (serving/chaos.py). None = no-op.
        self.admission_hook = None
        # admission-control seam (serving/supervisor.py): called as
        # gate(engine, step, extra) with the hook-injected burst; returns
        # the requests actually admitted to the run queue. Distinct from
        # admission_hook so supervisor backpressure composes with chaos
        # storms instead of clobbering them. None = admit everything.
        self.admission_gate = None
        self.admit_prefills = 0   # batched-admission prefill calls (one
        #                           per flush boundary with freed slots,
        #                           NOT one per admitted request)

        if serve is not None:
            self.step = dispatch.make_serve_step(
                cfg, serve.comm, mesh, channel_indices=channel_indices,
                pod_axis=serve.pod_axis if serve.pods > 1 else None)
            self._prefill = self.step.prefill
            self._decode = self.step.decode
            self.n_shards = self.step.n_shards
        else:
            self.step = None
            self.n_shards = 1
            self._prefill = jax.jit(
                lambda p, b: api.prefill(p, b, cfg, shard_fn))
            self._decode = jax.jit(
                lambda p, c, b: api.decode_step(p, c, b, cfg, shard_fn))

    # -- batching ------------------------------------------------------

    def _buckets(self, reqs: Sequence[Request]) -> list:
        """Recurrent archs bucket by exact prompt length (no pads)."""
        groups = defaultdict(list)
        for r in reqs:
            groups[len(r.prompt)].append(r)
        out = []
        for _, rs in sorted(groups.items()):
            for i in range(0, len(rs), self.max_batch):
                out.append(rs[i:i + self.max_batch])
        return out

    # -- sampling ------------------------------------------------------

    def _sample(self, logits: jax.Array, temps: np.ndarray) -> jax.Array:
        self.rng, k = jax.random.split(self.rng)
        greedy = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        t = jnp.asarray(np.maximum(temps, 1e-6), jnp.float32)
        sampled = jax.random.categorical(k, logits / t[:, None], axis=-1)
        return jnp.where(jnp.asarray(temps) > 0.0,
                         sampled.astype(jnp.int32), greedy)

    # -- main entry ----------------------------------------------------

    def generate(self, reqs: Sequence[Request]) -> list:
        reqs = list(reqs)
        results: list = []
        if self._recurrent:
            # equal-length buckets; no mid-flight admission (the
            # recurrence has no pad-exactness to admit against)
            for bucket in self._buckets(reqs):
                results.extend(self._run_wave(bucket, deque()))
        elif reqs:
            initial = reqs[: self.max_batch]
            pending = deque(reqs[self.max_batch:])   # the run queue
            results.extend(self._run_wave(initial, pending))
        results.sort(key=lambda r: r.uid)
        return results

    # -- batch assembly ------------------------------------------------

    def _prefill_batch(self, toks: np.ndarray, lens: np.ndarray) -> dict:
        b = toks.shape[0]
        batch = {"tokens": jnp.asarray(toks)}
        if not self._recurrent:
            batch["last_pos"] = jnp.asarray(np.maximum(lens - 1, 0))
        if self.cfg.family == "vlm":
            batch["patches"] = jnp.zeros(
                (b, self.cfg.num_patches, self.cfg.d_model),
                jnp.dtype(self.cfg.compute_dtype))
        if self.cfg.family == "encdec":
            batch["frames"] = jnp.zeros(
                (b, self.cfg.num_frames, self.cfg.d_model),
                jnp.dtype(self.cfg.compute_dtype))
        return batch

    # -- the slot loop -------------------------------------------------

    def _run_wave(self, initial: list, pending: deque) -> list:
        b = len(initial)
        R = self.n_shards
        b_pad = max(R, -(-b // R) * R)    # rows padded to the ring size
        lens = np.zeros((b_pad,), np.int32)
        for i, r in enumerate(initial):
            lens[i] = len(r.prompt)
        pad_to = int(lens.max())
        assert pad_to + max(r.max_new for r in initial) <= self.max_len, \
            "prompt + max_new exceeds engine max_len"
        toks = np.zeros((b_pad, pad_to), np.int32)
        for i, r in enumerate(initial):
            toks[i, : lens[i]] = r.prompt

        if obs_trace.enabled():
            with obs_trace.span("prefill", f"wave_b{b}", batch=b,
                                pad_to=pad_to):
                logits, cache = self._prefill(
                    self.params, self._prefill_batch(toks, lens))
                self.poller.wait(logits)
        else:
            logits, cache = self._prefill(self.params,
                                          self._prefill_batch(toks, lens))
            self.poller.wait(logits)
        cache = api.grow_cache(self.cfg, cache, self.max_len)

        slots: list = [_Slot(r, 0, []) for r in initial] \
            + [None] * (b_pad - b)
        temps = np.zeros((b_pad,), np.float32)
        for i, r in enumerate(initial):
            temps[i] = r.temperature
        pos = jnp.asarray(lens)           # next write slot per request
        tok = self._sample(logits, temps)
        steps = 0
        results: list = []

        while True:
            # flush boundary: the staged emission's channel flushes for
            # this step are complete once the sampled tokens are ready
            self.poller.wait(tok)
            tok_np = np.asarray(tok)
            for i, s in enumerate(slots):
                if s is None:
                    continue
                if s.req.max_new > 0:    # max_new=0: prefill-only, no token
                    s.toks.append(int(tok_np[i]))
                    if self.eos_id is not None \
                            and s.toks[-1] == self.eos_id:
                        s.done = True
                if len(s.toks) >= s.req.max_new:
                    s.done = True
                if s.done:
                    results.append(Result(
                        uid=s.req.uid,
                        tokens=np.asarray(s.toks, np.int64),
                        prompt_len=len(s.req.prompt),
                        steps=steps + 1 - s.admitted_step))
                    slots[i] = None
            steps += 1
            # the flush-boundary fault window: storm requests injected
            # here enter the run queue like any client's and are admitted
            # (or queued) by the very same admission path below — per-row
            # exactness keeps the residents' tokens bit-identical. The
            # gate sees the burst AFTER the hook, so supervisor
            # backpressure composes with chaos storms.
            if not self._recurrent and (self.admission_hook is not None
                                        or self.admission_gate is not None):
                extra = list(self.admission_hook(self, steps) or []) \
                    if self.admission_hook is not None else []
                if self.admission_gate is not None:
                    extra = self.admission_gate(self, steps, extra)
                if extra:
                    pending.extend(extra)
            # continuous batching: admit from the run queue into freed
            # slots, at this flush boundary — ONE batched prefill over
            # every freed slot (solo == batched bit-for-bit; exactness is
            # per-row). Only the first max_batch slots are
            # admission-eligible — ring-padding rows beyond the
            # configured bound carry no requests (max_batch stays the
            # true per-loop in-flight limit even when b_pad > max_batch).
            if pending and not self._recurrent:
                tok, cache, pos = self._admit_ready(
                    pending, cache, pos, temps, tok, steps, slots, results)
            if not any(s is not None for s in slots) and not pending:
                break
            active = np.array([s is not None for s in slots])
            dec = {"token": tok, "pos": pos}
            if obs_trace.enabled():
                with obs_trace.span("decode", f"step{steps}", step=steps,
                                    active=int(active.sum())):
                    logits, cache = self._decode(self.params, cache, dec)
            else:
                logits, cache = self._decode(self.params, cache, dec)
            tok = self._sample(logits, temps)
            pos = jnp.where(jnp.asarray(active), pos + 1, pos)
        return results

    def _admit_ready(self, pending: deque, cache: PyTree, pos: jax.Array,
                     temps: np.ndarray, tok: jax.Array, steps: int,
                     slots: list, results: list):
        """Admit from the run queue into EVERY freed slot at this flush
        boundary with one batched prefill per round (the ROADMAP's
        batched admission — replacing the one-prefill-per-slot solo
        path). Loops because a request finishing AT admission
        (eos / max_new<=1) leaves its slot free for the next queued
        request within the same boundary; each round pops at least one
        request, so it terminates."""
        while pending:
            free = [i for i in range(min(len(slots), self.max_batch))
                    if slots[i] is None]
            if not free:
                break
            take = min(len(free), len(pending))
            batch = [pending.popleft() for _ in range(take)]
            if obs_trace.enabled():
                with obs_trace.span("admission", f"admit{take}", n=take,
                                    step=steps):
                    tok, cache, pos = self._admit_batch(
                        free[:take], batch, cache, pos, temps, tok,
                        steps, slots, results)
            else:
                tok, cache, pos = self._admit_batch(
                    free[:take], batch, cache, pos, temps, tok, steps,
                    slots, results)
        return tok, cache, pos

    def _admit_batch(self, free: list, reqs: list, cache: PyTree,
                     pos: jax.Array, temps: np.ndarray, tok: jax.Array,
                     steps: int, slots: list, results: list):
        """Admit ``reqs[j]`` into freed slot ``free[j]`` with ONE prefill
        over the whole batch (rows padded to the ring size; prompts
        right-padded to the batch max — exactness is per-row, so this is
        bit-identical to solo admission). Each request's first token is
        sampled from its own prefill logits AND recorded immediately (it
        is the request's first generated token — the main loop's append
        phase has already run this step, and the next one records the
        token sampled AFTER it). A request done at its first token (eos,
        or max_new == 1) finishes here and leaves the slot free. Mutates
        ``temps`` / ``slots`` / ``results`` in place; returns the new
        (tok, cache, pos)."""
        R = self.n_shards
        k = len(reqs)
        rows = max(R, -(-k // R) * R)     # rows padded to the ring size
        for req in reqs:
            assert len(req.prompt) + req.max_new <= self.max_len, \
                "prompt + max_new exceeds engine max_len"
        # round for bounded recompiles, but never past the resident
        # cache's sequence capacity (max_len, or the rolling window) —
        # an over-rounded prefill cache could not fit the slot write
        limit = self.max_len
        if self.cfg.sliding_window:
            limit = min(limit, self.cfg.sliding_window)
        pmax = max(len(req.prompt) for req in reqs)
        pad_to = min(-(-pmax // ADMIT_PAD) * ADMIT_PAD, max(pmax, limit))
        toks = np.zeros((rows, pad_to), np.int32)
        lens = np.zeros((rows,), np.int32)
        rtemps = np.zeros((rows,), np.float32)
        for row, req in enumerate(reqs):
            plen = len(req.prompt)
            toks[row, :plen] = req.prompt
            lens[row] = plen
            rtemps[row] = req.temperature
        logits1, cache1 = self._prefill(self.params,
                                        self._prefill_batch(toks, lens))
        self.poller.wait(logits1)
        self.admit_prefills += 1
        t_arr = self._sample(logits1, rtemps)
        t_np = np.asarray(t_arr)
        live_rows: list = []
        live_slots: list = []
        for row, (i, req) in enumerate(zip(free, reqs)):
            t0 = int(t_np[row])
            plen = int(lens[row])
            if req.max_new <= 0:          # prefill-only: zero tokens
                results.append(Result(uid=req.uid,
                                      tokens=np.asarray([], np.int64),
                                      prompt_len=plen, steps=0))
                continue
            if (self.eos_id is not None and t0 == self.eos_id) \
                    or req.max_new == 1:  # finished at its first token
                results.append(Result(uid=req.uid,
                                      tokens=np.asarray([t0], np.int64),
                                      prompt_len=plen, steps=1))
                continue
            live_rows.append(row)
            live_slots.append(i)
            temps[i] = req.temperature
            slots[i] = _Slot(req, steps, [t0])
        if live_rows:
            cache1 = api.grow_cache(self.cfg, cache1, self.max_len)
            rsel = np.asarray(live_rows)
            ssel = np.asarray(live_slots)
            # attention caches carry batch at axis 1 (L, B, S, KV, Dh)
            cache = jax.tree.map(lambda c, n: c.at[:, ssel].set(n[:, rsel]),
                                 cache, cache1)
            tok = tok.at[ssel].set(t_arr[rsel])
            pos = pos.at[ssel].set(jnp.asarray(lens[rsel]))
        return tok, cache, pos


# ---------------------------------------------------------------------------
# Event-loop glue: one engine per loop, channel affinity baked in
# ---------------------------------------------------------------------------


def make_engine_group(cfg: ModelConfig, params: PyTree, serve: ServeConfig,
                      *, mesh=None, eos_id: Optional[int] = None,
                      seed: int = 0,
                      affinity: Optional[tuple] = None) -> EventLoopGroup:
    """The serving subsystem's front door: an
    :class:`~repro.serving.event_loop.EventLoopGroup` of
    ``serve.event_loops`` loops, each owning a disjoint contiguous run of
    the ``serve.comm.channels`` pool (``channel_affinity``) and driving
    its OWN :class:`DecodeEngine` whose serve step emits only on those
    channels. Requests submitted to the group are assigned round-robin;
    results merge by uid. GREEDY outputs are bit-identical for any
    ``event_loops`` (the affinity changes emission structure, never
    logits — conformance-tested); temperature>0 requests draw from each
    engine's own PRNG stream, so sampled tokens legitimately vary with
    the loop assignment.

    With ``serve.pods > 1`` the group is TOPOLOGY-AWARE: the default
    mesh becomes the two-level ``(pod_axis, "data")`` fabric
    (``launch/mesh.make_serve_mesh``), and — when ``comm.hierarchical``
    keeps pod-aware collectives on — the affinity pins the pool's
    leader lanes to the first ``serve.leader_loops`` loops while each
    remaining loop owns only local lanes whose peers share a pod
    (``channel_affinity`` topology form).

    ``affinity`` overrides the computed partition with an explicit one
    (validated disjoint + covering + nonempty per loop) — the elastic
    reshard path (``launch/elastic.reshard_affinity`` keeps migrations
    minimal, so the resharded partition is deliberately NOT what
    ``channel_affinity`` would recompute) and the supervisor's rebuilds
    both use it.

    MULTI-TENANT form: with ``serve.tenants`` set, the loops are carved
    into contiguous per-tenant ranges in declaration order (tenant 0
    owns loops ``0..e0-1``, tenant 1 the next ``e1``, …) so channel
    ownership stays disjoint per tenant, and ``cfg`` / ``params`` may
    EACH be either a single value (every tenant serves the same model)
    or a dict keyed by tenant name (heterogeneous families side by
    side — one group, one channel pool, different engines per range).
    The group then routes ``Request.tenant`` to the owning range with
    deterministic weighted-fair scheduling (``EventLoopGroup``
    docstring; docs/FAMILIES.md §Tenants and fairness)."""
    if serve.pods > 1 and mesh is None:
        from repro.launch.mesh import make_serve_mesh
        mesh = make_serve_mesh(serve.pods, serve.pod_axis)
    if affinity is not None:
        affinity = tuple(tuple(g) for g in affinity)
        owned = sorted(c for g in affinity for c in g)
        if len(affinity) != serve.event_loops \
                or owned != list(range(serve.comm.channels)) \
                or any(not g for g in affinity):
            raise ValueError(
                f"explicit affinity {affinity} must partition channels "
                f"0..{serve.comm.channels - 1} into {serve.event_loops} "
                "nonempty disjoint groups")
    elif serve.pods > 1 and serve.comm.hierarchical:
        affinity = channel_affinity(
            serve.comm.channels, serve.event_loops, n_pods=serve.pods,
            leaders=min(serve.comm.leader_channels,
                        serve.comm.channels - 1),
            leader_loops=serve.leader_loops)
    else:
        affinity = channel_affinity(serve.comm.channels, serve.event_loops)
    bindings = []
    loop_tenant = {}
    start = 0
    for t in serve.tenants:
        ix = tuple(range(start, start + t.event_loops))
        bindings.append((t.name, t.weight, ix))
        for i in ix:
            loop_tenant[i] = t.name
        start += t.event_loops

    names = {t.name for t in serve.tenants}

    def resolve(v, what, always_dict=False):
        # params is a pytree that is ITSELF a dict, so a dict is treated
        # as per-tenant only when its keys touch the tenant names
        per_tenant = isinstance(v, dict) and (
            always_dict or (names and set(v) & names))
        if not per_tenant:
            return (lambda _name: v)
        if not names:
            raise ValueError(
                f"{what} is a per-tenant dict but serve.tenants is empty: "
                "heterogeneous groups need named tenants to route by")
        if set(v) != names:
            raise ValueError(
                f"{what} keys {sorted(v)} must match the tenant names "
                f"{sorted(names)} exactly (one model binding per tenant)")
        return v.__getitem__

    cfg_of = resolve(cfg, "cfg", always_dict=True)
    params_of = resolve(params, "params")
    loops = []
    for i, chans in enumerate(affinity):
        name = loop_tenant.get(i, "")
        loop = EventLoop(i, channels=chans, poll=serve.poll,
                         spin_s=serve.spin_us * 1e-6)
        eng = DecodeEngine(cfg_of(name), params_of(name),
                           max_batch=serve.max_batch,
                           max_len=serve.max_len, eos_id=eos_id,
                           rng=jax.random.PRNGKey(seed + i), serve=serve,
                           mesh=mesh, channel_indices=chans,
                           poller=loop.poller)
        loop.engine = eng
        loop.runner = lambda _loop, items, eng=eng: eng.generate(items)
        loops.append(loop)
    return EventLoopGroup(loops, tenants=bindings or None)
