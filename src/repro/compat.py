"""Version-adaptive JAX shims.

The repo targets the current jax API (``jax.shard_map``, ``jax.set_mesh``,
``jax.sharding.AxisType``); the pinned environment may carry an older
release where those live under different names (or don't exist). Every
call site goes through this module so the drift is handled exactly once.

Nothing here changes semantics: on a new-enough jax each shim is a
pass-through to the public API.
"""
from __future__ import annotations

import contextlib

import jax

try:                                    # jax >= 0.5
    from jax.sharding import AxisType
except ImportError:                     # older jax: meshes are untyped
    AxisType = None


def make_mesh(shape: tuple, axes: tuple):
    """``jax.make_mesh`` with Auto axis types when the API supports them."""
    if AxisType is None:
        return jax.make_mesh(shape, axes)
    return jax.make_mesh(shape, axes,
                         axis_types=(AxisType.Auto,) * len(axes))


def abstract_mesh(shape: tuple, axes: tuple):
    """``AbstractMesh`` across the signature change: new jax takes
    (axis_sizes, axis_names); old jax takes one tuple of (name, size)."""
    from jax.sharding import AbstractMesh
    try:
        return AbstractMesh(shape, axes)
    except TypeError:
        return AbstractMesh(tuple(zip(axes, shape)))


def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool = False):
    """``jax.shard_map`` (new) / ``jax.experimental.shard_map`` (old).
    ``check_vma`` maps onto the old API's ``check_rep``."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=check_vma)
    from jax.experimental.shard_map import shard_map as _sm
    return _sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
               check_rep=check_vma)


def tpu_compiler_params(**kwargs):
    """Pallas-TPU compiler params across the ``TPUCompilerParams`` ->
    ``CompilerParams`` rename."""
    from jax.experimental.pallas import tpu as pltpu
    cls = getattr(pltpu, "CompilerParams", None) \
        or getattr(pltpu, "TPUCompilerParams")
    return cls(**kwargs)


def pallas_available() -> bool:
    """True when ``jax.experimental.pallas`` is importable (and, on TPU,
    its compiler-params class resolves). The comm pack stage
    (``comm.pack="pallas"``) falls back to the jnp path when this is
    False, so a CPU-only or pallas-less environment still runs every
    backend."""
    try:
        from jax.experimental import pallas  # noqa: F401
    except Exception:
        return False
    if jax.default_backend() == "tpu":
        try:
            tpu_compiler_params()
        except Exception:
            return False
    return True


def set_mesh(mesh):
    """``jax.set_mesh`` context. Old jax has no sharding-in-types mesh
    context; entering the ``Mesh`` itself provides the legacy global-mesh
    scope, which is all pre-0.5 code paths consult."""
    if hasattr(jax, "set_mesh"):
        return jax.set_mesh(mesh)
    if hasattr(mesh, "__enter__"):
        return mesh
    return contextlib.nullcontext(mesh)
