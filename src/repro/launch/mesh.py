"""Production mesh construction.

A FUNCTION (not a module-level constant) so importing this module never
touches jax device state — required for the dry-run's forced host device
count to take effect first.
"""
from __future__ import annotations

import jax
from jax.sharding import AxisType


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; 2 pods = 512 chips multi-pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes,
                         axis_types=(AxisType.Auto,) * len(axes))


def make_mesh(shape: tuple, axes: tuple):
    """Arbitrary mesh for tests/benchmarks (host devices or real)."""
    return jax.make_mesh(shape, axes,
                         axis_types=(AxisType.Auto,) * len(axes))


def data_axes(mesh) -> tuple:
    """The DP axes of a mesh (everything that is not 'model')."""
    return tuple(a for a in mesh.axis_names if a != "model")


def axis_size(mesh, name: str) -> int:
    return mesh.shape[name] if name in mesh.axis_names else 1
