"""Production mesh construction.

A FUNCTION (not a module-level constant) so importing this module never
touches jax device state — required for the dry-run's forced host device
count to take effect first.

``AxisType`` moved under ``jax.sharding`` in newer jax; the guarded import
lives in :mod:`repro.compat` so a pinned older release still collects.
"""
from __future__ import annotations

from repro import compat
from repro.compat import AxisType  # noqa: F401  (re-export, may be None)


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; 2 pods = 512 chips multi-pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return compat.make_mesh(shape, axes)


def make_mesh(shape: tuple, axes: tuple):
    """Arbitrary mesh for tests/benchmarks (host devices or real)."""
    return compat.make_mesh(shape, axes)


def make_serve_mesh(pods: int = 1, pod_axis: str = "pod", devices=None):
    """The serving fabric's mesh: a flat DP ring at ``pods=1``, a
    two-level ``(pod_axis, "data")`` topology otherwise — the shape
    ``ServeConfig.pods`` / ``--pods`` flows into
    ``serving/dispatch.make_serve_step`` (pod-aware leader emission) and
    ``serving/event_loop.channel_affinity`` (topology-aware loop
    ownership). ``devices`` defaults to every visible device; ``pods``
    must divide the count (the pod is a physical partition, not a
    round-robin)."""
    import jax
    n = len(devices if devices is not None else jax.devices())
    if pods < 1:
        raise ValueError(f"pods must be >= 1, got {pods}")
    if n % pods != 0:
        raise ValueError(
            f"pods={pods} does not divide the device count {n}; a pod is "
            "a physical partition of the fabric — pick a pod count that "
            f"divides {n} (divisors: "
            f"{[d for d in range(1, n + 1) if n % d == 0]})")
    if pods == 1:
        return compat.make_mesh((n,), ("data",))
    return compat.make_mesh((pods, n // pods), (pod_axis, "data"))


def make_abstract_mesh(shape: tuple, axes: tuple):
    """Device-free mesh for sharding-rule tests (signature-drift safe)."""
    return compat.abstract_mesh(shape, axes)


def data_axes(mesh) -> tuple:
    """The DP axes of a mesh (everything that is not 'model')."""
    return tuple(a for a in mesh.axis_names if a != "model")


def axis_size(mesh, name: str) -> int:
    return mesh.shape[name] if name in mesh.axis_names else 1
