import os
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=512 "
                           + os.environ.get("XLA_FLAGS", ""))
# The two lines above MUST run before any other import (jax locks the
# device count on first init). Everything below is ordinary code.
"""Multi-pod dry-run driver (brief: MULTI-POD DRY-RUN).

For each (architecture × input shape × mesh) cell: build the step function,
``jax.jit(...).lower(**abstract inputs)``, ``.compile()``, and record
memory/cost/collective analysis into a JSON artifact. No arrays are ever
allocated — state and inputs are ShapeDtypeStructs.

Usage:
  python -m repro.launch.dryrun --arch qwen2-0.5b --shape train_4k
  python -m repro.launch.dryrun --all                 # every runnable cell
  python -m repro.launch.dryrun --all --mesh multipod # 2 pods = 512 chips
  python -m repro.launch.dryrun --arch qwen2-0.5b --shape train_4k \
      --mode hadronio                                 # paper-faithful step
"""
import argparse
import json
import time
import traceback

import jax
import numpy as np

from repro import compat
from repro.configs.base import CommConfig, RunConfig
from repro.core.backends import available_modes, get_backend
from repro.configs.registry import SHAPES, ARCH_IDS, cell_skip_reason, \
    get_config, get_shape
from repro.launch import hlo_analysis as hlo
from repro.launch import steps
from repro.launch.mesh import make_production_mesh
from repro.launch.sharding import batch_sharding
from repro.models import api

ARTIFACT_DIR = os.path.join(os.path.dirname(__file__),
                            "..", "..", "..", "artifacts")


def _mesh_chips(mesh) -> int:
    return int(np.prod(list(mesh.shape.values())))


def _lower_cell(cfg, shape, mesh, mode: str, microbatches: int):
    """Build + lower one cell's step. Returns the lowered computation."""
    run = RunConfig(model=cfg, shape=shape, comm=CommConfig(mode=mode),
                    microbatches=microbatches)
    if shape.kind == "train":
        step_fn, state_shardings, batch_sh_fn = steps.make_train_step(
            run, mesh)
        if get_backend(mode).manual:
            state = steps.abstract_tac_state(run, _mesh_chips(mesh),
                                             mesh.shape.get("pod", 1))
        else:
            state = steps.abstract_train_state(run)
        inputs = api.input_specs(cfg, shape)
        in_sh = (state_shardings, batch_sh_fn(mesh, inputs))
        jitted = jax.jit(step_fn, in_shardings=in_sh,
                         out_shardings=(state_shardings, None),
                         donate_argnums=(0,))
        return jitted.lower(state, inputs)
    if shape.kind == "prefill":
        fn = steps.make_prefill_step(run, mesh)
        params, cache, inputs, psh, csh, ish = steps.serve_specs(
            run, shape, mesh)
        jitted = jax.jit(fn, in_shardings=(psh, ish))
        return jitted.lower(params, inputs)
    fn = steps.make_decode_step(run, mesh)
    params, cache, inputs, psh, csh, ish = steps.serve_specs(
        run, shape, mesh)
    jitted = jax.jit(fn, in_shardings=(psh, csh, ish),
                     out_shardings=(None, csh), donate_argnums=(1,))
    return jitted.lower(params, cache, inputs)


def _variant_cfg(cfg, groups: int):
    """A ``groups``-deep variant of cfg for the unrolled cost probe."""
    import dataclasses
    pat = len(cfg.block_pattern) if cfg.block_pattern else 1
    kw = {"num_layers": groups * pat}
    if cfg.family == "encdec":
        kw["encoder_layers"] = groups
    return dataclasses.replace(cfg, **kw)


def _units_full(cfg) -> float:
    """Full depth in variant-group units (see unroll.py / EXPERIMENTS.md)."""
    pat = len(cfg.block_pattern) if cfg.block_pattern else 1
    return cfg.num_layers / pat


def _costs_of(lowered) -> dict:
    compiled = lowered.compile()
    cost = hlo.flops_and_bytes(compiled)
    coll = hlo.collective_stats(compiled.as_text())
    return {"flops": cost.get("flops", 0.0),
            "bytes": cost.get("bytes_accessed", 0.0),
            "coll_bytes": float(coll.total_bytes),
            "coll_ops": float(coll.total_ops)}


def scan_corrected_costs(cfg, shape, mesh, mode: str) -> dict:
    """Two-point extrapolation of per-layer HLO costs.

    cost_analysis counts loop bodies once (see models/unroll.py), so the
    full-depth lowering under-reports. We lower UNROLLED 1-group and
    2-group variants and extrapolate: cost(L) = overhead + L * per_group.
    """
    from repro.models.unroll import unrolled_layers
    with unrolled_layers():
        c1 = _costs_of(_lower_cell(_variant_cfg(cfg, 1), shape, mesh, mode, 1))
        c2 = _costs_of(_lower_cell(_variant_cfg(cfg, 2), shape, mesh, mode, 1))
    units = _units_full(cfg)
    out = {}
    for k in c1:
        per_group = c2[k] - c1[k]
        overhead = c1[k] - per_group
        out[k] = max(0.0, overhead + per_group * units)
    out["variant_units"] = units
    return out


def dryrun_cell(arch: str, shape_name: str, *, multi_pod: bool = False,
                mode: str = "gspmd", microbatches: int = 1,
                correct_scans: bool = True,
                extra: dict | None = None) -> dict:
    """Lower + compile one cell; return the artifact dict."""
    cfg = get_config(arch)
    shape = get_shape(shape_name)
    skip = cell_skip_reason(cfg, shape)
    if skip:
        return {"arch": arch, "shape": shape_name, "mesh":
                "multipod" if multi_pod else "pod", "mode": mode,
                "status": "skip", "reason": skip}

    mesh = make_production_mesh(multi_pod=multi_pod)
    t0 = time.time()
    with compat.set_mesh(mesh):
        lowered = _lower_cell(cfg, shape, mesh, mode, microbatches)
        compiled = lowered.compile()
        t1 = time.time()
        corrected = None
        if correct_scans:
            try:
                corrected = scan_corrected_costs(cfg, shape, mesh, mode)
            except Exception as e:       # noqa: BLE001 — probe is optional
                corrected = {"error": f"{type(e).__name__}: {e}"}

    text = compiled.as_text()
    coll = hlo.collective_stats(text)
    cost = hlo.flops_and_bytes(compiled)
    memory = hlo.memory_stats(compiled)
    n_chips = _mesh_chips(mesh)
    mf = hlo.model_flops(cfg, shape)
    ab = hlo.analytic_hbm_bytes(cfg, shape, n_chips,
                                tp=mesh.shape.get("model", 1),
                                dp=mesh.shape.get("data", 1))
    # roofline terms: compute from analytic MODEL_FLOPS (exact), memory
    # from the analytic traffic model, collective from the scan-corrected
    # parsed HLO (falls back to raw when the probe failed).
    coll_bytes = (corrected or {}).get("coll_bytes", coll.total_bytes) \
        if isinstance(corrected, dict) and "error" not in (corrected or {}) \
        else coll.total_bytes
    terms = hlo.roofline_terms(flops=mf, hbm_bytes=ab,
                               collective_bytes=coll_bytes,
                               n_chips=n_chips, flops_are_global=True,
                               hbm_is_global=False)
    raw_terms = hlo.roofline_terms(
        flops=cost.get("flops", 0.0),
        hbm_bytes=cost.get("bytes_accessed", 0.0),
        collective_bytes=coll.total_bytes,
        n_chips=n_chips, flops_are_global=False)
    art = {
        "arch": arch, "shape": shape_name,
        "mesh": "multipod" if multi_pod else "pod",
        "mode": mode, "status": "ok",
        "n_chips": n_chips,
        "compile_seconds": round(t1 - t0, 2),
        "collectives": coll.as_dict(),
        "cost_analysis": cost,
        "scan_corrected": corrected,
        "memory_analysis": memory,
        "analytic_hbm_bytes_per_chip": ab,
        "roofline": terms,
        "roofline_raw_hlo": raw_terms,
        "model_flops_global": mf,
        "model_flops_per_chip": mf / n_chips,
        "hlo_flops_corrected_per_chip":
            (corrected or {}).get("flops") if isinstance(corrected, dict)
            else None,
        "useful_flops_ratio":
            (mf / n_chips) / corrected["flops"]
            if isinstance(corrected, dict) and corrected.get("flops")
            else None,
        "param_count": cfg.param_count(),
        "active_param_count": cfg.active_param_count(),
    }
    if extra:
        art.update(extra)
    return art


def artifact_path(arch: str, shape: str, mesh: str, mode: str,
                  out_dir: str) -> str:
    safe = arch.replace("/", "_").replace(".", "_")
    return os.path.join(out_dir, f"dryrun_{safe}_{shape}_{mesh}_{mode}.json")


def main() -> int:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--arch", choices=list(ARCH_IDS))
    p.add_argument("--shape", choices=list(SHAPES))
    p.add_argument("--mesh", choices=["pod", "multipod"], default="pod")
    p.add_argument("--mode", default="gspmd",
                   choices=list(available_modes()))
    p.add_argument("--all", action="store_true",
                   help="run every (arch x shape) cell for --mesh/--mode")
    p.add_argument("--no-correct", action="store_true",
                   help="skip the unrolled scan-correction probe "
                        "(multipod runs: pass/fail + memory only)")
    p.add_argument("--out", default=os.path.normpath(ARTIFACT_DIR))
    p.add_argument("--skip-existing", action="store_true")
    args = p.parse_args()

    os.makedirs(args.out, exist_ok=True)
    cells = ([(a, s) for a in ARCH_IDS for s in SHAPES] if args.all
             else [(args.arch, args.shape)])
    failures = 0
    for arch, shape in cells:
        path = artifact_path(arch, shape, args.mesh, args.mode, args.out)
        if args.skip_existing and os.path.exists(path):
            with open(path) as f:
                if json.load(f).get("status") in ("ok", "skip"):
                    print(f"[cached] {arch} x {shape}")
                    continue
        try:
            art = dryrun_cell(arch, shape, multi_pod=args.mesh == "multipod",
                              mode=args.mode,
                              correct_scans=not args.no_correct)
        except Exception as e:
            art = {"arch": arch, "shape": shape, "mesh": args.mesh,
                   "mode": args.mode, "status": "fail",
                   "error": f"{type(e).__name__}: {e}",
                   "traceback": traceback.format_exc()}
            failures += 1
        with open(path, "w") as f:
            json.dump(art, f, indent=1)
        status = art["status"]
        if status == "ok":
            r = art["roofline"]
            print(f"[ok]   {arch} x {shape} ({args.mesh},{args.mode}): "
                  f"compile {art['compile_seconds']}s, "
                  f"bottleneck={r['bottleneck']}, "
                  f"coll={art['collectives']['total_bytes']/1e9:.2f}GB, "
                  f"mem_temp={art['memory_analysis'].get('temp_size_in_bytes', 0)/1e9:.2f}GB")
        elif status == "skip":
            print(f"[skip] {arch} x {shape}: {art['reason'][:60]}")
        else:
            print(f"[FAIL] {arch} x {shape}: {art['error']}")
    return 1 if failures else 0


if __name__ == "__main__":
    raise SystemExit(main())
