"""Step builders: the executable units the launcher / dry-run lower.

Two train-step families (DESIGN.md §4):

* ``gspmd`` — the production 2D-sharded step. Parameters follow the
  logical-axis rules (FSDP over ``data``, TP over ``model``), activations
  carry SP constraints, XLA owns every collective. This is the substrate
  every architecture (including the 110B/132B cells) runs on, and the
  baseline the roofline table is derived from.

* TAC modes (``sockets`` / ``vma`` / ``hadronio`` / ``hadronio_rs``) — the
  paper's regime: data-parallel peers exchanging gradient traffic, with the
  synchronization strategy swapped behind a fixed API (the transparency
  claim). The step runs inside a fully-manual ``shard_map`` over every mesh
  axis (one flattened DP ring — each device is one netty "connection");
  model compute is purely local, gradient sync is the explicit per-slice
  collective schedule of :mod:`repro.core.tac`.

Serve steps (prefill / decode) always run under GSPMD — inference has no
gradient traffic, which is the paper's scope; the cache/batch sharding
rules live in launch/sharding.py.
"""
from __future__ import annotations

import functools
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, RunConfig, ShapeConfig
from repro.core import aggregation as agg
from repro.core import tac
from repro.models import api
from repro.models.common import abstract_params, param_bytes
from repro.models.layers import no_shard
from repro.optim import adamw
from repro.launch.sharding import (batch_sharding, cache_shardings,
                                   make_shard_fn, param_shardings)

PyTree = Any


class TrainState(NamedTuple):
    params: PyTree
    opt: adamw.AdamState          # tree moments (gspmd/ddp) or flat shards (_rs)
    step: jax.Array
    ef: Optional[jax.Array] = None   # error-feedback (TAC compression)


# ---------------------------------------------------------------------------
# Shared pieces
# ---------------------------------------------------------------------------


def _loss_fn(cfg: ModelConfig, shard_fn):
    def f(params, batch):
        l, aux = api.loss(params, batch, cfg, shard_fn)
        return l, aux
    return f


def _microbatches(batch: PyTree, n: int) -> PyTree:
    """(B, ...) -> (n, B/n, ...) for gradient accumulation."""
    def split(x):
        b = x.shape[0]
        assert b % n == 0, (b, n)
        return x.reshape(n, b // n, *x.shape[1:])
    return jax.tree.map(split, batch)


def _accumulate_grads(loss_fn, params, batch, n_micro: int):
    """Mean loss/grads over ``n_micro`` sequential microbatches."""
    if n_micro == 1:
        (l, aux), grads = jax.value_and_grad(loss_fn, has_aux=True)(
            params, batch)
        return l, aux, grads
    micro = _microbatches(batch, n_micro)

    def body(carry, mb):
        acc, lsum = carry
        (l, _aux), g = jax.value_and_grad(loss_fn, has_aux=True)(params, mb)
        acc = jax.tree.map(lambda a, b: a + b.astype(jnp.float32), acc, g)
        return (acc, lsum + l), None

    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    (gacc, lsum), _ = jax.lax.scan(body, (zeros, jnp.zeros((), jnp.float32)),
                                   micro)
    inv = 1.0 / n_micro
    grads = jax.tree.map(lambda g: g * inv, gacc)
    return lsum * inv, {}, grads


# ---------------------------------------------------------------------------
# GSPMD production step (2D sharded: FSDP + TP + SP)
# ---------------------------------------------------------------------------


def init_train_state(rng: jax.Array, run: RunConfig) -> TrainState:
    params = api.init(rng, run.model)
    return TrainState(params=params, opt=adamw.init(params),
                      step=jnp.zeros((), jnp.int32))


def abstract_train_state(run: RunConfig) -> TrainState:
    """ShapeDtypeStruct state for the dry-run (no allocation)."""
    params = api.abstract(run.model)
    f32 = lambda p: jax.ShapeDtypeStruct(p.shape, jnp.float32)
    return TrainState(
        params=params,
        opt=adamw.AdamState(mu=jax.tree.map(f32, params),
                            nu=jax.tree.map(f32, params),
                            count=jax.ShapeDtypeStruct((), jnp.int32)),
        step=jax.ShapeDtypeStruct((), jnp.int32))


def train_state_shardings(mesh, run: RunConfig, *, fsdp: bool = True):
    """NamedSharding tree matching :func:`abstract_train_state`."""
    specs = api.specs(run.model)
    ps = param_shardings(mesh, specs, fsdp=fsdp)
    scalar = NamedSharding(mesh, P())
    return TrainState(params=ps,
                      opt=adamw.AdamState(mu=ps, nu=ps, count=scalar),
                      step=scalar)


def make_train_step_gspmd(run: RunConfig, mesh):
    """Returns (step_fn, state_shardings, batch_shardings_fn).

    ``step_fn(state, batch) -> (state, metrics)`` — jit with the returned
    shardings; XLA/GSPMD owns all collectives (the "kernel network stack"
    baseline at 2D scale).
    """
    cfg = run.model
    shard_fn = make_shard_fn(mesh)
    loss_fn = _loss_fn(cfg, shard_fn)

    def step_fn(state: TrainState, batch: dict):
        l, aux, grads = _accumulate_grads(loss_fn, state.params, batch,
                                          run.microbatches)
        new_params, new_opt, metrics = adamw.update(
            grads, state.opt, state.params, run)
        metrics = dict(metrics, loss=l)
        return TrainState(new_params, new_opt, state.step + 1), metrics

    return step_fn, train_state_shardings(mesh, run), batch_sharding


# ---------------------------------------------------------------------------
# TAC step (paper's technique): fully-manual DP ring over every mesh axis
# ---------------------------------------------------------------------------


def tac_scatter_size(n_shards: int, pod_size: int, comm) -> int:
    """ZeRO-1 scatter-group size: with hierarchical (pod-aware)
    collectives the reduce-scatter runs IN-POD, so shards are 1/in-pod
    sized and replicated across pods (hierarchical ZeRO)."""
    if comm.hierarchical and pod_size > 1:
        assert n_shards % pod_size == 0
        return n_shards // pod_size
    return n_shards


def abstract_tac_state(run: RunConfig, n_shards: int,
                       pod_size: int = 1) -> TrainState:
    """State for the TAC step. ``hadronio_rs`` keeps flat ZeRO-1 moment
    shards of length padded_elems / scatter_size; other modes keep tree
    moments. ``n_shards`` is the TOTAL ring size; ``pod_size`` > 1 makes
    the scatter group in-pod (see tac_scatter_size)."""
    params = api.abstract(run.model)
    f32 = lambda p: jax.ShapeDtypeStruct(p.shape, jnp.float32)
    ef = None
    if run.comm.compress in ("bf16", "int8_ef"):
        # per-peer residual: global shape carries the ring dim
        plan = agg.make_plan(params, run.comm)
        ef = jax.ShapeDtypeStruct((n_shards, plan.n_slices, plan.slice_elems),
                                  jnp.float32)
    if run.comm.mode == "hadronio_rs":
        # flat ZeRO-1 moment shards; the leading ring dim makes each peer's
        # shard explicit (global (n_shards, len), local (1, len))
        plan = agg.make_plan(params, run.comm)
        eff = tac_scatter_size(n_shards, pod_size, run.comm)
        assert plan.padded_elems % eff == 0
        shard = jax.ShapeDtypeStruct(
            (n_shards, plan.padded_elems // eff), jnp.float32)
        opt = adamw.AdamState(mu=shard, nu=shard,
                              count=jax.ShapeDtypeStruct((), jnp.int32))
    else:
        opt = adamw.AdamState(mu=jax.tree.map(f32, params),
                              nu=jax.tree.map(f32, params),
                              count=jax.ShapeDtypeStruct((), jnp.int32))
    return TrainState(params=params, opt=opt,
                      step=jax.ShapeDtypeStruct((), jnp.int32), ef=ef)


def init_tac_state(rng: jax.Array, run: RunConfig, n_shards: int,
                   pod_size: int = 1) -> TrainState:
    sds = abstract_tac_state(run, n_shards, pod_size)
    params = api.init(rng, run.model)
    zeros = lambda s: jnp.zeros(s.shape, s.dtype)
    return TrainState(params=params,
                      opt=adamw.AdamState(jax.tree.map(zeros, sds.opt.mu),
                                          jax.tree.map(zeros, sds.opt.nu),
                                          jnp.zeros((), jnp.int32)),
                      step=jnp.zeros((), jnp.int32),
                      ef=None if sds.ef is None else zeros(sds.ef))


def _decay_mask_flat(plan: agg.PackPlan) -> np.ndarray:
    """Per-element weight-decay mask in packed-flat layout (decay only
    params with ndim >= 2, matching adamw.update)."""
    mask = np.zeros((plan.padded_elems,), np.float32)
    for (start, end), shape in zip(plan.offsets, plan.shapes):
        if len(shape) >= 2:
            mask[start:end] = 1.0
    return mask


def _decay_mask_traced(plan: agg.PackPlan) -> jax.Array:
    """Same mask built from fills inside the trace — avoids embedding a
    params-sized host constant in the jaxpr (a 110B model's mask is
    ~2 GB; ranges of 2D leaves are contiguous, so a handful of
    dynamic-update-slices suffice)."""
    mask = jnp.zeros((plan.padded_elems,), jnp.float32)
    run_start = None
    runs = []
    for (start, end), shape in zip(plan.offsets, plan.shapes):
        if len(shape) >= 2:
            if run_start is None:
                run_start = start
            run_end = end
        else:
            if run_start is not None:
                runs.append((run_start, run_end))
                run_start = None
    if run_start is not None:
        runs.append((run_start, run_end))
    for s, e in runs:
        mask = jax.lax.dynamic_update_slice_in_dim(
            mask, jnp.ones((e - s,), jnp.float32), s, axis=0)
    return mask


def _flat_adamw_update(flat_p, flat_g, mu, nu, count, decay_mask, run):
    """AdamW on flat vectors (the ZeRO-1 shard path). All f32."""
    b1, b2 = run.beta1, run.beta2
    lr = adamw.schedule(run, count)
    c1 = 1.0 - b1 ** count.astype(jnp.float32)
    c2 = 1.0 - b2 ** count.astype(jnp.float32)
    mu = b1 * mu + (1 - b1) * flat_g
    nu = b2 * nu + (1 - b2) * jnp.square(flat_g)
    step = (mu / c1) / (jnp.sqrt(nu / c2) + run.eps)
    step = step + run.weight_decay * decay_mask * flat_p
    return flat_p - lr * step, mu, nu


def make_train_step_tac(run: RunConfig, mesh):
    """Returns (step_fn, state_shardings, batch_shardings_fn).

    Fully-manual shard_map over every mesh axis: one flattened DP ring of
    ``n_shards`` peers ("connections"). Params replicated; batch sharded on
    dim 0; gradient sync is the explicit TAC schedule. ``hadronio_rs``
    additionally shards the optimizer moments (ZeRO-1) as flat slices.
    """
    cfg = run.model
    comm = run.comm
    axes = tuple(mesh.axis_names)
    n_shards = int(np.prod([mesh.shape[a] for a in axes]))
    pod_size = mesh.shape.get("pod", 1)
    pod_axis = "pod" if pod_size > 1 else None
    data_axes = tuple(a for a in axes if a != "pod") if pod_axis else axes
    eff_shards = tac_scatter_size(n_shards, pod_size, comm)
    loss_fn = _loss_fn(cfg, no_shard)   # manual region: compute is local

    plan = None
    if comm.mode == "hadronio_rs":
        plan = agg.make_plan(api.abstract(cfg), comm)
        assert plan.padded_elems % eff_shards == 0, \
            (plan.padded_elems, eff_shards)

    def body(state: TrainState, batch: dict):
        # local loss scaled so psum'd grads are the global-mean grads
        def scaled_loss(p, b):
            l, aux = loss_fn(p, b)
            return l / n_shards, aux

        l, _aux, grads = _accumulate_grads(scaled_loss, state.params, batch,
                                           run.microbatches)
        loss = jax.lax.psum(l, axes)

        ef = None if state.ef is None else state.ef[0]   # local residual
        res = tac.sync_grads(grads, comm, data_axis=data_axes,
                             pod_axis=pod_axis, ef=ef)
        new_ef = None if res.ef is None else res.ef[None]

        if comm.mode == "hadronio_rs":
            # ZeRO-1: update this peer's flat param/moment shard, then
            # all-gather the updated parameter slices (per slice). With
            # hierarchical collectives the shard index is in-pod.
            flat_p = agg.pack(state.params, res.plan)
            nsl = res.plan.n_slices
            my = jax.lax.axis_index(res.gather_axes)
            psl = flat_p.reshape(nsl, eff_shards, -1)[:, my].reshape(-1)
            gsh = res.flat_shard
            # grad clip on the global flat grad norm (shards replicate
            # across pods in hierarchical mode: normalize the psum)
            gn2 = jax.lax.psum(jnp.sum(jnp.square(gsh)), axes)
            gn2 = gn2 / (n_shards // eff_shards)
            gnorm = jnp.sqrt(gn2)
            scale = jnp.minimum(1.0, run.grad_clip / jnp.maximum(gnorm, 1e-12))
            gsh = gsh * scale
            dm = _decay_mask_traced(res.plan).reshape(nsl, eff_shards,
                                                      -1)[:, my]
            count = state.opt.count + 1
            new_psl, new_mu, new_nu = _flat_adamw_update(
                psl, gsh, state.opt.mu[0], state.opt.nu[0], count,
                dm.reshape(-1), run)
            new_params = tac.gather_updated(
                new_psl.astype(jnp.float32), res.plan, state.params, comm,
                gather_axes=res.gather_axes)
            new_opt = adamw.AdamState(new_mu[None], new_nu[None], count)
            metrics = {"loss": loss, "grad_norm": gnorm,
                       "lr": adamw.schedule(run, count)}
            return TrainState(new_params, new_opt, state.step + 1,
                              new_ef), metrics

        new_params, new_opt, metrics = adamw.update(
            res.grads, state.opt, state.params, run)
        metrics = dict(metrics, loss=loss)
        return TrainState(new_params, new_opt, state.step + 1,
                          new_ef), metrics

    # ---- shard_map plumbing -------------------------------------------
    state_sds = abstract_tac_state(run, n_shards, pod_size)
    replicated = P()
    batch_spec = P(axes)          # dim 0 over the flattened ring

    if comm.mode == "hadronio_rs":
        opt_specs = adamw.AdamState(mu=batch_spec, nu=batch_spec,
                                    count=replicated)
    else:
        opt_specs = jax.tree.map(lambda _: replicated, state_sds.opt)
    state_specs = TrainState(
        params=jax.tree.map(lambda _: replicated, state_sds.params),
        opt=opt_specs,
        step=replicated,
        ef=None if state_sds.ef is None else batch_spec)
    batch_specs_fn = lambda b: jax.tree.map(lambda _: batch_spec, b)

    def step_fn(state: TrainState, batch: dict):
        bspecs = batch_specs_fn(batch)
        out = jax.shard_map(
            body, mesh=mesh,
            in_specs=(state_specs, bspecs),
            out_specs=(state_specs,
                       {"loss": replicated, "grad_norm": replicated,
                        "lr": replicated}),
            check_vma=False)(state, batch)
        return out

    def shardings(b=None):
        ns = lambda spec: NamedSharding(mesh, spec)
        ss = jax.tree.map(ns, state_specs)
        return ss

    def batch_shardings(mesh_, batch_tree):
        return jax.tree.map(lambda _: NamedSharding(mesh_, batch_spec),
                            batch_tree)

    return step_fn, shardings(), batch_shardings


def make_train_step(run: RunConfig, mesh):
    """Dispatch on ``run.comm.mode`` (the transparent boundary: callers
    never change)."""
    if run.comm.mode == "gspmd":
        return make_train_step_gspmd(run, mesh)
    return make_train_step_tac(run, mesh)


# ---------------------------------------------------------------------------
# Serve steps (GSPMD)
# ---------------------------------------------------------------------------


def make_prefill_step(run: RunConfig, mesh):
    cfg = run.model
    shard_fn = make_shard_fn(mesh)

    def prefill_fn(params, batch):
        return api.prefill(params, batch, cfg, shard_fn)

    return prefill_fn


def make_decode_step(run: RunConfig, mesh):
    """``serve_step``: one new token against a KV cache of seq_len."""
    cfg = run.model
    shard_fn = make_shard_fn(mesh)

    def decode_fn(params, cache, batch):
        logits, new_cache = api.decode_step(params, cache, batch, cfg,
                                            shard_fn)
        return logits, new_cache

    return decode_fn


def serve_specs(run: RunConfig, shape: ShapeConfig, mesh):
    """(abstract params, abstract cache, inputs, shardings) for decode
    cells. The cache length is the cell's seq_len (sliding-window archs
    cap at the window — that is the sub-quadratic property)."""
    cfg = run.model
    params = api.abstract(cfg)
    cache = api.cache_specs(cfg, shape.global_batch, shape.seq_len)
    inputs = api.input_specs(cfg, shape)
    pshard = param_shardings(mesh, api.specs(cfg), fsdp=True)
    cshard = cache_shardings(mesh, cache)
    ishard = batch_sharding(mesh, inputs)
    return params, cache, inputs, pshard, cshard, ishard
