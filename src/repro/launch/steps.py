"""Step builders: the executable units the launcher / dry-run lower.

Two train-step families (DESIGN.md §4):

* ``gspmd`` — the production 2D-sharded step. Parameters follow the
  logical-axis rules (FSDP over ``data``, TP over ``model``), activations
  carry SP constraints, XLA owns every collective. This is the substrate
  every architecture (including the 110B/132B cells) runs on, and the
  baseline the roofline table is derived from.

* TAC modes (every registered backend with ``manual=True``) — the paper's
  regime: data-parallel peers exchanging gradient traffic, with the
  synchronization strategy swapped behind a fixed API (the transparency
  claim). The step runs inside a fully-manual ``shard_map`` over every
  mesh axis (one flattened DP ring — each device is one netty
  "connection"); model compute is purely local, gradient sync is the
  backend's explicit per-slice collective schedule (repro.core.backends).

This module never branches on mode names: the backend registry supplies
state layouts (``state_specs``), the optimizer application
(``apply_update``) and the step family (``manual``), so adding a mode is
one new backend module and zero launcher edits.

Serve steps (prefill / decode) always run under GSPMD — inference has no
gradient traffic, which is the paper's scope; the cache/batch sharding
rules live in launch/sharding.py.
"""
from __future__ import annotations

from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import compat
from repro.configs.base import ModelConfig, RunConfig, ShapeConfig
from repro.core import backends as backends_mod
from repro.core import tac
from repro.core.backends import UpdateContext, get_backend
from repro.models import api
from repro.models.layers import no_shard
from repro.optim import adamw
from repro.optim import flat as flat_opt
from repro.launch.sharding import (batch_sharding, cache_shardings,
                                   make_shard_fn, param_shardings)

PyTree = Any

# packed-flat optimizer helpers kept under their historical names (tests
# and notebooks import them from here)
_decay_mask_flat = flat_opt.decay_mask_flat
_decay_mask_traced = flat_opt.decay_mask_traced
_flat_adamw_update = flat_opt.flat_adamw_update
tac_scatter_size = backends_mod.scatter_group_size


class TrainState(NamedTuple):
    params: PyTree
    opt: adamw.AdamState          # tree moments (gspmd/ddp) or flat shards (zero1)
    step: jax.Array
    ef: Optional[PyTree] = None   # error-feedback (TAC compression): one
    #                               array keyed to the global ring plan, or
    #                               a per-bucket pytree (overlap modes) —
    #                               every leaf carries a leading ring dim


# ---------------------------------------------------------------------------
# Shared pieces
# ---------------------------------------------------------------------------


def _loss_fn(cfg: ModelConfig, shard_fn):
    def f(params, batch):
        l, aux = api.loss(params, batch, cfg, shard_fn)
        return l, aux
    return f


def _microbatches(batch: PyTree, n: int) -> PyTree:
    """(B, ...) -> (n, B/n, ...) for gradient accumulation."""
    def split(x):
        b = x.shape[0]
        assert b % n == 0, (b, n)
        return x.reshape(n, b // n, *x.shape[1:])
    return jax.tree.map(split, batch)


def _accumulate_grads(loss_fn, params, batch, n_micro: int):
    """Mean loss/grads over ``n_micro`` sequential microbatches."""
    if n_micro == 1:
        (l, aux), grads = jax.value_and_grad(loss_fn, has_aux=True)(
            params, batch)
        return l, aux, grads
    micro = _microbatches(batch, n_micro)

    def body(carry, mb):
        acc, lsum = carry
        (l, _aux), g = jax.value_and_grad(loss_fn, has_aux=True)(params, mb)
        acc = jax.tree.map(lambda a, b: a + b.astype(jnp.float32), acc, g)
        return (acc, lsum + l), None

    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    (gacc, lsum), _ = jax.lax.scan(body, (zeros, jnp.zeros((), jnp.float32)),
                                   micro)
    inv = 1.0 / n_micro
    grads = jax.tree.map(lambda g: g * inv, gacc)
    return lsum * inv, {}, grads


# ---------------------------------------------------------------------------
# GSPMD production step (2D sharded: FSDP + TP + SP)
# ---------------------------------------------------------------------------


def init_train_state(rng: jax.Array, run: RunConfig) -> TrainState:
    params = api.init(rng, run.model)
    return TrainState(params=params, opt=adamw.init(params),
                      step=jnp.zeros((), jnp.int32))


def abstract_train_state(run: RunConfig) -> TrainState:
    """ShapeDtypeStruct state for the dry-run (no allocation)."""
    params = api.abstract(run.model)
    specs = get_backend("gspmd").state_specs(run, 1)
    return TrainState(params=params, opt=specs.opt,
                      step=jax.ShapeDtypeStruct((), jnp.int32))


def train_state_shardings(mesh, run: RunConfig, *, fsdp: bool = True):
    """NamedSharding tree matching :func:`abstract_train_state`."""
    specs = api.specs(run.model)
    ps = param_shardings(mesh, specs, fsdp=fsdp)
    scalar = NamedSharding(mesh, P())
    return TrainState(params=ps,
                      opt=adamw.AdamState(mu=ps, nu=ps, count=scalar),
                      step=scalar)


def make_train_step_gspmd(run: RunConfig, mesh):
    """Returns (step_fn, state_shardings, batch_shardings_fn).

    ``step_fn(state, batch) -> (state, metrics)`` — jit with the returned
    shardings; XLA/GSPMD owns all collectives (the "kernel network stack"
    baseline at 2D scale).
    """
    cfg = run.model
    shard_fn = make_shard_fn(mesh)
    loss_fn = _loss_fn(cfg, shard_fn)

    def step_fn(state: TrainState, batch: dict):
        l, aux, grads = _accumulate_grads(loss_fn, state.params, batch,
                                          run.microbatches)
        new_params, new_opt, metrics = adamw.update(
            grads, state.opt, state.params, run)
        metrics = dict(metrics, loss=l)
        return TrainState(new_params, new_opt, state.step + 1), metrics

    return step_fn, train_state_shardings(mesh, run), batch_sharding


# ---------------------------------------------------------------------------
# TAC step (paper's technique): fully-manual DP ring over every mesh axis
# ---------------------------------------------------------------------------


def abstract_tac_state(run: RunConfig, n_shards: int,
                       pod_size: int = 1) -> TrainState:
    """State for the TAC step: the backend owns the optimizer / error
    feedback layout (``CommBackend.state_specs``). ``n_shards`` is the
    TOTAL ring size; ``pod_size`` > 1 makes zero1 scatter groups in-pod
    (see backends.scatter_group_size)."""
    params = api.abstract(run.model)
    specs = get_backend(run.comm.mode).state_specs(run, n_shards, pod_size)
    return TrainState(params=params, opt=specs.opt,
                      step=jax.ShapeDtypeStruct((), jnp.int32), ef=specs.ef)


def init_tac_state(rng: jax.Array, run: RunConfig, n_shards: int,
                   pod_size: int = 1) -> TrainState:
    sds = abstract_tac_state(run, n_shards, pod_size)
    params = api.init(rng, run.model)
    zeros = lambda s: jnp.zeros(s.shape, s.dtype)
    return TrainState(params=params,
                      opt=adamw.AdamState(jax.tree.map(zeros, sds.opt.mu),
                                          jax.tree.map(zeros, sds.opt.nu),
                                          jnp.zeros((), jnp.int32)),
                      step=jnp.zeros((), jnp.int32),
                      ef=None if sds.ef is None
                      else jax.tree.map(zeros, sds.ef))


def make_train_step_tac(run: RunConfig, mesh):
    """Returns (step_fn, state_shardings, batch_shardings_fn).

    Fully-manual shard_map over every mesh axis: one flattened DP ring of
    ``n_shards`` peers ("connections"). Params replicated; batch sharded on
    dim 0; gradient sync is the registered backend's collective schedule.
    zero1 backends additionally shard the optimizer moments as flat slices.
    """
    cfg = run.model
    comm = run.comm
    backend = get_backend(comm.mode)
    backend.validate(comm)
    axes = tuple(mesh.axis_names)
    n_shards = int(np.prod([mesh.shape[a] for a in axes]))
    pod_size = mesh.shape.get("pod", 1)
    pod_axis = "pod" if pod_size > 1 else None
    data_axes = tuple(a for a in axes if a != "pod") if pod_axis else axes
    eff_shards = tac_scatter_size(n_shards, pod_size, comm)
    uctx = UpdateContext(axes=axes, n_shards=n_shards,
                         eff_shards=eff_shards)
    loss_fn = _loss_fn(cfg, no_shard)   # manual region: compute is local

    def body(state: TrainState, batch: dict):
        # local loss scaled so psum'd grads are the global-mean grads
        def scaled_loss(p, b):
            l, aux = loss_fn(p, b)
            return l / n_shards, aux

        l, _aux, grads = _accumulate_grads(scaled_loss, state.params, batch,
                                           run.microbatches)

        # local residual: strip the leading ring dim from every EF leaf
        # (one array for global-ring keying, a pytree for per-bucket)
        ef = None if state.ef is None \
            else jax.tree.map(lambda e: e[0], state.ef)
        res = tac.sync_grads(grads, comm, data_axis=data_axes,
                             pod_axis=pod_axis, ef=ef)
        new_ef = None if res.ef is None \
            else jax.tree.map(lambda e: e[None], res.ef)

        # loss epilogue AFTER the sync emission: overlap-style backends'
        # early-slice collectives precede it in the program
        loss = jax.lax.psum(l, axes)

        new_params, new_opt, metrics = backend.apply_update(
            state.params, state.opt, res, run, uctx)
        metrics = dict(metrics, loss=loss)
        return TrainState(new_params, new_opt, state.step + 1,
                          new_ef), metrics

    # ---- shard_map plumbing -------------------------------------------
    state_sds = abstract_tac_state(run, n_shards, pod_size)
    replicated = P()
    batch_spec = P(axes)          # dim 0 over the flattened ring

    if backend.zero1:
        # flat moment shards carry the explicit leading ring dim
        opt_specs = adamw.AdamState(mu=batch_spec, nu=batch_spec,
                                    count=replicated)
    else:
        opt_specs = jax.tree.map(lambda _: replicated, state_sds.opt)
    state_specs = TrainState(
        params=jax.tree.map(lambda _: replicated, state_sds.params),
        opt=opt_specs,
        step=replicated,
        ef=None if state_sds.ef is None
        else jax.tree.map(lambda _: batch_spec, state_sds.ef))
    batch_specs_fn = lambda b: jax.tree.map(lambda _: batch_spec, b)

    def step_fn(state: TrainState, batch: dict):
        bspecs = batch_specs_fn(batch)
        # metrics take a replicated PREFIX spec: whatever dict the
        # backend's apply_update returns works without launcher edits
        out = compat.shard_map(
            body, mesh=mesh,
            in_specs=(state_specs, bspecs),
            out_specs=(state_specs, replicated),
            check_vma=False)(state, batch)
        return out

    def shardings(b=None):
        ns = lambda spec: NamedSharding(mesh, spec)
        ss = jax.tree.map(ns, state_specs)
        return ss

    def batch_shardings(mesh_, batch_tree):
        return jax.tree.map(lambda _: NamedSharding(mesh_, batch_spec),
                            batch_tree)

    return step_fn, shardings(), batch_shardings


def make_train_step(run: RunConfig, mesh):
    """Dispatch on the registered backend's step family (the transparent
    boundary: callers never change, and no mode names appear here)."""
    backend = get_backend(run.comm.mode)
    backend.validate(run.comm)
    if backend.manual:
        return make_train_step_tac(run, mesh)
    return make_train_step_gspmd(run, mesh)


# ---------------------------------------------------------------------------
# Serve steps (GSPMD)
# ---------------------------------------------------------------------------


def make_prefill_step(run: RunConfig, mesh):
    cfg = run.model
    shard_fn = make_shard_fn(mesh)

    def prefill_fn(params, batch):
        return api.prefill(params, batch, cfg, shard_fn)

    return prefill_fn


def make_decode_step(run: RunConfig, mesh):
    """``serve_step``: one new token against a KV cache of seq_len."""
    cfg = run.model
    shard_fn = make_shard_fn(mesh)

    def decode_fn(params, cache, batch):
        logits, new_cache = api.decode_step(params, cache, batch, cfg,
                                            shard_fn)
        return logits, new_cache
    return decode_fn


def serve_specs(run: RunConfig, shape: ShapeConfig, mesh):
    """(abstract params, abstract cache, inputs, shardings) for decode
    cells. The cache length is the cell's seq_len (sliding-window archs
    cap at the window — that is the sub-quadratic property)."""
    cfg = run.model
    params = api.abstract(cfg)
    cache = api.cache_specs(cfg, shape.global_batch, shape.seq_len)
    inputs = api.input_specs(cfg, shape)
    pshard = param_shardings(mesh, api.specs(cfg), fsdp=True)
    cshard = cache_shardings(mesh, cache)
    ishard = batch_sharding(mesh, inputs)
    return params, cache, inputs, pshard, cshard, ishard
