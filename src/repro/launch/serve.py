"""Serving launcher: load (or init) params, run the event-loop serving
subsystem (EventLoopGroup of decode engines over the CommBackend wire).

CLI::

  python -m repro.launch.serve --arch qwen2-0.5b-reduced --requests 8 \
      --max-new 16 --ckpt /tmp/run1        # params from a train checkpoint

  # paper §IV topology: 2 event loops, busy polling, hadronio wire
  python -m repro.launch.serve --arch qwen2-0.5b-reduced --requests 16 \
      --event-loops 2 --poll busy --comm-mode hadronio --channels 4

  # two-level fabric: 2 pods, hierarchical leader-channel emission —
  # intra-pod traffic stays on local channels, the 1/n-reduced shard
  # rides the leader lane pinned to loop 0
  python -m repro.launch.serve --arch qwen2-0.5b-reduced --requests 16 \
      --event-loops 2 --comm-mode hadronio_overlap --channels 4 \
      --aggregate channel --flush ready --pods 2 --emission hierarchical

  # self-healing supervisor: bounded admission, retry/backoff healing,
  # autoscaling between --event-loops (floor) and --max-loops
  python -m repro.launch.serve --arch qwen2-0.5b-reduced --requests 32 \
      --event-loops 1 --supervised --max-loops 4 --scale-up-depth 4 \
      --admission-capacity 16 --dispatch-quantum 8

  # multi-tenant: two model FAMILIES side by side in one group — each
  # --tenant NAME=ARCH[:WEIGHT[:LOOPS]] owns a contiguous loop range,
  # requests route by tenant with weighted-fair admission (2:1 here)
  python -m repro.launch.serve --requests 12 --comm-mode hadronio \
      --tenant chat=qwen2-0.5b-reduced:2 \
      --tenant rnn=rwkv6-7b-reduced:1
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro import obs
from repro.configs.registry import get_config
from repro.configs.base import CommConfig, ServeConfig, TenantConfig
from repro.checkpoint import CheckpointStore
from repro.core.backends import available_modes
from repro.models import api
from repro.serving import (Request, RetryBudget, Supervisor,
                           SupervisorConfig, make_engine_group)


def parse_tenant_specs(specs) -> tuple:
    """``NAME=ARCH[:WEIGHT[:LOOPS]]`` -> TenantConfig tuple (shared by
    this launcher and examples/serve_batched.py)."""
    out = []
    for spec in specs or ():
        name, _, rest = spec.partition("=")
        if not name or not rest:
            raise ValueError(
                f"--tenant {spec!r}: expected NAME=ARCH[:WEIGHT[:LOOPS]]")
        parts = rest.split(":")
        out.append(TenantConfig(
            name, arch=parts[0],
            weight=int(parts[1]) if len(parts) > 1 else 1,
            event_loops=int(parts[2]) if len(parts) > 2 else 1))
    return tuple(out)


def load_params(args, cfg):
    if args.ckpt:
        store = CheckpointStore(args.ckpt)
        step = store.latest_step()
        if step is not None:
            from repro.launch import steps as steps_mod
            from repro.configs.base import RunConfig, ShapeConfig
            run = RunConfig(model=cfg, shape=ShapeConfig(
                "serve", "decode", args.max_len, args.batch))
            like = steps_mod.abstract_train_state(run)
            state = store.restore(step, like)
            print(f"[serve] restored params from step {step}")
            return state.params
    return api.init(jax.random.PRNGKey(args.seed), cfg)


def main() -> int:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--arch", default="",
                   help="registry id (required unless --tenant is given)")
    p.add_argument("--tenant", action="append", default=[],
                   metavar="NAME=ARCH[:WEIGHT[:LOOPS]]",
                   help="repeatable: serve several models in ONE group — "
                        "each tenant owns LOOPS event loops (contiguous "
                        "range, disjoint channels) and a WEIGHT share of "
                        "weighted-fair admission; requests route by "
                        "Request.tenant (docs/FAMILIES.md)")
    p.add_argument("--requests", type=int, default=8)
    p.add_argument("--batch", type=int, default=4)
    p.add_argument("--max-new", type=int, default=16)
    p.add_argument("--max-len", type=int, default=256)
    p.add_argument("--temperature", type=float, default=0.0)
    p.add_argument("--ckpt", default="")
    p.add_argument("--seed", type=int, default=0)
    # the event-loop serving subsystem (ServeConfig)
    p.add_argument("--event-loops", type=int, default=1,
                   help="EventLoopGroup size; each loop owns a disjoint "
                        "run of the channel pool")
    p.add_argument("--poll", default="busy",
                   choices=ServeConfig.POLLS,
                   help="completion polling: busy spins, park blocks, "
                        "adaptive spins then parks (hadroNIO §IV-B)")
    p.add_argument("--comm-mode", default="gspmd",
                   choices=available_modes(),
                   help="CommBackend the serving collectives (KV gathers, "
                        "TP logit reductions) flow through")
    p.add_argument("--channels", type=int, default=4,
                   help="global CommChannel pool partitioned across loops")
    p.add_argument("--aggregate", default="slice",
                   choices=CommConfig.AGGREGATES)
    p.add_argument("--flush", default="step", choices=CommConfig.FLUSHES)
    # the two-level serving fabric (pod topology)
    p.add_argument("--pods", type=int, default=1,
                   help="pod count of the two-level fabric; must divide "
                        "the device count (1 = flat ring)")
    p.add_argument("--pod-axis", default="pod",
                   help="mesh axis name of the pod dimension")
    p.add_argument("--leader-loops", type=int, default=1,
                   help="event loops pinned to the cross-pod leader lanes")
    p.add_argument("--leader-channels", type=int, default=1,
                   help="channels carved from the pool tail as dedicated "
                        "cross-pod leader lanes")
    p.add_argument("--emission", default="flat",
                   choices=("flat", "hierarchical"),
                   help="flat: one-level ring collectives over all "
                        "devices; hierarchical: pod-aware two-level "
                        "leader-channel emission (bit-identical tokens, "
                        "different wire structure)")
    # the self-healing supervisor (serving/supervisor.py)
    p.add_argument("--supervised", action="store_true",
                   help="run under the Supervisor: failure detection, "
                        "retry/backoff healing, elastic autoscaling and "
                        "admission backpressure")
    p.add_argument("--admission-capacity", type=int, default=64,
                   help="bounded admission queue; over capacity the "
                        "lowest-priority request is shed with an "
                        "explicit rejected outcome")
    p.add_argument("--dispatch-quantum", type=int, default=0,
                   help="requests dispatched per supervision round "
                        "(0 = drain the whole queue)")
    p.add_argument("--retry-limit", type=int, default=3,
                   help="drain retry attempts before a structured "
                        "retry_exhausted outcome")
    p.add_argument("--max-loops", type=int, default=0,
                   help="autoscale ceiling (0 = channel pool size); "
                        "--event-loops is the starting size")
    p.add_argument("--scale-up-depth", type=float, default=8.0,
                   help="queued requests per loop that votes to grow "
                        "the fleet")
    p.add_argument("--scale-down-depth", type=float, default=-1.0,
                   help="backlog per loop that votes to shrink "
                        "(negative disables shrinking)")
    # the Observatory telemetry plane (repro/obs, docs/OBSERVABILITY.md)
    p.add_argument("--trace-out", default="",
                   help="write a Chrome-trace/Perfetto JSON of the run's "
                        "spans here (enables tracing; tokens stay "
                        "bit-identical to an untraced run)")
    p.add_argument("--metrics-out", default="",
                   help="write the unified metrics snapshot (obs "
                        "registry JSON: poll/emission/loop/tenant/"
                        "supervisor counters) here")
    args = p.parse_args()

    if args.trace_out:
        obs.enable()

    tenants = parse_tenant_specs(args.tenant)
    if not tenants and not args.arch:
        p.error("--arch is required (or pass one or more --tenant specs)")
    if tenants and args.supervised:
        p.error("--supervised requires a single-tenant group: tenant loop "
                "ranges pin the fleet size, which autoscaling would "
                "resize (drop --tenant or --supervised)")
    if tenants:
        cfg = {t.name: get_config(t.arch) for t in tenants}
        params = {t.name: api.init(jax.random.PRNGKey(args.seed + i),
                                   cfg[t.name])
                  for i, t in enumerate(tenants)}
        if args.event_loops == 1:      # default: one loop per tenant
            args.event_loops = sum(t.event_loops for t in tenants)
    else:
        cfg = get_config(args.arch)
        params = load_params(args, cfg)
    # no silent clamping: ServeConfig raises its own clear errors when
    # event_loops > channels (each loop must own a disjoint run), the
    # pod topology cannot be honored (leader lanes must leave every loop
    # a local lane), or the tenant loop counts do not sum to the fleet
    # size; make_serve_mesh rejects pods not dividing devices
    serve = ServeConfig(
        event_loops=args.event_loops, poll=args.poll,
        max_batch=args.batch, max_len=args.max_len,
        pods=args.pods, pod_axis=args.pod_axis,
        leader_loops=args.leader_loops, tenants=tenants,
        comm=CommConfig(mode=args.comm_mode, channels=args.channels,
                        aggregate=args.aggregate, flush=args.flush,
                        hierarchical=args.emission == "hierarchical",
                        leader_channels=args.leader_channels))
    sup = None
    if args.supervised:
        sup = Supervisor(cfg, params, serve, seed=args.seed,
                         config=SupervisorConfig(
                             admission_capacity=args.admission_capacity,
                             dispatch_quantum=args.dispatch_quantum,
                             max_loops=args.max_loops,
                             scale_up_depth=args.scale_up_depth,
                             scale_down_depth=args.scale_down_depth,
                             retry=RetryBudget(limit=args.retry_limit)))
        group = sup.group
    else:
        group = make_engine_group(cfg, params, serve, seed=args.seed)
    if args.pods > 1:
        eng = group.loops[0].engine
        print(f"[serve] two-level fabric: pods={args.pods} "
              f"(axis {args.pod_axis!r}), emission={args.emission}, "
              f"leader lanes={args.leader_channels} -> "
              f"loops 0..{args.leader_loops - 1}, "
              f"mesh={dict(eng.step.mesh.shape)}")

    rng = np.random.default_rng(args.seed)
    if tenants:
        names = [t.name for t in tenants]
        reqs = []
        for i in range(args.requests):
            name = names[i % len(names)]
            reqs.append(Request(
                uid=i,
                prompt=rng.integers(0, cfg[name].vocab_size,
                                    size=rng.integers(4, 32)),
                max_new=args.max_new, temperature=args.temperature,
                tenant=name))
    else:
        reqs = [Request(uid=i,
                        prompt=rng.integers(0, cfg.vocab_size,
                                            size=rng.integers(4, 32)),
                        max_new=args.max_new,
                        temperature=args.temperature)
                for i in range(args.requests)]
    t0 = time.time()
    if sup is not None:
        sup.submit(reqs)
        results = sup.run(threads=args.event_loops > 1)
        group = sup.group          # may have been rebuilt by a resize
    else:
        group.submit(reqs)
        results = sorted(group.run(threads=args.event_loops > 1),
                         key=lambda r: r.uid)
    dt = time.time() - t0
    tok = sum(len(r.tokens) for r in results)
    st = sup.poll_stats() if sup is not None else group.poll_stats()
    print(f"[serve] {len(results)} requests, {tok} tokens in {dt:.2f}s "
          f"({tok / dt:.1f} tok/s) | {serve.event_loops} event loop(s), "
          f"poll={serve.poll} (spins={st.spins} parks={st.parks}), "
          f"comm={args.comm_mode}")
    if sup is not None:
        shed = sum(1 for o in sup.outcomes.values()
                   if o.status == "rejected")
        print(f"[serve] supervisor: {sup.rounds} rounds, "
              f"{len(sup.trace)} healing actions, {shed} shed, "
              f"fleet={sup.group.n_loops} loops, mttr="
              f"{sup.mttr_s() if sup.trace else None}")
        for a in sup.healing_trace():
            print(f"  heal round={a[0]} {a[1]} target={a[2]} {a[3]}")
    if tenants:
        print(f"[serve] tenants: fairness={group.fairness_counters} "
              f"dispatch={group.dispatch_log[:12]}")
    for loop in group.loops:
        print(f"  loop {loop.index}: channels={loop.channels} "
              f"results={len(loop.results)}")
    for r in results[:4]:
        print(f"  uid={r.uid} prompt_len={r.prompt_len} -> "
              f"{r.tokens[:12].tolist()}")
    if args.metrics_out:
        reg = obs.collect(group=group, supervisor=sup,
                          mode=args.comm_mode)
        with open(args.metrics_out, "w") as f:
            f.write(reg.to_json())
        snap = reg.snapshot()
        print(f"[serve] metrics snapshot -> {args.metrics_out} "
              f"({len(snap['counters']) + len(snap['gauges'])} "
              f"deterministic metrics)")
    if args.trace_out:
        rec = obs.disable()
        doc = rec.write(args.trace_out)
        print(f"[serve] span trace -> {args.trace_out} "
              f"({len(doc['traceEvents'])} spans, kinds={rec.kinds()})")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
