"""Serving launcher: load (or init) params, run the batched decode engine.

CLI::

  python -m repro.launch.serve --arch qwen2-0.5b-reduced --requests 8 \
      --max-new 16 --ckpt /tmp/run1        # params from a train checkpoint
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs.registry import get_config
from repro.checkpoint import CheckpointStore
from repro.models import api
from repro.serving import DecodeEngine, Request


def load_params(args, cfg):
    if args.ckpt:
        store = CheckpointStore(args.ckpt)
        step = store.latest_step()
        if step is not None:
            from repro.launch import steps as steps_mod
            from repro.configs.base import RunConfig, ShapeConfig
            run = RunConfig(model=cfg, shape=ShapeConfig(
                "serve", "decode", args.max_len, args.batch))
            like = steps_mod.abstract_train_state(run)
            state = store.restore(step, like)
            print(f"[serve] restored params from step {step}")
            return state.params
    return api.init(jax.random.PRNGKey(args.seed), cfg)


def main() -> int:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--arch", required=True)
    p.add_argument("--requests", type=int, default=8)
    p.add_argument("--batch", type=int, default=4)
    p.add_argument("--max-new", type=int, default=16)
    p.add_argument("--max-len", type=int, default=256)
    p.add_argument("--temperature", type=float, default=0.0)
    p.add_argument("--ckpt", default="")
    p.add_argument("--seed", type=int, default=0)
    args = p.parse_args()

    cfg = get_config(args.arch)
    params = load_params(args, cfg)
    engine = DecodeEngine(cfg, params, max_batch=args.batch,
                          max_len=args.max_len)
    rng = np.random.default_rng(args.seed)
    reqs = [Request(uid=i,
                    prompt=rng.integers(0, cfg.vocab_size,
                                        size=rng.integers(4, 32)),
                    max_new=args.max_new, temperature=args.temperature)
            for i in range(args.requests)]
    t0 = time.time()
    results = engine.generate(reqs)
    dt = time.time() - t0
    tok = sum(len(r.tokens) for r in results)
    print(f"[serve] {len(results)} requests, {tok} tokens in {dt:.2f}s "
          f"({tok / dt:.1f} tok/s)")
    for r in results[:4]:
        print(f"  uid={r.uid} prompt_len={r.prompt_len} -> "
              f"{r.tokens[:12].tolist()}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
