"""Elastic scaling: continue a run on a different device count / mesh.

Checkpoints are mesh-agnostic (full host arrays per leaf — see
checkpoint/store.py), so elasticity reduces to: build the new mesh, derive
the new shardings from the same logical rules, restore, continue. The two
things that must be re-derived on a scale change:

* ``CommConfig``-dependent state — the ZeRO-1 modes keep *flat,
  ring-sharded* optimizer moments whose shard length depends on the
  device count. The owning backend's ``reshard_flat_shards`` hook
  re-slices them for the new ring (the global flat vector is an
  invariant; the segment layout — ring slices vs overlap buckets — is
  backend-owned).
* data order — the pipeline is addressed by (step, global index), so a
  different host count reads the same global batch (DataConfig.host_*).

Straggler/eviction policy (documented for the 1000-node deployment): a
persistently slow host is evicted by the cluster manager; the survivors
restart from LATEST via this module onto the shrunken mesh. Synchronous
SGD semantics are preserved exactly — only wall-clock is lost.
"""
from __future__ import annotations

from typing import Optional

import jax
import numpy as np

from repro import compat
from repro.configs.base import RunConfig
from repro.core.backends import get_backend
from repro.checkpoint import CheckpointStore
from repro.launch import steps as steps_mod
from repro.launch.mesh import make_mesh
from repro.optim import adamw


def reshard_tac_opt(flat_mu: np.ndarray, flat_nu: np.ndarray,
                    old_shards: int, new_shards: int, n_slices: int):
    """Re-slice hadronio_rs-style flat moment shards for a new ring size
    (thin wrapper over :func:`repro.optim.flat.reshard_ring_segments`,
    which owns the segment-major re-slice rule — the live restore path
    goes through the backend's ``reshard_flat_shards`` hook).

    Saved checkpoints hold the *global* stacked shards (old_shards,
    shard_len); the global flat layout is n_slices equal segments.
    Returns (new_mu, new_nu) of shape (new_shards, new_shard_len)."""
    from repro.optim.flat import reshard_ring_segments
    seg = [flat_mu.shape[1] * old_shards // n_slices] * n_slices
    return (reshard_ring_segments(flat_mu, old_shards, new_shards, seg),
            reshard_ring_segments(flat_nu, old_shards, new_shards, seg))


def reshard_event_loops(serve, new_loops: int):
    """Elastic reshard of the SERVING fleet: the same continue-on-a-
    different-shape contract applied to event loops instead of devices.
    Returns a re-validated :class:`~repro.configs.base.ServeConfig` with
    ``event_loops=new_loops`` (``dataclasses.replace`` re-runs the config
    invariants — a loop count the channel pool cannot feed raises here,
    not mid-request); ``leader_loops`` is clamped so the leader lanes
    always keep an owning loop. Served tokens are invariant to the
    resize: affinity changes emission structure, never logits (the
    conformance invariant), so a group rebuilt with the new config at a
    flush boundary continues bit-identically — the recovery property the
    chaos harness's reshard-mid-request scenario asserts."""
    import dataclasses as _dc
    return _dc.replace(serve, event_loops=new_loops,
                       leader_loops=min(serve.leader_loops, new_loops))


def _minimal_regroup(n_channels: int, old_groups: tuple, new_loops: int):
    """Minimal-migration repartition for the FLAT fabric. Shrink: the
    surviving loops keep their runs and the removed TAIL loops' channels
    coalesce onto the last survivor — only the removed loops' channels
    change owner. Grow by ``k``: each added loop takes exactly ONE
    channel from the pool tail (added loop ``i`` gets channel
    ``n-k+i``); donors keep their prefixes. Returns None when the
    minimal move would violate an ownership invariant (a donor emptied,
    or a non-contiguous run) — the caller falls back to a full
    recompute. Balance-to-within-one is deliberately NOT preserved:
    fewer owner changes means fewer serve-step recompiles (the affinity
    keys the step cache), which is the whole point of an in-flight
    resize."""
    old_k = len(old_groups)
    if new_loops == old_k:
        return old_groups
    if new_loops < old_k:
        groups = [list(g) for g in old_groups[:new_loops]]
        tail = sorted(c for g in old_groups[new_loops:] for c in g)
        groups[-1] = sorted(groups[-1] + tail)
    else:
        add = new_loops - old_k
        donate = set(range(n_channels - add, n_channels))
        groups = [[c for c in g if c not in donate] for g in old_groups]
        if any(not g for g in groups):
            return None               # a donor would own nothing
        groups += [[c] for c in sorted(donate)]
    for g in groups:                  # contiguous runs only
        if list(g) != list(range(min(g), max(g) + 1)):
            return None
    if sorted(c for g in groups for c in g) != list(range(n_channels)):
        return None                   # disjoint + covering
    return tuple(tuple(g) for g in groups)


def reshard_affinity(n_channels: int, old_groups, new_loops: int, *,
                     n_pods: int = 1, leaders: int = 0,
                     leader_loops: int = 1):
    """Re-derive the channel-affinity partition for a resized fleet and
    report the migration: ``(new_groups, moved)`` where ``moved`` is the
    sorted tuple of channel ids whose owning loop index changed — the
    connections that must be handed to a different worker thread on a
    netty-style rebalance. Ownership stays disjoint, contiguous and
    covering in both partitions (``channel_affinity`` invariants).

    The FLAT fabric (no leader lanes, one pod) migrates MINIMALLY
    (:func:`_minimal_regroup`): channels only move off removed loops on
    a shrink, and only onto added loops on a grow — survivors keep
    their serve steps warm across the resize. The TOPOLOGY form
    (``leaders > 0`` or ``n_pods > 1``) always recomputes
    ``channel_affinity``: pod alignment and leader pinning are
    correctness constraints worth the extra migrations."""
    from repro.serving.event_loop import channel_affinity
    old_groups = tuple(tuple(g) for g in old_groups)
    if new_loops > n_channels:
        # raise the standard ownership error
        channel_affinity(n_channels, new_loops)
    new_groups = None
    if leaders <= 0 and n_pods <= 1:
        new_groups = _minimal_regroup(n_channels, old_groups, new_loops)
    if new_groups is None:
        new_groups = channel_affinity(n_channels, new_loops, n_pods=n_pods,
                                      leaders=leaders,
                                      leader_loops=leader_loops)
    old_owner = {c: i for i, g in enumerate(old_groups) for c in g}
    moved = tuple(sorted(
        c for i, g in enumerate(new_groups) for c in g
        if old_owner.get(c) != i))
    return new_groups, moved


def make_on_mismatch(run: RunConfig):
    """Shape-mismatch resolver for elastic restores. Ring-sized state is
    backend-owned, so the re-slice rule is the backend's
    ``reshard_flat_shards`` hook (zero1 flat moments — including the
    replan-and-reinit path a non-power-of-two scatter group takes, where
    even the total flat length changes); error-feedback residuals are
    per-peer and keyed to the ring/bucket layout, so any mismatch resets
    them to zero (one uncompensated step of truncation — the EF
    telescoping restarts cleanly). Leaves are told apart by their
    checkpoint path name (``.ef...`` vs ``.opt_...``, see
    checkpoint/store._leaf_files), not by shape: an overlap bucket's
    residual and a flat moment shard are both 2-D."""
    backend = get_backend(run.comm.mode)
    if not backend.zero1 and run.comm.compress == "none":
        return None

    def on_mismatch(name: str, arr: np.ndarray, ref) -> np.ndarray:
        want = tuple(ref.shape)
        if name.startswith(".ef") and arr.ndim == len(want):
            return np.zeros(want, np.float32)
        if arr.ndim == 2 and len(want) == 2:
            out = backend.reshard_flat_shards(run, arr, want[0])
            if tuple(out.shape) != want:
                raise ValueError(
                    f"{name}: backend resharded {arr.shape} -> {out.shape},"
                    f" expected {want}")
            return out
        if arr.ndim == len(want) and arr.shape[1:] == want[1:]:
            # leading ring dim changed on a per-peer residual: reset
            return np.zeros(want, np.float32)
        raise ValueError(f"{name}: cannot reshard {arr.shape}->{want}")

    return on_mismatch


def restore_elastic(store: CheckpointStore, run: RunConfig, mesh,
                    step: Optional[int] = None):
    """Restore the latest (or given) checkpoint onto ``mesh`` — the mesh
    may have a different shape/size than the one that saved. Returns
    (state, step)."""
    s = store.latest_step() if step is None else step
    if s is None:
        raise FileNotFoundError(f"no checkpoint under {store.dir}")
    n_shards = int(np.prod(list(mesh.shape.values())))
    with compat.set_mesh(mesh):
        _, state_sh, _ = steps_mod.make_train_step(run, mesh)
        if get_backend(run.comm.mode).manual:
            like = steps_mod.abstract_tac_state(run, n_shards,
                                                mesh.shape.get("pod", 1))
        else:
            like = steps_mod.abstract_train_state(run)
        state = store.restore(s, like, state_sh,
                              on_mismatch=make_on_mismatch(run))
    return state, s
