"""Elastic scaling: continue a run on a different device count / mesh.

Checkpoints are mesh-agnostic (full host arrays per leaf — see
checkpoint/store.py), so elasticity reduces to: build the new mesh, derive
the new shardings from the same logical rules, restore, continue. The two
things that must be re-derived on a scale change:

* ``CommConfig``-dependent state — the TAC ``hadronio_rs`` mode keeps
  *flat, ring-sharded* optimizer moments whose shard length depends on the
  device count. ``reshard_tac_opt`` re-slices them for the new ring (the
  global flat vector is an invariant).
* data order — the pipeline is addressed by (step, global index), so a
  different host count reads the same global batch (DataConfig.host_*).

Straggler/eviction policy (documented for the 1000-node deployment): a
persistently slow host is evicted by the cluster manager; the survivors
restart from LATEST via this module onto the shrunken mesh. Synchronous
SGD semantics are preserved exactly — only wall-clock is lost.
"""
from __future__ import annotations

from typing import Optional

import jax
import numpy as np

from repro import compat
from repro.configs.base import RunConfig
from repro.core.backends import get_backend
from repro.checkpoint import CheckpointStore
from repro.launch import steps as steps_mod
from repro.launch.mesh import make_mesh
from repro.optim import adamw


def reshard_tac_opt(flat_mu: np.ndarray, flat_nu: np.ndarray,
                    old_shards: int, new_shards: int, n_slices: int):
    """Re-slice hadronio_rs flat moment shards for a new ring size.

    Saved checkpoints hold the *global* stacked shards (old_shards,
    shard_len). The global flat layout is (n_slices, padded/n_slices)
    sliced per-shard chunk-wise; rebuild it, then re-slice.
    Returns (new_mu, new_nu) of shape (new_shards, new_shard_len).
    """
    def reslice(stacked: np.ndarray) -> np.ndarray:
        old = stacked.reshape(old_shards, n_slices, -1)      # (O, n, c_o)
        # global slice view: (n, slice_elems) with chunks in ring order
        glob = np.stack([np.concatenate(
            [old[i, s] for i in range(old_shards)]) for s in range(n_slices)])
        assert glob.shape[1] % new_shards == 0
        c_n = glob.shape[1] // new_shards
        return np.stack([glob[:, i * c_n:(i + 1) * c_n].reshape(-1)
                         for i in range(new_shards)])

    return reslice(flat_mu), reslice(flat_nu)


def make_on_mismatch(run: RunConfig):
    """Shape-mismatch resolver for elastic restores. Only the TAC
    ``hadronio_rs`` mode has ring-sized state (flat moment shards + error
    feedback); everything else restores shape-identically."""
    if not get_backend(run.comm.mode).zero1 and run.comm.compress == "none":
        return None
    from repro.core import aggregation as agg
    from repro.models import api
    plan = agg.make_plan(api.abstract(run.model), run.comm)

    def on_mismatch(name: str, arr: np.ndarray, ref) -> np.ndarray:
        want = tuple(ref.shape)
        if arr.ndim == 2 and len(want) == 2 and \
                arr.size == int(np.prod(want)):
            out, _ = reshard_tac_opt(arr, arr, arr.shape[0], want[0],
                                     plan.n_slices)
            return out
        raise ValueError(f"{name}: cannot reshard {arr.shape}->{want}")

    return on_mismatch


def restore_elastic(store: CheckpointStore, run: RunConfig, mesh,
                    step: Optional[int] = None):
    """Restore the latest (or given) checkpoint onto ``mesh`` — the mesh
    may have a different shape/size than the one that saved. Returns
    (state, step)."""
    s = store.latest_step() if step is None else step
    if s is None:
        raise FileNotFoundError(f"no checkpoint under {store.dir}")
    n_shards = int(np.prod(list(mesh.shape.values())))
    with compat.set_mesh(mesh):
        _, state_sh, _ = steps_mod.make_train_step(run, mesh)
        if get_backend(run.comm.mode).manual:
            like = steps_mod.abstract_tac_state(run, n_shards,
                                                mesh.shape.get("pod", 1))
        else:
            like = steps_mod.abstract_train_state(run)
        state = store.restore(s, like, state_sh,
                              on_mismatch=make_on_mismatch(run))
    return state, s
