"""Fault-tolerant training driver.

Structure (DESIGN.md §4, fault tolerance):

* ``Trainer`` — owns mesh, step function, checkpoint store, data source.
  One ``run()`` call trains from the latest checkpoint (or step 0) to
  ``total_steps``; data is addressed by step index (stateless pipeline),
  so resume needs nothing beyond the restored step counter.
* ``train_with_restarts`` — the supervision loop: catches step-time
  failures (including injected faults and watchdog timeouts), restores
  from the last good checkpoint and continues, up to ``max_restarts``.
  On a real cluster this loop runs per-host under the cluster manager;
  the logic is identical.
* Watchdog — a monitor thread that aborts a step stuck longer than
  ``watchdog_secs`` (straggler/hang mitigation: the sync train step means
  a dead peer manifests as a hang; the watchdog turns it into a restart).
* Elastic restarts — ``Trainer`` takes the mesh as a constructor arg;
  restoring a checkpoint saved on a different mesh works because
  checkpoints are mesh-agnostic (see checkpoint/store.py). See
  launch/elastic.py for the device-count-change path.

Fault injection for tests/demos: set ``REPRO_FAULT_AT_STEP=<k>`` to make
step k raise once (the file flag keeps it once-per-process-tree).

CLI::

  python -m repro.launch.train --arch qwen2-0.5b-reduced --steps 50 \
      --global-batch 8 --seq-len 128 --mode hadronio --ckpt /tmp/run1
"""
from __future__ import annotations

import argparse
import dataclasses
import os
import threading
import time
from typing import Callable, Optional

import jax
import numpy as np

from repro import compat
from repro.core.backends import available_modes, get_backend

from repro.configs.base import CommConfig, RunConfig, ShapeConfig
from repro.configs.registry import get_config
from repro.checkpoint import CheckpointStore
from repro.data import DataConfig, batch_at, make_source
from repro.launch import steps as steps_mod
from repro.launch.mesh import make_mesh
from repro.models import api


class WatchdogTimeout(RuntimeError):
    pass


class Watchdog:
    """Aborts the process out of a stuck step: arm() before blocking work,
    disarm() after. CPU-friendly (a single timer thread)."""

    def __init__(self, timeout_secs: float, on_timeout: Callable[[], None]):
        self.timeout = timeout_secs
        self.on_timeout = on_timeout
        self._timer: Optional[threading.Timer] = None

    def arm(self):
        self.disarm()
        self._timer = threading.Timer(self.timeout, self.on_timeout)
        self._timer.daemon = True
        self._timer.start()

    def disarm(self):
        if self._timer is not None:
            self._timer.cancel()
            self._timer = None


def _maybe_inject_fault(step: int):
    at = os.environ.get("REPRO_FAULT_AT_STEP")
    if at is None:
        return
    flag = os.environ.get("REPRO_FAULT_FLAG", "/tmp/repro_fault_fired")
    if int(at) == step and not os.path.exists(flag):
        with open(flag, "w") as f:
            f.write(str(step))
        raise RuntimeError(f"injected fault at step {step}")


class Trainer:
    def __init__(self, run: RunConfig, mesh, *, log_every: int = 10,
                 watchdog_secs: float = 0.0,
                 log_fn: Callable[[str], None] = print):
        self.run = run
        self.mesh = mesh
        self.log_every = log_every
        self.log_fn = log_fn
        self.n_shards = int(np.prod(list(mesh.shape.values())))
        self.store = (CheckpointStore(run.checkpoint_dir,
                                      keep=run.keep_checkpoints)
                      if run.checkpoint_dir else None)
        self.source = make_source(run)
        self.dc = DataConfig(seq_len=run.shape.seq_len,
                             global_batch=run.shape.global_batch)
        self.watchdog = None
        if watchdog_secs > 0:
            def _abort():
                # deliberately crash the step: the restart loop recovers
                self.log_fn(f"[watchdog] step exceeded {watchdog_secs}s")
                os._exit(42)
            self.watchdog = Watchdog(watchdog_secs, _abort)

        with compat.set_mesh(mesh):
            step_fn, self.state_sh, batch_sh_fn = \
                steps_mod.make_train_step(run, mesh)
            self._batch_sh_fn = batch_sh_fn
            self._jitted = jax.jit(
                step_fn,
                donate_argnums=(0,))

    # -- state ----------------------------------------------------------

    def init_state(self, seed: Optional[int] = None):
        rng = jax.random.PRNGKey(self.run.seed if seed is None else seed)
        pod = self.mesh.shape.get("pod", 1)
        if get_backend(self.run.comm.mode).manual:
            state = steps_mod.init_tac_state(rng, self.run, self.n_shards,
                                             pod)
        else:
            state = steps_mod.init_train_state(rng, self.run)
        return jax.device_put(state, self.state_sh)

    def abstract_state(self):
        if not get_backend(self.run.comm.mode).manual:
            return steps_mod.abstract_train_state(self.run)
        return steps_mod.abstract_tac_state(self.run, self.n_shards,
                                            self.mesh.shape.get("pod", 1))

    def restore_or_init(self):
        if self.store is not None:
            latest = self.store.latest_step()
            if latest is not None:
                from repro.launch.elastic import make_on_mismatch
                self.log_fn(f"[trainer] restoring step {latest}")
                state = self.store.restore(
                    latest, self.abstract_state(), self.state_sh,
                    on_mismatch=make_on_mismatch(self.run))
                return state, latest
        return self.init_state(), 0

    # -- loop ------------------------------------------------------------

    def run_loop(self) -> dict:
        run = self.run
        state, start = self.restore_or_init()
        metrics = {}
        losses = []
        with compat.set_mesh(self.mesh):
            # double-buffered host data: build batch k+1 while step k runs
            next_batch = batch_at(self.source, self.dc, start)
            for step in range(start, run.total_steps):
                _maybe_inject_fault(step)
                batch = jax.device_put(
                    next_batch, self._batch_sh_fn(self.mesh, next_batch))
                if self.watchdog:
                    self.watchdog.arm()
                state, metrics = self._jitted(state, batch)
                if step + 1 < run.total_steps:
                    next_batch = batch_at(self.source, self.dc, step + 1)
                loss = float(metrics["loss"])   # also blocks for watchdog
                if self.watchdog:
                    self.watchdog.disarm()
                losses.append(loss)
                if step % self.log_every == 0 or step == run.total_steps - 1:
                    self.log_fn(
                        f"[trainer] step {step} loss {loss:.4f} "
                        f"gnorm {float(metrics['grad_norm']):.3f} "
                        f"lr {float(metrics['lr']):.2e}")
                if self.store is not None and (
                        (step + 1) % run.checkpoint_every == 0
                        or step == run.total_steps - 1):
                    save = (self.store.save_async if run.async_checkpoint
                            else self.store.save)
                    save(step + 1, state,
                         extra={"loss": loss, "arch": run.model.name})
            if self.store is not None:
                self.store.wait()
        return {"final_loss": losses[-1] if losses else None,
                "losses": losses, "state": state}


def train_with_restarts(make_trainer: Callable[[], Trainer],
                        max_restarts: Optional[int] = None,
                        log_fn: Callable[[str], None] = print) -> dict:
    """Supervision loop: restart from the last checkpoint on failure."""
    trainer = make_trainer()
    limit = (trainer.run.max_restarts if max_restarts is None
             else max_restarts)
    attempts = 0
    while True:
        try:
            return trainer.run_loop()
        except Exception as e:         # noqa: BLE001 — supervision boundary
            attempts += 1
            if attempts > limit:
                raise
            log_fn(f"[supervisor] step failed ({type(e).__name__}: {e}); "
                   f"restart {attempts}/{limit}")
            trainer = make_trainer()   # fresh mesh/state; restores ckpt


# ---------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------


def build_run(args) -> RunConfig:
    cfg = get_config(args.arch)
    shape = ShapeConfig(name="cli", kind="train",
                        seq_len=args.seq_len, global_batch=args.global_batch)
    comm = CommConfig(mode=args.mode, slice_bytes=args.slice_bytes,
                      hierarchical=not args.flat_collectives,
                      compress=args.compress, pack=args.pack,
                      aggregate=args.aggregate, flush=args.flush)
    return RunConfig(model=cfg, shape=shape, comm=comm,
                     lr=args.lr, total_steps=args.steps,
                     warmup_steps=max(args.steps // 10, 1),
                     microbatches=args.microbatches,
                     checkpoint_dir=args.ckpt,
                     checkpoint_every=args.ckpt_every,
                     data_path=args.data, seed=args.seed)


def main() -> int:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--arch", required=True,
                   help="arch id; append -reduced for the smoke variant")
    p.add_argument("--steps", type=int, default=100)
    p.add_argument("--global-batch", type=int, default=8)
    p.add_argument("--seq-len", type=int, default=128)
    p.add_argument("--mode", default="hadronio",
                   choices=list(available_modes()))
    p.add_argument("--compress", default="none",
                   choices=list(CommConfig.COMPRESS_CODECS))
    p.add_argument("--pack", default="jnp",
                   choices=list(CommConfig.PACK_IMPLS),
                   help="pack/cast/EF copy-path impl (pallas = fused "
                        "ring_pack kernel; falls back to jnp off-TPU "
                        "toolchains)")
    p.add_argument("--aggregate", default="slice",
                   choices=list(CommConfig.AGGREGATES),
                   help="wire-flush granularity: 'slice' = one collective "
                        "per ring slice/bucket; 'channel' = coalesce each "
                        "channel's slices into one flush (paper §III-C "
                        "gathering write; bit-identical numerics)")
    p.add_argument("--flush", default="step",
                   choices=list(CommConfig.FLUSHES),
                   help="channel schedule: 'step' = round-robin groups "
                        "flushed at one end-of-exchange loop; 'ready' = "
                        "flush-when-ready (contiguous production-order "
                        "groups, each emitted the moment its last bucket "
                        "is staged — recovers overlap under "
                        "--aggregate channel; bit-identical numerics)")
    p.add_argument("--slice-bytes", type=int, default=4 * 1024 * 1024)
    p.add_argument("--flat-collectives", action="store_true")
    p.add_argument("--microbatches", type=int, default=1)
    p.add_argument("--lr", type=float, default=3e-4)
    p.add_argument("--ckpt", default="")
    p.add_argument("--ckpt-every", type=int, default=50)
    p.add_argument("--data", default="", help="binary shard dir (else synthetic)")
    p.add_argument("--seed", type=int, default=0)
    p.add_argument("--mesh", default="",
                   help="'4x2' style; default: all devices on one data axis")
    p.add_argument("--watchdog-secs", type=float, default=0.0)
    p.add_argument("--max-restarts", type=int, default=None)
    args = p.parse_args()

    if args.mesh:
        dims = tuple(int(x) for x in args.mesh.split("x"))
        axes = ("data", "model")[: len(dims)] if len(dims) <= 2 else \
            ("pod", "data", "model")
    else:
        dims = (len(jax.devices()),)
        axes = ("data",)
    run = build_run(args)
    mesh = make_mesh(dims, axes)

    out = train_with_restarts(
        lambda: Trainer(run, mesh, watchdog_secs=args.watchdog_secs),
        max_restarts=args.max_restarts)
    print(f"final loss: {out['final_loss']:.4f}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
