"""Compiled-HLO analysis: collective bytes/op-counts + roofline terms.

``cost_analysis()`` gives FLOPs and HBM bytes but not collective traffic;
per the brief we parse the compiled HLO text and sum operand sizes of every
all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute.
The same parser feeds the dry-run artifacts, the roofline table and the
gradsync benchmark (per-mode op counts — the paper's "number of send
calls" axis).

Hardware model (TPU v5e, per brief): 197 TFLOP/s bf16 per chip,
819 GB/s HBM, ~50 GB/s/link ICI.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field

import numpy as np

PEAK_FLOPS = 197e12          # bf16 per chip
HBM_BW = 819e9               # bytes/s per chip
ICI_BW = 50e9                # bytes/s per link (per chip, one direction)

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1,
    "f8e5m2": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "token": 0, "opaque": 0,
}

COLLECTIVE_KINDS = ("all-gather", "all-reduce", "reduce-scatter",
                    "all-to-all", "collective-permute")

# e.g. "bf16[16,512,128]{2,1,0}" or "f32[]"
_SHAPE_RE = re.compile(r"([a-z]\d*[a-z]*\d*(?:e\d+m\d+(?:fn)?)?)\[([\d,]*)\]")
# "%name = TYPE[...] op-name(", with optional leading spaces / ROOT
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?[%\w.\-]+\s*=\s*(\(?.+?\)?)\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(")


def shape_bytes(shape_str: str) -> int:
    """Total bytes of one 'dtype[dims]' (tuples handled by caller)."""
    total = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            n = int(np.prod([int(d) for d in dims.split(",")]))
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclass
class CollectiveStats:
    counts: dict = field(default_factory=dict)     # kind -> op count
    bytes_: dict = field(default_factory=dict)     # kind -> operand bytes

    @property
    def total_ops(self) -> int:
        return sum(self.counts.values())

    @property
    def total_bytes(self) -> int:
        return sum(self.bytes_.values())

    def as_dict(self) -> dict:
        return {"counts": dict(self.counts), "bytes": dict(self.bytes_),
                "total_ops": self.total_ops, "total_bytes": self.total_bytes}


# StableHLO (pre-optimization, ``lowered.as_text()``): the schedule the
# program EMITS, before XLA's combiner — the paper's "number of send
# calls" axis. e.g. "stablehlo.all_reduce"..."-> tensor<16x512xbf16>"
_MLIR_OP_RE = re.compile(
    r"stablehlo\.(all_reduce|all_gather|reduce_scatter|all_to_all|"
    r"collective_permute)")
_MLIR_TYPE_RE = re.compile(r"tensor<([0-9x]*)x?([a-z]\w*)>")
_MLIR_DTYPE_BYTES = {
    "i1": 1, "i8": 1, "ui8": 1, "i16": 2, "ui16": 2, "bf16": 2, "f16": 2,
    "i32": 4, "ui32": 4, "f32": 4, "i64": 8, "ui64": 8, "f64": 8,
}


def _mlir_result_bytes(tail: str) -> int:
    b = 0
    for tm in _MLIR_TYPE_RE.finditer(tail):
        dims, dt = tm.group(1), tm.group(2)
        if dt not in _MLIR_DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split("x"):
            if d:
                n *= int(d)
        b += n * _MLIR_DTYPE_BYTES[dt]
    return b


def stablehlo_collective_stats(mlir_text: str) -> CollectiveStats:
    """Collective op counts/bytes from pre-optimization StableHLO (one
    entry per emitted collective; result-type bytes). Region-form ops
    (all_reduce / reduce_scatter carry a reduction body) put their type
    signature on the region-closing ``}) : (...) -> ...`` line, so a
    pending-op stack matches types to ops."""
    st = CollectiveStats()
    pending: list[str] = []
    for line in mlir_text.splitlines():
        m = _MLIR_OP_RE.search(line)
        if m:
            kind = m.group(1).replace("_", "-")
            st.counts[kind] = st.counts.get(kind, 0) + 1
            if "->" in line and "tensor<" in line.rsplit("->", 1)[-1]:
                b = _mlir_result_bytes(line.rsplit("->", 1)[-1])
                st.bytes_[kind] = st.bytes_.get(kind, 0) + b
            else:
                pending.append(kind)
            continue
        stripped = line.lstrip()
        if pending and stripped.startswith("})") and "->" in line:
            kind = pending.pop()
            b = _mlir_result_bytes(line.rsplit("->", 1)[-1])
            st.bytes_[kind] = st.bytes_.get(kind, 0) + b
    return st


_MLIR_ANY_OP_RE = re.compile(r"stablehlo\.\w+")

# "replica_groups = dense<[[0, 1], [2, 3]]> : tensor<2x2xi64>" — also the
# splat form "dense<0> : tensor<1x1xi64>" XLA emits for degenerate groups
_MLIR_GROUPS_RE = re.compile(r"replica_groups\s*=\s*dense<(.*?)>\s*:")
_GROUP_BODY_RE = re.compile(r"\[([^\[\]]*)\]")

# the kinds the two-level fabric decomposes (permutes/all-to-all carry
# source-target pairs, not replica groups, and never ride leader lanes)
_POD_KINDS = ("all-reduce", "all-gather", "reduce-scatter")


def parse_replica_groups(line: str):
    """Replica groups of one StableHLO collective line, as a list of
    member-id lists — or None when the line carries no
    ``replica_groups`` attribute. The splat form ``dense<c>`` (every
    entry c — XLA's degenerate single-member groups) parses as
    ``[[c]]``."""
    m = _MLIR_GROUPS_RE.search(line)
    if m is None:
        return None
    body = m.group(1).strip()
    if not body.startswith("["):
        return [[int(body)]]
    return [[int(v) for v in g.split(",") if v.strip()]
            for g in _GROUP_BODY_RE.findall(body)]


def cross_pod_collective_count(mlir_text: str, in_pod_size: int) -> dict:
    """Classify every emitted reduce/gather collective as IN-POD or
    CROSS-POD — the headline evidence of the two-level serving fabric.
    Device ids in ``replica_groups`` are flattened mesh indices with the
    pod axis major (``make_serve_mesh`` builds the mesh that way), so
    device ``m`` lives in pod ``m // in_pod_size`` and an op is
    cross-pod iff some group spans two pods. Under leader emission the
    cross-pod count drops from n_channels to n_leader_channels per
    exchange while the flat schedule keeps every collective cross-pod.

    Returns ``{"in_pod": {kind: n}, "cross_pod": {kind: n},
    "in_pod_total": int, "cross_pod_total": int}``."""
    assert in_pod_size >= 1, in_pod_size
    out = {"in_pod": {}, "cross_pod": {}}
    for line in mlir_text.splitlines():
        m = _MLIR_OP_RE.search(line)
        if m is None:
            continue
        kind = m.group(1).replace("_", "-")
        if kind not in _POD_KINDS:
            continue
        groups = parse_replica_groups(line)
        if groups is None:
            continue
        cross = any(len({mem // in_pod_size for mem in g}) > 1
                    for g in groups)
        side = "cross_pod" if cross else "in_pod"
        out[side][kind] = out[side].get(kind, 0) + 1
    out["in_pod_total"] = sum(out["in_pod"].values())
    out["cross_pod_total"] = sum(out["cross_pod"].values())
    return out


def first_collective_position(mlir_text: str):
    """Emission-position evidence: ``(first, total)`` where ``first`` is
    the index of the FIRST collective among all emitted StableHLO ops
    and ``total`` the op count — or ``None`` when the program emits no
    collective at all (a serving jaxpr on 1 device, a local decode step:
    there is no emission position to report, and callers must not treat
    an arbitrary sentinel as one). The flush-when-ready schedule
    (``comm.flush="ready"``) moves the first gathering-write flush ahead
    of the later buckets' pack ops, so ``first/total`` drops measurably
    vs ``"step"`` — the §III-B flush-on-writable property read off the
    emitted program."""
    first, total = None, 0
    for line in mlir_text.splitlines():
        for m in _MLIR_ANY_OP_RE.finditer(line):
            if first is None and _MLIR_OP_RE.match(m.group(0)):
                first = total
            total += 1
    return None if first is None else (first, total)


def collective_stats(hlo_text: str) -> CollectiveStats:
    """Sum operand sizes (the result shape of -start/plain collective ops,
    which for these ops equals the transferred payload up to the gather
    factor) per collective kind.

    ``-done`` ops are skipped (the payload was counted at ``-start``).
    """
    st = CollectiveStats()
    for line in hlo_text.splitlines():
        if "-done(" in line:
            continue
        m = _INSTR_RE.match(line)
        if not m:
            continue
        shape_str, kind = m.group(1), m.group(2)
        b = shape_bytes(shape_str)
        st.counts[kind] = st.counts.get(kind, 0) + 1
        st.bytes_[kind] = st.bytes_.get(kind, 0) + b
    return st


def flops_and_bytes(compiled) -> dict:
    """FLOPs / HBM-byte estimates from compiled.cost_analysis()."""
    try:
        ca = compiled.cost_analysis()
    except Exception:
        return {}
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    out = {}
    for k in ("flops", "bytes accessed", "transcendentals",
              "bytes accessed output", "optimal_seconds"):
        if k in ca:
            out[k.replace(" ", "_")] = float(ca[k])
    return out


def memory_stats(compiled) -> dict:
    try:
        ma = compiled.memory_analysis()
    except Exception:
        return {}
    if ma is None:
        return {}
    out = {}
    for k in ("argument_size_in_bytes", "output_size_in_bytes",
              "temp_size_in_bytes", "generated_code_size_in_bytes",
              "alias_size_in_bytes", "host_temp_size_in_bytes"):
        v = getattr(ma, k, None)
        if v is not None:
            out[k] = int(v)
    return out


def roofline_terms(*, flops: float, hbm_bytes: float,
                   collective_bytes: float, n_chips: int,
                   flops_are_global: bool = True,
                   hbm_is_global: bool | None = None) -> dict:
    """The three roofline terms, in seconds (brief §Roofline).

    collective term uses per-chip link bandwidth; collective_bytes from the
    SPMD module is already per-chip traffic. ``hbm_is_global`` defaults to
    ``flops_are_global`` (HLO numbers are per-chip together; the analytic
    model passes flops globally but bytes per-chip).
    """
    if hbm_is_global is None:
        hbm_is_global = flops_are_global
    compute_s = flops / ((n_chips if flops_are_global else 1) * PEAK_FLOPS)
    memory_s = hbm_bytes / ((n_chips if hbm_is_global else 1) * HBM_BW)
    collective_s = collective_bytes / ICI_BW
    terms = {"compute_s": compute_s, "memory_s": memory_s,
             "collective_s": collective_s}
    terms["bottleneck"] = max(terms, key=lambda k: terms[k]).replace("_s", "")
    return terms


def analytic_hbm_bytes(cfg, shape, n_chips: int, *, tp: int = 16,
                       dp: int = 16) -> float:
    """Analytic per-chip HBM traffic per step (bytes) — the roofline
    memory-term numerator. HLO ``bytes accessed`` is unusable for this:
    it counts every operand of every HLO op pre-fusion AND counts loop
    bodies once, so it both over- and under-counts. The model below is a
    streaming lower bound (weights + activations + logits + optimizer /
    cache traffic), documented in EXPERIMENTS.md §Methodology.
    """
    p_bytes = cfg.param_count() * 2                    # bf16
    p_active = cfg.active_param_count() * 2
    d, L, V = cfg.d_model, cfg.num_layers, cfg.vocab_size
    tokens = shape.global_batch * shape.seq_len
    tokens_chip = tokens / n_chips                     # batch/dp x seq/tp(SP)
    tokens_row = tokens / dp                           # per data-shard row

    if shape.kind == "train":
        # weights: fwd read + remat re-read + bwd read of the TP shard
        w = 3.0 * p_bytes / tp
        # activations: residual+attn+mlp streams, ~6 passes of (tok, d)
        act = 6.0 * tokens_chip * d * 2 * L
        # logits: f32 write + read (CE) + bwd of the vocab/model shard
        logits = 3.0 * tokens_row * (V / tp) * 4
        # optimizer: grads f32 rw + two moments rw + param rw, sharded
        opt = (4 + 16 + 4) * (cfg.param_count() / n_chips)
        return w + act + logits + opt
    if shape.kind == "prefill":
        w = 1.0 * p_active / tp
        act = 4.0 * tokens_chip * d * 2 * L
        kv = 2.0 * tokens_chip * cfg.num_kv_heads * cfg.head_dim * 2 * L \
            if cfg.num_heads else 2.0 * tokens_chip * d * 2
        return w + act + kv
    # decode: every active weight shard read once; cache read + write
    w = 1.0 * p_active / tp
    eff = min(shape.seq_len, cfg.sliding_window) if cfg.sliding_window \
        else shape.seq_len
    if cfg.family == "ssm":
        hs = cfg.rwkv_head_size
        cache = (d // hs) * hs * hs * 4 * L * shape.global_batch
    elif cfg.family == "hybrid":
        lw = cfg.lru_width or d
        n_attn = sum(1 for i in range(L) if cfg.block_pattern[
            i % len(cfg.block_pattern)] == "local_attn")
        cache = (shape.global_batch
                 * (cfg.local_window * cfg.num_kv_heads * cfg.head_dim * 2
                    * n_attn + lw * 4 * (L - n_attn)))
    else:
        cache = (shape.global_batch * eff * cfg.num_kv_heads
                 * cfg.head_dim * 2 * 2 * L)
        if cfg.family == "encdec":
            cache += (shape.global_batch * cfg.num_frames
                      * cfg.num_kv_heads * cfg.head_dim * 2 * 2 * L)
    return w + 1.5 * cache / n_chips     # read whole cache + write 1 slot


def model_flops(cfg, shape, n_tokens: int | None = None) -> float:
    """MODEL_FLOPS per the brief: 6·N·D (dense) / 6·N_active·D (MoE) for a
    train step, with two standard refinements so the "useful compute" ratio
    is honest: the token-embedding table does no matmul FLOPs (it is a
    lookup — only the LM head's V·d matmul counts, and it is already in N),
    and causal attention contributes 12·L·H·dh·S_eff per token (S_eff =
    effective mean KV span) on top of the parameter matmuls.
    """
    n_active = cfg.active_param_count()
    # remove the lookup-only embedding table from the matmul-param count
    n_matmul = n_active - cfg.vocab_size * cfg.d_model
    if n_tokens is None:
        n_tokens = shape.global_batch * shape.seq_len

    def attn_span(kv_len: float) -> float:
        if cfg.sliding_window:
            return min(kv_len, float(cfg.sliding_window))
        return kv_len

    # attention score+value FLOPs per token per attention layer (fwd):
    # 2·(H·dh)·span for QK^T plus 2·(H·dh)·span for PV.
    h_dim = cfg.num_heads * cfg.head_dim if cfg.num_heads else 0
    if cfg.family == "hybrid" and cfg.block_pattern:
        n_attn = sum(1 for i in range(cfg.num_layers)
                     if cfg.block_pattern[i % len(cfg.block_pattern)]
                     == "local_attn")
        window = cfg.local_window
    elif cfg.family == "ssm":
        n_attn, window = 0, 0
    else:
        n_attn, window = cfg.num_layers, cfg.sliding_window

    def attn_flops_fwd(seq: float, causal_mean: bool) -> float:
        span = seq / 2 if causal_mean else seq
        if window:
            span = min(span, float(window))
        return 4.0 * h_dim * span * n_attn

    if shape.kind == "train":
        per_tok = 2.0 * n_matmul + attn_flops_fwd(shape.seq_len, True)
        return 3.0 * per_tok * n_tokens          # fwd + bwd = 3x fwd
    if shape.kind == "prefill":
        per_tok = 2.0 * n_matmul + attn_flops_fwd(shape.seq_len, True)
        return per_tok * n_tokens
    # decode: one token per sequence; attention spans the whole cache
    per_tok = 2.0 * n_matmul + attn_flops_fwd(shape.seq_len, False)
    return per_tok * shape.global_batch
