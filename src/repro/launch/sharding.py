"""Logical-axis -> mesh-axis sharding rules with divisibility fallback.

Parameters carry logical axes on their ParamSpecs; activations name their
axes at ``shard_fn`` call sites. The rules below map logical names to mesh
axes; a dim that is not divisible by the target axis size falls back to
replicated (recorded — the roofline notes surface these fallbacks, e.g.
qwen1.5-4b's 20 heads on a 16-way model axis).

Parallelism coverage (DESIGN.md §4): TP = heads/mlp/vocab/experts/lru over
``model``; FSDP = embed dims over ``data``; DP = batch over (pod, data);
SP = seq over ``model``; EP = experts over ``model``.
"""
from __future__ import annotations

from typing import Any, Optional

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.models.common import ParamSpec

PyTree = Any

# logical axis -> candidate mesh axes, tried in order
PARAM_RULES: dict[str, tuple] = {
    "vocab": ("model",),
    "heads": ("model",),
    "kv_heads": ("model",),
    "mlp": ("model",),
    "experts": ("model",),
    "lru": ("model",),
    "lru_blocks": ("model",),
    "embed": ("data",),       # FSDP
    "frames": (),
    "seq": (),
    "layers": (),
}

ACT_RULES: dict[str, tuple] = {
    "batch": (("pod", "data"),),
    "seq": ("model",),
    "seq_model": ("model",),    # decode KV length (flash-decoding layout)
    "heads": ("model",),
    "kv_heads": ("model",),
    "mlp": ("model",),
    "vocab": ("model",),
    "lru": ("model",),
    "experts": ("model",),
    "expert_cap": ("data",),
    "seq_kv": ("data",),
}


def _mesh_axes_size(mesh, axes) -> int:
    if isinstance(axes, str):
        axes = (axes,)
    n = 1
    for a in axes:
        n *= mesh.shape[a] if a in mesh.axis_names else 0
    return n


def _resolve(mesh, rules: dict, logical: Optional[str], dim: int,
             used: set, *, fsdp: bool = True):
    """Pick a mesh axis (or axis tuple) for one dim, or None."""
    if logical is None or logical not in rules:
        return None
    if logical == "embed" and not fsdp:
        return None
    for cand in rules[logical]:
        names = (cand,) if isinstance(cand, str) else tuple(cand)
        # drop axes not present in this mesh (e.g. 'pod' on single pod)
        names = tuple(a for a in names if a in mesh.axis_names)
        if not names:
            continue
        size = int(np.prod([mesh.shape[a] for a in names]))
        if size <= 1 or dim % size != 0:
            continue
        if any(a in used for a in names):
            continue
        used.update(names)
        return names if len(names) > 1 else names[0]
    return None


def spec_partition(mesh, spec: ParamSpec, *, fsdp: bool = True) -> P:
    used: set = set()
    parts = [_resolve(mesh, PARAM_RULES, ax, dim, used, fsdp=fsdp)
             for dim, ax in zip(spec.shape, spec.axes)]
    return P(*parts)


def param_shardings(mesh, specs: PyTree, *, fsdp: bool = True) -> PyTree:
    return jax.tree.map(
        lambda s: NamedSharding(mesh, spec_partition(mesh, s, fsdp=fsdp)),
        specs, is_leaf=lambda x: isinstance(x, ParamSpec))


def make_shard_fn(mesh, *, manual_axes: tuple = (),
                  sp_explicit: bool | None = None):
    """Activation-constraint function threaded through model code.

    ``manual_axes``: axes already manual (inside a partial-manual
    shard_map) — constraints must not mention them.

    ``sp_explicit`` (default from env ``REPRO_SP_EXPLICIT``): Megatron-SP
    transition pinning — the ``seq_gather`` logical axis becomes an
    explicit *replicated* constraint, so each block performs exactly one
    seq->replicated all-gather before its projections and one
    reduce-scatter at the residual (instead of GSPMD's per-einsum
    resharding). §Perf iteration A1.
    """
    if mesh is None:
        from repro.models.layers import no_shard
        return no_shard
    if sp_explicit is None:
        import os
        sp_explicit = os.environ.get("REPRO_SP_EXPLICIT", "") == "1"

    import os
    no_sp = os.environ.get("REPRO_NO_SP", "") == "1"

    def shard_fn(x, logical):
        if "seq_gather" in logical:
            if not sp_explicit:
                return x
            used: set = set(manual_axes)
            parts = [
                _resolve(mesh, ACT_RULES, "batch", x.shape[i], used)
                if ax == "batch" else None
                for i, ax in enumerate(logical)]
            return jax.lax.with_sharding_constraint(
                x, NamedSharding(mesh, P(*parts)))
        used = set(manual_axes)
        parts = []
        force = False
        for dim, ax in zip(x.shape, logical):
            if ax == "rep":               # explicit replication pin
                force = True
                parts.append(None)
                continue
            if no_sp and ax == "seq":     # §Perf A2: TP-AR, no seq shard
                parts.append(None)
                continue
            r = _resolve(mesh, ACT_RULES, ax, dim, used)
            parts.append(r)
        if not force and all(p is None for p in parts):
            return x
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(mesh, P(*parts)))

    return shard_fn


def batch_sharding(mesh, tree: PyTree) -> PyTree:
    """Input batch: leading dim over the DP axes when divisible."""
    dp = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    size = int(np.prod([mesh.shape[a] for a in dp])) if dp else 1

    def one(x):
        shape = x.shape
        if shape and size > 1 and shape[0] % size == 0:
            return NamedSharding(mesh, P(dp if len(dp) > 1 else dp[0]))
        return NamedSharding(mesh, P())

    return jax.tree.map(one, tree)


def cache_shardings(mesh, cache_tree: PyTree) -> PyTree:
    """KV caches / recurrent states: batch over DP when divisible; else
    the longest remaining dim over 'data' (long_500k: batch 1, shard the
    cache length instead). The model axis takes the kv-heads dim when it
    divides, else the sequence/length dim — a 110B decode_32k cache is
    687 GB and MUST shard over both axes (tests/test_sharding.py).
    Leading 'layers' dims are never sharded."""
    dp = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    size = int(np.prod([mesh.shape[a] for a in dp])) if dp else 1
    model = mesh.shape.get("model", 1)

    def one(x):
        # heuristic: dims are (layers?, batch, length/state..., heads, dh)
        parts: list = [None] * len(x.shape)
        # batch is dim 1 under a leading layers dim (ndim >= 3), else 0
        bdim = 1 if len(x.shape) >= 3 else 0
        if size > 1 and x.shape[bdim] % size == 0:
            parts[bdim] = dp if len(dp) > 1 else dp[0]
        elif "data" in mesh.axis_names and len(x.shape) > bdim + 1:
            # shard the longest non-batch dim over data
            rest = [(d, i) for i, d in enumerate(x.shape) if i > bdim]
            if rest:
                d, i = max(rest)
                if d % mesh.shape["data"] == 0:
                    parts[i] = "data"
        if model > 1:
            candidates = []
            if len(x.shape) >= 4:
                candidates.append(len(x.shape) - 2)   # kv-heads
            if len(x.shape) >= 3:
                candidates.append(bdim + 1)           # seq / length / heads
            for i in candidates:
                if parts[i] is None and x.shape[i] % model == 0:
                    parts[i] = "model"
                    break
        return NamedSharding(mesh, P(*parts))

    return jax.tree.map(one, cache_tree)
