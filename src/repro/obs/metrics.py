"""Metrics registry for the Observatory telemetry plane.

Nine subsystems each grew their own ad-hoc counters (``PollStats``,
``EmissionStats``, ``EventLoopGroup.failures``/``heartbeats``, tenant
``fairness_counters``, the supervisor's healing trace, admission /
shedding outcomes). This module gives them ONE snapshot surface without
rewriting any of them: typed metrics with a fixed label taxonomy, plus
THIN PULL-BASED ADAPTERS (the ``publish_*`` functions) that scrape the
live ad-hoc counters into a registry at collection time. The existing
objects stay the source of truth — their tests keep passing — and the
registry is the unified export (``snapshot()`` / ``to_json()``).

Determinism contract (docs/OBSERVABILITY.md):

* **counters / gauges** are DETERMINISTIC: same seed + same ChaosPlan ⇒
  identical values (they count events on seeded or structural paths —
  waits, stalls, delays, drops, heal actions, fairness strides).
* **volatile gauges** carry wall-clock-COUPLED counts (busy-poll
  ``spins``, adaptive ``parks``) — real telemetry, but excluded from
  the deterministic snapshot because their values depend on how fast
  the host happened to run.
* **histograms** hold wall-clock measurements (durations). Count/sum/
  min/max/percentiles are reported; nothing in them participates in
  the determinism contract.

``snapshot()`` returns ``{"counters", "gauges", "volatile",
"histograms"}``; ``deterministic_snapshot()`` returns only the first
two — the byte-comparable view the telemetry determinism matrix tests
(same seed ⇒ identical ``to_json(deterministic=True)`` bytes).

:class:`RingLog` is the shared bounded evidence container (the
dispatch-log / chaos ``fired``/``emissions`` satellite): list-like
(append/extend/iter/index/slice/==) with a ring capacity and a
``dropped`` eviction counter the registry exposes.
"""
from __future__ import annotations

import json
from collections import deque
from typing import Any, Dict, Iterable, Optional, Tuple

# The label taxonomy. Closed on purpose: a bounded, documented label set
# is what keeps snapshots joinable across subsystems (an unknown key is
# a bug in the instrumentation, not a new dimension).
LABEL_KEYS = frozenset({"channel", "loop", "tenant", "mode", "pod",
                        "kind", "scope", "scenario", "seed"})


def _label_key(name: str, labels: Dict[str, Any]) -> str:
    for k in labels:
        if k not in LABEL_KEYS:
            raise ValueError(
                f"unknown metric label {k!r} on {name!r}: the taxonomy is "
                f"{sorted(LABEL_KEYS)} (docs/OBSERVABILITY.md — extend it "
                "there first)")
    if not labels:
        return name
    inner = ",".join(f"{k}={labels[k]}" for k in sorted(labels))
    return f"{name}{{{inner}}}"


class Counter:
    """Monotone event count (deterministic by contract)."""
    __slots__ = ("key", "value")

    def __init__(self, key: str):
        self.key = key
        self.value = 0

    def inc(self, n: int = 1) -> None:
        self.value += int(n)


class Gauge:
    """Point-in-time value (deterministic unless ``volatile``)."""
    __slots__ = ("key", "value", "volatile")

    def __init__(self, key: str, volatile: bool = False):
        self.key = key
        self.value = 0
        self.volatile = volatile

    def set(self, v) -> None:
        self.value = int(v) if float(v).is_integer() else float(v)


class Histogram:
    """Wall-clock distribution: bounded raw samples (ring — long serves
    must not grow memory) + running count/sum/min/max."""
    __slots__ = ("key", "count", "total", "min", "max", "_samples")

    def __init__(self, key: str, sample_capacity: int = 2048):
        self.key = key
        self.count = 0
        self.total = 0.0
        self.min: Optional[float] = None
        self.max: Optional[float] = None
        self._samples: deque = deque(maxlen=int(sample_capacity))

    def observe(self, v: float) -> None:
        v = float(v)
        self.count += 1
        self.total += v
        self.min = v if self.min is None else min(self.min, v)
        self.max = v if self.max is None else max(self.max, v)
        self._samples.append(v)

    def summary(self) -> dict:
        out = {"count": self.count, "sum": self.total,
               "min": self.min, "max": self.max}
        if self._samples:
            xs = sorted(self._samples)
            for q, lab in ((0.5, "p50"), (0.99, "p99")):
                out[lab] = xs[min(len(xs) - 1, int(q * len(xs)))]
        return out


class MetricsRegistry:
    """Typed metrics keyed by ``name{label=value,...}`` (labels sorted,
    so the key — and therefore the snapshot — is order-independent)."""

    def __init__(self, *, histogram_samples: int = 2048):
        self._histogram_samples = histogram_samples
        self._metrics: Dict[str, Any] = {}

    def _get(self, cls, name: str, labels: Dict[str, Any], **kw):
        key = _label_key(name, labels)
        m = self._metrics.get(key)
        if m is None:
            m = self._metrics[key] = cls(key, **kw)
        elif not isinstance(m, cls):
            raise TypeError(
                f"metric {key!r} already registered as "
                f"{type(m).__name__}, requested {cls.__name__}")
        return m

    def counter(self, name: str, **labels) -> Counter:
        return self._get(Counter, name, labels)

    def gauge(self, name: str, *, volatile: bool = False,
              **labels) -> Gauge:
        g = self._get(Gauge, name, labels, volatile=volatile)
        g.volatile = g.volatile or volatile
        return g

    def histogram(self, name: str, **labels) -> Histogram:
        return self._get(Histogram, name, labels,
                         sample_capacity=self._histogram_samples)

    def clear(self) -> None:
        self._metrics.clear()

    # -- the unified view ----------------------------------------------

    def snapshot(self) -> dict:
        """``{"counters", "gauges", "volatile", "histograms"}`` — each a
        key-sorted dict. Counters + gauges are the deterministic half;
        volatile gauges and histograms carry wall-clock."""
        out: dict = {"counters": {}, "gauges": {}, "volatile": {},
                     "histograms": {}}
        for key in sorted(self._metrics):
            m = self._metrics[key]
            if isinstance(m, Counter):
                out["counters"][key] = m.value
            elif isinstance(m, Gauge):
                out["volatile" if m.volatile else "gauges"][key] = m.value
            else:
                out["histograms"][key] = m.summary()
        return out

    def deterministic_snapshot(self) -> dict:
        snap = self.snapshot()
        return {"counters": snap["counters"], "gauges": snap["gauges"]}

    def to_json(self, *, deterministic: bool = False,
                indent: Optional[int] = 1) -> str:
        snap = (self.deterministic_snapshot() if deterministic
                else self.snapshot())
        return json.dumps(snap, indent=indent, sort_keys=True)


# ---------------------------------------------------------------------------
# RingLog — the bounded evidence container
# ---------------------------------------------------------------------------


class RingLog:
    """Bounded append-mostly log: the newest ``capacity`` entries with a
    ``dropped`` eviction count. List-like where the call sites need it —
    ``append``/``extend``/``len``/``iter``/``bool``/indexing/slicing and
    ``==`` against any sequence (the fairness tests compare dispatch
    logs to plain lists)."""

    def __init__(self, capacity: int = 65536):
        if capacity < 1:
            raise ValueError(f"RingLog capacity must be >= 1: {capacity}")
        self.capacity = int(capacity)
        self._q: deque = deque(maxlen=self.capacity)
        self.dropped = 0

    def append(self, item) -> None:
        if len(self._q) == self.capacity:
            self.dropped += 1
        self._q.append(item)

    def extend(self, items: Iterable) -> None:
        for it in items:
            self.append(it)

    def clear(self) -> None:
        self._q.clear()

    def __len__(self) -> int:
        return len(self._q)

    def __bool__(self) -> bool:
        return bool(self._q)

    def __iter__(self):
        return iter(self._q)

    def __getitem__(self, i):
        if isinstance(i, slice):
            return list(self._q)[i]
        return self._q[i]

    def __eq__(self, other) -> bool:
        if isinstance(other, RingLog):
            return list(self._q) == list(other._q)
        if isinstance(other, (list, tuple)):
            return list(self._q) == list(other)
        return NotImplemented

    def __repr__(self) -> str:
        return (f"RingLog({list(self._q)!r}, capacity={self.capacity}, "
                f"dropped={self.dropped})")


# ---------------------------------------------------------------------------
# Thin adapters: scrape the live ad-hoc counters into a registry. Pull-based
# on purpose — the producing subsystems keep their own state (and tests);
# collection is a read-only pass at snapshot time.
# ---------------------------------------------------------------------------

# PollStats fields that are deterministic (seeded/structural) vs coupled
# to host speed (busy-spin probe counts, adaptive park decisions).
_POLL_DETERMINISTIC = ("waits", "stalls", "delays")
_POLL_VOLATILE = ("spins", "parks")


def publish_poll_stats(reg: MetricsRegistry, stats, **labels) -> None:
    """One ``PollStats`` (or anything with its fields) -> ``poll.*``."""
    for f in _POLL_DETERMINISTIC:
        reg.gauge(f"poll.{f}", **labels).set(getattr(stats, f))
    for f in _POLL_VOLATILE:
        reg.gauge(f"poll.{f}", volatile=True, **labels).set(
            getattr(stats, f))


def publish_emission_stats(reg: MetricsRegistry, stats, **labels) -> None:
    """One ``pipeline.EmissionStats`` -> ``emission.*`` (trace-time
    counters: deterministic for a given program trace)."""
    for f in ("drops", "dups", "allocs"):
        reg.gauge(f"emission.{f}", **labels).set(getattr(stats, f))


def publish_pipeline(reg: MetricsRegistry, **labels) -> None:
    """The ACTIVE emission-stats scope (``pipeline.current_stats()`` —
    the module global unless a ``stats_scope`` is armed)."""
    from repro.core.backends import pipeline    # lazy: obs must not
    #                                             import the core at
    #                                             module load (the core
    #                                             imports obs.trace)
    publish_emission_stats(reg, pipeline.current_stats(), **labels)


def publish_group(reg: MetricsRegistry, group, **labels) -> None:
    """An ``EventLoopGroup``: per-loop poll stats (lifetime — restart
    folds included), heartbeats/restarts/queue depth, failure and
    dispatch-log counters, tenant fairness."""
    for l in group.loops:
        st = l.poll_stats() if hasattr(l, "poll_stats") else l.poller.stats
        publish_poll_stats(reg, st, loop=l.index, **labels)
        reg.gauge("loop.heartbeats", loop=l.index, **labels).set(
            l.heartbeats)
        reg.gauge("loop.restarts", loop=l.index, **labels).set(l.restarts)
        reg.gauge("loop.queue_depth", loop=l.index, **labels).set(
            len(l.queue))
        eng = getattr(l, "engine", None)
        if eng is not None:
            reg.gauge("engine.admit_prefills", loop=l.index, **labels).set(
                eng.admit_prefills)
    reg.gauge("group.loops", **labels).set(group.n_loops)
    reg.gauge("group.loop_failures", **labels).set(group.loop_failures)
    for name, n in getattr(group, "fairness_counters", {}).items():
        reg.gauge("tenant.dispatched", tenant=name, **labels).set(n)
    dlog = getattr(group, "dispatch_log", None)
    if dlog is not None:
        reg.gauge("group.dispatch_log_len", **labels).set(len(dlog))
        if hasattr(dlog, "dropped"):
            reg.gauge("group.dispatch_log_dropped", **labels).set(
                dlog.dropped)


def publish_supervisor(reg: MetricsRegistry, sup, **labels) -> None:
    """A ``Supervisor``: rounds, heal actions by kind, outcomes by
    status, per-channel emission counts, shed/served totals."""
    reg.gauge("supervisor.rounds", **labels).set(sup.rounds)
    by_kind: Dict[str, int] = {}
    for a in sup.trace:
        by_kind[a.kind] = by_kind.get(a.kind, 0) + 1
    for k, n in by_kind.items():
        reg.gauge("heal.actions", kind=k, **labels).set(n)
    reg.gauge("heal.total", **labels).set(len(sup.trace))
    by_status: Dict[str, int] = {}
    for o in sup.outcomes.values():
        by_status[o.status] = by_status.get(o.status, 0) + 1
    for s, n in by_status.items():
        reg.gauge("outcome.requests", kind=s, **labels).set(n)
    for c, n in sorted(sup.emission_counts.items()):
        reg.gauge("channel.emissions", channel=c, **labels).set(n)


def publish_chaos(reg: MetricsRegistry, result, **labels) -> None:
    """A ``ChaosResult`` / ``SupervisedResult``: injection + evidence
    counts (the fired/emissions RingLogs) and the recovery bit."""
    reg.gauge("chaos.injected", **labels).set(len(result.fired))
    reg.gauge("chaos.drains", **labels).set(len(result.drains))
    reg.gauge("chaos.emissions", **labels).set(len(result.emissions))
    reg.gauge("chaos.recovered", **labels).set(
        1 if getattr(result.report, "recovered", False) else 0)
    if result.poll_stats is not None:
        publish_poll_stats(reg, result.poll_stats, **labels)


def collect(*, group=None, supervisor=None, registry=None,
            **labels) -> MetricsRegistry:
    """The one-call snapshot builder: a fresh registry (or ``registry``)
    with everything reachable published — pipeline emission stats
    always; group and supervisor when given (a supervisor implies its
    group)."""
    reg = registry if registry is not None else MetricsRegistry()
    publish_pipeline(reg, **labels)
    if supervisor is not None:
        publish_supervisor(reg, supervisor, **labels)
        if group is None:
            group = getattr(supervisor, "group", None)
    if group is not None:
        publish_group(reg, group, **labels)
    return reg
