"""Perf-regression gate: compare two ``BENCH_*.json`` artifacts.

Every benchmark script emits rows through ``benchmarks/common.py`` with
a shared schema — ``(benchmark, figure, mode, msg_bytes, channels,
metric, value, unit, kind, seed)`` — and CI uploads the resulting
``BENCH_*.json`` files per run. Until now those artifacts were
advisory: a latency doubling shipped silently. This module makes the
trajectory ENFORCED: :func:`diff` joins a candidate artifact against a
baseline on the row identity ``(benchmark, figure, mode, msg_bytes,
channels, metric, unit)`` (seed intentionally excluded — reseeded rows
must still be comparable) and judges each pair against a per-metric
:class:`Tolerance` band; ``benchmarks/bench_diff.py`` is the CLI that
exits non-zero on any regression.

Default tolerance policy (override per-pattern via the CLI):

* ``measured`` rows in time units (us/ms/s) — wall-clock on shared CI
  runners is noisy, so the default band is generous (rel=1.0, i.e. a
  2x slowdown trips the gate) and LOWER IS BETTER.
* ``derived`` rows in time units — analytic model outputs, tight band
  (rel=0.05), lower is better.
* ``derived`` rows in structural units (ops, B, bytes, frac, slices,
  ratio, x) — EXACT: these are deterministic functions of the config;
  any drift is a real behavior change.
* any row in unit ``count`` — IGNORED by default: poll spins/parks are
  wall-clock-coupled counters (see docs/OBSERVABILITY.md) and obs
  snapshot rows are gated by their own determinism tests instead.
* ``measured`` rows in non-time units — ignored (throughput-style rows
  mirror a time row that is already gated).
"""
from __future__ import annotations

import fnmatch
import json
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

TIME_UNITS = frozenset({"us", "ms", "s", "ns"})
EXACT_UNITS = frozenset({"ops", "B", "bytes", "frac", "slices", "ratio",
                         "x", "tok"})

Key = Tuple[str, str, str, object, object, str, str]


def row_key(row: dict) -> Key:
    return (str(row.get("benchmark", "")), str(row.get("figure", "")),
            str(row.get("mode", "")), row.get("msg_bytes"),
            row.get("channels"), str(row.get("metric", "")),
            str(row.get("unit", "")))


def key_label(key: Key) -> str:
    bench, fig, mode, msg, chans, metric, unit = key
    parts = [bench, mode, metric]
    if msg not in (None, "", 0):
        parts.append(f"{msg}B")
    if chans not in (None, "", 0):
        parts.append(f"c{chans}")
    return ":".join(str(p) for p in parts if p != "") + f" [{unit}]"


@dataclass
class Tolerance:
    """One comparison band. ``direction``:

    * ``lower_is_better`` — regression iff cand > base * (1+rel) + abs
    * ``higher_is_better`` — regression iff cand < base * (1-rel) - abs
    * ``exact`` — regression iff |cand - base| > abs
    * ``ignore`` — never a regression
    """
    rel: float = 0.0
    abs: float = 0.0
    direction: str = "lower_is_better"

    def judge(self, base: float, cand: float) -> str:
        """-> "ok" | "regression" | "improved"."""
        if self.direction == "ignore":
            return "ok"
        if self.direction == "exact":
            return "ok" if abs(cand - base) <= max(self.abs, 0.0) else \
                "regression"
        if self.direction == "higher_is_better":
            lo = base * (1.0 - self.rel) - self.abs
            hi = base * (1.0 + self.rel) + self.abs
            if cand < lo:
                return "regression"
            return "improved" if cand > hi else "ok"
        # lower_is_better
        hi = base * (1.0 + self.rel) + self.abs
        lo = base * (1.0 - self.rel) - self.abs
        if cand > hi:
            return "regression"
        return "improved" if cand < lo else "ok"


def default_tolerance(row: dict, *, tol_measured: float = 1.0,
                      tol_derived_time: float = 0.05) -> Tolerance:
    """The policy table above, parameterized on the two band widths."""
    unit = str(row.get("unit", ""))
    kind = str(row.get("kind", "measured"))
    if unit == "count":
        return Tolerance(direction="ignore")
    if unit in TIME_UNITS:
        rel = tol_measured if kind == "measured" else tol_derived_time
        return Tolerance(rel=rel, direction="lower_is_better")
    if kind == "derived" and unit in EXACT_UNITS:
        return Tolerance(abs=1e-9, direction="exact")
    return Tolerance(direction="ignore")


@dataclass
class Delta:
    key: Key
    status: str                     # ok|regression|improved|missing|added|ignored
    base: Optional[float] = None
    cand: Optional[float] = None
    tol: Optional[Tolerance] = None

    @property
    def label(self) -> str:
        return key_label(self.key)

    @property
    def change(self) -> Optional[float]:
        if self.base in (None, 0) or self.cand is None:
            return None
        return (self.cand - self.base) / self.base

    def describe(self) -> str:
        if self.status in ("missing", "added"):
            return f"{self.status:>10}  {self.label}"
        ch = self.change
        pct = "" if ch is None else f"  {ch:+.1%}"
        return (f"{self.status:>10}  {self.label}  "
                f"{self.base!r} -> {self.cand!r}{pct}")


@dataclass
class DiffReport:
    deltas: List[Delta] = field(default_factory=list)

    def of(self, status: str) -> List[Delta]:
        return [d for d in self.deltas if d.status == status]

    @property
    def regressions(self) -> List[Delta]:
        return self.of("regression")

    @property
    def ok(self) -> bool:
        return not self.regressions

    def summary(self) -> str:
        counts: Dict[str, int] = {}
        for d in self.deltas:
            counts[d.status] = counts.get(d.status, 0) + 1
        return ", ".join(f"{counts[s]} {s}" for s in
                         ("regression", "improved", "ok", "ignored",
                          "missing", "added") if s in counts)


def load_rows(path: str) -> List[dict]:
    """A ``BENCH_*.json`` artifact: a JSON array of row dicts (the
    format ``benchmarks/common.py:write_json`` emits)."""
    with open(path) as f:
        doc = json.load(f)
    if isinstance(doc, dict):                 # tolerate {"rows": [...]}
        doc = doc.get("rows", [])
    if not isinstance(doc, list):
        raise ValueError(f"{path}: expected a JSON array of bench rows")
    return [r for r in doc if isinstance(r, dict)]


def _index(rows: List[dict]) -> Dict[Key, dict]:
    out: Dict[Key, dict] = {}
    for r in rows:
        out[row_key(r)] = r                   # last write wins (reruns)
    return out


def diff(base_rows: List[dict], cand_rows: List[dict], *,
         tol_measured: float = 1.0, tol_derived_time: float = 0.05,
         overrides: Optional[List[Tuple[str, Tolerance]]] = None,
         ignore: Optional[List[str]] = None) -> DiffReport:
    """Join candidate against baseline and judge every shared key.

    ``overrides`` is an ordered ``[(glob, Tolerance), ...]`` list —
    globs match either the bare metric or ``benchmark:metric``; the
    FIRST match wins and replaces the default policy for that row.
    ``ignore`` globs (same matching) force status "ignored".
    """
    base_ix, cand_ix = _index(base_rows), _index(cand_rows)
    rep = DiffReport()

    def _match(key: Key, pat: str) -> bool:
        bench, _, _, _, _, metric, _ = key
        return (fnmatch.fnmatch(metric, pat)
                or fnmatch.fnmatch(f"{bench}:{metric}", pat))

    for key in sorted(set(base_ix) | set(cand_ix), key=str):
        b, c = base_ix.get(key), cand_ix.get(key)
        if c is None:
            rep.deltas.append(Delta(key, "missing",
                                    base=b.get("value")))
            continue
        if b is None:
            rep.deltas.append(Delta(key, "added", cand=c.get("value")))
            continue
        if ignore and any(_match(key, p) for p in ignore):
            rep.deltas.append(Delta(key, "ignored", base=b.get("value"),
                                    cand=c.get("value")))
            continue
        tol = None
        for pat, t in (overrides or []):
            if _match(key, pat):
                tol = t
                break
        if tol is None:
            tol = default_tolerance(c, tol_measured=tol_measured,
                                    tol_derived_time=tol_derived_time)
        try:
            bv, cv = float(b.get("value")), float(c.get("value"))
        except (TypeError, ValueError):
            status = "ok" if b.get("value") == c.get("value") else \
                "regression"
            rep.deltas.append(Delta(key, status, base=b.get("value"),
                                    cand=c.get("value"), tol=tol))
            continue
        status = tol.judge(bv, cv)
        if tol.direction == "ignore":
            status = "ignored"
        rep.deltas.append(Delta(key, status, base=bv, cand=cv, tol=tol))
    return rep


def diff_files(base_path: str, cand_path: str, **kw) -> DiffReport:
    return diff(load_rows(base_path), load_rows(cand_path), **kw)
