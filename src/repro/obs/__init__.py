"""Observatory: the unified telemetry plane.

Three layers (docs/OBSERVABILITY.md):

* :mod:`repro.obs.metrics` — the typed metrics registry with the fixed
  label taxonomy, the :class:`RingLog` bounded evidence container, and
  the pull-based ``publish_*`` adapters that scrape every subsystem's
  existing ad-hoc counters into one ``snapshot()``.
* :mod:`repro.obs.trace` — ring-buffered nested span tracing over the
  staged emission API and the serving plane, Chrome-trace/Perfetto
  export, zero overhead when disabled.
* :mod:`repro.obs.baseline` — the perf-regression gate comparing two
  ``BENCH_*.json`` artifacts with per-metric tolerance bands (CLI:
  ``benchmarks/bench_diff.py``).

The tracing gate is re-exported here so instrumentation sites and
entrypoints write ``obs.enable()`` / ``obs.enabled()`` / ``obs.span``
without caring which layer owns the global.
"""
from repro.obs.trace import (KINDS, Span, TraceRecorder, begin, capture,
                             complete, containing, disable, enable,
                             enabled, end, recorder, span, well_formed)
from repro.obs.metrics import (LABEL_KEYS, Counter, Gauge, Histogram,
                               MetricsRegistry, RingLog, collect,
                               publish_chaos, publish_emission_stats,
                               publish_group, publish_pipeline,
                               publish_poll_stats, publish_supervisor)
from repro.obs.baseline import (Delta, DiffReport, Tolerance,
                                default_tolerance, diff, diff_files,
                                load_rows, row_key)

__all__ = [
    # trace
    "KINDS", "Span", "TraceRecorder", "begin", "capture", "complete",
    "containing", "disable", "enable", "enabled", "end", "recorder",
    "span", "well_formed",
    # metrics
    "LABEL_KEYS", "Counter", "Gauge", "Histogram", "MetricsRegistry",
    "RingLog", "collect", "publish_chaos", "publish_emission_stats",
    "publish_group", "publish_pipeline", "publish_poll_stats",
    "publish_supervisor",
    # baseline
    "Delta", "DiffReport", "Tolerance", "default_tolerance", "diff",
    "diff_files", "load_rows", "row_key",
]
