"""Span tracing for the Observatory telemetry plane.

The paper's evaluation hinges on seeing WHERE time goes inside the
transport (per-connection RTT structure, buffer-fill behavior, poll
strategy effects — Figs. 5-8), and the same group's benchmark suite
(arXiv:1910.02245) instruments exactly those seams. This module is the
repro's equivalent: NESTED SPANS over the staged emission API
(``begin_emission`` -> ``stage_slices`` -> ``flush_ready`` ->
``finish_emission``, leader flushes, the a2a expert exchange) and the
serving plane (admission -> prefill -> decode waves, event-loop drains,
supervisor heal windows), recorded into a RING-BUFFERED
:class:`TraceRecorder` and exported as Chrome-trace / Perfetto JSON
(``chrome://tracing`` / https://ui.perfetto.dev load it directly).

Design rules:

* **Zero overhead when disabled.** The module-level gate is one global:
  ``enabled()`` is a ``None`` check, :func:`span` returns a shared
  ``nullcontext`` and :func:`begin` returns ``None`` without touching a
  clock. Instrumentation sites on hot paths guard with ``if
  trace.enabled():`` so the disabled cost is a single load+compare.
* **Observation only.** Spans record host-side wall-clock around work
  that already happens; nothing in this module feeds back into emission
  structure, scheduling, or numerics — which is why telemetry-enabled
  runs serve bit-identical tokens (tested).
* **Trace-time vs run-time spans.** Emission/flush/stage spans fire when
  a program is TRACED (first compile of a serve step); steady-state
  decode steps replay the compiled program and record only the serving
  plane's spans (decode/admission/drain). A run that never traces a
  fresh program legitimately has no emission spans — clear the
  serve-step cache (``serving/dispatch.clear_serve_step_cache``) when
  you need them.
* **Thread safety.** Each thread keeps its own span stack (nesting is a
  per-thread property — threaded drains interleave); the span ring and
  the tid table are lock-protected. ``complete()`` records a span from
  explicit timestamps without touching any stack — the supervisor's
  detect->heal windows use it.

Span kinds in the shipped instrumentation (docs/OBSERVABILITY.md):
``emission`` / ``stage`` / ``flush`` / ``leader_flush`` (pipeline.py),
``build`` (dispatch.py), ``prefill`` / ``decode`` / ``admission``
(engine.py), ``drain`` (event_loop.py), ``heal`` (supervisor.py).
"""
from __future__ import annotations

import contextlib
import json
import threading
import time
from collections import deque
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional

# The instrumented span kinds (open set — the recorder accepts any string;
# this tuple is the documented taxonomy the smoke assertions key on).
KINDS = ("emission", "stage", "flush", "leader_flush", "build",
         "prefill", "decode", "admission", "drain", "heal")


@dataclass
class Span:
    """One closed span. Times are seconds relative to the recorder's
    epoch (``perf_counter`` at construction); ``depth`` is the nesting
    depth at close time on the recording thread (0 = top level)."""
    kind: str
    name: str
    t0: float
    dur: float
    tid: int
    depth: int
    args: dict = field(default_factory=dict)

    @property
    def t1(self) -> float:
        return self.t0 + self.dur


class TraceRecorder:
    """Ring-buffered span recorder with per-thread nesting stacks.

    ``capacity`` bounds the ring: the oldest span is evicted per
    overflowing append and counted in ``dropped`` (long-running serves
    must never grow memory unboundedly — same rule as the evidence
    RingLogs). ``forced_closes`` counts non-LIFO closes (an ``end``
    whose token was not on top — intermediates are force-closed so the
    trace stays an interval forest); a well-formed run keeps it at 0.
    """

    def __init__(self, capacity: int = 65536):
        self.capacity = int(capacity)
        self.spans: deque = deque(maxlen=self.capacity)
        self.dropped = 0
        self.forced_closes = 0
        self._epoch = time.perf_counter()
        self._local = threading.local()
        self._lock = threading.Lock()
        self._tids: Dict[int, int] = {}
        self._stacks: Dict[int, list] = {}   # tid -> live stack (open_spans)

    # -- clocks / identity ---------------------------------------------

    def _now(self) -> float:
        return time.perf_counter() - self._epoch

    def _tid(self) -> int:
        ident = threading.get_ident()
        with self._lock:
            if ident not in self._tids:
                self._tids[ident] = len(self._tids)
            return self._tids[ident]

    def _stack(self) -> list:
        st = getattr(self._local, "stack", None)
        if st is None:
            st = self._local.stack = []
            tid = self._tid()            # before the lock: _tid locks too
            with self._lock:
                self._stacks[tid] = st
        return st

    # -- the span API ---------------------------------------------------

    def begin(self, kind: str, name: str = "", **args) -> list:
        """Open a span; returns an opaque token for :meth:`end`."""
        tok = [kind, name, self._now(), args]
        self._stack().append(tok)
        return tok

    def end(self, token: Optional[list] = None, **extra) -> Optional[Span]:
        """Close the span ``token`` (or the top of this thread's stack).
        A non-LIFO token force-closes the intermediates above it (counted
        in ``forced_closes``); a token that is not on this thread's
        stack at all is counted and ignored — ends must never raise on
        the serving path."""
        st = self._stack()
        if token is not None and not any(t is token for t in st):
            self.forced_closes += 1
            return None
        out = None
        while st:
            top = st.pop()
            if token is None or top is token:
                out = self._emit(top, extra)
                break
            self.forced_closes += 1          # non-LIFO close
            self._emit(top, {})
        return out

    @contextlib.contextmanager
    def span(self, kind: str, name: str = "", **args):
        tok = self.begin(kind, name, **args)
        try:
            yield tok
        finally:
            self.end(tok)

    def complete(self, kind: str, name: str, t0_s: float, t1_s: float,
                 **args) -> Span:
        """Record a span from explicit ``perf_counter`` stamps, bypassing
        the nesting stacks (the supervisor's detect->heal windows carry
        their own ``t_detect``/``t_heal``)."""
        sp = Span(kind, name, t0_s - self._epoch,
                  max(0.0, t1_s - t0_s), self._tid(),
                  depth=len(self._stack()), args=dict(args))
        self._append(sp)
        return sp

    def _emit(self, tok: list, extra: dict) -> Span:
        kind, name, t0, args = tok
        if extra:
            args = {**args, **extra}
        sp = Span(kind, name, t0, self._now() - t0, self._tid(),
                  depth=len(self._stack()), args=args)
        self._append(sp)
        return sp

    def _append(self, sp: Span) -> None:
        with self._lock:
            if len(self.spans) == self.capacity:
                self.dropped += 1            # ring eviction, counted
            self.spans.append(sp)

    # -- introspection --------------------------------------------------

    def open_spans(self) -> list:
        """Every thread's still-open ``(kind, name)`` pairs — the
        well-formedness probe (a clean run returns [])."""
        with self._lock:
            stacks = list(self._stacks.values())
        return [(t[0], t[1]) for st in stacks for t in st]

    def kinds(self) -> list:
        with self._lock:
            return sorted({s.kind for s in self.spans})

    def spans_of(self, kind: str) -> list:
        with self._lock:
            return [s for s in self.spans if s.kind == kind]

    # -- export ---------------------------------------------------------

    def to_chrome(self) -> dict:
        """Chrome-trace JSON object (the ``traceEvents`` array of
        complete ``"ph": "X"`` events, microsecond timestamps) —
        loadable by chrome://tracing and Perfetto."""
        evs: List[dict] = []
        with self._lock:
            spans = list(self.spans)
        for s in spans:
            evs.append({"name": s.name or s.kind, "cat": s.kind,
                        "ph": "X", "ts": round(s.t0 * 1e6, 3),
                        "dur": round(s.dur * 1e6, 3), "pid": 0,
                        "tid": s.tid, "args": dict(s.args)})
        return {"traceEvents": evs, "displayTimeUnit": "ms",
                "otherData": {"dropped": self.dropped,
                              "forced_closes": self.forced_closes,
                              "open_spans": len(self.open_spans())}}

    def write(self, path: str) -> dict:
        doc = self.to_chrome()
        with open(path, "w") as f:
            json.dump(doc, f, indent=1, sort_keys=True)
        return doc


def well_formed(rec: TraceRecorder) -> tuple:
    """``(ok, problems)``: every opened span closed, no forced closes,
    and — per thread — spans form a proper interval forest (children
    contained in their parents; the span-tree contract the tests and the
    obs-smoke CI job assert)."""
    problems: list = []
    open_ = rec.open_spans()
    if open_:
        problems.append(f"{len(open_)} unclosed spans: {open_[:8]}")
    if rec.forced_closes:
        problems.append(f"{rec.forced_closes} forced (non-LIFO) closes")
    eps = 1e-9
    by_tid: Dict[int, list] = {}
    for s in rec.spans:
        by_tid.setdefault(s.tid, []).append(s)
    for tid, spans in by_tid.items():
        ends: list = []                      # stack of enclosing t1s
        for s in sorted(spans, key=lambda s: (s.t0, -s.dur)):
            while ends and ends[-1] <= s.t0 + eps:
                ends.pop()
            if ends and s.t1 > ends[-1] + eps:
                problems.append(
                    f"tid {tid}: span {s.kind}:{s.name} "
                    f"[{s.t0:.6f},{s.t1:.6f}] straddles its parent "
                    f"(ends {ends[-1]:.6f})")
            ends.append(s.t1)
    return (not problems, problems)


def containing(rec: TraceRecorder, inner: Span, kind: str) -> Optional[Span]:
    """The tightest span of ``kind`` (same thread) whose interval
    contains ``inner`` — nesting queries for tests ("every leader flush
    sits inside a local flush span")."""
    eps = 1e-9
    best = None
    for s in rec.spans:
        if s.kind != kind or s.tid != inner.tid or s is inner:
            continue
        if s.t0 <= inner.t0 + eps and inner.t1 <= s.t1 + eps:
            if best is None or s.dur < best.dur:
                best = s
    return best


# ---------------------------------------------------------------------------
# The module-level gate (obs.enabled()). One global; every instrumentation
# site either checks enabled() explicitly or calls span()/begin()/end()/
# complete(), which no-op on the disabled path without touching a clock.
# ---------------------------------------------------------------------------

_RECORDER: Optional[TraceRecorder] = None
_NULL = contextlib.nullcontext()             # reusable + reentrant


def enabled() -> bool:
    return _RECORDER is not None


def enable(capacity: int = 65536) -> TraceRecorder:
    """Install a fresh recorder (replacing any active one)."""
    global _RECORDER
    _RECORDER = TraceRecorder(capacity)
    return _RECORDER


def disable() -> Optional[TraceRecorder]:
    """Remove the active recorder and return it (for export)."""
    global _RECORDER
    rec, _RECORDER = _RECORDER, None
    return rec


def recorder() -> Optional[TraceRecorder]:
    return _RECORDER


def span(kind: str, name: str = "", **args):
    """Context manager: a recorded span when enabled, a shared
    ``nullcontext`` otherwise."""
    rec = _RECORDER
    if rec is None:
        return _NULL
    return rec.span(kind, name, **args)


def begin(kind: str, name: str = "", **args):
    """Token-style open (for spans that straddle function boundaries,
    e.g. ``begin_emission`` -> ``finish_emission``); None when disabled."""
    rec = _RECORDER
    return None if rec is None else rec.begin(kind, name, **args)


def end(token, **extra) -> None:
    rec = _RECORDER
    if rec is not None and token is not None:
        rec.end(token, **extra)


def complete(kind: str, name: str, t0_s: float, t1_s: float, **args) -> None:
    rec = _RECORDER
    if rec is not None:
        rec.complete(kind, name, t0_s, t1_s, **args)


@contextlib.contextmanager
def capture(capacity: int = 65536):
    """Scoped enable/disable (tests): yields the recorder, restores the
    previously-active one on exit."""
    global _RECORDER
    prev = _RECORDER
    rec = enable(capacity)
    try:
        yield rec
    finally:
        _RECORDER = prev
