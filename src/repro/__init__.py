"""repro — hadroNIO-for-JAX: a multi-pod JAX training/serving framework
whose communication layer implements the paper's transparent aggregated
communication technique (see DESIGN.md)."""

__version__ = "0.1.0"
