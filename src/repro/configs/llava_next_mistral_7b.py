"""llava-next-mistral-7b [vlm] — 32L d_model=4096 32H (GQA kv=8) d_ff=14336
vocab=32000 — anyres tiling. [hf:llava-hf/llava-v1.6-mistral-7b-hf; unverified]

The vision frontend is a STUB per the brief: ``input_specs()`` provides
precomputed patch embeddings (batch, num_patches, d_model) which the model
prepends to the text-token embeddings. anyres tiling: 5 tiles x 576 patches.
Backbone is Mistral-7B (full attention in this checkpoint lineage).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llava-next-mistral-7b",
    family="vlm",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    d_ff=14336,
    vocab_size=32000,
    qkv_bias=False,
    mlp_kind="swiglu",
    norm_kind="rmsnorm",
    rope_theta=1_000_000.0,
    num_patches=2880,          # 5 anyres tiles x 24x24 patches
    source="hf:llava-hf/llava-v1.6-mistral-7b-hf; unverified",
)
