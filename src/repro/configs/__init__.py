from repro.configs.base import (CommConfig, ModelConfig, MoEConfig,
                                RunConfig, ShapeConfig, SHAPES, cells_for,
                                cell_skip_reason, describe, reduced)
from repro.configs.registry import ARCH_IDS, all_cells, get_config, get_shape

__all__ = [
    "ARCH_IDS", "CommConfig", "ModelConfig", "MoEConfig", "RunConfig",
    "ShapeConfig", "SHAPES", "all_cells", "cells_for", "cell_skip_reason",
    "describe", "get_config", "get_shape", "reduced",
]
