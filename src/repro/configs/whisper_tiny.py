"""whisper-tiny [audio] — 4L d_model=384 6H (GQA kv=6) d_ff=1536
vocab=51865 — enc-dec, conv frontend (stub). [arXiv:2212.04356; unverified]

The conv/mel frontend is a STUB per the brief: ``input_specs()`` provides
precomputed frame embeddings of shape (batch, num_frames=1500, d_model).
Whisper uses LayerNorm + GELU, learned positions (no RoPE), biases.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-tiny",
    family="encdec",
    num_layers=4,              # decoder layers
    encoder_layers=4,
    num_frames=1500,
    d_model=384,
    num_heads=6,
    num_kv_heads=6,
    d_ff=1536,
    vocab_size=51865,
    qkv_bias=True,
    mlp_kind="gelu",
    norm_kind="layernorm",
    rope_theta=0.0,            # 0 -> learned absolute positions
    source="arXiv:2212.04356; unverified",
)
