"""dbrx-132b [moe] — 40L d_model=6144 48H (GQA kv=8) d_ff=10752
vocab=100352, MoE 16e top-4, fine-grained. [hf:databricks/dbrx-base; unverified]"""
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="dbrx-132b",
    family="moe",
    num_layers=40,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    d_ff=10752,
    vocab_size=100352,
    qkv_bias=False,
    mlp_kind="swiglu",
    norm_kind="layernorm",
    rope_theta=500_000.0,
    moe=MoEConfig(num_experts=16, top_k=4),
    source="hf:databricks/dbrx-base; unverified",
)
