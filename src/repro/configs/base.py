"""Configuration system.

Every assigned architecture is a frozen dataclass instance built by one
``src/repro/configs/<id>.py`` module. Configs are pure data: models,
sharding, and the launcher all key off these fields. ``reduced()`` derives
the CPU smoke-test variant of any config (same family/topology, tiny dims).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field, replace
from typing import Optional, Tuple

# ---------------------------------------------------------------------------
# Input shapes (assigned cells). Every arch is paired with all four; cells
# that are inapplicable for a family are resolved by `cells_for()` below.
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    kind: str                 # "train" | "prefill" | "decode"
    seq_len: int
    global_batch: int

    def __post_init__(self):
        assert self.kind in ("train", "prefill", "decode"), self.kind


SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeConfig("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeConfig("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeConfig("long_500k", "decode", 524288, 1),
}


# ---------------------------------------------------------------------------
# Model config
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    # capacity factor for the one-hot dispatch path (tokens per expert =
    # capacity_factor * tokens * top_k / num_experts). The dry-run uses the
    # einsum dispatch which is capacity-free; this is kept for the serving
    # batcher.
    capacity_factor: float = 1.25


@dataclass(frozen=True)
class ModelConfig:
    """Architecture hyperparameters (exact assigned numbers live in the
    per-arch modules)."""

    name: str
    family: str               # dense | moe | ssm | hybrid | encdec | vlm
    num_layers: int
    d_model: int
    num_heads: int            # 0 for attention-free families
    num_kv_heads: int
    d_ff: int
    vocab_size: int

    head_dim: int = 0         # 0 -> d_model // num_heads
    qkv_bias: bool = False
    mlp_kind: str = "swiglu"  # swiglu | gelu
    norm_kind: str = "rmsnorm"  # rmsnorm | layernorm
    rope_theta: float = 10_000.0
    tie_embeddings: bool = False
    sliding_window: int = 0   # 0 -> full attention; >0 -> SWA window

    # MoE
    moe: Optional[MoEConfig] = None

    # hybrid (recurrentgemma): block pattern cycled over layers
    block_pattern: Tuple[str, ...] = ()   # e.g. ("rglru", "rglru", "local_attn")
    local_window: int = 2048
    lru_width: int = 0        # 0 -> d_model
    conv1d_width: int = 4     # temporal conv in recurrent block

    # ssm (rwkv6)
    rwkv_head_size: int = 64
    rwkv_decay_lora: int = 64
    rwkv_mix_lora: int = 32

    # encoder-decoder (whisper): encoder depth == num_layers, plus frontend
    # stub that feeds (batch, num_frames, d_model) embeddings.
    encoder_layers: int = 0
    num_frames: int = 1500

    # vlm (llava): patch-embedding prefix length (anyres: 5 tiles x 576)
    num_patches: int = 0

    # numerics
    param_dtype: str = "bfloat16"
    compute_dtype: str = "bfloat16"

    # notes for DESIGN/EXPERIMENTS provenance
    source: str = ""

    def __post_init__(self):
        assert self.family in ("dense", "moe", "ssm", "hybrid", "encdec", "vlm")
        if self.family == "moe":
            assert self.moe is not None
        if self.head_dim == 0 and self.num_heads:
            object.__setattr__(self, "head_dim", self.d_model // self.num_heads)

    # -- derived quantities -------------------------------------------------

    @property
    def attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def sub_quadratic(self) -> bool:
        """True if decode state is O(window) / O(1) rather than O(seq)."""
        if self.family in ("ssm",):
            return True
        if self.family == "hybrid":
            return True            # RG-LRU state + bounded local-attn window
        return self.sliding_window > 0

    def param_count(self) -> int:
        """Analytic parameter count (used for MODEL_FLOPS and napkin math)."""
        d, f, v, L = self.d_model, self.d_ff, self.vocab_size, self.num_layers
        hd = self.head_dim
        n = 0
        # embeddings (+ untied output head)
        n += v * d * (1 if self.tie_embeddings else 2)
        if self.family == "ssm":
            per = 0
            per += 5 * d * d                      # r,k,v,g,o projections (w via lora)
            per += d * self.rwkv_decay_lora * 2   # decay lora
            per += 5 * (d * self.rwkv_mix_lora * 2)  # token-shift mix loras
            per += 7 * d                          # mix biases / decay base / bonus
            per += 2 * d * f + d * d              # channel mix k,v,r
            per += 2 * d                          # norms
            return n + L * per
        att = d * (self.num_heads * hd) + d * (self.num_kv_heads * hd) * 2 \
            + (self.num_heads * hd) * d
        if self.qkv_bias:
            att += self.num_heads * hd + 2 * self.num_kv_heads * hd
        mlp = (3 if self.mlp_kind == "swiglu" else 2) * d * f
        if self.family == "moe":
            mlp_total = self.moe.num_experts * mlp + d * self.moe.num_experts
        else:
            mlp_total = mlp
        if self.family == "hybrid":
            lw = self.lru_width or d
            rec = 2 * d * lw + lw * d + self.conv1d_width * lw + 3 * lw \
                + 2 * (lw * max(lw // 8, 1))      # gates are block-diagonal LoRA-ish
            pat = self.block_pattern or ("rglru",)
            n_attn = sum(1 for i in range(L) if pat[i % len(pat)] == "local_attn")
            n_rec = L - n_attn
            return n + n_attn * (att + mlp + 2 * d) + n_rec * (rec + mlp + 2 * d)
        per = att + mlp_total + 2 * d
        total = n + L * per
        if self.family == "encdec":
            # encoder layers + decoder cross-attention
            total += self.encoder_layers * (att + mlp + 2 * d)
            total += L * (att + d)                # cross-attn per decoder layer
        return total

    def active_param_count(self) -> int:
        """Active (per-token) params — differs from total only for MoE."""
        if self.family != "moe":
            return self.param_count()
        d, f, L = self.d_model, self.d_ff, self.num_layers
        mlp = 3 * d * f
        inactive = L * (self.moe.num_experts - self.moe.top_k) * mlp
        return self.param_count() - inactive


@dataclass(frozen=True)
class CommConfig:
    """TAC — the paper's technique (see DESIGN.md §2).

    mode:
      gspmd      — pure GSPMD auto sharding; XLA owns all collectives
                   ("the kernel network stack").
      sockets    — explicit per-tensor psum over the DP axes
                   (plain-sockets baseline: one op per tensor).
      vma        — one monolithic fused psum of the whole flattened grad
                   (libvma analogue: minimal op count, no overlap, peak mem).
      hadronio   — gathering-write aggregation: pack into ring-buffer slices,
                   one psum per slice (paper-faithful).
      hadronio_rs— beyond-paper: per-slice reduce-scatter + all-gather with
                   data-sharded (ZeRO-1) optimizer update.
      hadronio_overlap — beyond-paper: DDP-style reverse-layer bucketing;
                   per-bucket collectives depend only on their own leaves
                   so they overlap the remaining backward compute.
      hadronio_overlap_rs — beyond-paper: bucketed ZeRO-1; each bucket
                   reduce-scatters its own shard (same overlap property)
                   and the optimizer updates flat data-sharded moments.

    ``pack`` selects the pack/cast/error-feedback copy-path implementation
    (the paper's gathering-write hot spot): ``jnp`` (reference) or
    ``pallas`` (fused one-pass kernel, kernels/ring_pack.py; falls back to
    jnp via repro.compat when pallas is unavailable). The same switch
    selects the unpack-stage implementation (the scattering-read epilogue
    — one fused cast-from-wire-dtype pass over the collective results).

    ``aggregate`` is the wire-flush granularity of the channel schedule
    (paper §III-C: hadroNIO's ring buffer merges many small writes into
    one large UCX request per connection):

      slice   — one collective per ring slice / bucket; same-channel
                collectives are chained in order.
      channel — gathering write at connection granularity: every slice
                assigned to a channel is coalesced into ONE contiguous
                wire buffer and flushed with a single collective per
                channel. Bit-identical numerics; the reduce-scatter
                flush interleaves per-slice shard chunks so the ZeRO-1
                flat-shard ordering is unchanged.

    ``flush`` is the channel SCHEDULE (core/flush_scheduler.py —
    hadroNIO flushes a connection the moment the selector reports it
    writable, §III-B, instead of at a global barrier):

      step    — slices/buckets land on channels round-robin and every
                coalesced flush is emitted in one end-of-exchange loop.
      ready   — flush-when-ready: buckets are grouped onto channels
                contiguously in gradient-production (reverse-layer)
                order and each channel's flush is emitted the moment its
                last bucket is staged — mid-backward, so under
                ``aggregate="channel"`` with channels < n_buckets the
                overlap modes keep per-channel independence that the
                ``step`` schedule forfeits. Bit-identical numerics (the
                schedule moves the same bytes; only the emission
                structure changes).

    Modes without a channel schedule (gspmd / sockets / vma) have nothing
    to coalesce; ``aggregate`` and ``flush`` are documented no-ops there
    (unlike ``compress``, they never change numerics, so no rejection is
    needed).

    The authoritative mode list is the backend registry
    (``repro.core.backends.available_modes``) — new modes register
    themselves and need no edit here.
    """

    mode: str = "gspmd"
    ring_capacity_bytes: int = 256 * 1024 * 1024
    slice_bytes: int = 4 * 1024 * 1024
    channels: int = 4                  # in-flight slices ("connections")
    compress: str = "none"             # none | bf16 | int8_ef
    pack: str = "jnp"                  # pack/unpack-stage impl: jnp | pallas
    aggregate: str = "slice"           # wire-flush granularity: slice | channel
    flush: str = "step"                # channel schedule: step | ready
    hierarchical: bool = True          # pod-aware two-level collectives
    leader_channels: int = 1           # channels carved for cross-pod traffic
    #   Under pod-aware hierarchical emission with aggregate="channel",
    #   the LAST ``leader_channels`` channels of the pool are the leader
    #   lanes: intra-pod stages ride the remaining (local) lanes and only
    #   the 1/n_pod-reduced shards are coalesced onto leader lanes for the
    #   cross-pod collective (UCX multi-rail: the scarce link gets its own
    #   dedicated connections). Clamped at emission time to pool-1 so a
    #   1-channel pool stays flat; ServeConfig validates the strict form
    #   when pods are actually configured.

    COMPRESS_CODECS = ("none", "bf16", "int8_ef")
    PACK_IMPLS = ("jnp", "pallas")
    AGGREGATES = ("slice", "channel")
    FLUSHES = ("step", "ready")

    def __post_init__(self):
        # the backend registry is the single source of truth for modes
        # (lazy import: backends import this module for the dataclass)
        from repro.core.backends import available_modes
        assert self.mode in available_modes(), \
            f"unknown comm mode {self.mode!r}; registered: {available_modes()}"
        if self.channels < 1:
            raise ValueError(
                f"comm.channels must be >= 1 (got {self.channels}): the "
                "connection pool needs at least one channel; values above "
                "n_slices are clamped to fully-independent emission")
        if self.compress not in self.COMPRESS_CODECS:
            raise ValueError(
                f"unknown comm.compress {self.compress!r}: expected one of "
                f"{self.COMPRESS_CODECS}")
        if self.pack not in self.PACK_IMPLS:
            raise ValueError(
                f"unknown comm.pack {self.pack!r}: expected one of "
                f"{self.PACK_IMPLS} (pallas falls back to jnp when the "
                "kernel toolchain is unavailable)")
        if self.aggregate not in self.AGGREGATES:
            raise ValueError(
                f"unknown comm.aggregate {self.aggregate!r}: expected one "
                f"of {self.AGGREGATES} ('channel' coalesces every slice on "
                "a channel into one wire flush per collective)")
        if self.flush not in self.FLUSHES:
            raise ValueError(
                f"unknown comm.flush {self.flush!r}: expected one of "
                f"{self.FLUSHES} ('ready' emits each channel's flush the "
                "moment its last assigned bucket is staged; 'step' flushes "
                "every channel at one end-of-exchange loop)")
        if self.leader_channels < 1:
            raise ValueError(
                f"comm.leader_channels must be >= 1 (got "
                f"{self.leader_channels}): the cross-pod stage of the "
                "hierarchical emission needs at least one dedicated lane; "
                "values >= comm.channels are clamped to channels-1 at "
                "emission time (a 1-channel pool has no lane to carve)")
        assert self.slice_bytes > 0 and self.ring_capacity_bytes >= self.slice_bytes


@dataclass(frozen=True)
class TenantConfig:
    """One tenant of a multi-tenant ``EventLoopGroup``: a named model
    family sharing the group's channel pool with the others. Tenants
    partition ``serve.event_loops`` into disjoint contiguous loop
    ranges (declaration order), so channel ownership stays disjoint
    per loop AND per tenant; ``weight`` sets the tenant's share of the
    group-level admission via deterministic weighted-fair scheduling
    (docs/FAMILIES.md §Tenants and fairness)."""

    name: str                          # unique tenant key (Request.tenant)
    arch: str = ""                     # registry arch served for this tenant
    weight: int = 1                    # weighted-fair admission share
    event_loops: int = 1               # loops owned by this tenant


@dataclass(frozen=True)
class ServeConfig:
    """Event-loop serving (the paper's §IV benchmark topology, applied to
    inference): an ``EventLoopGroup`` of ``event_loops`` loops, each
    owning a DISJOINT contiguous run of the ``comm.channels`` pool
    (Ibdxnet's per-thread connection ownership) and a run queue of
    in-flight requests; new requests are admitted at flush boundaries
    (continuous batching). ``poll`` mirrors hadroNIO's completion
    polling:

      busy     — spin on readiness; lowest latency, one core per loop
                 (the paper's busy-polling optimization).
      park     — block until complete (the epoll / selector.select
                 fallback).
      adaptive — spin for ``spin_us`` then park (hadroNIO's bounded
                 busy-poll before yielding).

    ``comm`` is the SAME config the training path uses — serving
    collectives (KV gathering writes, tensor-parallel logit reductions)
    flow through the registered CommBackend's wire path, so
    mode/channels/slice_bytes/aggregate/flush all apply to inference
    traffic (see docs/SERVING.md). Serving payloads are activations, not
    gradients: wire compression (an error-feedback feature) is rejected
    by the dispatch layer.

    ``pods`` configures the two-level serving fabric (docs/SERVING.md
    §Topology): the serve mesh becomes ``(pods, devices//pods)`` over
    ``(pod_axis, "data")``, and with ``comm.hierarchical`` the emission
    decomposes so intra-pod traffic rides local channels and only the
    1/n_pod-reduced shards cross pods on the ``comm.leader_channels``
    leader lanes, which are pinned to the first ``leader_loops`` event
    loops (topology-aware channel affinity). ``pods`` must divide the
    device count — validated where the devices are known
    (``launch/mesh.make_serve_mesh``), not here.
    """

    event_loops: int = 1
    poll: str = "busy"                 # busy | park | adaptive
    spin_us: float = 50.0              # adaptive: spin budget before parking
    max_batch: int = 8                 # decode slots per event loop
    max_len: int = 256                 # prompt + generation bound (KV alloc)
    comm: CommConfig = field(default_factory=CommConfig)
    pods: int = 1                      # two-level fabric: pod count
    pod_axis: str = "pod"              # mesh axis name of the pod dimension
    leader_loops: int = 1              # loops pinned to the leader lanes
    tenants: tuple = ()                # TenantConfig partition of the loops

    POLLS = ("busy", "park", "adaptive")

    def __post_init__(self):
        if self.event_loops < 1:
            raise ValueError(
                f"serve.event_loops must be >= 1 (got {self.event_loops})")
        if self.poll not in self.POLLS:
            raise ValueError(
                f"unknown serve.poll {self.poll!r}: expected one of "
                f"{self.POLLS} (busy spins, park blocks, adaptive spins "
                f"for spin_us then parks)")
        if self.event_loops > self.comm.channels:
            raise ValueError(
                f"serve.event_loops={self.event_loops} exceeds "
                f"comm.channels={self.comm.channels}: each event loop "
                "must OWN a disjoint non-empty run of the channel pool "
                "(raise comm.channels or lower event_loops)")
        if self.spin_us < 0:
            raise ValueError(f"serve.spin_us must be >= 0 ({self.spin_us})")
        if self.pods < 1:
            raise ValueError(f"serve.pods must be >= 1 (got {self.pods})")
        if not self.pod_axis:
            raise ValueError("serve.pod_axis must be a non-empty axis name")
        if not 1 <= self.leader_loops <= self.event_loops:
            raise ValueError(
                f"serve.leader_loops={self.leader_loops} must be in "
                f"[1, event_loops={self.event_loops}]: leader channels are "
                "pinned to a designated subset of the loops, and at least "
                "one loop must carry the cross-pod lanes")
        if self.pods > 1 and self.comm.hierarchical:
            if self.comm.leader_channels >= self.comm.channels:
                raise ValueError(
                    f"comm.leader_channels={self.comm.leader_channels} must "
                    f"be < comm.channels={self.comm.channels} when serving "
                    f"{self.pods} pods hierarchically: carving every lane "
                    "for cross-pod traffic leaves no local lane for the "
                    "in-pod stages (raise comm.channels or lower "
                    "leader_channels)")
            if self.event_loops > self.comm.channels - self.comm.leader_channels:
                raise ValueError(
                    f"serve.event_loops={self.event_loops} exceeds the "
                    f"{self.comm.channels - self.comm.leader_channels} "
                    f"LOCAL channels (channels={self.comm.channels} minus "
                    f"leader_channels={self.comm.leader_channels}): under "
                    "the two-level fabric every loop must own at least one "
                    "local lane for its in-pod stages")
        if self.tenants:
            names = [t.name for t in self.tenants]
            if any(not n for n in names) or len(set(names)) != len(names):
                raise ValueError(
                    f"serve.tenants names must be unique and non-empty "
                    f"(got {names!r}): Request.tenant routes by name")
            for t in self.tenants:
                if t.weight < 1:
                    raise ValueError(
                        f"tenant {t.name!r}: weight must be >= 1 (got "
                        f"{t.weight}) — zero-weight tenants would starve")
                if t.event_loops < 1:
                    raise ValueError(
                        f"tenant {t.name!r}: event_loops must be >= 1 (got "
                        f"{t.event_loops}): every tenant needs at least one "
                        "loop, hence at least one owned channel")
            total = sum(t.event_loops for t in self.tenants)
            if total != self.event_loops:
                raise ValueError(
                    f"serve.tenants pin the fleet size: per-tenant "
                    f"event_loops sum to {total} but serve.event_loops="
                    f"{self.event_loops}. Tenant loop ranges are a static "
                    "partition of the group, so supervisor autoscaling "
                    "requires tenants=()")


@dataclass(frozen=True)
class RunConfig:
    """Everything a launcher needs beyond the model itself."""

    model: ModelConfig
    shape: ShapeConfig
    comm: CommConfig = field(default_factory=CommConfig)

    # optimizer
    lr: float = 3e-4
    weight_decay: float = 0.1
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 1000
    microbatches: int = 1              # gradient accumulation

    # checkpointing / fault tolerance
    checkpoint_dir: str = ""
    checkpoint_every: int = 100
    keep_checkpoints: int = 3
    async_checkpoint: bool = True
    max_restarts: int = 100

    # data
    data_path: str = ""                # empty -> synthetic
    data_seed: int = 0

    seed: int = 0


# ---------------------------------------------------------------------------
# Cell applicability (DESIGN.md §5)
# ---------------------------------------------------------------------------


def cell_skip_reason(model: ModelConfig, shape: ShapeConfig) -> Optional[str]:
    """Return a reason string if (model, shape) is an assigned-but-skipped
    cell, else None. Mirrors the brief: ``long_500k`` needs sub-quadratic
    attention; encoder-only archs have no decode step (none assigned)."""
    if shape.name == "long_500k" and not model.sub_quadratic:
        return ("pure full attention: 500k-token decode requires a 500k KV "
                "cache and O(seq) attention per step — skipped per brief")
    return None


def cells_for(model: ModelConfig) -> list[ShapeConfig]:
    return [s for s in SHAPES.values() if cell_skip_reason(model, s) is None]


# ---------------------------------------------------------------------------
# Reduced (smoke) variants — same family/topology, tiny dims.
# ---------------------------------------------------------------------------


def reduced(cfg: ModelConfig) -> ModelConfig:
    """A CPU-runnable config of the same family: few layers, small width,
    few experts, tiny vocab — exercises every code path of the family."""
    num_heads = min(cfg.num_heads, 4) if cfg.num_heads else 0
    num_kv = max(1, min(cfg.num_kv_heads, num_heads)) if num_heads else 0
    kw = dict(
        name=cfg.name + "-reduced",
        num_layers=min(cfg.num_layers, 4 if not cfg.block_pattern else 2 * len(cfg.block_pattern)),
        d_model=64,
        num_heads=num_heads,
        num_kv_heads=num_kv,
        head_dim=16 if num_heads else 0,
        d_ff=128,
        vocab_size=256,
        lru_width=64 if cfg.family == "hybrid" else 0,
        rwkv_head_size=16,
        rwkv_decay_lora=8,
        rwkv_mix_lora=8,
        encoder_layers=min(cfg.encoder_layers, 2),
        num_frames=8,
        num_patches=min(cfg.num_patches, 8),
        sliding_window=min(cfg.sliding_window, 16) if cfg.sliding_window else 0,
        local_window=16,
        param_dtype="float32",
        compute_dtype="float32",
    )
    if cfg.moe is not None:
        # capacity_factor = num_experts makes reduced configs dropless
        # (capacity >= tokens*k), so prefill/decode consistency is exact;
        # full configs keep the production 1.25.
        kw["moe"] = MoEConfig(num_experts=min(cfg.moe.num_experts, 4),
                              top_k=min(cfg.moe.top_k, 2),
                              capacity_factor=float(
                                  min(cfg.moe.num_experts, 4)))
    return replace(cfg, **kw)


def describe(cfg: ModelConfig) -> str:
    n = cfg.param_count()
    a = cfg.active_param_count()
    extra = f" (active {a/1e9:.2f}B)" if a != n else ""
    return f"{cfg.name}: {cfg.family}, {cfg.num_layers}L d={cfg.d_model} " \
           f"ff={cfg.d_ff} vocab={cfg.vocab_size} -> {n/1e9:.2f}B params{extra}"
