"""recurrentgemma-9b [hybrid] — 38L d_model=4096 16H (GQA kv=1) d_ff=12288
vocab=256000 — RG-LRU + local attn, 1:2. [arXiv:2402.19427; unverified]

Block pattern cycles (rglru, rglru, local_attn): one local-attention block
per two recurrent blocks (1:2). Local window 2048 bounds the decode state,
so all long-context cells run. MQA (kv=1).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-9b",
    family="hybrid",
    num_layers=38,
    d_model=4096,
    num_heads=16,
    num_kv_heads=1,
    head_dim=256,
    d_ff=12288,
    vocab_size=256000,
    mlp_kind="swiglu",
    norm_kind="rmsnorm",
    rope_theta=10_000.0,
    block_pattern=("rglru", "rglru", "local_attn"),
    local_window=2048,
    lru_width=4096,
    source="arXiv:2402.19427; unverified",
)
