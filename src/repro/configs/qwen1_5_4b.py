"""qwen1.5-4b [dense] — 40L d_model=2560 20H (GQA kv=20) d_ff=6912
vocab=151936 — QKV bias. [hf:Qwen/Qwen1.5-0.5B; hf]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen1.5-4b",
    family="dense",
    num_layers=40,
    d_model=2560,
    num_heads=20,
    num_kv_heads=20,
    d_ff=6912,
    vocab_size=151936,
    qkv_bias=True,
    mlp_kind="swiglu",
    norm_kind="rmsnorm",
    rope_theta=1_000_000.0,
    source="hf:Qwen/Qwen1.5-0.5B; hf",
)
