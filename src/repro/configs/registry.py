"""Architecture registry: ``--arch <id>`` resolution.

Every assigned architecture (plus its reduced smoke variant) is reachable by
name. IDs match the assignment sheet exactly.
"""
from __future__ import annotations

import importlib

from repro.configs.base import (ModelConfig, ShapeConfig, SHAPES, cells_for,
                                cell_skip_reason, reduced, describe)

# arch-id -> module name
_ARCH_MODULES = {
    "qwen1.5-4b": "qwen1_5_4b",
    "starcoder2-3b": "starcoder2_3b",
    "qwen2-0.5b": "qwen2_0_5b",
    "qwen1.5-110b": "qwen1_5_110b",
    "whisper-tiny": "whisper_tiny",
    "dbrx-132b": "dbrx_132b",
    "mixtral-8x7b": "mixtral_8x7b",
    "llava-next-mistral-7b": "llava_next_mistral_7b",
    "rwkv6-7b": "rwkv6_7b",
    "recurrentgemma-9b": "recurrentgemma_9b",
}

ARCH_IDS: tuple[str, ...] = tuple(_ARCH_MODULES)


def get_config(arch: str) -> ModelConfig:
    """Resolve ``--arch`` ids; ``<id>-reduced`` yields the smoke variant."""
    want_reduced = arch.endswith("-reduced")
    base = arch[: -len("-reduced")] if want_reduced else arch
    if base not in _ARCH_MODULES:
        raise KeyError(
            f"unknown arch {arch!r}; known: {', '.join(ARCH_IDS)}")
    mod = importlib.import_module(f"repro.configs.{_ARCH_MODULES[base]}")
    cfg: ModelConfig = mod.CONFIG
    assert cfg.name == base, (cfg.name, base)
    return reduced(cfg) if want_reduced else cfg


def get_shape(name: str) -> ShapeConfig:
    return SHAPES[name]


def all_cells() -> list[tuple[ModelConfig, ShapeConfig, str | None]]:
    """All 40 assigned (arch x shape) cells with skip reasons (None = runs)."""
    out = []
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        for shape in SHAPES.values():
            out.append((cfg, shape, cell_skip_reason(cfg, shape)))
    return out


__all__ = ["ARCH_IDS", "get_config", "get_shape", "all_cells", "cells_for",
           "cell_skip_reason", "reduced", "describe", "SHAPES"]
