"""rwkv6-7b [ssm] — 32L d_model=4096 (attn-free) d_ff=14336 vocab=65536
— Finch, data-dependent decay. [arXiv:2404.05892; hf]

Attention-free: decode state is O(heads * head_size^2) per layer, so all
long-context cells run. head_size=64 -> 64 heads.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="rwkv6-7b",
    family="ssm",
    num_layers=32,
    d_model=4096,
    num_heads=0,
    num_kv_heads=0,
    d_ff=14336,
    vocab_size=65536,
    norm_kind="layernorm",
    rwkv_head_size=64,
    rwkv_decay_lora=64,
    rwkv_mix_lora=32,
    source="arXiv:2404.05892; hf",
)
