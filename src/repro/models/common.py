"""Parameter-spec system shared by all model families.

Models declare their parameters as a pytree of :class:`ParamSpec` (shape +
*logical axes* + initializer). The launch layer maps logical axes to mesh
axes (launch/sharding.py); ``init_params`` materializes the tree. Keeping
specs separate from arrays lets the dry-run build shardings without ever
allocating full-size parameters.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Any, Callable, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

PyTree = Any

# Logical axis vocabulary (see launch/sharding.py for the mesh mapping):
#   layers   — stacked-scan leading dim (never sharded)
#   vocab    — vocabulary dim (TP)
#   embed    — d_model dim (FSDP over data)
#   heads    — fused q-heads dim H*Dh (TP when divisible)
#   kv_heads — fused kv-heads dim (TP when divisible)
#   mlp      — d_ff dim (TP)
#   experts  — MoE expert dim (EP)
#   lru      — RG-LRU width (TP)
#   frames/seq — positional tables (not sharded)


@dataclass(frozen=True)
class ParamSpec:
    shape: Tuple[int, ...]
    axes: Tuple[Optional[str], ...]
    init: str = "normal"          # normal | zeros | ones
    scale: float = 1.0            # stddev = 0.02 * scale for "normal"

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)
        assert self.init in ("normal", "zeros", "ones")


def stacked(spec: ParamSpec, layers: int) -> ParamSpec:
    """Add a leading ``layers`` dim for scan-over-layers stacking."""
    return ParamSpec((layers,) + spec.shape, ("layers",) + spec.axes,
                     spec.init, spec.scale)


def tree_paths(tree: PyTree) -> list[tuple[str, Any]]:
    """Flatten a nested-dict tree into (dotted_path, leaf) pairs."""
    out: list[tuple[str, Any]] = []

    def rec(prefix: str, node: Any):
        if isinstance(node, dict):
            for k in sorted(node):
                rec(f"{prefix}.{k}" if prefix else str(k), node[k])
        else:
            out.append((prefix, node))

    rec("", tree)
    return out


def init_params(rng: jax.Array, specs: PyTree, dtype: str) -> PyTree:
    """Materialize a ParamSpec tree. Keys are folded from the dotted path so
    init is order-independent (property-tested)."""
    jdt = jnp.dtype(dtype)

    def leaf(path: str, spec: ParamSpec) -> jax.Array:
        if spec.init == "zeros":
            return jnp.zeros(spec.shape, jdt)
        if spec.init == "ones":
            return jnp.ones(spec.shape, jdt)
        key = jax.random.fold_in(rng, _path_hash(path))
        x = jax.random.normal(key, spec.shape, jnp.float32) * (0.02 * spec.scale)
        return x.astype(jdt)

    return _map_with_path(leaf, specs)


def abstract_params(specs: PyTree, dtype: str) -> PyTree:
    """ShapeDtypeStruct tree for the dry-run (no allocation)."""
    jdt = jnp.dtype(dtype)
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s.shape, jdt),
        specs, is_leaf=lambda x: isinstance(x, ParamSpec))


def _path_hash(path: str) -> int:
    h = 2166136261
    for ch in path.encode():
        h = ((h ^ ch) * 16777619) & 0x7FFFFFFF
    return h


def _map_with_path(fn: Callable[[str, Any], Any], tree: PyTree,
                   prefix: str = "") -> PyTree:
    if isinstance(tree, dict):
        return {k: _map_with_path(fn, v, f"{prefix}.{k}" if prefix else str(k))
                for k, v in tree.items()}
    return fn(prefix, tree)


def param_bytes(specs: PyTree, dtype: str) -> int:
    n = 0
    for _, s in tree_paths(specs):
        n += int(np.prod(s.shape)) * jnp.dtype(dtype).itemsize
    return n


def cast_compute(x: jax.Array, cfg) -> jax.Array:
    return x.astype(jnp.dtype(cfg.compute_dtype))
