"""Decoder-only transformer stack (dense / MoE / hybrid share this).

Layers are stacked along a leading ``layers`` dim and iterated with
``lax.scan`` (compact HLO; FSDP all-gathers land inside the loop body —
verified in DESIGN.md §4). Training remats each block.

``mode``:
  train   — full sequence, causal (optionally windowed), no cache.
  prefill — full sequence, returns per-layer KV cache.
  decode  — one token per call against the cache.
"""
from __future__ import annotations

import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention as att
from repro.models import moe as moe_mod
from repro.models.common import ParamSpec, stacked
from repro.models.layers import (ShardFn, apply_mlp, apply_norm, mlp_specs,
                                 no_shard, norm_specs)


def depth_scale(cfg: ModelConfig) -> float:
    return 1.0 / (2.0 * max(cfg.num_layers, 1)) ** 0.5


# ---------------------------------------------------------------------------
# One transformer block (pre-norm attention + pre-norm MLP/MoE)
# ---------------------------------------------------------------------------


def block_specs(cfg: ModelConfig, kind: str = "dense") -> dict:
    s = {
        "ln1": norm_specs(cfg.d_model, cfg.norm_kind),
        "ln2": norm_specs(cfg.d_model, cfg.norm_kind),
        "attn": att.attention_specs(cfg.d_model, cfg.num_heads,
                                    cfg.num_kv_heads, cfg.head_dim,
                                    cfg.qkv_bias, depth_scale(cfg)),
    }
    if kind == "moe":
        s["moe"] = moe_mod.moe_specs(cfg)
    else:
        s["mlp"] = mlp_specs(cfg.d_model, cfg.d_ff, cfg.mlp_kind,
                             depth_scale(cfg))
    return s


def apply_block(p: dict, x: jax.Array, cfg: ModelConfig, *, kind: str,
                mode: str, shard_fn: ShardFn, window: int,
                cache_k: Optional[jax.Array] = None,
                cache_v: Optional[jax.Array] = None,
                pos: Optional[jax.Array] = None,
                q_positions: Optional[jax.Array] = None,
                expert_fn=None):
    """Returns (x, new_cache_k, new_cache_v, aux_loss)."""
    b, s, _ = x.shape
    h = apply_norm(p["ln1"], x, cfg.norm_kind)
    h = shard_fn(h, ("batch", "seq_gather", None))   # SP: one AG per block
    if q_positions is None:
        if mode != "decode":
            q_positions = jnp.arange(s)
        else:
            # scalar pos -> (s,); per-request (B,) pos -> (B, s)
            base = pos[..., None] if jnp.ndim(pos) else pos
            q_positions = base + jnp.zeros((s,), jnp.int32)
    q, k, v = att.project_qkv(p["attn"], h, h, q_positions, q_positions,
                              cfg.rope_theta, shard_fn)
    new_k = new_v = None
    if mode == "decode":
        out, new_k, new_v = att.decode_attend(
            q, cache_k, cache_v, k, v, pos,
            num_heads=cfg.num_heads, window=window, shard_fn=shard_fn)
    else:
        kx = att.expand_kv(k, cfg.num_heads)
        vx = att.expand_kv(v, cfg.num_heads)
        out = att.attend_chunked(q, kx, vx, causal=True, window=window)
        if mode == "prefill":
            if window > 0:     # rolling layout for windowed decode caches
                new_k = att.to_rolling(k, window)
                new_v = att.to_rolling(v, window)
            else:
                new_k, new_v = k, v
    x = x + att.out_project(p["attn"], out, shard_fn)
    x = shard_fn(x, ("batch", "seq", None))

    h = apply_norm(p["ln2"], x, cfg.norm_kind)
    h = shard_fn(h, ("batch", "seq_gather", None))   # SP: one AG per block
    aux = jnp.zeros((), jnp.float32)
    if kind == "moe":
        y, aux = moe_mod.apply_moe(p["moe"], h, cfg, shard_fn,
                                   expert_fn=expert_fn)
    else:
        y = apply_mlp(p["mlp"], h, cfg.mlp_kind, shard_fn)
    x = x + y
    x = shard_fn(x, ("batch", "seq", None))
    return x, new_k, new_v, aux


# ---------------------------------------------------------------------------
# Stack
# ---------------------------------------------------------------------------


def stack_specs(cfg: ModelConfig, kind: str = "dense") -> dict:
    one = block_specs(cfg, kind)
    return jax.tree.map(lambda s: stacked(s, cfg.num_layers), one,
                        is_leaf=lambda x: isinstance(x, ParamSpec))


def apply_stack(params: dict, x: jax.Array, cfg: ModelConfig, *, kind: str,
                mode: str, shard_fn: ShardFn = no_shard,
                cache: Optional[dict] = None,
                pos: Optional[jax.Array] = None,
                q_positions: Optional[jax.Array] = None,
                expert_fn=None):
    """Scan the block over stacked params.

    Returns (x, new_cache, aux_sum). ``cache`` is {"k","v"}: (L,B,S,KV,Dh)
    for prefill/decode; None in train mode.
    """
    window = cfg.sliding_window

    def body(carry, xs):
        x = carry
        if mode == "decode":
            p, ck, cv = xs
            x, nk, nv, aux = apply_block(
                p, x, cfg, kind=kind, mode=mode, shard_fn=shard_fn,
                window=window, cache_k=ck, cache_v=cv, pos=pos,
                q_positions=q_positions, expert_fn=expert_fn)
            return x, (nk, nv, aux)
        p = xs
        x, nk, nv, aux = apply_block(
            p, x, cfg, kind=kind, mode=mode, shard_fn=shard_fn,
            window=window, pos=pos, q_positions=q_positions,
            expert_fn=expert_fn)
        if mode == "prefill":
            return x, (nk, nv, aux)
        return x, aux

    from repro.models.unroll import scan_or_unroll
    L = cfg.num_layers
    if mode == "train":
        body = jax.checkpoint(body)
        x, aux = scan_or_unroll(body, x, params, L)
        return x, None, jnp.sum(aux)
    if mode == "prefill":
        x, (ks, vs, aux) = scan_or_unroll(body, x, params, L)
        return x, {"k": ks, "v": vs}, jnp.sum(aux)
    x, (ks, vs, aux) = scan_or_unroll(body, x,
                                      (params, cache["k"], cache["v"]), L)
    return x, {"k": ks, "v": vs}, jnp.sum(aux)
