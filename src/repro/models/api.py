"""Unified model API — every family exposes the same four entry points:

  specs(cfg)                                  -> ParamSpec pytree
  loss(params, batch, cfg, shard_fn)          -> (loss, aux-dict)
  prefill(params, batch, cfg, shard_fn)       -> (last-token logits, cache)
  decode_step(params, cache, batch, cfg, ...) -> (logits, new cache)

plus shape builders for the dry-run:

  input_specs(cfg, shape)        -> {name: ShapeDtypeStruct} (model inputs)
  cache_specs(cfg, batch, max_len)-> cache pytree of ShapeDtypeStruct

``batch`` is a dict: train/prefill {"tokens", "labels"?, ("frames"|"patches")?};
decode {"token": (B,), "pos": ()}. The modality frontends (whisper conv/mel,
llava vision tower) are STUBS per the brief — inputs arrive as precomputed
embeddings.
"""
from __future__ import annotations

from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models import attention as att
from repro.models import hybrid as hyb
from repro.models import rwkv6 as rwkv
from repro.models import transformer as tfm
from repro.models import whisper as whi
from repro.models.common import ParamSpec, abstract_params, init_params
from repro.models.layers import (ShardFn, apply_norm, cross_entropy,
                                 embedding_specs, embed_tokens, lm_logits,
                                 no_shard, norm_specs)

PyTree = Any


# ---------------------------------------------------------------------------
# Param specs
# ---------------------------------------------------------------------------


def specs(cfg: ModelConfig) -> PyTree:
    if cfg.family == "encdec":
        return whi.whisper_specs(cfg)
    base = {
        "embed": embedding_specs(cfg.vocab_size, cfg.d_model,
                                 cfg.tie_embeddings),
        "ln_f": norm_specs(cfg.d_model, cfg.norm_kind),
    }
    if cfg.family == "ssm":
        base["layers"] = rwkv.rwkv_stack_specs(cfg)
        base["ln_in"] = norm_specs(cfg.d_model, "layernorm")
    elif cfg.family == "hybrid":
        base["layers"] = hyb.hybrid_stack_specs(cfg)
    elif cfg.family == "moe":
        base["layers"] = tfm.stack_specs(cfg, "moe")
    else:  # dense, vlm
        base["layers"] = tfm.stack_specs(cfg, "dense")
    return base


def init(rng: jax.Array, cfg: ModelConfig) -> PyTree:
    return init_params(rng, specs(cfg), cfg.param_dtype)


def abstract(cfg: ModelConfig) -> PyTree:
    return abstract_params(specs(cfg), cfg.param_dtype)


# ---------------------------------------------------------------------------
# Embedding / trunk helpers
# ---------------------------------------------------------------------------


def _embed_inputs(params, batch, cfg: ModelConfig, dtype):
    """Token (+ prefix) embeddings and the number of prefix positions."""
    x = embed_tokens(params["embed"], batch["tokens"], dtype)
    prefix = 0
    if cfg.family == "vlm":
        patches = batch["patches"].astype(dtype)
        x = jnp.concatenate([patches, x], axis=1)
        prefix = patches.shape[1]
    return x, prefix


def _trunk(params, x, cfg: ModelConfig, *, mode, shard_fn,
           cache=None, pos=None, q_positions=None, expert_fn=None):
    """Dispatch to the family stack. Returns (x, new_cache, aux).
    ``expert_fn`` replaces the MoE expert-compute stage
    (:func:`repro.models.moe.apply_moe`) — ignored by expert-free
    families."""
    if cfg.family == "ssm":
        x = apply_norm(params["ln_in"], x, "layernorm")
        x, st = rwkv.apply_rwkv_stack(params["layers"], x, cfg, mode=mode,
                                      shard_fn=shard_fn, state=cache)
        return x, st, jnp.zeros((), jnp.float32)
    if cfg.family == "hybrid":
        return hyb.apply_hybrid_stack(params["layers"], x, cfg, mode=mode,
                                      shard_fn=shard_fn, cache=cache, pos=pos,
                                      q_positions=q_positions)
    kind = "moe" if cfg.family == "moe" else "dense"
    return tfm.apply_stack(params["layers"], x, cfg, kind=kind, mode=mode,
                           shard_fn=shard_fn, cache=cache, pos=pos,
                           q_positions=q_positions, expert_fn=expert_fn)


# ---------------------------------------------------------------------------
# Train loss
# ---------------------------------------------------------------------------


def loss(params: PyTree, batch: dict, cfg: ModelConfig,
         shard_fn: ShardFn = no_shard):
    dtype = jnp.dtype(cfg.compute_dtype)
    if cfg.family == "encdec":
        enc_out = whi.encode(params, batch["frames"].astype(dtype), cfg,
                             shard_fn)
        cross_k, cross_v = whi._cross_kv(params, enc_out, cfg)
        x = embed_tokens(params["embed"], batch["tokens"], dtype)
        x = x + params["pos_dec"].astype(dtype)[None, :x.shape[1]]
        x, _ = whi.decode_stack(params, x, cfg, mode="train",
                                cross_k=cross_k, cross_v=cross_v,
                                shard_fn=shard_fn)
        logits = lm_logits(params["embed"], x, shard_fn)
        l = cross_entropy(logits, batch["labels"], batch.get("loss_mask"))
        return l, {"xent": l}

    x, prefix = _embed_inputs(params, batch, cfg, dtype)
    x = shard_fn(x, ("batch", "seq", None))
    x, _, aux = _trunk(params, x, cfg, mode="train", shard_fn=shard_fn)
    x = apply_norm(params["ln_f"], x, cfg.norm_kind)
    if prefix:
        x = x[:, prefix:]
    logits = lm_logits(params["embed"], x, shard_fn)
    xent = cross_entropy(logits, batch["labels"], batch.get("loss_mask"))
    total = xent + aux
    return total, {"xent": xent, "aux": aux}


# ---------------------------------------------------------------------------
# Prefill
# ---------------------------------------------------------------------------


def prefill(params: PyTree, batch: dict, cfg: ModelConfig,
            shard_fn: ShardFn = no_shard, logits_fn=None, expert_fn=None):
    """``logits_fn`` overrides the LM head (signature of
    :func:`repro.models.layers.lm_logits`) — the serving dispatch layer
    passes a tensor-parallel head whose partial-logit reduction flows
    through the registered CommBackend wire (serving/dispatch.py).
    ``expert_fn`` likewise overrides the MoE expert-compute stage (the
    expert-parallel all-to-all path); expert-free families ignore it."""
    head = logits_fn or lm_logits
    dtype = jnp.dtype(cfg.compute_dtype)
    if cfg.family == "encdec":
        enc_out = whi.encode(params, batch["frames"].astype(dtype), cfg,
                             shard_fn)
        cross_k, cross_v = whi._cross_kv(params, enc_out, cfg)
        x = embed_tokens(params["embed"], batch["tokens"], dtype)
        x = x + params["pos_dec"].astype(dtype)[None, :x.shape[1]]
        x, cache = whi.decode_stack(params, x, cfg, mode="prefill",
                                    cross_k=cross_k, cross_v=cross_v,
                                    shard_fn=shard_fn)
        cache = {"self": cache, "cross_k": cross_k, "cross_v": cross_v}
        logits = head(params["embed"], x[:, -1:], shard_fn)[:, 0]
        return logits, cache

    x, _ = _embed_inputs(params, batch, cfg, dtype)
    x = shard_fn(x, ("batch", "seq", None))
    x, cache, _ = _trunk(params, x, cfg, mode="prefill", shard_fn=shard_fn,
                         expert_fn=expert_fn)
    x = apply_norm(params["ln_f"], x, cfg.norm_kind)
    if "last_pos" in batch:     # per-request prompt end (serving engine)
        b_idx = jnp.arange(x.shape[0])
        x_last = x[b_idx, batch["last_pos"]][:, None]
    else:
        x_last = x[:, -1:]
    logits = head(params["embed"], x_last, shard_fn)[:, 0]
    return logits, cache


# ---------------------------------------------------------------------------
# Decode step
# ---------------------------------------------------------------------------


def decode_step(params: PyTree, cache: PyTree, batch: dict, cfg: ModelConfig,
                shard_fn: ShardFn = no_shard, logits_fn=None, expert_fn=None):
    """One token for the whole batch. batch: {"token": (B,), "pos": ()}.
    ``logits_fn`` and ``expert_fn`` override the LM head / MoE expert
    stage exactly as in :func:`prefill`."""
    head = logits_fn or lm_logits
    dtype = jnp.dtype(cfg.compute_dtype)
    pos = batch["pos"]
    tok = batch["token"][:, None]                        # (B,1)
    if cfg.family == "encdec":
        x = embed_tokens(params["embed"], tok, dtype)
        if jnp.ndim(pos):
            pe = jnp.take(params["pos_dec"], pos, axis=0)[:, None]  # (B,1,D)
        else:
            pe = jax.lax.dynamic_slice_in_dim(
                params["pos_dec"], pos, 1, axis=0)[None]
        x = x + pe.astype(dtype)
        x, new_self = whi.decode_stack(params, x, cfg, mode="decode",
                                       cross_k=cache["cross_k"],
                                       cross_v=cache["cross_v"],
                                       shard_fn=shard_fn,
                                       cache=cache["self"], pos=pos)
        logits = head(params["embed"], x, shard_fn)[:, 0]
        new_cache = dict(cache, self=new_self)
        return logits, new_cache

    x = embed_tokens(params["embed"], tok, dtype)
    if cfg.family == "vlm":
        pos = pos + cfg.num_patches   # cache slots 0..P-1 hold the prefix
    x, new_cache, _ = _trunk(params, x, cfg, mode="decode",
                             shard_fn=shard_fn, cache=cache, pos=pos,
                             expert_fn=expert_fn)
    x = apply_norm(params["ln_f"], x, cfg.norm_kind)
    logits = head(params["embed"], x, shard_fn)[:, 0]
    return logits, new_cache


# ---------------------------------------------------------------------------
# Shape builders (dry-run: ShapeDtypeStruct only, no allocation)
# ---------------------------------------------------------------------------


def cache_specs(cfg: ModelConfig, batch: int, max_len: int) -> PyTree:
    dt = cfg.compute_dtype
    if cfg.family == "ssm":
        return rwkv.init_state_specs(cfg, batch, dt)
    if cfg.family == "hybrid":
        return hyb.hybrid_cache_specs(cfg, batch, dt)
    if cfg.family == "encdec":
        self_len = min(max_len, whi.WHISPER_MAX_POS)
        kv = (cfg.num_layers, batch, self_len, cfg.num_kv_heads, cfg.head_dim)
        xv = (cfg.num_layers, batch, cfg.num_frames, cfg.num_kv_heads,
              cfg.head_dim)
        return {
            "self": {"k": jax.ShapeDtypeStruct(kv, jnp.dtype(dt)),
                     "v": jax.ShapeDtypeStruct(kv, jnp.dtype(dt))},
            "cross_k": jax.ShapeDtypeStruct(xv, jnp.dtype(dt)),
            "cross_v": jax.ShapeDtypeStruct(xv, jnp.dtype(dt)),
        }
    eff = min(max_len, cfg.sliding_window) if cfg.sliding_window else max_len
    eff += cfg.num_patches            # vlm: prefix occupies leading slots
    return att.kv_cache_specs(cfg.num_layers, batch, eff, cfg.num_kv_heads,
                              cfg.head_dim, dt)


def init_cache(cfg: ModelConfig, batch: int, max_len: int) -> PyTree:
    return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype),
                        cache_specs(cfg, batch, max_len))


def grow_cache(cfg: ModelConfig, cache: PyTree, max_len: int) -> PyTree:
    """Pad prefill KV caches (sized to the prompt) to ``max_len`` decode
    slots. Rolling-window and recurrent states are already fixed-size.
    Decoding past a prefill cache's length without this is an error (the
    slot write clamps) — the serving engine and tests both route here."""
    if cfg.family in ("ssm", "hybrid"):
        return cache
    window = cfg.sliding_window
    tgt = (min(max_len, window) if window else max_len) + cfg.num_patches

    def grow(x):
        # KV caches: (..., S, KV, Dh)
        if x.ndim >= 4 and x.shape[-2] == cfg.num_kv_heads \
                and x.shape[-3] < tgt:
            pad = [(0, 0)] * x.ndim
            pad[x.ndim - 3] = (0, tgt - x.shape[-3])
            return jnp.pad(x, pad)
        return x

    if cfg.family == "encdec":
        return dict(cache, self=jax.tree.map(grow, cache["self"]))
    return jax.tree.map(grow, cache)


def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> dict:
    """Model inputs for one cell (the dry-run's ShapeDtypeStruct stand-ins)."""
    b, s = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    dt = jnp.dtype(cfg.compute_dtype)
    if shape.kind == "train":
        out = {"tokens": jax.ShapeDtypeStruct((b, s), i32),
               "labels": jax.ShapeDtypeStruct((b, s), i32)}
        if cfg.family == "encdec":
            out["frames"] = jax.ShapeDtypeStruct((b, cfg.num_frames,
                                                  cfg.d_model), dt)
        if cfg.family == "vlm":
            out["patches"] = jax.ShapeDtypeStruct((b, cfg.num_patches,
                                                   cfg.d_model), dt)
        return out
    if shape.kind == "prefill":
        out = {"tokens": jax.ShapeDtypeStruct((b, s), i32)}
        if cfg.family == "encdec":
            out["frames"] = jax.ShapeDtypeStruct((b, cfg.num_frames,
                                                  cfg.d_model), dt)
        if cfg.family == "vlm":
            out["patches"] = jax.ShapeDtypeStruct((b, cfg.num_patches,
                                                   cfg.d_model), dt)
        return out
    # decode: one new token against a cache of length seq_len
    return {"token": jax.ShapeDtypeStruct((b,), i32),
            "pos": jax.ShapeDtypeStruct((), i32)}
