"""Shared layers: norms, RoPE, embeddings, MLPs, activation-sharding hooks.

All functions are pure; parameters arrive as dict subtrees produced from the
matching ``*_specs`` helpers. Activation sharding constraints are injected
via the ``shard_fn`` threaded through model code (identity on a single
device; launch/sharding.py supplies the mesh-aware version).
"""
from __future__ import annotations

from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.models.common import ParamSpec

ShardFn = Callable[[jax.Array, tuple], jax.Array]


def no_shard(x: jax.Array, logical_axes: tuple) -> jax.Array:
    return x


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------


def norm_specs(d: int, kind: str) -> dict:
    if kind == "rmsnorm":
        return {"scale": ParamSpec((d,), ("embed",), init="ones")}
    return {"scale": ParamSpec((d,), ("embed",), init="ones"),
            "bias": ParamSpec((d,), ("embed",), init="zeros")}


def apply_norm(p: dict, x: jax.Array, kind: str, eps: float = 1e-6) -> jax.Array:
    xf = x.astype(jnp.float32)
    if kind == "rmsnorm":
        var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
        y = xf * jax.lax.rsqrt(var + eps)
        return (y * p["scale"].astype(jnp.float32)).astype(x.dtype)
    mean = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mean) * jax.lax.rsqrt(var + eps)
    y = y * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)
    return y.astype(x.dtype)


# ---------------------------------------------------------------------------
# Rotary position embeddings
# ---------------------------------------------------------------------------


def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., S, H, Dh); positions: broadcastable to (..., S)."""
    if theta <= 0:
        return x
    dh = x.shape[-1]
    half = dh // 2
    freq = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., :, None].astype(jnp.float32) * freq  # (..., S, half)
    cos = jnp.cos(ang)[..., :, None, :]                       # (..., S, 1, half)
    sin = jnp.sin(ang)[..., :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    xf1, xf2 = x1.astype(jnp.float32), x2.astype(jnp.float32)
    out = jnp.concatenate([xf1 * cos - xf2 * sin,
                           xf2 * cos + xf1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Embeddings / LM head
# ---------------------------------------------------------------------------


def embedding_specs(vocab: int, d: int, tie: bool) -> dict:
    out = {"tok": ParamSpec((vocab, d), ("vocab", "embed"))}
    if not tie:
        out["out"] = ParamSpec((d, vocab), ("embed", "vocab"))
    return out


def embed_tokens(p: dict, tokens: jax.Array, dtype) -> jax.Array:
    return jnp.take(p["tok"], tokens, axis=0).astype(dtype)


def lm_logits(p: dict, x: jax.Array, shard_fn: ShardFn = no_shard) -> jax.Array:
    w = p.get("out")
    if w is None:
        w = p["tok"].T
    logits = jnp.einsum("bsd,dv->bsv", x, w.astype(x.dtype))
    return shard_fn(logits, ("batch", None, "vocab"))


def cross_entropy(logits: jax.Array, labels: jax.Array,
                  mask: Optional[jax.Array] = None) -> jax.Array:
    """Token-mean cross entropy, fp32 reductions, fused-friendly."""
    lf = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(lf, axis=-1)
    gold = jnp.take_along_axis(lf, labels[..., None], axis=-1)[..., 0]
    nll = lse - gold
    if mask is not None:
        m = mask.astype(jnp.float32)
        return jnp.sum(nll * m) / jnp.maximum(jnp.sum(m), 1.0)
    return jnp.mean(nll)


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------


def mlp_specs(d: int, f: int, kind: str, depth_scale: float) -> dict:
    if kind == "swiglu":
        return {
            "wi": ParamSpec((d, f), ("embed", "mlp")),
            "wg": ParamSpec((d, f), ("embed", "mlp")),
            "wo": ParamSpec((f, d), ("mlp", "embed"), scale=depth_scale),
        }
    return {
        "wi": ParamSpec((d, f), ("embed", "mlp")),
        "bi": ParamSpec((f,), ("mlp",), init="zeros"),
        "wo": ParamSpec((f, d), ("mlp", "embed"), scale=depth_scale),
        "bo": ParamSpec((d,), ("embed",), init="zeros"),
    }


def apply_mlp(p: dict, x: jax.Array, kind: str,
              shard_fn: ShardFn = no_shard) -> jax.Array:
    if kind == "swiglu":
        h = jnp.einsum("bsd,df->bsf", x, p["wi"].astype(x.dtype))
        g = jnp.einsum("bsd,df->bsf", x, p["wg"].astype(x.dtype))
        h = jax.nn.silu(g) * h
    else:
        h = jnp.einsum("bsd,df->bsf", x, p["wi"].astype(x.dtype))
        h = h + p["bi"].astype(x.dtype)
        h = jax.nn.gelu(h)
    h = shard_fn(h, ("batch", None, "mlp"))
    out = jnp.einsum("bsf,fd->bsd", h, p["wo"].astype(x.dtype))
    if "bo" in p:
        out = out + p["bo"].astype(x.dtype)
    return out
