"""RecurrentGemma-style hybrid stack (arXiv:2402.19427): RG-LRU recurrent
blocks + local (windowed) attention, cycled by ``cfg.block_pattern``
(assigned 1 attention : 2 recurrent). Every temporal block is followed by a
gated MLP, per the Griffin residual structure.

RG-LRU: r_t = sigmoid(Wa y_t + ba); i_t = sigmoid(Wx y_t + bx)
        a_t = exp(-c * softplus(Lambda) * r_t)           (c = 8)
        h_t = a_t h_{t-1} + sqrt(1 - a_t^2) * (i_t * y_t)
Train/prefill evaluate the recurrence with ``lax.associative_scan``
(parallel prefix — the TPU-friendly form; the Pallas kernel in
kernels/rglru.py is the fused production path). Decode is the single-step
update, so decode state is O(lru_width) — long_500k runs.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention as att
from repro.models import transformer as tfm
from repro.models.common import ParamSpec, stacked
from repro.models.layers import (ShardFn, apply_mlp, apply_norm, mlp_specs,
                                 no_shard, norm_specs)

RGLRU_C = 8.0


def _lru_blocks(cfg: ModelConfig) -> tuple[int, int]:
    lw = cfg.lru_width or cfg.d_model
    nb = max(1, cfg.num_heads)
    assert lw % nb == 0, (lw, nb)
    return nb, lw // nb


def recurrent_block_specs(cfg: ModelConfig) -> dict:
    d = cfg.d_model
    lw = cfg.lru_width or cfg.d_model
    nb, bs = _lru_blocks(cfg)
    ds = tfm.depth_scale(cfg)
    return {
        "ln1": norm_specs(d, cfg.norm_kind),
        "ln2": norm_specs(d, cfg.norm_kind),
        "w_in": ParamSpec((d, lw), ("embed", "lru")),
        "w_gate": ParamSpec((d, lw), ("embed", "lru")),
        "conv_w": ParamSpec((cfg.conv1d_width, lw), (None, "lru")),
        "conv_b": ParamSpec((lw,), ("lru",), init="zeros"),
        "wa": ParamSpec((nb, bs, bs), ("lru_blocks", None, None)),
        "ba": ParamSpec((lw,), ("lru",), init="zeros"),
        "wx": ParamSpec((nb, bs, bs), ("lru_blocks", None, None)),
        "bx": ParamSpec((lw,), ("lru",), init="zeros"),
        "lam": ParamSpec((lw,), ("lru",), init="ones"),
        "w_out": ParamSpec((lw, d), ("lru", "embed"), scale=ds),
        "mlp": mlp_specs(d, cfg.d_ff, cfg.mlp_kind, ds),
    }


def _causal_conv1d(x: jax.Array, w: jax.Array, b: jax.Array,
                   prev: Optional[jax.Array]):
    """Depthwise causal conv. x: (B,T,C); w: (cw,C); prev: (B,cw-1,C) state.
    Returns (y, new_prev)."""
    cw = w.shape[0]
    if prev is None:
        prev = jnp.zeros((x.shape[0], cw - 1, x.shape[2]), x.dtype)
    xp = jnp.concatenate([prev, x], axis=1)
    y = b.astype(x.dtype)[None, None, :] + sum(
        xp[:, i:i + x.shape[1]] * w[i].astype(x.dtype) for i in range(cw))
    return y, xp[:, -(cw - 1):, :]


def _rglru(y: jax.Array, p: dict, h0: jax.Array, nb: int, bs: int):
    """y: (B,T,lru) f32. h0: (B,lru) f32. Returns (h_seq (B,T,lru), h_last)."""
    b, t, lw = y.shape
    yb = y.reshape(b, t, nb, bs)
    r = jax.nn.sigmoid(jnp.einsum("btni,nij->btnj", yb,
                                  p["wa"].astype(jnp.float32)).reshape(b, t, lw)
                       + p["ba"].astype(jnp.float32))
    i = jax.nn.sigmoid(jnp.einsum("btni,nij->btnj", yb,
                                  p["wx"].astype(jnp.float32)).reshape(b, t, lw)
                       + p["bx"].astype(jnp.float32))
    log_a = -RGLRU_C * jax.nn.softplus(p["lam"].astype(jnp.float32)) * r
    a = jnp.exp(log_a)
    gated = jnp.sqrt(jnp.maximum(1.0 - jnp.square(a), 1e-12)) * (i * y)

    if t == 1:
        h = a[:, 0] * h0 + gated[:, 0]
        return h[:, None], h
    # h_t = a_t h_{t-1} + b_t  via associative scan; fold h0 into b_1.
    gated = gated.at[:, 0].add(a[:, 0] * h0)

    def combine(c1, c2):
        a1, b1 = c1
        a2, b2 = c2
        return a1 * a2, a2 * b1 + b2

    _, hs = jax.lax.associative_scan(combine, (a, gated), axis=1)
    return hs, hs[:, -1]


def apply_recurrent_block(p: dict, x: jax.Array, cfg: ModelConfig, *,
                          shard_fn: ShardFn, state: dict):
    """state: {"h": (B,lru) f32, "conv": (B,cw-1,lru)}."""
    nb, bs = _lru_blocks(cfg)
    dt = x.dtype
    xin = apply_norm(p["ln1"], x, cfg.norm_kind)
    y = jnp.einsum("btd,dl->btl", xin, p["w_in"].astype(dt))
    gate = jnp.einsum("btd,dl->btl", xin, p["w_gate"].astype(dt))
    y = shard_fn(y, ("batch", None, "lru"))
    y, new_conv = _causal_conv1d(y, p["conv_w"], p["conv_b"], state["conv"])
    hs, h_last = _rglru(y.astype(jnp.float32), p,
                        state["h"].astype(jnp.float32), nb, bs)
    out = hs.astype(dt) * jax.nn.gelu(gate)
    out = jnp.einsum("btl,ld->btd", out, p["w_out"].astype(dt))
    x = x + out
    x = shard_fn(x, ("batch", "seq", None))

    h2 = apply_norm(p["ln2"], x, cfg.norm_kind)
    x = x + apply_mlp(p["mlp"], h2, cfg.mlp_kind, shard_fn)
    x = shard_fn(x, ("batch", "seq", None))
    return x, {"h": h_last, "conv": new_conv}


# ---------------------------------------------------------------------------
# Pattern stack: scan over groups of len(block_pattern); remainder unrolled.
# ---------------------------------------------------------------------------


def _group_layout(cfg: ModelConfig) -> tuple[int, tuple[str, ...]]:
    pat = cfg.block_pattern
    n_groups = cfg.num_layers // len(pat)
    tail = tuple(pat[i % len(pat)]
                 for i in range(n_groups * len(pat), cfg.num_layers))
    return n_groups, tail


def hybrid_stack_specs(cfg: ModelConfig) -> dict:
    pat = cfg.block_pattern
    n_groups, tail = _group_layout(cfg)

    def one(kind: str) -> dict:
        if kind == "rglru":
            return recurrent_block_specs(cfg)
        return tfm.block_specs(cfg, "dense")

    group = {f"b{i}_{k}": one(k) for i, k in enumerate(pat)}
    specs = {"groups": jax.tree.map(lambda s: stacked(s, n_groups), group,
                                    is_leaf=lambda x: isinstance(x, ParamSpec))}
    for i, k in enumerate(tail):
        specs[f"tail{i}_{k}"] = one(k)
    return specs


def _cache_entry_specs(cfg: ModelConfig, kind: str, batch: int, dtype):
    if kind == "rglru":
        lw = cfg.lru_width or cfg.d_model
        return {
            "h": jax.ShapeDtypeStruct((batch, lw), jnp.float32),
            "conv": jax.ShapeDtypeStruct((batch, cfg.conv1d_width - 1, lw),
                                         jnp.dtype(dtype)),
        }
    w = cfg.local_window
    return {
        "k": jax.ShapeDtypeStruct((batch, w, cfg.num_kv_heads, cfg.head_dim),
                                  jnp.dtype(dtype)),
        "v": jax.ShapeDtypeStruct((batch, w, cfg.num_kv_heads, cfg.head_dim),
                                  jnp.dtype(dtype)),
    }


def hybrid_cache_specs(cfg: ModelConfig, batch: int, dtype) -> dict:
    pat = cfg.block_pattern
    n_groups, tail = _group_layout(cfg)
    group = {f"b{i}_{k}": _cache_entry_specs(cfg, k, batch, dtype)
             for i, k in enumerate(pat)}
    out = {"groups": jax.tree.map(
        lambda s: jax.ShapeDtypeStruct((n_groups,) + s.shape, s.dtype), group)}
    for i, k in enumerate(tail):
        out[f"tail{i}_{k}"] = _cache_entry_specs(cfg, k, batch, dtype)
    return out


def init_hybrid_cache(cfg: ModelConfig, batch: int, dtype) -> dict:
    return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype),
                        hybrid_cache_specs(cfg, batch, dtype))


def _apply_kind(p, x, cfg, kind, mode, shard_fn, cache, pos, q_positions):
    if kind == "rglru":
        if cache is None:
            b = x.shape[0]
            lw = cfg.lru_width or cfg.d_model
            cache = {"h": jnp.zeros((b, lw), jnp.float32),
                     "conv": jnp.zeros((b, cfg.conv1d_width - 1, lw), x.dtype)}
        x, new = apply_recurrent_block(p, x, cfg, shard_fn=shard_fn,
                                       state=cache)
        if mode == "train":
            new = None
        return x, new, jnp.zeros((), jnp.float32)
    # local attention block
    ck = cache["k"] if cache else None
    cv = cache["v"] if cache else None
    x, nk, nv, aux = tfm.apply_block(
        p, x, cfg, kind="dense", mode=mode, shard_fn=shard_fn,
        window=cfg.local_window, cache_k=ck, cache_v=cv, pos=pos,
        q_positions=q_positions)
    if mode == "train":
        return x, None, aux
    # prefill caches arrive already in rolling window layout (apply_block)
    return x, {"k": nk, "v": nv}, aux


def apply_hybrid_stack(params: dict, x: jax.Array, cfg: ModelConfig, *,
                       mode: str, shard_fn: ShardFn = no_shard,
                       cache: Optional[dict] = None,
                       pos: Optional[jax.Array] = None,
                       q_positions: Optional[jax.Array] = None):
    pat = cfg.block_pattern
    n_groups, tail = _group_layout(cfg)
    use_cache = mode != "train"
    if use_cache and cache is None:
        cache = init_hybrid_cache(cfg, x.shape[0], x.dtype)

    def group_body(carry, xs):
        x = carry
        aux = jnp.zeros((), jnp.float32)
        if use_cache:
            p, c = xs
        else:
            p, c = xs, {}
        new_c = {}
        for i, k in enumerate(pat):
            key = f"b{i}_{k}"
            x, nc, a = _apply_kind(p[key], x, cfg, k, mode, shard_fn,
                                   c.get(key) if use_cache else None,
                                   pos, q_positions)
            aux = aux + a
            if use_cache:
                new_c[key] = nc
        return x, (new_c, aux) if use_cache else aux

    from repro.models.unroll import scan_or_unroll
    body = jax.checkpoint(group_body) if mode == "train" else group_body
    if use_cache:
        x, (gcache, auxs) = scan_or_unroll(
            body, x, (params["groups"], cache["groups"]), n_groups)
    else:
        x, auxs = scan_or_unroll(body, x, params["groups"], n_groups)
        gcache = None
    aux = jnp.sum(auxs)

    new_cache = {"groups": gcache} if use_cache else None
    for i, k in enumerate(tail):
        key = f"tail{i}_{k}"
        x, nc, a = _apply_kind(params[key], x, cfg, k, mode, shard_fn,
                               cache.get(key) if use_cache else None,
                               pos, q_positions)
        aux = aux + a
        if use_cache:
            new_cache[key] = nc
    return x, new_cache, aux
