"""RWKV-6 "Finch" (arXiv:2404.05892): attention-free time mix with
data-dependent decay + squared-ReLU channel mix.

Recurrence (per head, head_size hs): state S in R^{hs x hs},
    S_t = diag(w_t) S_{t-1} + k_t (x) v_t
    y_t = r_t . (S_{t-1} + diag(u) k_t (x) v_t)
with w_t = exp(-exp(w0 + lora_w(x_t))) in (0,1) — the data-dependent decay.

Train/prefill use a ``lax.scan`` over time (compact HLO; the Pallas chunked
kernel in kernels/rwkv6_scan.py is the TPU production path and is validated
against this module). Decode is the single-step update — O(1) in sequence
length, which is why all long-context cells run for this family.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.common import ParamSpec, stacked
from repro.models.layers import ShardFn, apply_norm, no_shard, norm_specs

N_MIX = 5  # r, k, v, g, w token-shift interpolations


def rwkv_block_specs(cfg: ModelConfig) -> dict:
    d, f = cfg.d_model, cfg.d_ff
    lw, lm = cfg.rwkv_decay_lora, cfg.rwkv_mix_lora
    return {
        "ln1": norm_specs(d, "layernorm"),
        "ln2": norm_specs(d, "layernorm"),
        "tm": {
            "mu_x": ParamSpec((d,), ("embed",), init="zeros"),
            "mu": ParamSpec((N_MIX, d), (None, "embed"), init="zeros"),
            "mix_a": ParamSpec((d, N_MIX * lm), ("embed", None)),
            "mix_b": ParamSpec((N_MIX, lm, d), (None, None, "embed")),
            "w0": ParamSpec((d,), ("embed",), init="zeros"),
            "w_a": ParamSpec((d, lw), ("embed", None)),
            "w_b": ParamSpec((lw, d), (None, "embed")),
            "u": ParamSpec((d,), ("embed",), init="zeros"),
            "wr": ParamSpec((d, d), ("embed", "heads")),
            "wk": ParamSpec((d, d), ("embed", "heads")),
            "wv": ParamSpec((d, d), ("embed", "heads")),
            "wg": ParamSpec((d, d), ("embed", "heads")),
            "wo": ParamSpec((d, d), ("heads", "embed")),
            "ln_x": norm_specs(d, "layernorm"),
        },
        "cm": {
            "mu_k": ParamSpec((d,), ("embed",), init="zeros"),
            "mu_r": ParamSpec((d,), ("embed",), init="zeros"),
            "wk": ParamSpec((d, f), ("embed", "mlp")),
            "wv": ParamSpec((f, d), ("mlp", "embed")),
            "wr": ParamSpec((d, d), ("embed", "heads")),
        },
    }


def rwkv_stack_specs(cfg: ModelConfig) -> dict:
    one = rwkv_block_specs(cfg)
    return jax.tree.map(lambda s: stacked(s, cfg.num_layers), one,
                        is_leaf=lambda x: isinstance(x, ParamSpec))


def _shift(x: jax.Array, prev: jax.Array) -> jax.Array:
    """Token shift: x_{t-1}, with ``prev`` (B,1,D) for position -1."""
    return jnp.concatenate([prev, x[:, :-1]], axis=1)


def _ddlerp(p: dict, x: jax.Array, xx: jax.Array):
    """Data-dependent interpolations for the 5 branches. Returns (B,T,5,D)."""
    dt = x.dtype
    base = x + xx * p["mu_x"].astype(dt)
    lo = jnp.tanh(jnp.einsum("btd,dm->btm", base, p["mix_a"].astype(dt)))
    lo = lo.reshape(*lo.shape[:-1], N_MIX, -1)
    delta = jnp.einsum("btnm,nmd->btnd", lo, p["mix_b"].astype(dt))
    mix = p["mu"].astype(dt) + delta                      # (B,T,5,D)
    return x[:, :, None, :] + xx[:, :, None, :] * mix


def _wkv_scan(r, k, v, w, u, state):
    """r,k,v: (B,T,H,hs); w: (B,T,H,hs) decay in (0,1); u: (H,hs).
    state: (B,H,hs,hs). Returns (out (B,T,H,hs), new_state). f32 math."""
    def step(s, inp):
        rt, kt, vt, wt = inp                              # (B,H,hs)
        kv = kt[..., :, None] * vt[..., None, :]          # (B,H,hs,hs)
        y = jnp.einsum("bhi,bhij->bhj", rt, s + u[None, :, :, None] * kv)
        s = wt[..., :, None] * s + kv
        return s, y

    seq = jax.tree.map(lambda a: jnp.moveaxis(a, 1, 0), (r, k, v, w))
    state, ys = jax.lax.scan(step, state, seq)
    return jnp.moveaxis(ys, 0, 1), state


def apply_rwkv_block(p: dict, x: jax.Array, cfg: ModelConfig, *,
                     shard_fn: ShardFn, state: dict):
    """state: {"wkv": (B,H,hs,hs) f32, "tm_x": (B,1,D), "cm_x": (B,1,D)}.
    Works for any T (train/prefill: T=S; decode: T=1)."""
    b, t, d = x.shape
    hs = cfg.rwkv_head_size
    h = d // hs
    dt = x.dtype

    # ---- time mix ----
    xin = apply_norm(p["ln1"], x, "layernorm")
    xprev = _shift(xin, state["tm_x"].astype(dt))
    xx = xprev - xin
    xb = _ddlerp(p["tm"], xin, xx)                         # (B,T,5,D)
    xr, xk, xv, xg, xw = [xb[:, :, i] for i in range(N_MIX)]
    r = jnp.einsum("btd,dk->btk", xr, p["tm"]["wr"].astype(dt))
    k = jnp.einsum("btd,dk->btk", xk, p["tm"]["wk"].astype(dt))
    v = jnp.einsum("btd,dk->btk", xv, p["tm"]["wv"].astype(dt))
    g = jax.nn.silu(jnp.einsum("btd,dk->btk", xg, p["tm"]["wg"].astype(dt)))
    wl = jnp.tanh(jnp.einsum("btd,dl->btl", xw, p["tm"]["w_a"].astype(dt)))
    wlog = p["tm"]["w0"].astype(jnp.float32) + \
        jnp.einsum("btl,ld->btd", wl, p["tm"]["w_b"].astype(dt)).astype(jnp.float32)
    w = jnp.exp(-jnp.exp(wlog))                            # (B,T,D) in (0,1)

    shp = (b, t, h, hs)
    rh = shard_fn(r.reshape(shp).astype(jnp.float32), ("batch", None, "heads", None))
    kh = shard_fn(k.reshape(shp).astype(jnp.float32), ("batch", None, "heads", None))
    vh = shard_fn(v.reshape(shp).astype(jnp.float32), ("batch", None, "heads", None))
    wh = shard_fn(w.reshape(shp), ("batch", None, "heads", None))
    u = p["tm"]["u"].astype(jnp.float32).reshape(h, hs)
    y, new_wkv = _wkv_scan(rh, kh, vh, wh, u, state["wkv"].astype(jnp.float32))

    y = apply_norm(p["tm"]["ln_x"], y.reshape(b, t, d).astype(dt),
                   "layernorm", eps=1e-5)
    y = y * g
    y = jnp.einsum("btk,kd->btd", y, p["tm"]["wo"].astype(dt))
    x = x + y
    x = shard_fn(x, ("batch", "seq", None))
    new_tm_x = xin[:, -1:, :]

    # ---- channel mix ----
    xin = apply_norm(p["ln2"], x, "layernorm")
    xprev = _shift(xin, state["cm_x"].astype(dt))
    xx = xprev - xin
    xk_ = xin + xx * p["cm"]["mu_k"].astype(dt)
    xr_ = xin + xx * p["cm"]["mu_r"].astype(dt)
    kk = jnp.einsum("btd,df->btf", xk_, p["cm"]["wk"].astype(dt))
    kk = jnp.square(jax.nn.relu(kk))
    kk = shard_fn(kk, ("batch", None, "mlp"))
    vv = jnp.einsum("btf,fd->btd", kk, p["cm"]["wv"].astype(dt))
    rr = jax.nn.sigmoid(jnp.einsum("btd,dk->btk", xr_, p["cm"]["wr"].astype(dt)))
    x = x + rr * vv
    x = shard_fn(x, ("batch", "seq", None))
    new_state = {"wkv": new_wkv, "tm_x": new_tm_x, "cm_x": xin[:, -1:, :]}
    return x, new_state


def init_state_specs(cfg: ModelConfig, batch: int, dtype) -> dict:
    d = cfg.d_model
    hs = cfg.rwkv_head_size
    h = d // hs
    L = cfg.num_layers
    return {
        "wkv": jax.ShapeDtypeStruct((L, batch, h, hs, hs), jnp.float32),
        "tm_x": jax.ShapeDtypeStruct((L, batch, 1, d), jnp.dtype(dtype)),
        "cm_x": jax.ShapeDtypeStruct((L, batch, 1, d), jnp.dtype(dtype)),
    }


def init_state(cfg: ModelConfig, batch: int, dtype) -> dict:
    return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype),
                        init_state_specs(cfg, batch, dtype))


def apply_rwkv_stack(params: dict, x: jax.Array, cfg: ModelConfig, *,
                     mode: str, shard_fn: ShardFn = no_shard,
                     state: dict = None):
    """Scan blocks over layers, threading per-layer state (always present —
    zeros in train mode; state doubles as the decode cache)."""
    b = x.shape[0]
    if state is None:
        state = init_state(cfg, b, x.dtype)

    def body(carry, xs):
        x = carry
        p, st = xs
        x, new_st = apply_rwkv_block(p, x, cfg, shard_fn=shard_fn, state=st)
        return x, new_st

    if mode == "train":
        body = jax.checkpoint(body)
    from repro.models.unroll import scan_or_unroll
    x, new_state = scan_or_unroll(body, x, (params, state), cfg.num_layers)
    return x, new_state
