"""Layer-loop unrolling switch.

XLA's ``cost_analysis`` counts a while-loop body ONCE regardless of trip
count (verified: scan(n=1|2|8) of a matmul all report identical FLOPs),
so every scanned-over-layers model under-reports FLOPs / bytes /
collective traffic by ~L x in the dry-run. The dry-run therefore lowers
two small UNROLLED variants (1 and 2 layer groups) under this switch and
extrapolates per-layer costs to the assigned depth — see
launch/dryrun.py and EXPERIMENTS.md §Methodology.

Training/serving code never sets this: scan keeps the HLO (and compile
time) small, which is the production-correct choice.
"""
from __future__ import annotations

import contextlib

_UNROLL = False


def unroll_enabled() -> bool:
    return _UNROLL


@contextlib.contextmanager
def unrolled_layers():
    global _UNROLL
    prev = _UNROLL
    _UNROLL = True
    try:
        yield
    finally:
        _UNROLL = prev


def scan_or_unroll(body, init, xs, length: int):
    """lax.scan(body, init, xs) or an equivalent Python loop when
    unrolling is on. ``xs`` is a pytree stacked on dim 0 (length L)."""
    import jax

    if not _UNROLL:
        return jax.lax.scan(body, init, xs)
    carry = init
    ys = []
    for i in range(length):
        x_i = jax.tree.map(lambda a: a[i], xs)
        carry, y = body(carry, x_i)
        ys.append(y)
    if ys and ys[0] is not None:
        stacked = jax.tree.map(lambda *zs: jax.numpy.stack(zs), *ys)
    else:
        stacked = None
    return carry, stacked
