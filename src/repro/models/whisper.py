"""Whisper-style encoder-decoder backbone (arXiv:2212.04356).

The conv/mel frontend is a STUB per the brief: inputs are precomputed frame
embeddings (B, num_frames, d_model). Learned absolute positions (no RoPE),
LayerNorm + GELU, biases — per the Whisper architecture. The few layers are
unrolled (no scan; HLO stays small at 4+4).

Decode cells run ``serve_step`` on the decoder: rolling self-attention KV
cache of length seq_len plus precomputed cross-attention K/V.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention as att
from repro.models import transformer as tfm
from repro.models.common import ParamSpec
from repro.models.layers import (ShardFn, apply_mlp, apply_norm,
                                 embedding_specs, embed_tokens, lm_logits,
                                 mlp_specs, no_shard, norm_specs)

WHISPER_MAX_POS = 32768   # sized for the decode_32k cell (mechanical)


def _enc_block_specs(cfg: ModelConfig) -> dict:
    ds = tfm.depth_scale(cfg)
    return {
        "ln1": norm_specs(cfg.d_model, "layernorm"),
        "ln2": norm_specs(cfg.d_model, "layernorm"),
        "attn": att.attention_specs(cfg.d_model, cfg.num_heads,
                                    cfg.num_kv_heads, cfg.head_dim,
                                    cfg.qkv_bias, ds),
        "mlp": mlp_specs(cfg.d_model, cfg.d_ff, "gelu", ds),
    }


def _dec_block_specs(cfg: ModelConfig) -> dict:
    s = _enc_block_specs(cfg)
    s["ln_x"] = norm_specs(cfg.d_model, "layernorm")
    s["xattn"] = att.attention_specs(cfg.d_model, cfg.num_heads,
                                     cfg.num_kv_heads, cfg.head_dim,
                                     cfg.qkv_bias, tfm.depth_scale(cfg))
    return s


def whisper_specs(cfg: ModelConfig) -> dict:
    specs: dict = {
        "embed": embedding_specs(cfg.vocab_size, cfg.d_model,
                                 cfg.tie_embeddings),
        "pos_enc": ParamSpec((cfg.num_frames, cfg.d_model), ("frames", "embed")),
        "pos_dec": ParamSpec((WHISPER_MAX_POS, cfg.d_model), ("seq", "embed")),
        "ln_enc": norm_specs(cfg.d_model, "layernorm"),
        "ln_dec": norm_specs(cfg.d_model, "layernorm"),
    }
    for i in range(cfg.encoder_layers):
        specs[f"enc{i}"] = _enc_block_specs(cfg)
    for i in range(cfg.num_layers):
        specs[f"dec{i}"] = _dec_block_specs(cfg)
    return specs


def _self_attn(p, x, cfg, *, causal, positions, shard_fn,
               cache_k=None, cache_v=None, pos=None, window=0):
    q, k, v = att.project_qkv(p, x, x, positions, positions, 0.0, shard_fn)
    if cache_k is not None:
        out, nk, nv = att.decode_attend(q, cache_k, cache_v, k, v, pos,
                                        num_heads=cfg.num_heads,
                                        window=window, shard_fn=shard_fn)
        return att.out_project(p, out, shard_fn), nk, nv
    kx = att.expand_kv(k, cfg.num_heads)
    vx = att.expand_kv(v, cfg.num_heads)
    out = att.attend_chunked(q, kx, vx, causal=causal, window=0)
    return att.out_project(p, out, shard_fn), k, v


def _cross_attn(p, x, cfg, *, enc_k, enc_v, shard_fn):
    """enc_k/v: (B,F,KV,Dh) precomputed from encoder output."""
    b, s, _ = x.shape
    dtp = x.dtype
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(dtp))
    if "bq" in p:
        q = q + p["bq"].astype(dtp)
    kx = att.expand_kv(enc_k, cfg.num_heads)
    vx = att.expand_kv(enc_v, cfg.num_heads)
    qpos = jnp.arange(s)
    kpos = jnp.arange(enc_k.shape[1])
    out = att.attend_direct(q, kx, vx, qpos, kpos, causal=False)
    return att.out_project(p, out, shard_fn)


def encode(params: dict, frames: jax.Array, cfg: ModelConfig,
           shard_fn: ShardFn = no_shard) -> jax.Array:
    x = frames + params["pos_enc"].astype(frames.dtype)[None, :frames.shape[1]]
    pos = jnp.arange(frames.shape[1])
    for i in range(cfg.encoder_layers):
        p = params[f"enc{i}"]
        h = apply_norm(p["ln1"], x, "layernorm")
        a, _, _ = _self_attn(p["attn"], h, cfg, causal=False, positions=pos,
                             shard_fn=shard_fn)
        x = x + a
        h = apply_norm(p["ln2"], x, "layernorm")
        x = x + apply_mlp(p["mlp"], h, "gelu", shard_fn)
    return apply_norm(params["ln_enc"], x, "layernorm")


def _cross_kv(params: dict, enc_out: jax.Array, cfg: ModelConfig):
    """Precompute per-decoder-layer cross K/V: (L,B,F,KV,Dh) pair."""
    ks, vs = [], []
    dt = enc_out.dtype
    for i in range(cfg.num_layers):
        p = params[f"dec{i}"]["xattn"]
        k = jnp.einsum("bsd,dhk->bshk", enc_out, p["wk"].astype(dt))
        v = jnp.einsum("bsd,dhk->bshk", enc_out, p["wv"].astype(dt))
        if "bk" in p:
            k = k + p["bk"].astype(dt)
            v = v + p["bv"].astype(dt)
        ks.append(k)
        vs.append(v)
    return jnp.stack(ks), jnp.stack(vs)


def decode_stack(params: dict, x: jax.Array, cfg: ModelConfig, *,
                 mode: str, cross_k, cross_v, shard_fn: ShardFn,
                 cache: Optional[dict] = None, pos=None):
    """x: embedded decoder input (B,S,D). cross_k/v: (L,B,F,KV,Dh)."""
    new_k, new_v = [], []
    for i in range(cfg.num_layers):
        p = params[f"dec{i}"]
        h = apply_norm(p["ln1"], x, "layernorm")
        if mode == "decode":
            base = pos[..., None] if jnp.ndim(pos) else pos
            positions = base + jnp.zeros((1,), jnp.int32)
            a, nk, nv = _self_attn(p["attn"], h, cfg, causal=True,
                                   positions=positions, shard_fn=shard_fn,
                                   cache_k=cache["k"][i], cache_v=cache["v"][i],
                                   pos=pos)
        else:
            positions = jnp.arange(x.shape[1])
            a, nk, nv = _self_attn(p["attn"], h, cfg, causal=True,
                                   positions=positions, shard_fn=shard_fn)
        x = x + a
        h = apply_norm(p["ln_x"], x, "layernorm")
        x = x + _cross_attn(p["xattn"], h, cfg, enc_k=cross_k[i],
                            enc_v=cross_v[i], shard_fn=shard_fn)
        h = apply_norm(p["ln2"], x, "layernorm")
        x = x + apply_mlp(p["mlp"], h, "gelu", shard_fn)
        if mode != "train":
            new_k.append(nk)
            new_v.append(nv)
    x = apply_norm(params["ln_dec"], x, "layernorm")
    if mode == "train":
        return x, None
    return x, {"k": jnp.stack(new_k), "v": jnp.stack(new_v)}
