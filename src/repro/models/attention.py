"""Attention: GQA projections + chunked online-softmax attention.

Three execution regimes:

* ``attend_chunked`` — train/prefill. Outer python loop over query chunks
  (static per-chunk KV prefix => causal FLOPs ~= S^2/2, not S^2), inner
  ``lax.scan`` over KV chunks with online softmax (flash-style; bounded
  VMEM/HBM working set). Sliding windows slice a static band per q-chunk.
* ``attend_direct`` — short sequences (encoders) and decode (Sq == 1).
* ``kernels/flash_attention.py`` — the Pallas TPU production kernel; this
  module is its jnp oracle and the CPU/dry-run path.

KV caches: full-attention caches are (B, S_max, KV, Dh) written at ``pos``;
windowed caches are rolling (slot = pos % window).
"""
from __future__ import annotations

import math
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.models.common import ParamSpec
from repro.models.layers import ShardFn, no_shard, rope

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# Projections
# ---------------------------------------------------------------------------


def attention_specs(d: int, num_heads: int, num_kv: int, head_dim: int,
                    bias: bool, depth_scale: float) -> dict:
    s: dict = {
        "wq": ParamSpec((d, num_heads, head_dim), ("embed", "heads", None)),
        "wk": ParamSpec((d, num_kv, head_dim), ("embed", "kv_heads", None)),
        "wv": ParamSpec((d, num_kv, head_dim), ("embed", "kv_heads", None)),
        "wo": ParamSpec((num_heads, head_dim, d), ("heads", None, "embed"),
                        scale=depth_scale),
    }
    if bias:
        s["bq"] = ParamSpec((num_heads, head_dim), ("heads", None), init="zeros")
        s["bk"] = ParamSpec((num_kv, head_dim), ("kv_heads", None), init="zeros")
        s["bv"] = ParamSpec((num_kv, head_dim), ("kv_heads", None), init="zeros")
    return s


def project_qkv(p: dict, xq: jax.Array, xkv: jax.Array,
                q_positions: jax.Array, kv_positions: jax.Array,
                rope_theta: float, shard_fn: ShardFn = no_shard):
    """Returns q (B,Sq,H,Dh), k/v (B,Skv,KV,Dh); RoPE applied to q and k."""
    dt = xq.dtype
    q = jnp.einsum("bsd,dhk->bshk", xq, p["wq"].astype(dt))
    k = jnp.einsum("bsd,dhk->bshk", xkv, p["wk"].astype(dt))
    v = jnp.einsum("bsd,dhk->bshk", xkv, p["wv"].astype(dt))
    if "bq" in p:
        q = q + p["bq"].astype(dt)
        k = k + p["bk"].astype(dt)
        v = v + p["bv"].astype(dt)
    q = rope(q, q_positions, rope_theta)
    k = rope(k, kv_positions, rope_theta)
    q = shard_fn(q, ("batch", None, "heads", None))
    k = shard_fn(k, ("batch", None, "kv_heads", None))
    v = shard_fn(v, ("batch", None, "kv_heads", None))
    return q, k, v


def out_project(p: dict, attn: jax.Array, shard_fn: ShardFn = no_shard):
    out = jnp.einsum("bshk,hkd->bsd", attn, p["wo"].astype(attn.dtype))
    return shard_fn(out, ("batch", None, "embed"))


def expand_kv(k: jax.Array, num_heads: int) -> jax.Array:
    """(B,S,KV,Dh) -> (B,S,H,Dh) by broadcasting each kv head over its
    query group (XLA fuses the broadcast into the downstream dot)."""
    b, s, kv, dh = k.shape
    g = num_heads // kv
    if g == 1:
        return k
    k = jnp.broadcast_to(k[:, :, :, None, :], (b, s, kv, g, dh))
    return k.reshape(b, s, num_heads, dh)


# ---------------------------------------------------------------------------
# Core attention
# ---------------------------------------------------------------------------


def _scores_mask(qpos: jax.Array, kpos: jax.Array, causal: bool,
                 window: int, valid_len: Optional[int] = None) -> jax.Array:
    """(..., Sq, Skv) boolean validity from absolute positions."""
    m = kpos[..., None, :] >= 0
    if valid_len is not None:
        m &= kpos[..., None, :] < valid_len
    if causal:
        m &= kpos[..., None, :] <= qpos[..., :, None]
    if window > 0:
        m &= (qpos[..., :, None] - kpos[..., None, :]) < window
    return m


def attend_direct(q: jax.Array, k: jax.Array, v: jax.Array,
                  qpos: jax.Array, kpos: jax.Array, *,
                  causal: bool, window: int = 0) -> jax.Array:
    """q: (B,Sq,H,Dh); k/v: (B,Skv,H,Dh) (already expanded)."""
    dh = q.shape[-1]
    scale = 1.0 / math.sqrt(dh)
    s = jnp.einsum("bqhd,bkhd->bhqk", q, k,
                   preferred_element_type=jnp.float32) * scale
    mask = _scores_mask(qpos, kpos, causal, window)      # (B?,Sq,Skv) or (Sq,Skv)
    while mask.ndim < s.ndim:
        mask = mask[..., None, :, :] if mask.ndim == s.ndim - 1 else mask[None]
    s = jnp.where(mask, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", p.astype(v.dtype), v,
                     preferred_element_type=jnp.float32)
    return out.astype(q.dtype)


class _OnlineState(NamedTuple):
    m: jax.Array    # (B,H,Sq) running max, f32
    l: jax.Array    # (B,H,Sq) running denom, f32
    acc: jax.Array  # (B,H,Sq,Dh) running numerator, f32


def _online_block(state: _OnlineState, q: jax.Array, kc: jax.Array,
                  vc: jax.Array, qpos: jax.Array, kpos: jax.Array,
                  causal: bool, window: int,
                  valid_len: Optional[int] = None) -> _OnlineState:
    """One KV chunk of online softmax. q: (B,Sq,H,Dh); kc/vc: (B,Kc,H,Dh)."""
    dh = q.shape[-1]
    scale = 1.0 / math.sqrt(dh)
    s = jnp.einsum("bqhd,bkhd->bhqk", q, kc,
                   preferred_element_type=jnp.float32) * scale
    mask = _scores_mask(qpos, kpos, causal, window, valid_len)[None, None]
    s = jnp.where(mask, s, NEG_INF)
    m_new = jnp.maximum(state.m, jnp.max(s, axis=-1))
    corr = jnp.exp(state.m - m_new)
    p = jnp.exp(s - m_new[..., None])
    l_new = state.l * corr + jnp.sum(p, axis=-1)
    pv = jnp.einsum("bhqk,bkhd->bhqd", p.astype(vc.dtype), vc,
                    preferred_element_type=jnp.float32)
    acc_new = state.acc * corr[..., None] + pv
    return _OnlineState(m_new, l_new, acc_new)


def attend_chunked(q: jax.Array, k: jax.Array, v: jax.Array, *,
                   causal: bool = True, window: int = 0,
                   q_chunk: int = 512, kv_chunk: int = 512) -> jax.Array:
    """Flash-style chunked attention over already-expanded k/v.

    q: (B,S,H,Dh), k/v: (B,S,H,Dh), positions are 0..S-1 (self-attention).
    Outer python loop over q-chunks keeps each chunk's KV extent *static*:
    full-causal chunk i sees prefix [0, (i+1)*qc); windowed chunk i sees the
    band [i*qc - ceil(W/kc)*kc, (i+1)*qc). HLO FLOPs are therefore the true
    causal/banded cost, which keeps the roofline compute term honest.
    """
    b, s_valid, h, dh = q.shape
    assert k.shape == (b, s_valid, h, dh), (q.shape, k.shape)
    from repro.models.unroll import unroll_enabled
    if unroll_enabled():
        # dry-run cost accounting: avoid inner KV scans (loop bodies are
        # counted once by cost_analysis) — use one direct block per q-chunk
        kv_chunk = max(kv_chunk, s_valid)
    if s_valid <= q_chunk:
        pos = jnp.arange(s_valid)
        return attend_direct(q, k, v, pos, pos, causal=causal, window=window)
    # pad to a q_chunk multiple; padded keys are masked via valid_len,
    # padded queries produce zeros (l == 0 guard) and are sliced off.
    s = -(-s_valid // q_chunk) * q_chunk
    if s != s_valid:
        pad = [(0, 0)] * 4
        pad[1] = (0, s - s_valid)
        q = jnp.pad(q, pad)
        k = jnp.pad(k, pad)
        v = jnp.pad(v, pad)
    nq = s // q_chunk

    outs = []
    for i in range(nq):
        q_i = jax.lax.slice_in_dim(q, i * q_chunk, (i + 1) * q_chunk, axis=1)
        qpos = i * q_chunk + jnp.arange(q_chunk)
        if causal and window <= 0:
            kv_start, kv_end = 0, (i + 1) * q_chunk
        elif window > 0:
            lo = i * q_chunk - (-(-window // kv_chunk)) * kv_chunk
            kv_start, kv_end = max(0, lo), (i + 1) * q_chunk
        else:
            kv_start, kv_end = 0, s
        k_i = jax.lax.slice_in_dim(k, kv_start, kv_end, axis=1)
        v_i = jax.lax.slice_in_dim(v, kv_start, kv_end, axis=1)
        span = kv_end - kv_start

        state = _OnlineState(
            m=jnp.full((b, h, q_chunk), NEG_INF, jnp.float32),
            l=jnp.zeros((b, h, q_chunk), jnp.float32),
            acc=jnp.zeros((b, h, q_chunk, dh), jnp.float32),
        )
        if span <= kv_chunk:
            kpos = kv_start + jnp.arange(span)
            state = _online_block(state, q_i, k_i, v_i, qpos, kpos,
                                  causal, window, s_valid)
        else:
            nk = -(-span // kv_chunk)
            pad = nk * kv_chunk - span
            if pad:
                cfgpad = [(0, 0)] * 4
                cfgpad[1] = (pad, 0)     # left-pad; padded kpos < 0 masked out
                k_i = jnp.pad(k_i, cfgpad)
                v_i = jnp.pad(v_i, cfgpad)
            k_i = k_i.reshape(b, nk, kv_chunk, h, dh).transpose(1, 0, 2, 3, 4)
            v_i = v_i.reshape(b, nk, kv_chunk, h, dh).transpose(1, 0, 2, 3, 4)
            base = kv_start - pad

            def body(st, inp):
                j, kc, vc = inp
                kpos = base + j * kv_chunk + jnp.arange(kv_chunk)
                return _online_block(st, q_i, kc, vc, qpos, kpos,
                                     causal, window, s_valid), None

            state, _ = jax.lax.scan(body, state,
                                    (jnp.arange(nk), k_i, v_i))
        out_i = state.acc / jnp.maximum(state.l, 1e-30)[..., None]
        outs.append(out_i.transpose(0, 2, 1, 3).astype(q.dtype))  # (B,qc,H,Dh)
    out = jnp.concatenate(outs, axis=1)
    return out[:, :s_valid] if s != s_valid else out


# ---------------------------------------------------------------------------
# Decode-step attention against a cache
# ---------------------------------------------------------------------------


def to_rolling(k: jax.Array, window: int) -> jax.Array:
    """Convert a chronological prefill cache (B,S,KV,Dh) into the rolling
    layout decode expects for windowed attention: fixed length ``window``,
    position p stored at slot p % window. Pads when S < window."""
    b, s, kv, dh = k.shape
    if s >= window:
        tail = jax.lax.slice_in_dim(k, s - window, s, axis=1)
        return jnp.roll(tail, s % window, axis=1)
    pad = [(0, 0)] * 4
    pad[1] = (0, window - s)
    return jnp.pad(k, pad)


def init_kv_cache(num_layers: int, batch: int, max_len: int, num_kv: int,
                  head_dim: int, dtype) -> dict:
    return {
        "k": jnp.zeros((num_layers, batch, max_len, num_kv, head_dim), dtype),
        "v": jnp.zeros((num_layers, batch, max_len, num_kv, head_dim), dtype),
    }


def kv_cache_specs(num_layers: int, batch: int, max_len: int, num_kv: int,
                   head_dim: int, dtype) -> dict:
    sh = (num_layers, batch, max_len, num_kv, head_dim)
    return {"k": jax.ShapeDtypeStruct(sh, jnp.dtype(dtype)),
            "v": jax.ShapeDtypeStruct(sh, jnp.dtype(dtype))}


def decode_attend(q: jax.Array, cache_k: jax.Array, cache_v: jax.Array,
                  new_k: jax.Array, new_v: jax.Array, pos: jax.Array, *,
                  num_heads: int, window: int = 0,
                  shard_fn: ShardFn = no_shard):
    """Single-token decode. q: (B,1,H,Dh); cache_k/v: (B,S_max,KV,Dh);
    new_k/v: (B,1,KV,Dh) (already roped at ``pos``). Returns (out, k, v).

    ``pos`` may be a scalar (whole batch at one position — the dry-run
    cells) or a ``(B,)`` vector (the serving engine's mixed-length
    batches). Full attention writes slot ``pos``; windowed caches are
    rolling (slot = pos % window, S_max == window).

    Sharding (§Perf B2, flash-decoding layout): when kv-heads don't
    divide the model axis, the cache shards its LENGTH dim over
    ``model``; q is pinned replicated (tiny), scores stay length-sharded
    (softmax max/sum become small psums), and the output is resharded to
    heads late — so no cache-sized gather ever materializes."""
    s_max = cache_k.shape[1]
    q = shard_fn(q, ("batch", "rep", "rep", "rep"))
    cache_k = shard_fn(cache_k, ("batch", "seq_model", "rep", "rep"))
    cache_v = shard_fn(cache_v, ("batch", "seq_model", "rep", "rep"))
    slot = pos % s_max if window > 0 else pos
    if jnp.ndim(pos) == 0:
        cache_k = jax.lax.dynamic_update_slice_in_dim(cache_k, new_k, slot,
                                                      axis=1)
        cache_v = jax.lax.dynamic_update_slice_in_dim(cache_v, new_v, slot,
                                                      axis=1)
    else:
        b_idx = jnp.arange(q.shape[0])
        cache_k = cache_k.at[b_idx, slot].set(new_k[:, 0])
        cache_v = cache_v.at[b_idx, slot].set(new_v[:, 0])

    kx = expand_kv(cache_k, num_heads)
    vx = expand_kv(cache_v, num_heads)
    kx = shard_fn(kx, ("batch", "seq_model", "rep", "rep"))
    vx = shard_fn(vx, ("batch", "seq_model", "rep", "rep"))
    dh = q.shape[-1]
    scale = 1.0 / math.sqrt(dh)
    s = jnp.einsum("bqhd,bkhd->bhqk", q, kx,
                   preferred_element_type=jnp.float32) * scale
    s = shard_fn(s, ("batch", "rep", "rep", "seq_model"))
    j = jnp.arange(s_max)
    if window > 0:
        valid = ((pos[..., None] - j) % s_max) <= pos[..., None]   # rolling
    else:
        valid = j <= pos[..., None]
    # scalar pos -> (S,); vector pos -> (B,S)
    valid = valid[None, None, None, :] if valid.ndim == 1 \
        else valid[:, None, None, :]
    s = jnp.where(valid, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", p.astype(vx.dtype), vx,
                     preferred_element_type=jnp.float32).astype(q.dtype)
    out = shard_fn(out, ("batch", None, "heads", None))   # late reshard
    return out, cache_k, cache_v
