"""Token-choice top-k MoE with grouped (per-batch-row) sort dispatch.

Dispatch is computed independently per batch row (the GShard "group"
trick, G = batch): every (token, choice) gets a rank within its expert
*within its row* via a sort + change-point cummax (O(S·k log S·k), fully
vectorized over rows); ranks >= per-row capacity drop (scatter
``mode="drop"`` / gather ``mode="fill"`` keep it branch-free).

Why grouped: scatter indices become row-local, so under GSPMD the
dispatch buffer shards cleanly as (batch -> data, experts -> model) and
expert compute ("begd,edf") is LOCAL to each (data, model) shard pair —
no token ever crosses the data axis. The earlier global-flat dispatch
made GSPMD replicate the whole buffer ("involuntary full
rematerialization"): 939 s collective on dbrx train_4k vs this layout —
see EXPERIMENTS.md §Perf cell D. Expert FLOPs remain the true *active*
FLOPs (E x C x d x f with C ~= S*k/E), keeping the roofline honest.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.common import ParamSpec
from repro.models.layers import ShardFn, no_shard


def moe_specs(cfg: ModelConfig) -> dict:
    d, f, e = cfg.d_model, cfg.d_ff, cfg.moe.num_experts
    return {
        "router": ParamSpec((d, e), ("embed", None)),
        "wi": ParamSpec((e, d, f), ("experts", "embed", "mlp")),
        "wg": ParamSpec((e, d, f), ("experts", "embed", "mlp")),
        "wo": ParamSpec((e, f, d), ("experts", "mlp", "embed")),
    }


def capacity(tokens_per_group: int, cfg: ModelConfig) -> int:
    m = cfg.moe
    c = int(tokens_per_group * m.top_k / m.num_experts * m.capacity_factor)
    return max(16, -(-c // 16) * 16)      # sublane-aligned multiple


def _ranks_within_expert(eids: jax.Array) -> jax.Array:
    """eids: (B, N) expert ids. Returns (B, N) rank of each entry among
    same-expert entries of its row (stable order). Sort + change-point
    cummax — no segment_sum, vectorizes over rows."""
    b, n = eids.shape
    order = jnp.argsort(eids, axis=-1, stable=True)              # (B, N)
    sorted_e = jnp.take_along_axis(eids, order, axis=-1)
    idx = jnp.arange(n)
    change = jnp.concatenate(
        [jnp.ones((b, 1), bool), sorted_e[:, 1:] != sorted_e[:, :-1]],
        axis=-1)
    start = jnp.where(change, idx, 0)
    running_start = jax.lax.cummax(start, axis=1)
    rank_sorted = idx - running_start                            # (B, N)
    ranks = jnp.zeros_like(eids)
    brow = jnp.arange(b)[:, None]
    ranks = ranks.at[brow, order].set(rank_sorted)
    return ranks


def apply_experts(p: dict, buf: jax.Array, cfg: ModelConfig,
                  shard_fn: ShardFn = no_shard) -> jax.Array:
    """The expert-compute stage alone: grouped swiglu over a dispatched
    ``(B, E', C, D)`` buffer -> same-shape output buffer. ``E'`` may be a
    SLICE of the expert axis (the serving expert-parallel path exchanges
    tokens peer-major, slices the expert weights per peer, and calls
    this on the local slice); ``p["wi"]/["wg"]/["wo"]`` must then be the
    matching ``(E', ...)`` slices. Routing/dispatch/combine stay with
    :func:`apply_moe` — they are per-row and never cross peers."""
    dt = buf.dtype
    h = jnp.einsum("becd,edf->becf", buf, p["wi"].astype(dt))
    g = jnp.einsum("becd,edf->becf", buf, p["wg"].astype(dt))
    h = jax.nn.silu(g) * h
    h = shard_fn(h, ("batch", "experts", None, "mlp"))
    out_buf = jnp.einsum("becf,efd->becd", h, p["wo"].astype(dt))
    return shard_fn(out_buf, ("batch", "experts", None, None))


def apply_moe(p: dict, x: jax.Array, cfg: ModelConfig,
              shard_fn: ShardFn = no_shard, expert_fn=None):
    """x: (B, S, D) -> (out, aux_loss). Dispatch is per-row (grouped).

    ``expert_fn(p, buf, cfg, shard_fn) -> out_buf`` replaces ONLY the
    expert-compute stage (default :func:`apply_experts`) — the seam the
    serving dispatch uses to run expert-parallel compute with the
    dispatch/combine exchange on the CommBackend wire. Routing, the
    capacity scatter and the weighted combine are per-row and identical
    either way, so any ``expert_fn`` computing the same math is
    bit-exact."""
    m = cfg.moe
    b, s, d = x.shape
    k, e = m.top_k, m.num_experts
    c = capacity(s, cfg)
    dt = x.dtype

    # --- route (per token) ---
    logits = jnp.einsum("bsd,de->bse", x, p["router"].astype(dt))
    logits = logits.astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    weights, idx = jax.lax.top_k(probs, k)                       # (B,S,k)
    weights = weights / jnp.sum(weights, axis=-1, keepdims=True)

    # --- per-row rank within expert ---
    eids = idx.reshape(b, s * k)                                 # (B, S*k)
    ranks = _ranks_within_expert(eids)

    # --- dispatch: (B, E, C, D) buffer, over-capacity drops. vmap over
    # rows so the scatter carries operand-batching dims — GSPMD then
    # shards it over batch instead of replicating (§Perf cell D) ---
    tok_of = jnp.repeat(jnp.arange(s), k)                        # (S*k,)
    src = x[:, tok_of]                                           # (B,S*k,D)

    def scatter_row(src_r, eids_r, ranks_r):
        return jnp.zeros((e, c, d), dt).at[eids_r, ranks_r].set(
            src_r, mode="drop")

    buf = jax.vmap(scatter_row)(src, eids, ranks)
    buf = shard_fn(buf, ("batch", "experts", None, None))

    # --- expert compute (grouped swiglu; local per (data, model) shard) ---
    fn = expert_fn if expert_fn is not None else apply_experts
    out_buf = fn(p, buf, cfg, shard_fn)

    # --- combine: gather per-assignment outputs, weighted sum over k ---
    def gather_row(buf_r, eids_r, ranks_r):
        return buf_r.at[eids_r, ranks_r].get(mode="fill", fill_value=0)

    gathered = jax.vmap(gather_row)(out_buf, eids, ranks)
    gathered = gathered.reshape(b, s, k, d)
    out = jnp.einsum("bskd,bsk->bsd", gathered, weights.astype(dt))
    out = shard_fn(out, ("batch", "seq", None))

    # --- aux losses: load balance (Switch) + router z-loss ---
    me = jnp.mean(probs, axis=(0, 1))                            # (e,)
    oh = jax.nn.one_hot(eids, e, dtype=jnp.float32)              # (B,S*k,E)
    frac = jnp.mean(oh, axis=(0, 1))
    lb = e * jnp.sum(me * frac)
    z = jnp.mean(jnp.square(jax.nn.logsumexp(logits, axis=-1)))
    aux = 0.01 * lb + 1e-3 * z
    return out, aux
