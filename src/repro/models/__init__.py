from repro.models import api
from repro.models.api import (abstract, cache_specs, decode_step, init,
                              init_cache, input_specs, loss, prefill, specs)

__all__ = ["api", "abstract", "cache_specs", "decode_step", "init",
           "init_cache", "input_specs", "loss", "prefill", "specs"]
