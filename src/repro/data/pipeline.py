"""Tokenized LM data pipeline: deterministic, resumable, host-sharded.

Two sources behind one interface:

* ``SyntheticSource`` — endless pseudo-text (zipfian token draws with a
  Markov bigram flavour) generated *statelessly* from (seed, step, index):
  resuming at step k needs no iterator state, only k. This is the
  fault-tolerance property the trainer relies on (DESIGN.md §4).
* ``BinarySource`` — flat binary shards of token ids (np.uint16/uint32)
  read via memmap; sequences are sampled by a stateless hash of
  (seed, step, index) as well, so restart/resume and elastic re-sharding
  (different host count) never replay or skip data deterministically.

``make_batches`` yields {"tokens", "labels"} host-local slices of the
global batch; labels are next-token shifted.
"""
from __future__ import annotations

import hashlib
import os
from dataclasses import dataclass
from typing import Iterator, Optional, Sequence

import numpy as np

from repro.configs.base import RunConfig


def _hash_u64(*ints: int) -> int:
    h = hashlib.blake2b(np.asarray(ints, np.int64).tobytes(), digest_size=8)
    return int.from_bytes(h.digest(), "little")


class SyntheticSource:
    """Stateless synthetic token stream with a learnable structure
    (bigram-ish), so small-model training loss visibly decreases."""

    def __init__(self, vocab_size: int, seed: int = 0):
        self.vocab_size = vocab_size
        self.seed = seed

    def sequence(self, step: int, index: int, seq_len: int) -> np.ndarray:
        rng = np.random.default_rng(_hash_u64(self.seed, step, index))
        v = self.vocab_size
        # zipfian unigram pool + deterministic "grammar": tok[t] depends on
        # tok[t-1] through a fixed affine map with occasional resets.
        pool = (rng.zipf(1.5, size=seq_len + 1) - 1) % v
        toks = np.empty(seq_len + 1, np.int64)
        toks[0] = pool[0]
        for t in range(1, seq_len + 1):
            if pool[t] % 7 == 0:      # reset: draw from pool
                toks[t] = pool[t]
            else:                      # deterministic bigram successor
                toks[t] = (toks[t - 1] * 31 + 17) % v
        return toks

    def num_sequences(self) -> Optional[int]:
        return None                    # endless


class BinarySource:
    """Flat binary token shards (``*.bin``), memmapped. dtype is inferred
    from a sidecar ``<name>.meta`` ("uint16"/"uint32"), default uint16."""

    def __init__(self, path: str, seed: int = 0):
        self.seed = seed
        files = sorted(
            os.path.join(path, f) for f in os.listdir(path)
            if f.endswith(".bin")) if os.path.isdir(path) else [path]
        if not files:
            raise FileNotFoundError(f"no .bin shards under {path!r}")
        self.maps = []
        for f in files:
            dtype = np.uint16
            meta = f[:-4] + ".meta"
            if os.path.exists(meta):
                dtype = np.dtype(open(meta).read().strip())
            self.maps.append(np.memmap(f, dtype=dtype, mode="r"))
        self.sizes = np.array([m.shape[0] for m in self.maps], np.int64)
        self.total = int(self.sizes.sum())

    def sequence(self, step: int, index: int, seq_len: int) -> np.ndarray:
        start = _hash_u64(self.seed, step, index) % max(
            self.total - seq_len - 1, 1)
        # locate shard
        cum = np.cumsum(self.sizes)
        shard = int(np.searchsorted(cum, start, side="right"))
        off = start - (cum[shard - 1] if shard else 0)
        m = self.maps[shard]
        need = seq_len + 1
        if off + need <= m.shape[0]:
            return np.asarray(m[off:off + need], np.int64)
        a = np.asarray(m[off:], np.int64)
        b = self.maps[(shard + 1) % len(self.maps)][: need - a.shape[0]]
        return np.concatenate([a, np.asarray(b, np.int64)])

    def num_sequences(self) -> Optional[int]:
        return None


@dataclass
class DataConfig:
    seq_len: int
    global_batch: int
    host_index: int = 0
    num_hosts: int = 1

    @property
    def host_batch(self) -> int:
        assert self.global_batch % self.num_hosts == 0
        return self.global_batch // self.num_hosts


def make_source(run: RunConfig):
    if run.data_path:
        return BinarySource(run.data_path, run.data_seed)
    return SyntheticSource(run.model.vocab_size, run.data_seed)


def batch_at(source, dc: DataConfig, step: int) -> dict:
    """The host-local batch for ``step`` — pure function of (source config,
    step): this is what makes checkpoint-resume exact."""
    lo = dc.host_index * dc.host_batch
    seqs = np.stack([source.sequence(step, lo + i, dc.seq_len)
                     for i in range(dc.host_batch)])
    return {"tokens": seqs[:, :-1].astype(np.int32),
            "labels": seqs[:, 1:].astype(np.int32)}


def make_batches(source, dc: DataConfig, start_step: int = 0
                 ) -> Iterator[dict]:
    step = start_step
    while True:
        yield batch_at(source, dc, step)
        step += 1
