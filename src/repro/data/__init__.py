from repro.data.pipeline import (BinarySource, DataConfig, SyntheticSource,
                                 batch_at, make_batches, make_source)

__all__ = ["BinarySource", "DataConfig", "SyntheticSource", "batch_at",
           "make_batches", "make_source"]
